//! The mutable fault overlay the engine consults at run time.
//!
//! [`FaultRuntime`] sits between the static `NodeProfile`/`LinkModel`
//! tables and the DES hot path. The engine schedules one calendar-queue
//! wake per scenario event (plus the chained toggles a flapping link
//! generates); each wake calls [`FaultRuntime::on_event`], which advances
//! that event's state machine (`Pending → Active → Done`) and pushes or
//! pops the corresponding overlay entry. Effective per-node profiles and
//! link modifiers are **recomputed by folding the active set from the
//! static tables on every transition** — transitions are rare (a handful
//! per run), queries are per-simstep — so the hot path reads cached
//! tables and the fold is always evaluated from the identity in event
//! order, making effective factors independent of activation history
//! (pinned by `tests/prop_faults.rs` against a reference fold).
//!
//! Determinism: the runtime consumes no randomness at all — every
//! transition time is a pure function of the scenario — so fault runs are
//! reproducible from `SimConfig::seed` exactly like fault-free ones.

use crate::net::NodeProfile;
use crate::util::Nanos;

use super::scenario::{FaultKind, FaultScenario, LinkFault, ScenarioPhase};

/// Per-event state machine. Windowed degradations traverse all three
/// states; commands jump straight to `Done`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EventState {
    Pending,
    Active { flap_on: bool },
    Done,
}

/// Block-contiguous clique of `node` when the allocation is split into
/// `cliques` blocks (every clique non-empty for `cliques <= n_nodes`).
pub fn clique_of(node: usize, cliques: usize, n_nodes: usize) -> usize {
    node * cliques / n_nodes.max(1)
}

/// Mutable overlay over the static per-node profile table.
pub struct FaultRuntime {
    scenario: FaultScenario,
    statics: Vec<NodeProfile>,
    state: Vec<EventState>,
    /// Bitmask of currently-active events.
    active: ScenarioPhase,
    /// Overlay stack depth: count of active windowed effects. Guarded
    /// against underflow — a pop without a matching push is a state
    /// machine bug, not a recoverable condition.
    depth: usize,
    /// Cached fold of active `DegradeNode` faults over `statics`.
    eff_nodes: Vec<NodeProfile>,
    /// Cached per-node link modifiers from active "on" flaps.
    node_link: Vec<LinkFault>,
    /// Cached fold of active congestion storms (internode links).
    storm: LinkFault,
    /// Active partition: `(cliques, cut)`; multiple concurrent partitions
    /// fold into the finest clique count with stacked cuts.
    partition: Option<(usize, LinkFault)>,
    n_nodes: usize,
}

impl FaultRuntime {
    /// Validate and load a scenario over the static profile table.
    pub fn new(scenario: FaultScenario, statics: Vec<NodeProfile>) -> Self {
        scenario.validate(statics.len());
        let n = statics.len();
        Self {
            state: vec![EventState::Pending; scenario.events.len()],
            active: ScenarioPhase::QUIESCENT,
            depth: 0,
            eff_nodes: statics.clone(),
            node_link: vec![LinkFault::IDENTITY; n],
            storm: LinkFault::IDENTITY,
            partition: None,
            n_nodes: n,
            statics,
            scenario,
        }
    }

    /// The loaded scenario (engine compile reads event start times).
    pub fn scenario(&self) -> &FaultScenario {
        &self.scenario
    }

    /// Events currently active.
    pub fn phase(&self) -> ScenarioPhase {
        self.active
    }

    /// Overlay stack depth (active windowed effects).
    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn is_active(&self, k: usize) -> bool {
        matches!(self.state[k], EventState::Active { .. })
    }

    /// Is flap event `k` currently in its degraded sub-phase?
    /// (Always false for non-flap events; test/instrumentation hook.)
    pub fn flap_on(&self, k: usize) -> bool {
        matches!(self.state[k], EventState::Active { flap_on: true })
            && matches!(self.scenario.events[k].kind, FaultKind::FlapLink { .. })
    }

    /// Effective profile of `node` under the current overlay.
    #[inline]
    pub fn node_profile(&self, node: usize) -> &NodeProfile {
        &self.eff_nodes[node]
    }

    /// All effective node profiles (tests / reporting).
    pub fn effective_nodes(&self) -> &[NodeProfile] {
        &self.eff_nodes
    }

    /// Aggregate link-level modifiers for a channel between `src_node`
    /// and `dst_node`. Flap modifiers follow their node onto every
    /// touching link; storms and partitions only affect internode
    /// (`crossnode`) links.
    #[inline]
    pub fn link_mods(&self, src_node: usize, dst_node: usize, crossnode: bool) -> LinkFault {
        let mut f = self.node_link[src_node];
        if dst_node != src_node {
            f = f.stack(&self.node_link[dst_node]);
        }
        if crossnode {
            f = f.stack(&self.storm);
            if let Some((cliques, cut)) = self.partition {
                if clique_of(src_node, cliques, self.n_nodes)
                    != clique_of(dst_node, cliques, self.n_nodes)
                {
                    f = f.stack(&cut);
                }
            }
        }
        f
    }

    /// Advance event `k`'s state machine at time `t`; returns the next
    /// wake time the caller must schedule for this event, if any. Wakes
    /// for events a command already deactivated are no-ops — the overlay
    /// never pops what is not pushed.
    pub fn on_event(&mut self, k: usize, t: Nanos) -> Option<Nanos> {
        let ev = self.scenario.events[k];
        match self.state[k] {
            EventState::Done => None,
            EventState::Pending => {
                if ev.kind.is_instant() {
                    self.state[k] = EventState::Done;
                    match ev.kind {
                        FaultKind::RestoreNode { node } => self.deactivate_node(node, t),
                        FaultKind::Heal => self.deactivate_all(t),
                        FaultKind::ProcJoin { proc } => self.deactivate_proc(proc, t),
                        _ => unreachable!("only commands are instant"),
                    }
                    self.recompute();
                    return None;
                }
                self.state[k] = EventState::Active { flap_on: true };
                self.active = self.active.union(ScenarioPhase::single(k));
                self.depth += 1;
                self.recompute();
                let end = ev.end();
                match ev.kind {
                    FaultKind::FlapLink { on_for, .. } => {
                        Some(t.saturating_add(on_for).min(end))
                    }
                    _ if end == Nanos::MAX => None,
                    _ => Some(end),
                }
            }
            EventState::Active { flap_on } => {
                if t >= ev.end() {
                    self.deactivate(k);
                    self.recompute();
                    return None;
                }
                if let FaultKind::FlapLink {
                    on_for, off_for, ..
                } = ev.kind
                {
                    let now_on = !flap_on;
                    self.state[k] = EventState::Active { flap_on: now_on };
                    self.recompute();
                    let step = if now_on { on_for } else { off_for };
                    Some(t.saturating_add(step).min(ev.end()))
                } else {
                    // Spurious early wake (the engine never produces one);
                    // keep waiting for the window end.
                    Some(ev.end())
                }
            }
        }
    }

    /// Pop event `k` off the overlay if (and only if) it is active.
    fn deactivate(&mut self, k: usize) {
        if matches!(self.state[k], EventState::Active { .. }) {
            self.state[k] = EventState::Done;
            self.active = self.active.remove(k);
            self.depth = self
                .depth
                .checked_sub(1)
                .expect("overlay pop without matching push");
        }
    }

    /// Cancel event `k` if a command covers it before its own onset wake
    /// ran: a window whose start is at (or before) the command time but
    /// whose wake sits later in the same same-timestamp batch is still
    /// `Pending` — mark it `Done` directly, never touching `active`/
    /// `depth` (it was never pushed). Without this, a `Heal` sharing a
    /// calendar wake batch with the onset it cancels left the onset to
    /// activate afterwards and stay `Active` past the command.
    fn cancel_pending(&mut self, k: usize, t: Nanos) {
        if self.state[k] == EventState::Pending && self.scenario.events[k].start <= t {
            self.state[k] = EventState::Done;
        }
    }

    /// `RestoreNode`: deactivate active (or same-batch pending)
    /// degradations targeting `node`.
    fn deactivate_node(&mut self, node: usize, t: Nanos) {
        for k in 0..self.scenario.events.len() {
            let hit = match self.scenario.events[k].kind {
                FaultKind::DegradeNode { node: n, .. } | FaultKind::FlapLink { node: n, .. } => {
                    n == node
                }
                _ => false,
            };
            if hit {
                self.deactivate(k);
                self.cancel_pending(k, t);
            }
        }
    }

    /// `ProcJoin`: deactivate active (or same-batch pending) `ProcLeave`
    /// windows targeting `proc`.
    fn deactivate_proc(&mut self, proc: usize, t: Nanos) {
        for k in 0..self.scenario.events.len() {
            if matches!(self.scenario.events[k].kind, FaultKind::ProcLeave { proc: q } if q == proc)
            {
                self.deactivate(k);
                self.cancel_pending(k, t);
            }
        }
    }

    /// `Heal`: deactivate every windowed degradation (commands hold no
    /// window and are left to fire on their own).
    fn deactivate_all(&mut self, t: Nanos) {
        for k in 0..self.scenario.events.len() {
            if self.scenario.events[k].kind.is_instant() {
                continue;
            }
            self.deactivate(k);
            self.cancel_pending(k, t);
        }
    }

    /// Rebuild every cached effective table as a fold of the active set
    /// over the static tables, in event order. When nothing is active the
    /// caches equal the static tables bit-for-bit.
    fn recompute(&mut self) {
        self.eff_nodes.copy_from_slice(&self.statics);
        for f in self.node_link.iter_mut() {
            *f = LinkFault::IDENTITY;
        }
        self.storm = LinkFault::IDENTITY;
        self.partition = None;
        for k in 0..self.scenario.events.len() {
            let flap_on = match self.state[k] {
                EventState::Active { flap_on } => flap_on,
                _ => continue,
            };
            match self.scenario.events[k].kind {
                FaultKind::DegradeNode { node, fault } => {
                    let base = self.eff_nodes[node];
                    self.eff_nodes[node] = fault.apply(&base);
                }
                FaultKind::FlapLink { node, fault, .. } => {
                    if flap_on {
                        self.node_link[node] = self.node_link[node].stack(&fault);
                    }
                }
                FaultKind::CongestionStorm { fault } => {
                    self.storm = self.storm.stack(&fault);
                }
                FaultKind::PartitionCliques { cliques, cut } => {
                    self.partition = Some(match self.partition {
                        None => (cliques, cut),
                        Some((c, prev)) => (c.max(cliques), prev.stack(&cut)),
                    });
                }
                // Churn is interpreted by the engine's live-set
                // reconciliation; it never touches profile/link tables.
                FaultKind::RestoreNode { .. }
                | FaultKind::Heal
                | FaultKind::ProcLeave { .. }
                | FaultKind::ProcJoin { .. } => {}
            }
        }
    }

    /// Is process `proc` currently departed (any active `ProcLeave`
    /// naming it)? The engine reconciles its live set against this after
    /// every scenario transition.
    pub fn is_departed(&self, proc: usize) -> bool {
        self.scenario.events.iter().enumerate().any(|(k, ev)| {
            matches!(ev.kind, FaultKind::ProcLeave { proc: q } if q == proc)
                && matches!(self.state[k], EventState::Active { .. })
        })
    }

    /// Serialize the per-event state machine for a checkpoint (one byte
    /// per event: 0 pending, 1 active/off, 2 active/on, 3 done).
    pub fn export_states(&self) -> Vec<u8> {
        self.state
            .iter()
            .map(|s| match s {
                EventState::Pending => 0,
                EventState::Active { flap_on: false } => 1,
                EventState::Active { flap_on: true } => 2,
                EventState::Done => 3,
            })
            .collect()
    }

    /// Restore the per-event state machine from [`Self::export_states`]
    /// bytes, rebuilding the active mask, depth, and cached tables.
    /// Returns `false` (leaving the runtime untouched) on malformed
    /// input.
    pub fn restore_states(&mut self, states: &[u8]) -> bool {
        if states.len() != self.scenario.events.len() {
            return false;
        }
        let mut decoded = Vec::with_capacity(states.len());
        for &b in states {
            decoded.push(match b {
                0 => EventState::Pending,
                1 => EventState::Active { flap_on: false },
                2 => EventState::Active { flap_on: true },
                3 => EventState::Done,
                _ => return false,
            });
        }
        self.state = decoded;
        self.active = ScenarioPhase::QUIESCENT;
        self.depth = 0;
        for (k, s) in self.state.iter().enumerate() {
            if matches!(s, EventState::Active { .. }) {
                self.active = self.active.union(ScenarioPhase::single(k));
                self.depth += 1;
            }
        }
        self.recompute();
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::scenario::{FaultScenario, NodeFault, ALWAYS};

    fn healthy(n: usize) -> Vec<NodeProfile> {
        vec![NodeProfile::healthy(); n]
    }

    #[test]
    fn clique_blocks_are_contiguous_and_complete() {
        for (n, c) in [(4, 2), (16, 4), (7, 3), (64, 2)] {
            let cliques: Vec<usize> = (0..n).map(|i| clique_of(i, c, n)).collect();
            // Monotone non-decreasing (contiguous blocks)…
            assert!(cliques.windows(2).all(|w| w[0] <= w[1]), "{cliques:?}");
            // …covering every clique index.
            let mut seen = cliques.clone();
            seen.dedup();
            assert_eq!(seen, (0..c).collect::<Vec<_>>(), "n={n} c={c}");
        }
    }

    #[test]
    fn degrade_window_activates_and_expires() {
        let sc = FaultScenario::default().with(100, 50, FaultKind::DegradeNode {
            node: 1,
            fault: NodeFault::lac417(),
        });
        let mut rt = FaultRuntime::new(sc, healthy(4));
        assert!(rt.phase().is_quiescent());
        assert_eq!(rt.depth(), 0);

        let next = rt.on_event(0, 100);
        assert_eq!(next, Some(150));
        assert!(rt.phase().contains(0));
        assert_eq!(rt.depth(), 1);
        assert_eq!(
            rt.node_profile(1).latency_factor,
            NodeProfile::faulty_lac417().latency_factor
        );
        // Untouched nodes stay bitwise static.
        assert_eq!(
            rt.node_profile(0).latency_factor.to_bits(),
            NodeProfile::healthy().latency_factor.to_bits()
        );

        assert_eq!(rt.on_event(0, 150), None);
        assert!(rt.phase().is_quiescent());
        assert_eq!(rt.depth(), 0);
        assert_eq!(
            rt.node_profile(1).latency_factor.to_bits(),
            NodeProfile::healthy().latency_factor.to_bits()
        );
    }

    #[test]
    fn heal_deactivates_and_stale_end_wake_is_noop() {
        let sc = FaultScenario::default()
            .with(10, 100, FaultKind::CongestionStorm {
                fault: LinkFault::storm(),
            })
            .with(50, 0, FaultKind::Heal);
        let mut rt = FaultRuntime::new(sc, healthy(2));
        assert_eq!(rt.on_event(0, 10), Some(110));
        assert_eq!(rt.link_mods(0, 1, true).latency_factor, 25.0);
        assert_eq!(rt.on_event(1, 50), None); // heal
        assert!(rt.phase().is_quiescent());
        assert_eq!(rt.depth(), 0);
        assert_eq!(rt.link_mods(0, 1, true), LinkFault::IDENTITY);
        // The storm's own end wake still arrives at 110: must be a no-op.
        assert_eq!(rt.on_event(0, 110), None);
        assert_eq!(rt.depth(), 0);
    }

    #[test]
    fn restore_node_is_selective() {
        let sc = FaultScenario::default()
            .with(0, ALWAYS, FaultKind::DegradeNode {
                node: 0,
                fault: NodeFault::lac417(),
            })
            .with(0, ALWAYS, FaultKind::DegradeNode {
                node: 1,
                fault: NodeFault::lac417(),
            })
            .with(20, 0, FaultKind::RestoreNode { node: 0 });
        let mut rt = FaultRuntime::new(sc, healthy(2));
        assert_eq!(rt.on_event(0, 0), None); // ALWAYS: no end wake
        assert_eq!(rt.on_event(1, 0), None);
        assert_eq!(rt.depth(), 2);
        assert_eq!(rt.on_event(2, 20), None);
        assert!(!rt.phase().contains(0));
        assert!(rt.phase().contains(1));
        assert_eq!(rt.depth(), 1);
        assert_eq!(
            rt.node_profile(0).latency_factor.to_bits(),
            NodeProfile::healthy().latency_factor.to_bits()
        );
        assert!(rt.node_profile(1).latency_factor > 100.0);
    }

    #[test]
    fn flap_toggles_until_window_end() {
        let sc = FaultScenario::flapping_clique(0, 100, 50, 10, 5);
        let mut rt = FaultRuntime::new(sc, healthy(2));
        // Activation: on for 10.
        assert_eq!(rt.on_event(0, 100), Some(110));
        assert!(rt.link_mods(0, 1, true).extra_drop_prob > 0.0);
        // Off for 5.
        assert_eq!(rt.on_event(0, 110), Some(115));
        assert_eq!(rt.link_mods(0, 1, true), LinkFault::IDENTITY);
        assert!(rt.phase().contains(0), "flap stays phase-active while off");
        // On again for 10.
        assert_eq!(rt.on_event(0, 115), Some(125));
        assert!(rt.link_mods(0, 1, true).extra_drop_prob > 0.0);
        // …and the chain clamps to the window end (150).
        assert_eq!(rt.on_event(0, 125), Some(130));
        assert_eq!(rt.on_event(0, 130), Some(140));
        assert_eq!(rt.on_event(0, 140), Some(145));
        assert_eq!(rt.on_event(0, 145), Some(150));
        assert_eq!(rt.on_event(0, 150), None);
        assert!(rt.phase().is_quiescent());
        assert_eq!(rt.link_mods(0, 1, true), LinkFault::IDENTITY);
    }

    #[test]
    fn partition_cuts_cross_clique_internode_links_only() {
        let sc = FaultScenario::partition_and_heal(2, 0, 100);
        let mut rt = FaultRuntime::new(sc, healthy(4));
        assert_eq!(rt.on_event(0, 0), None); // ALWAYS + explicit heal
        // Nodes {0,1} vs {2,3}: cross-clique internode links are cut…
        assert_eq!(rt.link_mods(0, 2, true).extra_drop_prob, 1.0);
        assert_eq!(rt.link_mods(1, 3, true).extra_drop_prob, 1.0);
        // …same-clique and intranode links are untouched.
        assert_eq!(rt.link_mods(0, 1, true), LinkFault::IDENTITY);
        assert_eq!(rt.link_mods(0, 2, false), LinkFault::IDENTITY);
        assert_eq!(rt.on_event(1, 100), None); // heal
        assert_eq!(rt.link_mods(0, 2, true), LinkFault::IDENTITY);
    }

    #[test]
    fn storm_hits_internode_links_only() {
        let sc = FaultScenario::congestion_storm(0, 10);
        let mut rt = FaultRuntime::new(sc, healthy(2));
        rt.on_event(0, 0);
        assert_eq!(rt.link_mods(0, 1, true).latency_factor, 25.0);
        assert_eq!(rt.link_mods(0, 0, false), LinkFault::IDENTITY);
    }

    /// The depth-guard edge the same-timestamp batch exposes: a `Heal`
    /// whose wake is processed *before* the onset it cancels (same t,
    /// lower seq) must leave the onset `Done`, not let it activate and
    /// stay `Active` forever.
    #[test]
    fn heal_cancels_same_timestamp_pending_onset() {
        // Event 0: heal at t=100. Event 1: ALWAYS storm also at t=100.
        let sc = FaultScenario::default()
            .with(100, 0, FaultKind::Heal)
            .with(100, ALWAYS, FaultKind::CongestionStorm {
                fault: LinkFault::storm(),
            });
        let mut rt = FaultRuntime::new(sc, healthy(2));
        assert_eq!(rt.on_event(0, 100), None); // heal first in the batch
        assert_eq!(rt.on_event(1, 100), None); // cancelled onset: no-op
        assert!(rt.phase().is_quiescent());
        assert_eq!(rt.depth(), 0);
        assert!(!rt.is_active(1));
        assert_eq!(rt.link_mods(0, 1, true), LinkFault::IDENTITY);
    }

    #[test]
    fn restore_node_cancels_same_timestamp_pending_onset_selectively() {
        let sc = FaultScenario::default()
            .with(50, 0, FaultKind::RestoreNode { node: 1 })
            .with(50, ALWAYS, FaultKind::DegradeNode {
                node: 1,
                fault: NodeFault::lac417(),
            })
            .with(50, ALWAYS, FaultKind::DegradeNode {
                node: 0,
                fault: NodeFault::lac417(),
            });
        let mut rt = FaultRuntime::new(sc, healthy(2));
        assert_eq!(rt.on_event(0, 50), None);
        assert_eq!(rt.on_event(1, 50), None); // cancelled (node 1)
        rt.on_event(2, 50); // unrelated node: activates normally
        assert!(!rt.is_active(1));
        assert!(rt.is_active(2));
        assert_eq!(rt.depth(), 1);
        assert_eq!(
            rt.node_profile(1).latency_factor.to_bits(),
            NodeProfile::healthy().latency_factor.to_bits()
        );
        assert!(rt.node_profile(0).latency_factor > 100.0);
    }

    /// Commands must not cancel *future* onsets: a window opening after
    /// the command time still activates.
    #[test]
    fn heal_leaves_future_onsets_pending() {
        let sc = FaultScenario::default()
            .with(100, 0, FaultKind::Heal)
            .with(200, 50, FaultKind::CongestionStorm {
                fault: LinkFault::storm(),
            });
        let mut rt = FaultRuntime::new(sc, healthy(2));
        assert_eq!(rt.on_event(0, 100), None);
        assert_eq!(rt.on_event(1, 200), Some(250));
        assert!(rt.is_active(1));
        assert_eq!(rt.depth(), 1);
    }

    #[test]
    fn proc_leave_window_and_join_command() {
        let sc = FaultScenario::default()
            .with(100, 50, FaultKind::ProcLeave { proc: 3 })
            .with(100, ALWAYS, FaultKind::ProcLeave { proc: 5 })
            .with(200, 0, FaultKind::ProcJoin { proc: 5 });
        let mut rt = FaultRuntime::new(sc, healthy(2));
        assert!(!rt.is_departed(3));
        assert_eq!(rt.on_event(0, 100), Some(150));
        assert_eq!(rt.on_event(1, 100), None); // ALWAYS: no end wake
        assert!(rt.is_departed(3) && rt.is_departed(5));
        assert_eq!(rt.depth(), 2);
        // Churn never touches the profile/link tables.
        assert_eq!(
            rt.node_profile(0).latency_factor.to_bits(),
            NodeProfile::healthy().latency_factor.to_bits()
        );
        assert_eq!(rt.link_mods(0, 1, true), LinkFault::IDENTITY);
        // Window expiry rejoins proc 3.
        assert_eq!(rt.on_event(0, 150), None);
        assert!(!rt.is_departed(3));
        // Explicit join re-admits proc 5.
        assert_eq!(rt.on_event(2, 200), None);
        assert!(!rt.is_departed(5));
        assert_eq!(rt.depth(), 0);
        assert!(rt.phase().is_quiescent());
    }

    #[test]
    fn join_cancels_same_timestamp_pending_leave() {
        let sc = FaultScenario::default()
            .with(100, 0, FaultKind::ProcJoin { proc: 2 })
            .with(100, ALWAYS, FaultKind::ProcLeave { proc: 2 });
        let mut rt = FaultRuntime::new(sc, healthy(2));
        assert_eq!(rt.on_event(0, 100), None);
        assert_eq!(rt.on_event(1, 100), None);
        assert!(!rt.is_departed(2));
        assert!(rt.phase().is_quiescent());
    }

    #[test]
    fn overlay_states_round_trip() {
        let sc = FaultScenario::default()
            .with(0, ALWAYS, FaultKind::DegradeNode {
                node: 1,
                fault: NodeFault::lac417(),
            })
            .with(10, 100, FaultKind::FlapLink {
                node: 0,
                on_for: 10,
                off_for: 5,
                fault: LinkFault::flap(),
            })
            .with(500, 0, FaultKind::Heal);
        let mut rt = FaultRuntime::new(sc.clone(), healthy(2));
        rt.on_event(0, 0);
        rt.on_event(1, 10); // flap on
        rt.on_event(1, 20); // flap off
        let states = rt.export_states();
        let mut rt2 = FaultRuntime::new(sc, healthy(2));
        assert!(rt2.restore_states(&states));
        assert_eq!(rt2.depth(), rt.depth());
        assert_eq!(rt2.phase(), rt.phase());
        assert_eq!(rt2.flap_on(1), rt.flap_on(1));
        for n in 0..2 {
            assert_eq!(
                rt2.node_profile(n).latency_factor.to_bits(),
                rt.node_profile(n).latency_factor.to_bits()
            );
            assert_eq!(
                rt2.link_mods(n, 1 - n, true).latency_factor.to_bits(),
                rt.link_mods(n, 1 - n, true).latency_factor.to_bits()
            );
        }
        assert!(!rt2.restore_states(&[0]), "length mismatch rejected");
        assert!(!rt2.restore_states(&[9, 9, 9]), "bad tag rejected");
    }
}
