//! Declarative fault-scenario timelines.
//!
//! A [`FaultScenario`] is a list of [`FaultEvent`]s — each a degradation
//! (or command) with a start time, a duration, and intensity parameters.
//! Scenarios are *data*: the engine compiles them into calendar-queue wake
//! events at construction ([`crate::sim::SimConfig::scenario`]) and the
//! [`crate::faults::FaultRuntime`] overlay interprets them at run time,
//! deterministically — the same `(scenario, seed)` pair always produces a
//! bit-identical simulation.
//!
//! Two event families exist:
//!
//! * **windowed degradations** (`DegradeNode`, `FlapLink`,
//!   `CongestionStorm`, `PartitionCliques`) — active over
//!   `[start, start + duration)`, or until a command deactivates them
//!   ([`ALWAYS`] never self-expires);
//! * **instantaneous commands** (`RestoreNode`, `Heal`) — fire once at
//!   `start` and deactivate currently-active degradations.
//!
//! [`ScenarioPhase`] is the bitmask of scenario events active at an
//! instant (or over a snapshot window); the QoS layer carries it on every
//! observation so metrics can be attributed to the faults in force when
//! they were measured (the paper's "distribution of quality of service
//! ... and over time" concern, §III-G / Conclusion).

use crate::net::NodeProfile;
use crate::util::{Nanos, MILLI};

/// Duration sentinel: the effect never self-expires — it stays active
/// until an explicit `RestoreNode`/`Heal` command or the end of the run.
pub const ALWAYS: Nanos = Nanos::MAX;

/// The set of scenario events active at an instant (or over a window),
/// as a bitmask of event indices — scenarios are capped at 64 events.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct ScenarioPhase(u64);

impl ScenarioPhase {
    /// No scenario fault active (also the phase of every static-profile
    /// run).
    pub const QUIESCENT: ScenarioPhase = ScenarioPhase(0);

    /// Phase containing exactly scenario event `event`.
    pub fn single(event: usize) -> Self {
        assert!(event < 64, "scenario events are capped at 64");
        ScenarioPhase(1 << event)
    }

    pub fn union(self, other: Self) -> Self {
        ScenarioPhase(self.0 | other.0)
    }

    pub fn remove(self, event: usize) -> Self {
        if event >= 64 {
            return self;
        }
        ScenarioPhase(self.0 & !(1u64 << event))
    }

    pub fn contains(self, event: usize) -> bool {
        event < 64 && self.0 & (1u64 << event) != 0
    }

    pub fn is_quiescent(self) -> bool {
        self.0 == 0
    }

    /// Number of active events.
    pub fn len(self) -> usize {
        self.0.count_ones() as usize
    }

    pub fn is_empty(self) -> bool {
        self.is_quiescent()
    }

    pub fn bits(self) -> u64 {
        self.0
    }

    /// Indices of the active events, ascending.
    pub fn events(self) -> impl Iterator<Item = usize> {
        (0..64).filter(move |&i| self.0 & (1u64 << i) != 0)
    }
}

/// Node-scoped degradation factors, folded over the static
/// [`NodeProfile`]: multiplicative speed/latency, additive (clamped)
/// drop, max-combined jitter and stall scale. Applying the identity fold
/// leaves a profile bit-identical.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeFault {
    /// Multiplies the profile's compute-duration factor.
    pub speed_factor: f64,
    /// Raises (never lowers) per-update lognormal jitter.
    pub jitter_sigma: f64,
    /// Raises (never lowers) the mean OS-noise stall duration, ns.
    pub stall_mean_ns: f64,
    /// Multiplies latency of links touching the node.
    pub latency_factor: f64,
    /// Adds per-send drop probability on links touching the node.
    pub extra_drop_prob: f64,
}

impl NodeFault {
    pub fn identity() -> Self {
        Self {
            speed_factor: 1.0,
            jitter_sigma: 0.0,
            stall_mean_ns: 0.0,
            latency_factor: 1.0,
            extra_drop_prob: 0.0,
        }
    }

    /// Degradation factors reproducing the paper's `lac-417` (§III-G):
    /// over a healthy profile the effective profile equals
    /// [`NodeProfile::faulty_lac417`] exactly.
    pub fn lac417() -> Self {
        Self {
            speed_factor: 1.35,
            jitter_sigma: 0.8,
            stall_mean_ns: 180.0 * MILLI as f64,
            latency_factor: 400.0,
            extra_drop_prob: 0.35,
        }
    }

    /// Near-total mid-run failure: the node crawls, its links drop almost
    /// everything — fail-stop as seen by a best-effort neighbor.
    pub fn fail_stop() -> Self {
        Self {
            speed_factor: 25.0,
            jitter_sigma: 1.0,
            stall_mean_ns: 400.0 * MILLI as f64,
            latency_factor: 2_000.0,
            extra_drop_prob: 0.95,
        }
    }

    /// Fold this fault onto a base profile.
    pub fn apply(&self, base: &NodeProfile) -> NodeProfile {
        NodeProfile {
            speed_factor: base.speed_factor * self.speed_factor,
            jitter_sigma: base.jitter_sigma.max(self.jitter_sigma),
            stall_prob: base.stall_prob,
            stall_mean_ns: base.stall_mean_ns.max(self.stall_mean_ns),
            latency_factor: base.latency_factor * self.latency_factor,
            extra_drop_prob: (base.extra_drop_prob + self.extra_drop_prob).min(1.0),
        }
    }
}

/// Link-scoped degradation: multiplicative latency, additive (clamped)
/// drop. Stacks associatively enough for the overlay's recompute-by-fold
/// (the fold is always evaluated from the identity in event order, so
/// float non-associativity never produces order-dependent results).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkFault {
    pub latency_factor: f64,
    pub extra_drop_prob: f64,
}

impl LinkFault {
    pub const IDENTITY: LinkFault = LinkFault {
        latency_factor: 1.0,
        extra_drop_prob: 0.0,
    };

    /// A cluster-fabric congestion storm: heavy latency inflation plus
    /// moderate loss on every internode link.
    pub fn storm() -> Self {
        Self {
            latency_factor: 25.0,
            extra_drop_prob: 0.15,
        }
    }

    /// One flapping endpoint: bursts of severe latency and loss while the
    /// link is "down-ish".
    pub fn flap() -> Self {
        Self {
            latency_factor: 60.0,
            extra_drop_prob: 0.5,
        }
    }

    /// A clean partition cut: nothing crosses.
    pub fn cut() -> Self {
        Self {
            latency_factor: 1.0,
            extra_drop_prob: 1.0,
        }
    }

    /// Stack another fault on top of this one.
    pub fn stack(&self, other: &LinkFault) -> LinkFault {
        LinkFault {
            latency_factor: self.latency_factor * other.latency_factor,
            extra_drop_prob: (self.extra_drop_prob + other.extra_drop_prob).min(1.0),
        }
    }
}

/// What one scenario event does.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Degrade one node's compute and links by `fault` for the event
    /// window.
    DegradeNode { node: usize, fault: NodeFault },
    /// Command: deactivate every active `DegradeNode`/`FlapLink` targeting
    /// `node`.
    RestoreNode { node: usize },
    /// Links touching `node` oscillate: degraded by `fault` for `on_for`,
    /// clean for `off_for`, repeating across the event window.
    FlapLink {
        node: usize,
        on_for: Nanos,
        off_for: Nanos,
        fault: LinkFault,
    },
    /// Degrade every internode link by `fault` for the event window.
    CongestionStorm { fault: LinkFault },
    /// Split the nodes into `cliques` contiguous blocks; internode links
    /// crossing a block boundary suffer `cut` for the event window.
    PartitionCliques { cliques: usize, cut: LinkFault },
    /// Command: deactivate every active degradation.
    Heal,
    /// Membership churn: process `proc` leaves the allocation for the
    /// event window (its channels stop accepting sends; barrier
    /// protocols exclude it), rejoining when the window closes —
    /// [`ALWAYS`] models a permanent crash unless a [`FaultKind::ProcJoin`]
    /// re-admits it. Scoped to *processes*, not nodes — validated against
    /// the process count by [`FaultScenario::validate_procs`].
    ProcLeave { proc: usize },
    /// Command: re-admit a departed process immediately (deactivates
    /// every active `ProcLeave` targeting `proc`).
    ProcJoin { proc: usize },
}

impl FaultKind {
    /// Commands fire once and hold no window of their own.
    pub fn is_instant(&self) -> bool {
        matches!(
            self,
            FaultKind::RestoreNode { .. } | FaultKind::Heal | FaultKind::ProcJoin { .. }
        )
    }

    /// Membership-churn events live in process space (not node space) and
    /// are interpreted by the engine's live-set reconciliation rather
    /// than the profile/link fold.
    pub fn is_churn(&self) -> bool {
        matches!(
            self,
            FaultKind::ProcLeave { .. } | FaultKind::ProcJoin { .. }
        )
    }

    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::DegradeNode { .. } => "degrade",
            FaultKind::RestoreNode { .. } => "restore",
            FaultKind::FlapLink { .. } => "flap",
            FaultKind::CongestionStorm { .. } => "storm",
            FaultKind::PartitionCliques { .. } => "partition",
            FaultKind::Heal => "heal",
            FaultKind::ProcLeave { .. } => "leave",
            FaultKind::ProcJoin { .. } => "join",
        }
    }
}

/// One timed entry of a scenario.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// Virtual time the event fires (window opens, or command executes).
    pub start: Nanos,
    /// Window length for degradations ([`ALWAYS`] never self-expires);
    /// ignored for commands.
    pub duration: Nanos,
    pub kind: FaultKind,
}

impl FaultEvent {
    /// End of the event's window (saturating; [`ALWAYS`] yields
    /// `Nanos::MAX`).
    pub fn end(&self) -> Nanos {
        self.start.saturating_add(self.duration)
    }
}

/// A declarative timeline of fault events. The default (empty) scenario
/// leaves the engine on the static-profile path, bit-identically.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultScenario {
    pub events: Vec<FaultEvent>,
}

impl FaultScenario {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Builder: append one event.
    pub fn with(mut self, start: Nanos, duration: Nanos, kind: FaultKind) -> Self {
        self.events.push(FaultEvent {
            start,
            duration,
            kind,
        });
        self
    }

    /// Panic on malformed scenarios: too many events, out-of-range nodes,
    /// degenerate flap cadences or partitions. Run by the overlay runtime
    /// at engine construction — a bad experiment definition should fail
    /// loudly before any simulation time is spent.
    pub fn validate(&self, n_nodes: usize) {
        assert!(
            self.events.len() <= 64,
            "scenario has {} events; the phase bitmask caps at 64",
            self.events.len()
        );
        for (k, ev) in self.events.iter().enumerate() {
            if !ev.kind.is_instant() {
                assert!(ev.duration > 0, "event #{k}: zero-duration degradation");
            }
            match ev.kind {
                FaultKind::DegradeNode { node, .. } | FaultKind::RestoreNode { node } => {
                    assert!(node < n_nodes, "event #{k}: node {node} >= {n_nodes} nodes");
                }
                FaultKind::FlapLink {
                    node,
                    on_for,
                    off_for,
                    ..
                } => {
                    assert!(node < n_nodes, "event #{k}: node {node} >= {n_nodes} nodes");
                    assert!(
                        on_for > 0 && off_for > 0,
                        "event #{k}: flap cadence must be positive (on={on_for} off={off_for})"
                    );
                }
                FaultKind::PartitionCliques { cliques, .. } => {
                    assert!(
                        cliques >= 2 && cliques <= n_nodes,
                        "event #{k}: {cliques} cliques over {n_nodes} nodes"
                    );
                }
                FaultKind::CongestionStorm { .. } | FaultKind::Heal => {}
                // Churn events index processes, not nodes: their bounds
                // are checked by `validate_procs` (the engine knows the
                // process count; the overlay only knows nodes).
                FaultKind::ProcLeave { .. } | FaultKind::ProcJoin { .. } => {}
            }
        }
    }

    /// Panic on churn events naming out-of-range processes. Run by the
    /// engine at construction (complementing [`FaultScenario::validate`],
    /// which covers the node-indexed events).
    pub fn validate_procs(&self, n_procs: usize) {
        for (k, ev) in self.events.iter().enumerate() {
            if let FaultKind::ProcLeave { proc } | FaultKind::ProcJoin { proc } = ev.kind {
                assert!(
                    proc < n_procs,
                    "event #{k}: proc {proc} >= {n_procs} procs"
                );
            }
        }
    }

    /// Does the timeline contain membership-churn events? The engine only
    /// materializes live-set bookkeeping when this holds, keeping
    /// churn-free scenario runs bit-identical to pre-churn engines.
    pub fn has_churn(&self) -> bool {
        self.events.iter().any(|ev| ev.kind.is_churn())
    }

    /// Human label for a phase mask, e.g. `"degrade#0+storm#2"`;
    /// `"quiescent"` when empty.
    pub fn describe(&self, phase: ScenarioPhase) -> String {
        if phase.is_quiescent() {
            return "quiescent".to_string();
        }
        phase
            .events()
            .map(|k| match self.events.get(k) {
                Some(ev) => format!("{}#{k}", ev.kind.label()),
                None => format!("event#{k}"),
            })
            .collect::<Vec<_>>()
            .join("+")
    }

    // ---- Canned scenarios (see `faults/mod.rs` for the paper map). ----

    /// §III-G static reproduction: `node` runs the lac-417 degradation
    /// from t=0 for the whole run — the scenario-subsystem equivalent of
    /// [`crate::sim::profiles_with_faulty`].
    pub fn lac417(node: usize) -> Self {
        Self::default().with(0, ALWAYS, FaultKind::DegradeNode {
            node,
            fault: NodeFault::lac417(),
        })
    }

    /// Mid-run fail-stop: `node` collapses at `at` and never recovers.
    pub fn midrun_failure(node: usize, at: Nanos) -> Self {
        Self::default().with(at, ALWAYS, FaultKind::DegradeNode {
            node,
            fault: NodeFault::fail_stop(),
        })
    }

    /// Degradation onset and recovery: `node` runs lac-417 factors from
    /// `at`, explicitly restored `duration` later (exercises
    /// `RestoreNode` rather than window expiry).
    pub fn degrade_recover(node: usize, at: Nanos, duration: Nanos) -> Self {
        Self::default()
            .with(at, ALWAYS, FaultKind::DegradeNode {
                node,
                fault: NodeFault::lac417(),
            })
            .with(
                at.saturating_add(duration),
                0,
                FaultKind::RestoreNode { node },
            )
    }

    /// Fabric-wide congestion storm over `[at, at + duration)`.
    pub fn congestion_storm(at: Nanos, duration: Nanos) -> Self {
        Self::default().with(at, duration, FaultKind::CongestionStorm {
            fault: LinkFault::storm(),
        })
    }

    /// Partition-and-heal: the allocation splits into `cliques` blocks at
    /// `at`; an explicit `Heal` reunites it `duration` later.
    pub fn partition_and_heal(cliques: usize, at: Nanos, duration: Nanos) -> Self {
        Self::default()
            .with(at, ALWAYS, FaultKind::PartitionCliques {
                cliques,
                cut: LinkFault::cut(),
            })
            .with(at.saturating_add(duration), 0, FaultKind::Heal)
    }

    /// Flapping faulty endpoint: links touching `node` oscillate between
    /// degraded (`on_for`) and clean (`off_for`) across the window.
    pub fn flapping_clique(
        node: usize,
        at: Nanos,
        duration: Nanos,
        on_for: Nanos,
        off_for: Nanos,
    ) -> Self {
        Self::default().with(at, duration, FaultKind::FlapLink {
            node,
            on_for,
            off_for,
            fault: LinkFault::flap(),
        })
    }

    /// Membership-churn storm: `leavers` processes (spread evenly over
    /// the allocation) crash with staggered onsets across
    /// `[at, at + duration)`. Even-indexed leavers rejoin when their
    /// window closes (transient crash-recovery); odd-indexed ones crash
    /// permanently ([`ALWAYS`]) and are re-admitted by an explicit
    /// [`FaultKind::ProcJoin`] — exercising both rejoin paths.
    pub fn leave_join_storm(
        n_procs: usize,
        at: Nanos,
        duration: Nanos,
        leavers: usize,
    ) -> Self {
        // Two events per odd leaver: cap well under the 64-event mask.
        let leavers = leavers.clamp(1, 21).min(n_procs.saturating_sub(1).max(1));
        let stride = (n_procs / leavers).max(1);
        let stagger = duration / (2 * leavers as Nanos);
        let mut sc = Self::default();
        for i in 0..leavers {
            let proc = i * stride;
            let start = at + i as Nanos * stagger;
            if i % 2 == 0 {
                sc = sc.with(start, duration, FaultKind::ProcLeave { proc });
            } else {
                sc = sc
                    .with(start, ALWAYS, FaultKind::ProcLeave { proc })
                    .with(
                        start.saturating_add(duration),
                        0,
                        FaultKind::ProcJoin { proc },
                    );
            }
        }
        sc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_mask_operations() {
        let p = ScenarioPhase::single(3).union(ScenarioPhase::single(17));
        assert!(p.contains(3) && p.contains(17));
        assert!(!p.contains(4));
        assert_eq!(p.len(), 2);
        assert!(!p.is_quiescent());
        assert_eq!(p.remove(3), ScenarioPhase::single(17));
        assert_eq!(p.events().collect::<Vec<_>>(), vec![3, 17]);
        assert!(ScenarioPhase::QUIESCENT.is_quiescent());
        assert!(!ScenarioPhase::QUIESCENT.contains(0));
    }

    #[test]
    fn lac417_factors_reproduce_static_profile() {
        let eff = NodeFault::lac417().apply(&NodeProfile::healthy());
        let want = NodeProfile::faulty_lac417();
        assert_eq!(eff.speed_factor.to_bits(), want.speed_factor.to_bits());
        assert_eq!(eff.jitter_sigma.to_bits(), want.jitter_sigma.to_bits());
        assert_eq!(eff.stall_mean_ns.to_bits(), want.stall_mean_ns.to_bits());
        assert_eq!(eff.latency_factor.to_bits(), want.latency_factor.to_bits());
        assert_eq!(
            eff.extra_drop_prob.to_bits(),
            want.extra_drop_prob.to_bits()
        );
    }

    #[test]
    fn identity_fault_is_bitwise_invisible() {
        for base in [
            NodeProfile::healthy(),
            NodeProfile::faulty_lac417(),
        ] {
            let eff = NodeFault::identity().apply(&base);
            assert_eq!(eff.speed_factor.to_bits(), base.speed_factor.to_bits());
            assert_eq!(eff.jitter_sigma.to_bits(), base.jitter_sigma.to_bits());
            assert_eq!(eff.stall_mean_ns.to_bits(), base.stall_mean_ns.to_bits());
            assert_eq!(eff.latency_factor.to_bits(), base.latency_factor.to_bits());
            assert_eq!(eff.extra_drop_prob.to_bits(), base.extra_drop_prob.to_bits());
        }
        let f = LinkFault {
            latency_factor: 7.5,
            extra_drop_prob: 0.25,
        };
        let stacked = f.stack(&LinkFault::IDENTITY);
        assert_eq!(stacked.latency_factor.to_bits(), f.latency_factor.to_bits());
        assert_eq!(
            stacked.extra_drop_prob.to_bits(),
            f.extra_drop_prob.to_bits()
        );
    }

    #[test]
    fn link_fault_stack_clamps_drop() {
        let a = LinkFault {
            latency_factor: 2.0,
            extra_drop_prob: 0.7,
        };
        let b = LinkFault {
            latency_factor: 3.0,
            extra_drop_prob: 0.6,
        };
        let s = a.stack(&b);
        assert_eq!(s.latency_factor, 6.0);
        assert_eq!(s.extra_drop_prob, 1.0);
    }

    #[test]
    fn event_end_saturates() {
        let ev = FaultEvent {
            start: 100,
            duration: ALWAYS,
            kind: FaultKind::Heal,
        };
        assert_eq!(ev.end(), Nanos::MAX);
        let ev = FaultEvent {
            start: 100,
            duration: 50,
            kind: FaultKind::CongestionStorm {
                fault: LinkFault::storm(),
            },
        };
        assert_eq!(ev.end(), 150);
    }

    #[test]
    fn canned_scenarios_validate() {
        FaultScenario::lac417(5).validate(16);
        FaultScenario::midrun_failure(3, 1_000).validate(4);
        FaultScenario::degrade_recover(0, 10, 20).validate(1);
        FaultScenario::congestion_storm(5, 10).validate(2);
        FaultScenario::partition_and_heal(2, 5, 10).validate(4);
        FaultScenario::flapping_clique(1, 0, 100, 5, 5).validate(2);
        FaultScenario::default().validate(0);
        let storm = FaultScenario::leave_join_storm(64, 100, 1_000, 8);
        storm.validate(1); // churn is node-agnostic
        storm.validate_procs(64);
    }

    #[test]
    fn leave_join_storm_shape() {
        let sc = FaultScenario::leave_join_storm(64, 100, 1_000, 8);
        assert!(sc.has_churn());
        // 8 leavers, half permanent-with-explicit-join: 8 + 4 events.
        assert_eq!(sc.events.len(), 12);
        let leaves = sc
            .events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::ProcLeave { .. }))
            .count();
        let joins = sc
            .events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::ProcJoin { .. }))
            .count();
        assert_eq!((leaves, joins), (8, 4));
        // Distinct procs, staggered monotone onsets.
        let mut procs: Vec<usize> = sc
            .events
            .iter()
            .filter_map(|e| match e.kind {
                FaultKind::ProcLeave { proc } => Some(proc),
                _ => None,
            })
            .collect();
        procs.sort_unstable();
        procs.dedup();
        assert_eq!(procs.len(), 8);
        assert!(!FaultScenario::congestion_storm(0, 10).has_churn());
    }

    #[test]
    #[should_panic(expected = "proc 9")]
    fn validate_procs_rejects_out_of_range() {
        FaultScenario::default()
            .with(0, 10, FaultKind::ProcLeave { proc: 9 })
            .validate_procs(8);
    }

    #[test]
    fn churn_kinds_classify() {
        assert!(!FaultKind::ProcLeave { proc: 0 }.is_instant());
        assert!(FaultKind::ProcJoin { proc: 0 }.is_instant());
        assert!(FaultKind::ProcLeave { proc: 0 }.is_churn());
        assert!(FaultKind::ProcJoin { proc: 0 }.is_churn());
        assert!(!FaultKind::Heal.is_churn());
        assert_eq!(FaultKind::ProcLeave { proc: 0 }.label(), "leave");
        assert_eq!(FaultKind::ProcJoin { proc: 0 }.label(), "join");
    }

    #[test]
    #[should_panic(expected = "node 7")]
    fn validate_rejects_out_of_range_node() {
        FaultScenario::lac417(7).validate(4);
    }

    #[test]
    #[should_panic(expected = "flap cadence")]
    fn validate_rejects_zero_flap_cadence() {
        FaultScenario::flapping_clique(0, 0, 100, 0, 5).validate(2);
    }

    #[test]
    #[should_panic(expected = "cliques")]
    fn validate_rejects_degenerate_partition() {
        FaultScenario::partition_and_heal(1, 0, 10).validate(4);
    }

    #[test]
    fn describe_names_active_events() {
        let s = FaultScenario::partition_and_heal(2, 5, 10);
        assert_eq!(s.describe(ScenarioPhase::QUIESCENT), "quiescent");
        assert_eq!(s.describe(ScenarioPhase::single(0)), "partition#0");
        let storm = FaultScenario::congestion_storm(0, 10);
        let both = ScenarioPhase::single(0);
        assert_eq!(storm.describe(both), "storm#0");
    }
}
