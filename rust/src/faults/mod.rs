//! Deterministic fault scenarios: scripted time-varying degradation with
//! time-resolved QoS attribution.
//!
//! The paper's §III-G experiment plants one statically faulty node in a
//! 256-process allocation; its central claim is that "characterizing the
//! distribution of quality of service across processing components *and
//! over time* is critical". This subsystem makes the *over time* half a
//! first-class experiment input: a [`FaultScenario`] scripts degradation
//! onset, recovery, flapping links, congestion storms, and
//! partition-and-heal as a declarative timeline; the engine compiles it
//! into calendar-queue wake events and consults a mutable overlay
//! ([`FaultRuntime`]) over the static `NodeProfile`/`LinkModel` tables,
//! so effective latency/drop/speed factors change mid-run —
//! deterministically from `SimConfig::seed`. Every QoS snapshot window is
//! tagged with the [`ScenarioPhase`] (set of faults) active while it was
//! measured, so metrics can be attributed to the interference regime that
//! produced them.
//!
//! ## Canned scenarios → paper sections
//!
//! | constructor | probes |
//! |---|---|
//! | [`FaultScenario::lac417`] | §III-G verbatim: the always-on faulty node (`lac-417`); scenario-subsystem equivalent of [`crate::sim::profiles_with_faulty`], which remains available and bit-identical |
//! | [`FaultScenario::midrun_failure`] | §III-G's motivating threat, time-resolved: a node fail-stops mid-run; best-effort medians should hold while means/tails shift only after onset |
//! | [`FaultScenario::degrade_recover`] | degradation onset *and recovery* — the transient interference Conduit (Moreno et al. 2021) targets; exercises `RestoreNode` |
//! | [`FaultScenario::congestion_storm`] | §III-C/D's latency regime shifted in time: a fabric-wide storm (cf. Bienz et al. 2018 on time- and topology-local congestion dominating irregular point-to-point performance) |
//! | [`FaultScenario::partition_and_heal`] | scalability under the harshest transient: the allocation splits into cliques, then heals (`PartitionCliques` + `Heal`) |
//! | [`FaultScenario::flapping_clique`] | §III-G's outlier-generating clique made intermittent: links touching one node flap between degraded and clean |
//! | [`FaultScenario::leave_join_storm`] | membership churn: staggered process departures (some permanent, some rejoining) over a window — the best-effort claim under allocation shrink/regrow |
//! | [`chaos::generate_scenario`] | seeded chaos campaigns: randomized timelines over every kind, invariant-checked, failures auto-shrunk to minimal scenarios (see [`chaos`]) |
//!
//! An **empty** scenario is guaranteed bit-identical to the static-profile
//! path (the engine skips the overlay entirely); a scenario whose events
//! never activate inside the run window is bit-identical too, because the
//! overlay's effective tables equal the static tables whenever nothing is
//! active — both pinned by the golden-signature tests.

pub mod chaos;
pub mod overlay;
pub mod scenario;

pub use chaos::{
    generate_scenario, run_chaos_cell, shrink_timeline, ChaosFailure, CHAOS_PROCS, CHAOS_RUN_FOR,
};
pub use overlay::{clique_of, FaultRuntime};
pub use scenario::{
    FaultEvent, FaultKind, FaultScenario, LinkFault, NodeFault, ScenarioPhase, ALWAYS,
};
