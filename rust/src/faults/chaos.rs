//! Seeded chaos campaigns: randomized fault timelines, engine-invariant
//! checking, and automatic shrinking of failures to minimal scenarios.
//!
//! A campaign draws [`FaultScenario`] timelines deterministically per
//! seed — every fault kind, including membership churn — runs each one
//! through small DES cells under both a best-effort and a barriered
//! mode, and checks structural invariants that must hold on *every*
//! timeline:
//!
//! 1. **No panic** anywhere in the engine.
//! 2. **Message conservation**: `sent == delivered + purged + in-flight`
//!    ([`SimResult::conserves_messages`]) — and the same ledger balances
//!    *channel by channel*
//!    ([`SimResult::channel_conservation_violations`]), so compensating
//!    errors that net out globally are still caught.
//! 3. **Well-formed QoS windows**: one window per channel per snapshot,
//!    monotone counters/clocks within each window, phase tags naming
//!    only real scenario events.
//! 4. **Barrier liveness**: in `Sync` mode, processes never named by a
//!    churn event finish in lockstep — a departed participant must never
//!    wedge the barrier for the survivors.
//!
//! On a violation the offending timeline is shrunk to a local minimum —
//! drop-one-event passes, then halve-duration passes, to fixpoint — and
//! the seed plus the shrunk scenario are reported for replay. Everything
//! is a pure function of the seed, so a CI failure reproduces exactly.

use crate::faults::{FaultKind, FaultScenario, LinkFault, NodeFault, ALWAYS};
use crate::net::{PlacementKind, Topology};
use crate::sim::{healthy_profiles, AsyncMode, Engine, ModeTiming, SimConfig, SimResult};
use crate::util::rng::{Rng, Xoshiro256};
use crate::util::Nanos;
use crate::workloads::{GcConfig, GraphColoringShard, ShardWorkload};

/// Processes per chaos cell (2x2 mesh, one per node: every proc has all
/// four cross-shard directions, so churn touches real channel fan-out).
pub const CHAOS_PROCS: usize = 4;

/// Virtual runtime per chaos cell — long enough for several snapshot
/// windows and barrier epochs, short enough for 100s of cells in CI.
pub const CHAOS_RUN_FOR: Nanos = 30 * crate::util::MILLI;

/// Draw a random — but valid and fully seed-determined — fault timeline.
/// All eight [`FaultKind`]s are reachable, including permanent-crash
/// `ProcLeave`s and re-admitting `ProcJoin`s.
pub fn generate_scenario(
    seed: u64,
    n_nodes: usize,
    n_procs: usize,
    run_for: Nanos,
) -> FaultScenario {
    let mut rng = Xoshiro256::new(seed ^ 0xC4A0_5EED);
    let n_events = 1 + rng.below(6) as usize;
    let mut sc = FaultScenario::default();
    for _ in 0..n_events {
        let start = rng.below(run_for);
        let mut duration = (run_for / 20).max(1) + rng.below(run_for / 2);
        let kind = match rng.below(8) {
            0 => FaultKind::DegradeNode {
                node: rng.below(n_nodes as u64) as usize,
                fault: if rng.chance(0.5) {
                    NodeFault::lac417()
                } else {
                    NodeFault::fail_stop()
                },
            },
            1 => FaultKind::RestoreNode {
                node: rng.below(n_nodes as u64) as usize,
            },
            2 => FaultKind::FlapLink {
                node: rng.below(n_nodes as u64) as usize,
                on_for: 1 + rng.below(run_for / 8),
                off_for: 1 + rng.below(run_for / 8),
                fault: LinkFault::flap(),
            },
            4 if n_nodes >= 2 => FaultKind::PartitionCliques {
                cliques: 2 + rng.below((n_nodes - 1) as u64) as usize,
                cut: LinkFault::cut(),
            },
            5 => FaultKind::Heal,
            6 => {
                let proc = rng.below(n_procs as u64) as usize;
                if rng.chance(0.25) {
                    duration = ALWAYS; // permanent crash
                }
                FaultKind::ProcLeave { proc }
            }
            7 => FaultKind::ProcJoin {
                proc: rng.below(n_procs as u64) as usize,
            },
            _ => FaultKind::CongestionStorm {
                fault: LinkFault::storm(),
            },
        };
        sc = sc.with(start, duration, kind);
    }
    sc.validate(n_nodes);
    sc.validate_procs(n_procs);
    sc
}

fn chaos_engine(
    scenario: FaultScenario,
    mode: AsyncMode,
    seed: u64,
    run_for: Nanos,
) -> Engine<GraphColoringShard> {
    let topo = Topology::new(CHAOS_PROCS, PlacementKind::OnePerNode);
    let mut rng = Xoshiro256::new(seed);
    let shards: Vec<_> = (0..CHAOS_PROCS)
        .map(|r| {
            GraphColoringShard::new(
                GcConfig {
                    simels_per_proc: 4,
                    ..GcConfig::default()
                },
                &topo,
                r,
                &mut rng,
            )
        })
        .collect();
    let mut cfg = SimConfig::from_env(mode, ModeTiming::graph_coloring(CHAOS_PROCS), run_for);
    cfg.seed = seed;
    cfg.send_buffer = 4;
    cfg.scenario = scenario;
    // Chaos invariants walk the exact per-window stream; pin the storage
    // mode so `EBCOMM_QOS=sketch` cannot empty it.
    cfg.qos_storage = crate::qos::QosStorage::Exact;
    cfg.snapshots = Some(crate::qos::SnapshotSchedule::compressed(
        run_for / 6,
        run_for / 4,
        run_for / 8,
        3,
    ));
    let profiles = healthy_profiles(&topo);
    Engine::new(cfg, topo, profiles, shards)
}

/// Processes never named by any churn event of `scenario` — the ones the
/// sync-lockstep invariant ranges over.
fn never_churned(scenario: &FaultScenario, n_procs: usize) -> Vec<usize> {
    (0..n_procs)
        .filter(|&p| {
            !scenario.events.iter().any(|ev| {
                matches!(ev.kind,
                    FaultKind::ProcLeave { proc } | FaultKind::ProcJoin { proc } if proc == p)
            })
        })
        .collect()
}

fn check_result(
    scenario: &FaultScenario,
    mode: AsyncMode,
    result: &SimResult<GraphColoringShard>,
) -> Result<(), String> {
    if !result.conserves_messages() {
        return Err(format!(
            "conservation violated under {mode:?}: sent={} != delivered={} + purged={} + in_flight={}",
            result.successful_sends,
            result.messages_delivered,
            result.messages_purged,
            result.messages_in_flight,
        ));
    }
    if result.channel_conservation_violations > 0 {
        return Err(format!(
            "per-channel conservation violated under {mode:?}: {} channels out of balance \
             (global ledger nets out, so the error hides in compensating channels)",
            result.channel_conservation_violations,
        ));
    }
    let n_channels: usize = result.shards.iter().map(|s| s.channels().len()).sum();
    if n_channels > 0 && result.windows.len() % n_channels != 0 {
        return Err(format!(
            "ragged QoS windows under {mode:?}: {} windows over {} channels",
            result.windows.len(),
            n_channels
        ));
    }
    for (i, w) in result.windows.iter().enumerate() {
        for (before, after) in [
            (&w.inlet_before, &w.inlet_after),
            (&w.outlet_before, &w.outlet_after),
        ] {
            if after.wall_ns < before.wall_ns
                || after.update_count < before.update_count
                || after.counters.attempted_sends < before.counters.attempted_sends
                || after.counters.successful_sends < before.counters.successful_sends
                || after.counters.pull_attempts < before.counters.pull_attempts
                || after.counters.laden_pulls < before.counters.laden_pulls
                || after.counters.messages_received < before.counters.messages_received
            {
                return Err(format!(
                    "non-monotone QoS window #{i} under {mode:?}"
                ));
            }
        }
        if w.phase().events().any(|k| k >= scenario.events.len()) {
            return Err(format!(
                "window #{i} under {mode:?} tagged with nonexistent scenario event"
            ));
        }
    }
    if mode.uses_barriers() {
        let steady = never_churned(scenario, result.updates.len());
        if let (Some(&min), Some(&max)) = (
            steady.iter().map(|&p| &result.updates[p]).min(),
            steady.iter().map(|&p| &result.updates[p]).max(),
        ) {
            if mode == AsyncMode::Sync && max - min > 1 {
                return Err(format!(
                    "sync lockstep broken among never-churned procs: {:?} (steady set {:?})",
                    result.updates, steady
                ));
            }
        }
    }
    Ok(())
}

/// Run one timeline through both treatment cells and check every
/// invariant. `Err` carries a human-readable violation description.
pub fn check_timeline(
    scenario: &FaultScenario,
    seed: u64,
    run_for: Nanos,
) -> Result<(), String> {
    for mode in [AsyncMode::BestEffort, AsyncMode::Sync] {
        let sc = scenario.clone();
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            chaos_engine(sc, mode, seed, run_for).run()
        }));
        let result = match run {
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                return Err(format!("panic under {mode:?}: {msg}"));
            }
            Ok(r) => r,
        };
        check_result(scenario, mode, &result)?;
    }
    Ok(())
}

/// Greedily shrink a failing timeline to a local minimum: repeated
/// drop-one-event passes, then halve-duration passes, iterated to
/// fixpoint. `fails` must return `true` for the input scenario; the
/// result still satisfies `fails` and no single further drop or halving
/// does.
pub fn shrink_timeline<F>(mut scenario: FaultScenario, fails: &F) -> FaultScenario
where
    F: Fn(&FaultScenario) -> bool,
{
    debug_assert!(fails(&scenario), "shrinking a passing scenario");
    loop {
        let mut progressed = false;
        // Pass 1: drop single events.
        let mut i = 0;
        while i < scenario.events.len() {
            let mut cand = scenario.clone();
            cand.events.remove(i);
            if fails(&cand) {
                scenario = cand;
                progressed = true;
            } else {
                i += 1;
            }
        }
        // Pass 2: halve finite durations (ALWAYS stays a permanent
        // crash; zero-length command durations stay untouched; windowed
        // degradations keep validity because halves stay positive).
        for i in 0..scenario.events.len() {
            let d = scenario.events[i].duration;
            if d < 2 || d == ALWAYS {
                continue;
            }
            let mut cand = scenario.clone();
            cand.events[i].duration = d / 2;
            if fails(&cand) {
                scenario = cand;
                progressed = true;
            }
        }
        if !progressed {
            return scenario;
        }
    }
}

/// One confirmed campaign failure: the violating seed, the original and
/// shrunk timelines, and their violation descriptions. `Display` prints
/// a replay-ready report.
#[derive(Clone, Debug)]
pub struct ChaosFailure {
    pub seed: u64,
    pub violation: String,
    pub scenario: FaultScenario,
    pub shrunk: FaultScenario,
    pub shrunk_violation: String,
}

impl std::fmt::Display for ChaosFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "chaos violation @ seed {}", self.seed)?;
        writeln!(f, "  violation: {}", self.violation)?;
        writeln!(
            f,
            "  original timeline ({} events):",
            self.scenario.events.len()
        )?;
        for ev in &self.scenario.events {
            writeln!(
                f,
                "    t={} dur={} {:?}",
                ev.start, ev.duration, ev.kind
            )?;
        }
        writeln!(
            f,
            "  shrunk timeline ({} events): {}",
            self.shrunk.events.len(),
            self.shrunk_violation
        )?;
        for ev in &self.shrunk.events {
            writeln!(
                f,
                "    t={} dur={} {:?}",
                ev.start, ev.duration, ev.kind
            )?;
        }
        write!(
            f,
            "  replay: run_chaos_cell({}, CHAOS_RUN_FOR)",
            self.seed
        )
    }
}

/// Run one full campaign cell: generate the seed's timeline, check it,
/// and on violation shrink to a minimal failing scenario. `None` means
/// the seed passed.
pub fn run_chaos_cell(seed: u64, run_for: Nanos) -> Option<ChaosFailure> {
    let scenario = generate_scenario(seed, CHAOS_PROCS, CHAOS_PROCS, run_for);
    let violation = match check_timeline(&scenario, seed, run_for) {
        Ok(()) => return None,
        Err(v) => v,
    };
    let fails = |sc: &FaultScenario| check_timeline(sc, seed, run_for).is_err();
    let shrunk = shrink_timeline(scenario.clone(), &fails);
    let shrunk_violation = check_timeline(&shrunk, seed, run_for)
        .err()
        .unwrap_or_default();
    Some(ChaosFailure {
        seed,
        violation,
        scenario,
        shrunk,
        shrunk_violation,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MILLI;

    #[test]
    fn generator_is_deterministic_per_seed() {
        for seed in 0..32 {
            let a = generate_scenario(seed, 4, 4, 30 * MILLI);
            let b = generate_scenario(seed, 4, 4, 30 * MILLI);
            assert_eq!(a, b, "seed {seed} not deterministic");
            assert!(!a.is_empty());
            assert!(a.events.len() <= 64);
        }
    }

    #[test]
    fn generator_covers_churn_kinds() {
        let mut saw_leave = false;
        let mut saw_join = false;
        for seed in 0..200 {
            let sc = generate_scenario(seed, 4, 4, 30 * MILLI);
            for ev in &sc.events {
                match ev.kind {
                    FaultKind::ProcLeave { .. } => saw_leave = true,
                    FaultKind::ProcJoin { .. } => saw_join = true,
                    _ => {}
                }
            }
        }
        assert!(saw_leave, "200 seeds never drew a ProcLeave");
        assert!(saw_join, "200 seeds never drew a ProcJoin");
    }

    /// The shrinker, exercised against a synthetic predicate (no engine
    /// runs): "fails iff it still contains a storm AND a leave". The
    /// minimum is exactly one of each with minimal durations.
    #[test]
    fn shrinker_reaches_minimal_failing_scenario() {
        let mut sc = FaultScenario::default()
            .with(MILLI, 4 * MILLI, FaultKind::DegradeNode {
                node: 0,
                fault: NodeFault::lac417(),
            })
            .with(2 * MILLI, 0, FaultKind::Heal)
            .with(3 * MILLI, 6 * MILLI, FaultKind::FlapLink {
                node: 1,
                on_for: MILLI,
                off_for: MILLI,
                fault: LinkFault::flap(),
            })
            .with(5 * MILLI, 8 * MILLI, FaultKind::CongestionStorm {
                fault: LinkFault::storm(),
            })
            .with(6 * MILLI, 8 * MILLI, FaultKind::ProcLeave { proc: 1 });
        // Make sure the predicate holds on the input.
        let fails = |s: &FaultScenario| {
            let storm = s
                .events
                .iter()
                .any(|e| matches!(e.kind, FaultKind::CongestionStorm { .. }));
            let leave = s
                .events
                .iter()
                .any(|e| matches!(e.kind, FaultKind::ProcLeave { .. }));
            storm && leave
        };
        // Guarantee at least one storm+leave beyond the random prefix.
        assert!(fails(&sc));
        sc = shrink_timeline(sc, &fails);
        assert!(fails(&sc), "shrinking lost the failure");
        assert_eq!(
            sc.events.len(),
            2,
            "not minimal: {:?}",
            sc.events
        );
        // Durations halved to the floor.
        for ev in &sc.events {
            assert!(ev.duration <= 1, "duration not minimized: {}", ev.duration);
        }
    }

    #[test]
    fn shrinker_handles_always_durations() {
        let sc = FaultScenario::default()
            .with(MILLI, ALWAYS, FaultKind::ProcLeave { proc: 0 })
            .with(2 * MILLI, 4 * MILLI, FaultKind::Heal);
        let fails = |s: &FaultScenario| {
            s.events
                .iter()
                .any(|e| e.duration == ALWAYS)
        };
        let out = shrink_timeline(sc, &fails);
        assert_eq!(out.events.len(), 1);
        assert_eq!(out.events[0].duration, ALWAYS);
    }

    /// Smoke: a handful of seeds run clean end-to-end (the full range
    /// lives in `tests/chaos_campaign.rs`).
    #[test]
    fn small_campaign_passes() {
        for seed in 0..8 {
            if let Some(failure) = run_chaos_cell(seed, CHAOS_RUN_FOR) {
                panic!("{failure}");
            }
        }
    }
}
