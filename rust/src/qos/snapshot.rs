//! Snapshot windows and per-replicate QoS aggregation (§II-E).
//!
//! The paper's apparatus takes snapshot observations at one-minute
//! intervals over each replicate's runtime; each snapshot records a first
//! tranche of counters, lets the system run unimpeded for one second, then
//! records a second tranche. Metrics are computed per snapshot per channel
//! endpoint, inlet- and outlet-derived values are averaged, and snapshots
//! are aggregated per replicate by mean and median for the treatment
//! regressions.

use super::metrics::{MetricName, QosMetrics, QosObservation};
use crate::faults::ScenarioPhase;
use crate::stats::descriptive::{mean, median};
use crate::util::{Nanos, MILLI, SECOND};

/// Schedule of snapshot windows over a replicate.
#[derive(Clone, Copy, Debug)]
pub struct SnapshotSchedule {
    /// Time of the first window opening (paper: 60 s).
    pub first_at: Nanos,
    /// Interval between window openings (paper: 60 s).
    pub every: Nanos,
    /// Window duration (paper: 1 s).
    pub window: Nanos,
    /// Number of windows (paper: 5 over slightly-past-5-minutes runs).
    pub count: usize,
}

impl SnapshotSchedule {
    /// The paper's QoS-experiment schedule: five 1 s windows at minutes
    /// 1–5.
    pub fn paper() -> Self {
        Self {
            first_at: 60 * SECOND,
            every: 60 * SECOND,
            window: SECOND,
            count: 5,
        }
    }

    /// Compressed schedule for fast benches/tests: `count` windows of
    /// `window` ns, starting at `first_at` and spaced `every`.
    pub fn compressed(first_at: Nanos, every: Nanos, window: Nanos, count: usize) -> Self {
        Self {
            first_at,
            every,
            window,
            count,
        }
    }

    /// Wall-clock smoke schedule for real-thread (`exec/`) runs: four
    /// 20 ms windows every 40 ms starting at 30 ms (~170 ms of runtime).
    /// Windows are kept wide so a worker descheduled by the OS for a
    /// timeslice still lands many updates inside each one on a 2-core
    /// CI box.
    pub fn hardware_smoke() -> Self {
        Self {
            first_at: 30 * MILLI,
            every: 40 * MILLI,
            window: 20 * MILLI,
            count: 4,
        }
    }

    /// Opening time of window `i`.
    pub fn open_at(&self, i: usize) -> Nanos {
        self.first_at + self.every * i as u64
    }

    /// Closing time of window `i`.
    pub fn close_at(&self, i: usize) -> Nanos {
        self.open_at(i) + self.window
    }

    /// Total runtime needed to complete all windows.
    pub fn runtime(&self) -> Nanos {
        self.close_at(self.count.saturating_sub(1))
    }
}

/// One completed snapshot for one channel: inlet- and outlet-derived
/// observations at open and close.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapshotWindow {
    pub inlet_before: QosObservation,
    pub inlet_after: QosObservation,
    pub outlet_before: QosObservation,
    pub outlet_after: QosObservation,
}

impl SnapshotWindow {
    /// Inlet-derived, outlet-derived, and averaged metrics (§II-E reports
    /// the mean over the two).
    pub fn metrics(&self) -> QosMetrics {
        let inlet = QosMetrics::from_window(&self.inlet_before, &self.inlet_after);
        let outlet = QosMetrics::from_window(&self.outlet_before, &self.outlet_after);
        inlet.mean_with(&outlet)
    }

    pub fn inlet_metrics(&self) -> QosMetrics {
        QosMetrics::from_window(&self.inlet_before, &self.inlet_after)
    }

    pub fn outlet_metrics(&self) -> QosMetrics {
        QosMetrics::from_window(&self.outlet_before, &self.outlet_after)
    }

    /// Scenario faults active at any point during this window: the union
    /// of the four observations' phase tags (the engine folds mid-window
    /// fault transitions into the closing observations).
    pub fn phase(&self) -> ScenarioPhase {
        self.inlet_before
            .phase
            .union(self.inlet_after.phase)
            .union(self.outlet_before.phase)
            .union(self.outlet_after.phase)
    }
}

/// All snapshots collected from one replicate run, flattened across
/// processes/channels/timepoints.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReplicateQos {
    pub snapshots: Vec<QosMetrics>,
    /// Scenario faults active during each window, parallel to
    /// `snapshots` (all quiescent for static-profile runs).
    pub phases: Vec<ScenarioPhase>,
}

impl ReplicateQos {
    pub fn push(&mut self, m: QosMetrics) {
        self.push_phased(m, ScenarioPhase::QUIESCENT);
    }

    /// [`Self::push`] with the window's scenario-phase tag.
    pub fn push_phased(&mut self, m: QosMetrics, phase: ScenarioPhase) {
        self.snapshots.push(m);
        self.phases.push(phase);
    }

    /// Scan completed windows into per-window metrics (inlet/outlet
    /// averaged) and phase tags, in window order — the engine's
    /// end-of-run QoS pass.
    pub fn from_windows(windows: &[SnapshotWindow]) -> Self {
        Self {
            snapshots: windows.iter().map(SnapshotWindow::metrics).collect(),
            phases: windows.iter().map(SnapshotWindow::phase).collect(),
        }
    }

    pub fn values(&self, metric: MetricName) -> Vec<f64> {
        self.snapshots.iter().map(|m| m.get(metric)).collect()
    }

    /// Metric values restricted to windows whose phase satisfies `pred` —
    /// the time-resolved attribution query ("how did delivery failure
    /// look *while the storm was active*?").
    pub fn values_where<F: Fn(ScenarioPhase) -> bool>(
        &self,
        metric: MetricName,
        pred: F,
    ) -> Vec<f64> {
        self.snapshots
            .iter()
            .zip(self.phases.iter())
            .filter(|&(_, &ph)| pred(ph))
            .map(|(m, _)| m.get(metric))
            .collect()
    }

    /// Mean over windows selected by `pred` (0 when none match).
    pub fn mean_where<F: Fn(ScenarioPhase) -> bool>(&self, metric: MetricName, pred: F) -> f64 {
        mean(&self.values_where(metric, pred))
    }

    /// Median over windows selected by `pred` (0 when none match).
    pub fn median_where<F: Fn(ScenarioPhase) -> bool>(&self, metric: MetricName, pred: F) -> f64 {
        median(&self.values_where(metric, pred))
    }

    /// Replicate-level mean (captures extreme outliers, §II-E).
    pub fn mean(&self, metric: MetricName) -> f64 {
        mean(&self.values(metric))
    }

    /// Replicate-level median (represents typicality, §II-E).
    pub fn median(&self, metric: MetricName) -> f64 {
        median(&self.values(metric))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conduit::CounterTranche;

    #[test]
    fn paper_schedule_timing() {
        let s = SnapshotSchedule::paper();
        assert_eq!(s.open_at(0), 60 * SECOND);
        assert_eq!(s.close_at(0), 61 * SECOND);
        assert_eq!(s.open_at(4), 300 * SECOND);
        assert_eq!(s.runtime(), 301 * SECOND);
        assert_eq!(s.count, 5);
    }

    #[test]
    fn hardware_smoke_schedule_fits_a_smoke_run() {
        let s = SnapshotSchedule::hardware_smoke();
        assert_eq!(s.count, 4);
        assert_eq!(s.open_at(0), 30 * MILLI);
        assert_eq!(s.runtime(), 170 * MILLI);
    }

    #[test]
    fn window_metrics_average_inlet_outlet() {
        let zero = QosObservation::default();
        let mk = |updates, wall| QosObservation {
            counters: CounterTranche::default(),
            update_count: updates,
            wall_ns: wall,
            phase: ScenarioPhase::QUIESCENT,
        };
        let w = SnapshotWindow {
            inlet_before: zero,
            inlet_after: mk(10, 1_000),
            outlet_before: zero,
            outlet_after: mk(10, 3_000),
        };
        // inlet period 100, outlet period 300 -> mean 200.
        assert_eq!(w.metrics().simstep_period_ns, 200.0);
    }

    #[test]
    fn from_windows_matches_per_window_push() {
        let zero = QosObservation::default();
        let mk = |updates, wall| QosObservation {
            counters: CounterTranche::default(),
            update_count: updates,
            wall_ns: wall,
            phase: ScenarioPhase::QUIESCENT,
        };
        let windows = vec![
            SnapshotWindow {
                inlet_before: zero,
                inlet_after: mk(10, 1_000),
                outlet_before: zero,
                outlet_after: mk(10, 3_000),
            },
            SnapshotWindow {
                inlet_before: zero,
                inlet_after: mk(4, 800),
                outlet_before: zero,
                outlet_after: mk(4, 800),
            },
        ];
        let batch = ReplicateQos::from_windows(&windows);
        let mut reference = ReplicateQos::default();
        for w in &windows {
            reference.push(w.metrics());
        }
        assert_eq!(batch, reference);
    }

    #[test]
    fn window_phase_is_union_of_observation_phases() {
        let mut w = SnapshotWindow {
            inlet_before: QosObservation::default(),
            inlet_after: QosObservation::default(),
            outlet_before: QosObservation::default(),
            outlet_after: QosObservation::default(),
        };
        assert!(w.phase().is_quiescent());
        w.inlet_before.phase = ScenarioPhase::single(1);
        w.outlet_after.phase = ScenarioPhase::single(3);
        let p = w.phase();
        assert!(p.contains(1) && p.contains(3) && p.len() == 2);
    }

    #[test]
    fn values_where_splits_by_phase() {
        let mk = |period| QosMetrics {
            simstep_period_ns: period,
            simstep_latency: 1.0,
            walltime_latency_ns: period,
            delivery_failure_rate: 0.0,
            delivery_clumpiness: 0.0,
        };
        let mut rq = ReplicateQos::default();
        rq.push_phased(mk(10.0), ScenarioPhase::QUIESCENT);
        rq.push_phased(mk(500.0), ScenarioPhase::single(0));
        rq.push_phased(mk(20.0), ScenarioPhase::QUIESCENT);
        rq.push_phased(mk(700.0), ScenarioPhase::single(0).union(ScenarioPhase::single(1)));
        assert_eq!(
            rq.values_where(MetricName::SimstepPeriod, |p| p.is_quiescent()),
            vec![10.0, 20.0]
        );
        assert_eq!(
            rq.values_where(MetricName::SimstepPeriod, |p| p.contains(0)),
            vec![500.0, 700.0]
        );
        assert_eq!(
            rq.median_where(MetricName::SimstepPeriod, |p| p.is_quiescent()),
            15.0
        );
        assert_eq!(
            rq.mean_where(MetricName::SimstepPeriod, |p| p.contains(1)),
            700.0
        );
        // Full-window queries see everything, phases stay parallel.
        assert_eq!(rq.values(MetricName::SimstepPeriod).len(), 4);
        assert_eq!(rq.phases.len(), 4);
    }

    #[test]
    fn replicate_aggregation() {
        let mut rq = ReplicateQos::default();
        for period in [10.0, 20.0, 90.0] {
            rq.push(QosMetrics {
                simstep_period_ns: period,
                simstep_latency: 1.0,
                walltime_latency_ns: period,
                delivery_failure_rate: 0.0,
                delivery_clumpiness: 0.0,
            });
        }
        assert_eq!(rq.mean(MetricName::SimstepPeriod), 40.0);
        assert_eq!(rq.median(MetricName::SimstepPeriod), 20.0);
    }
}
