//! Mergeable streaming sketches for QoS telemetry at scale.
//!
//! Exact QoS storage keeps one [`SnapshotWindow`] per channel per window
//! — O(channels × windows) memory, the first thing to blow up on the
//! 10⁴–10⁵-proc runs the memory-diet engine otherwise fits. Sketch
//! storage replaces that with O(1) state per window per metric:
//!
//! * [`QuantileSketch`] — a DDSketch-style log-linear bucketed histogram.
//!   The bucket index is computed with **integer math only** over the
//!   IEEE-754 bit pattern of the value (exponent field + top mantissa
//!   bits), so indices — and therefore sketch state — are bit-identical
//!   across platforms and across merge orders. Nearest-rank quantile
//!   estimates carry a relative error of at most
//!   [`QUANTILE_REL_ERROR_BOUND`] (1/64 ≈ 1.6%) against the exact
//!   nearest-rank quantile for in-range positive values.
//! * [`CardinalitySketch`] — a HyperLogLog over a fixed-seed splitmix64
//!   finalizer, for distinct-channel / distinct-sender counts. Register
//!   state is integer and merge is element-wise max, so merges are exact
//!   unions; the estimate is accurate to ~10% (±a few counts at tiny
//!   cardinalities).
//!
//! [`SketchQos`] bundles one quantile sketch per QoS metric (overall and
//! per observed scenario phase) plus the two cardinality counters, and is
//! what the engine feeds from `snapshot_close` under
//! [`QosStorage::Sketch`] — without ever materializing per-channel
//! vectors. All state is integral, so `Eq` is bit-identity and the
//! sketches ride the `EBCK` checkpoint verbatim.
//!
//! The algorithms are pre-validated by `python/qos_sketch_model_fuzz.py`;
//! the constants here mirror that model exactly.

use super::metrics::{MetricName, QosMetrics};
use super::snapshot::SnapshotWindow;
use crate::faults::ScenarioPhase;

/// How a replicate stores its QoS observations.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QosStorage {
    /// One [`SnapshotWindow`] per channel per window — exact medians and
    /// full raw-window access, O(channels × windows) memory. The default
    /// at small scale; cross-checks the sketches in tests.
    #[default]
    Exact,
    /// Fold every closed window into [`SketchQos`] and drop it — O(1)
    /// memory per window per metric, quantiles within
    /// [`QUANTILE_REL_ERROR_BOUND`].
    Sketch,
}

impl QosStorage {
    /// Resolve from `EBCOMM_QOS` (`"exact"` / `"sketch"`), defaulting to
    /// exact. Panics on anything else — a misspelled selector silently
    /// falling back would invalidate a storage-parity experiment.
    pub fn from_env() -> Self {
        match std::env::var("EBCOMM_QOS") {
            Ok(v) if v.eq_ignore_ascii_case("exact") => QosStorage::Exact,
            Ok(v) if v.eq_ignore_ascii_case("sketch") => QosStorage::Sketch,
            Ok(v) => panic!("EBCOMM_QOS must be \"exact\" or \"sketch\", got {v:?}"),
            Err(_) => QosStorage::Exact,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            QosStorage::Exact => "exact",
            QosStorage::Sketch => "sketch",
        }
    }
}

// ---- quantile sketch constants (mirror qos_sketch_model_fuzz.py) ----

/// Mantissa bits used for the sub-bucket: 2^5 = 32 sub-buckets per
/// octave.
const SUB_BITS: u32 = 5;
const SUBS: usize = 1 << SUB_BITS;
/// Biased exponent of 2^-40 — positive values below collapse into the
/// zero bucket (QoS metrics are rates in [0, 1] and ns-scale times;
/// anything under 2^-40 is indistinguishable from zero for them).
const MIN_EXP: usize = 983;
/// Octaves covered before the top bucket saturates: [2^-40, 2^48) spans
/// sub-ns rates through ~78 virtual hours.
const N_OCTAVES: usize = 88;
/// Fixed bucket count — the whole sketch is `N_BUCKETS` u64 counters.
pub const N_BUCKETS: usize = N_OCTAVES * SUBS;

/// Documented relative-error bound of [`QuantileSketch::quantile`]
/// against the exact nearest-rank quantile, for in-range positives: half
/// of one sub-bucket width with the midpoint representative.
pub const QUANTILE_REL_ERROR_BOUND: f64 = 1.0 / 64.0;

/// Where a value lands: skipped (NaN), the zero bucket, or a log bucket.
enum Slot {
    Skip,
    Zero,
    Bucket(usize),
}

/// Integer-only bucketing over the IEEE-754 bit pattern: biased exponent
/// selects the octave, the top [`SUB_BITS`] mantissa bits the sub-bucket.
/// Monotone non-decreasing in the value (positive f64 ordering is the
/// unsigned ordering of the bit patterns).
fn slot_of(x: f64) -> Slot {
    if x.is_nan() {
        return Slot::Skip;
    }
    let bits = x.to_bits();
    if bits >> 63 != 0 {
        // Negative (metrics are non-negative; a negative reading is a
        // degenerate zero) and -0.0.
        return Slot::Zero;
    }
    let exp = ((bits >> 52) & 0x7ff) as usize;
    if exp < MIN_EXP {
        // +0.0, subnormals, and positives under 2^-40.
        return Slot::Zero;
    }
    if exp == 0x7ff {
        // +inf saturates into the top bucket.
        return Slot::Bucket(N_BUCKETS - 1);
    }
    let sub = ((bits >> (52 - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
    Slot::Bucket(((exp - MIN_EXP) * SUBS + sub).min(N_BUCKETS - 1))
}

/// Midpoint of bucket `idx`, constructed purely from bits: lower edge
/// `2^e · (1 + sub/32)` plus half a sub-bucket (`1` in the next mantissa
/// bit below the sub-bucket field).
fn representative(idx: usize) -> f64 {
    let exp = (MIN_EXP + idx / SUBS) as u64;
    let sub = (idx % SUBS) as u64;
    f64::from_bits((exp << 52) | (sub << (52 - SUB_BITS)) | (1 << (52 - SUB_BITS - 1)))
}

/// Fixed-size relative-error quantile sketch (DDSketch-style log-linear
/// histogram). All state is integral: insert order, merge order, and
/// platform cannot change a single bit of it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuantileSketch {
    /// Log-bucket counters, ascending value order.
    pub(crate) counts: Vec<u64>,
    /// Observations that collapsed to zero (true zeros, negatives,
    /// positives under 2^-40).
    pub(crate) zero: u64,
    /// Non-finite (NaN) observations skipped — mirrors the exact path's
    /// NaN-filtering quantiles.
    pub(crate) skipped: u64,
    /// Finite observations counted (zero bucket included).
    pub(crate) total: u64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new()
    }
}

impl QuantileSketch {
    pub fn new() -> Self {
        Self {
            counts: vec![0; N_BUCKETS],
            zero: 0,
            skipped: 0,
            total: 0,
        }
    }

    pub fn insert(&mut self, x: f64) {
        match slot_of(x) {
            Slot::Skip => self.skipped += 1,
            Slot::Zero => {
                self.zero += 1;
                self.total += 1;
            }
            Slot::Bucket(i) => {
                self.counts[i] += 1;
                self.total += 1;
            }
        }
    }

    /// Fold `other` into `self`. Associative, commutative, idempotent on
    /// empties; the merged state is bit-identical to the straight-through
    /// insert order.
    pub fn merge(&mut self, other: &Self) {
        self.zero += other.zero;
        self.skipped += other.skipped;
        self.total += other.total;
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    /// Finite observations recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Nearest-rank quantile: the representative of the bucket holding
    /// the `ceil(q·n)`-th smallest observation. 0 for an empty sketch.
    /// Within [`QUANTILE_REL_ERROR_BOUND`] of the exact nearest-rank
    /// quantile whenever that quantile is a positive in-range value.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        if rank <= self.zero {
            return 0.0;
        }
        let mut seen = self.zero;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return representative(i);
            }
        }
        representative(N_BUCKETS - 1)
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// Mean over bucket representatives (ascending-index summation, so
    /// deterministic). Carries the same per-value relative error bound
    /// as the quantiles.
    pub fn approx_mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let mut sum = 0.0;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                sum += representative(i) * c as f64;
            }
        }
        sum / self.total as f64
    }

    /// Heap owned by the bucket array.
    pub fn heap_bytes(&self) -> usize {
        self.counts.capacity() * std::mem::size_of::<u64>()
    }

    /// Rebuild from persisted parts: sparse `(bucket, count)` pairs in
    /// strictly ascending bucket order. Validates structure and the
    /// zero-bucket/total ledger — the checkpoint loader's constructor.
    pub(crate) fn from_parts(
        zero: u64,
        skipped: u64,
        total: u64,
        pairs: &[(u32, u64)],
    ) -> Result<Self, &'static str> {
        let mut sk = Self::new();
        sk.zero = zero;
        sk.skipped = skipped;
        sk.total = total;
        let mut sum = zero;
        let mut prev: Option<u32> = None;
        for &(idx, c) in pairs {
            if idx as usize >= N_BUCKETS {
                return Err("sketch bucket index");
            }
            if prev.is_some_and(|p| idx <= p) {
                return Err("sketch bucket order");
            }
            if c == 0 {
                return Err("empty sketch bucket entry");
            }
            sk.counts[idx as usize] = c;
            sum = sum.checked_add(c).ok_or("sketch count overflow")?;
            prev = Some(idx);
        }
        if sum != total {
            return Err("sketch total ledger");
        }
        Ok(sk)
    }
}

// ---- cardinality sketch (HLL) ----------------------------------------

/// Register-index bits: 2^10 = 1024 registers ⇒ ~3.25% asymptotic sigma.
const HLL_P: u32 = 10;
const HLL_M: usize = 1 << HLL_P;
/// Fixed hash seed — never derived from run seeds, so two runs' sketches
/// are always mergeable and cross-comparable.
const HLL_SEED: u64 = 0xEBC0_4444_51E7_C4D1;

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// HyperLogLog distinct counter over `u64` identifiers. Merge is
/// element-wise register max — an exact union, in any order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CardinalitySketch {
    pub(crate) regs: Vec<u8>,
}

impl Default for CardinalitySketch {
    fn default() -> Self {
        Self::new()
    }
}

impl CardinalitySketch {
    pub fn new() -> Self {
        Self {
            regs: vec![0; HLL_M],
        }
    }

    pub fn insert(&mut self, item: u64) {
        let h = splitmix64(item ^ HLL_SEED);
        let idx = (h >> (64 - HLL_P)) as usize;
        let w = h << HLL_P;
        let rank = if w == 0 {
            (64 - HLL_P + 1) as u8
        } else {
            (w.leading_zeros() + 1) as u8
        };
        if rank > self.regs[idx] {
            self.regs[idx] = rank;
        }
    }

    pub fn merge(&mut self, other: &Self) {
        for (a, &b) in self.regs.iter_mut().zip(&other.regs) {
            if b > *a {
                *a = b;
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.regs.iter().all(|&r| r == 0)
    }

    /// Estimated distinct count, with the standard small-range linear
    /// counting correction. ~10% accurate (±a few counts when tiny).
    pub fn estimate(&self) -> f64 {
        let m = HLL_M as f64;
        let alpha = 0.7213 / (1.0 + 1.079 / m);
        // 2^-r computed as an exact power of two — no libm involved.
        let sum: f64 = self.regs.iter().map(|&r| 1.0 / (1u64 << r) as f64).sum();
        let e = alpha * m * m / sum;
        let zeros = self.regs.iter().filter(|&&r| r == 0).count();
        if e <= 2.5 * m && zeros > 0 {
            m * (m / zeros as f64).ln()
        } else {
            e
        }
    }

    pub fn heap_bytes(&self) -> usize {
        self.regs.capacity()
    }

    /// Rebuild from a persisted register file, validating shape and the
    /// per-register rank ceiling.
    pub(crate) fn from_registers(regs: Vec<u8>) -> Result<Self, &'static str> {
        if regs.len() != HLL_M {
            return Err("HLL register count");
        }
        let max_rank = (64 - HLL_P + 1) as u8;
        if regs.iter().any(|&r| r > max_rank) {
            return Err("HLL register rank");
        }
        Ok(Self { regs })
    }
}

// ---- replicate-level sketch bundle ------------------------------------

/// Rebuild a [`ScenarioPhase`] from its persisted bit set.
fn phase_from_bits(bits: u64) -> ScenarioPhase {
    (0..64)
        .filter(|&i| bits & (1u64 << i) != 0)
        .fold(ScenarioPhase::QUIESCENT, |p, i| {
            p.union(ScenarioPhase::single(i))
        })
}

/// One quantile sketch per QoS metric.
type MetricSketches = [QuantileSketch; 5];

fn new_metric_sketches() -> MetricSketches {
    std::array::from_fn(|_| QuantileSketch::new())
}

/// Sketch-backed replicate QoS: the [`QosStorage::Sketch`] counterpart of
/// [`super::snapshot::ReplicateQos`]. Fed one closed window at a time by
/// the engine's capture path; never stores per-channel values.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SketchQos {
    /// Closed (channel, window) observations folded in.
    pub(crate) windows: u64,
    /// Per-metric distribution over all windows.
    pub(crate) overall: MetricSketches,
    /// Per-metric distributions keyed by the window's scenario-phase bit
    /// set, ascending — one entry per *observed* phase, so quiescent
    /// runs carry exactly one.
    pub(crate) by_phase: Vec<(u64, MetricSketches)>,
    /// Distinct channels that attempted at least one send inside an
    /// observed window.
    pub(crate) distinct_channels: CardinalitySketch,
    /// Distinct sender processes behind those channels.
    pub(crate) distinct_senders: CardinalitySketch,
}

impl Default for SketchQos {
    fn default() -> Self {
        Self::new()
    }
}

impl SketchQos {
    pub fn new() -> Self {
        Self {
            windows: 0,
            overall: new_metric_sketches(),
            by_phase: Vec::new(),
            distinct_channels: CardinalitySketch::new(),
            distinct_senders: CardinalitySketch::new(),
        }
    }

    fn phase_entry(&mut self, bits: u64) -> &mut MetricSketches {
        let at = match self.by_phase.binary_search_by_key(&bits, |e| e.0) {
            Ok(i) => i,
            Err(i) => {
                self.by_phase.insert(i, (bits, new_metric_sketches()));
                i
            }
        };
        &mut self.by_phase[at].1
    }

    /// Fold one closed per-channel window in: exactly the values the
    /// exact path would have pushed (`SnapshotWindow::metrics`, inlet and
    /// outlet averaged, tagged with the window's phase union).
    pub fn absorb_window(&mut self, w: &SnapshotWindow, chan_id: u64, sender_id: u64) {
        let m = w.metrics();
        let phase = w.phase().bits();
        let mut values = [0.0f64; 5];
        for name in MetricName::ALL {
            values[name.index()] = m.get(name);
        }
        self.windows += 1;
        for (i, &v) in values.iter().enumerate() {
            self.overall[i].insert(v);
        }
        let set = self.phase_entry(phase);
        for (i, &v) in values.iter().enumerate() {
            set[i].insert(v);
        }
        if w.inlet_after.counters.attempted_sends > w.inlet_before.counters.attempted_sends {
            self.distinct_channels.insert(chan_id);
            self.distinct_senders.insert(sender_id);
        }
    }

    /// As [`Self::absorb_window`] but from an already-computed metrics
    /// row — the hardware executor's bridge, where windows are built from
    /// wall-clock tranches rather than [`SnapshotWindow`]s.
    pub fn absorb_metrics(&mut self, m: &QosMetrics, phase: ScenarioPhase) {
        let mut values = [0.0f64; 5];
        for name in MetricName::ALL {
            values[name.index()] = m.get(name);
        }
        self.windows += 1;
        for (i, &v) in values.iter().enumerate() {
            self.overall[i].insert(v);
        }
        let set = self.phase_entry(phase.bits());
        for (i, &v) in values.iter().enumerate() {
            set[i].insert(v);
        }
    }

    /// Fold another replicate's sketches in (shard-merge / post-restore
    /// merge). Order-invariant: any merge tree yields bit-identical
    /// state.
    pub fn merge(&mut self, other: &Self) {
        self.windows += other.windows;
        for (a, b) in self.overall.iter_mut().zip(&other.overall) {
            a.merge(b);
        }
        for (bits, set) in &other.by_phase {
            let mine = self.phase_entry(*bits);
            for (a, b) in mine.iter_mut().zip(set) {
                a.merge(b);
            }
        }
        self.distinct_channels.merge(&other.distinct_channels);
        self.distinct_senders.merge(&other.distinct_senders);
    }

    /// Closed (channel, window) observations folded in so far.
    pub fn window_count(&self) -> u64 {
        self.windows
    }

    pub fn is_empty(&self) -> bool {
        self.windows == 0
    }

    pub fn quantile(&self, metric: MetricName, q: f64) -> f64 {
        self.overall[metric.index()].quantile(q)
    }

    pub fn median(&self, metric: MetricName) -> f64 {
        self.quantile(metric, 0.5)
    }

    pub fn p95(&self, metric: MetricName) -> f64 {
        self.quantile(metric, 0.95)
    }

    /// Deterministic approximate mean (bucket representatives).
    pub fn approx_mean(&self, metric: MetricName) -> f64 {
        self.overall[metric.index()].approx_mean()
    }

    /// Observed scenario phases, ascending by bit set — quiescent first
    /// when present.
    pub fn phases(&self) -> Vec<ScenarioPhase> {
        self.by_phase.iter().map(|e| phase_from_bits(e.0)).collect()
    }

    /// Quantile over the windows whose phase satisfies `pred` — the
    /// sketch-side counterpart of `ReplicateQos::median_where`. Folds the
    /// matching phase sketches into a scratch sketch (cheap: fixed-size
    /// adds), so any phase predicate is queryable.
    pub fn quantile_where<F: Fn(ScenarioPhase) -> bool>(
        &self,
        metric: MetricName,
        pred: F,
        q: f64,
    ) -> f64 {
        let mut acc = QuantileSketch::new();
        for (bits, set) in &self.by_phase {
            if pred(phase_from_bits(*bits)) {
                acc.merge(&set[metric.index()]);
            }
        }
        acc.quantile(q)
    }

    pub fn median_where<F: Fn(ScenarioPhase) -> bool>(&self, metric: MetricName, pred: F) -> f64 {
        self.quantile_where(metric, pred, 0.5)
    }

    /// Windows recorded under phases satisfying `pred`.
    pub fn window_count_where<F: Fn(ScenarioPhase) -> bool>(&self, pred: F) -> u64 {
        self.by_phase
            .iter()
            .filter(|(bits, _)| pred(phase_from_bits(*bits)))
            .map(|(_, set)| set[0].count() + set[0].skipped)
            .sum()
    }

    /// Estimated distinct channels that sent during observed windows.
    pub fn distinct_channels(&self) -> f64 {
        self.distinct_channels.estimate()
    }

    /// Estimated distinct sender processes during observed windows.
    pub fn distinct_senders(&self) -> f64 {
        self.distinct_senders.estimate()
    }

    /// Heap owned by every constituent sketch — the `qos_sketch` census
    /// line of `Engine::memory_footprint`.
    pub fn heap_bytes(&self) -> usize {
        let quant: usize = self
            .overall
            .iter()
            .chain(self.by_phase.iter().flat_map(|(_, s)| s.iter()))
            .map(QuantileSketch::heap_bytes)
            .sum();
        quant
            + self.by_phase.capacity() * std::mem::size_of::<(u64, MetricSketches)>()
            + self.distinct_channels.heap_bytes()
            + self.distinct_senders.heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Xoshiro256};

    fn exact_nearest_rank(xs: &[f64], q: f64) -> f64 {
        let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
        if v.is_empty() {
            return 0.0;
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((q * v.len() as f64).ceil() as usize).clamp(1, v.len());
        v[rank - 1]
    }

    #[test]
    fn bucket_index_monotone_and_rep_in_bucket() {
        let mut rng = Xoshiro256::new(7);
        for _ in 0..20_000 {
            let a = rng.uniform(1e-9, 1e12);
            let b = a * (1.0 + rng.uniform(0.0, 2.0));
            let (ia, ib) = match (slot_of(a), slot_of(b)) {
                (Slot::Bucket(x), Slot::Bucket(y)) => (x, y),
                _ => continue,
            };
            assert!(ia <= ib, "index not monotone: {a} -> {ia}, {b} -> {ib}");
            let rep = representative(ia);
            assert!(
                (rep / a - 1.0).abs() <= QUANTILE_REL_ERROR_BOUND,
                "representative {rep} off by more than the bound from {a}"
            );
        }
    }

    #[test]
    fn quantiles_within_documented_bound() {
        let mut rng = Xoshiro256::new(0x5EED);
        for _ in 0..60 {
            let n = 1 + rng.below(2000) as usize;
            let xs: Vec<f64> = (0..n)
                .map(|_| match rng.below(5) {
                    0 => 0.0,
                    1 => rng.uniform(0.0, 1.0),
                    2 => rng.exponential(2.0e6),
                    3 => rng.uniform(1.0, 1e12),
                    _ => rng.uniform(1e3, 1e9),
                })
                .collect();
            let mut sk = QuantileSketch::new();
            for &x in &xs {
                sk.insert(x);
            }
            for q in [0.05, 0.5, 0.95, 0.99] {
                let est = sk.quantile(q);
                let exact = exact_nearest_rank(&xs, q);
                if exact == 0.0 {
                    assert_eq!(est, 0.0);
                } else {
                    let rel = (est - exact).abs() / exact;
                    assert!(
                        rel <= QUANTILE_REL_ERROR_BOUND + 1e-12,
                        "q={q}: rel={rel} est={est} exact={exact}"
                    );
                }
            }
        }
    }

    #[test]
    fn merge_is_order_invariant_and_empty_idempotent() {
        let mut rng = Xoshiro256::new(42);
        let xs: Vec<f64> = (0..3000).map(|_| rng.exponential(1e6)).collect();
        let mut whole = QuantileSketch::new();
        for &x in &xs {
            whole.insert(x);
        }
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        let mut c = QuantileSketch::new();
        for (i, &x) in xs.iter().enumerate() {
            [&mut a, &mut b, &mut c][i % 3].insert(x);
        }
        // (a+b)+c and c+(b+a) both equal the straight-through sketch.
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut c_ba = c.clone();
        let mut ba = b.clone();
        ba.merge(&a);
        c_ba.merge(&ba);
        assert_eq!(ab_c, whole);
        assert_eq!(c_ba, whole);
        let before = whole.clone();
        whole.merge(&QuantileSketch::new());
        assert_eq!(whole, before);
    }

    #[test]
    fn nan_skipped_inf_saturates_negatives_zero() {
        let mut sk = QuantileSketch::new();
        sk.insert(f64::NAN);
        sk.insert(f64::INFINITY);
        sk.insert(-3.0);
        sk.insert(0.0);
        assert_eq!(sk.skipped, 1);
        assert_eq!(sk.count(), 3);
        assert_eq!(sk.zero, 2);
        assert_eq!(sk.quantile(1.0), representative(N_BUCKETS - 1));
    }

    #[test]
    fn hll_estimates_within_envelope_and_merges_as_union() {
        for n in [1u64, 17, 500, 5_000, 100_000] {
            let mut sk = CardinalitySketch::new();
            for i in 0..n {
                // splitmix64 is a bijection, so these n items are distinct.
                let item = splitmix64(i ^ 0xD157_1AC7);
                sk.insert(item);
                sk.insert(item); // duplicates are free
            }
            let est = sk.estimate();
            let err = (est - n as f64).abs();
            assert!(
                err <= 4.0 + 0.10 * n as f64,
                "HLL err {err} at n={n} (est {est})"
            );
        }
        let mut a = CardinalitySketch::new();
        let mut b = CardinalitySketch::new();
        let mut u = CardinalitySketch::new();
        for i in 0..3000u64 {
            a.insert(i);
            u.insert(i);
        }
        for i in 2000..7000u64 {
            b.insert(i);
            u.insert(i);
        }
        a.merge(&b);
        assert_eq!(a, u);
    }

    #[test]
    fn storage_from_env_defaults_exact() {
        // Don't touch the process env (tests run concurrently) — just pin
        // the default and the labels.
        assert_eq!(QosStorage::default(), QosStorage::Exact);
        assert_eq!(QosStorage::Exact.label(), "exact");
        assert_eq!(QosStorage::Sketch.label(), "sketch");
    }

    #[test]
    fn sketch_qos_phase_split_and_merge() {
        use crate::conduit::CounterTranche;
        use crate::qos::QosObservation;
        let mk = |updates, wall, phase| QosObservation {
            counters: CounterTranche::default(),
            update_count: updates,
            wall_ns: wall,
            phase,
        };
        let quiet = ScenarioPhase::QUIESCENT;
        let storm = ScenarioPhase::single(2);
        let w_quiet = SnapshotWindow {
            inlet_before: mk(0, 0, quiet),
            inlet_after: mk(10, 1_000, quiet),
            outlet_before: mk(0, 0, quiet),
            outlet_after: mk(10, 1_000, quiet),
        };
        let w_storm = SnapshotWindow {
            inlet_before: mk(0, 0, quiet),
            inlet_after: mk(10, 9_000, storm),
            outlet_before: mk(0, 0, quiet),
            outlet_after: mk(10, 9_000, storm),
        };
        let mut sq = SketchQos::new();
        sq.absorb_window(&w_quiet, 0, 0);
        sq.absorb_window(&w_storm, 1, 1);
        assert_eq!(sq.window_count(), 2);
        assert_eq!(sq.phases(), vec![quiet, storm]);
        // periods: 100 ns quiet, 900 ns storm — medians land in-bucket.
        let quiet_med = sq.median_where(MetricName::SimstepPeriod, |p| p.is_quiescent());
        let storm_med = sq.median_where(MetricName::SimstepPeriod, |p| p.contains(2));
        assert!((quiet_med / 100.0 - 1.0).abs() <= QUANTILE_REL_ERROR_BOUND);
        assert!((storm_med / 900.0 - 1.0).abs() <= QUANTILE_REL_ERROR_BOUND);
        // split-and-merge equals straight-through, bit for bit.
        let mut p1 = SketchQos::new();
        p1.absorb_window(&w_quiet, 0, 0);
        let mut p2 = SketchQos::new();
        p2.absorb_window(&w_storm, 1, 1);
        let mut merged = SketchQos::new();
        merged.merge(&p2);
        merged.merge(&p1);
        assert_eq!(merged, sq);
        assert!(sq.heap_bytes() > 0);
    }
}
