//! The paper's five quality-of-service metrics (§II-D).
//!
//! All metrics are derived from two observations ("tranches") bracketing a
//! snapshot window during which the simulation runs unimpeded:
//!
//! * **Simstep period** — wall-time elapsed per simulation update:
//!   `(wall after − wall before) / (updates after − updates before)`.
//! * **Simstep latency** — simulation updates elapsed per message one-way
//!   trip, estimated from round-trip *touch counters*:
//!   `(updates after − updates before) / max(touches after − touches
//!   before, 1)`. (The paper prints `min`, but describes counting "at
//!   least one elapsed touch" — i.e. a floor on the denominator, which is
//!   `max`. We implement the described best-case assumption.)
//! * **Walltime latency** — `simstep latency × simstep period`.
//! * **Delivery failure rate** — `1 − successful sends / attempted sends`
//!   over the window. (The paper's formula shows the success ratio; the
//!   reported metric is the failure fraction.)
//! * **Delivery clumpiness** — `1 − steadiness` where
//!   `steadiness = laden pulls / min(messages received, pull attempts)`.
//!
//! Touch-counter protocol (§II-D.2): each element keeps a zero-initialized
//! counter per neighbor; outgoing messages bundle the counter associated
//! with the target; when a message arrives back from the target, the local
//! counter is set to `1 + bundled value`, so one completed round trip
//! advances it by two.

use crate::conduit::CounterTranche;
use crate::faults::ScenarioPhase;
use crate::util::Nanos;

/// One endpoint observation: channel counters plus the owning process's
/// update counter and wall clock.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QosObservation {
    pub counters: CounterTranche,
    pub update_count: u64,
    pub wall_ns: Nanos,
    /// Scenario faults in force when the observation was captured
    /// (quiescent for static-profile runs; the real-thread executor tags
    /// its observations from the compiled wall-clock timeline the same
    /// way the DES tags from the overlay). Window-closing observations
    /// carry the union over the whole window, so faults that started
    /// *and* ended inside it are not lost.
    pub phase: ScenarioPhase,
}

impl QosObservation {
    /// Record one endpoint observation (a counter tranche bracketed with
    /// the owning process's update count and wall clock).
    pub fn capture(counters: CounterTranche, update_count: u64, wall_ns: Nanos) -> Self {
        Self::capture_phased(counters, update_count, wall_ns, ScenarioPhase::QUIESCENT)
    }

    /// [`Self::capture`] tagged with the scenario phase in force.
    pub fn capture_phased(
        counters: CounterTranche,
        update_count: u64,
        wall_ns: Nanos,
        phase: ScenarioPhase,
    ) -> Self {
        Self {
            counters,
            update_count,
            wall_ns,
            phase,
        }
    }
}

/// The five QoS metrics for one snapshot window on one channel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QosMetrics {
    /// Wall-time per simulation update (ns). Lower is better.
    pub simstep_period_ns: f64,
    /// One-way message latency in elapsed simulation updates.
    pub simstep_latency: f64,
    /// One-way message latency in wall-time (ns).
    pub walltime_latency_ns: f64,
    /// Fraction of attempted sends dropped, in `[0, 1]` (may exceed
    /// slightly under observation blur; see paper §II-E).
    pub delivery_failure_rate: f64,
    /// `1 − steadiness`, in `[0, 1]`.
    pub delivery_clumpiness: f64,
}

impl QosMetrics {
    /// Compute all five metrics from before/after observations.
    pub fn from_window(before: &QosObservation, after: &QosObservation) -> QosMetrics {
        let d = after.counters.delta(&before.counters);
        let updates = after.update_count.saturating_sub(before.update_count);
        let wall = after.wall_ns.saturating_sub(before.wall_ns);

        let simstep_period_ns = if updates == 0 {
            // No updates elapsed: period is at least the whole window.
            wall as f64
        } else {
            wall as f64 / updates as f64
        };

        // Touch counter advances by 2 per round trip => one-way trips
        // completed = touches elapsed; elapsed updates per one-way trip:
        let touches = d.touches.max(1);
        let simstep_latency = updates as f64 / touches as f64;

        let walltime_latency_ns = simstep_latency * simstep_period_ns;

        let delivery_failure_rate = if d.attempted_sends == 0 {
            0.0
        } else {
            1.0 - d.successful_sends as f64 / d.attempted_sends as f64
        };

        let delivery_clumpiness =
            1.0 - steadiness(d.laden_pulls, d.messages_received, d.pull_attempts);

        QosMetrics {
            simstep_period_ns,
            simstep_latency,
            walltime_latency_ns,
            delivery_failure_rate,
            delivery_clumpiness,
        }
    }

    /// Mean of two metric sets (used to average inlet- and outlet-derived
    /// statistics, §II-E: "we simply report the mean over these two
    /// options").
    pub fn mean_with(&self, other: &QosMetrics) -> QosMetrics {
        QosMetrics {
            simstep_period_ns: 0.5 * (self.simstep_period_ns + other.simstep_period_ns),
            simstep_latency: 0.5 * (self.simstep_latency + other.simstep_latency),
            walltime_latency_ns: 0.5 * (self.walltime_latency_ns + other.walltime_latency_ns),
            delivery_failure_rate: 0.5
                * (self.delivery_failure_rate + other.delivery_failure_rate),
            delivery_clumpiness: 0.5 * (self.delivery_clumpiness + other.delivery_clumpiness),
        }
    }

    /// Extract a metric by name (report/bench plumbing).
    pub fn get(&self, name: MetricName) -> f64 {
        match name {
            MetricName::SimstepPeriod => self.simstep_period_ns,
            MetricName::SimstepLatency => self.simstep_latency,
            MetricName::WalltimeLatency => self.walltime_latency_ns,
            MetricName::DeliveryFailureRate => self.delivery_failure_rate,
            MetricName::DeliveryClumpiness => self.delivery_clumpiness,
        }
    }
}

/// Identifier for one of the five QoS metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MetricName {
    SimstepPeriod,
    SimstepLatency,
    WalltimeLatency,
    DeliveryFailureRate,
    DeliveryClumpiness,
}

impl MetricName {
    pub const ALL: [MetricName; 5] = [
        MetricName::SimstepPeriod,
        MetricName::SimstepLatency,
        MetricName::WalltimeLatency,
        MetricName::DeliveryFailureRate,
        MetricName::DeliveryClumpiness,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            MetricName::SimstepPeriod => "Simstep Period (ns)",
            MetricName::SimstepLatency => "Latency Simsteps",
            MetricName::WalltimeLatency => "Latency Walltime (ns)",
            MetricName::DeliveryFailureRate => "Delivery Failure Rate",
            MetricName::DeliveryClumpiness => "Delivery Clumpiness",
        }
    }

    /// Snake-case identifier for machine-readable outputs (bench JSON
    /// entry names, dashboard keys).
    pub fn key(&self) -> &'static str {
        match self {
            MetricName::SimstepPeriod => "simstep_period_ns",
            MetricName::SimstepLatency => "simstep_latency",
            MetricName::WalltimeLatency => "walltime_latency_ns",
            MetricName::DeliveryFailureRate => "delivery_failure_rate",
            MetricName::DeliveryClumpiness => "delivery_clumpiness",
        }
    }

    /// Unit string for machine-readable outputs (`BenchJson` entries).
    pub fn unit(&self) -> &'static str {
        match self {
            MetricName::SimstepPeriod | MetricName::WalltimeLatency => "ns",
            MetricName::SimstepLatency => "steps",
            MetricName::DeliveryFailureRate | MetricName::DeliveryClumpiness => "rate",
        }
    }

    /// Dense index in [`Self::ALL`] order — the layout of the per-metric
    /// sketch arrays in [`crate::qos::SketchQos`].
    pub fn index(&self) -> usize {
        match self {
            MetricName::SimstepPeriod => 0,
            MetricName::SimstepLatency => 1,
            MetricName::WalltimeLatency => 2,
            MetricName::DeliveryFailureRate => 3,
            MetricName::DeliveryClumpiness => 4,
        }
    }
}

/// Steadiness component statistic (§II-D.5).
///
/// `laden / min(messages, pulls)`; 1.0 when no opportunities existed
/// (an idle window is perfectly steady, not clumpy).
pub fn steadiness(laden_pulls: u64, messages_received: u64, pull_attempts: u64) -> f64 {
    let opportunities = messages_received.min(pull_attempts);
    if opportunities == 0 {
        1.0
    } else {
        (laden_pulls as f64 / opportunities as f64).min(1.0)
    }
}

/// Touch-counter bookkeeping for one element↔neighbor relationship.
#[derive(Clone, Copy, Debug, Default)]
pub struct TouchCounter {
    value: u64,
}

impl TouchCounter {
    /// Value to bundle with an outgoing message to the partner.
    #[inline]
    pub fn outgoing(&self) -> u64 {
        self.value
    }

    /// Record an incoming message from the partner carrying `bundled`.
    /// Advances the counter by two per completed round trip.
    #[inline]
    pub fn on_receive(&mut self, bundled: u64) {
        // Only advance; a stale bundled value (from a long-delayed message)
        // must not rewind progress.
        self.value = self.value.max(1 + bundled);
    }

    #[inline]
    pub fn value(&self) -> u64 {
        self.value
    }

    /// Rebuild a counter from a previously read [`Self::value`] —
    /// checkpoint restore (the value is the counter's entire state).
    #[inline]
    pub fn from_value(value: u64) -> Self {
        Self { value }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{forall, prop_assert, Config};

    fn obs(
        updates: u64,
        wall: Nanos,
        attempted: u64,
        successful: u64,
        pulls: u64,
        laden: u64,
        msgs: u64,
        touches: u64,
    ) -> QosObservation {
        QosObservation {
            counters: CounterTranche {
                attempted_sends: attempted,
                successful_sends: successful,
                pull_attempts: pulls,
                laden_pulls: laden,
                messages_received: msgs,
                touches,
            },
            update_count: updates,
            wall_ns: wall,
            phase: ScenarioPhase::QUIESCENT,
        }
    }

    #[test]
    fn simstep_period_basic() {
        let before = obs(100, 0, 0, 0, 0, 0, 0, 0);
        let after = obs(200, 1_000_000, 0, 0, 0, 0, 0, 0);
        let m = QosMetrics::from_window(&before, &after);
        assert_eq!(m.simstep_period_ns, 10_000.0); // 1ms / 100 updates
    }

    #[test]
    fn latency_from_touches() {
        // 100 updates, 50 touches elapsed => 2 updates per one-way trip.
        let before = obs(0, 0, 0, 0, 0, 0, 0, 0);
        let after = obs(100, 1_000_000, 0, 0, 0, 0, 0, 50);
        let m = QosMetrics::from_window(&before, &after);
        assert_eq!(m.simstep_latency, 2.0);
        assert_eq!(m.walltime_latency_ns, 2.0 * 10_000.0);
    }

    #[test]
    fn zero_touches_best_case_assumption() {
        let before = obs(0, 0, 0, 0, 0, 0, 0, 0);
        let after = obs(40, 1_000, 0, 0, 0, 0, 0, 0);
        let m = QosMetrics::from_window(&before, &after);
        // Denominator floored at 1.
        assert_eq!(m.simstep_latency, 40.0);
    }

    #[test]
    fn failure_rate() {
        let before = obs(0, 0, 0, 0, 0, 0, 0, 0);
        let after = obs(10, 1_000, 100, 70, 0, 0, 0, 0);
        let m = QosMetrics::from_window(&before, &after);
        assert!((m.delivery_failure_rate - 0.3).abs() < 1e-12);
    }

    #[test]
    fn failure_rate_no_sends_is_zero() {
        let before = obs(0, 0, 0, 0, 0, 0, 0, 0);
        let after = obs(10, 1_000, 0, 0, 0, 0, 0, 0);
        assert_eq!(
            QosMetrics::from_window(&before, &after).delivery_failure_rate,
            0.0
        );
    }

    #[test]
    fn clumpiness_extremes() {
        // All messages in one pull out of many: clumpy.
        let before = obs(0, 0, 0, 0, 0, 0, 0, 0);
        let after = obs(10, 1_000, 0, 0, 100, 1, 100, 0);
        let m = QosMetrics::from_window(&before, &after);
        assert!((m.delivery_clumpiness - 0.99).abs() < 1e-12);

        // One message per pull: perfectly steady.
        let after = obs(10, 1_000, 0, 0, 100, 100, 100, 0);
        let m = QosMetrics::from_window(&before, &after);
        assert_eq!(m.delivery_clumpiness, 0.0);

        // Pigeonhole regime: more messages than pulls, every pull laden.
        let after = obs(10, 1_000, 0, 0, 10, 10, 100, 0);
        let m = QosMetrics::from_window(&before, &after);
        assert_eq!(m.delivery_clumpiness, 0.0);
    }

    #[test]
    fn idle_window_not_clumpy() {
        let before = obs(0, 0, 0, 0, 0, 0, 0, 0);
        let after = obs(10, 1_000, 0, 0, 50, 0, 0, 0);
        assert_eq!(
            QosMetrics::from_window(&before, &after).delivery_clumpiness,
            0.0
        );
    }

    #[test]
    fn touch_counter_round_trip_advances_by_two() {
        let mut a = TouchCounter::default();
        let mut b = TouchCounter::default();
        // A sends to B bundling 0; B receives: b = 1.
        b.on_receive(a.outgoing());
        assert_eq!(b.value(), 1);
        // B sends to A bundling 1; A receives: a = 2 — one round trip.
        a.on_receive(b.outgoing());
        assert_eq!(a.value(), 2);
        b.on_receive(a.outgoing());
        a.on_receive(b.outgoing());
        assert_eq!(a.value(), 4);
    }

    #[test]
    fn touch_counter_ignores_stale() {
        let mut a = TouchCounter::default();
        a.on_receive(9); // value 10
        a.on_receive(3); // stale, must not rewind
        assert_eq!(a.value(), 10);
    }

    #[test]
    fn inlet_outlet_mean() {
        let m1 = QosMetrics {
            simstep_period_ns: 10.0,
            simstep_latency: 2.0,
            walltime_latency_ns: 20.0,
            delivery_failure_rate: 0.0,
            delivery_clumpiness: 0.5,
        };
        let m2 = QosMetrics {
            simstep_period_ns: 20.0,
            simstep_latency: 4.0,
            walltime_latency_ns: 80.0,
            delivery_failure_rate: 0.2,
            delivery_clumpiness: 0.7,
        };
        let m = m1.mean_with(&m2);
        assert_eq!(m.simstep_period_ns, 15.0);
        assert_eq!(m.simstep_latency, 3.0);
        assert!((m.delivery_failure_rate - 0.1).abs() < 1e-12);
    }

    #[test]
    fn prop_metrics_bounded() {
        forall(Config::default().cases(256), |g| {
            let attempted = g.u64_in(0, 10_000);
            let successful = g.u64_in(0, attempted.max(0));
            let pulls = g.u64_in(0, 10_000);
            let laden = g.u64_in(0, pulls);
            // messages >= laden (each laden pull retrieves >= 1)
            let msgs = g.u64_in(laden, laden + 10_000);
            let updates = g.u64_in(0, 1_000_000);
            let wall = g.u64_in(1, u64::MAX / 2);
            let touches = g.u64_in(0, updates.max(1));
            let before = obs(0, 0, 0, 0, 0, 0, 0, 0);
            let after = obs(updates, wall, attempted, successful, pulls, laden, msgs, touches);
            let m = QosMetrics::from_window(&before, &after);
            prop_assert(
                (0.0..=1.0).contains(&m.delivery_failure_rate),
                format!("failure rate {}", m.delivery_failure_rate),
            )?;
            prop_assert(
                (0.0..=1.0).contains(&m.delivery_clumpiness),
                format!("clumpiness {}", m.delivery_clumpiness),
            )?;
            prop_assert(m.simstep_period_ns >= 0.0, "negative period")?;
            prop_assert(m.simstep_latency >= 0.0, "negative latency")?;
            prop_assert(
                (m.walltime_latency_ns - m.simstep_latency * m.simstep_period_ns).abs()
                    <= 1e-9 * m.walltime_latency_ns.abs().max(1.0),
                "walltime latency != simstep latency * period",
            )
        });
    }
}
