//! Quality-of-service metrics and snapshot machinery (paper §II-D/E).

pub mod metrics;
pub mod sketch;
pub mod snapshot;

pub use metrics::{MetricName, QosMetrics, QosObservation, TouchCounter};
pub use sketch::{
    CardinalitySketch, QosStorage, QuantileSketch, SketchQos, QUANTILE_REL_ERROR_BOUND,
};
pub use snapshot::{ReplicateQos, SnapshotSchedule, SnapshotWindow};

/// Re-exported for convenience: every QoS window carries the scenario
/// phase (set of active faults) it was measured under.
pub use crate::faults::ScenarioPhase;
