//! Quality-of-service metrics and snapshot machinery (paper §II-D/E).

pub mod metrics;
pub mod snapshot;

pub use metrics::{MetricName, QosMetrics, QosObservation, TouchCounter};
pub use snapshot::{ReplicateQos, SnapshotSchedule, SnapshotWindow};
