//! Experiment orchestration: configs for every paper table/figure,
//! replicate sweeps, and report rendering.

pub mod experiment;
pub mod hardware;
pub mod report;
pub mod runner;

pub use experiment::{
    BenchmarkExperiment, QosExperiment, ScenarioExperiment, ScenarioKind, Workload,
};
pub use hardware::{
    run_hardware, run_multiproc_sweep, HardwareExperiment, HardwarePoint, HardwareResults,
    MultiprocExperiment, MultiprocPoint, MultiprocResults,
};
pub use runner::{
    run_benchmark, run_benchmark_serial, run_benchmark_with_workers, run_qos,
    run_qos_with_workers, run_scenario, run_scenario_with_workers, ScenarioPoint,
    ScenarioResults,
};
