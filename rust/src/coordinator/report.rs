//! Paper-style report rendering: figure bars with bootstrap CIs,
//! regression tables, significance calls.

use crate::qos::MetricName;
use crate::sim::AsyncMode;
use crate::stats::{bootstrap_mean_ci95, mean, median, ols, quantile_regression};
use crate::util::csv::CsvTable;
use crate::util::fmt_ns;

use super::experiment::{ScenarioExperiment, ScenarioKind};
use super::hardware::{HardwareExperiment, HardwareResults};
use super::runner::{BenchmarkResults, QosResults, ScenarioResults};

/// Render a Fig-2/3-style table: per-CPU update rate (or quality) by mode
/// and CPU count, with bootstrapped 95 % CIs.
pub fn benchmark_table(
    title: &str,
    results: &BenchmarkResults,
    cpu_counts: &[usize],
    modes: &[AsyncMode],
    quality: bool,
) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!(
        "{:<34} {:>10} {:>12} {:>12} {:>12}\n",
        "mode",
        "cpus",
        if quality { "quality" } else { "rate/cpu" },
        "ci95_lo",
        "ci95_hi"
    ));
    for &mode in modes {
        for &cpus in cpu_counts {
            let vals = if quality {
                results.qualities(mode, cpus)
            } else {
                results.rates(mode, cpus)
            };
            if vals.is_empty() {
                continue;
            }
            let ci = bootstrap_mean_ci95(&vals, 0xC1);
            out.push_str(&format!(
                "{:<34} {:>10} {:>12.2} {:>12.2} {:>12.2}\n",
                mode.label(),
                cpus,
                ci.estimate,
                ci.lo,
                ci.hi
            ));
        }
    }
    out
}

/// The paper's headline comparisons for a benchmark figure: speedup of
/// best-effort (mode 3) over fully-synchronous (mode 0) at the largest CPU
/// count, and weak-scaling efficiency of mode 3 vs a single CPU.
pub struct Headline {
    pub speedup_mode3_vs_mode0: f64,
    pub scaling_efficiency_mode3: f64,
    pub significant: bool,
}

pub fn headline(results: &BenchmarkResults, max_cpus: usize) -> Headline {
    let m3 = results.rates(AsyncMode::BestEffort, max_cpus);
    let m0 = results.rates(AsyncMode::Sync, max_cpus);
    let single = results.rates(AsyncMode::BestEffort, 1);
    let ci3 = bootstrap_mean_ci95(&m3, 1);
    let ci0 = bootstrap_mean_ci95(&m0, 2);
    Headline {
        speedup_mode3_vs_mode0: if mean(&m0) > 0.0 {
            mean(&m3) / mean(&m0)
        } else {
            f64::NAN
        },
        scaling_efficiency_mode3: if mean(&single) > 0.0 {
            mean(&m3) / mean(&single)
        } else {
            f64::NAN
        },
        significant: ci3.disjoint_from(&ci0),
    }
}

/// Render a QoS metric summary block for one treatment.
pub fn qos_summary(title: &str, results: &QosResults) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!(
        "{:<26} {:>14} {:>14}\n",
        "metric", "mean", "median"
    ));
    for metric in MetricName::ALL {
        let all = results.all_values(metric);
        let (m, md) = (mean(&all), median(&all));
        let (ms, mds) = match metric {
            MetricName::SimstepPeriod | MetricName::WalltimeLatency => {
                (fmt_ns(m), fmt_ns(md))
            }
            _ => (format!("{m:.4}"), format!("{md:.4}")),
        };
        out.push_str(&format!("{:<26} {:>14} {:>14}\n", metric.label(), ms, mds));
    }
    out
}

/// Treatment-comparison regressions (§II-E): OLS on replicate means and
/// quantile regression on replicate medians, with a 0/1-coded treatment.
pub fn qos_comparison(
    title: &str,
    group0: (&str, &QosResults),
    group1: (&str, &QosResults),
) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "== {title}: {} (0) vs {} (1) ==\n",
        group0.0, group1.0
    ));
    out.push_str(&format!(
        "{:<26} {:>14} {:>10} {:>14} {:>10}\n",
        "metric", "mean effect", "p(OLS)", "median effect", "p(QR)"
    ));
    for metric in MetricName::ALL {
        let (mut x, mut ym, mut yq) = (Vec::new(), Vec::new(), Vec::new());
        for r in &group0.1.replicates {
            x.push(0.0);
            ym.push(r.qos.mean(metric));
            yq.push(r.qos.median(metric));
        }
        for r in &group1.1.replicates {
            x.push(1.0);
            ym.push(r.qos.mean(metric));
            yq.push(r.qos.median(metric));
        }
        let o = ols(&x, &ym);
        let q = quantile_regression(&x, &yq, 0x9E);
        let (oe, op) = o.map(|f| (f.slope, f.p_value)).unwrap_or((f64::NAN, f64::NAN));
        let (qe, qp) = q.map(|f| (f.slope, f.p_value)).unwrap_or((f64::NAN, f64::NAN));
        out.push_str(&format!(
            "{:<26} {:>14.4e} {:>10.4} {:>14.4e} {:>10.4}\n",
            metric.label(),
            oe,
            op,
            qe,
            qp
        ));
    }
    out
}

/// Weak-scaling regressions against log4(process count), complete and
/// piecewise-rightmost (paper Figs. 4–8).
pub fn scaling_regression(
    title: &str,
    points: &[(usize, QosResults)],
    metric: MetricName,
) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title}: {} vs log4(procs) ==\n", metric.label()));
    let log4 = |p: usize| (p as f64).ln() / 4.0f64.ln();

    let fit_over = |counts: &[usize]| -> String {
        let (mut x, mut ym, mut yq) = (Vec::new(), Vec::new(), Vec::new());
        for (procs, res) in points.iter().filter(|(p, _)| counts.contains(p)) {
            for r in &res.replicates {
                x.push(log4(*procs));
                ym.push(r.qos.mean(metric));
                yq.push(r.qos.median(metric));
            }
        }
        let o = ols(&x, &ym);
        let q = quantile_regression(&x, &yq, 0x5CA1);
        let (oe, op) = o.map(|f| (f.slope, f.p_value)).unwrap_or((f64::NAN, f64::NAN));
        let (qe, qp) = q.map(|f| (f.slope, f.p_value)).unwrap_or((f64::NAN, f64::NAN));
        format!(
            "  procs {counts:?}: OLS slope {oe:.4e} (p={op:.4}) | QR slope {qe:.4e} (p={qp:.4})\n"
        )
    };

    let all: Vec<usize> = points.iter().map(|(p, _)| *p).collect();
    out.push_str(&fit_over(&all));
    if all.len() >= 2 {
        let rightmost: Vec<usize> = all[all.len() - 2..].to_vec();
        out.push_str(&fit_over(&rightmost));
    }
    out
}

/// Overview table for a scenario sweep: per (scenario, mode, procs)
/// treatment, the whole-run update rate and failure plus median simstep
/// period over replicates.
pub fn scenario_table(title: &str, exp: &ScenarioExperiment, results: &ScenarioResults) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!(
        "{:<18} {:<34} {:>6} {:>12} {:>10} {:>14}\n",
        "scenario", "mode", "procs", "rate/cpu", "fail", "med period"
    ));
    for &kind in &exp.scenarios {
        for &mode in &exp.modes {
            for &n_procs in &exp.proc_counts {
                let cells = results.select(kind, mode, n_procs);
                if cells.is_empty() {
                    continue;
                }
                let rate = mean(&cells.iter().map(|p| p.update_rate_hz).collect::<Vec<_>>());
                let fail = mean(&cells.iter().map(|p| p.failure_rate).collect::<Vec<_>>());
                let period = median(&results.all_values(
                    kind,
                    mode,
                    n_procs,
                    MetricName::SimstepPeriod,
                ));
                out.push_str(&format!(
                    "{:<18} {:<34} {:>6} {:>12.1} {:>10.4} {:>14}\n",
                    kind.label(),
                    mode.label(),
                    n_procs,
                    rate,
                    fail,
                    fmt_ns(period),
                ));
            }
        }
    }
    out
}

/// Shared body of every phase-attribution table: per QoS metric, count
/// and median over quiescent vs fault-active window populations.
/// `split` supplies the two populations for one metric; the DES,
/// adaptive, and hardware attribution blocks all render through here so
/// their column layouts cannot drift apart.
fn phase_attribution_body(split: impl Fn(MetricName) -> (Vec<f64>, Vec<f64>)) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<26} {:>8} {:>14} {:>8} {:>14}\n",
        "metric", "n(quiet)", "med(quiet)", "n(fault)", "med(fault)"
    ));
    for metric in MetricName::ALL {
        let (quiet, fault) = split(metric);
        let (mq, mf) = (median(&quiet), median(&fault));
        let (sq, sf) = match metric {
            MetricName::SimstepPeriod | MetricName::WalltimeLatency => (fmt_ns(mq), fmt_ns(mf)),
            _ => (format!("{mq:.4}"), format!("{mf:.4}")),
        };
        out.push_str(&format!(
            "{:<26} {:>8} {:>14} {:>8} {:>14}\n",
            metric.label(),
            quiet.len(),
            sq,
            fault.len(),
            sf,
        ));
    }
    out
}

/// Time-resolved attribution block for one treatment: every QoS metric's
/// median over quiescent windows vs fault-active windows — the query the
/// scenario subsystem exists to answer.
pub fn phase_attribution(
    title: &str,
    results: &ScenarioResults,
    scenario: ScenarioKind,
    mode: AsyncMode,
    n_procs: usize,
) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "== {title}: {} @ {} procs, {} ==\n",
        scenario.label(),
        n_procs,
        mode.label()
    ));
    out.push_str(&phase_attribution_body(|metric| {
        results.phase_split(scenario, mode, n_procs, metric)
    }));
    out
}

/// [`phase_attribution`] for the adaptive-controller treatment of one
/// (scenario, procs) cell family.
pub fn adaptive_phase_attribution(
    title: &str,
    results: &ScenarioResults,
    scenario: ScenarioKind,
    n_procs: usize,
) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "== {title}: {} @ {} procs, adaptive ==\n",
        scenario.label(),
        n_procs,
    ));
    out.push_str(&phase_attribution_body(|metric| {
        results.phase_split_adaptive(scenario, n_procs, metric)
    }));
    out
}

/// Adaptive-vs-static comparison: per (scenario, procs), the best
/// static mode by median whole-run delivery failure against the
/// adaptive controller's cells, with controller activity (escalations,
/// heal-backs, channels still escalated at run end). The acceptance
/// question for the controller: does it match or beat the best static
/// mode per fault family?
pub fn adaptive_table(
    title: &str,
    exp: &ScenarioExperiment,
    results: &ScenarioResults,
) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!(
        "{:<18} {:>6} {:<12} {:>11} {:>11} {:>6} {:>6} {:>8} {:>9}\n",
        "scenario",
        "procs",
        "best static",
        "stat fail",
        "adpt fail",
        "flips",
        "heals",
        "esc@end",
        "verdict"
    ));
    for &kind in &exp.scenarios {
        for &n_procs in &exp.proc_counts {
            let ad = results.select_adaptive(kind, n_procs);
            if ad.is_empty() {
                continue;
            }
            let mut best: Option<(AsyncMode, f64)> = None;
            for &mode in &exp.modes {
                let cells = results.select(kind, mode, n_procs);
                if cells.is_empty() {
                    continue;
                }
                let f = median(&cells.iter().map(|p| p.failure_rate).collect::<Vec<_>>());
                if best.is_none() || f < best.unwrap().1 {
                    best = Some((mode, f));
                }
            }
            let Some((best_mode, best_fail)) = best else {
                continue;
            };
            let adpt_fail = median(&ad.iter().map(|p| p.failure_rate).collect::<Vec<_>>());
            let flips: u64 = ad.iter().map(|p| p.policy_flips).sum();
            let heals: u64 = ad.iter().map(|p| p.policy_heals).sum();
            let esc: u64 = ad.iter().map(|p| p.policy_escalated_final).sum();
            let verdict = if adpt_fail <= best_fail {
                "<= best"
            } else {
                "> best"
            };
            out.push_str(&format!(
                "{:<18} {:>6} {:<12} {:>11.4} {:>11.4} {:>6} {:>6} {:>8} {:>9}\n",
                kind.label(),
                n_procs,
                format!("mode {}", best_mode.index()),
                best_fail,
                adpt_fail,
                flips,
                heals,
                esc,
                verdict,
            ));
        }
    }
    out
}

/// Dump scenario sweep points to CSV (one row per channel-window
/// snapshot — `ReplicateQos` flattens windows × channels, so `snapshot`
/// is that flat index, not a chronological window number — with its
/// phase bitmask) for external analysis. Chronological grouping is
/// recoverable via `phase_bits` or `snapshot / n_channels`.
pub fn scenario_csv(results: &ScenarioResults) -> CsvTable {
    let mut t = CsvTable::new(vec![
        "scenario",
        "mode",
        "procs",
        "replicate",
        "snapshot",
        "phase_bits",
        "simstep_period_ns",
        "simstep_latency",
        "walltime_latency_ns",
        "delivery_failure_rate",
        "delivery_clumpiness",
        "adaptive",
    ]);
    for p in &results.points {
        for (w, (m, ph)) in p.qos.snapshots.iter().zip(p.qos.phases.iter()).enumerate() {
            t.push_row(vec![
                p.scenario.label().to_string(),
                p.mode.index().to_string(),
                p.n_procs.to_string(),
                p.replicate.to_string(),
                w.to_string(),
                format!("{:#x}", ph.bits()),
                format!("{}", m.simstep_period_ns),
                format!("{}", m.simstep_latency),
                format!("{}", m.walltime_latency_ns),
                format!("{}", m.delivery_failure_rate),
                format!("{}", m.delivery_clumpiness),
                u8::from(p.adaptive).to_string(),
            ]);
        }
    }
    t
}

/// Overview table for a hardware (real-thread) sweep: per (mode, shard
/// count) treatment, the real thread count, measured update rate,
/// whole-run delivery failure, and median windowed period/clumpiness —
/// the same columns the DES tables report, measured on metal.
pub fn hardware_table(
    title: &str,
    exp: &HardwareExperiment,
    results: &HardwareResults,
) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!(
        "{:<34} {:>7} {:>8} {:>12} {:>10} {:>14} {:>10}\n",
        "mode", "shards", "threads", "rate/shard", "fail", "med period", "med clump"
    ));
    for &mode in &exp.modes {
        for &n_shards in &exp.shard_counts {
            let cells = results.select(mode, n_shards);
            if cells.is_empty() {
                continue;
            }
            let threads = cells[0].threads;
            let rate = mean(&cells.iter().map(|p| p.update_rate_hz).collect::<Vec<_>>());
            let fail = mean(&cells.iter().map(|p| p.failure_rate).collect::<Vec<_>>());
            let period = median(&results.all_values(mode, n_shards, MetricName::SimstepPeriod));
            let clump =
                median(&results.all_values(mode, n_shards, MetricName::DeliveryClumpiness));
            out.push_str(&format!(
                "{:<34} {:>7} {:>8} {:>12.1} {:>10.4} {:>14} {:>10.4}\n",
                mode.label(),
                n_shards,
                threads,
                rate,
                fail,
                fmt_ns(period),
                clump,
            ));
        }
    }
    out
}

/// Hardware-side time-resolved attribution: every QoS metric's median
/// over quiescent vs fault-active windows for one (mode, shards)
/// treatment — the same query [`phase_attribution`] answers for DES
/// scenario sweeps.
pub fn hardware_phase_attribution(
    title: &str,
    results: &HardwareResults,
    mode: AsyncMode,
    n_shards: usize,
) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "== {title}: {n_shards} shards, {} ==\n",
        mode.label()
    ));
    out.push_str(&phase_attribution_body(|metric| {
        results.phase_split(mode, n_shards, metric)
    }));
    out
}

/// Dump hardware sweep points to CSV (one row per window snapshot with
/// its phase bitmask), mirroring [`scenario_csv`].
pub fn hardware_csv(results: &HardwareResults) -> CsvTable {
    let mut t = CsvTable::new(vec![
        "mode",
        "shards",
        "threads",
        "replicate",
        "snapshot",
        "phase_bits",
        "simstep_period_ns",
        "simstep_latency",
        "walltime_latency_ns",
        "delivery_failure_rate",
        "delivery_clumpiness",
    ]);
    for p in &results.points {
        for (w, (m, ph)) in p.qos.snapshots.iter().zip(p.qos.phases.iter()).enumerate() {
            t.push_row(vec![
                p.mode.index().to_string(),
                p.n_shards.to_string(),
                p.threads.to_string(),
                p.replicate.to_string(),
                w.to_string(),
                format!("{:#x}", ph.bits()),
                format!("{}", m.simstep_period_ns),
                format!("{}", m.simstep_latency),
                format!("{}", m.walltime_latency_ns),
                format!("{}", m.delivery_failure_rate),
                format!("{}", m.delivery_clumpiness),
            ]);
        }
    }
    t
}

/// Dump benchmark points to CSV for external analysis.
pub fn benchmark_csv(results: &BenchmarkResults) -> CsvTable {
    let mut t = CsvTable::new(vec![
        "mode", "cpus", "replicate", "update_rate_hz", "quality", "failure_rate",
    ]);
    for p in &results.points {
        t.push_row(vec![
            p.mode.index().to_string(),
            p.n_cpus.to_string(),
            p.replicate.to_string(),
            format!("{}", p.update_rate_hz),
            format!("{}", p.quality),
            format!("{}", p.failure_rate),
        ]);
    }
    t
}

/// Dump QoS snapshot metrics to CSV.
pub fn qos_csv(results: &QosResults) -> CsvTable {
    let mut t = CsvTable::new(vec![
        "replicate",
        "simstep_period_ns",
        "simstep_latency",
        "walltime_latency_ns",
        "delivery_failure_rate",
        "delivery_clumpiness",
    ]);
    for r in &results.replicates {
        for m in &r.qos.snapshots {
            t.push_row(vec![
                r.replicate.to_string(),
                format!("{}", m.simstep_period_ns),
                format!("{}", m.simstep_latency),
                format!("{}", m.walltime_latency_ns),
                format!("{}", m.delivery_failure_rate),
                format!("{}", m.delivery_clumpiness),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::runner::{BenchmarkPoint, QosReplicate};
    use crate::qos::{QosMetrics, ReplicateQos};

    fn fake_bench() -> BenchmarkResults {
        let mut r = BenchmarkResults::default();
        for rep in 0..3 {
            for (mode, rate) in [(AsyncMode::Sync, 100.0), (AsyncMode::BestEffort, 500.0)] {
                r.points.push(BenchmarkPoint {
                    mode,
                    n_cpus: 64,
                    replicate: rep,
                    update_rate_hz: rate + rep as f64,
                    quality: 10.0,
                    failure_rate: 0.0,
                });
                r.points.push(BenchmarkPoint {
                    mode,
                    n_cpus: 1,
                    replicate: rep,
                    update_rate_hz: 600.0,
                    quality: 5.0,
                    failure_rate: 0.0,
                });
            }
        }
        r
    }

    fn fake_qos(scale: f64) -> QosResults {
        let mut out = QosResults::default();
        for rep in 0..4 {
            let mut q = ReplicateQos::default();
            for i in 0..5 {
                q.push(QosMetrics {
                    simstep_period_ns: scale * (10.0 + i as f64),
                    simstep_latency: 2.0,
                    walltime_latency_ns: scale * 20.0,
                    delivery_failure_rate: 0.1,
                    delivery_clumpiness: 0.5,
                });
            }
            out.replicates.push(QosReplicate {
                replicate: rep,
                qos: q,
                updates: vec![100],
                run_for: 1,
            });
        }
        out
    }

    #[test]
    fn benchmark_table_renders_all_cells() {
        let t = benchmark_table(
            "test",
            &fake_bench(),
            &[1, 64],
            &[AsyncMode::Sync, AsyncMode::BestEffort],
            false,
        );
        assert!(t.contains("mode 0"));
        assert!(t.contains("mode 3"));
        assert_eq!(t.lines().count(), 2 + 4);
    }

    #[test]
    fn headline_computes_speedup() {
        let h = headline(&fake_bench(), 64);
        assert!((h.speedup_mode3_vs_mode0 - 5.0).abs() < 0.1);
        assert!((h.scaling_efficiency_mode3 - 501.0 / 600.0).abs() < 0.01);
        assert!(h.significant);
    }

    #[test]
    fn qos_comparison_detects_scale_difference() {
        let a = fake_qos(1.0);
        let b = fake_qos(100.0);
        let s = qos_comparison("placement", ("intra", &a), ("inter", &b));
        assert!(s.contains("Simstep Period"));
        // mean effect on period should be ~ (100-1)*12 = 1188
        assert!(s.contains("1.1880e3") || s.contains("1.188e3") || s.contains("1.1880"), "{s}");
    }

    #[test]
    fn csv_dumps_have_rows() {
        assert_eq!(benchmark_csv(&fake_bench()).n_rows(), 12);
        assert_eq!(qos_csv(&fake_qos(1.0)).n_rows(), 20);
    }

    #[test]
    fn scenario_report_renders_and_attributes_phases() {
        use crate::coordinator::runner::{ScenarioPoint, ScenarioResults};
        use crate::faults::ScenarioPhase;
        use crate::sim::AsyncMode;

        let mk_metrics = |period| QosMetrics {
            simstep_period_ns: period,
            simstep_latency: 2.0,
            walltime_latency_ns: 2.0 * period,
            delivery_failure_rate: 0.1,
            delivery_clumpiness: 0.2,
        };
        let mut qos = ReplicateQos::default();
        qos.push_phased(mk_metrics(10.0), ScenarioPhase::QUIESCENT);
        qos.push_phased(mk_metrics(900.0), ScenarioPhase::single(0));
        let results = ScenarioResults {
            points: vec![ScenarioPoint {
                scenario: ScenarioKind::CongestionStorm,
                mode: AsyncMode::BestEffort,
                n_procs: 4,
                replicate: 0,
                adaptive: false,
                policy_flips: 0,
                policy_heals: 0,
                policy_escalated_final: 0,
                qos,
                updates: vec![10; 4],
                update_rate_hz: 1000.0,
                failure_rate: 0.05,
            }],
        };
        let mut exp = ScenarioExperiment::smoke();
        exp.scenarios = vec![ScenarioKind::CongestionStorm];
        exp.modes = vec![AsyncMode::BestEffort];
        exp.proc_counts = vec![4];
        let table = scenario_table("suite", &exp, &results);
        assert!(table.contains("congestion_storm"), "{table}");
        let attr = phase_attribution(
            "attribution",
            &results,
            ScenarioKind::CongestionStorm,
            AsyncMode::BestEffort,
            4,
        );
        assert!(attr.contains("10ns"), "quiet median missing: {attr}");
        assert!(attr.contains("900ns"), "fault median missing: {attr}");
        assert_eq!(scenario_csv(&results).n_rows(), 2);
    }

    #[test]
    fn adaptive_report_compares_against_best_static() {
        use crate::coordinator::runner::{ScenarioPoint, ScenarioResults};
        use crate::faults::ScenarioPhase;

        let mk_metrics = |period| QosMetrics {
            simstep_period_ns: period,
            simstep_latency: 2.0,
            walltime_latency_ns: 2.0 * period,
            delivery_failure_rate: 0.1,
            delivery_clumpiness: 0.2,
        };
        let mk_point = |mode, adaptive, failure_rate, flips| {
            let mut qos = ReplicateQos::default();
            qos.push_phased(mk_metrics(10.0), ScenarioPhase::QUIESCENT);
            qos.push_phased(mk_metrics(500.0), ScenarioPhase::single(0));
            ScenarioPoint {
                scenario: ScenarioKind::Lac417Static,
                mode,
                n_procs: 4,
                replicate: 0,
                adaptive,
                policy_flips: flips,
                policy_heals: 0,
                policy_escalated_final: flips,
                qos,
                updates: vec![10; 4],
                update_rate_hz: 1000.0,
                failure_rate,
            }
        };
        let results = ScenarioResults {
            points: vec![
                mk_point(AsyncMode::Sync, false, 0.20, 0),
                mk_point(AsyncMode::BestEffort, false, 0.08, 0),
                mk_point(AsyncMode::Sync, true, 0.05, 3),
            ],
        };
        let mut exp = ScenarioExperiment::adaptive_smoke();
        exp.scenarios = vec![ScenarioKind::Lac417Static];
        exp.proc_counts = vec![4];

        // Static selectors must not leak the adaptive cell.
        assert_eq!(results.select(ScenarioKind::Lac417Static, AsyncMode::Sync, 4).len(), 1);
        assert_eq!(results.select_adaptive(ScenarioKind::Lac417Static, 4).len(), 1);

        let t = adaptive_table("adaptive vs static", &exp, &results);
        // Best static arm is mode 3 (0.08); adaptive (0.05) beats it.
        assert!(t.contains("mode 3"), "{t}");
        assert!(t.contains("<= best"), "{t}");
        assert!(t.contains("0.0500"), "{t}");

        let attr =
            adaptive_phase_attribution("adaptive attribution", &results, ScenarioKind::Lac417Static, 4);
        assert!(attr.contains("adaptive"), "{attr}");
        assert!(attr.contains("500ns"), "fault median missing: {attr}");

        // CSV tags adaptive rows.
        let csv = scenario_csv(&results).render();
        assert!(csv.lines().next().unwrap().ends_with("adaptive"), "{csv}");
        assert!(csv.lines().any(|l| l.ends_with(",1")), "{csv}");
    }

    #[test]
    fn hardware_report_renders_and_attributes_phases() {
        use crate::coordinator::hardware::{HardwarePoint, HardwareResults};
        use crate::coordinator::HardwareExperiment;
        use crate::faults::ScenarioPhase;

        let mk_metrics = |period| QosMetrics {
            simstep_period_ns: period,
            simstep_latency: 2.0,
            walltime_latency_ns: 2.0 * period,
            delivery_failure_rate: 0.1,
            delivery_clumpiness: 0.2,
        };
        let mut qos = ReplicateQos::default();
        qos.push_phased(mk_metrics(25.0), ScenarioPhase::QUIESCENT);
        qos.push_phased(mk_metrics(800.0), ScenarioPhase::single(0));
        let results = HardwareResults {
            points: vec![HardwarePoint {
                mode: AsyncMode::BestEffort,
                n_shards: 16,
                replicate: 0,
                threads: 2,
                qos,
                updates: vec![10; 16],
                update_rate_hz: 500.0,
                failure_rate: 0.02,
                span_ns: 150_000_000,
            }],
        };
        let mut exp = HardwareExperiment::smoke();
        exp.modes = vec![AsyncMode::BestEffort];
        exp.shard_counts = vec![16];
        let table = hardware_table("hardware sweep", &exp, &results);
        assert!(table.contains("mode 3"), "{table}");
        assert!(table.contains("16"), "{table}");
        let attr = hardware_phase_attribution(
            "hardware attribution",
            &results,
            AsyncMode::BestEffort,
            16,
        );
        assert!(attr.contains("25ns"), "quiet median missing: {attr}");
        assert!(attr.contains("800ns"), "fault median missing: {attr}");
        assert_eq!(hardware_csv(&results).n_rows(), 2);
        // The QoS-results bridge feeds the DES summary table unchanged.
        let s = qos_summary(
            "hardware qos",
            &results.qos_results(AsyncMode::BestEffort, 16),
        );
        assert!(s.contains("Simstep Period"), "{s}");
    }

    #[test]
    fn scaling_regression_renders() {
        let pts = vec![(16, fake_qos(1.0)), (64, fake_qos(1.1)), (256, fake_qos(1.2))];
        let s = scaling_regression("weak scaling", &pts, MetricName::SimstepPeriod);
        assert!(s.contains("OLS slope"));
        assert!(s.contains("[64, 256]"), "{s}");
    }
}
