//! Hardware (real-thread) experiment sweeps — the on-metal counterpart
//! of the DES sweeps in [`super::runner`].
//!
//! A [`HardwareExperiment`] fans (mode × shard count × replicate) cells
//! over [`crate::exec::run_threads`], each cell a real wall-clock run
//! with windowed QoS capture, oversubscribed shard multiplexing, and an
//! optional scripted fault scenario. Cells reuse the DES sweeps' LPT
//! fan-out machinery ([`crate::util::parallel::parallel_map_lpt`]) —
//! but, unlike DES cells, each hardware cell spawns its own real
//! threads, so the sweep defaults to **one cell at a time**
//! (`EBCOMM_WORKERS` raises it explicitly on big boxes); LPT ordering
//! still claims the expensive large-shard-count cells first.
//!
//! Hardware results are wall-clock measurements: never bit-reproducible,
//! never golden-gated (see `rust/tests/golden/README.md`). Use them for
//! the ordinal cross-validation the reproduction exists for — the DES
//! predicts, hardware confirms.

use std::io;
use std::path::PathBuf;
use std::time::Duration;

use crate::conduit::{ChannelConfig, StageLatencies};
use crate::exec::{run_multiproc, run_threads, MultiprocConfig, ThreadExecConfig};
use crate::net::{PlacementKind, Topology};
use crate::qos::{MetricName, ReplicateQos, SketchQos, SnapshotSchedule};
use crate::sim::AsyncMode;
use crate::util::parallel::{log_telemetry, parallel_map_lpt};
use crate::util::rng::Xoshiro256;
use crate::util::Nanos;
use crate::workloads::{GcConfig, GraphColoringShard};

use super::experiment::ScenarioKind;
use super::runner::{QosReplicate, QosResults};

/// Worker count for fanning hardware cells: `EBCOMM_WORKERS` if set,
/// otherwise 1 — each cell already owns real threads, so parallel cells
/// on a small box would contend with the measurement itself.
fn hw_sweep_workers() -> usize {
    std::env::var("EBCOMM_WORKERS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(1)
}

/// A real-thread experiment: modes × shard counts × replicates on
/// hardware, with windowed QoS and optional scenario faults.
#[derive(Clone, Debug)]
pub struct HardwareExperiment {
    pub name: &'static str,
    pub modes: Vec<AsyncMode>,
    pub shard_counts: Vec<usize>,
    /// Hardware-thread budget per cell (further capped by
    /// `EBCOMM_THREADS`); `None` = one thread per shard.
    pub threads: Option<usize>,
    pub replicates: usize,
    /// Wall-clock run window per cell (extended to cover `schedule`).
    pub run_for: Duration,
    /// Wall-clock QoS snapshot schedule.
    pub schedule: SnapshotSchedule,
    /// Scripted fault shape, built per shard count over the run window
    /// ([`ScenarioKind::build`]); `None` = fault-free cells.
    pub scenario_kind: Option<ScenarioKind>,
    pub added_work_units: u64,
    pub channel: ChannelConfig,
    pub simels_per_shard: usize,
    /// See [`ThreadExecConfig::degrade_spin_units`].
    pub degrade_spin_units: u64,
    pub seed: u64,
}

impl HardwareExperiment {
    fn base(name: &'static str) -> Self {
        Self {
            name,
            modes: vec![AsyncMode::Sync, AsyncMode::BestEffort],
            shard_counts: vec![4, 16],
            threads: Some(4),
            replicates: 1,
            run_for: Duration::from_millis(180),
            schedule: SnapshotSchedule::hardware_smoke(),
            scenario_kind: None,
            added_work_units: 0,
            channel: ChannelConfig::qos(),
            simels_per_shard: 4,
            degrade_spin_units: 4_000,
            seed: 0x4A4D,
        }
    }

    /// CI-smoke grid: sync vs best-effort at 4/16 shards on ≤4 threads —
    /// exercises wiring, windowed capture, and multiplexing end to end
    /// in under a second of wall time.
    pub fn smoke() -> Self {
        Self::base("hw_smoke")
    }

    /// The oversubscription probe: 64- and 256-shard best-effort runs
    /// multiplexed onto ≤4 hardware threads with the paper's
    /// benchmarking channel (capacity 2, so drops are real) — the
    /// "real-thread runs past 64 threads" rung the ROADMAP called for,
    /// sized for a 2-core CI box.
    pub fn oversubscribed() -> Self {
        let mut e = Self::base("hw_oversubscribed");
        e.modes = vec![AsyncMode::BestEffort];
        e.shard_counts = vec![64, 256];
        e.channel = ChannelConfig::benchmarking();
        e.simels_per_shard = 1;
        e.run_for = Duration::from_millis(220);
        e
    }

    /// Scenario-driven real-thread probe: a mid-run fail-stop on one
    /// shard of a 16-shard best-effort run, with windows tagged for
    /// degraded-phase vs baseline-phase attribution.
    pub fn scenario_probe() -> Self {
        let mut e = Self::base("hw_scenario_midrun_failure");
        e.modes = vec![AsyncMode::BestEffort];
        e.shard_counts = vec![16];
        e.scenario_kind = Some(ScenarioKind::MidrunFailure);
        // Make the degraded shard's slowdown visible against real step
        // costs on a busy CI box.
        e.degrade_spin_units = 8_000;
        e
    }
}

/// One hardware sweep cell's measurements.
#[derive(Clone, Debug)]
pub struct HardwarePoint {
    pub mode: AsyncMode,
    pub n_shards: usize,
    pub replicate: usize,
    /// Real threads the cell ran on (after `EBCOMM_THREADS` capping).
    pub threads: usize,
    /// Windowed QoS with phase tags — the same [`ReplicateQos`] the DES
    /// produces, so `values_where`/report queries work unchanged.
    pub qos: ReplicateQos,
    pub updates: Vec<u64>,
    /// Mean per-shard update rate over measured worker spans (Hz).
    pub update_rate_hz: f64,
    /// Whole-run delivery failure fraction.
    pub failure_rate: f64,
    /// Measured wall span (mean per-worker first→last step), ns.
    pub span_ns: Nanos,
}

/// All cells from one [`HardwareExperiment`], grid order
/// (shard count, mode, replicate).
#[derive(Clone, Debug, Default)]
pub struct HardwareResults {
    pub points: Vec<HardwarePoint>,
}

impl HardwareResults {
    /// Cells of one (mode, shards) treatment, replicate order.
    pub fn select(&self, mode: AsyncMode, n_shards: usize) -> Vec<&HardwarePoint> {
        self.points
            .iter()
            .filter(|p| p.mode == mode && p.n_shards == n_shards)
            .collect()
    }

    /// All snapshot values of a metric for one treatment, flattened.
    pub fn all_values(&self, mode: AsyncMode, n_shards: usize, metric: MetricName) -> Vec<f64> {
        self.select(mode, n_shards)
            .iter()
            .flat_map(|p| p.qos.values(metric))
            .collect()
    }

    /// Per-replicate update rates for one treatment.
    pub fn rates(&self, mode: AsyncMode, n_shards: usize) -> Vec<f64> {
        self.select(mode, n_shards)
            .iter()
            .map(|p| p.update_rate_hz)
            .collect()
    }

    /// Per-replicate whole-run failure rates for one treatment.
    pub fn failure_rates(&self, mode: AsyncMode, n_shards: usize) -> Vec<f64> {
        self.select(mode, n_shards)
            .iter()
            .map(|p| p.failure_rate)
            .collect()
    }

    /// Snapshot values split into (quiescent-window, fault-active-window)
    /// populations — hardware-side time-resolved attribution.
    pub fn phase_split(
        &self,
        mode: AsyncMode,
        n_shards: usize,
        metric: MetricName,
    ) -> (Vec<f64>, Vec<f64>) {
        let mut quiet = Vec::new();
        let mut faulted = Vec::new();
        for p in self.select(mode, n_shards) {
            quiet.extend(p.qos.values_where(metric, |ph| ph.is_quiescent()));
            faulted.extend(p.qos.values_where(metric, |ph| !ph.is_quiescent()));
        }
        (quiet, faulted)
    }

    /// Bridge one treatment into the DES sweeps' [`QosResults`] shape so
    /// `report::qos_summary`/`qos_comparison`/`qos_csv` work unchanged
    /// on hardware runs.
    pub fn qos_results(&self, mode: AsyncMode, n_shards: usize) -> QosResults {
        QosResults {
            replicates: self
                .select(mode, n_shards)
                .iter()
                .map(|p| QosReplicate {
                    replicate: p.replicate,
                    qos: p.qos.clone(),
                    updates: p.updates.clone(),
                    run_for: p.span_ns,
                })
                .collect(),
        }
    }
}

/// Run one hardware cell: build shards, compile the scenario for this
/// scale, execute on real threads.
fn run_hardware_cell(
    exp: &HardwareExperiment,
    mode: AsyncMode,
    n_shards: usize,
    rep: usize,
) -> HardwarePoint {
    let topo = Topology::new(n_shards, PlacementKind::SingleNode);
    let gc_cfg = GcConfig {
        simels_per_proc: exp.simels_per_shard,
        ..GcConfig::default()
    };
    let seed = exp
        .seed
        .wrapping_add((rep as u64) << 32)
        .wrapping_add((mode.index() as u64) << 16)
        .wrapping_add(n_shards as u64);
    let mut rng = Xoshiro256::new(seed ^ 0x4A4D);
    let shards: Vec<_> = (0..n_shards)
        .map(|r| GraphColoringShard::new(gc_cfg, &topo, r, &mut rng))
        .collect();
    let scenario = match exp.scenario_kind {
        Some(kind) => kind.build(exp.run_for.as_nanos() as Nanos, n_shards, n_shards),
        None => Default::default(),
    };
    let result = run_threads(
        ThreadExecConfig {
            mode,
            run_for: exp.run_for,
            added_work_units: exp.added_work_units,
            channel: exp.channel,
            threads: exp.threads,
            snapshots: Some(exp.schedule),
            scenario,
            degrade_spin_units: exp.degrade_spin_units,
            seed,
            ..Default::default()
        },
        shards,
    );
    HardwarePoint {
        mode,
        n_shards,
        replicate: rep,
        threads: result.threads,
        update_rate_hz: result.update_rate_per_cpu_hz(),
        failure_rate: result.overall_failure_rate(),
        span_ns: result.elapsed.as_nanos() as Nanos,
        updates: result.updates,
        qos: result.qos,
    }
}

/// Run a hardware experiment's full grid. Cells claim in LPT order
/// (shard count dominates — the 256-shard stragglers start first) and
/// come back in grid order; see [`hw_sweep_workers`] for why the fan-out
/// defaults to one cell at a time.
pub fn run_hardware(exp: &HardwareExperiment) -> HardwareResults {
    let mut cells: Vec<(usize, AsyncMode, usize)> = Vec::new();
    for &n_shards in &exp.shard_counts {
        for &mode in &exp.modes {
            for rep in 0..exp.replicates {
                cells.push((n_shards, mode, rep));
            }
        }
    }
    let (points, timings) = parallel_map_lpt(
        hw_sweep_workers(),
        &cells,
        |&(n_shards, _, _)| n_shards as u64,
        |&(n_shards, mode, rep)| run_hardware_cell(exp, mode, n_shards, rep),
    );
    log_telemetry(exp.name, &timings);
    HardwareResults { points }
}

// ---- multi-process sweeps -------------------------------------------

/// A real-process experiment: modes × process counts × replicates over
/// [`crate::exec::run_multiproc`]. Each cell runs `procs` graph-coloring
/// shards partitioned across (up to) `procs` real OS worker processes
/// wired by unix-socket ducts, so best-effort sends fail against real
/// kernel buffers and real dead peers. `EBCOMM_PROCS` caps the spawned
/// process count, so big grids oversubscribe shards per process exactly
/// like the thread sweeps oversubscribe shards per thread.
#[derive(Clone, Debug)]
pub struct MultiprocExperiment {
    pub name: &'static str,
    pub modes: Vec<AsyncMode>,
    /// Shard counts; each cell requests one worker process per shard
    /// (before the `EBCOMM_PROCS` cap).
    pub proc_counts: Vec<usize>,
    pub replicates: usize,
    /// Wall-clock run window per cell (extended to cover `schedule`).
    pub run_for: Duration,
    /// Wall-clock QoS snapshot schedule, captured inside every worker.
    pub schedule: SnapshotSchedule,
    /// Scripted fault shape, built per cell scale; `None` = fault-free.
    pub scenario_kind: Option<ScenarioKind>,
    pub added_work_units: u64,
    pub channel: ChannelConfig,
    pub simels_per_shard: usize,
    pub degrade_spin_units: u64,
    pub seed: u64,
    /// Worker binary override (tests and benches pass
    /// `env!("CARGO_BIN_EXE_ebcomm")`); `None` resolves `EBCOMM_MP_BIN`
    /// or the current executable.
    pub binary: Option<PathBuf>,
}

impl MultiprocExperiment {
    fn mp_base(name: &'static str) -> Self {
        Self {
            name,
            modes: vec![AsyncMode::Sync, AsyncMode::BestEffort],
            proc_counts: vec![2, 4],
            replicates: 1,
            run_for: Duration::from_millis(180),
            schedule: SnapshotSchedule::hardware_smoke(),
            scenario_kind: None,
            added_work_units: 0,
            channel: ChannelConfig::qos(),
            simels_per_shard: 4,
            degrade_spin_units: 4_000,
            seed: 0x4D50,
            binary: None,
        }
    }

    /// CI-smoke grid: sync vs best-effort at 2 and 4 shards — with
    /// `EBCOMM_PROCS=2` the 4-shard cells oversubscribe two shards per
    /// process, exercising both intra-process and socket ducts.
    pub fn smoke() -> Self {
        Self::mp_base("mp_smoke")
    }

    /// Scenario-driven real-process probe: the allocation splits into
    /// two cliques mid-run and heals ([`ScenarioKind::PartitionHeal`]),
    /// so cross-process sends are force-failed while the partition is
    /// up and QoS windows carry the phase tags to prove it.
    pub fn scenario_probe() -> Self {
        let mut e = Self::mp_base("mp_partition_heal");
        e.modes = vec![AsyncMode::BestEffort];
        e.proc_counts = vec![4];
        e.scenario_kind = Some(ScenarioKind::PartitionHeal);
        e
    }
}

/// One multi-process sweep cell's measurements.
#[derive(Clone, Debug)]
pub struct MultiprocPoint {
    pub mode: AsyncMode,
    /// Requested process count (= shard count for the cell).
    pub procs: usize,
    pub replicate: usize,
    /// Worker processes actually spawned (after `EBCOMM_PROCS` capping).
    pub procs_used: usize,
    pub updates: Vec<u64>,
    /// Mean per-shard update rate over measured worker spans (Hz).
    pub update_rate_hz: f64,
    /// Whole-run delivery failure fraction.
    pub failure_rate: f64,
    /// Measured wall span (mean per-worker first→last step), ns.
    pub span_ns: Nanos,
    /// Sketch-merged windowed QoS across every worker process — all
    /// four paper metrics, queryable per channel/sender/phase.
    pub qos: SketchQos,
    /// Sketch-merged serialize/enqueue/transport/drain breakdown.
    pub stages: StageLatencies,
}

/// All cells from one [`MultiprocExperiment`], grid order
/// (proc count, mode, replicate).
#[derive(Clone, Debug, Default)]
pub struct MultiprocResults {
    pub points: Vec<MultiprocPoint>,
}

impl MultiprocResults {
    /// Cells of one (mode, procs) treatment, replicate order.
    pub fn select(&self, mode: AsyncMode, procs: usize) -> Vec<&MultiprocPoint> {
        self.points
            .iter()
            .filter(|p| p.mode == mode && p.procs == procs)
            .collect()
    }

    /// Per-replicate update rates for one treatment.
    pub fn rates(&self, mode: AsyncMode, procs: usize) -> Vec<f64> {
        self.select(mode, procs).iter().map(|p| p.update_rate_hz).collect()
    }

    /// Per-replicate whole-run failure rates for one treatment.
    pub fn failure_rates(&self, mode: AsyncMode, procs: usize) -> Vec<f64> {
        self.select(mode, procs).iter().map(|p| p.failure_rate).collect()
    }

    /// One treatment's QoS sketches merged across replicates.
    pub fn merged_qos(&self, mode: AsyncMode, procs: usize) -> SketchQos {
        let mut q = SketchQos::new();
        for p in self.select(mode, procs) {
            q.merge(&p.qos);
        }
        q
    }

    /// Stage breakdown merged across the whole grid.
    pub fn merged_stages(&self) -> StageLatencies {
        let mut s = StageLatencies::new();
        for p in &self.points {
            s.merge(&p.stages);
        }
        s
    }
}

/// Run one multi-process cell: compile the scenario for this scale and
/// fan `procs` shards over real worker processes.
fn run_multiproc_cell(
    exp: &MultiprocExperiment,
    mode: AsyncMode,
    procs: usize,
    rep: usize,
) -> io::Result<MultiprocPoint> {
    let seed = exp
        .seed
        .wrapping_add((rep as u64) << 32)
        .wrapping_add((mode.index() as u64) << 16)
        .wrapping_add(procs as u64);
    let scenario = match exp.scenario_kind {
        Some(kind) => kind.build(exp.run_for.as_nanos() as Nanos, procs, procs),
        None => Default::default(),
    };
    let result = run_multiproc(
        MultiprocConfig {
            mode,
            run_for: exp.run_for,
            added_work_units: exp.added_work_units,
            channel: exp.channel,
            procs: Some(procs),
            snapshots: Some(exp.schedule),
            scenario,
            degrade_spin_units: exp.degrade_spin_units,
            seed,
            workload: crate::workloads::GcConfig {
                simels_per_proc: exp.simels_per_shard,
                ..Default::default()
            },
            binary: exp.binary.clone(),
            ..Default::default()
        },
        procs,
    )?;
    Ok(MultiprocPoint {
        mode,
        procs,
        replicate: rep,
        procs_used: result.procs,
        update_rate_hz: result.update_rate_per_cpu_hz(),
        failure_rate: result.overall_failure_rate(),
        span_ns: result.elapsed.as_nanos() as Nanos,
        updates: result.updates,
        qos: result.qos,
        stages: result.stages,
    })
}

/// Run a multi-process experiment's full grid. Like [`run_hardware`],
/// cells default to one at a time (each already owns real processes);
/// the first cell error aborts the sweep.
pub fn run_multiproc_sweep(exp: &MultiprocExperiment) -> io::Result<MultiprocResults> {
    let mut cells: Vec<(usize, AsyncMode, usize)> = Vec::new();
    for &procs in &exp.proc_counts {
        for &mode in &exp.modes {
            for rep in 0..exp.replicates {
                cells.push((procs, mode, rep));
            }
        }
    }
    let (points, timings) = parallel_map_lpt(
        hw_sweep_workers(),
        &cells,
        |&(procs, _, _)| procs as u64,
        |&(procs, mode, rep)| run_multiproc_cell(exp, mode, procs, rep),
    );
    log_telemetry(exp.name, &timings);
    let points: io::Result<Vec<MultiprocPoint>> = points.into_iter().collect();
    Ok(MultiprocResults { points: points? })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiproc_presets_are_shaped_for_their_probes() {
        let s = MultiprocExperiment::smoke();
        assert!(s.modes.contains(&AsyncMode::Sync));
        assert!(s.proc_counts.iter().all(|&n| n <= 4), "CI-box sized");

        let p = MultiprocExperiment::scenario_probe();
        assert_eq!(p.scenario_kind, Some(ScenarioKind::PartitionHeal));
        for &n in &p.proc_counts {
            p.scenario_kind
                .unwrap()
                .build(p.run_for.as_nanos() as Nanos, n, n)
                .validate(n);
        }
    }

    #[test]
    fn presets_are_shaped_for_their_probes() {
        let s = HardwareExperiment::smoke();
        assert!(s.modes.contains(&AsyncMode::Sync));
        assert!(s.shard_counts.iter().all(|&n| n <= 16));

        let o = HardwareExperiment::oversubscribed();
        assert!(o.shard_counts.contains(&256), "the 64+-shard rung");
        assert!(o.threads.unwrap() <= 4, "must fit a small-core CI box");
        assert_eq!(o.channel.capacity, 2, "paper benchmarking buffer: real drops");
        assert_eq!(o.modes, vec![AsyncMode::BestEffort]);

        let p = HardwareExperiment::scenario_probe();
        assert_eq!(p.scenario_kind, Some(ScenarioKind::MidrunFailure));
        // The scenario must build and validate at the preset's scale.
        for &n in &p.shard_counts {
            p.scenario_kind
                .unwrap()
                .build(p.run_for.as_nanos() as Nanos, n, n)
                .validate(n);
        }
    }

    #[test]
    fn tiny_hardware_sweep_produces_grid_with_qos() {
        let mut exp = HardwareExperiment::smoke();
        exp.shard_counts = vec![4];
        exp.modes = vec![AsyncMode::BestEffort];
        exp.replicates = 2;
        exp.run_for = Duration::from_millis(60);
        exp.schedule = SnapshotSchedule::compressed(
            10 * crate::util::MILLI,
            20 * crate::util::MILLI,
            10 * crate::util::MILLI,
            2,
        );
        let res = run_hardware(&exp);
        assert_eq!(res.points.len(), 2);
        for (i, p) in res.points.iter().enumerate() {
            assert_eq!(p.replicate, i, "grid order");
            assert_eq!(p.updates.len(), 4);
            assert!(p.update_rate_hz > 0.0);
            assert!(!p.qos.snapshots.is_empty());
            assert_eq!(p.qos.snapshots.len(), p.qos.phases.len());
        }
        assert_eq!(res.rates(AsyncMode::BestEffort, 4).len(), 2);
        assert!(!res
            .all_values(AsyncMode::BestEffort, 4, MetricName::SimstepPeriod)
            .is_empty());
        // Bridge to the DES report shape.
        let qr = res.qos_results(AsyncMode::BestEffort, 4);
        assert_eq!(qr.replicates.len(), 2);
        assert!(!qr.replicate_means(MetricName::SimstepPeriod).is_empty());
    }
}
