//! Replicate sweeps: turn experiment definitions into measured results.
//!
//! Every (mode, CPU count, replicate) sweep cell is independently seeded,
//! so sweeps fan out over a scoped worker pool
//! ([`crate::util::parallel`]) and use all host cores by default. Results
//! are assembled in grid order, so parallel and serial sweeps produce
//! identical `BenchmarkResults`/`QosResults` — guaranteed by tests below
//! and in `rust/tests/integration_sim.rs`.

use crate::faults::ScenarioPhase;
use crate::net::{NodeProfile, Topology};
use crate::qos::{MetricName, ReplicateQos};
use crate::sim::{
    healthy_profiles, heterogeneous_profiles, AdaptiveConfig, AsyncMode, Engine, ModeTiming,
    PolicyConfig, SimConfig, SimResult,
};
use crate::util::parallel::{default_workers, log_telemetry, parallel_map_lpt};
use crate::util::rng::Xoshiro256;
use crate::util::Nanos;
use crate::workloads::dishtiny::{DeConfig, DishtinyShard};
use crate::workloads::graph_coloring::{global_conflicts, GcConfig, GraphColoringShard};

use super::experiment::{
    BenchmarkExperiment, QosExperiment, ScenarioExperiment, ScenarioKind, Workload,
};

/// One benchmark measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchmarkPoint {
    pub mode: AsyncMode,
    pub n_cpus: usize,
    pub replicate: usize,
    /// Mean per-CPU update rate (updates/s of virtual time).
    pub update_rate_hz: f64,
    /// Solution quality: GC = global conflicts remaining (lower better);
    /// DE = mean cell resource (higher better).
    pub quality: f64,
    /// Whole-run delivery failure fraction.
    pub failure_rate: f64,
}

/// All points from one benchmark experiment.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BenchmarkResults {
    pub points: Vec<BenchmarkPoint>,
}

impl BenchmarkResults {
    /// Update rates for a (mode, cpus) cell across replicates.
    pub fn rates(&self, mode: AsyncMode, n_cpus: usize) -> Vec<f64> {
        self.points
            .iter()
            .filter(|p| p.mode == mode && p.n_cpus == n_cpus)
            .map(|p| p.update_rate_hz)
            .collect()
    }

    pub fn qualities(&self, mode: AsyncMode, n_cpus: usize) -> Vec<f64> {
        self.points
            .iter()
            .filter(|p| p.mode == mode && p.n_cpus == n_cpus)
            .map(|p| p.quality)
            .collect()
    }
}

/// LPT cost hint for one sweep cell. Wall-clock cost tracks events
/// processed: proportional to process count, scaled by how many simsteps
/// the mode completes per virtual second — barrier-bound cells spend
/// much of the window waiting out releases (few events), best-effort
/// cells run at full cadence (the most events). Process count spans the
/// grid in ≥4× rungs (1, 4, …, 1024, 4096) while mode weights span only
/// 2–4×, so the scale axis still dominates the claim order: 1024/4096-
/// proc stragglers start first, and within one rung the expensive
/// asynchronous cells lead.
fn cell_cost_hint(n_procs: usize, mode: AsyncMode) -> u64 {
    let mode_weight: u64 = match mode {
        AsyncMode::Sync => 2,
        AsyncMode::RollingBarrier | AsyncMode::FixedBarrier | AsyncMode::NoComm => 3,
        AsyncMode::BestEffort => 4,
    };
    (n_procs as u64).saturating_mul(mode_weight)
}

/// One `ModeTiming` per distinct CPU count, interned once before a sweep
/// fans out. Cells used to re-derive the timing — and, for benchmark
/// sweeps, re-read the `EBCOMM_FULL` env — once per cell; interning
/// makes every cell of a rung share a single copy and keeps env reads
/// out of the parallel fan-out. Lookup is a linear scan: sweeps have a
/// handful of distinct rungs.
struct TimingInterner {
    entries: Vec<(usize, ModeTiming)>,
}

impl TimingInterner {
    fn build(counts: &[usize], derive: impl Fn(usize) -> ModeTiming) -> Self {
        let mut entries: Vec<(usize, ModeTiming)> = Vec::new();
        for &n in counts {
            if !entries.iter().any(|(c, _)| *c == n) {
                entries.push((n, derive(n)));
            }
        }
        Self { entries }
    }

    fn get(&self, n: usize) -> ModeTiming {
        self.entries
            .iter()
            .find(|(c, _)| *c == n)
            .map(|(_, t)| *t)
            .expect("CPU count interned before fan-out")
    }
}

fn sim_config(
    exp: &BenchmarkExperiment,
    timing: ModeTiming,
    mode: AsyncMode,
    n_cpus: usize,
    replicate: usize,
) -> SimConfig {
    let mut cfg = SimConfig::from_env(mode, timing, exp.run_for);
    cfg.backend = exp.backend();
    cfg.seed = exp
        .seed
        .wrapping_add((replicate as u64) << 32)
        .wrapping_add((mode.index() as u64) << 16)
        .wrapping_add(n_cpus as u64);
    cfg.send_buffer = exp.send_buffer;
    cfg.contention = exp.contention();
    cfg
}

/// Simulate one benchmark sweep cell. Entirely self-seeded from
/// `(exp.seed, mode, n_cpus, replicate)`, so cells can run on any worker
/// in any order.
fn run_benchmark_cell(
    exp: &BenchmarkExperiment,
    timings: &TimingInterner,
    mode: AsyncMode,
    n_cpus: usize,
    rep: usize,
) -> BenchmarkPoint {
    let cfg = sim_config(exp, timings.get(n_cpus), mode, n_cpus, rep);
    let topo = Topology::new(n_cpus, exp.placement());
    // Heterogeneous node speeds (paper SII-F1) drive the straggler
    // effects the benchmarks measure.
    let profiles = heterogeneous_profiles(&topo, cfg.seed, 0.20);
    match exp.workload {
        Workload::GraphColoring => {
            let gc_cfg = GcConfig {
                simels_per_proc: exp.simels_per_cpu,
                per_simel_cost_ns: GcConfig::default().per_simel_cost_ns * exp.cost_scale,
                ..GcConfig::default()
            };
            let mut rng = Xoshiro256::new(cfg.seed ^ 0xC0105);
            let shards: Vec<_> = (0..n_cpus)
                .map(|r| GraphColoringShard::new(gc_cfg, &topo, r, &mut rng))
                .collect();
            let result = Engine::new(cfg, topo.clone(), profiles, shards).run();
            let conflicts = global_conflicts(&topo, &result.shards) as f64;
            point_from(&result, mode, n_cpus, rep, conflicts)
        }
        Workload::DigitalEvolution => {
            let de_cfg = DeConfig {
                cells_per_proc: exp.simels_per_cpu,
                per_cell_cost_ns: DeConfig::default().per_cell_cost_ns * exp.cost_scale,
                ..DeConfig::default()
            };
            let mut rng = Xoshiro256::new(cfg.seed ^ 0xD15);
            let shards: Vec<_> = (0..n_cpus)
                .map(|r| DishtinyShard::new(de_cfg, &topo, r, &mut rng))
                .collect();
            let result = Engine::new(cfg, topo, profiles, shards).run();
            let fitness = result.shards.iter().map(|s| s.mean_resource()).sum::<f64>()
                / result.shards.len() as f64;
            point_from(&result, mode, n_cpus, rep, fitness)
        }
    }
}

/// Run a full benchmark experiment (every mode × CPU count × replicate)
/// on all host cores (`EBCOMM_WORKERS` overrides).
pub fn run_benchmark(exp: &BenchmarkExperiment) -> BenchmarkResults {
    run_benchmark_with_workers(exp, default_workers())
}

/// [`run_benchmark`] on one thread — the serial reference path.
pub fn run_benchmark_serial(exp: &BenchmarkExperiment) -> BenchmarkResults {
    run_benchmark_with_workers(exp, 1)
}

/// Run a benchmark experiment on up to `workers` threads. Points come
/// back in grid order (cpu count, then mode, then replicate) whatever
/// the worker count — results are bit-identical across worker counts.
/// Cells are *claimed* in longest-processing-time order (see
/// [`cell_cost_hint`]: CPU count dominates, mode breaks ties) so
/// 1024/4096-proc stragglers start first; per-cell wall times log under
/// `EBCOMM_SWEEP_TELEMETRY=1`.
pub fn run_benchmark_with_workers(
    exp: &BenchmarkExperiment,
    workers: usize,
) -> BenchmarkResults {
    let mut cells: Vec<(usize, AsyncMode, usize)> = Vec::new();
    for &n_cpus in &exp.cpu_counts {
        for &mode in &exp.modes {
            for rep in 0..exp.replicates {
                cells.push((n_cpus, mode, rep));
            }
        }
    }
    let interned = TimingInterner::build(&exp.cpu_counts, |n| exp.timing(n));
    let (points, timings) = parallel_map_lpt(
        workers,
        &cells,
        |&(n_cpus, mode, _)| cell_cost_hint(n_cpus, mode),
        |&(n_cpus, mode, rep)| run_benchmark_cell(exp, &interned, mode, n_cpus, rep),
    );
    log_telemetry(exp.name, &timings);
    BenchmarkResults { points }
}

fn point_from<W>(
    result: &SimResult<W>,
    mode: AsyncMode,
    n_cpus: usize,
    replicate: usize,
    quality: f64,
) -> BenchmarkPoint {
    BenchmarkPoint {
        mode,
        n_cpus,
        replicate,
        update_rate_hz: result.update_rate_per_cpu_hz(),
        quality,
        failure_rate: result.overall_failure_rate(),
    }
}

/// QoS measurements from one replicate.
#[derive(Clone, Debug, PartialEq)]
pub struct QosReplicate {
    pub replicate: usize,
    pub qos: ReplicateQos,
    pub updates: Vec<u64>,
    pub run_for: Nanos,
}

/// All replicates of one QoS experiment.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QosResults {
    pub replicates: Vec<QosReplicate>,
}

impl QosResults {
    /// Per-replicate means of a metric (OLS inputs, §II-E).
    pub fn replicate_means(&self, metric: MetricName) -> Vec<f64> {
        self.replicates.iter().map(|r| r.qos.mean(metric)).collect()
    }

    /// Per-replicate medians of a metric (quantile-regression inputs).
    pub fn replicate_medians(&self, metric: MetricName) -> Vec<f64> {
        self.replicates
            .iter()
            .map(|r| r.qos.median(metric))
            .collect()
    }

    /// All snapshot values of a metric, flattened.
    pub fn all_values(&self, metric: MetricName) -> Vec<f64> {
        self.replicates
            .iter()
            .flat_map(|r| r.qos.values(metric))
            .collect()
    }
}

/// Simulate one QoS replicate (self-seeded, any worker, any order).
fn run_qos_replicate(exp: &QosExperiment, rep: usize) -> QosReplicate {
    let topo = Topology::new(exp.n_procs, exp.placement);
    let mut profiles = healthy_profiles(&topo);
    if let Some(node) = exp.faulty_node {
        if node < profiles.len() {
            profiles[node] = NodeProfile::faulty_lac417();
        }
    }
    let timing = crate::sim::ModeTiming::graph_coloring(exp.n_procs);
    let mut cfg = SimConfig::from_env(AsyncMode::BestEffort, timing, exp.run_for);
    cfg.backend = exp.backend;
    cfg.seed = exp.seed.wrapping_add((rep as u64) << 24);
    cfg.send_buffer = exp.send_buffer;
    cfg.added_work_units = exp.added_work_units;
    // These sweeps aggregate through the exact `ReplicateQos` pipeline;
    // pin the storage mode so `EBCOMM_QOS=sketch` cannot empty it. The
    // sketch pipeline is engine-level (`SimResult::qos_sketch`).
    cfg.qos_storage = crate::qos::QosStorage::Exact;
    cfg.snapshots = Some(exp.schedule);
    cfg.scenario = exp.scenario.clone();

    let gc_cfg = GcConfig {
        simels_per_proc: exp.simels_per_cpu,
        per_simel_cost_ns: GcConfig::default().per_simel_cost_ns * exp.cost_scale,
        ..GcConfig::default()
    };
    let mut rng = Xoshiro256::new(cfg.seed ^ 0x905);
    let shards: Vec<_> = (0..exp.n_procs)
        .map(|r| GraphColoringShard::new(gc_cfg, &topo, r, &mut rng))
        .collect();
    let result = Engine::new(cfg, topo, profiles, shards).run();
    QosReplicate {
        replicate: rep,
        qos: result.qos,
        updates: result.updates,
        run_for: result.run_for,
    }
}

/// Run a QoS experiment's replicates on all host cores
/// (`EBCOMM_WORKERS` overrides).
pub fn run_qos(exp: &QosExperiment) -> QosResults {
    run_qos_with_workers(exp, default_workers())
}

/// [`run_qos`] on up to `workers` threads; replicates come back in
/// replicate order, bit-identical across worker counts.
pub fn run_qos_with_workers(exp: &QosExperiment, workers: usize) -> QosResults {
    let reps: Vec<usize> = (0..exp.replicates).collect();
    let (replicates, timings) =
        parallel_map_lpt(workers, &reps, |_| 0, |&rep| run_qos_replicate(exp, rep));
    log_telemetry(exp.name, &timings);
    QosResults { replicates }
}

/// One fault-scenario sweep cell's measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioPoint {
    pub scenario: ScenarioKind,
    /// Static mode of the cell, or the *base* mode when `adaptive`.
    pub mode: AsyncMode,
    pub n_procs: usize,
    pub replicate: usize,
    /// Cell ran under the adaptive per-channel controller rather than a
    /// static uniform mode.
    pub adaptive: bool,
    /// Controller escalations (channel → best-effort) over the run.
    pub policy_flips: u64,
    /// Controller heal-backs (channel → base discipline) over the run.
    pub policy_heals: u64,
    /// Channels still escalated when the run ended.
    pub policy_escalated_final: u64,
    /// Per-window QoS with scenario-phase tags (time-resolved
    /// attribution).
    pub qos: ReplicateQos,
    pub updates: Vec<u64>,
    /// Mean per-CPU update rate over the run (updates/s virtual).
    pub update_rate_hz: f64,
    /// Whole-run delivery failure fraction.
    pub failure_rate: f64,
}

/// All cells from one [`ScenarioExperiment`], in grid order
/// (scenario, mode, procs, replicate).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScenarioResults {
    pub points: Vec<ScenarioPoint>,
}

impl ScenarioResults {
    /// Cells of one *static* (scenario, mode, procs) treatment,
    /// replicate order. Adaptive cells share a base mode with a static
    /// arm, so they are excluded here — fetch them with
    /// [`Self::select_adaptive`].
    pub fn select(
        &self,
        scenario: ScenarioKind,
        mode: AsyncMode,
        n_procs: usize,
    ) -> Vec<&ScenarioPoint> {
        self.points
            .iter()
            .filter(|p| {
                p.scenario == scenario && p.mode == mode && p.n_procs == n_procs && !p.adaptive
            })
            .collect()
    }

    /// Adaptive-controller cells of one (scenario, procs) treatment,
    /// replicate order.
    pub fn select_adaptive(&self, scenario: ScenarioKind, n_procs: usize) -> Vec<&ScenarioPoint> {
        self.points
            .iter()
            .filter(|p| p.scenario == scenario && p.n_procs == n_procs && p.adaptive)
            .collect()
    }

    /// Per-replicate medians of a metric for the adaptive treatment.
    pub fn replicate_medians_adaptive(
        &self,
        scenario: ScenarioKind,
        n_procs: usize,
        metric: MetricName,
    ) -> Vec<f64> {
        self.select_adaptive(scenario, n_procs)
            .iter()
            .map(|p| p.qos.median(metric))
            .collect()
    }

    /// [`Self::phase_split`] for the adaptive treatment.
    pub fn phase_split_adaptive(
        &self,
        scenario: ScenarioKind,
        n_procs: usize,
        metric: MetricName,
    ) -> (Vec<f64>, Vec<f64>) {
        let mut quiescent = Vec::new();
        let mut faulted = Vec::new();
        for p in self.select_adaptive(scenario, n_procs) {
            quiescent.extend(p.qos.values_where(metric, ScenarioPhase::is_quiescent));
            faulted.extend(p.qos.values_where(metric, |ph| !ph.is_quiescent()));
        }
        (quiescent, faulted)
    }

    /// All snapshot values of a metric for one treatment, flattened
    /// across replicates.
    pub fn all_values(
        &self,
        scenario: ScenarioKind,
        mode: AsyncMode,
        n_procs: usize,
        metric: MetricName,
    ) -> Vec<f64> {
        self.select(scenario, mode, n_procs)
            .iter()
            .flat_map(|p| p.qos.values(metric))
            .collect()
    }

    /// Per-replicate means of a metric for one treatment (OLS inputs).
    pub fn replicate_means(
        &self,
        scenario: ScenarioKind,
        mode: AsyncMode,
        n_procs: usize,
        metric: MetricName,
    ) -> Vec<f64> {
        self.select(scenario, mode, n_procs)
            .iter()
            .map(|p| p.qos.mean(metric))
            .collect()
    }

    /// Per-replicate medians of a metric for one treatment.
    pub fn replicate_medians(
        &self,
        scenario: ScenarioKind,
        mode: AsyncMode,
        n_procs: usize,
        metric: MetricName,
    ) -> Vec<f64> {
        self.select(scenario, mode, n_procs)
            .iter()
            .map(|p| p.qos.median(metric))
            .collect()
    }

    /// Time-resolved attribution for one treatment: snapshot values
    /// split into (quiescent-window, fault-active-window) populations by
    /// each window's scenario-phase tag.
    pub fn phase_split(
        &self,
        scenario: ScenarioKind,
        mode: AsyncMode,
        n_procs: usize,
        metric: MetricName,
    ) -> (Vec<f64>, Vec<f64>) {
        let mut quiescent = Vec::new();
        let mut faulted = Vec::new();
        for p in self.select(scenario, mode, n_procs) {
            quiescent.extend(p.qos.values_where(metric, ScenarioPhase::is_quiescent));
            faulted.extend(p.qos.values_where(metric, |ph| !ph.is_quiescent()));
        }
        (quiescent, faulted)
    }
}

/// Simulate one scenario sweep cell (self-seeded, any worker, any
/// order). Profiles are homogeneous-healthy — all degradation comes from
/// the scripted scenario, so baseline cells are the uncontaminated
/// control.
fn run_scenario_cell(
    exp: &ScenarioExperiment,
    timings: &TimingInterner,
    kind: ScenarioKind,
    mode: AsyncMode,
    n_procs: usize,
    rep: usize,
    adaptive: bool,
) -> ScenarioPoint {
    let topo = Topology::new(n_procs, exp.placement());
    let profiles = healthy_profiles(&topo);
    let mut cfg = SimConfig::from_env(mode, timings.get(n_procs), exp.run_for);
    if adaptive {
        cfg = cfg.with_policy(PolicyConfig::Adaptive(AdaptiveConfig::paper_defaults(mode)));
    }
    // Static cells keep the historical packing bit-identically; adaptive
    // cells take a disjoint slot (bit 40, above every static field).
    cfg.seed = exp
        .seed
        .wrapping_add((adaptive as u64) << 40)
        .wrapping_add((rep as u64) << 32)
        .wrapping_add((kind.index() as u64) << 24)
        .wrapping_add((mode.index() as u64) << 16)
        .wrapping_add(n_procs as u64);
    cfg.send_buffer = exp.send_buffer;
    // These sweeps aggregate through the exact `ReplicateQos` pipeline;
    // pin the storage mode so `EBCOMM_QOS=sketch` cannot empty it. The
    // sketch pipeline is engine-level (`SimResult::qos_sketch`).
    cfg.qos_storage = crate::qos::QosStorage::Exact;
    cfg.snapshots = Some(exp.schedule);
    cfg.scenario = kind.build(exp.run_for, topo.n_nodes(), topo.n_procs());

    let gc_cfg = GcConfig {
        simels_per_proc: 1,
        ..GcConfig::default()
    };
    let mut rng = Xoshiro256::new(cfg.seed ^ 0xFA57);
    let shards: Vec<_> = (0..n_procs)
        .map(|r| GraphColoringShard::new(gc_cfg, &topo, r, &mut rng))
        .collect();
    let result = Engine::new(cfg, topo, profiles, shards).run();
    ScenarioPoint {
        scenario: kind,
        mode,
        n_procs,
        replicate: rep,
        adaptive,
        policy_flips: result.policy_flips,
        policy_heals: result.policy_heals,
        policy_escalated_final: result.policy_escalated_final,
        update_rate_hz: result.update_rate_per_cpu_hz(),
        failure_rate: result.overall_failure_rate(),
        updates: result.updates,
        qos: result.qos,
    }
}

/// Run a scenario experiment's full grid on all host cores
/// (`EBCOMM_WORKERS` overrides).
pub fn run_scenario(exp: &ScenarioExperiment) -> ScenarioResults {
    run_scenario_with_workers(exp, default_workers())
}

/// [`run_scenario`] on up to `workers` threads. Cells come back in grid
/// order whatever the worker count; claiming is LPT-ordered
/// ([`cell_cost_hint`]) so the largest-scale cells start first.
pub fn run_scenario_with_workers(exp: &ScenarioExperiment, workers: usize) -> ScenarioResults {
    let interned = TimingInterner::build(&exp.proc_counts, ModeTiming::graph_coloring);
    let mut cells: Vec<(ScenarioKind, AsyncMode, usize, usize, bool)> = Vec::new();
    for &kind in &exp.scenarios {
        for &mode in &exp.modes {
            for &n_procs in &exp.proc_counts {
                for rep in 0..exp.replicates {
                    cells.push((kind, mode, n_procs, rep, false));
                }
            }
        }
        if exp.adaptive {
            // Adaptive arm rides behind the scenario's static modes:
            // base mode 0 under the paper-default controller.
            for &n_procs in &exp.proc_counts {
                for rep in 0..exp.replicates {
                    cells.push((kind, AsyncMode::Sync, n_procs, rep, true));
                }
            }
        }
    }
    let (points, timings) = parallel_map_lpt(
        workers,
        &cells,
        // Adaptive cells can free-run most of the window once escalated,
        // so hint them like best-effort, not their sync base.
        |&(_, mode, n_procs, _, adaptive)| {
            cell_cost_hint(n_procs, if adaptive { AsyncMode::BestEffort } else { mode })
        },
        |&(kind, mode, n_procs, rep, adaptive)| {
            run_scenario_cell(exp, &interned, kind, mode, n_procs, rep, adaptive)
        },
    );
    log_telemetry(exp.name, &timings);
    ScenarioResults { points }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{MILLI, SECOND};

    fn tiny_benchmark(workload: Workload) -> BenchmarkExperiment {
        let mut e = match workload {
            Workload::GraphColoring => BenchmarkExperiment::fig3_multiprocess_gc(),
            Workload::DigitalEvolution => BenchmarkExperiment::fig3_multiprocess_de(),
        };
        e.cpu_counts = vec![1, 4];
        e.modes = vec![AsyncMode::Sync, AsyncMode::BestEffort];
        e.replicates = 2;
        e.run_for = 60 * MILLI;
        e.simels_per_cpu = 16;
        e.cost_scale = 1.0;
        e
    }

    #[test]
    fn cost_hints_rank_scale_above_mode() {
        // Across the grid's ≥4× proc rungs, scale dominates the claim
        // order; within one rung, best-effort (full-cadence, most
        // events) outranks sync (barrier-bound).
        for &(lo, hi) in &[(1usize, 4usize), (64, 256), (256, 1024), (1024, 4096)] {
            assert!(
                cell_cost_hint(hi, AsyncMode::Sync)
                    > cell_cost_hint(lo, AsyncMode::BestEffort),
                "{hi}-proc sync must outrank {lo}-proc best-effort"
            );
        }
        assert!(
            cell_cost_hint(1024, AsyncMode::BestEffort)
                > cell_cost_hint(1024, AsyncMode::Sync)
        );
    }

    #[test]
    fn benchmark_runner_produces_grid() {
        let exp = tiny_benchmark(Workload::GraphColoring);
        let res = run_benchmark(&exp);
        assert_eq!(res.points.len(), 2 * 2 * 2);
        assert_eq!(res.rates(AsyncMode::BestEffort, 4).len(), 2);
        for p in &res.points {
            assert!(p.update_rate_hz > 0.0);
            assert!(p.quality >= 0.0);
        }
    }

    #[test]
    fn best_effort_beats_sync_at_4_cpus() {
        let exp = tiny_benchmark(Workload::GraphColoring);
        let res = run_benchmark(&exp);
        let be: f64 = res.rates(AsyncMode::BestEffort, 4).iter().sum();
        let sync: f64 = res.rates(AsyncMode::Sync, 4).iter().sum();
        assert!(be > sync, "best-effort {be} vs sync {sync}");
    }

    #[test]
    fn de_benchmark_runs() {
        let exp = tiny_benchmark(Workload::DigitalEvolution);
        let res = run_benchmark(&exp);
        assert_eq!(res.points.len(), 8);
        // resource accrues
        assert!(res.points.iter().any(|p| p.quality > 0.0));
    }

    #[test]
    fn parallel_benchmark_sweep_is_bitwise_identical_to_serial() {
        let exp = tiny_benchmark(Workload::GraphColoring);
        let serial = run_benchmark_serial(&exp);
        let parallel = run_benchmark_with_workers(&exp, 4);
        // Full structural equality, including every f64 bit pattern:
        // cells are independently seeded, so worker count must be
        // invisible in the results.
        assert_eq!(serial, parallel);
        let more = run_benchmark_with_workers(&exp, 16);
        assert_eq!(serial, more);
    }

    #[test]
    fn parallel_qos_sweep_is_bitwise_identical_to_serial() {
        let mut exp = QosExperiment::internode();
        exp.replicates = 3;
        exp.schedule =
            crate::qos::SnapshotSchedule::compressed(100 * MILLI, 100 * MILLI, 30 * MILLI, 2);
        exp.run_for = 300 * MILLI;
        let serial = run_qos_with_workers(&exp, 1);
        let parallel = run_qos_with_workers(&exp, 3);
        assert_eq!(serial, parallel);
        assert_eq!(serial.replicates.len(), 3);
        for (i, r) in serial.replicates.iter().enumerate() {
            assert_eq!(r.replicate, i, "replicate order must be deterministic");
        }
    }

    fn tiny_scenario() -> ScenarioExperiment {
        let mut e = ScenarioExperiment::smoke();
        e.scenarios = vec![ScenarioKind::Baseline, ScenarioKind::CongestionStorm];
        e.modes = vec![AsyncMode::BestEffort];
        e.proc_counts = vec![4];
        e.replicates = 2;
        e.schedule =
            crate::qos::SnapshotSchedule::compressed(60 * MILLI, 60 * MILLI, 25 * MILLI, 3);
        e.run_for = 220 * MILLI;
        e
    }

    #[test]
    fn scenario_runner_produces_grid_with_phase_tags() {
        let exp = tiny_scenario();
        let res = run_scenario(&exp);
        assert_eq!(res.points.len(), 2 * 1 * 1 * 2);
        for p in &res.points {
            assert!(p.update_rate_hz > 0.0);
            assert!(!p.qos.snapshots.is_empty());
            assert_eq!(p.qos.snapshots.len(), p.qos.phases.len());
        }
        // Baseline cells are quiescent throughout; the storm cell tags
        // at least one window with the active fault.
        let (bq, bf) = res.phase_split(
            ScenarioKind::Baseline,
            AsyncMode::BestEffort,
            4,
            MetricName::SimstepPeriod,
        );
        assert!(!bq.is_empty() && bf.is_empty());
        let (_, sf) = res.phase_split(
            ScenarioKind::CongestionStorm,
            AsyncMode::BestEffort,
            4,
            MetricName::SimstepPeriod,
        );
        assert!(!sf.is_empty(), "storm must overlap at least one window");
    }

    fn tiny_adaptive() -> ScenarioExperiment {
        let mut e = ScenarioExperiment::adaptive_smoke();
        e.scenarios = vec![ScenarioKind::Baseline, ScenarioKind::CongestionStorm];
        e.proc_counts = vec![8];
        // Storm spans 350–600 ms of the 1 s window; snapshot windows at
        // 100/200/300 ms calibrate healthy baselines, 400/500 ms sit in
        // the storm (25x latency, well past the 2.5x escalation ratio),
        // 600–800 ms give the controller room to heal.
        e.schedule =
            crate::qos::SnapshotSchedule::compressed(100 * MILLI, 100 * MILLI, 50 * MILLI, 8);
        e.run_for = 1000 * MILLI;
        e
    }

    #[test]
    fn adaptive_cells_ride_behind_static_grid() {
        let exp = tiny_adaptive();
        let res = run_scenario(&exp);
        // 2 scenarios x (2 static modes + 1 adaptive family) x 1 rep.
        assert_eq!(res.points.len(), 2 * 3);
        let stat = res.select(ScenarioKind::CongestionStorm, AsyncMode::Sync, 8);
        assert_eq!(stat.len(), 1, "static select must exclude adaptive cells");
        assert!(!stat[0].adaptive);
        assert_eq!(stat[0].policy_flips, 0, "uniform cells never flip");
        let ad = res.select_adaptive(ScenarioKind::CongestionStorm, 8);
        assert_eq!(ad.len(), 1);
        assert!(ad[0].adaptive);
        assert_eq!(ad[0].mode, AsyncMode::Sync, "base mode recorded");
        // A fabric-wide 25x latency storm after healthy calibration
        // windows must trip the controller on at least one channel.
        assert!(ad[0].policy_flips > 0, "controller never escalated");
        assert!(!res
            .replicate_medians_adaptive(
                ScenarioKind::Baseline,
                8,
                MetricName::SimstepPeriod
            )
            .is_empty());
        let (q, f) =
            res.phase_split_adaptive(ScenarioKind::CongestionStorm, 8, MetricName::SimstepPeriod);
        assert!(!q.is_empty() && !f.is_empty(), "storm windows tagged");
    }

    #[test]
    fn parallel_adaptive_sweep_is_bitwise_identical_to_serial() {
        let exp = tiny_adaptive();
        let serial = run_scenario_with_workers(&exp, 1);
        let parallel = run_scenario_with_workers(&exp, 4);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn parallel_scenario_sweep_is_bitwise_identical_to_serial() {
        let exp = tiny_scenario();
        let serial = run_scenario_with_workers(&exp, 1);
        let parallel = run_scenario_with_workers(&exp, 4);
        assert_eq!(serial, parallel);
        for (a, b) in serial.points.iter().zip(&parallel.points) {
            assert_eq!(a.update_rate_hz.to_bits(), b.update_rate_hz.to_bits());
            assert_eq!(a.failure_rate.to_bits(), b.failure_rate.to_bits());
        }
    }

    #[test]
    fn qos_runner_produces_snapshots() {
        let mut exp = QosExperiment::internode();
        exp.replicates = 2;
        exp.schedule =
            crate::qos::SnapshotSchedule::compressed(200 * MILLI, 200 * MILLI, 50 * MILLI, 3);
        exp.run_for = SECOND;
        let res = run_qos(&exp);
        assert_eq!(res.replicates.len(), 2);
        for r in &res.replicates {
            assert!(!r.qos.snapshots.is_empty());
        }
        assert!(!res.replicate_means(MetricName::SimstepPeriod).is_empty());
        assert!(res
            .replicate_medians(MetricName::SimstepPeriod)
            .iter()
            .all(|&v| v > 0.0));
    }
}
