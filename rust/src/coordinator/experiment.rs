//! Experiment definitions for every table and figure in the paper.
//!
//! Each preset mirrors a paper treatment (see DESIGN.md §4 for the
//! experiment index). Paper-scale parameters (5 s benchmark windows, five
//! 1-minute-spaced QoS snapshots, 5–10 replicates) are expensive under
//! simulation, so every preset also has a *compressed* variant preserving
//! the treatment structure at reduced virtual runtime; benches run
//! compressed by default and full scale with `EBCOMM_FULL=1`.

use crate::faults::FaultScenario;
use crate::net::PlacementKind;
use crate::qos::SnapshotSchedule;
use crate::sim::{AsyncMode, CommBackend, ContentionModel, ModeTiming};
use crate::util::{Nanos, MILLI, SECOND};

/// Which benchmark workload an experiment runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    GraphColoring,
    DigitalEvolution,
}

impl Workload {
    pub fn label(self) -> &'static str {
        match self {
            Workload::GraphColoring => "graph coloring",
            Workload::DigitalEvolution => "digital evolution",
        }
    }
}

/// Is full-scale (paper-fidelity) execution requested?
pub fn full_scale() -> bool {
    std::env::var("EBCOMM_FULL").map(|v| v == "1").unwrap_or(false)
}

/// A performance-benchmark experiment (Figs. 2–3).
#[derive(Clone, Debug)]
pub struct BenchmarkExperiment {
    pub name: &'static str,
    pub workload: Workload,
    /// CPU counts swept (paper: 1, 4, 16, 64).
    pub cpu_counts: Vec<usize>,
    pub modes: Vec<AsyncMode>,
    /// Threads on one node (true) vs one process per node (false).
    pub multithread: bool,
    pub replicates: usize,
    /// Virtual run window per replicate (paper: 5 s).
    pub run_for: Nanos,
    /// Simulation elements per CPU (paper: 2048 GC / 3600 DE).
    pub simels_per_cpu: usize,
    /// Scale nominal per-simel cost by this factor — lets compressed runs
    /// host fewer real simels at unchanged virtual workload profile.
    pub cost_scale: f64,
    pub send_buffer: usize,
    pub seed: u64,
}

impl BenchmarkExperiment {
    fn base(name: &'static str, workload: Workload, multithread: bool) -> Self {
        let full = full_scale();
        let (simels, cost_scale) = match (workload, full) {
            (Workload::GraphColoring, true) => (2048, 1.0),
            (Workload::GraphColoring, false) => (256, 8.0),
            (Workload::DigitalEvolution, true) => (3600, 1.0),
            (Workload::DigitalEvolution, false) => (400, 9.0),
        };
        Self {
            name,
            workload,
            cpu_counts: vec![1, 4, 16, 64],
            modes: AsyncMode::ALL.to_vec(),
            multithread,
            replicates: if full { 5 } else { 3 },
            run_for: if full { 5 * SECOND } else { SECOND },
            simels_per_cpu: simels,
            cost_scale,
            send_buffer: 2,
            seed: 0x5EED,
        }
    }

    /// Fig. 2a/2b: multithread graph coloring.
    pub fn fig2_multithread_gc() -> Self {
        Self::base("fig2ab_multithread_graph_coloring", Workload::GraphColoring, true)
    }

    /// Fig. 2c: multithread digital evolution.
    pub fn fig2_multithread_de() -> Self {
        Self::base("fig2c_multithread_digital_evolution", Workload::DigitalEvolution, true)
    }

    /// Fig. 3a/3b: multiprocess graph coloring (distinct nodes).
    pub fn fig3_multiprocess_gc() -> Self {
        Self::base("fig3ab_multiprocess_graph_coloring", Workload::GraphColoring, false)
    }

    /// Fig. 3c: multiprocess digital evolution.
    pub fn fig3_multiprocess_de() -> Self {
        Self::base("fig3c_multiprocess_digital_evolution", Workload::DigitalEvolution, false)
    }

    /// ROADMAP scale push beyond the paper's 64-proc ceiling: 256-,
    /// 1024-, and 4096-proc graph-coloring cells at 1 simel/CPU
    /// (communication-dominated, so the cells time the engine — barrier
    /// releases and channel wiring — not the solver). Smoke-capped by
    /// default: short virtual windows, one replicate, sync + best-effort
    /// only, and the 4096-proc rung reserved for `EBCOMM_FULL=1`, so CI
    /// exercises the 1024-proc path in seconds.
    pub fn scale_multiprocess_gc() -> Self {
        let full = full_scale();
        let mut e = Self::base("scale_multiprocess_graph_coloring", Workload::GraphColoring, false);
        e.cpu_counts = if full {
            vec![256, 1024, 4096]
        } else {
            vec![256, 1024]
        };
        e.modes = vec![AsyncMode::Sync, AsyncMode::BestEffort];
        e.replicates = if full { 3 } else { 1 };
        e.run_for = if full { SECOND } else { 8 * MILLI };
        e.simels_per_cpu = 1;
        e.cost_scale = 1.0;
        e
    }

    pub fn placement(&self) -> PlacementKind {
        if self.multithread {
            PlacementKind::SingleNode
        } else {
            PlacementKind::OnePerNode
        }
    }

    pub fn backend(&self) -> CommBackend {
        if self.multithread {
            CommBackend::SharedMemory
        } else {
            CommBackend::Mpi
        }
    }

    pub fn contention(&self) -> ContentionModel {
        if !self.multithread {
            return ContentionModel::none();
        }
        match self.workload {
            Workload::GraphColoring => ContentionModel::graph_coloring_threads(),
            Workload::DigitalEvolution => ContentionModel::digital_evolution_threads(),
        }
    }

    pub fn timing(&self, n_cpus: usize) -> ModeTiming {
        let mut t = match self.workload {
            Workload::GraphColoring => ModeTiming::graph_coloring(n_cpus),
            Workload::DigitalEvolution => ModeTiming::digital_evolution(n_cpus),
        };
        // Compressed runs scale the mode-2 epoch (paper: 1 s of a 5 s
        // window) to a fifth of the virtual window so fixed-barrier
        // behaviour — including the startup-skew race — is exercised.
        if !full_scale() {
            t.fixed_epoch = (self.run_for / 5).max(1);
            t.fixed_skew_max =
                ((n_cpus as f64 / 64.0).min(1.0) * t.fixed_epoch as f64) as u64;
        }
        t
    }
}

/// A quality-of-service experiment (§III-C..G).
#[derive(Clone, Debug)]
pub struct QosExperiment {
    pub name: &'static str,
    pub n_procs: usize,
    pub placement: PlacementKind,
    pub backend: CommBackend,
    /// Simulation elements per CPU (1 = maximal communication intensity).
    pub simels_per_cpu: usize,
    pub cost_scale: f64,
    pub added_work_units: u64,
    pub replicates: usize,
    pub send_buffer: usize,
    pub schedule: SnapshotSchedule,
    pub run_for: Nanos,
    /// Node index hosting the faulty profile, if any (§III-G).
    pub faulty_node: Option<usize>,
    /// Scripted time-varying fault timeline ([`crate::faults`]); the
    /// default empty scenario keeps replicates on the static-profile
    /// path, bit-identically.
    pub scenario: FaultScenario,
    pub seed: u64,
}

impl QosExperiment {
    fn base(name: &'static str, n_procs: usize, placement: PlacementKind) -> Self {
        let full = full_scale();
        let (schedule, run_for) = if full {
            (SnapshotSchedule::paper(), 301 * SECOND)
        } else {
            (
                SnapshotSchedule::compressed(500 * MILLI, 500 * MILLI, 100 * MILLI, 5),
                2_600 * MILLI,
            )
        };
        Self {
            name,
            n_procs,
            placement,
            backend: CommBackend::Mpi,
            simels_per_cpu: 1,
            cost_scale: 1.0,
            added_work_units: 0,
            replicates: if full { 10 } else { 3 },
            send_buffer: 64,
            schedule,
            run_for,
            faulty_node: None,
            scenario: FaultScenario::default(),
            seed: 0x0905,
        }
    }

    /// §III-C: compute-vs-communication sweep point (2 procs, 2 nodes,
    /// 1 simel/CPU, `work` added units).
    pub fn compute_vs_comm(work: u64) -> Self {
        let mut e = Self::base("qos_compute_vs_comm", 2, PlacementKind::OnePerNode);
        e.added_work_units = work;
        // Heavy-work points need longer virtual windows than the
        // compressed default to complete even a handful of updates.
        if !full_scale() && work >= 262_144 {
            e.schedule = SnapshotSchedule::compressed(2 * SECOND, 2 * SECOND, SECOND, 3);
            e.run_for = 9 * SECOND;
            e.replicates = 2;
        }
        e
    }

    /// §III-D: two processes on one node (intranode MPI).
    pub fn intranode() -> Self {
        Self::base("qos_intranode", 2, PlacementKind::SingleNode)
    }

    /// §III-D: two processes on distinct nodes (internode MPI).
    pub fn internode() -> Self {
        Self::base("qos_internode", 2, PlacementKind::OnePerNode)
    }

    /// §III-E: two threads on one node (shared-memory backend).
    pub fn multithread_pair() -> Self {
        let mut e = Self::base("qos_multithread", 2, PlacementKind::SingleNode);
        e.backend = CommBackend::SharedMemory;
        e
    }

    /// §III-E: two processes on one node (MPI backend). Alias of
    /// [`Self::intranode`] with its own name for the report.
    pub fn multiprocess_pair() -> Self {
        let mut e = Self::base("qos_multiprocess", 2, PlacementKind::SingleNode);
        e.name = "qos_multiprocess";
        e
    }

    /// §III-F: weak-scaling point.
    pub fn weak_scaling(n_procs: usize, cpus_per_node: usize, simels: usize) -> Self {
        let placement = if cpus_per_node == 1 {
            PlacementKind::OnePerNode
        } else {
            PlacementKind::PerNode(cpus_per_node)
        };
        let mut e = Self::base("qos_weak_scaling", n_procs, placement);
        if simels > 1 && !full_scale() {
            e.simels_per_cpu = 256;
            e.cost_scale = simels as f64 / 256.0;
        } else {
            e.simels_per_cpu = simels;
        }
        e.replicates = if full_scale() { 10 } else { 2 };
        e
    }

    /// §III-G: 256-process allocation with or without the faulty node.
    pub fn faulty_allocation(include_faulty: bool) -> Self {
        let mut e = Self::weak_scaling(256, 4, 1);
        e.name = if include_faulty {
            "qos_with_lac417"
        } else {
            "qos_without_lac417"
        };
        // Place the degraded node mid-allocation (paper: lac-417).
        e.faulty_node = include_faulty.then_some(17);
        e
    }

    /// §III-G via the fault-scenario subsystem: the same treatment
    /// structure as [`Self::faulty_allocation`], but the degradation is
    /// injected by the always-on canned lac-417 scenario instead of a
    /// static profile swap (identical degradation factors; the overlay
    /// path rather than the baked path).
    pub fn faulty_allocation_scenario(include_faulty: bool) -> Self {
        let mut e = Self::weak_scaling(256, 4, 1);
        e.name = if include_faulty {
            "qos_with_lac417_scenario"
        } else {
            "qos_without_lac417_scenario"
        };
        if include_faulty {
            e.scenario = FaultScenario::lac417(17);
        }
        e
    }
}

/// Canned fault-scenario shapes a [`ScenarioExperiment`] sweeps. Each
/// builds a concrete [`FaultScenario`] for a cell's allocation size and
/// run window, so one experiment can sweep the same shape across scales.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// No faults — the control cell every shape is compared against.
    Baseline,
    /// §III-G verbatim: an always-on lac-417 node.
    Lac417Static,
    /// A node fail-stops at 40 % of the run and never recovers.
    MidrunFailure,
    /// Fabric-wide congestion storm (paper scale: 30 s) starting at 35 %
    /// of the run.
    CongestionStorm,
    /// The allocation splits into two cliques at 35 % of the run and
    /// heals 30 % later.
    PartitionHeal,
    /// Links touching one node flap between degraded and clean across
    /// the middle 60 % of the run.
    FlappingClique,
    /// Membership churn: staggered process leave/join storm across the
    /// middle of the run (some departures permanent, some rejoining).
    /// Deliberately NOT in [`Self::ALL`] — it is process-scoped (DES
    /// engine only, never hardware threads) and joined the enum after
    /// the seed-packing grid froze; benches opt in explicitly.
    LeaveJoinStorm,
}

impl ScenarioKind {
    pub const ALL: [ScenarioKind; 6] = [
        ScenarioKind::Baseline,
        ScenarioKind::Lac417Static,
        ScenarioKind::MidrunFailure,
        ScenarioKind::CongestionStorm,
        ScenarioKind::PartitionHeal,
        ScenarioKind::FlappingClique,
    ];

    /// Position in [`Self::ALL`] (the enum is fieldless, so the
    /// discriminant IS the grid index used for seed packing).
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn label(self) -> &'static str {
        match self {
            ScenarioKind::Baseline => "baseline",
            ScenarioKind::Lac417Static => "lac417_static",
            ScenarioKind::MidrunFailure => "midrun_failure",
            ScenarioKind::CongestionStorm => "congestion_storm",
            ScenarioKind::PartitionHeal => "partition_heal",
            ScenarioKind::FlappingClique => "flapping_clique",
            ScenarioKind::LeaveJoinStorm => "leave_join_storm",
        }
    }

    /// The degraded node for node-scoped shapes: mid-allocation, like the
    /// paper's lac-417.
    pub fn fault_node(n_nodes: usize) -> usize {
        (n_nodes / 3).min(n_nodes.saturating_sub(1))
    }

    /// Build the concrete scenario for an allocation of `n_nodes` nodes,
    /// `n_procs` processes, and a `run_for` virtual window. Event times
    /// scale with the window so compressed and full-scale runs share the
    /// treatment structure; the storm clamps at the paper's 30 s. Only
    /// the churn shape reads `n_procs` (it is process-scoped).
    pub fn build(self, run_for: Nanos, n_nodes: usize, n_procs: usize) -> FaultScenario {
        let node = Self::fault_node(n_nodes);
        match self {
            ScenarioKind::Baseline => FaultScenario::default(),
            ScenarioKind::Lac417Static => FaultScenario::lac417(node),
            ScenarioKind::MidrunFailure => {
                FaultScenario::midrun_failure(node, run_for * 2 / 5)
            }
            ScenarioKind::CongestionStorm => {
                FaultScenario::congestion_storm(run_for * 7 / 20, (30 * SECOND).min(run_for / 4))
            }
            ScenarioKind::PartitionHeal => {
                FaultScenario::partition_and_heal(2, run_for * 7 / 20, run_for * 3 / 10)
            }
            ScenarioKind::FlappingClique => FaultScenario::flapping_clique(
                node,
                run_for / 5,
                run_for * 3 / 5,
                (run_for / 64).max(1),
                (run_for / 64).max(1),
            ),
            ScenarioKind::LeaveJoinStorm => FaultScenario::leave_join_storm(
                n_procs,
                run_for / 5,
                run_for * 2 / 5,
                (n_procs / 16).max(2),
            ),
        }
    }
}

/// A scenario × mode × scale sweep: the fault-subsystem counterpart of
/// [`QosExperiment`], reproducing §III-G and extending it with
/// time-varying shapes across asynchronicity modes and allocation sizes.
#[derive(Clone, Debug)]
pub struct ScenarioExperiment {
    pub name: &'static str,
    pub scenarios: Vec<ScenarioKind>,
    pub modes: Vec<AsyncMode>,
    pub proc_counts: Vec<usize>,
    /// Processes per node (paper §III-G allocation: 4).
    pub cpus_per_node: usize,
    pub replicates: usize,
    pub schedule: SnapshotSchedule,
    pub run_for: Nanos,
    pub send_buffer: usize,
    pub seed: u64,
    /// When set, the sweep appends one adaptive-controller cell family
    /// per (scenario, procs, replicate) on top of the static `modes`
    /// grid: base mode 0 (Sync) under
    /// `PolicyConfig::Adaptive(AdaptiveConfig::paper_defaults(..))`.
    /// Static cells keep their historical seed packing bit-identically;
    /// adaptive cells get a disjoint seed slot (bit 40).
    pub adaptive: bool,
}

impl ScenarioExperiment {
    /// The full suite: every canned shape × modes 0–3 × 64/256 procs.
    pub fn paper_suite() -> Self {
        let full = full_scale();
        let (schedule, run_for) = if full {
            (SnapshotSchedule::paper(), 301 * SECOND)
        } else {
            (
                SnapshotSchedule::compressed(400 * MILLI, 400 * MILLI, 100 * MILLI, 6),
                2_600 * MILLI,
            )
        };
        Self {
            name: "fault_scenarios",
            scenarios: ScenarioKind::ALL.to_vec(),
            modes: vec![
                AsyncMode::Sync,
                AsyncMode::RollingBarrier,
                AsyncMode::FixedBarrier,
                AsyncMode::BestEffort,
            ],
            proc_counts: vec![64, 256],
            cpus_per_node: 4,
            replicates: if full { 5 } else { 2 },
            schedule,
            run_for,
            send_buffer: 64,
            seed: 0xFA57,
            adaptive: false,
        }
    }

    /// Adaptive-vs-static sweep: every canned shape (plus the
    /// process-scoped leave/join storm) × static modes 0–3 × one
    /// adaptive cell family (base mode 0, paper-default controller
    /// thresholds) at the §III-G 64-proc allocation. The comparison the
    /// controller exists for: does flipping only the degraded channels
    /// to best-effort match — or beat — the best static mode's median
    /// failure rate per scenario family, without giving up mode 0's
    /// quiescent discipline?
    pub fn adaptive_suite() -> Self {
        let mut e = Self::paper_suite();
        e.name = "fault_scenarios_adaptive";
        let mut scenarios = ScenarioKind::ALL.to_vec();
        scenarios.push(ScenarioKind::LeaveJoinStorm);
        e.scenarios = scenarios;
        e.proc_counts = vec![64];
        e.replicates = if full_scale() { 5 } else { 2 };
        e.adaptive = true;
        e
    }

    /// CI-sized rung of [`Self::adaptive_suite`]: three shapes, modes 0
    /// and 3 static, 16 procs, one replicate — exercises controller
    /// escalation, heal-back, and the adaptive report section in
    /// seconds.
    pub fn adaptive_smoke() -> Self {
        let mut e = Self::adaptive_suite();
        e.name = "fault_scenarios_adaptive_smoke";
        e.scenarios = vec![
            ScenarioKind::Baseline,
            ScenarioKind::Lac417Static,
            ScenarioKind::FlappingClique,
        ];
        e.modes = vec![AsyncMode::Sync, AsyncMode::BestEffort];
        e.proc_counts = vec![16];
        e.replicates = 1;
        e.schedule = SnapshotSchedule::compressed(150 * MILLI, 150 * MILLI, 50 * MILLI, 4);
        e.run_for = 700 * MILLI;
        e
    }

    /// Scale rung of the scenario sweep: baseline + congestion storm at
    /// 256 and 1024 procs (4096 under `EBCOMM_FULL=1`), sync vs
    /// best-effort, one replicate, trimmed windows — the "communication
    /// coagulation at scale" probe the paper's QoS suite exists for,
    /// kept small enough to run outside CI without an allocation.
    pub fn scale_suite() -> Self {
        let mut e = Self::paper_suite();
        e.name = "fault_scenarios_scale";
        e.scenarios = vec![ScenarioKind::Baseline, ScenarioKind::CongestionStorm];
        e.modes = vec![AsyncMode::Sync, AsyncMode::BestEffort];
        e.proc_counts = if full_scale() {
            vec![256, 1024, 4096]
        } else {
            vec![256, 1024]
        };
        e.replicates = 1;
        e.schedule = SnapshotSchedule::compressed(150 * MILLI, 150 * MILLI, 50 * MILLI, 3);
        e.run_for = 600 * MILLI;
        e
    }

    /// Membership-churn rung: baseline vs [`ScenarioKind::LeaveJoinStorm`]
    /// at 64/256 procs (4 and 16 staggered leavers respectively), sync vs
    /// best-effort. Snapshot windows straddle the churn phase (run 20–60 %)
    /// and the post-rejoin steady state, so phase attribution splits
    /// churn-transient from steady medians. Opt-in via `--churn` on
    /// `bench_fault_scenarios` — the shape is process-scoped, so it never
    /// joins the node-scoped `ALL` grid.
    pub fn churn_suite() -> Self {
        let mut e = Self::paper_suite();
        e.name = "fault_scenarios_churn";
        e.scenarios = vec![ScenarioKind::Baseline, ScenarioKind::LeaveJoinStorm];
        e.modes = vec![AsyncMode::Sync, AsyncMode::BestEffort];
        e.proc_counts = vec![64, 256];
        e.replicates = if full_scale() { 3 } else { 1 };
        e.schedule = SnapshotSchedule::compressed(100 * MILLI, 150 * MILLI, 50 * MILLI, 4);
        e.run_for = 600 * MILLI;
        e
    }

    /// CI-smoke grid: two shapes per family, 16 procs, modes 0 and 3,
    /// one replicate — exercises compile/overlay/attribution end to end
    /// in seconds.
    pub fn smoke() -> Self {
        let mut e = Self::paper_suite();
        e.name = "fault_scenarios_smoke";
        e.scenarios = vec![
            ScenarioKind::Baseline,
            ScenarioKind::Lac417Static,
            ScenarioKind::CongestionStorm,
            ScenarioKind::PartitionHeal,
        ];
        e.modes = vec![AsyncMode::Sync, AsyncMode::BestEffort];
        e.proc_counts = vec![16];
        e.replicates = 1;
        e.schedule = SnapshotSchedule::compressed(150 * MILLI, 150 * MILLI, 50 * MILLI, 4);
        e.run_for = 700 * MILLI;
        e
    }

    pub fn placement(&self) -> PlacementKind {
        if self.cpus_per_node <= 1 {
            PlacementKind::OnePerNode
        } else {
            PlacementKind::PerNode(self.cpus_per_node)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn benchmark_presets_cover_paper_sweep() {
        let e = BenchmarkExperiment::fig3_multiprocess_gc();
        assert_eq!(e.cpu_counts, vec![1, 4, 16, 64]);
        assert_eq!(e.modes.len(), 5);
        assert_eq!(e.send_buffer, 2, "paper benchmarking buffer size");
        assert!(!e.multithread);
        assert_eq!(e.placement(), PlacementKind::OnePerNode);
        assert_eq!(e.backend(), CommBackend::Mpi);
    }

    #[test]
    fn multithread_presets_use_shared_memory_and_contention() {
        let e = BenchmarkExperiment::fig2_multithread_gc();
        assert!(e.multithread);
        assert_eq!(e.backend(), CommBackend::SharedMemory);
        assert!(e.contention().factor(64) > 5.0);
        let de = BenchmarkExperiment::fig2_multithread_de();
        assert!(de.contention().factor(64) < 3.0, "DE contends less");
    }

    #[test]
    fn virtual_workload_profile_preserved_under_compression() {
        // simels * cost_scale must equal the paper's full-scale product.
        let e = BenchmarkExperiment::fig3_multiprocess_gc();
        let product = e.simels_per_cpu as f64 * e.cost_scale;
        assert_eq!(product, 2048.0);
        let d = BenchmarkExperiment::fig2_multithread_de();
        assert_eq!(d.simels_per_cpu as f64 * d.cost_scale, 3600.0);
    }

    #[test]
    fn qos_presets_match_paper_parameters() {
        let e = QosExperiment::compute_vs_comm(4096);
        assert_eq!(e.n_procs, 2);
        assert_eq!(e.simels_per_cpu, 1, "1 simel/CPU maximizes comm intensity");
        assert_eq!(e.send_buffer, 64, "QoS experiments need buffer 64");
        assert_eq!(e.added_work_units, 4096);

        assert_eq!(QosExperiment::intranode().placement, PlacementKind::SingleNode);
        assert_eq!(QosExperiment::internode().placement, PlacementKind::OnePerNode);
        assert_eq!(
            QosExperiment::multithread_pair().backend,
            CommBackend::SharedMemory
        );
    }

    #[test]
    fn weak_scaling_placements() {
        let e = QosExperiment::weak_scaling(64, 4, 2048);
        assert_eq!(e.placement, PlacementKind::PerNode(4));
        assert_eq!(e.simels_per_cpu as f64 * e.cost_scale, 2048.0);
        let h = QosExperiment::weak_scaling(256, 1, 1);
        assert_eq!(h.placement, PlacementKind::OnePerNode);
        assert_eq!(h.simels_per_cpu, 1);
    }

    #[test]
    fn faulty_allocation_toggles_node() {
        assert!(QosExperiment::faulty_allocation(true).faulty_node.is_some());
        assert!(QosExperiment::faulty_allocation(false).faulty_node.is_none());
    }

    #[test]
    fn scenario_faulty_allocation_mirrors_static_treatment() {
        let stat = QosExperiment::faulty_allocation(true);
        let scen = QosExperiment::faulty_allocation_scenario(true);
        assert_eq!(stat.n_procs, scen.n_procs);
        assert_eq!(stat.placement, scen.placement);
        assert_eq!(stat.send_buffer, scen.send_buffer);
        assert!(stat.scenario.is_empty() && !scen.scenario.is_empty());
        assert!(QosExperiment::faulty_allocation_scenario(false)
            .scenario
            .is_empty());
    }

    #[test]
    fn scenario_kinds_build_valid_scenarios_across_scales() {
        for &n_nodes in &[4usize, 16, 64] {
            for kind in ScenarioKind::ALL {
                let sc = kind.build(2_600 * MILLI, n_nodes, n_nodes * 4);
                sc.validate(n_nodes); // would panic on a bad build
                if kind == ScenarioKind::Baseline {
                    assert!(sc.is_empty());
                } else {
                    assert!(!sc.is_empty(), "{}", kind.label());
                }
            }
        }
        // Paper-scale storm clamps to 30 s.
        let storm = ScenarioKind::CongestionStorm.build(301 * SECOND, 64, 256);
        assert_eq!(storm.events[0].duration, 30 * SECOND);
        // Discriminant-as-index stays aligned with ALL's ordering (seed
        // packing depends on it).
        for (i, kind) in ScenarioKind::ALL.iter().enumerate() {
            assert_eq!(kind.index(), i);
        }
        let node = ScenarioKind::fault_node(64);
        assert!(node > 0 && node < 64, "mid-allocation node, got {node}");
    }

    #[test]
    fn scale_presets_reach_1024_procs() {
        // Without EBCOMM_FULL these are the smoke-capped grids CI runs:
        // the 1024-proc rung is always present, 4096 is full-scale only.
        let e = BenchmarkExperiment::scale_multiprocess_gc();
        assert!(e.cpu_counts.contains(&1024));
        assert_eq!(e.simels_per_cpu, 1, "communication-dominated cells");
        assert_eq!(e.placement(), PlacementKind::OnePerNode);
        assert!(e.modes.contains(&AsyncMode::Sync), "barrier storms at scale");
        let s = ScenarioExperiment::scale_suite();
        assert!(s.proc_counts.contains(&1024));
        assert_eq!(s.replicates, 1);
        assert!(s.scenarios.contains(&ScenarioKind::CongestionStorm));
        if !full_scale() {
            assert!(!e.cpu_counts.contains(&4096), "4096 is full-scale only");
            assert!(!s.proc_counts.contains(&4096), "4096 is full-scale only");
        }
    }

    #[test]
    fn churn_suite_builds_valid_process_scoped_storms() {
        let e = ScenarioExperiment::churn_suite();
        assert!(e.scenarios.contains(&ScenarioKind::LeaveJoinStorm));
        assert_eq!(e.proc_counts, vec![64, 256]);
        // Process-scoped shape stays out of the node-scoped seed grid…
        assert!(!ScenarioKind::ALL.contains(&ScenarioKind::LeaveJoinStorm));
        // …but keeps a stable discriminant index after the frozen six.
        assert_eq!(ScenarioKind::LeaveJoinStorm.index(), ScenarioKind::ALL.len());
        for &n_procs in &[64usize, 256] {
            let n_nodes = n_procs / e.cpus_per_node;
            let sc = ScenarioKind::LeaveJoinStorm.build(e.run_for, n_nodes, n_procs);
            sc.validate(n_nodes);
            sc.validate_procs(n_procs);
            assert!(sc.has_churn());
        }
    }

    #[test]
    fn scenario_suite_covers_modes_0_to_3() {
        let e = ScenarioExperiment::paper_suite();
        assert_eq!(e.modes.len(), 4);
        assert!(!e.modes.contains(&AsyncMode::NoComm));
        assert_eq!(e.proc_counts, vec![64, 256]);
        assert_eq!(e.scenarios.len(), 6);
        assert_eq!(e.send_buffer, 64, "QoS-style buffer");
        assert_eq!(e.placement(), PlacementKind::PerNode(4));
        let s = ScenarioExperiment::smoke();
        assert!(s.scenarios.len() < e.scenarios.len());
        assert_eq!(s.replicates, 1);
    }

    #[test]
    fn adaptive_suite_extends_static_grid() {
        let e = ScenarioExperiment::adaptive_suite();
        assert!(e.adaptive);
        assert_eq!(e.modes.len(), 4, "static comparison arms stay intact");
        assert!(e.scenarios.contains(&ScenarioKind::LeaveJoinStorm));
        assert_eq!(e.proc_counts, vec![64]);
        assert!(
            !ScenarioExperiment::paper_suite().adaptive,
            "historical suites stay static (seed grid frozen)"
        );
        let s = ScenarioExperiment::adaptive_smoke();
        assert!(s.adaptive);
        assert_eq!(s.replicates, 1);
        assert!(s.scenarios.len() < e.scenarios.len());
    }
}
