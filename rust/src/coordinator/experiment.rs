//! Experiment definitions for every table and figure in the paper.
//!
//! Each preset mirrors a paper treatment (see DESIGN.md §4 for the
//! experiment index). Paper-scale parameters (5 s benchmark windows, five
//! 1-minute-spaced QoS snapshots, 5–10 replicates) are expensive under
//! simulation, so every preset also has a *compressed* variant preserving
//! the treatment structure at reduced virtual runtime; benches run
//! compressed by default and full scale with `EBCOMM_FULL=1`.

use crate::net::PlacementKind;
use crate::qos::SnapshotSchedule;
use crate::sim::{AsyncMode, CommBackend, ContentionModel, ModeTiming};
use crate::util::{Nanos, MILLI, SECOND};

/// Which benchmark workload an experiment runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    GraphColoring,
    DigitalEvolution,
}

impl Workload {
    pub fn label(self) -> &'static str {
        match self {
            Workload::GraphColoring => "graph coloring",
            Workload::DigitalEvolution => "digital evolution",
        }
    }
}

/// Is full-scale (paper-fidelity) execution requested?
pub fn full_scale() -> bool {
    std::env::var("EBCOMM_FULL").map(|v| v == "1").unwrap_or(false)
}

/// A performance-benchmark experiment (Figs. 2–3).
#[derive(Clone, Debug)]
pub struct BenchmarkExperiment {
    pub name: &'static str,
    pub workload: Workload,
    /// CPU counts swept (paper: 1, 4, 16, 64).
    pub cpu_counts: Vec<usize>,
    pub modes: Vec<AsyncMode>,
    /// Threads on one node (true) vs one process per node (false).
    pub multithread: bool,
    pub replicates: usize,
    /// Virtual run window per replicate (paper: 5 s).
    pub run_for: Nanos,
    /// Simulation elements per CPU (paper: 2048 GC / 3600 DE).
    pub simels_per_cpu: usize,
    /// Scale nominal per-simel cost by this factor — lets compressed runs
    /// host fewer real simels at unchanged virtual workload profile.
    pub cost_scale: f64,
    pub send_buffer: usize,
    pub seed: u64,
}

impl BenchmarkExperiment {
    fn base(name: &'static str, workload: Workload, multithread: bool) -> Self {
        let full = full_scale();
        let (simels, cost_scale) = match (workload, full) {
            (Workload::GraphColoring, true) => (2048, 1.0),
            (Workload::GraphColoring, false) => (256, 8.0),
            (Workload::DigitalEvolution, true) => (3600, 1.0),
            (Workload::DigitalEvolution, false) => (400, 9.0),
        };
        Self {
            name,
            workload,
            cpu_counts: vec![1, 4, 16, 64],
            modes: AsyncMode::ALL.to_vec(),
            multithread,
            replicates: if full { 5 } else { 3 },
            run_for: if full { 5 * SECOND } else { SECOND },
            simels_per_cpu: simels,
            cost_scale,
            send_buffer: 2,
            seed: 0x5EED,
        }
    }

    /// Fig. 2a/2b: multithread graph coloring.
    pub fn fig2_multithread_gc() -> Self {
        Self::base("fig2ab_multithread_graph_coloring", Workload::GraphColoring, true)
    }

    /// Fig. 2c: multithread digital evolution.
    pub fn fig2_multithread_de() -> Self {
        Self::base("fig2c_multithread_digital_evolution", Workload::DigitalEvolution, true)
    }

    /// Fig. 3a/3b: multiprocess graph coloring (distinct nodes).
    pub fn fig3_multiprocess_gc() -> Self {
        Self::base("fig3ab_multiprocess_graph_coloring", Workload::GraphColoring, false)
    }

    /// Fig. 3c: multiprocess digital evolution.
    pub fn fig3_multiprocess_de() -> Self {
        Self::base("fig3c_multiprocess_digital_evolution", Workload::DigitalEvolution, false)
    }

    pub fn placement(&self) -> PlacementKind {
        if self.multithread {
            PlacementKind::SingleNode
        } else {
            PlacementKind::OnePerNode
        }
    }

    pub fn backend(&self) -> CommBackend {
        if self.multithread {
            CommBackend::SharedMemory
        } else {
            CommBackend::Mpi
        }
    }

    pub fn contention(&self) -> ContentionModel {
        if !self.multithread {
            return ContentionModel::none();
        }
        match self.workload {
            Workload::GraphColoring => ContentionModel::graph_coloring_threads(),
            Workload::DigitalEvolution => ContentionModel::digital_evolution_threads(),
        }
    }

    pub fn timing(&self, n_cpus: usize) -> ModeTiming {
        let mut t = match self.workload {
            Workload::GraphColoring => ModeTiming::graph_coloring(n_cpus),
            Workload::DigitalEvolution => ModeTiming::digital_evolution(n_cpus),
        };
        // Compressed runs scale the mode-2 epoch (paper: 1 s of a 5 s
        // window) to a fifth of the virtual window so fixed-barrier
        // behaviour — including the startup-skew race — is exercised.
        if !full_scale() {
            t.fixed_epoch = (self.run_for / 5).max(1);
            t.fixed_skew_max =
                ((n_cpus as f64 / 64.0).min(1.0) * t.fixed_epoch as f64) as u64;
        }
        t
    }
}

/// A quality-of-service experiment (§III-C..G).
#[derive(Clone, Debug)]
pub struct QosExperiment {
    pub name: &'static str,
    pub n_procs: usize,
    pub placement: PlacementKind,
    pub backend: CommBackend,
    /// Simulation elements per CPU (1 = maximal communication intensity).
    pub simels_per_cpu: usize,
    pub cost_scale: f64,
    pub added_work_units: u64,
    pub replicates: usize,
    pub send_buffer: usize,
    pub schedule: SnapshotSchedule,
    pub run_for: Nanos,
    /// Node index hosting the faulty profile, if any (§III-G).
    pub faulty_node: Option<usize>,
    pub seed: u64,
}

impl QosExperiment {
    fn base(name: &'static str, n_procs: usize, placement: PlacementKind) -> Self {
        let full = full_scale();
        let (schedule, run_for) = if full {
            (SnapshotSchedule::paper(), 301 * SECOND)
        } else {
            (
                SnapshotSchedule::compressed(500 * MILLI, 500 * MILLI, 100 * MILLI, 5),
                2_600 * MILLI,
            )
        };
        Self {
            name,
            n_procs,
            placement,
            backend: CommBackend::Mpi,
            simels_per_cpu: 1,
            cost_scale: 1.0,
            added_work_units: 0,
            replicates: if full { 10 } else { 3 },
            send_buffer: 64,
            schedule,
            run_for,
            faulty_node: None,
            seed: 0x0905,
        }
    }

    /// §III-C: compute-vs-communication sweep point (2 procs, 2 nodes,
    /// 1 simel/CPU, `work` added units).
    pub fn compute_vs_comm(work: u64) -> Self {
        let mut e = Self::base("qos_compute_vs_comm", 2, PlacementKind::OnePerNode);
        e.added_work_units = work;
        // Heavy-work points need longer virtual windows than the
        // compressed default to complete even a handful of updates.
        if !full_scale() && work >= 262_144 {
            e.schedule = SnapshotSchedule::compressed(2 * SECOND, 2 * SECOND, SECOND, 3);
            e.run_for = 9 * SECOND;
            e.replicates = 2;
        }
        e
    }

    /// §III-D: two processes on one node (intranode MPI).
    pub fn intranode() -> Self {
        Self::base("qos_intranode", 2, PlacementKind::SingleNode)
    }

    /// §III-D: two processes on distinct nodes (internode MPI).
    pub fn internode() -> Self {
        Self::base("qos_internode", 2, PlacementKind::OnePerNode)
    }

    /// §III-E: two threads on one node (shared-memory backend).
    pub fn multithread_pair() -> Self {
        let mut e = Self::base("qos_multithread", 2, PlacementKind::SingleNode);
        e.backend = CommBackend::SharedMemory;
        e
    }

    /// §III-E: two processes on one node (MPI backend). Alias of
    /// [`Self::intranode`] with its own name for the report.
    pub fn multiprocess_pair() -> Self {
        let mut e = Self::base("qos_multiprocess", 2, PlacementKind::SingleNode);
        e.name = "qos_multiprocess";
        e
    }

    /// §III-F: weak-scaling point.
    pub fn weak_scaling(n_procs: usize, cpus_per_node: usize, simels: usize) -> Self {
        let placement = if cpus_per_node == 1 {
            PlacementKind::OnePerNode
        } else {
            PlacementKind::PerNode(cpus_per_node)
        };
        let mut e = Self::base("qos_weak_scaling", n_procs, placement);
        if simels > 1 && !full_scale() {
            e.simels_per_cpu = 256;
            e.cost_scale = simels as f64 / 256.0;
        } else {
            e.simels_per_cpu = simels;
        }
        e.replicates = if full_scale() { 10 } else { 2 };
        e
    }

    /// §III-G: 256-process allocation with or without the faulty node.
    pub fn faulty_allocation(include_faulty: bool) -> Self {
        let mut e = Self::weak_scaling(256, 4, 1);
        e.name = if include_faulty {
            "qos_with_lac417"
        } else {
            "qos_without_lac417"
        };
        // Place the degraded node mid-allocation (paper: lac-417).
        e.faulty_node = include_faulty.then_some(17);
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn benchmark_presets_cover_paper_sweep() {
        let e = BenchmarkExperiment::fig3_multiprocess_gc();
        assert_eq!(e.cpu_counts, vec![1, 4, 16, 64]);
        assert_eq!(e.modes.len(), 5);
        assert_eq!(e.send_buffer, 2, "paper benchmarking buffer size");
        assert!(!e.multithread);
        assert_eq!(e.placement(), PlacementKind::OnePerNode);
        assert_eq!(e.backend(), CommBackend::Mpi);
    }

    #[test]
    fn multithread_presets_use_shared_memory_and_contention() {
        let e = BenchmarkExperiment::fig2_multithread_gc();
        assert!(e.multithread);
        assert_eq!(e.backend(), CommBackend::SharedMemory);
        assert!(e.contention().factor(64) > 5.0);
        let de = BenchmarkExperiment::fig2_multithread_de();
        assert!(de.contention().factor(64) < 3.0, "DE contends less");
    }

    #[test]
    fn virtual_workload_profile_preserved_under_compression() {
        // simels * cost_scale must equal the paper's full-scale product.
        let e = BenchmarkExperiment::fig3_multiprocess_gc();
        let product = e.simels_per_cpu as f64 * e.cost_scale;
        assert_eq!(product, 2048.0);
        let d = BenchmarkExperiment::fig2_multithread_de();
        assert_eq!(d.simels_per_cpu as f64 * d.cost_scale, 3600.0);
    }

    #[test]
    fn qos_presets_match_paper_parameters() {
        let e = QosExperiment::compute_vs_comm(4096);
        assert_eq!(e.n_procs, 2);
        assert_eq!(e.simels_per_cpu, 1, "1 simel/CPU maximizes comm intensity");
        assert_eq!(e.send_buffer, 64, "QoS experiments need buffer 64");
        assert_eq!(e.added_work_units, 4096);

        assert_eq!(QosExperiment::intranode().placement, PlacementKind::SingleNode);
        assert_eq!(QosExperiment::internode().placement, PlacementKind::OnePerNode);
        assert_eq!(
            QosExperiment::multithread_pair().backend,
            CommBackend::SharedMemory
        );
    }

    #[test]
    fn weak_scaling_placements() {
        let e = QosExperiment::weak_scaling(64, 4, 2048);
        assert_eq!(e.placement, PlacementKind::PerNode(4));
        assert_eq!(e.simels_per_cpu as f64 * e.cost_scale, 2048.0);
        let h = QosExperiment::weak_scaling(256, 1, 1);
        assert_eq!(h.placement, PlacementKind::OnePerNode);
        assert_eq!(h.simels_per_cpu, 1);
    }

    #[test]
    fn faulty_allocation_toggles_node() {
        assert!(QosExperiment::faulty_allocation(true).faulty_node.is_some());
        assert!(QosExperiment::faulty_allocation(false).faulty_node.is_none());
    }
}
