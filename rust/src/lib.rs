//! # ebcomm — Best-Effort Communication on Conventional Hardware
//!
//! A Rust + JAX/Pallas reproduction of Moreno & Ofria (2022), *"Best-Effort
//! Communication Improves Performance and Scales Robustly on Conventional
//! Hardware"* — the Conduit library paper.
//!
//! The crate provides:
//!
//! * [`conduit`] — the best-effort channel abstraction (inlets/outlets,
//!   bounded lossy buffers, pooling/aggregation, QoS instrumentation);
//! * [`qos`] — the paper's five quality-of-service metrics and snapshot
//!   machinery (simstep period, simstep latency, walltime latency,
//!   delivery failure rate, delivery clumpiness);
//! * [`net`] — cluster topology and link/fault models;
//! * [`faults`] — deterministic fault scenarios: scripted time-varying
//!   degradation (onset/recovery, flapping links, congestion storms,
//!   partition-and-heal) with per-window QoS phase attribution;
//! * [`sim`] — a deterministic discrete-event simulator of a multi-node
//!   allocation running the paper's asynchronicity modes 0–4;
//! * [`exec`] — a real `std::thread` executor over the same workload API;
//! * [`workloads`] — the two benchmark workloads: distributed graph
//!   coloring (Leith et al. 2012) and a DISHTINY-style digital-evolution
//!   simulation;
//! * [`runtime`] — PJRT loading/execution of the AOT-compiled JAX/Pallas
//!   compute kernels (`artifacts/*.hlo.txt`);
//! * [`stats`] — bootstrap CIs, OLS and quantile regression used to render
//!   the paper's statistical comparisons;
//! * [`coordinator`] — experiment definitions and replicate orchestration
//!   for every table and figure in the paper's evaluation.
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `EXPERIMENTS.md` for measured-vs-paper results.

pub mod conduit;
pub mod coordinator;
pub mod exec;
pub mod faults;
pub mod net;
pub mod qos;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod testing;
pub mod util;
pub mod workloads;
