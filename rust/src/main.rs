//! `ebcomm` CLI — launcher for the paper's experiments.
//!
//! ```text
//! ebcomm bench <fig2gc|fig2de|fig3gc|fig3de>    benchmark figures (Figs. 2-3)
//! ebcomm qos <work|placement|backend|scaling|faulty>
//!                                                QoS experiments (SIII-C..G)
//! ebcomm run [--procs N] [--mode M] [--seconds S] [--workload gc|de]
//!                                                one ad-hoc simulated run
//! ebcomm runtime-smoke                           verify PJRT artifact loading
//! ```
//!
//! Results print as paper-style tables and are also written as CSV under
//! `results/`. Set `EBCOMM_FULL=1` for paper-fidelity scales (slow).

use std::process::ExitCode;

use ebcomm::coordinator::experiment::{BenchmarkExperiment, QosExperiment, Workload};
use ebcomm::coordinator::report;
use ebcomm::coordinator::{run_benchmark, run_qos};
use ebcomm::net::{PlacementKind, Topology};
use ebcomm::qos::MetricName;
use ebcomm::sim::{healthy_profiles, AsyncMode, Engine, ModeTiming, SimConfig};
use ebcomm::util::rng::Xoshiro256;
use ebcomm::util::SECOND;
use ebcomm::workloads::dishtiny::{DeConfig, DishtinyShard};
use ebcomm::workloads::graph_coloring::{global_conflicts, GcConfig, GraphColoringShard};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let result = match cmd {
        "bench" => cmd_bench(rest),
        "qos" => cmd_qos(rest),
        "run" => cmd_run(rest),
        "runtime-smoke" => cmd_runtime_smoke(),
        // Hidden: re-exec entry point for multiprocess executor workers
        // (spawned by `exec::multiproc::run_multiproc`, never by hand).
        ebcomm::exec::multiproc::CHILD_SUBCOMMAND => {
            ebcomm::exec::multiproc::child_main().map_err(Into::into)
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try `ebcomm help`)").into()),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

type CliResult = Result<(), Box<dyn std::error::Error>>;

fn print_help() {
    println!(
        "ebcomm — best-effort communication reproduction (Moreno & Ofria 2022)\n\
         \n\
         USAGE:\n\
         \x20 ebcomm bench <fig2gc|fig2de|fig3gc|fig3de>\n\
         \x20 ebcomm qos <work|placement|backend|scaling|faulty>\n\
         \x20 ebcomm run [--procs N] [--mode 0..4] [--seconds S] [--workload gc|de]\n\
         \x20 ebcomm runtime-smoke\n\
         \n\
         ENV:\n\
         \x20 EBCOMM_FULL=1        paper-fidelity scales (slow)\n\
         \x20 EBCOMM_ARTIFACTS=dir artifact directory (default: ./artifacts)"
    );
}

fn cmd_bench(args: &[String]) -> CliResult {
    let which = args.first().map(String::as_str).unwrap_or("fig3gc");
    let exp = match which {
        "fig2gc" => BenchmarkExperiment::fig2_multithread_gc(),
        "fig2de" => BenchmarkExperiment::fig2_multithread_de(),
        "fig3gc" => BenchmarkExperiment::fig3_multiprocess_gc(),
        "fig3de" => BenchmarkExperiment::fig3_multiprocess_de(),
        other => return Err(format!("unknown benchmark '{other}'").into()),
    };
    eprintln!("running {} ({} replicates)...", exp.name, exp.replicates);
    let results = run_benchmark(&exp);
    println!(
        "{}",
        report::benchmark_table(exp.name, &results, &exp.cpu_counts, &exp.modes, false)
    );
    if exp.workload == Workload::GraphColoring {
        println!(
            "{}",
            report::benchmark_table(
                &format!("{} — solution conflicts (lower better)", exp.name),
                &results,
                &exp.cpu_counts,
                &exp.modes,
                true
            )
        );
    }
    let max_cpus = *exp.cpu_counts.iter().max().unwrap();
    let h = report::headline(&results, max_cpus);
    println!(
        "headline @{} cpus: mode3/mode0 speedup {:.2}x, mode3 scaling efficiency {:.1}%, significant={}",
        max_cpus,
        h.speedup_mode3_vs_mode0,
        100.0 * h.scaling_efficiency_mode3,
        h.significant
    );
    let csv = report::benchmark_csv(&results);
    let path = format!("results/{}.csv", exp.name);
    csv.write_to(&path)?;
    eprintln!("wrote {path}");
    Ok(())
}

fn cmd_qos(args: &[String]) -> CliResult {
    match args.first().map(String::as_str).unwrap_or("placement") {
        "work" => {
            let mut all = Vec::new();
            for &w in &ebcomm::workloads::workunit::PAPER_WORK_SWEEP {
                eprintln!("work sweep: {w} units...");
                let exp = QosExperiment::compute_vs_comm(w);
                let res = run_qos(&exp);
                println!("{}", report::qos_summary(&format!("{w} work units"), &res));
                all.push((w, res));
            }
            for (w, res) in &all {
                report::qos_csv(res).write_to(format!("results/qos_work_{w}.csv"))?;
            }
        }
        "placement" => {
            let intra = run_qos(&QosExperiment::intranode());
            let inter = run_qos(&QosExperiment::internode());
            println!("{}", report::qos_summary("intranode (2 procs, 1 node)", &intra));
            println!("{}", report::qos_summary("internode (2 procs, 2 nodes)", &inter));
            println!(
                "{}",
                report::qos_comparison(
                    "SIII-D placement",
                    ("intranode", &intra),
                    ("internode", &inter)
                )
            );
            report::qos_csv(&intra).write_to("results/qos_intranode.csv")?;
            report::qos_csv(&inter).write_to("results/qos_internode.csv")?;
        }
        "backend" => {
            let thr = run_qos(&QosExperiment::multithread_pair());
            let proc = run_qos(&QosExperiment::multiprocess_pair());
            println!("{}", report::qos_summary("multithreading (mutex)", &thr));
            println!("{}", report::qos_summary("multiprocessing (MPI model)", &proc));
            println!(
                "{}",
                report::qos_comparison("SIII-E backend", ("threads", &thr), ("processes", &proc))
            );
            report::qos_csv(&thr).write_to("results/qos_threads.csv")?;
            report::qos_csv(&proc).write_to("results/qos_processes.csv")?;
        }
        "scaling" => {
            let mut points = Vec::new();
            for &procs in &[16usize, 64, 256] {
                eprintln!("weak scaling: {procs} procs...");
                let exp = QosExperiment::weak_scaling(procs, 1, 1);
                points.push((procs, run_qos(&exp)));
            }
            for metric in MetricName::ALL {
                println!(
                    "{}",
                    report::scaling_regression("SIII-F (1 cpu/node, 1 simel)", &points, metric)
                );
            }
        }
        "faulty" => {
            let with = run_qos(&QosExperiment::faulty_allocation(true));
            let without = run_qos(&QosExperiment::faulty_allocation(false));
            println!("{}", report::qos_summary("with lac-417", &with));
            println!("{}", report::qos_summary("without lac-417", &without));
            println!(
                "{}",
                report::qos_comparison("SIII-G fault", ("without", &without), ("with", &with))
            );
        }
        other => return Err(format!("unknown qos experiment '{other}'").into()),
    }
    Ok(())
}

fn parse_flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn cmd_run(args: &[String]) -> CliResult {
    let procs: usize = parse_flag(args, "--procs").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let mode_idx: usize = parse_flag(args, "--mode").map(|s| s.parse()).transpose()?.unwrap_or(3);
    let seconds: f64 = parse_flag(args, "--seconds").map(|s| s.parse()).transpose()?.unwrap_or(1.0);
    let workload = parse_flag(args, "--workload").unwrap_or_else(|| "gc".into());
    let mode = AsyncMode::from_index(mode_idx).ok_or("mode must be 0..=4")?;
    let run_for = (seconds * SECOND as f64) as u64;

    let topo = Topology::new(procs, PlacementKind::OnePerNode);
    let profiles = healthy_profiles(&topo);
    let mut rng = Xoshiro256::new(42);

    match workload.as_str() {
        "gc" => {
            let mut cfg = SimConfig::from_env(mode, ModeTiming::graph_coloring(procs), run_for);
            cfg.send_buffer = 64;
            let shards: Vec<_> = (0..procs)
                .map(|r| {
                    GraphColoringShard::new(
                        GcConfig { simels_per_proc: 256, ..GcConfig::default() },
                        &topo,
                        r,
                        &mut rng,
                    )
                })
                .collect();
            let result = Engine::new(cfg, topo.clone(), profiles, shards).run();
            println!("mode: {}", mode.label());
            println!("procs: {procs}, virtual runtime: {seconds}s");
            println!("per-CPU update rate: {:.1}/s", result.update_rate_per_cpu_hz());
            println!("delivery failure rate: {:.4}", result.overall_failure_rate());
            println!("conflicts remaining: {}", global_conflicts(&topo, &result.shards));
        }
        "de" => {
            let mut cfg = SimConfig::from_env(mode, ModeTiming::digital_evolution(procs), run_for);
            cfg.send_buffer = 64;
            let shards: Vec<_> = (0..procs)
                .map(|r| {
                    DishtinyShard::new(
                        DeConfig { cells_per_proc: 100, ..DeConfig::default() },
                        &topo,
                        r,
                        &mut rng,
                    )
                })
                .collect();
            let result = Engine::new(cfg, topo, profiles, shards).run();
            println!("mode: {}", mode.label());
            println!("per-CPU update rate: {:.1}/s", result.update_rate_per_cpu_hz());
            let fitness: f64 = result.shards.iter().map(|s| s.mean_resource()).sum::<f64>()
                / result.shards.len() as f64;
            let births: u64 = result.shards.iter().map(|s| s.births()).sum();
            println!("mean cell resource: {fitness:.4}, births: {births}");
        }
        other => return Err(format!("unknown workload '{other}'").into()),
    }
    Ok(())
}

fn cmd_runtime_smoke() -> CliResult {
    use ebcomm::runtime::{ArtifactManifest, RuntimeClient};
    let dir = ArtifactManifest::default_dir();
    let manifest = ArtifactManifest::load(&dir)
        .map_err(|e| format!("{e:#} — run `make artifacts` first"))?;
    let rt = RuntimeClient::cpu()?;
    println!("PJRT platform: {} ({} devices)", rt.platform_name(), rt.device_count());
    for name in manifest.names() {
        let spec = manifest.get(name).unwrap();
        let kernel = rt.load_hlo_text(name, &spec.file)?;
        println!("compiled {name} <- {}", spec.file.display());
        let _ = kernel;
    }
    println!("runtime smoke OK ({} artifacts)", manifest.len());
    Ok(())
}
