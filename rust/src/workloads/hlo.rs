//! HLO-backed workload execution: the AOT-compiled Pallas kernels on the
//! request path.
//!
//! These wrappers implement [`ShardWorkload`] by delegating state,
//! channels, and messaging to the native shards while routing the compute
//! hot-spot through a PJRT executable loaded from `artifacts/` — the full
//! three-layer composition (L3 Rust coordination → L2 JAX graph → L1
//! Pallas kernel). The native and HLO paths compute the same function
//! (equivalence asserted in `rust/tests/integration_runtime.rs`), so
//! either can drive any experiment; examples default to HLO to prove the
//! stack end to end.

use anyhow::{Context, Result};

use super::dishtiny::{DishtinyShard, STATE_DIM};
use super::graph_coloring::{GcMsg, GraphColoringShard};
use super::partition::Dir;
use super::{ChannelSpec, ShardWorkload};
use crate::runtime::{ArtifactManifest, CompiledKernel, HostTensor, RuntimeClient};
use crate::util::rng::{Rng, Xoshiro256};
use crate::workloads::dishtiny::DeMsg;

/// Graph-coloring shard whose red-black CFL sweep runs through the
/// `gc_update_{H}x{W}` PJRT executable.
pub struct HloGraphColoringShard {
    inner: GraphColoringShard,
    kernel: CompiledKernel,
    /// Post-update tile conflict count reported by the kernel.
    pub last_conflicts: i32,
}

impl HloGraphColoringShard {
    /// Wrap a native shard, loading the matching artifact variant.
    pub fn new(
        inner: GraphColoringShard,
        rt: &RuntimeClient,
        manifest: &ArtifactManifest,
    ) -> Result<Self> {
        let part = inner.partition();
        let name = format!("gc_update_{}x{}", part.tile_h, part.tile_w);
        let spec = manifest.require(&name)?;
        let kernel = rt
            .load_hlo_text(&name, &spec.file)
            .with_context(|| format!("loading {name}"))?;
        Ok(Self {
            inner,
            kernel,
            last_conflicts: 0,
        })
    }

    pub fn inner(&self) -> &GraphColoringShard {
        &self.inner
    }

    /// Mutable access to the wrapped shard (test synchronization hook).
    pub fn inner_mut(&mut self) -> &mut GraphColoringShard {
        &mut self.inner
    }

    /// Run one kernel-backed sweep with explicit uniforms (test hook).
    pub fn sweep_hlo(&mut self, uniforms: &[f64]) -> Result<()> {
        let part = *self.inner.partition();
        let (h, w) = (part.tile_h as i64, part.tile_w as i64);
        let k = self.inner.config().n_colors as usize;

        let colors: Vec<i32> = self.inner.colors().iter().map(|&c| c as i32).collect();
        let probs: Vec<f32> = self.inner.probs().iter().map(|&p| p as f32).collect();
        let u: Vec<f32> = uniforms.iter().map(|&x| x as f32).collect();

        let inputs = [
            HostTensor::i32(vec![self.inner.parity_off() as i32], &[1]),
            HostTensor::i32(colors, &[h, w]),
            HostTensor::f32(probs, &[h, w, k as i64]),
            HostTensor::f32(u, &[h, w]),
            HostTensor::i32(self.inner.ghost_view(Dir::North), &[w]),
            HostTensor::i32(self.inner.ghost_view(Dir::East), &[h]),
            HostTensor::i32(self.inner.ghost_view(Dir::South), &[w]),
            HostTensor::i32(self.inner.ghost_view(Dir::West), &[h]),
        ];
        let outputs = self.kernel.run(&inputs)?;
        let new_colors: Vec<u8> = outputs[0]
            .expect_i32()
            .iter()
            .map(|&c| c as u8)
            .collect();
        let new_probs: Vec<f64> = outputs[1]
            .expect_f32()
            .iter()
            .map(|&p| p as f64)
            .collect();
        self.last_conflicts = outputs[2].expect_i32()[0];
        self.inner.load_state(&new_colors, &new_probs);
        Ok(())
    }
}

impl ShardWorkload for HloGraphColoringShard {
    type Msg = GcMsg;

    fn channels(&self) -> Vec<ChannelSpec> {
        self.inner.channels()
    }

    fn absorb(&mut self, ch: usize, msgs: &mut Vec<GcMsg>) {
        self.inner.absorb(ch, msgs);
    }

    fn step(&mut self, rng: &mut Xoshiro256) -> Vec<(usize, GcMsg)> {
        let n = self.inner.partition().simels_per_proc();
        let uniforms: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        self.sweep_hlo(&uniforms)
            .expect("PJRT execution failed on the request path");
        self.inner.pool_borders()
    }

    fn step_cost_ns(&self) -> f64 {
        self.inner.step_cost_ns()
    }

    fn quality(&self) -> f64 {
        self.inner.quality()
    }
}

/// Digital-evolution shard whose genome-evaluation phase runs through the
/// `cell_update_{N}` PJRT executable.
pub struct HloDishtinyShard {
    inner: DishtinyShard,
    kernel: CompiledKernel,
}

impl HloDishtinyShard {
    pub fn new(
        inner: DishtinyShard,
        rt: &RuntimeClient,
        manifest: &ArtifactManifest,
    ) -> Result<Self> {
        let n = inner.cells().len();
        let name = format!("cell_update_{n}");
        let spec = manifest.require(&name)?;
        let kernel = rt
            .load_hlo_text(&name, &spec.file)
            .with_context(|| format!("loading {name}"))?;
        Ok(Self { inner, kernel })
    }

    pub fn inner(&self) -> &DishtinyShard {
        &self.inner
    }
}

impl ShardWorkload for HloDishtinyShard {
    type Msg = DeMsg;

    fn channels(&self) -> Vec<ChannelSpec> {
        self.inner.channels()
    }

    fn absorb(&mut self, ch: usize, msgs: &mut Vec<DeMsg>) {
        self.inner.absorb(ch, msgs);
    }

    fn step(&mut self, rng: &mut Xoshiro256) -> Vec<(usize, DeMsg)> {
        let kernel = &self.kernel;
        self.inner.step_with(rng, |states, coefs, nbrs, resources, inflow| {
            let n = resources.len() as i64;
            let d = STATE_DIM as i64;
            let inputs = [
                HostTensor::f32(states.to_vec(), &[n, d]),
                HostTensor::f32(coefs.to_vec(), &[n, 2 * d]),
                HostTensor::f32(nbrs.to_vec(), &[n, d]),
                HostTensor::f32(resources.to_vec(), &[n]),
                HostTensor::f32(vec![inflow], &[1]),
            ];
            let outputs = kernel
                .run(&inputs)
                .expect("PJRT execution failed on the request path");
            (
                outputs[0].expect_f32().to_vec(),
                outputs[1].expect_f32().to_vec(),
            )
        })
    }

    fn step_cost_ns(&self) -> f64 {
        self.inner.step_cost_ns()
    }

    fn quality(&self) -> f64 {
        self.inner.quality()
    }
}

// Tests requiring built artifacts live in rust/tests/integration_runtime.rs.
