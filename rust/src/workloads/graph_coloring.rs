//! Distributed graph coloring via communication-free learning.
//!
//! The paper's communication-intensive benchmark (§II-B): the decentralized
//! WLAN channel-selection algorithm of Leith et al. (2012). Every vertex
//! holds a color and a probability vector over colors. Each update a
//! vertex checks its four torus neighbors for a color conflict; iff one
//! exists it applies the CFL failure update — `p <- (1-b) p + b/(C-1)
//! (1 - e_cur)`, decreasing the conflicting color's probability
//! multiplicatively and increasing all others (paper SII-B, b = 0.1) —
//! and resamples its color from the updated distribution. Conflict-free
//! vertices collapse their distribution onto the current color (the CFL
//! absorbing state). Vertices always transmit their current color to
//! neighbors.
//!
//! Cross-shard neighbor colors travel as *pooled* border messages — one
//! message per neighboring process per update (§II-B) — and are absorbed
//! into ghost buffers on arrival. Under best-effort operation ghosts may
//! be stale or absent; the algorithm simply acts on the freshest view.

use super::partition::{Dir, TilePartition};
use super::{ChannelSpec, ShardWorkload};
use crate::net::Topology;
use crate::util::rng::{Rng, Xoshiro256};

/// Pooled border-color message: the sender's border colors in pooling
/// order, as seen from the receiving side's ghost direction.
pub type GcMsg = Vec<u8>;

/// Graph-coloring benchmark parameters.
#[derive(Clone, Copy, Debug)]
pub struct GcConfig {
    /// Colors available (paper: 3).
    pub n_colors: u8,
    /// Multiplicative decay of a conflicting color's probability
    /// (paper: b = 0.1).
    pub b: f64,
    /// Simulation elements per process (paper: 2048 benchmarking, 1 QoS).
    pub simels_per_proc: usize,
    /// Nominal per-simel algorithm cost (ns) for the DES cost model.
    pub per_simel_cost_ns: f64,
    /// Nominal fixed per-update cost (ns).
    pub base_cost_ns: f64,
}

impl Default for GcConfig {
    fn default() -> Self {
        Self {
            n_colors: 3,
            b: 0.1,
            simels_per_proc: 2048,
            // Calibrated so a 1-simel update costs ~3.5 us of algorithm
            // work (total 2-proc intranode simstep ~9 us incl. messaging,
            // paper SIII-D.1) and a 2048-simel update ~170 us (weak-scaling
            // simstep ~200 us, SIII-F.1).
            per_simel_cost_ns: 80.0,
            base_cost_ns: 3_400.0,
        }
    }
}

/// One process's tile of the global coloring problem.
pub struct GraphColoringShard {
    cfg: GcConfig,
    part: TilePartition,
    rank: usize,
    /// Channel specs (direction order N,E,S,W, self-channels omitted).
    channels: Vec<ChannelSpec>,
    /// channel index -> direction
    chan_dirs: Vec<Dir>,
    /// Current color per local vertex (row-major tile).
    colors: Vec<u8>,
    /// Per-vertex color probability vectors, row-major `[v][color]`.
    probs: Vec<f64>,
    /// Ghost border colors per direction (None until first delivery).
    ghosts: [Option<Vec<u8>>; 4],
    /// Directions that wrap onto our own tile (self-neighbor mesh rows or
    /// columns) and are serviced locally instead of via channels.
    self_dirs: [bool; 4],
    /// Parity of this tile's global origin, aligning the red-black update
    /// schedule across shards: global parity of local (r, c) is
    /// `(parity_off + r + c) % 2`.
    parity_off: u8,
    /// Reusable per-step uniform-draw scratch (hot-loop allocation
    /// avoidance; see EXPERIMENTS.md SPerf).
    uniform_scratch: Vec<f64>,
}

impl GraphColoringShard {
    /// Build the shard for process `rank` on `topo`'s mesh.
    pub fn new(cfg: GcConfig, topo: &Topology, rank: usize, rng: &mut Xoshiro256) -> Self {
        let (mr, mc) = topo.mesh_dims();
        let part = TilePartition::new(mr, mc, cfg.simels_per_proc);
        let n = part.simels_per_proc();
        let neighbors = topo.neighbors4(rank);

        let mut channels = Vec::new();
        let mut chan_dirs = Vec::new();
        let mut self_dirs = [false; 4];
        for d in Dir::ALL {
            let peer = neighbors[d.index()];
            if peer == rank {
                self_dirs[d.index()] = true;
            } else {
                channels.push(ChannelSpec {
                    peer,
                    layer: d.index(),
                });
                chan_dirs.push(d);
            }
        }

        let colors: Vec<u8> = (0..n).map(|_| rng.below(cfg.n_colors as u64) as u8).collect();
        let probs = vec![1.0 / cfg.n_colors as f64; n * cfg.n_colors as usize];
        let (pr, pc) = (rank / mc, rank % mc);
        let parity_off = ((pr * part.tile_h + pc * part.tile_w) % 2) as u8;

        Self {
            cfg,
            part,
            rank,
            channels,
            chan_dirs,
            colors,
            probs,
            ghosts: [None, None, None, None],
            self_dirs,
            parity_off,
            uniform_scratch: vec![0.0; n],
        }
    }

    /// Parity of this tile's global origin.
    pub fn parity_off(&self) -> u8 {
        self.parity_off
    }

    pub fn config(&self) -> &GcConfig {
        &self.cfg
    }

    pub fn partition(&self) -> &TilePartition {
        &self.part
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Current tile colors (row-major).
    pub fn colors(&self) -> &[u8] {
        &self.colors
    }

    /// Color of the neighbor of local vertex (r, c) toward `dir`, or
    /// `None` when it lives across a border whose ghost has not arrived.
    fn neighbor_color(&self, r: usize, c: usize, dir: Dir) -> Option<u8> {
        let (th, tw) = (self.part.tile_h, self.part.tile_w);
        match dir {
            Dir::North if r > 0 => Some(self.colors[self.part.local_index(r - 1, c)]),
            Dir::South if r < th - 1 => Some(self.colors[self.part.local_index(r + 1, c)]),
            Dir::West if c > 0 => Some(self.colors[self.part.local_index(r, c - 1)]),
            Dir::East if c < tw - 1 => Some(self.colors[self.part.local_index(r, c + 1)]),
            _ => {
                // Crosses the tile border toward `dir`.
                if self.self_dirs[dir.index()] {
                    // Torus wraps back onto our own tile.
                    let idx = match dir {
                        Dir::North => self.part.local_index(th - 1, c),
                        Dir::South => self.part.local_index(0, c),
                        Dir::West => self.part.local_index(r, tw - 1),
                        Dir::East => self.part.local_index(r, 0),
                    };
                    Some(self.colors[idx])
                } else {
                    // Ghost from the neighboring shard: the neighbor sent
                    // its border in the same pooling order as ours, so the
                    // offset is c (horizontal borders) or r (vertical).
                    let off = match dir {
                        Dir::North | Dir::South => c,
                        Dir::East | Dir::West => r,
                    };
                    self.ghosts[dir.index()].as_ref().map(|g| g[off])
                }
            }
        }
    }

    /// Does local vertex (r, c) currently conflict with any visible
    /// neighbor?
    fn conflicted(&self, r: usize, c: usize) -> bool {
        let mine = self.colors[self.part.local_index(r, c)];
        Dir::ALL
            .iter()
            .any(|&d| self.neighbor_color(r, c, d) == Some(mine))
    }

    /// Local conflict count over the shard's current view (used for
    /// `quality()`; global exact counts come from
    /// [`global_conflicts`]).
    pub fn local_conflicts(&self) -> usize {
        let mut n = 0;
        for r in 0..self.part.tile_h {
            for c in 0..self.part.tile_w {
                if self.conflicted(r, c) {
                    n += 1;
                }
            }
        }
        n
    }

    /// Communication-free-learning failure update (Leith et al. 2012):
    /// `p <- (1-b) p + b/(C-1) (1 - e_cur)` — the conflicting color's
    /// probability decreases multiplicatively while every other color's
    /// increases (paper SII-B) — then resample from the updated
    /// distribution. Sampling from the full distribution retains
    /// stickiness (a conflicted vertex usually keeps its color for a few
    /// rounds), which is what lets the stochastic search settle instead of
    /// thrashing in synchronized resample storms.
    fn resample_color(&mut self, v: usize, u: f64) -> u8 {
        let k = self.cfg.n_colors as usize;
        let p = &mut self.probs[v * k..(v + 1) * k];
        let cur = self.colors[v] as usize;
        let b = self.cfg.b;
        let spread = b / (k - 1) as f64;
        for (c, q) in p.iter_mut().enumerate() {
            *q = (1.0 - b) * *q + if c == cur { 0.0 } else { spread };
        }
        // Sample the new color from the updated distribution.
        let mut acc = 0.0;
        for (color, &q) in p.iter().enumerate() {
            acc += q;
            if u < acc {
                return color as u8;
            }
        }
        (k - 1) as u8
    }

    /// One full red-black update sweep against a caller-supplied uniform
    /// draw per vertex (row-major). This is the exact computation the
    /// AOT-compiled Pallas kernel (`gc_update`) performs; `step()` drives
    /// it with freshly drawn uniforms, and the HLO-backed path feeds the
    /// identical inputs to PJRT (equivalence is asserted in
    /// `rust/tests/integration_runtime.rs`).
    pub fn sweep_with_uniforms(&mut self, uniforms: &[f64]) {
        let (th, tw) = (self.part.tile_h, self.part.tile_w);
        debug_assert_eq!(uniforms.len(), th * tw);
        for parity in 0..2u8 {
            for r in 0..th {
                for c in 0..tw {
                    if ((self.parity_off as usize + r + c) % 2) as u8 != parity {
                        continue;
                    }
                    let v = self.part.local_index(r, c);
                    if self.conflicted(r, c) {
                        self.colors[v] = self.resample_color(v, uniforms[v]);
                    } else {
                        self.reinforce_color(v);
                    }
                }
            }
        }
    }

    /// Raw mutable access for the HLO-backed execution path: replace tile
    /// state with kernel outputs.
    pub fn load_state(&mut self, colors: &[u8], probs: &[f64]) {
        assert_eq!(colors.len(), self.colors.len());
        assert_eq!(probs.len(), self.probs.len());
        self.colors.copy_from_slice(colors);
        self.probs.copy_from_slice(probs);
    }

    /// Current probability table (row-major `[v][color]`).
    pub fn probs(&self) -> &[f64] {
        &self.probs
    }

    /// Pool border colors into one message per cross-shard direction
    /// (Conduit pooling, paper §II-B).
    pub fn pool_borders(&self) -> Vec<(usize, GcMsg)> {
        self.chan_dirs
            .iter()
            .enumerate()
            .map(|(ch, &d)| {
                let msg: GcMsg = self
                    .part
                    .border_indices(d)
                    .into_iter()
                    .map(|i| self.colors[i])
                    .collect();
                (ch, msg)
            })
            .collect()
    }

    /// The -1-padded neighbor ghost view per direction, in pooling order
    /// (kernel input format; self-wrap directions are resolved to own
    /// border colors).
    pub fn ghost_view(&self, dir: Dir) -> Vec<i32> {
        let len = self.part.border_len(dir);
        if self.self_dirs[dir.index()] {
            // Torus wraps onto our own opposite border.
            self.part
                .border_indices(dir.opposite())
                .into_iter()
                .map(|i| self.colors[i] as i32)
                .collect()
        } else {
            match &self.ghosts[dir.index()] {
                Some(g) => g.iter().map(|&c| c as i32).collect(),
                None => vec![-1; len],
            }
        }
    }

    /// Communication-free-learning success update: collapse onto the
    /// current color (absorbing state — required for convergence).
    fn reinforce_color(&mut self, v: usize) {
        let k = self.cfg.n_colors as usize;
        let cur = self.colors[v] as usize;
        let p = &mut self.probs[v * k..(v + 1) * k];
        // Settled vertices dominate converged runs: skip the write when
        // the distribution is already collapsed (SPerf iteration 4).
        if p[cur] == 1.0 {
            return;
        }
        for (c, q) in p.iter_mut().enumerate() {
            *q = if c == cur { 1.0 } else { 0.0 };
        }
    }
}

impl ShardWorkload for GraphColoringShard {
    type Msg = GcMsg;

    fn channels(&self) -> Vec<ChannelSpec> {
        self.channels.clone()
    }

    fn absorb(&mut self, ch: usize, msgs: &mut Vec<GcMsg>) {
        // Best-effort: only the freshest border state matters.
        if let Some(latest) = msgs.drain(..).last() {
            let dir = self.chan_dirs[ch];
            if latest.len() == self.part.border_len(dir) {
                self.ghosts[dir.index()] = Some(latest);
            }
            // Arity mismatch => foreign/corrupt message; skipped.
        }
    }

    fn step(&mut self, rng: &mut Xoshiro256) -> Vec<(usize, GcMsg)> {
        // Red-black (checkerboard) sweep: the red phase updates against
        // frozen black neighbors, then the black phase sees the fresh red
        // colors. Torus neighbors always have opposite parity, so no two
        // adjacent vertices ever resample simultaneously — a synchronous
        // Jacobi sweep would oscillate forever on this tightly constrained
        // graph. The parity schedule is global (aligned across shards via
        // `parity_off`). One uniform is drawn per vertex up front so the
        // native and HLO (Pallas kernel) paths consume identical input
        // streams.
        let mut uniforms = std::mem::take(&mut self.uniform_scratch);
        for u in uniforms.iter_mut() {
            *u = rng.next_f64();
        }
        self.sweep_with_uniforms(&uniforms);
        self.uniform_scratch = uniforms;
        self.pool_borders()
    }

    fn step_cost_ns(&self) -> f64 {
        self.cfg.base_cost_ns + self.cfg.per_simel_cost_ns * self.part.simels_per_proc() as f64
    }

    fn quality(&self) -> f64 {
        self.local_conflicts() as f64
    }
}

// ---- checkpoint encoding -------------------------------------------

use crate::sim::checkpoint::{Persist, SnapError, SnapReader, SnapWriter};

impl Persist for GcConfig {
    fn save(&self, w: &mut SnapWriter) {
        self.n_colors.save(w);
        self.b.save(w);
        self.simels_per_proc.save(w);
        self.per_simel_cost_ns.save(w);
        self.base_cost_ns.save(w);
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            n_colors: u8::load(r)?,
            b: f64::load(r)?,
            simels_per_proc: usize::load(r)?,
            per_simel_cost_ns: f64::load(r)?,
            base_cost_ns: f64::load(r)?,
        })
    }
}

impl Persist for GraphColoringShard {
    fn save(&self, w: &mut SnapWriter) {
        self.cfg.save(w);
        self.part.save(w);
        self.rank.save(w);
        self.channels.save(w);
        let dirs: Vec<u8> = self.chan_dirs.iter().map(|d| d.index() as u8).collect();
        dirs.save(w);
        self.colors.save(w);
        self.probs.save(w);
        for g in &self.ghosts {
            g.save(w);
        }
        for &s in &self.self_dirs {
            s.save(w);
        }
        self.parity_off.save(w);
        // Scratch contents are dead (overwritten before every read), but
        // serializing them keeps double checkpoints byte-equal.
        self.uniform_scratch.save(w);
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        let cfg = GcConfig::load(r)?;
        let part = TilePartition::load(r)?;
        let rank = usize::load(r)?;
        let channels = Vec::<ChannelSpec>::load(r)?;
        let dirs = Vec::<u8>::load(r)?;
        let mut chan_dirs = Vec::with_capacity(dirs.len());
        for d in dirs {
            let d = usize::from(d);
            if d >= Dir::ALL.len() {
                return Err(SnapError::Corrupt("Dir index"));
            }
            chan_dirs.push(Dir::ALL[d]);
        }
        let colors = Vec::<u8>::load(r)?;
        let probs = Vec::<f64>::load(r)?;
        let ghosts = [
            Option::<Vec<u8>>::load(r)?,
            Option::<Vec<u8>>::load(r)?,
            Option::<Vec<u8>>::load(r)?,
            Option::<Vec<u8>>::load(r)?,
        ];
        let self_dirs = [
            bool::load(r)?,
            bool::load(r)?,
            bool::load(r)?,
            bool::load(r)?,
        ];
        let parity_off = u8::load(r)?;
        let uniform_scratch = Vec::<f64>::load(r)?;
        if colors.len() != part.simels_per_proc()
            || probs.len() != colors.len() * cfg.n_colors as usize
            || chan_dirs.len() != channels.len()
        {
            return Err(SnapError::Corrupt("gc shard dims"));
        }
        Ok(Self {
            cfg,
            part,
            rank,
            channels,
            chan_dirs,
            colors,
            probs,
            ghosts,
            self_dirs,
            parity_off,
            uniform_scratch,
        })
    }
}

/// Exact global conflict count over all shards (the paper's solution-error
/// measure: "the number of graph color conflicts remaining at the end of
/// the benchmark", §II-B). Assembles the true global grid, so the result
/// is independent of any stale ghost state.
pub fn global_conflicts(topo: &Topology, shards: &[GraphColoringShard]) -> usize {
    let refs: Vec<&GraphColoringShard> = shards.iter().collect();
    global_conflicts_refs(topo, &refs)
}

/// [`global_conflicts`] over borrowed shards (for wrapper workloads that
/// own their inner shard, e.g. the HLO-backed path).
pub fn global_conflicts_refs(topo: &Topology, shards: &[&GraphColoringShard]) -> usize {
    assert_eq!(shards.len(), topo.n_procs());
    let part = shards[0].partition();
    let (gh, gw) = part.global_dims();
    let (_, mc) = topo.mesh_dims();
    // Assemble global grid.
    let mut grid = vec![0u8; gh * gw];
    for (rank, shard) in shards.iter().enumerate() {
        let (pr, pc) = (rank / mc, rank % mc);
        for r in 0..part.tile_h {
            for c in 0..part.tile_w {
                let gr = pr * part.tile_h + r;
                let gc = pc * part.tile_w + c;
                grid[gr * gw + gc] = shard.colors()[part.local_index(r, c)];
            }
        }
    }
    // Count vertices in conflict with any of their four torus neighbors.
    let mut conflicts = 0;
    for r in 0..gh {
        for c in 0..gw {
            let mine = grid[r * gw + c];
            let nbrs = [
                grid[((r + gh - 1) % gh) * gw + c],
                grid[r * gw + (c + 1) % gw],
                grid[((r + 1) % gh) * gw + c],
                grid[r * gw + (c + gw - 1) % gw],
            ];
            if nbrs.contains(&mine) {
                conflicts += 1;
            }
        }
    }
    conflicts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::PlacementKind;

    fn mk(
        n_procs: usize,
        simels: usize,
        seed: u64,
    ) -> (Topology, Vec<GraphColoringShard>, Xoshiro256) {
        let topo = Topology::new(n_procs, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(seed);
        let cfg = GcConfig {
            simels_per_proc: simels,
            ..GcConfig::default()
        };
        let shards: Vec<_> = (0..n_procs)
            .map(|r| GraphColoringShard::new(cfg, &topo, r, &mut rng))
            .collect();
        (topo, shards, rng)
    }

    /// Exchange every pooled message faithfully (perfect communication).
    fn exchange_perfect(topo: &Topology, shards: &mut [GraphColoringShard], rng: &mut Xoshiro256) {
        let n = shards.len();
        let mut out: Vec<Vec<(usize, GcMsg)>> = Vec::with_capacity(n);
        for shard in shards.iter_mut() {
            out.push(shard.step(rng));
        }
        for (rank, msgs) in out.into_iter().enumerate() {
            let specs = shards[rank].channels();
            for (ch, msg) in msgs {
                let spec = specs[ch];
                // Deliver to the peer's channel pointing back at `rank`
                // in the opposite direction.
                let peer_specs = shards[spec.peer].channels();
                let back_dir = Dir::ALL[spec.layer].opposite().index();
                let back_ch = peer_specs
                    .iter()
                    .position(|s| s.peer == rank && s.layer == back_dir)
                    .expect("reciprocal channel must exist");
                shards[spec.peer].absorb(back_ch, &mut vec![msg]);
                let _ = topo;
            }
        }
    }

    #[test]
    fn single_shard_converges_to_zero_conflicts() {
        let (topo, mut shards, mut rng) = mk(1, 64, 7);
        for _ in 0..600 {
            let _ = shards[0].step(&mut rng);
        }
        assert_eq!(
            global_conflicts(&topo, &shards),
            0,
            "8x8 torus with 3 colors must settle"
        );
    }

    #[test]
    fn multi_shard_converges_under_perfect_comm() {
        let (topo, mut shards, mut rng) = mk(4, 16, 11);
        for _ in 0..2000 {
            exchange_perfect(&topo, &mut shards, &mut rng);
        }
        assert_eq!(global_conflicts(&topo, &shards), 0);
    }

    #[test]
    fn conflicts_decrease_from_random_start() {
        let (topo, mut shards, mut rng) = mk(4, 256, 13);
        let before = global_conflicts(&topo, &shards);
        for _ in 0..200 {
            exchange_perfect(&topo, &mut shards, &mut rng);
        }
        let after = global_conflicts(&topo, &shards);
        assert!(
            after < before / 4,
            "conflicts should fall sharply: before={before} after={after}"
        );
    }

    #[test]
    fn tolerates_message_loss() {
        // Drop every message: shards still run and local state stays sane.
        let (topo, mut shards, mut rng) = mk(4, 16, 17);
        for _ in 0..100 {
            for shard in shards.iter_mut() {
                let _ = shard.step(&mut rng); // outputs discarded
            }
        }
        let total = global_conflicts(&topo, &shards);
        let max = 4 * 16;
        assert!(total <= max);
        // interiors still converge locally
        for shard in &shards {
            assert!(shard.quality() <= 16.0);
        }
    }

    #[test]
    fn stale_ghosts_are_replaced_by_latest() {
        let (_, mut shards, _) = mk(2, 1, 19);
        // two channels (E and W) to the peer for a 1x2 mesh
        let specs = shards[0].channels();
        assert_eq!(specs.len(), 2);
        shards[0].absorb(0, &mut vec![vec![0], vec![2]]);
        assert_eq!(shards[0].ghosts[shards[0].chan_dirs[0].index()], Some(vec![2]));
    }

    #[test]
    fn malformed_message_skipped() {
        let (_, mut shards, _) = mk(2, 1, 23);
        shards[0].absorb(0, &mut vec![vec![1, 2, 3]]); // wrong arity
        assert_eq!(shards[0].ghosts[shards[0].chan_dirs[0].index()], None);
    }

    #[test]
    fn probability_vectors_stay_normalized() {
        let (_, mut shards, mut rng) = mk(1, 64, 29);
        for _ in 0..50 {
            let _ = shards[0].step(&mut rng);
        }
        let k = shards[0].cfg.n_colors as usize;
        for v in 0..shards[0].part.simels_per_proc() {
            let s: f64 = shards[0].probs[v * k..(v + 1) * k].iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "v={v} sum={s}");
            assert!(shards[0].probs[v * k..(v + 1) * k].iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn step_cost_scales_with_simels() {
        let (_, shards_small, _) = mk(1, 1, 31);
        let (_, shards_big, _) = mk(1, 2048, 31);
        assert!(shards_big[0].step_cost_ns() > 100.0 * shards_small[0].step_cost_ns() / 4.0);
        assert!(shards_small[0].step_cost_ns() > 1_000.0);
    }

    #[test]
    fn shard_persist_round_trips_bitwise() {
        let (_, mut shards, mut rng) = mk(4, 16, 41);
        // Dirty the state: ghosts populated, probabilities mid-decay.
        for _ in 0..20 {
            exchange_perfect(&Topology::new(4, PlacementKind::OnePerNode), &mut shards, &mut rng);
        }
        for shard in &shards {
            let mut w = SnapWriter::new();
            shard.save(&mut w);
            let bytes = w.finish();
            let mut r = SnapReader::new(&bytes).unwrap();
            let back = GraphColoringShard::load(&mut r).unwrap();
            assert!(r.is_exhausted());
            assert_eq!(back.colors, shard.colors);
            assert_eq!(back.ghosts, shard.ghosts);
            assert_eq!(back.channels, shard.channels);
            assert_eq!(back.rank, shard.rank);
            let pa: Vec<u64> = shard.probs.iter().map(|p| p.to_bits()).collect();
            let pb: Vec<u64> = back.probs.iter().map(|p| p.to_bits()).collect();
            assert_eq!(pa, pb, "probability table must round-trip bitwise");
            // Re-serializing the loaded shard reproduces the bytes.
            let mut w2 = SnapWriter::new();
            back.save(&mut w2);
            assert_eq!(w2.finish(), bytes);
        }
    }

    #[test]
    fn channels_reciprocal_across_shards() {
        let (_, shards, _) = mk(16, 4, 37);
        for (rank, shard) in shards.iter().enumerate() {
            for spec in shard.channels() {
                let back_dir = Dir::ALL[spec.layer].opposite().index();
                let found = shards[spec.peer]
                    .channels()
                    .iter()
                    .any(|s| s.peer == rank && s.layer == back_dir);
                assert!(found, "rank={rank} spec={spec:?} lacks reciprocal");
            }
        }
    }
}
