//! Benchmark workloads: distributed graph coloring and digital evolution.
//!
//! Both workloads implement [`ShardWorkload`], the interface the
//! simulation executors ([`crate::sim`] and [`crate::exec`]) drive. A
//! *shard* is the slice of the global simulation owned by one process or
//! thread: a tile of graph vertices (graph coloring) or of cells (digital
//! evolution) on the global torus. All cross-shard interaction flows
//! through best-effort channels; the executor owns delivery, the workload
//! owns state.

pub mod dishtiny;
pub mod hlo;
pub mod graph_coloring;
pub mod partition;
pub mod workunit;

use crate::util::rng::Xoshiro256;

/// Description of one outgoing channel a shard wants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChannelSpec {
    /// Destination process rank.
    pub peer: usize,
    /// Workload-defined layer tag (e.g. digital evolution's five
    /// messaging layers); echoes back on [`ShardWorkload::absorb`].
    pub layer: usize,
}

/// A process-local slice of a distributed simulation.
///
/// Contract:
/// * `channels()` is stable for the lifetime of the shard and symmetric
///   across the job: if shard A requests a channel to peer B on layer L,
///   shard B requests one to A on L (the torus is reciprocal).
/// * `step()` advances exactly one simulation update and returns the
///   messages to dispatch, keyed by index into `channels()`.
/// * `absorb()` may be called any number of times (including zero) between
///   steps — messages are best-effort: duplicated cadences, reordering
///   across channels, and loss must all be tolerated.
pub trait ShardWorkload {
    /// Message payload exchanged between shards.
    type Msg: Clone;

    /// Outgoing channels this shard dispatches on.
    fn channels(&self) -> Vec<ChannelSpec>;

    /// Deliver pulled messages from channel `ch` (index into
    /// `channels()`), oldest first.
    ///
    /// The buffer is borrowed so executors can reuse one scratch
    /// allocation across every channel and simstep (the per-pull `Vec`
    /// churn was the top allocation in the DES hot loop). Implementations
    /// take ownership of the contents (typically via `drain(..)`); callers
    /// must treat the buffer's contents as unspecified afterwards and
    /// clear it before refilling.
    fn absorb(&mut self, ch: usize, msgs: &mut Vec<Self::Msg>);

    /// Advance one simulation update; returns `(channel index, message)`
    /// pairs to dispatch.
    fn step(&mut self, rng: &mut Xoshiro256) -> Vec<(usize, Self::Msg)>;

    /// Nominal single-update compute cost in nanoseconds (before node
    /// speed, contention, jitter, and added synthetic work). Used by the
    /// DES cost model; ignored by the real-thread executor.
    fn step_cost_ns(&self) -> f64;

    /// Current solution-quality figure. Graph coloring: local conflict
    /// count (lower better). Digital evolution: mean cell resource
    /// (higher better).
    fn quality(&self) -> f64;
}

/// Offset distinguishing digital-evolution layer tags from graph
/// coloring's bare direction tags (0..4). DE channels are tagged
/// `DE_LAYER_BASE + dir * 5 + kind`.
pub const DE_LAYER_BASE: usize = 100;

/// The reciprocal of a channel's layer tag: the tag of the peer's channel
/// pointing back at us (opposite direction, same layer kind). Executors
/// use this to wire directed channel pairs.
pub fn reciprocal_layer(layer: usize) -> usize {
    if layer < 4 {
        // Graph coloring: bare Dir index.
        (layer + 2) % 4
    } else {
        debug_assert!(layer >= DE_LAYER_BASE, "unknown layer tag {layer}");
        let l = layer - DE_LAYER_BASE;
        let dir = l / 5;
        let kind = l % 5;
        DE_LAYER_BASE + ((dir + 2) % 4) * 5 + kind
    }
}

/// Sorted flat CSR-style index over per-shard channel specs, shared by
/// the executors' reciprocal-channel wiring (`Engine::new` and
/// `exec::run_threads`): one `(peer, layer, spec idx)` entry per
/// directed spec in a single arena, grouped by source shard with each
/// group sorted, so a reciprocal lookup is a `partition_point` lower
/// bound — first-match semantics, no per-shard allocations, no hashing
/// (the per-shard `HashMap`s it replaced made construction the dominant
/// cost of short-run sweep cells at 1024–4096 procs).
pub struct SpecIndex {
    offsets: Vec<usize>,
    flat: Vec<(usize, usize, usize)>,
}

impl SpecIndex {
    pub fn build(specs: &[Vec<ChannelSpec>]) -> Self {
        let total: usize = specs.iter().map(|s| s.len()).sum();
        let mut offsets: Vec<usize> = Vec::with_capacity(specs.len() + 1);
        let mut flat: Vec<(usize, usize, usize)> = Vec::with_capacity(total);
        offsets.push(0);
        for specs_p in specs {
            let base = flat.len();
            for (i, s) in specs_p.iter().enumerate() {
                flat.push((s.peer, s.layer, i));
            }
            flat[base..].sort_unstable();
            offsets.push(flat.len());
        }
        Self { offsets, flat }
    }

    /// Smallest spec index of `shard`'s `(peer, layer)` run, if any —
    /// the same first-match semantics as a `HashMap` `or_insert` build
    /// or a forward `position()` scan.
    pub fn lookup(&self, shard: usize, peer: usize, layer: usize) -> Option<usize> {
        let group = &self.flat[self.offsets[shard]..self.offsets[shard + 1]];
        let at = group.partition_point(|&(p, l, _)| (p, l) < (peer, layer));
        match group.get(at) {
            Some(&(p, l, i)) if p == peer && l == layer => Some(i),
            _ => None,
        }
    }

    /// Globally unique id of `shard`'s channel `ch`: the flattened
    /// `(shard, ch)` position.
    pub fn flat_id(&self, shard: usize, ch: usize) -> usize {
        self.offsets[shard] + ch
    }
}

#[cfg(test)]
mod spec_index_tests {
    use super::*;

    #[test]
    fn lookup_matches_forward_position_scan() {
        // Duplicated (peer, layer) pairs must resolve to the FIRST spec
        // index, exactly like the scan the index replaced.
        let specs = vec![
            vec![
                ChannelSpec { peer: 1, layer: 0 },
                ChannelSpec { peer: 1, layer: 2 },
                ChannelSpec { peer: 1, layer: 0 },
                ChannelSpec { peer: 0, layer: 3 },
            ],
            vec![ChannelSpec { peer: 0, layer: 2 }],
            vec![],
        ];
        let idx = SpecIndex::build(&specs);
        for (shard, specs_p) in specs.iter().enumerate() {
            for &ChannelSpec { peer, layer } in specs_p {
                let want = specs_p
                    .iter()
                    .position(|s| s.peer == peer && s.layer == layer);
                assert_eq!(idx.lookup(shard, peer, layer), want);
            }
        }
        assert_eq!(idx.lookup(0, 2, 0), None);
        assert_eq!(idx.lookup(2, 0, 0), None);
        assert_eq!(idx.lookup(1, 0, 3), None, "layer must match exactly");
    }

    #[test]
    fn flat_ids_are_globally_unique_and_contiguous() {
        let specs = vec![
            vec![ChannelSpec { peer: 1, layer: 0 }, ChannelSpec { peer: 1, layer: 2 }],
            vec![ChannelSpec { peer: 0, layer: 2 }],
        ];
        let idx = SpecIndex::build(&specs);
        let ids: Vec<usize> = specs
            .iter()
            .enumerate()
            .flat_map(|(p, sp)| (0..sp.len()).map(move |c| idx.flat_id(p, c)))
            .collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }
}

#[cfg(test)]
mod layer_tests {
    use super::*;

    #[test]
    fn reciprocal_layer_is_an_involution() {
        for l in 0..4 {
            assert_eq!(reciprocal_layer(reciprocal_layer(l)), l);
        }
        for l in DE_LAYER_BASE..DE_LAYER_BASE + 20 {
            assert_eq!(reciprocal_layer(reciprocal_layer(l)), l);
        }
    }

    #[test]
    fn gc_and_de_tags_never_collide() {
        for l in 0..4 {
            assert!(reciprocal_layer(l) < 4);
        }
        for l in DE_LAYER_BASE..DE_LAYER_BASE + 20 {
            assert!(reciprocal_layer(l) >= DE_LAYER_BASE);
        }
    }
}

pub use graph_coloring::{GraphColoringShard, GcConfig, GcMsg};
pub use hlo::{HloDishtinyShard, HloGraphColoringShard};
pub use partition::TilePartition;
pub use workunit::WorkUnitSpinner;
