//! Synthetic compute work, in the paper's own unit.
//!
//! §III-C: "We used a call to the `std::mt19937` random number engine as a
//! unit of compute work. In microbenchmarks, we found that one work unit
//! consumed about 35 ns of walltime and 21 ns of compute time."
//!
//! The real-thread executor spins the actual Mersenne Twister; the DES
//! charges [`WORK_UNIT_WALL_NS`] of virtual time per unit.

use crate::util::rng::Mt19937;

/// Virtual walltime charged per work unit (paper-measured).
pub const WORK_UNIT_WALL_NS: f64 = 35.0;

/// Spins real mt19937 calls for the on-hardware executor.
pub struct WorkUnitSpinner {
    engine: Mt19937,
    /// Accumulator defeating dead-code elimination.
    sink: u32,
}

impl WorkUnitSpinner {
    pub fn new(seed: u32) -> Self {
        Self {
            engine: Mt19937::new(seed),
            sink: 0,
        }
    }

    /// Perform `units` work units; returns an opaque value derived from
    /// the engine stream (callers may ignore it — reading it prevents the
    /// optimizer from deleting the loop).
    #[inline]
    pub fn spin(&mut self, units: u64) -> u32 {
        for _ in 0..units {
            self.sink = self.sink.wrapping_add(self.engine.next_u32());
        }
        self.sink
    }

    /// Virtual walltime equivalent (ns) of `units` work units.
    pub fn virtual_cost_ns(units: u64) -> f64 {
        units as f64 * WORK_UNIT_WALL_NS
    }
}

/// The paper's §III-C sweep of added per-update work.
pub const PAPER_WORK_SWEEP: [u64; 5] = [0, 64, 4096, 262_144, 16_777_216];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spin_consumes_engine_stream() {
        let mut a = WorkUnitSpinner::new(5489);
        let mut b = WorkUnitSpinner::new(5489);
        let ra = a.spin(1000);
        let rb = b.spin(1000);
        assert_eq!(ra, rb, "deterministic");
        let rc = a.spin(1);
        assert_ne!(ra, rc, "stream advances");
    }

    #[test]
    fn virtual_cost_matches_paper_constant() {
        assert_eq!(WorkUnitSpinner::virtual_cost_ns(0), 0.0);
        assert_eq!(WorkUnitSpinner::virtual_cost_ns(1), 35.0);
        // Max sweep point: 16777216 * 35ns ~ 587 ms — the paper measures
        // mean simstep period 611 ms / median 507 ms there (SIII-C.1).
        let cost = WorkUnitSpinner::virtual_cost_ns(16_777_216);
        assert!((cost - 5.87e8).abs() / 5.87e8 < 0.01);
    }

    #[test]
    fn sweep_matches_paper() {
        assert_eq!(PAPER_WORK_SWEEP, [0, 64, 4096, 262_144, 16_777_216]);
    }
}
