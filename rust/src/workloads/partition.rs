//! Partitioning a global simulation torus into per-process tiles.
//!
//! The global simel grid (graph vertices or cells) is a torus of
//! `(mesh_rows * tile_h) x (mesh_cols * tile_w)` elements, split into one
//! `tile_h x tile_w` tile per process, arranged to match the process mesh
//! of [`crate::net::Topology`]. Border elements interact with elements in
//! the four adjacent tiles; interior elements interact only locally.

/// One process's tile of the global torus.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TilePartition {
    /// Process mesh dimensions.
    pub mesh_rows: usize,
    pub mesh_cols: usize,
    /// Tile dimensions (simels per process = tile_h * tile_w).
    pub tile_h: usize,
    pub tile_w: usize,
}

impl TilePartition {
    /// Build a partition hosting `simels_per_proc` elements per process
    /// on a `mesh_rows x mesh_cols` process mesh. The tile is the most
    /// square factorization.
    pub fn new(mesh_rows: usize, mesh_cols: usize, simels_per_proc: usize) -> Self {
        let (tile_h, tile_w) = crate::net::topology::squarest_factors(simels_per_proc.max(1));
        Self {
            mesh_rows,
            mesh_cols,
            tile_h,
            tile_w,
        }
    }

    pub fn simels_per_proc(&self) -> usize {
        self.tile_h * self.tile_w
    }

    pub fn global_dims(&self) -> (usize, usize) {
        (self.mesh_rows * self.tile_h, self.mesh_cols * self.tile_w)
    }

    pub fn total_simels(&self) -> usize {
        let (h, w) = self.global_dims();
        h * w
    }

    /// Local index of tile cell (r, c), row-major.
    pub fn local_index(&self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.tile_h && c < self.tile_w);
        r * self.tile_w + c
    }

    /// Is a local element on the northern border (interacts with the tile
    /// above)? Similarly east/south/west. On degenerate tiles (height or
    /// width 1) an element can be on two opposite borders at once.
    pub fn on_border(&self, r: usize, c: usize, dir: Dir) -> bool {
        match dir {
            Dir::North => r == 0,
            Dir::East => c == self.tile_w - 1,
            Dir::South => r == self.tile_h - 1,
            Dir::West => c == 0,
        }
    }

    /// Border length (number of simels pooled per message) toward `dir`.
    pub fn border_len(&self, dir: Dir) -> usize {
        match dir {
            Dir::North | Dir::South => self.tile_w,
            Dir::East | Dir::West => self.tile_h,
        }
    }

    /// Local indices along the `dir` border, in pooling order (west→east
    /// for horizontal borders, north→south for vertical borders).
    pub fn border_indices(&self, dir: Dir) -> Vec<usize> {
        match dir {
            Dir::North => (0..self.tile_w).map(|c| self.local_index(0, c)).collect(),
            Dir::South => (0..self.tile_w)
                .map(|c| self.local_index(self.tile_h - 1, c))
                .collect(),
            Dir::West => (0..self.tile_h).map(|r| self.local_index(r, 0)).collect(),
            Dir::East => (0..self.tile_h)
                .map(|r| self.local_index(r, self.tile_w - 1))
                .collect(),
        }
    }
}

/// Cardinal direction toward a neighboring tile. Order matches
/// [`crate::net::Topology::neighbors4`]: N, E, S, W.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    North = 0,
    East = 1,
    South = 2,
    West = 3,
}

impl Dir {
    pub const ALL: [Dir; 4] = [Dir::North, Dir::East, Dir::South, Dir::West];

    /// The direction pointing back at us from the neighbor's perspective.
    pub fn opposite(self) -> Dir {
        match self {
            Dir::North => Dir::South,
            Dir::East => Dir::West,
            Dir::South => Dir::North,
            Dir::West => Dir::East,
        }
    }

    pub fn index(self) -> usize {
        self as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{forall, prop_assert, Config};

    #[test]
    fn partition_dims() {
        let p = TilePartition::new(8, 8, 2048);
        assert_eq!((p.tile_h, p.tile_w), (32, 64));
        assert_eq!(p.simels_per_proc(), 2048);
        assert_eq!(p.global_dims(), (256, 512));
        assert_eq!(p.total_simels(), 64 * 2048);
    }

    #[test]
    fn single_simel_tile() {
        let p = TilePartition::new(1, 2, 1);
        assert_eq!(p.simels_per_proc(), 1);
        // the lone element is on every border
        for d in Dir::ALL {
            assert!(p.on_border(0, 0, d));
            assert_eq!(p.border_len(d), 1);
            assert_eq!(p.border_indices(d), vec![0]);
        }
    }

    #[test]
    fn border_indices_cover_borders() {
        let p = TilePartition::new(2, 2, 12); // 3x4 tile
        assert_eq!(p.border_indices(Dir::North), vec![0, 1, 2, 3]);
        assert_eq!(p.border_indices(Dir::South), vec![8, 9, 10, 11]);
        assert_eq!(p.border_indices(Dir::West), vec![0, 4, 8]);
        assert_eq!(p.border_indices(Dir::East), vec![3, 7, 11]);
    }

    #[test]
    fn opposite_directions() {
        for d in Dir::ALL {
            assert_eq!(d.opposite().opposite(), d);
        }
        assert_eq!(Dir::North.opposite(), Dir::South);
        assert_eq!(Dir::East.opposite(), Dir::West);
    }

    #[test]
    fn prop_border_lengths_match_between_neighbors() {
        // A tile's border toward dir must have the same length as the
        // neighbor's border back toward us — pooled messages align.
        forall(Config::default().cases(64), |g| {
            let simels = g.usize_in(1, 4096);
            let p = TilePartition::new(4, 4, simels);
            for d in Dir::ALL {
                prop_assert(
                    p.border_len(d) == p.border_len(d.opposite()),
                    format!("simels={simels} dir={d:?}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_local_indices_unique_and_in_range() {
        forall(Config::default().cases(32), |g| {
            let simels = g.usize_in(1, 1024);
            let p = TilePartition::new(2, 2, simels);
            let mut seen = vec![false; p.simels_per_proc()];
            for r in 0..p.tile_h {
                for c in 0..p.tile_w {
                    let i = p.local_index(r, c);
                    prop_assert(i < seen.len(), "index out of range")?;
                    prop_assert(!seen[i], "duplicate index")?;
                    seen[i] = true;
                }
            }
            prop_assert(seen.iter().all(|&s| s), "not all indices covered")
        });
    }
}
