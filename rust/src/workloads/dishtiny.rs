//! DISHTINY-style digital evolution: the compute-intensive benchmark.
//!
//! A faithful-in-profile stand-in for the paper's digital evolution
//! workload (§II-A): a toroidal grid of evolving digital cells, 3600 per
//! process in the benchmark configuration, with *all* cell-cell
//! interaction mediated by best-effort channels across the five messaging
//! layers the paper enumerates — same cadences, payload shapes, and
//! transfer strategies:
//!
//! | layer | cadence | payload | transfer |
//! |---|---|---|---|
//! | cell spawn | every 16 updates | arbitrary-length genomes (seeded 100 units, cap 1000) | aggregation |
//! | resource transfer | every update | 4-byte float | pooling |
//! | cell-cell communication | every 16 updates | arbitrarily many 20-byte packets | aggregation |
//! | environmental state | every 8 updates | 216-byte struct | pooling |
//! | kin-group size detection | every update | 16-byte bitstring | pooling |
//!
//! Cell behaviour (genome evaluation) is a weight-vector-driven state
//! update — the compute hot-spot that the L1 Pallas kernel
//! (`python/compile/kernels/cell_update.py`) implements for the HLO-backed
//! path; the native path here computes the same recurrence in scalar Rust
//! (equivalence is tested in `rust/tests/integration_runtime.rs`).

use super::partition::{Dir, TilePartition};
use super::{ChannelSpec, ShardWorkload};
use crate::net::Topology;
use crate::util::rng::{Rng, Xoshiro256};

/// Dimension of each cell's internal state vector.
pub const STATE_DIM: usize = 8;
/// Genome seed length (paper: "seeded at 100 12-byte instructions").
pub const GENOME_SEED_LEN: usize = 100;
/// Genome hard cap (paper: "hard cap of 1000 instructions").
pub const GENOME_CAP: usize = 1000;

/// Evolvable genome: a variable-length weight program, interpreted in
/// fixed-size windows to parameterize the cell state recurrence.
#[derive(Clone, Debug, PartialEq)]
pub struct Genome {
    pub weights: Vec<f32>,
    pub kin_id: u64,
    pub generation: u32,
}

impl Genome {
    pub fn random(rng: &mut Xoshiro256) -> Self {
        Self {
            weights: (0..GENOME_SEED_LEN)
                .map(|_| rng.normal(0.0, 0.5) as f32)
                .collect(),
            kin_id: rng.next_u64(),
            generation: 0,
        }
    }

    /// Mutated offspring: point perturbations plus rare insertions and
    /// deletions (bounded by [`GENOME_CAP`]); kin id usually inherited.
    pub fn offspring(&self, rng: &mut Xoshiro256) -> Self {
        let mut weights = self.weights.clone();
        for w in weights.iter_mut() {
            if rng.chance(0.02) {
                *w += rng.normal(0.0, 0.3) as f32;
            }
        }
        if rng.chance(0.05) && weights.len() < GENOME_CAP {
            let at = rng.index(weights.len() + 1);
            weights.insert(at, rng.normal(0.0, 0.5) as f32);
        }
        if rng.chance(0.05) && weights.len() > 8 {
            let at = rng.index(weights.len());
            weights.remove(at);
        }
        Self {
            weights,
            // Kin-group fission: occasionally found a new group.
            kin_id: if rng.chance(0.05) {
                rng.next_u64()
            } else {
                self.kin_id
            },
            generation: self.generation.saturating_add(1),
        }
    }

    /// Effective recurrence weights: the genome folded into
    /// `STATE_DIM * 2` coefficients (gain and bias per state channel).
    pub fn coefficients(&self) -> [f32; STATE_DIM * 2] {
        let mut coef = [0.0f32; STATE_DIM * 2];
        for (i, &w) in self.weights.iter().enumerate() {
            coef[i % (STATE_DIM * 2)] += w;
        }
        let norm = (self.weights.len() as f32 / (STATE_DIM * 2) as f32).max(1.0);
        for c in coef.iter_mut() {
            *c /= norm;
        }
        coef
    }
}

/// One digital cell.
#[derive(Clone, Debug)]
pub struct Cell {
    pub genome: Genome,
    pub state: [f32; STATE_DIM],
    pub resource: f32,
}

impl Cell {
    fn new(genome: Genome) -> Self {
        Self {
            genome,
            state: [0.0; STATE_DIM],
            resource: 0.0,
        }
    }
}

/// Environmental state summary pooled across borders every 8 updates
/// (stands in for the paper's 216-byte struct: 54 f32 fields).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnvState {
    pub resource: f32,
    pub state0: f32,
    pub kin_low: u32,
}

/// 20-byte cell-cell communication packet.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Packet {
    /// Border slot of the addressee on the receiving side.
    pub slot: u32,
    pub payload: [f32; 4],
}

/// Spawn message: a genome aimed at a border slot on the receiving side.
#[derive(Clone, Debug)]
pub struct SpawnMsg {
    pub slot: u32,
    pub genome: Genome,
    pub endowment: f32,
}

/// Digital-evolution inter-shard message (one variant per paper layer).
#[derive(Clone, Debug)]
pub enum DeMsg {
    /// Pooled border resource outflows (every update).
    Resource(Vec<f32>),
    /// Pooled border kin ids (every update).
    Kin(Vec<u64>),
    /// Pooled border environment summaries (every 8 updates).
    Env(Vec<EnvState>),
    /// Aggregated cell-cell packets (every 16 updates).
    CellCell(Vec<Packet>),
    /// Aggregated spawn genomes (every 16 updates).
    Spawn(Vec<SpawnMsg>),
}

/// Message-layer kinds, with their paper cadences.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layer {
    Resource = 0,
    Kin = 1,
    Env = 2,
    CellCell = 3,
    Spawn = 4,
}

impl Layer {
    pub const ALL: [Layer; 5] = [
        Layer::Resource,
        Layer::Kin,
        Layer::Env,
        Layer::CellCell,
        Layer::Spawn,
    ];

    /// Updates between dispatches on this layer (paper §II-A).
    pub fn cadence(self) -> u64 {
        match self {
            Layer::Resource | Layer::Kin => 1,
            Layer::Env => 8,
            Layer::CellCell | Layer::Spawn => 16,
        }
    }
}

/// Digital-evolution benchmark parameters.
#[derive(Clone, Copy, Debug)]
pub struct DeConfig {
    /// Cells per process (paper: 3600).
    pub cells_per_proc: usize,
    /// Base resource inflow per cell-update.
    pub resource_inflow: f32,
    /// Fraction of resource shared to each neighbor per update.
    pub share_rate: f32,
    /// Resource threshold to attempt reproduction.
    pub spawn_threshold: f32,
    /// Nominal per-cell per-update compute cost (ns) for the DES model.
    pub per_cell_cost_ns: f64,
    pub base_cost_ns: f64,
}

impl Default for DeConfig {
    fn default() -> Self {
        Self {
            cells_per_proc: 3600,
            resource_inflow: 0.05,
            share_rate: 0.05,
            spawn_threshold: 1.0,
            // 3600 cells/update at ~900ns/cell -> ~3.2ms/update: a
            // compute-heavy profile, matching the paper's description of
            // the digital evolution benchmark as far more computationally
            // intensive than the ~10-100us graph-coloring updates.
            per_cell_cost_ns: 900.0,
            base_cost_ns: 12_000.0,
        }
    }
}

/// One process's tile of the digital-evolution world.
pub struct DishtinyShard {
    cfg: DeConfig,
    part: TilePartition,
    rank: usize,
    channels: Vec<ChannelSpec>,
    /// (direction, layer) for each channel, parallel to `channels`.
    chan_meta: Vec<(Dir, Layer)>,
    self_dirs: [bool; 4],
    cells: Vec<Cell>,
    update: u64,
    /// Ghost data per direction.
    ghost_resource: [Option<Vec<f32>>; 4],
    ghost_kin: [Option<Vec<u64>>; 4],
    ghost_env: [Option<Vec<EnvState>>; 4],
    /// Pending inbound packets / spawns addressed to border slots.
    inbox_packets: Vec<(Dir, Packet)>,
    inbox_spawns: Vec<(Dir, SpawnMsg)>,
    /// Cumulative births (evolutionary activity indicator).
    births: u64,
}

impl DishtinyShard {
    pub fn new(cfg: DeConfig, topo: &Topology, rank: usize, rng: &mut Xoshiro256) -> Self {
        let (mr, mc) = topo.mesh_dims();
        let part = TilePartition::new(mr, mc, cfg.cells_per_proc);
        let neighbors = topo.neighbors4(rank);

        let mut channels = Vec::new();
        let mut chan_meta = Vec::new();
        let mut self_dirs = [false; 4];
        for d in Dir::ALL {
            let peer = neighbors[d.index()];
            if peer == rank {
                self_dirs[d.index()] = true;
                continue;
            }
            for layer in Layer::ALL {
                channels.push(ChannelSpec {
                    peer,
                    layer: super::DE_LAYER_BASE + d.index() * Layer::ALL.len() + layer as usize,
                });
                chan_meta.push((d, layer));
            }
        }

        let cells = (0..part.simels_per_proc())
            .map(|_| Cell::new(Genome::random(rng)))
            .collect();

        Self {
            cfg,
            part,
            rank,
            channels,
            chan_meta,
            self_dirs,
            cells,
            update: 0,
            ghost_resource: [None, None, None, None],
            ghost_kin: [None, None, None, None],
            ghost_env: [None, None, None, None],
            inbox_packets: Vec::new(),
            inbox_spawns: Vec::new(),
            births: 0,
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn partition(&self) -> &TilePartition {
        &self.part
    }

    pub fn cells(&self) -> &[Cell] {
        &self.cells
    }

    pub fn births(&self) -> u64 {
        self.births
    }

    pub fn update_count(&self) -> u64 {
        self.update
    }

    /// Mean resource across cells (the benchmark's quality signal).
    pub fn mean_resource(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        self.cells.iter().map(|c| c.resource as f64).sum::<f64>() / self.cells.len() as f64
    }

    /// Number of distinct kin groups on this shard.
    pub fn kin_group_count(&self) -> usize {
        let mut ids: Vec<u64> = self.cells.iter().map(|c| c.genome.kin_id).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }

    fn local_neighbor_mean(&self, r: usize, c: usize) -> [f32; STATE_DIM] {
        let mut acc = [0.0f32; STATE_DIM];
        let mut n = 0.0f32;
        for d in Dir::ALL {
            let (th, tw) = (self.part.tile_h, self.part.tile_w);
            let nbr = match d {
                Dir::North if r > 0 => Some(self.part.local_index(r - 1, c)),
                Dir::South if r < th - 1 => Some(self.part.local_index(r + 1, c)),
                Dir::West if c > 0 => Some(self.part.local_index(r, c - 1)),
                Dir::East if c < tw - 1 => Some(self.part.local_index(r, c + 1)),
                _ if self.self_dirs[d.index()] => Some(match d {
                    Dir::North => self.part.local_index(th - 1, c),
                    Dir::South => self.part.local_index(0, c),
                    Dir::West => self.part.local_index(r, tw - 1),
                    Dir::East => self.part.local_index(r, 0),
                }),
                _ => None, // cross-border: covered by env ghosts below
            };
            if let Some(i) = nbr {
                for k in 0..STATE_DIM {
                    acc[k] += self.cells[i].state[k];
                }
                n += 1.0;
            } else if let Some(env) = &self.ghost_env[d.index()] {
                let off = match d {
                    Dir::North | Dir::South => c,
                    Dir::East | Dir::West => r,
                };
                if off < env.len() {
                    acc[0] += env[off].state0;
                    n += 1.0;
                }
            }
        }
        if n > 0.0 {
            for k in 0..STATE_DIM {
                acc[k] /= n;
            }
        }
        acc
    }

    fn apply_inbox(&mut self) {
        // Cell-cell packets: payload folds into the addressee's state.
        for (dir, pkt) in std::mem::take(&mut self.inbox_packets) {
            let border = self.part.border_indices(dir);
            if let Some(&idx) = border.get(pkt.slot as usize) {
                for (k, &v) in pkt.payload.iter().enumerate() {
                    self.cells[idx].state[k % STATE_DIM] += v * 0.1;
                }
            }
        }
        // Spawns: replace the border cell iff the incomer's endowment
        // beats the residents's resource (antagonistic competition for
        // limited space, paper §II-A).
        for (dir, spawn) in std::mem::take(&mut self.inbox_spawns) {
            let border = self.part.border_indices(dir);
            if let Some(&idx) = border.get(spawn.slot as usize) {
                if spawn.endowment > self.cells[idx].resource {
                    self.cells[idx] = Cell::new(spawn.genome);
                    self.cells[idx].resource = spawn.endowment;
                    self.births += 1;
                }
            }
        }
        // Pooled resource inflows along borders.
        for d in Dir::ALL {
            if let Some(inflow) = self.ghost_resource[d.index()].take() {
                let border = self.part.border_indices(d);
                for (off, &idx) in border.iter().enumerate() {
                    if let Some(&v) = inflow.get(off) {
                        self.cells[idx].resource += v;
                    }
                }
            }
        }
    }

    fn spawn_locally(&mut self, rng: &mut Xoshiro256) -> Vec<(Dir, SpawnMsg)> {
        let mut outgoing = Vec::new();
        let (th, tw) = (self.part.tile_h, self.part.tile_w);
        for r in 0..th {
            for c in 0..tw {
                let v = self.part.local_index(r, c);
                if self.cells[v].resource < self.cfg.spawn_threshold {
                    continue;
                }
                let endowment = self.cells[v].resource * 0.5;
                let genome = self.cells[v].genome.offspring(rng);
                self.cells[v].resource -= endowment;
                // Choose a random direction to spawn into.
                let d = Dir::ALL[rng.index(4)];
                let crosses = self.part.on_border(r, c, d) && !self.self_dirs[d.index()];
                if crosses {
                    let slot = match d {
                        Dir::North | Dir::South => c,
                        Dir::East | Dir::West => r,
                    } as u32;
                    outgoing.push((
                        d,
                        SpawnMsg {
                            slot,
                            genome,
                            endowment,
                        },
                    ));
                } else {
                    // Local (or torus-wrapped local) target.
                    let (tr, tc) = match d {
                        Dir::North => ((r + th - 1) % th, c),
                        Dir::South => ((r + 1) % th, c),
                        Dir::West => (r, (c + tw - 1) % tw),
                        Dir::East => (r, (c + 1) % tw),
                    };
                    let t = self.part.local_index(tr, tc);
                    // Spawning into limited space is competitive: the
                    // offspring displaces the resident iff its endowment
                    // beats the resident's banked resource. Same-kin
                    // residents yield at a discount (kin-group
                    // cooperation: parents propagate through their own
                    // group more easily; the kin layer communicates group
                    // ids across borders for the same purpose).
                    let resident = &self.cells[t];
                    let bar = if resident.genome.kin_id == self.cells[v].genome.kin_id {
                        resident.resource * 0.5
                    } else {
                        resident.resource
                    };
                    if endowment > bar {
                        self.cells[t] = Cell::new(genome);
                        self.cells[t].resource = endowment;
                        self.births += 1;
                    }
                }
            }
        }
        outgoing
    }

    /// Flatten per-cell evaluation inputs (row-major tile order).
    fn gather_eval_inputs(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let n = self.cells.len();
        let mut states = Vec::with_capacity(n * STATE_DIM);
        let mut coefs = Vec::with_capacity(n * STATE_DIM * 2);
        let mut nbrs = Vec::with_capacity(n * STATE_DIM);
        let mut resources = Vec::with_capacity(n);
        for r in 0..self.part.tile_h {
            for c in 0..self.part.tile_w {
                let v = self.part.local_index(r, c);
                states.extend_from_slice(&self.cells[v].state);
                coefs.extend_from_slice(&self.cells[v].genome.coefficients());
                nbrs.extend_from_slice(&self.local_neighbor_mean(r, c));
                resources.push(self.cells[v].resource);
            }
        }
        (states, coefs, nbrs, resources)
    }

    /// Write back evaluation outputs.
    fn apply_eval_outputs(&mut self, new_states: &[f32], new_resources: &[f32]) {
        let n = self.cells.len();
        assert_eq!(new_states.len(), n * STATE_DIM);
        assert_eq!(new_resources.len(), n);
        for (v, cell) in self.cells.iter_mut().enumerate() {
            cell.state
                .copy_from_slice(&new_states[v * STATE_DIM..(v + 1) * STATE_DIM]);
            cell.resource = new_resources[v];
        }
    }

    /// One simstep with a pluggable genome-evaluation phase.
    ///
    /// `eval` receives flat row-major arrays — states `f32[N*D]`,
    /// coefficients `f32[N*2D]`, neighbor means `f32[N*D]`, resources
    /// `f32[N]` — plus the inflow rate, and returns `(new_states,
    /// new_resources)`. The native path uses [`native_eval`]; the
    /// HLO-backed path substitutes the AOT-compiled Pallas kernel
    /// (`cell_update`), which computes the identical recurrence.
    pub fn step_with<F>(&mut self, rng: &mut Xoshiro256, eval: F) -> Vec<(usize, DeMsg)>
    where
        F: FnOnce(&[f32], &[f32], &[f32], &[f32], f32) -> (Vec<f32>, Vec<f32>),
    {
        self.apply_inbox();

        // Genome evaluation + resource dynamics for every cell.
        let (states, coefs, nbrs, resources) = self.gather_eval_inputs();
        let (new_states, new_resources) =
            eval(&states, &coefs, &nbrs, &resources, self.cfg.resource_inflow);
        self.apply_eval_outputs(&new_states, &new_resources);

        let mut out: Vec<(usize, DeMsg)> = Vec::new();
        let share = self.cfg.share_rate;

        // Resource layer (every update): pooled border outflows.
        for (ch, &(d, layer)) in self.chan_meta.iter().enumerate() {
            if layer != Layer::Resource {
                continue;
            }
            let border = self.part.border_indices(d);
            let mut pool = Vec::with_capacity(border.len());
            for &idx in &border {
                let outflow = self.cells[idx].resource * share;
                self.cells[idx].resource -= outflow;
                pool.push(outflow);
            }
            out.push((ch, DeMsg::Resource(pool)));
        }

        // Kin layer (every update): pooled border kin ids.
        for (ch, &(d, layer)) in self.chan_meta.iter().enumerate() {
            if layer != Layer::Kin {
                continue;
            }
            let pool = self
                .part
                .border_indices(d)
                .into_iter()
                .map(|i| self.cells[i].genome.kin_id)
                .collect();
            out.push((ch, DeMsg::Kin(pool)));
        }

        // Env layer (every 8 updates).
        if self.update % Layer::Env.cadence() == 0 {
            for (ch, &(d, layer)) in self.chan_meta.iter().enumerate() {
                if layer != Layer::Env {
                    continue;
                }
                let pool = self
                    .part
                    .border_indices(d)
                    .into_iter()
                    .map(|i| EnvState {
                        resource: self.cells[i].resource,
                        state0: self.cells[i].state[0],
                        kin_low: self.cells[i].genome.kin_id as u32,
                    })
                    .collect();
                out.push((ch, DeMsg::Env(pool)));
            }
        }

        // Cell-cell packets (every 16 updates): border cells signal their
        // cross-border neighbor with a state digest.
        if self.update % Layer::CellCell.cadence() == 0 {
            for (ch, &(d, layer)) in self.chan_meta.iter().enumerate() {
                if layer != Layer::CellCell {
                    continue;
                }
                let border = self.part.border_indices(d);
                let pkts: Vec<Packet> = border
                    .iter()
                    .enumerate()
                    .filter(|(_, &idx)| self.cells[idx].state[0] > 0.0)
                    .map(|(slot, &idx)| Packet {
                        slot: slot as u32,
                        payload: [
                            self.cells[idx].state[0],
                            self.cells[idx].state[1],
                            self.cells[idx].state[2],
                            self.cells[idx].state[3],
                        ],
                    })
                    .collect();
                out.push((ch, DeMsg::CellCell(pkts)));
            }
        }

        // Spawn layer (every 16 updates): reproduction, local + remote.
        if self.update % Layer::Spawn.cadence() == 0 {
            let outgoing = self.spawn_locally(rng);
            for (ch, &(d, layer)) in self.chan_meta.iter().enumerate() {
                if layer != Layer::Spawn {
                    continue;
                }
                let batch: Vec<SpawnMsg> = outgoing
                    .iter()
                    .filter(|(sd, _)| *sd == d)
                    .map(|(_, s)| s.clone())
                    .collect();
                out.push((ch, DeMsg::Spawn(batch)));
            }
        }

        self.update += 1;
        out
    }

}

impl ShardWorkload for DishtinyShard {
    type Msg = DeMsg;

    fn channels(&self) -> Vec<ChannelSpec> {
        self.channels.clone()
    }

    fn absorb(&mut self, ch: usize, msgs: &mut Vec<DeMsg>) {
        let (dir, layer) = self.chan_meta[ch];
        for msg in msgs.drain(..) {
            match (layer, msg) {
                (Layer::Resource, DeMsg::Resource(v)) => {
                    // Accumulate: every delivered transfer counts.
                    let entry = self.ghost_resource[dir.index()].get_or_insert_with(Vec::new);
                    if entry.len() < v.len() {
                        entry.resize(v.len(), 0.0);
                    }
                    for (a, b) in entry.iter_mut().zip(v) {
                        *a += b;
                    }
                }
                (Layer::Kin, DeMsg::Kin(v)) => self.ghost_kin[dir.index()] = Some(v),
                (Layer::Env, DeMsg::Env(v)) => self.ghost_env[dir.index()] = Some(v),
                (Layer::CellCell, DeMsg::CellCell(pkts)) => {
                    self.inbox_packets.extend(pkts.into_iter().map(|p| (dir, p)));
                }
                (Layer::Spawn, DeMsg::Spawn(spawns)) => {
                    self.inbox_spawns.extend(spawns.into_iter().map(|s| (dir, s)));
                }
                // Layer/payload mismatch: foreign message, skip.
                _ => {}
            }
        }
    }

    fn step(&mut self, rng: &mut Xoshiro256) -> Vec<(usize, DeMsg)> {
        self.step_with(rng, native_eval)
    }

    fn step_cost_ns(&self) -> f64 {
        self.cfg.base_cost_ns + self.cfg.per_cell_cost_ns * self.cells.len() as f64
    }

    fn quality(&self) -> f64 {
        self.mean_resource()
    }
}


/// The native genome-evaluation phase: scalar Rust mirror of the
/// `cell_update` Pallas kernel (see `python/compile/kernels/cell_update.py`
/// and the equivalence test in `rust/tests/integration_runtime.rs`).
pub fn native_eval(
    states: &[f32],
    coefs: &[f32],
    nbrs: &[f32],
    resources: &[f32],
    inflow: f32,
) -> (Vec<f32>, Vec<f32>) {
    let n = resources.len();
    let mut new_states = vec![0.0f32; n * STATE_DIM];
    let mut new_resources = vec![0.0f32; n];
    for v in 0..n {
        let s = &states[v * STATE_DIM..(v + 1) * STATE_DIM];
        let coef = &coefs[v * STATE_DIM * 2..(v + 1) * STATE_DIM * 2];
        let nbr = &nbrs[v * STATE_DIM..(v + 1) * STATE_DIM];
        for i in 0..STATE_DIM {
            let gain = coef[i];
            let bias = coef[STATE_DIM + i];
            new_states[v * STATE_DIM + i] = (gain * (s[i] + nbr[i]) + bias).tanh();
        }
        // Harvest efficiency is a bounded function of the leading state
        // channel - evolution tunes the genome to maximize it.
        let harvest = 0.5 * (1.0 + new_states[v * STATE_DIM]);
        new_resources[v] = resources[v] + inflow * harvest;
    }
    (new_states, new_resources)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::PlacementKind;

    fn mk(n_procs: usize, cells: usize, seed: u64) -> (Topology, Vec<DishtinyShard>, Xoshiro256) {
        let topo = Topology::new(n_procs, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(seed);
        let cfg = DeConfig {
            cells_per_proc: cells,
            ..DeConfig::default()
        };
        let shards: Vec<_> = (0..n_procs)
            .map(|r| DishtinyShard::new(cfg, &topo, r, &mut rng))
            .collect();
        (topo, shards, rng)
    }

    #[test]
    fn five_layers_per_cross_border_direction() {
        let (_, shards, _) = mk(4, 36, 1);
        // 2x2 mesh: all four directions cross borders -> 4*5 channels.
        assert_eq!(shards[0].channels().len(), 20);
    }

    #[test]
    fn resource_accumulates_over_updates() {
        let (_, mut shards, mut rng) = mk(1, 36, 2);
        let before = shards[0].mean_resource();
        for _ in 0..50 {
            let _ = shards[0].step(&mut rng);
        }
        assert!(shards[0].mean_resource() > before);
    }

    #[test]
    fn evolution_increases_harvest_capacity() {
        // Selection acts on harvest efficiency, which is a monotone
        // function of the leading state channel: mean state[0] must climb
        // as fitter genomes spread. (Mean *resource* is not monotone —
        // failed-reproduction endowments are a resource sink.)
        let (_, mut shards, mut rng) = mk(1, 100, 3);
        let mean_s0 = |s: &DishtinyShard| {
            s.cells().iter().map(|c| c.state[0] as f64).sum::<f64>() / s.cells().len() as f64
        };
        for _ in 0..200 {
            let _ = shards[0].step(&mut rng);
        }
        let early = mean_s0(&shards[0]);
        for _ in 0..1000 {
            let _ = shards[0].step(&mut rng);
        }
        let late = mean_s0(&shards[0]);
        assert!(
            late > early + 0.1,
            "selection should raise harvest capacity: early={early} late={late}"
        );
        assert!(shards[0].births() > 100, "reproduction must be ongoing");
        assert!(shards[0].mean_resource() > 0.5);
    }

    /// Deliver every message between two shards faithfully (perfect
    /// communication), one update at a time.
    fn exchange_pair(shards: &mut [DishtinyShard], rng: &mut Xoshiro256) {
        let out0 = shards[0].step(rng);
        let out1 = shards[1].step(rng);
        for (src, out) in [(0usize, out0), (1usize, out1)] {
            let dst = 1 - src;
            for (ch, msg) in out {
                let (dir, layer) = shards[src].chan_meta[ch];
                let back = shards[dst]
                    .chan_meta
                    .iter()
                    .position(|&(d, l)| d == dir.opposite() && l == layer)
                    .expect("reciprocal channel");
                shards[dst].absorb(back, &mut vec![msg]);
            }
        }
    }

    #[test]
    fn spawn_messages_cross_borders() {
        // Border cells continuously share resource outward, so cross-
        // border spawning only occurs when the reciprocal inflows are
        // actually delivered — run both shards with full exchange.
        let (_, mut shards, mut rng) = mk(2, 16, 4);
        let mut cross_spawn_msgs = 0usize;
        for _ in 0..600 {
            // Count non-empty spawn batches leaving shard 0 this update.
            let out0 = shards[0].step(&mut rng);
            for (ch, msg) in &out0 {
                if let DeMsg::Spawn(batch) = msg {
                    if !batch.is_empty() {
                        cross_spawn_msgs += batch.len();
                    }
                }
                let _ = ch;
            }
            // Deliver shard 0 -> 1.
            for (ch, msg) in out0 {
                let (dir, layer) = shards[0].chan_meta[ch];
                let back = shards[1]
                    .chan_meta
                    .iter()
                    .position(|&(d, l)| d == dir.opposite() && l == layer)
                    .unwrap();
                shards[1].absorb(back, &mut vec![msg]);
            }
            // Step + deliver shard 1 -> 0.
            let out1 = shards[1].step(&mut rng);
            for (ch, msg) in out1 {
                let (dir, layer) = shards[1].chan_meta[ch];
                let back = shards[0]
                    .chan_meta
                    .iter()
                    .position(|&(d, l)| d == dir.opposite() && l == layer)
                    .unwrap();
                shards[0].absorb(back, &mut vec![msg]);
            }
        }
        assert!(
            cross_spawn_msgs > 0,
            "cross-border spawns should occur under full exchange"
        );
        let _ = exchange_pair; // helper retained for other tests
    }

    #[test]
    fn cross_border_spawn_respects_endowment_competition() {
        let (_, mut shards, mut rng) = mk(2, 1, 5);
        let strong = SpawnMsg {
            slot: 0,
            genome: Genome::random(&mut rng),
            endowment: 100.0,
        };
        let kin = strong.genome.kin_id;
        // find a spawn channel on shard 1
        let ch = shards[1]
            .chan_meta
            .iter()
            .position(|&(_, l)| l == Layer::Spawn)
            .unwrap();
        shards[1].absorb(ch, &mut vec![DeMsg::Spawn(vec![strong])]);
        let _ = shards[1].step(&mut rng);
        assert_eq!(shards[1].cells()[0].genome.kin_id, kin, "invader wins");

        let weak = SpawnMsg {
            slot: 0,
            genome: Genome::random(&mut rng),
            endowment: 0.0,
        };
        shards[1].absorb(ch, &mut vec![DeMsg::Spawn(vec![weak])]);
        let _ = shards[1].step(&mut rng);
        assert_eq!(shards[1].cells()[0].genome.kin_id, kin, "weak invader loses");
    }

    #[test]
    fn genome_mutation_respects_cap() {
        let mut rng = Xoshiro256::new(6);
        let mut g = Genome::random(&mut rng);
        for _ in 0..2000 {
            g = g.offspring(&mut rng);
            assert!(g.weights.len() <= GENOME_CAP);
            assert!(g.weights.len() >= 8);
        }
        assert_eq!(g.generation, 2000);
    }

    #[test]
    fn kin_groups_diversify() {
        let (_, mut shards, mut rng) = mk(1, 64, 7);
        // all-random start: many groups
        assert!(shards[0].kin_group_count() > 32);
        for _ in 0..600 {
            let _ = shards[0].step(&mut rng);
        }
        // selection collapses diversity but fission maintains > 1
        let k = shards[0].kin_group_count();
        assert!(k >= 1 && k <= 64, "k={k}");
    }

    #[test]
    fn mismatched_layer_payload_skipped() {
        let (_, mut shards, _) = mk(2, 4, 8);
        let ch = shards[0]
            .chan_meta
            .iter()
            .position(|&(_, l)| l == Layer::Kin)
            .unwrap();
        // send a Resource payload on the Kin layer: must be ignored
        shards[0].absorb(ch, &mut vec![DeMsg::Resource(vec![1.0, 2.0])]);
        assert!(shards[0].ghost_kin.iter().all(Option::is_none));
    }

    #[test]
    fn step_cost_reflects_compute_heavy_profile() {
        let (_, shards, _) = mk(1, 3600, 9);
        // paper profile: ms-scale updates at 3600 cells
        assert!(shards[0].step_cost_ns() > 1e6);
    }

    #[test]
    fn resource_transfers_conserve_between_shards() {
        // What leaves shard A's border equals what B credits on absorb.
        let (_, mut shards, mut rng) = mk(2, 4, 10);
        let total_before: f64 = shards.iter().map(|s| s.mean_resource() * 4.0).sum();
        // one update with full delivery of resource messages only
        let out0 = shards[0].step(&mut rng);
        let out1 = shards[1].step(&mut rng);
        let inflow0 = shards[0].cfg.resource_inflow;
        for (src, out) in [(0usize, out0), (1usize, out1)] {
            let dst = 1 - src;
            for (ch, msg) in out {
                if let DeMsg::Resource(_) = msg {
                    let (dir, _) = shards[src].chan_meta[ch];
                    let back = shards[dst]
                        .chan_meta
                        .iter()
                        .position(|&(d, l)| l == Layer::Resource && d == dir.opposite())
                        .unwrap();
                    shards[dst].absorb(back, &mut vec![msg]);
                }
            }
        }
        // absorb applies at next step; run it with zero inflow to isolate
        for s in shards.iter_mut() {
            s.cfg.resource_inflow = 0.0;
            let _ = s.step(&mut rng);
        }
        let total_after: f64 = shards.iter().map(|s| s.mean_resource() * 4.0).sum();
        // only growth allowed is the two inflow-ful updates; transfers conserve
        let max_growth = 2.0 * inflow0 as f64 * 8.0; // 8 cells, harvest<=1
        assert!(
            total_after <= total_before + max_growth + 1e-6,
            "before={total_before} after={total_after}"
        );
    }
}
