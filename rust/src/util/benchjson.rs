//! Shared JSON emission for the self-contained bench harnesses.
//!
//! Criterion is unavailable offline, so benches are plain `main()`s that
//! print as they go and optionally serialize their measurements to a
//! `BENCH_*.json` at the repository root for `python/bench_diff.py`.
//! The serialization lives here so the gate's parser has exactly one
//! producer format to agree with:
//!
//! ```json
//! {"bench": "<name>", "schema": 1,
//!  "results": [{"name", "unit", "mean", "median", "p95"}, ...]}
//! ```
//!
//! Printing stays at the call sites (each bench has its own layout);
//! only entry storage and serialization are shared.

use std::path::PathBuf;

/// One recorded measurement: summary statistics over per-op samples.
pub struct BenchEntry {
    pub name: String,
    pub unit: &'static str,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
}

/// Accumulates [`BenchEntry`]s and serializes them to the repo root.
#[derive(Default)]
pub struct BenchJson {
    entries: Vec<BenchEntry>,
}

impl BenchJson {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: &str, unit: &'static str, mean: f64, median: f64, p95: f64) {
        self.entries.push(BenchEntry {
            name: name.to_string(),
            unit,
            mean,
            median,
            p95,
        });
    }

    pub fn entries(&self) -> &[BenchEntry] {
        &self.entries
    }

    fn render(&self, bench: &str) -> String {
        let mut out = format!(
            "{{\n  \"bench\": {},\n  \"schema\": 1,\n  \"results\": [\n",
            json_string(bench)
        );
        for (i, e) in self.entries.iter().enumerate() {
            let sep = if i + 1 < self.entries.len() { "," } else { "" };
            out.push_str(&format!(
                "    {{\"name\": {}, \"unit\": \"{}\", \"mean\": {}, \"median\": {}, \"p95\": {}}}{sep}\n",
                json_string(&e.name),
                e.unit,
                json_number(e.mean),
                json_number(e.median),
                json_number(e.p95),
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Serialize every entry to `<repo root>/<file>` (the root is one
    /// level above the crate manifest) under bench name `bench`.
    pub fn write(&self, bench: &str, file: &str) -> std::io::Result<PathBuf> {
        let root = std::env::var("CARGO_MANIFEST_DIR")
            .map(|d| PathBuf::from(d).join(".."))
            .unwrap_or_else(|_| PathBuf::from("."));
        let path = root.join(file);
        std::fs::write(&path, self.render(bench))?;
        Ok(path)
    }
}

fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn json_number(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_strings_and_nan() {
        assert_eq!(json_string("plain"), "\"plain\"");
        assert_eq!(json_string("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_string("x\ny"), "\"x\\u000ay\"");
        assert_eq!(json_number(1.5), "1.500");
        assert_eq!(json_number(f64::NAN), "null");
        assert_eq!(json_number(f64::INFINITY), "null");
    }

    #[test]
    fn serializes_the_gate_schema() {
        let mut j = BenchJson::new();
        j.push("thread QoS period (256 shards, mode 3)", "ns", 1.0, 2.0, 3.0);
        j.push("plain", "rate", 0.5, 0.25, 0.75);
        assert_eq!(j.entries().len(), 2);
        let out = j.render("t");
        assert!(out.starts_with("{\n  \"bench\": \"t\",\n  \"schema\": 1,"));
        assert!(out.contains("\"median\": 2.000"));
        assert!(out.contains("\"unit\": \"rate\""));
        // Entries comma-separated, no trailing comma on the last one.
        assert!(out.contains("\"p95\": 3.000},\n"));
        assert!(out.contains("\"p95\": 0.750}\n"));
        assert!(out.ends_with("  ]\n}\n"));
    }
}
