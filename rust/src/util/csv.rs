//! Minimal CSV emission for experiment results.
//!
//! The offline toolchain has no `csv`/`serde` crates; benches and the
//! coordinator write flat numeric tables, so a tiny writer suffices.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// In-memory CSV table with a fixed header row.
#[derive(Clone, Debug)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the arity differs from the header.
    pub fn push_row<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Convenience: append a row of f64s rendered with full precision.
    pub fn push_f64_row(&mut self, row: &[f64]) {
        self.push_row(row.iter().map(|v| format!("{v}")).collect::<Vec<_>>());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn header(&self) -> &[String] {
        &self.header
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Render to CSV text (RFC-4180-style quoting only when needed).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let write_row = |out: &mut String, row: &[String]| {
            for (i, field) in row.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if field.contains(',') || field.contains('"') || field.contains('\n') {
                    let escaped = field.replace('"', "\"\"");
                    let _ = write!(out, "\"{escaped}\"");
                } else {
                    out.push_str(field);
                }
            }
            out.push('\n');
        };
        write_row(&mut out, &self.header);
        for row in &self.rows {
            write_row(&mut out, row);
        }
        out
    }

    /// Write to disk, creating parent directories.
    pub fn write_to(&self, path: impl AsRef<Path>) -> io::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.push_row(vec!["1", "2"]);
        t.push_f64_row(&[0.5, 1.25]);
        let s = t.render();
        assert_eq!(s, "a,b\n1,2\n0.5,1.25\n");
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    fn quotes_fields_with_commas() {
        let mut t = CsvTable::new(vec!["x"]);
        t.push_row(vec!["hello, world"]);
        t.push_row(vec!["say \"hi\""]);
        let s = t.render();
        assert!(s.contains("\"hello, world\""));
        assert!(s.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.push_row(vec!["only-one"]);
    }

    #[test]
    fn writes_to_disk() {
        let mut t = CsvTable::new(vec!["v"]);
        t.push_row(vec!["42"]);
        let dir = std::env::temp_dir().join("ebcomm_csv_test");
        let path = dir.join("nested/out.csv");
        t.write_to(&path).unwrap();
        let read = std::fs::read_to_string(&path).unwrap();
        assert_eq!(read, "v\n42\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
