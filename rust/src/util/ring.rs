//! Fixed-capacity ring buffer with configurable overflow policy.
//!
//! This is the storage primitive backing every duct implementation. The
//! paper's MPI-backed channels drop messages when the *send buffer* fills
//! ([`Overflow::Reject`]); its shared-memory channels keep only the most
//! recent state ([`Overflow::Overwrite`] with capacity 1 models the
//! "directly wrote updates to a piece of shared memory" behaviour of the
//! multithread implementation, §III-E.5).

use std::collections::VecDeque;

/// What to do when a push would exceed capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Overflow {
    /// Refuse the new element (the caller observes a drop) — MPI send
    /// buffer semantics.
    Reject,
    /// Evict the oldest element to make room — latest-value semantics.
    Overwrite,
}

/// Outcome of a [`RingBuffer::push`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    /// Element stored without displacing anything.
    Stored,
    /// Element stored, oldest evicted (only under [`Overflow::Overwrite`]).
    Displaced,
    /// Element refused (only under [`Overflow::Reject`]).
    Rejected,
}

/// Bounded FIFO ring buffer.
#[derive(Clone, Debug)]
pub struct RingBuffer<T> {
    items: VecDeque<T>,
    capacity: usize,
    policy: Overflow,
}

impl<T> RingBuffer<T> {
    /// Create a buffer holding at most `capacity` (≥1) elements.
    pub fn new(capacity: usize, policy: Overflow) -> Self {
        assert!(capacity >= 1, "ring buffer capacity must be >= 1");
        Self {
            items: VecDeque::with_capacity(capacity),
            capacity,
            policy,
        }
    }

    /// Attempt to append an element.
    pub fn push(&mut self, item: T) -> PushOutcome {
        if self.items.len() < self.capacity {
            self.items.push_back(item);
            PushOutcome::Stored
        } else {
            match self.policy {
                Overflow::Reject => PushOutcome::Rejected,
                Overflow::Overwrite => {
                    self.items.pop_front();
                    self.items.push_back(item);
                    PushOutcome::Displaced
                }
            }
        }
    }

    /// Remove and return the oldest element.
    pub fn pop(&mut self) -> Option<T> {
        self.items.pop_front()
    }

    /// Drain every element currently buffered (bulk consumption — models
    /// `MPI_Testsome`-style backlog clearing, paper §II-F2).
    pub fn drain_all(&mut self) -> Vec<T> {
        self.items.drain(..).collect()
    }

    /// Drain every buffered element into `out` (appending, oldest
    /// first), returning how many were moved. The allocation-free
    /// counterpart of [`RingBuffer::drain_all`] for callers that reuse a
    /// scratch buffer.
    pub fn drain_into(&mut self, out: &mut Vec<T>) -> usize {
        let n = self.items.len();
        out.extend(self.items.drain(..));
        n
    }

    /// Keep only the newest element, discarding the rest; returns the
    /// number discarded. ("Skipped over to only get the latest message.")
    pub fn skip_to_latest(&mut self) -> usize {
        if self.items.len() <= 1 {
            return 0;
        }
        let skipped = self.items.len() - 1;
        let last = self.items.pop_back().unwrap();
        self.items.clear();
        self.items.push_back(last);
        skipped
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() == self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Peek the newest element.
    pub fn latest(&self) -> Option<&T> {
        self.items.back()
    }

    /// Peek the oldest element.
    pub fn oldest(&self) -> Option<&T> {
        self.items.front()
    }

    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.items.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reject_policy_drops_on_full() {
        let mut rb = RingBuffer::new(2, Overflow::Reject);
        assert_eq!(rb.push(1), PushOutcome::Stored);
        assert_eq!(rb.push(2), PushOutcome::Stored);
        assert_eq!(rb.push(3), PushOutcome::Rejected);
        assert_eq!(rb.len(), 2);
        assert_eq!(rb.pop(), Some(1));
        assert_eq!(rb.push(3), PushOutcome::Stored);
        assert_eq!(rb.drain_all(), vec![2, 3]);
        assert!(rb.is_empty());
    }

    #[test]
    fn drain_into_appends_and_counts() {
        let mut rb = RingBuffer::new(4, Overflow::Reject);
        rb.push(1);
        rb.push(2);
        let mut out = vec![0];
        assert_eq!(rb.drain_into(&mut out), 2);
        assert_eq!(out, vec![0, 1, 2]);
        assert!(rb.is_empty());
        assert_eq!(rb.drain_into(&mut out), 0);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn overwrite_policy_evicts_oldest() {
        let mut rb = RingBuffer::new(2, Overflow::Overwrite);
        rb.push(1);
        rb.push(2);
        assert_eq!(rb.push(3), PushOutcome::Displaced);
        assert_eq!(rb.drain_all(), vec![2, 3]);
    }

    #[test]
    fn capacity_one_latest_value() {
        let mut rb = RingBuffer::new(1, Overflow::Overwrite);
        for i in 0..10 {
            rb.push(i);
        }
        assert_eq!(rb.latest(), Some(&9));
        assert_eq!(rb.len(), 1);
    }

    #[test]
    fn skip_to_latest_counts_skipped() {
        let mut rb = RingBuffer::new(8, Overflow::Reject);
        for i in 0..5 {
            rb.push(i);
        }
        assert_eq!(rb.skip_to_latest(), 4);
        assert_eq!(rb.pop(), Some(4));
        assert_eq!(rb.skip_to_latest(), 0);
    }

    #[test]
    fn never_exceeds_capacity() {
        let mut rb = RingBuffer::new(3, Overflow::Overwrite);
        for i in 0..100 {
            rb.push(i);
            assert!(rb.len() <= 3);
        }
    }

    #[test]
    #[should_panic]
    fn zero_capacity_panics() {
        let _ = RingBuffer::<u8>::new(0, Overflow::Reject);
    }
}
