//! Deterministic pseudo-random number generation and distributions.
//!
//! The offline environment ships no `rand` crate, so we implement the
//! generators we need from scratch:
//!
//! * [`SplitMix64`] — used for seeding and cheap stream splitting.
//! * [`Xoshiro256`] — xoshiro256++, the workhorse generator for all
//!   simulation randomness (fast, 256-bit state, passes BigCrush).
//! * [`Mt19937`] — a faithful Mersenne Twister, because the paper defines
//!   "one unit of compute work" as *a call to the `std::mt19937` engine*
//!   (§III-C); the synthetic work spinner must match that definition.
//!
//! Distribution helpers (uniform, normal via Box–Muller, lognormal,
//! exponential) live on [`Rng`], a small trait both generators implement.

/// Minimal random-generator interface used across the crate.
pub trait Rng {
    /// Next raw 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // 53 high bits -> mantissa-exact uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection, unbiased).
    #[inline]
    fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform `usize` index into a slice of length `len`.
    #[inline]
    fn index(&mut self, len: usize) -> usize {
        self.below(len as u64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// branch-predictable — speed here is not on a hot path).
    fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        mean + sd * z
    }

    /// Lognormal with the given *underlying* normal parameters.
    fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with the given mean.
    fn exponential(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

/// SplitMix64 — tiny generator used to expand seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — main simulation generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = sm.next_u64();
        }
        // All-zero state is invalid (cannot happen from splitmix of any
        // seed in practice, but belt and braces).
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    /// Derive an independent child stream (seed-domain separation).
    pub fn split(&mut self, tag: u64) -> Xoshiro256 {
        let a = self.next_u64();
        Xoshiro256::new(a ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Raw generator state, for checkpoint serialization.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from [`Self::state`] output — the restored
    /// stream continues bit-identically. All-zero states (invalid for
    /// xoshiro) are remapped exactly like [`Self::new`] would.
    pub fn from_state(mut s: [u64; 4]) -> Self {
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }
}

impl Rng for Xoshiro256 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
}

/// Faithful MT19937 (32-bit Mersenne Twister).
///
/// One `next_u32` call == one paper "work unit" (§III-C: "a call to the
/// `std::mt19937` random number engine as a unit of compute work").
pub struct Mt19937 {
    mt: [u32; 624],
    index: usize,
}

impl Mt19937 {
    pub fn new(seed: u32) -> Self {
        let mut mt = [0u32; 624];
        mt[0] = seed;
        for i in 1..624 {
            mt[i] = 1_812_433_253u32
                .wrapping_mul(mt[i - 1] ^ (mt[i - 1] >> 30))
                .wrapping_add(i as u32);
        }
        Self { mt, index: 624 }
    }

    fn generate(&mut self) {
        for i in 0..624 {
            let y = (self.mt[i] & 0x8000_0000) | (self.mt[(i + 1) % 624] & 0x7FFF_FFFF);
            let mut next = y >> 1;
            if y & 1 != 0 {
                next ^= 0x9908_B0DF;
            }
            self.mt[i] = self.mt[(i + 397) % 624] ^ next;
        }
        self.index = 0;
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.index >= 624 {
            self.generate();
        }
        let mut y = self.mt[self.index];
        self.index += 1;
        y ^= y >> 11;
        y ^= (y << 7) & 0x9D2C_5680;
        y ^= (y << 15) & 0xEFC6_0000;
        y ^ (y >> 18)
    }
}

impl Rng for Mt19937 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference vector for seed 1234567 (first outputs of splitmix64).
        let mut g = SplitMix64::new(0);
        let a = g.next_u64();
        let b = g.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut g2 = SplitMix64::new(0);
        assert_eq!(g2.next_u64(), a);
        assert_eq!(g2.next_u64(), b);
    }

    #[test]
    fn mt19937_matches_cpp_reference() {
        // std::mt19937 seeded with 5489 produces 3499211612 first.
        let mut mt = Mt19937::new(5489);
        assert_eq!(mt.next_u32(), 3_499_211_612);
        assert_eq!(mt.next_u32(), 581_869_302);
        assert_eq!(mt.next_u32(), 3_890_346_734);
        // 10000th output of mt19937(5489) is famously 4123659995.
        let mut mt = Mt19937::new(5489);
        let mut last = 0;
        for _ in 0..10_000 {
            last = mt.next_u32();
        }
        assert_eq!(last, 4_123_659_995);
    }

    #[test]
    fn uniform_in_range() {
        let mut g = Xoshiro256::new(42);
        for _ in 0..10_000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = g.uniform(-3.0, 7.0);
            assert!((-3.0..7.0).contains(&y));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut g = Xoshiro256::new(7);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[g.below(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut g = Xoshiro256::new(11);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = g.normal(2.0, 3.0);
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
        assert!((var - 9.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn lognormal_positive_and_median() {
        let mut g = Xoshiro256::new(13);
        let mut v: Vec<f64> = (0..50_001).map(|_| g.lognormal(1.0, 0.5)).collect();
        assert!(v.iter().all(|&x| x > 0.0));
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = v[v.len() / 2];
        // median of lognormal = exp(mu)
        assert!((median - 1.0f64.exp()).abs() < 0.1, "median={median}");
    }

    #[test]
    fn exponential_mean() {
        let mut g = Xoshiro256::new(17);
        let n = 100_000;
        let total: f64 = (0..n).map(|_| g.exponential(4.0)).sum();
        let mean = total / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn split_streams_diverge() {
        let mut g = Xoshiro256::new(1);
        let mut a = g.split(0);
        let mut b = g.split(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn state_round_trip_continues_stream() {
        let mut g = Xoshiro256::new(99);
        for _ in 0..17 {
            g.next_u64();
        }
        let mut h = Xoshiro256::from_state(g.state());
        for _ in 0..32 {
            assert_eq!(g.next_u64(), h.next_u64());
        }
        // All-zero guard matches the constructor's remap.
        let mut z = Xoshiro256::from_state([0; 4]);
        assert_ne!(z.next_u64(), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Xoshiro256::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
