//! Scoped worker-pool parallel map with deterministic output ordering.
//!
//! Replicate sweeps are embarrassingly parallel — every (mode, CPU count,
//! replicate) cell is independently seeded — so the coordinator fans them
//! out over `std::thread::scope` workers (no external dependencies).
//! Results are returned **in input order** regardless of which worker
//! finished when, so a parallel sweep is bit-identical to a serial one.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count to use by default: `EBCOMM_WORKERS` if set (≥1),
/// otherwise the host's available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("EBCOMM_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every item on up to `workers` scoped threads.
///
/// Items are claimed dynamically (an atomic cursor), so stragglers don't
/// serialize behind a static partition; each result is written to its
/// item's slot, so the output order equals the input order. With
/// `workers <= 1` (or fewer than two items) everything runs on the
/// calling thread — the serial reference path.
///
/// `f` must be a pure function of the item for run-to-run determinism
/// (sweep cells are independently seeded, satisfying this). A panic in
/// `f` propagates to the caller when the scope joins.
pub fn parallel_map<T, R, F>(workers: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let workers = workers.max(1).min(items.len());
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..items.len()).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.into_inner()
                .unwrap()
                .unwrap_or_else(|| panic!("worker never filled slot {i}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(4, &items, |&x| x * 3);
        assert_eq!(out, (0..100).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..37).collect();
        let f = |&x: &u64| x.wrapping_mul(0x9E37_79B9).rotate_left(7);
        assert_eq!(parallel_map(1, &items, f), parallel_map(8, &items, f));
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(4, &empty, |&x| x).is_empty());
        assert_eq!(parallel_map(4, &[9u32], |&x| x + 1), vec![10]);
    }

    #[test]
    fn more_workers_than_items() {
        let items = [1u32, 2, 3];
        assert_eq!(parallel_map(64, &items, |&x| x * x), vec![1, 4, 9]);
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }
}
