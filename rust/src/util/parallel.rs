//! Scoped worker-pool parallel map with deterministic output ordering.
//!
//! Replicate sweeps are embarrassingly parallel — every (mode, CPU count,
//! replicate) cell is independently seeded — so the coordinator fans them
//! out over `std::thread::scope` workers (no external dependencies).
//! Results are returned **in input order** regardless of which worker
//! finished when, so a parallel sweep is bit-identical to a serial one.
//!
//! Two scheduling refinements for lopsided grids (a 256-proc cell costs
//! ~100× a 1-proc cell):
//!
//! * **LPT claim order** ([`parallel_map_lpt`]): cells are claimed in
//!   longest-processing-time-first order by a caller-supplied cost hint,
//!   so stragglers start first instead of serializing at the tail of the
//!   sweep. Output order (and hence results) is unaffected.
//! * **Per-cell telemetry**: every map records per-cell wall times
//!   ([`CellTiming`]); [`log_telemetry`] prints them to stderr when
//!   `EBCOMM_SWEEP_TELEMETRY=1`, for identifying the next split-scheduling
//!   candidate.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Worker count to use by default: `EBCOMM_WORKERS` if set (≥1),
/// otherwise the host's available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("EBCOMM_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Wall time one sweep cell took on its worker, by input index.
#[derive(Clone, Copy, Debug)]
pub struct CellTiming {
    /// Index of the cell in the caller's item slice.
    pub index: usize,
    pub wall: Duration,
}

/// Apply `f` to every item on up to `workers` scoped threads.
///
/// Items are claimed dynamically (an atomic cursor) in input order, so
/// stragglers don't serialize behind a static partition; each result is
/// written to its item's slot, so the output order equals the input
/// order. With `workers <= 1` (or fewer than two items) everything runs
/// on the calling thread — the serial reference path.
///
/// `f` must be a pure function of the item for run-to-run determinism
/// (sweep cells are independently seeded, satisfying this). A panic in
/// `f` propagates to the caller when the scope joins.
pub fn parallel_map<T, R, F>(workers: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_lpt(workers, items, |_| 0, f).0
}

/// [`parallel_map`] with longest-processing-time-first claiming: items
/// are claimed in descending `cost` order (ties keep input order —
/// uniform costs reduce to plain input-order claiming), so the most
/// expensive cells start before the cheap tail instead of landing on an
/// otherwise-drained pool. Results still come back in input order,
/// bit-identical to any other claim order; per-cell wall times are
/// returned alongside (in input order).
pub fn parallel_map_lpt<T, R, F, C>(
    workers: usize,
    items: &[T],
    cost: C,
    f: F,
) -> (Vec<R>, Vec<CellTiming>)
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
    C: Fn(&T) -> u64,
{
    // Claim order: descending cost, stable on ties (so a uniform-cost
    // grid is claimed exactly in input order, as before LPT existed).
    let mut order: Vec<usize> = (0..items.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(cost(&items[i])));

    let workers = workers.max(1).min(items.len());
    if workers <= 1 {
        let mut slots: Vec<Option<(R, Duration)>> = (0..items.len()).map(|_| None).collect();
        for &i in &order {
            let t0 = Instant::now();
            let r = f(&items[i]);
            slots[i] = Some((r, t0.elapsed()));
        }
        return unzip_slots(slots);
    }

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<(R, Duration)>>> =
        (0..items.len()).map(|_| Mutex::new(None)).collect();
    let order = &order;
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let pos = next.fetch_add(1, Ordering::Relaxed);
                if pos >= order.len() {
                    break;
                }
                let i = order[pos];
                let t0 = Instant::now();
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some((r, t0.elapsed()));
            });
        }
    });
    unzip_slots(
        slots
            .into_iter()
            .map(|slot| slot.into_inner().unwrap())
            .collect(),
    )
}

fn unzip_slots<R>(slots: Vec<Option<(R, Duration)>>) -> (Vec<R>, Vec<CellTiming>) {
    let mut results = Vec::with_capacity(slots.len());
    let mut timings = Vec::with_capacity(slots.len());
    for (i, slot) in slots.into_iter().enumerate() {
        let (r, wall) = slot.unwrap_or_else(|| panic!("worker never filled slot {i}"));
        results.push(r);
        timings.push(CellTiming { index: i, wall });
    }
    (results, timings)
}

/// Print per-cell sweep telemetry to stderr when
/// `EBCOMM_SWEEP_TELEMETRY=1`: each cell's wall time plus the
/// total/max/imbalance summary that motivates LPT ordering.
pub fn log_telemetry(label: &str, timings: &[CellTiming]) {
    if std::env::var("EBCOMM_SWEEP_TELEMETRY").map(|v| v == "1") != Ok(true) {
        return;
    }
    if timings.is_empty() {
        eprintln!("[sweep {label}] no cells");
        return;
    }
    let total: Duration = timings.iter().map(|t| t.wall).sum();
    let max = timings.iter().map(|t| t.wall).max().unwrap_or_default();
    let mean = total / timings.len() as u32;
    for t in timings {
        eprintln!("[sweep {label}] cell {:>4}: {:>10.3?}", t.index, t.wall);
    }
    eprintln!(
        "[sweep {label}] {} cells, total {:.3?}, mean {:.3?}, max {:.3?} ({:.1}x mean)",
        timings.len(),
        total,
        mean,
        max,
        max.as_secs_f64() / mean.as_secs_f64().max(1e-12),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(4, &items, |&x| x * 3);
        assert_eq!(out, (0..100).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..37).collect();
        let f = |&x: &u64| x.wrapping_mul(0x9E37_79B9).rotate_left(7);
        assert_eq!(parallel_map(1, &items, f), parallel_map(8, &items, f));
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(4, &empty, |&x| x).is_empty());
        assert_eq!(parallel_map(4, &[9u32], |&x| x + 1), vec![10]);
    }

    #[test]
    fn more_workers_than_items() {
        let items = [1u32, 2, 3];
        assert_eq!(parallel_map(64, &items, |&x| x * x), vec![1, 4, 9]);
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn lpt_output_order_is_input_order() {
        // Costs deliberately anti-sorted vs input order.
        let items: Vec<u64> = (0..50).collect();
        for workers in [1, 4] {
            let (out, timings) = parallel_map_lpt(workers, &items, |&x| x, |&x| x * 2);
            assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
            assert_eq!(timings.len(), 50);
            for (i, t) in timings.iter().enumerate() {
                assert_eq!(t.index, i, "timings come back in input order");
            }
        }
    }

    #[test]
    fn lpt_matches_uniform_claiming_results() {
        let items: Vec<u64> = (0..31).collect();
        let f = |&x: &u64| x.wrapping_mul(0xDEAD_BEEF).rotate_left(11);
        let plain = parallel_map(4, &items, f);
        let (lpt, _) = parallel_map_lpt(4, &items, |&x| 1_000 - x, f);
        assert_eq!(plain, lpt);
    }

    #[test]
    fn lpt_claims_expensive_cells_first_serially() {
        // On the serial path the claim order is observable through a
        // side-channel log: descending cost, ties in input order.
        let log = Mutex::new(Vec::new());
        let items: Vec<(usize, u64)> = vec![(0, 5), (1, 9), (2, 5), (3, 1)];
        let (out, _) = parallel_map_lpt(
            1,
            &items,
            |&(_, c)| c,
            |&(i, _)| {
                log.lock().unwrap().push(i);
                i
            },
        );
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(*log.lock().unwrap(), vec![1, 0, 2, 3]);
    }

    #[test]
    fn telemetry_log_does_not_panic() {
        let (_, timings) = parallel_map_lpt(2, &[1u32, 2, 3], |_| 0, |&x| x);
        // Env-gated: off in tests, but the formatting path must be sound.
        log_telemetry("test", &timings);
        log_telemetry("empty", &[]);
    }
}
