//! Foundation utilities: PRNGs, ring buffers, CSV emission, and the
//! scoped-thread parallel map behind sweep fan-out.

pub mod benchjson;
pub mod csv;
pub mod parallel;
pub mod ring;
pub mod rng;

/// Nanoseconds as a plain integer — the unit of virtual time throughout
/// the simulator. 2^63 ns ≈ 292 years; overflow is not a practical concern.
pub type Nanos = u64;

/// One virtual second, in nanoseconds.
pub const SECOND: Nanos = 1_000_000_000;

/// One virtual millisecond, in nanoseconds.
pub const MILLI: Nanos = 1_000_000;

/// One virtual microsecond, in nanoseconds.
pub const MICRO: Nanos = 1_000;

/// Format a nanosecond quantity with an adaptive unit for reports.
pub fn fmt_ns(ns: f64) -> String {
    if !ns.is_finite() {
        return format!("{ns}");
    }
    let abs = ns.abs();
    if abs >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if abs >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if abs >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(5.0), "5ns");
        assert_eq!(fmt_ns(1_500.0), "1.500us");
        assert_eq!(fmt_ns(2_000_000.0), "2.000ms");
        assert_eq!(fmt_ns(3_200_000_000.0), "3.200s");
    }
}
