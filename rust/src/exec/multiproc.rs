//! On-hardware multi-process executor.
//!
//! The paper's headline experiments run best-effort communication
//! *across process boundaries* on real HPC hardware. This executor is
//! that modality's hardware counterpart in this repo (the analogue of
//! Conduit's MPI backend): shards are partitioned across real OS
//! processes connected by nonblocking unix-socket ducts
//! ([`crate::conduit::socket`]), so a best-effort put genuinely fails
//! when the peer's buffer is full or the peer process is gone — no
//! simulation in the message path at all.
//!
//! # Topology
//!
//! The coordinator process spawns `n_procs` worker processes by
//! re-executing the `ebcomm` binary with the hidden `__mp-child`
//! subcommand (the [`ChildSpec`] rides along hex-encoded in
//! `EBCOMM_MP_SPEC`). Workers own contiguous shard blocks (the same
//! `rank * n_procs / n_shards` assignment the thread executor uses) and
//! connect to each other with a full socket mesh under a private
//! temporary directory: worker `r` listens on `data-r.sock`, dials every
//! lower rank, and accepts every higher rank (each dialer introduces
//! itself with its rank, so the mesh is deadlock-free without any
//! coordination). Channels between shards in the *same* process use
//! in-process [`crate::conduit::intra_duct`]s; cross-process channels
//! use socket ducts keyed by the global flat channel id.
//!
//! A blocking control socket per worker carries the tiny coordination
//! protocol: `HELLO` (worker ready), `GO` (start the clock), `BARRIER` /
//! `RELEASE` (parent-mediated barrier consensus for modes 0–2, with the
//! stop decision OR-folded across workers so every process exits the
//! same generation — the cross-process equivalent of the thread
//! executor's leader-latch protocol), and `RESULT` (the worker's
//! end-of-run report blob).
//!
//! # Measurement
//!
//! Each worker reuses the wall-clock [`SnapshotSchedule`] machinery to
//! bracket counter tranches per channel into [`SnapshotWindow`]s —
//! pairing each shard's inlet and outlet for the same peer relationship,
//! i.e. each process observes its own endpoints, exactly the paper's
//! per-process snapshot apparatus — and folds them into a mergeable
//! [`SketchQos`] carrying all four paper QoS metrics. The coordinator
//! merges every worker's sketches (that is what the sketches were built
//! for) plus the socket hub's serialize/enqueue/transport/drain
//! [`StageLatencies`]. Fault scenarios compile to the same wall-clock
//! [`HwFaultTimeline`] the thread executor consults, so degrade and
//! partition scenarios drive real processes.
//!
//! Wall-clock runs are **never** golden-gated; all assertions on them
//! are tolerance- or ordinal-based (`rust/tests/golden/README.md`).

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::conduit::{
    intra_duct, ChannelConfig, ChannelStats, CounterTranche, Discipline, InletLike, IntraInlet,
    IntraOutlet, OutletLike, SendOutcome, SocketHub, SocketInlet, SocketOutlet, StageLatencies,
    WireEnvelope,
};
use crate::faults::{FaultScenario, ScenarioPhase};
use crate::net::{PlacementKind, Topology};
use crate::qos::{QosObservation, SketchQos, SnapshotSchedule, SnapshotWindow, TouchCounter};
use crate::sim::{AsyncMode, Persist, SnapError, SnapReader, SnapWriter};
use crate::util::ring::Overflow;
use crate::util::rng::{Rng, Xoshiro256};
use crate::util::Nanos;
use crate::workloads::{
    reciprocal_layer, ChannelSpec, GcConfig, GcMsg, GraphColoringShard, ShardWorkload, SpecIndex,
    WorkUnitSpinner,
};

use super::hw_faults::HwFaultTimeline;

// Control-protocol tags (one blocking stream per worker).
const MSG_HELLO: u8 = 1;
const MSG_BARRIER: u8 = 2;
const MSG_RESULT: u8 = 3;
const MSG_GO: u8 = 10;
const MSG_RELEASE: u8 = 11;

/// Mesh/handshake setup budget.
const SETUP_TIMEOUT: Duration = Duration::from_secs(30);
/// Extra wall time the coordinator grants workers past the nominal run
/// (and workers grant the coordinator on barrier waits) before giving
/// up — generous for heavily loaded CI boxes.
const RUN_GRACE: Duration = Duration::from_secs(60);

/// Hidden CLI subcommand dispatching a spawned worker process into
/// [`child_main`].
pub const CHILD_SUBCOMMAND: &str = "__mp-child";

/// Configuration for a multi-process hardware run. Mirrors
/// [`super::threads::ThreadExecConfig`], with processes instead of
/// threads and a concrete (spawnable) workload description.
#[derive(Clone, Debug)]
pub struct MultiprocConfig {
    pub mode: AsyncMode,
    /// Real wall-clock run duration. Extended automatically to cover
    /// `snapshots` when the schedule's runtime is longer.
    pub run_for: Duration,
    /// Synthetic work units spun per update (real mt19937 calls).
    pub added_work_units: u64,
    /// Channel configuration. Socket ducts always reject on overflow;
    /// `capacity` bounds the per-channel send window.
    pub channel: ChannelConfig,
    /// Mode-1 chunk duration.
    pub rolling_chunk: Duration,
    /// Mode-2 epoch.
    pub fixed_epoch: Duration,
    /// Worker processes to host the shards: `None` = one per shard.
    /// Clamped to the shard count; `EBCOMM_PROCS` caps it further (CI
    /// boxes pin it to the core count).
    pub procs: Option<usize>,
    /// Wall-clock QoS snapshot windows; `None` disables windowed capture.
    pub snapshots: Option<SnapshotSchedule>,
    /// Scripted fault timeline (wall-clock ns from run start; node
    /// indices address shard ranks). Compiled per worker.
    pub scenario: FaultScenario,
    /// Spin units injected per update per unit of active degradation
    /// (same semantics as the thread executor).
    pub degrade_spin_units: u64,
    /// Global channel ids escalated from barriered to best-effort (same
    /// semantics as [`super::threads::ThreadExecConfig::escalated`]).
    /// Shipped to every worker in the [`ChildSpec`]; both endpoints of a
    /// cross-process duct stamp their own side from it, so the two
    /// processes agree without wire traffic.
    pub escalated: Vec<usize>,
    pub seed: u64,
    /// Workload the workers rebuild deterministically from the seed.
    /// Graph coloring only for now: its messages are already `Vec<u8>`,
    /// so they cross the wire without a serialization layer.
    pub workload: GcConfig,
    /// Worker binary override. `None` resolves `EBCOMM_MP_BIN`, then the
    /// current executable (tests and benches pass
    /// `env!("CARGO_BIN_EXE_ebcomm")` explicitly).
    pub binary: Option<PathBuf>,
}

impl Default for MultiprocConfig {
    fn default() -> Self {
        Self {
            mode: AsyncMode::BestEffort,
            run_for: Duration::from_millis(200),
            added_work_units: 0,
            channel: ChannelConfig::qos(),
            rolling_chunk: Duration::from_millis(10),
            fixed_epoch: Duration::from_secs(1),
            procs: None,
            snapshots: None,
            scenario: FaultScenario::default(),
            degrade_spin_units: 4_000,
            escalated: Vec::new(),
            seed: 1,
            workload: GcConfig {
                simels_per_proc: 16,
                ..GcConfig::default()
            },
            binary: None,
        }
    }
}

/// Resolve the worker-process count: the requested count (default one
/// per shard), capped by `env_cap` (`EBCOMM_PROCS`), clamped to
/// `[1, n_shards]`.
fn resolve_procs(requested: Option<usize>, env_cap: Option<usize>, n_shards: usize) -> usize {
    let mut p = requested.unwrap_or(n_shards).max(1);
    if let Some(cap) = env_cap {
        if cap >= 1 {
            p = p.min(cap);
        }
    }
    p.clamp(1, n_shards.max(1))
}

fn env_proc_cap() -> Option<usize> {
    std::env::var("EBCOMM_PROCS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
}

/// Worker process hosting shard `rank`: the contiguous-block assignment
/// the thread executor uses for shard→thread multiplexing.
fn proc_of(shard: usize, n_shards: usize, n_procs: usize) -> usize {
    shard * n_procs / n_shards
}

/// Shard ranks worker `p` hosts: `[start, end)`.
fn block_range(p: usize, n_shards: usize, n_procs: usize) -> (usize, usize) {
    (
        (p * n_shards).div_ceil(n_procs),
        ((p + 1) * n_shards).div_ceil(n_procs),
    )
}

// ---- spec / report wire blobs ---------------------------------------

fn to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

fn from_hex(s: &str) -> Option<Vec<u8>> {
    let s = s.trim();
    if s.len() % 2 != 0 {
        return None;
    }
    (0..s.len() / 2)
        .map(|i| u8::from_str_radix(s.get(2 * i..2 * i + 2)?, 16).ok())
        .collect()
}

/// Everything a worker process needs to rebuild its world: shipped
/// hex-encoded in `EBCOMM_MP_SPEC` (the spec is tiny — scenario events
/// and scalars).
#[derive(Clone, Debug)]
pub struct ChildSpec {
    pub rank: usize,
    pub n_procs: usize,
    pub n_shards: usize,
    pub mode: AsyncMode,
    /// Already extended to cover the snapshot schedule.
    pub run_for_ns: u64,
    pub added_work_units: u64,
    pub channel_capacity: usize,
    pub rolling_chunk_ns: u64,
    pub fixed_epoch_ns: u64,
    pub snapshots: Option<SnapshotSchedule>,
    pub scenario: FaultScenario,
    pub degrade_spin_units: u64,
    pub seed: u64,
    pub gc_colors: u8,
    pub gc_b: f64,
    pub gc_simels: usize,
    pub gc_per_simel_cost_ns: f64,
    pub gc_base_cost_ns: f64,
    /// Global channel ids escalated to best-effort (new fields ride at
    /// the end of the wire layout: parent and child are the same binary,
    /// so the blob never crosses versions, but tail placement keeps the
    /// prefix stable anyway). Workers derive barrier participation from
    /// this deterministically, so every process agrees without extra
    /// coordination.
    pub escalated: Vec<u64>,
}

impl Persist for ChildSpec {
    fn save(&self, w: &mut SnapWriter) {
        self.rank.save(w);
        self.n_procs.save(w);
        self.n_shards.save(w);
        self.mode.save(w);
        self.run_for_ns.save(w);
        self.added_work_units.save(w);
        self.channel_capacity.save(w);
        self.rolling_chunk_ns.save(w);
        self.fixed_epoch_ns.save(w);
        self.snapshots.save(w);
        self.scenario.save(w);
        self.degrade_spin_units.save(w);
        self.seed.save(w);
        self.gc_colors.save(w);
        self.gc_b.save(w);
        self.gc_simels.save(w);
        self.gc_per_simel_cost_ns.save(w);
        self.gc_base_cost_ns.save(w);
        self.escalated.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            rank: usize::load(r)?,
            n_procs: usize::load(r)?,
            n_shards: usize::load(r)?,
            mode: AsyncMode::load(r)?,
            run_for_ns: u64::load(r)?,
            added_work_units: u64::load(r)?,
            channel_capacity: usize::load(r)?,
            rolling_chunk_ns: u64::load(r)?,
            fixed_epoch_ns: u64::load(r)?,
            snapshots: Option::load(r)?,
            scenario: FaultScenario::load(r)?,
            degrade_spin_units: u64::load(r)?,
            seed: u64::load(r)?,
            gc_colors: u8::load(r)?,
            gc_b: f64::load(r)?,
            gc_simels: usize::load(r)?,
            gc_per_simel_cost_ns: f64::load(r)?,
            gc_base_cost_ns: f64::load(r)?,
            escalated: Vec::load(r)?,
        })
    }
}

/// One worker's end-of-run report, shipped back over the control socket.
#[derive(Clone, Debug)]
pub struct ChildReport {
    /// Worker (process) rank.
    pub rank: usize,
    /// Updates per hosted shard, block order.
    pub updates: Vec<u64>,
    pub attempted_sends: u64,
    pub successful_sends: u64,
    /// First-step→last-step span.
    pub span_ns: u64,
    /// Windowed paper QoS metrics, sketch form (mergeable).
    pub qos: SketchQos,
    /// Socket-duct stage latency breakdown (mergeable).
    pub stages: StageLatencies,
}

impl Persist for ChildReport {
    fn save(&self, w: &mut SnapWriter) {
        self.rank.save(w);
        self.updates.save(w);
        self.attempted_sends.save(w);
        self.successful_sends.save(w);
        self.span_ns.save(w);
        self.qos.save(w);
        self.stages.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            rank: usize::load(r)?,
            updates: Vec::load(r)?,
            attempted_sends: u64::load(r)?,
            successful_sends: u64::load(r)?,
            span_ns: u64::load(r)?,
            qos: SketchQos::load(r)?,
            stages: StageLatencies::load(r)?,
        })
    }
}

fn encode_blob<T: Persist>(v: &T) -> Vec<u8> {
    let mut w = SnapWriter::new();
    v.save(&mut w);
    w.finish()
}

fn decode_blob<T: Persist>(bytes: &[u8]) -> io::Result<T> {
    let mut r = SnapReader::new(bytes).map_err(io::Error::other)?;
    let v = T::load(&mut r).map_err(io::Error::other)?;
    if !r.is_exhausted() {
        return Err(io::Error::other("trailing bytes in wire blob"));
    }
    Ok(v)
}

// ---- endpoints -------------------------------------------------------

/// Per-channel sender a worker owns: in-process for a co-hosted peer,
/// socket duct for a remote one.
enum MpInlet {
    Local(IntraInlet<WireEnvelope>),
    Remote(SocketInlet),
}

impl MpInlet {
    fn put(&self, msg: WireEnvelope) -> SendOutcome {
        match self {
            MpInlet::Local(i) => i.put(msg),
            MpInlet::Remote(i) => i.put(msg),
        }
    }
    fn stats(&self) -> &ChannelStats {
        match self {
            MpInlet::Local(i) => i.stats(),
            MpInlet::Remote(i) => i.stats(),
        }
    }
    fn discipline(&self) -> Discipline {
        match self {
            MpInlet::Local(i) => i.discipline(),
            MpInlet::Remote(i) => i.discipline(),
        }
    }
    fn set_discipline(&self, d: Discipline) {
        match self {
            MpInlet::Local(i) => i.set_discipline(d),
            MpInlet::Remote(i) => i.set_discipline(d),
        }
    }
}

enum MpOutlet {
    Local(IntraOutlet<WireEnvelope>),
    Remote(SocketOutlet),
}

impl MpOutlet {
    fn pull_all_into(&self, out: &mut Vec<WireEnvelope>) {
        match self {
            MpOutlet::Local(o) => out.extend(o.pull_all()),
            MpOutlet::Remote(o) => o.pull_all_into(out),
        }
    }
    fn stats(&self) -> &ChannelStats {
        match self {
            MpOutlet::Local(o) => o.stats(),
            MpOutlet::Remote(o) => o.stats(),
        }
    }
    fn discipline(&self) -> Discipline {
        match self {
            MpOutlet::Local(o) => o.discipline(),
            MpOutlet::Remote(o) => o.discipline(),
        }
    }
    fn set_discipline(&self, d: Discipline) {
        match self {
            MpOutlet::Local(o) => o.set_discipline(d),
            MpOutlet::Remote(o) => o.set_discipline(d),
        }
    }
}

/// Per-shard state a worker owns (see the thread executor's `ShardSlot`;
/// the `usize` in each endpoint pair is the directed channel's global
/// flat id).
struct Slot {
    rank: usize,
    shard: GraphColoringShard,
    rng: Xoshiro256,
    spinner: WorkUnitSpinner,
    inlets: Vec<(usize, MpInlet)>,
    outlets: Vec<(usize, MpOutlet)>,
    peers: Vec<usize>,
    touch: Vec<TouchCounter>,
    updates: u64,
}

// ---- control-stream helpers -----------------------------------------

fn read_u8(s: &mut UnixStream) -> io::Result<u8> {
    let mut b = [0u8; 1];
    s.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u64(s: &mut UnixStream) -> io::Result<u64> {
    let mut b = [0u8; 8];
    s.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn ctrl_path(dir: &Path) -> PathBuf {
    dir.join("ctrl.sock")
}

fn data_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("data-{rank}.sock"))
}

fn accept_deadline(listener: &UnixListener, deadline: Instant) -> io::Result<UnixStream> {
    listener.set_nonblocking(true)?;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                return Ok(stream);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(io::Error::new(io::ErrorKind::TimedOut, "accept timed out"));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
}

// ---- worker (child) side --------------------------------------------

/// Entry point for a spawned worker process (hidden `__mp-child`
/// subcommand). Reads its [`ChildSpec`] from `EBCOMM_MP_SPEC` and the
/// rendezvous directory from `EBCOMM_MP_DIR`.
pub fn child_main() -> Result<(), String> {
    let spec_hex =
        std::env::var("EBCOMM_MP_SPEC").map_err(|_| "EBCOMM_MP_SPEC not set".to_string())?;
    let dir = std::env::var("EBCOMM_MP_DIR").map_err(|_| "EBCOMM_MP_DIR not set".to_string())?;
    let blob = from_hex(&spec_hex).ok_or_else(|| "EBCOMM_MP_SPEC is not hex".to_string())?;
    let spec: ChildSpec = decode_blob(&blob).map_err(|e| format!("bad child spec: {e}"))?;
    let rank = spec.rank;
    run_child(&spec, Path::new(&dir)).map_err(|e| format!("mp worker {rank}: {e}"))
}

/// Full mesh: listen on our own data socket, dial every lower rank
/// (introducing ourselves with our rank), accept every higher rank.
/// Returns the hub link id per peer worker.
fn build_mesh(
    dir: &Path,
    rank: usize,
    n_procs: usize,
    hub: &SocketHub,
) -> io::Result<Vec<Option<usize>>> {
    let deadline = Instant::now() + SETUP_TIMEOUT;
    let listener = UnixListener::bind(data_path(dir, rank))?;
    let mut links: Vec<Option<usize>> = (0..n_procs).map(|_| None).collect();
    for q in 0..rank {
        let mut stream = loop {
            match UnixStream::connect(data_path(dir, q)) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        };
        stream.write_all(&(rank as u64).to_le_bytes())?;
        links[q] = Some(hub.add_link(stream)?);
    }
    for _ in rank + 1..n_procs {
        let mut stream = accept_deadline(&listener, deadline)?;
        stream.set_read_timeout(Some(SETUP_TIMEOUT))?;
        let peer = read_u64(&mut stream)? as usize;
        if peer <= rank || peer >= n_procs {
            return Err(io::Error::other(format!("mesh peer {peer} out of range")));
        }
        stream.set_read_timeout(None)?;
        links[peer] = Some(hub.add_link(stream)?);
    }
    Ok(links)
}

/// Rebuild every shard deterministically (same seed ⇒ same draw order as
/// any other worker), keep our block, and wire endpoints: intra ducts
/// within the block, socket ducts across blocks. Also stamps every
/// endpoint with its policy discipline and derives whether any channel
/// anywhere is still barriered — from the full (identical-in-every-
/// worker) spec set, so all processes reach the same answer.
fn build_slots(
    spec: &ChildSpec,
    hub: &SocketHub,
    links: &[Option<usize>],
) -> (Vec<Slot>, bool) {
    let n = spec.n_shards;
    let topo = Topology::new(n, PlacementKind::SingleNode);
    let gc = GcConfig {
        n_colors: spec.gc_colors,
        b: spec.gc_b,
        simels_per_proc: spec.gc_simels,
        per_simel_cost_ns: spec.gc_per_simel_cost_ns,
        base_cost_ns: spec.gc_base_cost_ns,
    };
    let mut rng = Xoshiro256::new(spec.seed);
    let all: Vec<GraphColoringShard> =
        (0..n).map(|r| GraphColoringShard::new(gc, &topo, r, &mut rng)).collect();
    let specs: Vec<Vec<ChannelSpec>> = all.iter().map(|s| s.channels()).collect();
    let index = SpecIndex::build(&specs);
    let (lo, hi) = block_range(spec.rank, n, spec.n_procs);
    let mine = |r: usize| r >= lo && r < hi;
    let channel = ChannelConfig {
        capacity: spec.channel_capacity,
        overflow: Overflow::Reject,
    };

    type InletSlot = Option<(usize, MpInlet)>;
    type OutletSlot = Option<(usize, MpOutlet)>;
    let mut my_in: Vec<Vec<InletSlot>> =
        (lo..hi).map(|r| (0..specs[r].len()).map(|_| None).collect()).collect();
    let mut my_out: Vec<Vec<OutletSlot>> =
        (lo..hi).map(|r| (0..specs[r].len()).map(|_| None).collect()).collect();
    for (src, specs_p) in specs.iter().enumerate() {
        for (src_ch, sp) in specs_p.iter().enumerate() {
            let cid = index.flat_id(src, src_ch);
            let dst = sp.peer;
            match (mine(src), mine(dst)) {
                (true, true) => {
                    let dst_ch = index
                        .lookup(dst, src, reciprocal_layer(sp.layer))
                        .expect("reciprocal channel");
                    let (inlet, outlet) = intra_duct::<WireEnvelope>(channel);
                    my_in[src - lo][src_ch] = Some((cid, MpInlet::Local(inlet)));
                    my_out[dst - lo][dst_ch] = Some((cid, MpOutlet::Local(outlet)));
                }
                (true, false) => {
                    let link = links[proc_of(dst, n, spec.n_procs)].expect("link to peer proc");
                    let inlet = hub.open_sender(link, cid as u64, channel);
                    my_in[src - lo][src_ch] = Some((cid, MpInlet::Remote(inlet)));
                }
                (false, true) => {
                    let dst_ch = index
                        .lookup(dst, src, reciprocal_layer(sp.layer))
                        .expect("reciprocal channel");
                    let outlet = hub.open_receiver(cid as u64);
                    my_out[dst - lo][dst_ch] = Some((cid, MpOutlet::Remote(outlet)));
                }
                (false, false) => {}
            }
        }
    }

    // Per-channel discipline: the uniform mapping of the run mode,
    // downgraded to best-effort for escalated channels. Stamped on both
    // locally-owned endpoint kinds; the remote side of a socket duct is
    // stamped by its own process from the same shipped list.
    let base = Discipline::uniform(spec.mode);
    let stamp = |cid: usize| {
        if base == Discipline::Barriered && spec.escalated.contains(&(cid as u64)) {
            Discipline::BestEffort
        } else {
            base
        }
    };
    let total_channels: usize = specs.iter().map(|s| s.len()).sum();
    let any_barriered = base == Discipline::Barriered
        && (0..total_channels).any(|cid| stamp(cid) == Discipline::Barriered);

    let mut slots = Vec::with_capacity(hi - lo);
    for (rank, shard) in all.into_iter().enumerate() {
        if !mine(rank) {
            continue;
        }
        let inlets: Vec<_> =
            std::mem::take(&mut my_in[rank - lo]).into_iter().map(Option::unwrap).collect();
        let outlets: Vec<_> =
            std::mem::take(&mut my_out[rank - lo]).into_iter().map(Option::unwrap).collect();
        for (cid, inlet) in &inlets {
            inlet.set_discipline(stamp(*cid));
        }
        for (cid, outlet) in &outlets {
            outlet.set_discipline(stamp(*cid));
        }
        let n_ch = inlets.len();
        slots.push(Slot {
            rank,
            shard,
            rng: Xoshiro256::new(spec.seed ^ (rank as u64).wrapping_mul(0x9E37_79B9)),
            spinner: WorkUnitSpinner::new(spec.seed as u32 ^ rank as u32),
            inlets,
            outlets,
            peers: specs[rank].iter().map(|s| s.peer).collect(),
            touch: vec![TouchCounter::default(); n_ch],
            updates: 0,
        });
    }
    (slots, any_barriered)
}

/// Wall-clock snapshot-window state for one worker. Each shard's
/// endpoint pair for channel `ch` (outgoing inlet + incoming outlet for
/// the same peer relationship, both locally owned) brackets one
/// [`SnapshotWindow`] per schedule window, absorbed straight into the
/// mergeable sketch with the channel's global id and the shard's global
/// rank as sender id.
struct ChildWindows {
    schedule: SnapshotSchedule,
    next: usize,
    open: bool,
    phase_accum: ScenarioPhase,
    /// `[slot][ch] -> (inlet open obs, outlet open obs)`.
    open_obs: ObsPairs,
    qos: SketchQos,
}

type ObsPairs = Vec<Vec<(QosObservation, QosObservation)>>;

fn capture_slots(slots: &[Slot], t: Nanos, phase: ScenarioPhase) -> ObsPairs {
    slots
        .iter()
        .map(|s| {
            (0..s.inlets.len())
                .map(|ch| {
                    (
                        QosObservation::capture_phased(
                            s.inlets[ch].1.stats().tranche(),
                            s.updates,
                            t,
                            phase,
                        ),
                        QosObservation::capture_phased(
                            s.outlets[ch].1.stats().tranche(),
                            s.updates,
                            t,
                            phase,
                        ),
                    )
                })
                .collect()
        })
        .collect()
}

impl ChildWindows {
    fn new(schedule: SnapshotSchedule) -> Self {
        Self {
            schedule,
            next: 0,
            open: false,
            phase_accum: ScenarioPhase::QUIESCENT,
            open_obs: Vec::new(),
            qos: SketchQos::new(),
        }
    }

    /// Advance the window state machine to wall offset `t` (open due
    /// windows, close elapsed ones — possibly several in a long gap).
    fn tick(&mut self, slots: &[Slot], t: Nanos, phase: ScenarioPhase) {
        if self.open {
            self.phase_accum = self.phase_accum.union(phase);
        }
        while self.next < self.schedule.count {
            if !self.open {
                if t < self.schedule.open_at(self.next) {
                    return;
                }
                self.open_obs = capture_slots(slots, t, phase);
                self.open = true;
                self.phase_accum = phase;
            }
            if t < self.schedule.close_at(self.next) {
                return;
            }
            let close_phase = self.phase_accum.union(phase);
            let close_obs = capture_slots(slots, t, close_phase);
            for (si, slot) in slots.iter().enumerate() {
                for ch in 0..slot.inlets.len() {
                    let (in_open, out_open) = self.open_obs[si][ch];
                    let (in_close, out_close) = close_obs[si][ch];
                    let w = SnapshotWindow {
                        inlet_before: in_open,
                        inlet_after: in_close,
                        outlet_before: out_open,
                        outlet_after: out_close,
                    };
                    self.qos.absorb_window(&w, slot.inlets[ch].0 as u64, slot.rank as u64);
                }
            }
            self.open = false;
            self.next += 1;
        }
    }
}

fn run_child(spec: &ChildSpec, dir: &Path) -> io::Result<()> {
    let hub = SocketHub::new();
    let links = build_mesh(dir, spec.rank, spec.n_procs, &hub)?;
    let (mut slots, any_barriered) = build_slots(spec, &hub, &links);
    let timeline = if spec.scenario.is_empty() {
        None
    } else {
        Some(HwFaultTimeline::compile(&spec.scenario, spec.n_shards))
    };

    let mut ctrl = UnixStream::connect(ctrl_path(dir))?;
    ctrl.write_all(&[MSG_HELLO])?;
    ctrl.write_all(&(spec.rank as u64).to_le_bytes())?;
    ctrl.set_read_timeout(Some(SETUP_TIMEOUT))?;
    if read_u8(&mut ctrl)? != MSG_GO {
        return Err(io::Error::other("expected GO"));
    }
    // Barrier waits block on the parent; bound them so an orphaned
    // worker dies instead of lingering.
    ctrl.set_read_timeout(Some(Duration::from_nanos(spec.run_for_ns) + RUN_GRACE))?;

    let mut windows = spec.snapshots.map(ChildWindows::new);
    let start = Instant::now();
    let run_for = Duration::from_nanos(spec.run_for_ns);
    let deadline = start + run_for;
    let mut chunk_start = Instant::now();
    let mut next_fixed = Instant::now() + Duration::from_nanos(spec.fixed_epoch_ns);
    let mut generation: u64 = 0;
    let mut phase_cache = ScenarioPhase::QUIESCENT;
    let mut next_ckpt: Option<Nanos> = Some(0);
    let mut env_scratch: Vec<WireEnvelope> = Vec::new();
    let mut pull_scratch: Vec<GcMsg> = Vec::new();
    let first_step = Instant::now();
    let mut last_step = first_step;

    loop {
        let t_ns = start.elapsed().as_nanos() as Nanos;
        let phase = match &timeline {
            None => ScenarioPhase::QUIESCENT,
            Some(tl) => {
                if next_ckpt.is_some_and(|c| t_ns >= c) {
                    phase_cache = tl.phase_at(t_ns);
                    next_ckpt = tl.next_checkpoint_after(t_ns);
                }
                phase_cache
            }
        };
        if let Some(ws) = windows.as_mut() {
            ws.tick(&slots, t_ns, phase);
        }
        // One central service pass per work-loop pass: flush send
        // backlogs, read and route inbound frames.
        hub.poll();

        for slot in &mut slots {
            // ---- Pull/absorb phase (per-duct discipline gate). ----
            for ch in 0..slot.outlets.len() {
                if !slot.outlets[ch].1.discipline().carries_traffic() {
                    continue;
                }
                env_scratch.clear();
                slot.outlets[ch].1.pull_all_into(&mut env_scratch);
                if env_scratch.is_empty() {
                    continue;
                }
                let max_touch = env_scratch.iter().map(|e| e.touch).max().unwrap();
                slot.touch[ch].on_receive(max_touch);
                slot.inlets[ch].1.stats().set_touches(slot.touch[ch].value());
                pull_scratch.clear();
                pull_scratch.extend(env_scratch.drain(..).map(|e| e.payload));
                slot.shard.absorb(ch, &mut pull_scratch);
            }

            // ---- Compute phase. ----
            let mut work = spec.added_work_units;
            if let Some(tl) = &timeline {
                let f = tl.speed_factor(t_ns, slot.rank);
                if f > 1.0 {
                    work += ((f - 1.0) * spec.degrade_spin_units as f64) as u64;
                }
            }
            if work > 0 {
                std::hint::black_box(slot.spinner.spin(work));
            }
            let outputs = slot.shard.step(&mut slot.rng);

            // ---- Send phase (per-duct discipline gate). ----
            for (ch, payload) in outputs {
                if !slot.inlets[ch].1.discipline().carries_traffic() {
                    continue;
                }
                if let Some(tl) = &timeline {
                    let peer = slot.peers[ch];
                    let p = tl.drop_prob(t_ns, slot.rank, peer);
                    if p > 0.0 && slot.rng.chance(p) {
                        slot.inlets[ch].1.stats().on_send_attempt(false);
                        continue;
                    }
                    let lf = tl.latency_factor(t_ns, slot.rank, peer);
                    if lf > 1.0 {
                        let units = ((lf - 1.0).min(8.0)
                            * (spec.degrade_spin_units / 64).max(1) as f64)
                            as u64;
                        std::hint::black_box(slot.spinner.spin(units));
                    }
                }
                slot.inlets[ch].1.put(WireEnvelope {
                    touch: slot.touch[ch].outgoing(),
                    payload,
                });
            }
            slot.updates += 1;
        }
        last_step = Instant::now();
        let stopping = last_step >= deadline;

        if any_barriered {
            let due = match spec.mode {
                AsyncMode::Sync => true,
                AsyncMode::RollingBarrier => {
                    chunk_start.elapsed() >= Duration::from_nanos(spec.rolling_chunk_ns)
                }
                AsyncMode::FixedBarrier => Instant::now() >= next_fixed,
                _ => unreachable!(),
            };
            if due || stopping {
                // Parent-mediated barrier: every worker that entered this
                // generation is released together, with the stop decision
                // OR-folded by the parent — so all workers exit the same
                // generation (the thread executor's leader-latch
                // consensus, stretched over the control socket).
                ctrl.write_all(&[MSG_BARRIER])?;
                ctrl.write_all(&generation.to_le_bytes())?;
                ctrl.write_all(&[stopping as u8])?;
                if read_u8(&mut ctrl)? != MSG_RELEASE {
                    return Err(io::Error::other("expected RELEASE"));
                }
                let stop = read_u8(&mut ctrl)? != 0;
                generation += 1;
                chunk_start = Instant::now();
                if spec.mode == AsyncMode::FixedBarrier {
                    next_fixed += Duration::from_nanos(spec.fixed_epoch_ns);
                }
                if stop {
                    break;
                }
            }
        } else if stopping {
            break;
        }
    }

    // Final tick, stamped no earlier than the scheduled end of run, so
    // the schedule's tail window closes (see the thread executor).
    if let Some(ws) = windows.as_mut() {
        let t_ns = (start.elapsed().as_nanos() as Nanos).max(spec.run_for_ns);
        let phase = timeline.as_ref().map_or(phase_cache, |tl| tl.phase_at(t_ns));
        ws.tick(&slots, t_ns, phase);
    }

    let mut totals = CounterTranche::default();
    for slot in &slots {
        for (_, inlet) in &slot.inlets {
            totals.add(&inlet.stats().tranche());
        }
    }
    let report = ChildReport {
        rank: spec.rank,
        updates: slots.iter().map(|s| s.updates).collect(),
        attempted_sends: totals.attempted_sends,
        successful_sends: totals.successful_sends,
        span_ns: last_step.duration_since(first_step).as_nanos() as u64,
        qos: windows.map(|w| w.qos).unwrap_or_default(),
        stages: hub.stage_latencies(),
    };
    let blob = encode_blob(&report);
    ctrl.write_all(&[MSG_RESULT])?;
    ctrl.write_all(&(blob.len() as u64).to_le_bytes())?;
    ctrl.write_all(&blob)?;
    Ok(())
}

// ---- coordinator (parent) side --------------------------------------

/// Result of a multi-process hardware run.
pub struct MultiprocResult {
    /// Worker processes actually used (after `EBCOMM_PROCS` capping).
    pub procs: usize,
    /// Updates completed per shard (global rank order).
    pub updates: Vec<u64>,
    /// Mean per-worker first-step→last-step span.
    pub elapsed: Duration,
    pub attempted_sends: u64,
    pub successful_sends: u64,
    /// All workers' windowed QoS metrics, sketch-merged.
    pub qos: SketchQos,
    /// All workers' stage latency breakdowns, sketch-merged.
    pub stages: StageLatencies,
    /// Per-worker reports (rank order).
    pub reports: Vec<ChildReport>,
}

impl MultiprocResult {
    /// Mean per-shard update rate (updates per second of measured span).
    pub fn update_rate_per_cpu_hz(&self) -> f64 {
        if self.updates.is_empty() || self.elapsed.is_zero() {
            return 0.0;
        }
        let mean = self.updates.iter().sum::<u64>() as f64 / self.updates.len() as f64;
        mean / self.elapsed.as_secs_f64()
    }

    pub fn overall_failure_rate(&self) -> f64 {
        if self.attempted_sends == 0 {
            0.0
        } else {
            1.0 - self.successful_sends as f64 / self.attempted_sends as f64
        }
    }
}

/// Resolve the worker binary: explicit override, `EBCOMM_MP_BIN`, the
/// current executable when it *is* `ebcomm`, else an `ebcomm` sibling
/// (covers `target/<profile>/deps/<test-bin>` → `target/<profile>/ebcomm`).
fn worker_binary(explicit: Option<&Path>) -> io::Result<PathBuf> {
    if let Some(p) = explicit {
        return Ok(p.to_path_buf());
    }
    if let Ok(p) = std::env::var("EBCOMM_MP_BIN") {
        return Ok(PathBuf::from(p));
    }
    let exe = std::env::current_exe()?;
    if exe.file_name().and_then(|n| n.to_str()) == Some("ebcomm") {
        return Ok(exe);
    }
    let mut dir = exe.parent();
    while let Some(d) = dir {
        let cand = d.join("ebcomm");
        if cand.is_file() {
            return Ok(cand);
        }
        dir = d.parent();
    }
    Err(io::Error::other(
        "cannot locate the ebcomm worker binary (set EBCOMM_MP_BIN or MultiprocConfig::binary)",
    ))
}

/// Barrier bookkeeping shared by the per-worker control reader threads.
struct CtrlShared {
    writers: Vec<Mutex<UnixStream>>,
    book: Mutex<BarrierBook>,
}

struct BarrierBook {
    alive: Vec<bool>,
    n_alive: usize,
    /// generation -> (workers entered, stop votes OR-folded).
    pending: HashMap<u64, (usize, bool)>,
}

impl CtrlShared {
    /// Release every generation all living workers have entered.
    fn release_ready(&self, book: &mut BarrierBook) {
        let n_alive = book.n_alive;
        let ready: Vec<u64> =
            book.pending.iter().filter(|(_, v)| v.0 >= n_alive).map(|(g, _)| *g).collect();
        for g in ready {
            let (_, stop) = book.pending.remove(&g).unwrap();
            for (i, w) in self.writers.iter().enumerate() {
                if book.alive[i] {
                    let mut s = w.lock().expect("ctrl writer poisoned");
                    let _ = s.write_all(&[MSG_RELEASE, stop as u8]);
                }
            }
        }
    }

    fn on_barrier(&self, gen: u64, stopping: bool) {
        let mut book = self.book.lock().expect("barrier book poisoned");
        let e = book.pending.entry(gen).or_insert((0, false));
        e.0 += 1;
        e.1 |= stopping;
        self.release_ready(&mut book);
    }

    /// A worker died (EOF/error on its control stream): drop it from the
    /// quorum and release any barriers it was the last holdout for.
    fn on_death(&self, worker: usize) {
        let mut book = self.book.lock().expect("barrier book poisoned");
        if book.alive[worker] {
            book.alive[worker] = false;
            book.n_alive -= 1;
        }
        if book.n_alive > 0 {
            self.release_ready(&mut book);
        }
    }
}

fn reader_loop(
    worker: usize,
    mut stream: UnixStream,
    shared: Arc<CtrlShared>,
    tx: mpsc::Sender<(usize, io::Result<ChildReport>)>,
) {
    loop {
        match read_u8(&mut stream) {
            Ok(MSG_BARRIER) => {
                let res = read_u64(&mut stream).and_then(|gen| {
                    let stopping = read_u8(&mut stream)? != 0;
                    shared.on_barrier(gen, stopping);
                    Ok(())
                });
                if let Err(e) = res {
                    shared.on_death(worker);
                    let _ = tx.send((worker, Err(e)));
                    return;
                }
            }
            Ok(MSG_RESULT) => {
                let report = read_u64(&mut stream).and_then(|len| {
                    if len > (1u64 << 30) {
                        return Err(io::Error::other("absurd report length"));
                    }
                    let mut blob = vec![0u8; len as usize];
                    stream.read_exact(&mut blob)?;
                    decode_blob::<ChildReport>(&blob)
                });
                shared.on_death(worker); // out of the barrier quorum now
                let _ = tx.send((worker, report));
                return;
            }
            Ok(tag) => {
                shared.on_death(worker);
                let _ = tx.send((worker, Err(io::Error::other(format!("bad ctrl tag {tag}")))));
                return;
            }
            Err(e) => {
                shared.on_death(worker);
                let _ = tx.send((worker, Err(e)));
                return;
            }
        }
    }
}

static RUN_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Run `n_shards` graph-coloring shards across real OS processes until
/// the deadline. Blocks until every worker reports (or errors out after
/// a grace period, killing stragglers).
pub fn run_multiproc(cfg: MultiprocConfig, n_shards: usize) -> io::Result<MultiprocResult> {
    assert!(n_shards > 0, "need at least one shard");
    let n_procs = resolve_procs(cfg.procs, env_proc_cap(), n_shards);
    let run_for = match cfg.snapshots {
        Some(s) => cfg.run_for.max(Duration::from_nanos(s.runtime())),
        None => cfg.run_for,
    };
    let binary = worker_binary(cfg.binary.as_deref())?;

    let dir = std::env::temp_dir().join(format!(
        "ebcomm-mp-{}-{}",
        std::process::id(),
        RUN_COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir)?;
    // Best-effort cleanup on every exit path below.
    struct DirGuard(PathBuf);
    impl Drop for DirGuard {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }
    let _guard = DirGuard(dir.clone());

    let listener = UnixListener::bind(ctrl_path(&dir))?;
    let mut children = Vec::with_capacity(n_procs);
    for rank in 0..n_procs {
        let spec = ChildSpec {
            rank,
            n_procs,
            n_shards,
            mode: cfg.mode,
            run_for_ns: run_for.as_nanos() as u64,
            added_work_units: cfg.added_work_units,
            channel_capacity: cfg.channel.capacity,
            rolling_chunk_ns: cfg.rolling_chunk.as_nanos() as u64,
            fixed_epoch_ns: cfg.fixed_epoch.as_nanos() as u64,
            snapshots: cfg.snapshots,
            scenario: cfg.scenario.clone(),
            degrade_spin_units: cfg.degrade_spin_units,
            seed: cfg.seed,
            gc_colors: cfg.workload.n_colors,
            gc_b: cfg.workload.b,
            gc_simels: cfg.workload.simels_per_proc,
            gc_per_simel_cost_ns: cfg.workload.per_simel_cost_ns,
            gc_base_cost_ns: cfg.workload.base_cost_ns,
            escalated: cfg.escalated.iter().map(|&c| c as u64).collect(),
        };
        let child = std::process::Command::new(&binary)
            .arg(CHILD_SUBCOMMAND)
            .env("EBCOMM_MP_SPEC", to_hex(&encode_blob(&spec)))
            .env("EBCOMM_MP_DIR", &dir)
            .spawn()?;
        children.push(child);
    }

    // HELLO handshake: collect one control stream per worker rank.
    let kill_all = |children: &mut Vec<std::process::Child>| {
        for c in children.iter_mut() {
            let _ = c.kill();
        }
        for c in children.iter_mut() {
            let _ = c.wait();
        }
    };
    let setup_deadline = Instant::now() + SETUP_TIMEOUT;
    let mut streams: Vec<Option<UnixStream>> = (0..n_procs).map(|_| None).collect();
    for _ in 0..n_procs {
        let handshake = accept_deadline(&listener, setup_deadline).and_then(|mut s| {
            s.set_read_timeout(Some(SETUP_TIMEOUT))?;
            if read_u8(&mut s)? != MSG_HELLO {
                return Err(io::Error::other("expected HELLO"));
            }
            let rank = read_u64(&mut s)? as usize;
            if rank >= n_procs || streams[rank].is_some() {
                return Err(io::Error::other(format!("bad hello rank {rank}")));
            }
            s.set_read_timeout(Some(run_for + RUN_GRACE))?;
            Ok((rank, s))
        });
        match handshake {
            Ok((rank, s)) => streams[rank] = Some(s),
            Err(e) => {
                kill_all(&mut children);
                return Err(e);
            }
        }
    }
    let mut streams: Vec<UnixStream> = streams.into_iter().map(Option::unwrap).collect();

    let writers: io::Result<Vec<Mutex<UnixStream>>> =
        streams.iter().map(|s| s.try_clone().map(Mutex::new)).collect();
    let writers = match writers {
        Ok(w) => w,
        Err(e) => {
            kill_all(&mut children);
            return Err(e);
        }
    };
    let shared = Arc::new(CtrlShared {
        writers,
        book: Mutex::new(BarrierBook {
            alive: vec![true; n_procs],
            n_alive: n_procs,
            pending: HashMap::new(),
        }),
    });

    // Start the clock everywhere, then hand each stream to its reader.
    for s in streams.iter_mut() {
        if let Err(e) = s.write_all(&[MSG_GO]) {
            kill_all(&mut children);
            return Err(e);
        }
    }
    let (tx, rx) = mpsc::channel();
    let mut readers = Vec::with_capacity(n_procs);
    for (worker, stream) in streams.into_iter().enumerate() {
        let shared = Arc::clone(&shared);
        let tx = tx.clone();
        readers.push(std::thread::spawn(move || reader_loop(worker, stream, shared, tx)));
    }
    drop(tx);

    let mut reports: Vec<Option<ChildReport>> = (0..n_procs).map(|_| None).collect();
    let mut failures: Vec<String> = Vec::new();
    let run_deadline = Instant::now() + run_for + RUN_GRACE;
    for _ in 0..n_procs {
        let left = run_deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(left.max(Duration::from_millis(1))) {
            Ok((worker, Ok(report))) => reports[worker] = Some(report),
            Ok((worker, Err(e))) => failures.push(format!("worker {worker}: {e}")),
            Err(_) => {
                failures.push("timed out waiting for worker reports".to_string());
                break;
            }
        }
    }
    kill_all(&mut children); // reaps the (already exited) workers
    for r in readers {
        let _ = r.join();
    }
    if !failures.is_empty() {
        return Err(io::Error::other(failures.join("; ")));
    }
    let reports: Vec<ChildReport> = reports.into_iter().map(Option::unwrap).collect();

    let mut updates = vec![0u64; n_shards];
    let mut attempted = 0u64;
    let mut successful = 0u64;
    let mut span_sum = Duration::ZERO;
    let mut qos = SketchQos::new();
    let mut stages = StageLatencies::new();
    for report in &reports {
        let (lo, hi) = block_range(report.rank, n_shards, n_procs);
        assert_eq!(report.updates.len(), hi - lo, "worker block size mismatch");
        updates[lo..hi].copy_from_slice(&report.updates);
        attempted += report.attempted_sends;
        successful += report.successful_sends;
        span_sum += Duration::from_nanos(report.span_ns);
        qos.merge(&report.qos);
        stages.merge(&report.stages);
    }
    Ok(MultiprocResult {
        procs: n_procs,
        updates,
        elapsed: span_sum / n_procs as u32,
        attempted_sends: attempted,
        successful_sends: successful,
        qos,
        stages,
        reports,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MILLI;

    #[test]
    fn resolve_procs_clamps_and_caps() {
        assert_eq!(resolve_procs(None, None, 8), 8);
        assert_eq!(resolve_procs(Some(64), None, 8), 8);
        assert_eq!(resolve_procs(Some(0), None, 8), 1);
        assert_eq!(resolve_procs(Some(4), Some(2), 256), 2);
        assert_eq!(resolve_procs(None, Some(2), 256), 2);
        assert_eq!(resolve_procs(Some(2), Some(4), 256), 2);
        assert_eq!(resolve_procs(Some(4), Some(0), 256), 4);
        assert_eq!(resolve_procs(None, None, 0), 1);
    }

    #[test]
    fn block_assignment_is_a_contiguous_partition() {
        for (n_shards, n_procs) in [(4, 2), (5, 2), (7, 3), (8, 8), (9, 4), (3, 1)] {
            let mut covered = 0;
            for p in 0..n_procs {
                let (lo, hi) = block_range(p, n_shards, n_procs);
                assert_eq!(lo, covered, "blocks must be contiguous");
                for r in lo..hi {
                    assert_eq!(proc_of(r, n_shards, n_procs), p);
                }
                covered = hi;
            }
            assert_eq!(covered, n_shards, "blocks must cover every shard");
        }
    }

    #[test]
    fn hex_round_trips() {
        let bytes: Vec<u8> = (0..=255).collect();
        assert_eq!(from_hex(&to_hex(&bytes)).unwrap(), bytes);
        assert_eq!(from_hex("zz"), None);
        assert_eq!(from_hex("abc"), None);
        assert_eq!(from_hex(""), Some(Vec::new()));
    }

    #[test]
    fn child_spec_round_trips() {
        let spec = ChildSpec {
            rank: 1,
            n_procs: 2,
            n_shards: 4,
            mode: AsyncMode::Sync,
            run_for_ns: 123_456_789,
            added_work_units: 7,
            channel_capacity: 64,
            rolling_chunk_ns: 10 * MILLI,
            fixed_epoch_ns: 1_000 * MILLI,
            snapshots: Some(SnapshotSchedule::hardware_smoke()),
            scenario: FaultScenario::default(),
            degrade_spin_units: 4_000,
            seed: 42,
            gc_colors: 3,
            gc_b: 0.1,
            gc_simels: 16,
            gc_per_simel_cost_ns: 80.0,
            gc_base_cost_ns: 3_400.0,
            escalated: vec![0, 3],
        };
        let blob = encode_blob(&spec);
        let back: ChildSpec = decode_blob(&blob).unwrap();
        assert_eq!(back.rank, 1);
        assert_eq!(back.mode, AsyncMode::Sync);
        assert_eq!(back.run_for_ns, 123_456_789);
        assert_eq!(back.snapshots.unwrap().count, SnapshotSchedule::hardware_smoke().count);
        assert_eq!(back.gc_simels, 16);
        assert_eq!(back.gc_b, 0.1);
        assert_eq!(back.escalated, vec![0, 3]);
    }

    #[test]
    fn child_report_round_trips() {
        let mut stages = StageLatencies::new();
        stages.serialize.insert(100.0);
        stages.transport.insert(5_000.0);
        let report = ChildReport {
            rank: 0,
            updates: vec![10, 12],
            attempted_sends: 40,
            successful_sends: 38,
            span_ns: 200 * MILLI,
            qos: SketchQos::new(),
            stages,
        };
        let blob = encode_blob(&report);
        let back: ChildReport = decode_blob(&blob).unwrap();
        assert_eq!(back.updates, vec![10, 12]);
        assert_eq!(back.attempted_sends, 40);
        assert_eq!(back.successful_sends, 38);
        assert_eq!(back.stages.serialize.count(), 1);
        assert_eq!(back.stages.transport.count(), 1);
        assert!(back.qos.is_empty());
    }

    #[test]
    fn worker_binary_explicit_override_wins() {
        let p = worker_binary(Some(Path::new("/tmp/some-ebcomm"))).unwrap();
        assert_eq!(p, PathBuf::from("/tmp/some-ebcomm"));
    }
}
