//! On-hardware multithread executor.
//!
//! Runs the same [`ShardWorkload`] shards as the DES, but on real
//! `std::thread`s with real wall clocks, real `std::sync::Barrier`s, and
//! shared-memory mutex ducts ([`crate::conduit::thread_duct`]) — the
//! multithreading modality of paper §III-A/E. Since the QoS-parity pass
//! it measures the same things the DES does, on metal:
//!
//! * **Windowed QoS** (§II-D/E): an optional wall-clock
//!   [`SnapshotSchedule`] brackets counter tranches per channel endpoint
//!   into [`SnapshotWindow`]s, reusing the `qos/` types unchanged — so
//!   update period, per-channel latency (via the [`TouchCounter`] touch
//!   protocol), delivery failure, and delivery coagulation come back as
//!   windowed distributions and every `ReplicateQos` query
//!   (`values_where`, `mean_where`, `report::` tables) works on hardware
//!   runs. Inlet observations are captured by the sending worker and
//!   outlet observations by the receiving worker — each endpoint's owner
//!   observes it, like the paper's per-process snapshot apparatus — so
//!   the two sides of a window are bracketed at slightly different wall
//!   instants (observation "motion blur", accepted in §II-E; the metric
//!   layer saturates).
//! * **Oversubscription**: [`ThreadExecConfig::threads`] multiplexes many
//!   shards onto few hardware threads (round-robin stepping per pass), so
//!   64–256-shard runs fit a 2-core CI box. `EBCOMM_THREADS` caps the
//!   real thread count from the environment. Reciprocal channel wiring
//!   uses the same sorted flat CSR-style index as `Engine::new` (the
//!   former `position()` scan was O(channels²)).
//! * **Scenario faults**: a [`FaultScenario`] compiles to wall-clock
//!   checkpoints ([`crate::exec::hw_faults::HwFaultTimeline`]) consulted
//!   each worker pass — degradation becomes extra spin work, link faults
//!   become forced put failures and pre-send spin delays — and QoS
//!   windows carry [`ScenarioPhase`] tags for the same time-resolved
//!   attribution the DES has.
//!
//! Wall-clock runs are **never** golden-gated and all assertions on them
//! are tolerance- or ordinal-based — see `rust/tests/golden/README.md`
//! for the determinism contract.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use crate::conduit::{
    thread_duct, ChannelConfig, CounterTranche, Discipline, InletLike, OutletLike,
    ThreadInlet, ThreadOutlet,
};
use crate::faults::{FaultScenario, ScenarioPhase};
use crate::qos::{QosObservation, ReplicateQos, SnapshotSchedule, SnapshotWindow, TouchCounter};
use crate::sim::AsyncMode;
use crate::util::rng::{Rng, Xoshiro256};
use crate::util::Nanos;
use crate::workloads::{reciprocal_layer, ChannelSpec, ShardWorkload, SpecIndex, WorkUnitSpinner};

use super::hw_faults::HwFaultTimeline;

/// Message envelope carrying the touch counter (QoS latency protocol).
#[derive(Clone)]
struct Envelope<M> {
    touch: u64,
    payload: M,
}

/// Configuration for an on-hardware run.
#[derive(Clone, Debug)]
pub struct ThreadExecConfig {
    pub mode: AsyncMode,
    /// Real wall-clock run duration. Extended automatically to cover
    /// `snapshots` when the schedule's runtime is longer.
    pub run_for: Duration,
    /// Synthetic work units spun per update (real mt19937 calls).
    pub added_work_units: u64,
    /// Channel configuration (paper: capacity 2 benchmarking, 64 QoS).
    pub channel: ChannelConfig,
    /// Mode-1 chunk duration.
    pub rolling_chunk: Duration,
    /// Mode-2 epoch.
    pub fixed_epoch: Duration,
    /// Hardware threads to host the shards: `None` = one per shard
    /// (the pre-oversubscription behaviour). Shards are multiplexed onto
    /// threads in contiguous rank blocks and stepped round-robin, one
    /// update per shard per pass. Clamped to the shard count; the
    /// `EBCOMM_THREADS` environment variable caps it further (CI boxes
    /// pin it to the core count).
    pub threads: Option<usize>,
    /// Wall-clock QoS snapshot windows (times are nanoseconds from run
    /// start); `None` disables windowed capture.
    pub snapshots: Option<SnapshotSchedule>,
    /// Scripted fault timeline. Event times are wall-clock ns from run
    /// start; node indices address shard ranks (see
    /// [`crate::exec::hw_faults`]). The default empty scenario adds no
    /// per-pass work at all.
    pub scenario: FaultScenario,
    /// Spin units injected per update per unit of active
    /// `DegradeNode.speed_factor` above 1 (and, scaled down 64×, per unit
    /// of link `latency_factor` above 1 per send). At ~35 ns/unit the
    /// default makes a lac-417-grade degradation clearly visible in
    /// windowed metrics without freezing a CI worker.
    pub degrade_spin_units: u64,
    /// Global channel ids (flat `(src, src_ch)` positions, the same ids
    /// the DES uses) escalated from barriered to best-effort — e.g. the
    /// channels an adaptive-policy DES run flipped. Setup stamps every
    /// duct with `Discipline::uniform(mode)` and then downgrades these;
    /// workers consult the duct's stamp, not the global mode, for their
    /// pull/send gates, and the barrier only engages when at least one
    /// channel is still barriered. Empty (the default) reproduces the
    /// uniform-mode behaviour exactly.
    pub escalated: Vec<usize>,
    pub seed: u64,
}

impl Default for ThreadExecConfig {
    fn default() -> Self {
        Self {
            mode: AsyncMode::BestEffort,
            run_for: Duration::from_millis(200),
            added_work_units: 0,
            channel: ChannelConfig::qos(),
            rolling_chunk: Duration::from_millis(10),
            fixed_epoch: Duration::from_secs(1),
            threads: None,
            snapshots: None,
            scenario: FaultScenario::default(),
            degrade_spin_units: 4_000,
            escalated: Vec::new(),
            seed: 1,
        }
    }
}

/// Resolve the hardware thread count: the requested count (default one
/// per shard), capped by `env_cap` (`EBCOMM_THREADS`), clamped to
/// `[1, n_shards]`.
fn resolve_threads(requested: Option<usize>, env_cap: Option<usize>, n_shards: usize) -> usize {
    let mut t = requested.unwrap_or(n_shards).max(1);
    if let Some(cap) = env_cap {
        if cap >= 1 {
            t = t.min(cap);
        }
    }
    t.clamp(1, n_shards.max(1))
}

fn env_thread_cap() -> Option<usize> {
    std::env::var("EBCOMM_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
}

/// Result of an on-hardware run.
pub struct ThreadExecResult<W> {
    pub shards: Vec<W>,
    /// Updates completed per shard (global rank order).
    pub updates: Vec<u64>,
    /// Mean per-worker first-step→last-step span. (Formerly measured
    /// from before thread spawn to after join, which inflated
    /// `update_rate_per_cpu_hz` denominators on slow-spawn boxes.)
    pub elapsed: Duration,
    /// Spawn-to-join wall time (diagnostics; includes spawn/join skew).
    pub wall_elapsed: Duration,
    /// Per-worker first-step→last-step spans.
    pub worker_spans: Vec<Duration>,
    pub attempted_sends: u64,
    pub successful_sends: u64,
    /// Hardware threads actually used (after `EBCOMM_THREADS` capping).
    pub threads: usize,
    /// Completed QoS windows, one per directed channel per schedule
    /// window (channel-major), when `snapshots` was configured.
    pub windows: Vec<SnapshotWindow>,
    /// The windows scanned into per-window metrics + phase tags — the
    /// same [`ReplicateQos`] the DES returns, so every downstream QoS
    /// query and report table works unchanged on hardware runs.
    pub qos: ReplicateQos,
}

impl<W> ThreadExecResult<W> {
    /// Mean per-shard update rate (updates per second of measured worker
    /// span).
    pub fn update_rate_per_cpu_hz(&self) -> f64 {
        if self.updates.is_empty() || self.elapsed.is_zero() {
            return 0.0;
        }
        let mean = self.updates.iter().sum::<u64>() as f64 / self.updates.len() as f64;
        mean / self.elapsed.as_secs_f64()
    }

    pub fn overall_failure_rate(&self) -> f64 {
        if self.attempted_sends == 0 {
            0.0
        } else {
            1.0 - self.successful_sends as f64 / self.attempted_sends as f64
        }
    }
}

/// Per-shard state a worker owns: the shard plus its channel endpoints
/// in the shard's `channels()` order. `inlets[ch]`/`outlets[ch]`/
/// `touch[ch]` all address the same peer relationship; the `usize` in
/// each endpoint pair is the directed channel's global id (for pairing
/// inlet- and outlet-side window observations after join).
struct ShardSlot<W: ShardWorkload> {
    rank: usize,
    shard: W,
    rng: Xoshiro256,
    spinner: WorkUnitSpinner,
    inlets: Vec<(usize, ThreadInlet<Envelope<W::Msg>>)>,
    outlets: Vec<(usize, ThreadOutlet<Envelope<W::Msg>>)>,
    /// Peer rank per channel (fault-timeline link lookups).
    peers: Vec<usize>,
    touch: Vec<TouchCounter>,
    updates: u64,
}

/// An open/close observation pair for one endpoint of one window.
type ObsPair = (QosObservation, QosObservation);
/// Completed windows per endpoint, keyed by global channel id.
type EndpointLog = Vec<(usize, Vec<ObsPair>)>;

struct WorkerOut<W> {
    shards: Vec<(usize, W)>,
    updates: Vec<(usize, u64)>,
    attempted: u64,
    successful: u64,
    span: Duration,
    inlet_logs: EndpointLog,
    outlet_logs: EndpointLog,
}

struct WorkerCtx<W: ShardWorkload> {
    slots: Vec<ShardSlot<W>>,
    barrier: Arc<Barrier>,
    stop: Arc<AtomicBool>,
    decision: Arc<AtomicBool>,
    cfg: ThreadExecConfig,
    start: Instant,
    deadline: Instant,
    timeline: Option<Arc<HwFaultTimeline>>,
    /// At least one channel is still barriered — computed once by the
    /// parent from the duct stamps so every worker runs the identical
    /// barrier sequence (per-worker divergence would deadlock the
    /// fixed-count `Barrier`).
    any_barriered: bool,
}

/// Run `shards` on hardware threads until the deadline. One thread per
/// shard by default; see [`ThreadExecConfig::threads`] for
/// oversubscribed (multiplexed) runs.
pub fn run_threads<W>(cfg: ThreadExecConfig, shards: Vec<W>) -> ThreadExecResult<W>
where
    W: ShardWorkload + Send + 'static,
    W::Msg: Send + 'static,
{
    let n = shards.len();
    let n_threads = resolve_threads(cfg.threads, env_thread_cap(), n);
    let specs: Vec<Vec<ChannelSpec>> = shards.iter().map(|s| s.channels()).collect();
    let total_specs: usize = specs.iter().map(|s| s.len()).sum();

    // Reciprocal wiring via the shared sorted flat CSR spec index
    // ([`SpecIndex`], same structure `Engine::new` wires with) — the
    // former `position()` scan here was O(channels²) overall.
    let spec_index = SpecIndex::build(&specs);

    // Global channel id for the duct created from `src`'s spec
    // `src_ch`: the flattened (src, src_ch) position.
    type InletSlot<M> = Option<(usize, ThreadInlet<Envelope<M>>)>;
    type OutletSlot<M> = Option<(usize, ThreadOutlet<Envelope<M>>)>;
    let mut inlets: Vec<Vec<InletSlot<W::Msg>>> =
        specs.iter().map(|sp| (0..sp.len()).map(|_| None).collect()).collect();
    let mut outlets: Vec<Vec<OutletSlot<W::Msg>>> =
        specs.iter().map(|sp| (0..sp.len()).map(|_| None).collect()).collect();
    for (src, specs_p) in specs.iter().enumerate() {
        for (src_ch, spec) in specs_p.iter().enumerate() {
            let cid = spec_index.flat_id(src, src_ch);
            let (inlet, outlet) = thread_duct::<Envelope<W::Msg>>(cfg.channel);
            inlets[src][src_ch] = Some((cid, inlet));
            // The receiver reads this duct via its reciprocal channel slot.
            let dst_ch = spec_index
                .lookup(spec.peer, src, reciprocal_layer(spec.layer))
                .expect("reciprocal channel");
            outlets[spec.peer][dst_ch] = Some((cid, outlet));
        }
    }

    // Stamp every duct with its policy discipline: the uniform mapping
    // of the run mode, downgraded to best-effort for escalated channels.
    // Thread ducts share discipline storage between endpoints, so the
    // inlet-side stamp is also what the receiving worker's pull gate
    // reads. The barrier engages only while some channel is barriered —
    // decided here, once, so every worker agrees.
    let base = Discipline::uniform(cfg.mode);
    let mut any_barriered = false;
    for row in &inlets {
        for (cid, inlet) in row.iter().flatten() {
            let d = if base == Discipline::Barriered && cfg.escalated.contains(cid) {
                Discipline::BestEffort
            } else {
                base
            };
            inlet.set_discipline(d);
            any_barriered |= d == Discipline::Barriered;
        }
    }

    let timeline = if cfg.scenario.is_empty() {
        None
    } else {
        Some(Arc::new(HwFaultTimeline::compile(&cfg.scenario, n)))
    };

    // Contiguous-block shard→thread assignment: thread `k` hosts ranks
    // where `rank * n_threads / n == k` (sizes differ by at most one).
    let mut slot_groups: Vec<Vec<ShardSlot<W>>> =
        (0..n_threads).map(|_| Vec::new()).collect();
    for (rank, shard) in shards.into_iter().enumerate() {
        let my_inlets: Vec<_> = std::mem::take(&mut inlets[rank])
            .into_iter()
            .map(Option::unwrap)
            .collect();
        let my_outlets: Vec<_> = std::mem::take(&mut outlets[rank])
            .into_iter()
            .map(Option::unwrap)
            .collect();
        let n_ch = my_inlets.len();
        slot_groups[rank * n_threads / n].push(ShardSlot {
            rank,
            shard,
            rng: Xoshiro256::new(cfg.seed ^ (rank as u64).wrapping_mul(0x9E37_79B9)),
            spinner: WorkUnitSpinner::new(cfg.seed as u32 ^ rank as u32),
            inlets: my_inlets,
            outlets: my_outlets,
            peers: specs[rank].iter().map(|s| s.peer).collect(),
            touch: vec![TouchCounter::default(); n_ch],
            updates: 0,
        });
    }

    let barrier = Arc::new(Barrier::new(n_threads));
    let stop = Arc::new(AtomicBool::new(false));
    let decision = Arc::new(AtomicBool::new(false));
    let start = Instant::now();
    // The run must cover the snapshot schedule, or trailing windows never
    // close.
    let run_for = match cfg.snapshots {
        Some(s) => cfg.run_for.max(Duration::from_nanos(s.runtime())),
        None => cfg.run_for,
    };
    let deadline = start + run_for;

    let mut handles = Vec::with_capacity(n_threads);
    for slots in slot_groups {
        let ctx = WorkerCtx {
            slots,
            barrier: Arc::clone(&barrier),
            stop: Arc::clone(&stop),
            decision: Arc::clone(&decision),
            cfg: cfg.clone(),
            start,
            deadline,
            timeline: timeline.clone(),
            any_barriered,
        };
        handles.push(std::thread::spawn(move || worker_loop(ctx)));
    }

    let mut shards_out: Vec<(usize, W)> = Vec::with_capacity(n);
    let mut updates = vec![0u64; n];
    let mut attempted = 0u64;
    let mut successful = 0u64;
    let mut worker_spans = Vec::with_capacity(n_threads);
    type WindowLog = Vec<ObsPair>;
    let mut inlet_map: Vec<Option<WindowLog>> = (0..total_specs).map(|_| None).collect();
    let mut outlet_map: Vec<Option<WindowLog>> = (0..total_specs).map(|_| None).collect();
    for h in handles {
        let out = h.join().expect("worker panicked");
        for (rank, u) in out.updates {
            updates[rank] = u;
        }
        attempted += out.attempted;
        successful += out.successful;
        worker_spans.push(out.span);
        shards_out.extend(out.shards);
        for (cid, log) in out.inlet_logs {
            inlet_map[cid] = Some(log);
        }
        for (cid, log) in out.outlet_logs {
            outlet_map[cid] = Some(log);
        }
    }
    shards_out.sort_by_key(|(r, _)| *r);
    let wall_elapsed = start.elapsed();
    let elapsed = if worker_spans.is_empty() {
        wall_elapsed
    } else {
        worker_spans.iter().sum::<Duration>() / worker_spans.len() as u32
    };

    // Pair each channel's inlet- and outlet-side observation streams
    // into SnapshotWindows (channel-major, window order). The two sides
    // close windows independently, so pair the common prefix.
    let mut windows = Vec::new();
    for cid in 0..total_specs {
        if let (Some(ins), Some(outs)) = (&inlet_map[cid], &outlet_map[cid]) {
            for (i, o) in ins.iter().zip(outs.iter()) {
                windows.push(SnapshotWindow {
                    inlet_before: i.0,
                    inlet_after: i.1,
                    outlet_before: o.0,
                    outlet_after: o.1,
                });
            }
        }
    }
    let qos = ReplicateQos::from_windows(&windows);

    ThreadExecResult {
        shards: shards_out.into_iter().map(|(_, s)| s).collect(),
        updates,
        elapsed,
        wall_elapsed,
        worker_spans,
        attempted_sends: attempted,
        successful_sends: successful,
        threads: n_threads,
        windows,
        qos,
    }
}

/// Wall-clock snapshot-window state for one worker: opens and closes the
/// schedule's windows over every endpoint the worker hosts.
struct WindowState {
    schedule: SnapshotSchedule,
    next: usize,
    open: bool,
    /// Union of scenario phases seen while the current window is open
    /// (folds mid-window transitions into the tag, like the engine's
    /// `window_phase`).
    phase_accum: ScenarioPhase,
    inlet_open: Vec<QosObservation>,
    outlet_open: Vec<QosObservation>,
    inlet_windows: Vec<Vec<ObsPair>>,
    outlet_windows: Vec<Vec<ObsPair>>,
}

impl WindowState {
    fn new(schedule: SnapshotSchedule, n_inlets: usize, n_outlets: usize) -> Self {
        Self {
            schedule,
            next: 0,
            open: false,
            phase_accum: ScenarioPhase::QUIESCENT,
            inlet_open: Vec::new(),
            outlet_open: Vec::new(),
            inlet_windows: (0..n_inlets).map(|_| Vec::new()).collect(),
            outlet_windows: (0..n_outlets).map(|_| Vec::new()).collect(),
        }
    }
}

/// One observation per endpoint the worker hosts (inlets, then outlets),
/// each bracketing its channel's shared counter tranche with the owning
/// shard's update count.
fn capture_endpoints<W: ShardWorkload>(
    slots: &[ShardSlot<W>],
    t: Nanos,
    phase: ScenarioPhase,
) -> (Vec<QosObservation>, Vec<QosObservation>) {
    let mut ins = Vec::new();
    let mut outs = Vec::new();
    for s in slots {
        for (_, inlet) in &s.inlets {
            ins.push(QosObservation::capture_phased(
                inlet.stats().tranche(),
                s.updates,
                t,
                phase,
            ));
        }
        for (_, outlet) in &s.outlets {
            outs.push(QosObservation::capture_phased(
                outlet.stats().tranche(),
                s.updates,
                t,
                phase,
            ));
        }
    }
    (ins, outs)
}

/// Advance the window state machine to wall offset `t`: open a due
/// window, close an elapsed one (possibly several in a long gap —
/// degenerate zero-width windows are well-defined, the metric layer
/// saturates). Open observations carry the instantaneous phase, closing
/// observations the union over the window, as in the engine.
fn tick_windows<W: ShardWorkload>(
    ws: &mut WindowState,
    slots: &[ShardSlot<W>],
    t: Nanos,
    phase: ScenarioPhase,
) {
    if ws.open {
        ws.phase_accum = ws.phase_accum.union(phase);
    }
    while ws.next < ws.schedule.count {
        if !ws.open {
            if t < ws.schedule.open_at(ws.next) {
                return;
            }
            let (ins, outs) = capture_endpoints(slots, t, phase);
            ws.inlet_open = ins;
            ws.outlet_open = outs;
            ws.open = true;
            ws.phase_accum = phase;
        }
        if t < ws.schedule.close_at(ws.next) {
            return;
        }
        let close_phase = ws.phase_accum.union(phase);
        let (ins, outs) = capture_endpoints(slots, t, close_phase);
        for (i, obs) in ins.into_iter().enumerate() {
            ws.inlet_windows[i].push((ws.inlet_open[i], obs));
        }
        for (i, obs) in outs.into_iter().enumerate() {
            ws.outlet_windows[i].push((ws.outlet_open[i], obs));
        }
        ws.open = false;
        ws.next += 1;
    }
}

fn worker_loop<W>(mut ctx: WorkerCtx<W>) -> WorkerOut<W>
where
    W: ShardWorkload,
{
    let cfg = ctx.cfg.clone();
    let mut chunk_start = Instant::now();
    let mut next_fixed = Instant::now() + cfg.fixed_epoch;
    let mut windows = cfg.snapshots.map(|s| {
        let n_in: usize = ctx.slots.iter().map(|sl| sl.inlets.len()).sum();
        let n_out: usize = ctx.slots.iter().map(|sl| sl.outlets.len()).sum();
        WindowState::new(s, n_in, n_out)
    });
    // Reused across channels, shards, and passes: the pull path
    // allocates nothing in steady state (the real-thread counterpart of
    // the DES engine's scratch buffer).
    let mut pull_scratch: Vec<W::Msg> = Vec::new();
    let mut env_scratch: Vec<Envelope<W::Msg>> = Vec::new();

    let first_step = Instant::now();
    let mut last_step = first_step;
    // Phase cache: the timeline's compiled checkpoints (onset, expiry,
    // flap toggle) are the only instants the active set can change, so
    // the per-pass phase lookup is a cached read between them.
    let mut phase_cache = ScenarioPhase::QUIESCENT;
    let mut next_ckpt: Option<Nanos> = Some(0);

    loop {
        let t_ns = ctx.start.elapsed().as_nanos() as Nanos;
        let phase = match &ctx.timeline {
            None => ScenarioPhase::QUIESCENT,
            Some(tl) => {
                if next_ckpt.is_some_and(|c| t_ns >= c) {
                    phase_cache = tl.phase_at(t_ns);
                    next_ckpt = tl.next_checkpoint_after(t_ns);
                }
                phase_cache
            }
        };
        if let Some(ws) = windows.as_mut() {
            tick_windows(ws, &ctx.slots, t_ns, phase);
        }

        // One pass: every hosted shard advances exactly one update
        // (round-robin multiplexing).
        for slot in &mut ctx.slots {
            // ---- Pull/absorb phase (per-duct discipline gate). ----
            for ch in 0..slot.outlets.len() {
                if !slot.outlets[ch].1.discipline().carries_traffic() {
                    continue;
                }
                env_scratch.clear();
                slot.outlets[ch].1.pull_all_into(&mut env_scratch);
                if env_scratch.is_empty() {
                    continue;
                }
                let max_touch = env_scratch.iter().map(|e| e.touch).max().unwrap();
                slot.touch[ch].on_receive(max_touch);
                // Publish the advanced counter on the reciprocal
                // outgoing channel's stats so window tranches carry
                // it (the engine does the same via `set_touches`).
                slot.inlets[ch].1.stats().set_touches(slot.touch[ch].value());
                pull_scratch.clear();
                pull_scratch.extend(env_scratch.drain(..).map(|e| e.payload));
                slot.shard.absorb(ch, &mut pull_scratch);
            }

            // ---- Compute phase (real synthetic work + real step). ----
            let mut work = cfg.added_work_units;
            if let Some(tl) = &ctx.timeline {
                let f = tl.speed_factor(t_ns, slot.rank);
                if f > 1.0 {
                    work += ((f - 1.0) * cfg.degrade_spin_units as f64) as u64;
                }
            }
            if work > 0 {
                std::hint::black_box(slot.spinner.spin(work));
            }
            let outputs = slot.shard.step(&mut slot.rng);

            // ---- Send phase (per-duct discipline gate). ----
            for (ch, payload) in outputs {
                if !slot.inlets[ch].1.discipline().carries_traffic() {
                    continue;
                }
                if let Some(tl) = &ctx.timeline {
                    let peer = slot.peers[ch];
                    let p = tl.drop_prob(t_ns, slot.rank, peer);
                    if p > 0.0 && slot.rng.chance(p) {
                        // Forced congestion/partition failure: counts
                        // as an attempted-but-dropped send.
                        slot.inlets[ch].1.stats().on_send_attempt(false);
                        continue;
                    }
                    let lf = tl.latency_factor(t_ns, slot.rank, peer);
                    if lf > 1.0 {
                        // Latency inflation as pre-send spin, scaled
                        // down so a 25× storm delays rather than
                        // freezes a send (~(lf-1)/64 of the degrade
                        // budget per send, capped at 8× worth).
                        let units = ((lf - 1.0).min(8.0)
                            * (cfg.degrade_spin_units / 64).max(1) as f64)
                            as u64;
                        std::hint::black_box(slot.spinner.spin(units));
                    }
                }
                slot.inlets[ch].1.put(Envelope {
                    touch: slot.touch[ch].outgoing(),
                    payload,
                });
            }
            slot.updates += 1;
        }
        last_step = Instant::now();

        // Termination: any worker past the deadline raises the stop flag.
        if last_step >= ctx.deadline {
            ctx.stop.store(true, Ordering::SeqCst);
        }

        if ctx.any_barriered {
            // Deadlock-free exit protocol. A worker enters the barrier
            // when its mode calls for one OR when stop has been raised,
            // so all workers execute the same barrier sequence. Whether
            // to exit is decided by consensus: the barrier leader latches
            // the stop flag between two waits, so every worker observes
            // the identical decision for this generation. (A plain
            // post-wait `stop` check races: one worker can raise `stop`
            // after its release and re-enter the next barrier while a
            // peer, reading the freshly-raised flag after the *previous*
            // release, exits — deadlocking the re-entrant worker.)
            let stopping = ctx.stop.load(Ordering::SeqCst);
            let due = match cfg.mode {
                AsyncMode::Sync => true,
                AsyncMode::RollingBarrier => chunk_start.elapsed() >= cfg.rolling_chunk,
                AsyncMode::FixedBarrier => Instant::now() >= next_fixed,
                _ => unreachable!(),
            };
            if due || stopping {
                let res = ctx.barrier.wait();
                if res.is_leader() {
                    ctx.decision
                        .store(ctx.stop.load(Ordering::SeqCst), Ordering::SeqCst);
                }
                ctx.barrier.wait();
                chunk_start = Instant::now();
                if cfg.mode == AsyncMode::FixedBarrier {
                    next_fixed += cfg.fixed_epoch;
                }
                if ctx.decision.load(Ordering::SeqCst) {
                    break;
                }
            }
        } else if ctx.stop.load(Ordering::SeqCst) {
            break;
        }
    }

    // Final tick: the deadline coincides with the last window's close
    // time whenever run_for was auto-extended to the schedule runtime,
    // and in-loop ticks happen before the deadline check raises stop —
    // so close anything still due rather than silently dropping the
    // schedule's tail window. Stamped at no earlier than the scheduled
    // end of run: a worker that breaks on the stop consensus a few µs
    // before the deadline must close it too.
    if let Some(ws) = windows.as_mut() {
        let end_ns =
            ctx.deadline.saturating_duration_since(ctx.start).as_nanos() as Nanos;
        let t_ns = (ctx.start.elapsed().as_nanos() as Nanos).max(end_ns);
        let phase = ctx
            .timeline
            .as_ref()
            .map(|tl| tl.phase_at(t_ns))
            .unwrap_or(phase_cache);
        tick_windows(ws, &ctx.slots, t_ns, phase);
    }

    let mut totals = CounterTranche::default();
    for slot in &ctx.slots {
        for (_, inlet) in &slot.inlets {
            totals.add(&inlet.stats().tranche());
        }
    }
    let (inlet_logs, outlet_logs) = match windows {
        Some(ws) => {
            let mut in_iter = ws.inlet_windows.into_iter();
            let mut out_iter = ws.outlet_windows.into_iter();
            let mut ins: EndpointLog = Vec::new();
            let mut outs: EndpointLog = Vec::new();
            for slot in &ctx.slots {
                for (cid, _) in &slot.inlets {
                    ins.push((*cid, in_iter.next().expect("inlet log")));
                }
                for (cid, _) in &slot.outlets {
                    outs.push((*cid, out_iter.next().expect("outlet log")));
                }
            }
            (ins, outs)
        }
        None => (Vec::new(), Vec::new()),
    };
    let span = last_step.duration_since(first_step);
    WorkerOut {
        updates: ctx.slots.iter().map(|s| (s.rank, s.updates)).collect(),
        shards: ctx.slots.into_iter().map(|s| (s.rank, s.shard)).collect(),
        attempted: totals.attempted_sends,
        successful: totals.successful_sends,
        span,
        inlet_logs,
        outlet_logs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{FaultKind, NodeFault};
    use crate::net::{PlacementKind, Topology};
    use crate::qos::MetricName;
    use crate::util::MILLI;
    use crate::workloads::{GcConfig, GraphColoringShard};

    fn gc_shards(n: usize, simels: usize, seed: u64) -> (Topology, Vec<GraphColoringShard>) {
        let topo = Topology::new(n, PlacementKind::SingleNode);
        let mut rng = Xoshiro256::new(seed);
        let cfg = GcConfig {
            simels_per_proc: simels,
            ..GcConfig::default()
        };
        let shards = (0..n)
            .map(|r| GraphColoringShard::new(cfg, &topo, r, &mut rng))
            .collect();
        (topo, shards)
    }

    #[test]
    fn best_effort_two_threads() {
        let (_, shards) = gc_shards(2, 16, 1);
        let result = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(100),
                ..Default::default()
            },
            shards,
        );
        assert!(result.updates.iter().all(|&u| u > 10));
        assert!(result.attempted_sends > 0);
        assert!(result.update_rate_per_cpu_hz() > 10.0);
    }

    #[test]
    fn sync_mode_two_threads_lockstep() {
        let (_, shards) = gc_shards(2, 4, 2);
        let result = run_threads(
            ThreadExecConfig {
                mode: AsyncMode::Sync,
                run_for: Duration::from_millis(80),
                ..Default::default()
            },
            shards,
        );
        let d = result.updates[0].abs_diff(result.updates[1]);
        assert!(d <= 1, "updates={:?}", result.updates);
    }

    #[test]
    fn no_comm_mode_is_silent() {
        let (_, shards) = gc_shards(2, 4, 3);
        let result = run_threads(
            ThreadExecConfig {
                mode: AsyncMode::NoComm,
                run_for: Duration::from_millis(50),
                ..Default::default()
            },
            shards,
        );
        assert_eq!(result.attempted_sends, 0);
    }

    #[test]
    fn rolling_barrier_completes() {
        let (_, shards) = gc_shards(2, 4, 4);
        let result = run_threads(
            ThreadExecConfig {
                mode: AsyncMode::RollingBarrier,
                run_for: Duration::from_millis(60),
                rolling_chunk: Duration::from_millis(5),
                ..Default::default()
            },
            shards,
        );
        assert!(result.updates.iter().all(|&u| u > 0));
    }

    #[test]
    fn added_work_slows_update_rate() {
        let (_, shards_a) = gc_shards(1, 4, 5);
        let (_, shards_b) = gc_shards(1, 4, 5);
        let fast = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(60),
                ..Default::default()
            },
            shards_a,
        );
        let slow = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(60),
                added_work_units: 100_000,
                ..Default::default()
            },
            shards_b,
        );
        assert!(
            fast.update_rate_per_cpu_hz() > 3.0 * slow.update_rate_per_cpu_hz(),
            "fast={} slow={}",
            fast.update_rate_per_cpu_hz(),
            slow.update_rate_per_cpu_hz()
        );
    }

    #[test]
    fn converges_on_hardware_sync() {
        // Barrier-per-update gives perfect communication: the coloring
        // must actually settle.
        let (topo, shards) = gc_shards(2, 64, 6);
        let result = run_threads(
            ThreadExecConfig {
                mode: AsyncMode::Sync,
                run_for: Duration::from_millis(300),
                ..Default::default()
            },
            shards,
        );
        let conflicts =
            crate::workloads::graph_coloring::global_conflicts(&topo, &result.shards);
        assert!(conflicts < 20, "conflicts={conflicts}");
    }

    #[test]
    fn best_effort_on_one_core_still_beats_random() {
        // On a single hardware core, OS timeslices (~10 ms) make ghost
        // state extremely stale, so borders churn — the interesting
        // property is that best-effort still improves on the random
        // baseline (~2/3 of vertices conflicted for 3 colors) rather than
        // diverging. True concurrent-thread behaviour is exercised by the
        // DES, which models per-update message exchange.
        let (topo, shards) = gc_shards(2, 64, 6);
        let result = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(300),
                ..Default::default()
            },
            shards,
        );
        let conflicts =
            crate::workloads::graph_coloring::global_conflicts(&topo, &result.shards);
        let random_baseline = 128 * 2 / 3;
        assert!(conflicts < random_baseline + 10, "conflicts={conflicts}");
    }

    #[test]
    fn escalating_every_channel_disengages_the_barrier() {
        // Sync mode with every channel escalated to best-effort: traffic
        // still flows, but no worker ever enters the barrier, so the run
        // must complete via the free-run stop path (a partial barrier
        // set with a fixed-count Barrier would deadlock — this exercises
        // the parent-computed `any_barriered` consensus).
        let (_, shards) = gc_shards(2, 4, 13);
        let n_channels: usize = shards.iter().map(|s| s.channels().len()).sum();
        let result = run_threads(
            ThreadExecConfig {
                mode: AsyncMode::Sync,
                run_for: Duration::from_millis(60),
                escalated: (0..n_channels).collect(),
                ..Default::default()
            },
            shards,
        );
        assert!(result.updates.iter().all(|&u| u > 0));
        assert!(result.attempted_sends > 0, "escalated channels still carry traffic");
    }

    #[test]
    fn resolve_threads_clamps_and_caps() {
        // Default: one thread per shard.
        assert_eq!(resolve_threads(None, None, 8), 8);
        // Requested count clamps to the shard count.
        assert_eq!(resolve_threads(Some(64), None, 8), 8);
        assert_eq!(resolve_threads(Some(0), None, 8), 1);
        // Env cap binds below the request, never above the shard count.
        assert_eq!(resolve_threads(Some(4), Some(2), 256), 2);
        assert_eq!(resolve_threads(None, Some(2), 256), 2);
        assert_eq!(resolve_threads(Some(2), Some(4), 256), 2);
        // A zero cap is ignored.
        assert_eq!(resolve_threads(Some(4), Some(0), 256), 4);
        assert_eq!(resolve_threads(None, None, 0), 1);
    }

    #[test]
    fn oversubscribed_multiplexing_steps_every_shard() {
        // 10 shards on 2 hardware threads: round-robin passes must
        // advance every shard, in both barriered and best-effort modes.
        for mode in [AsyncMode::Sync, AsyncMode::BestEffort] {
            let (_, shards) = gc_shards(10, 4, 8);
            let result = run_threads(
                ThreadExecConfig {
                    mode,
                    threads: Some(2),
                    run_for: Duration::from_millis(80),
                    ..Default::default()
                },
                shards,
            );
            assert!(result.threads <= 2);
            assert_eq!(result.updates.len(), 10);
            assert!(
                result.updates.iter().all(|&u| u > 0),
                "{mode:?}: {:?}",
                result.updates
            );
            if mode == AsyncMode::Sync {
                // Per-pass barriers keep every shard's count within one
                // pass of every other, whatever thread hosts it.
                let lo = result.updates.iter().min().unwrap();
                let hi = result.updates.iter().max().unwrap();
                assert!(hi - lo <= 1, "lockstep: {:?}", result.updates);
            }
        }
    }

    #[test]
    fn windowed_qos_produces_paper_metrics() {
        let (_, shards) = gc_shards(4, 4, 9);
        let schedule = SnapshotSchedule::compressed(20 * MILLI, 30 * MILLI, 15 * MILLI, 3);
        let result = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(120),
                snapshots: Some(schedule),
                ..Default::default()
            },
            shards,
        );
        // 4 shards × 4 channels × 3 windows, minus any window a worker
        // missed entirely (tolerance: at least one full round).
        assert!(!result.windows.is_empty());
        assert!(result.windows.len() <= 16 * 3);
        assert_eq!(result.qos.snapshots.len(), result.windows.len());
        assert_eq!(result.qos.phases.len(), result.windows.len());
        for metric in MetricName::ALL {
            let vals = result.qos.values(metric);
            assert_eq!(vals.len(), result.windows.len());
            assert!(vals.iter().all(|v| v.is_finite()), "{metric:?}");
        }
        // Real time elapses and real updates complete inside windows.
        assert!(result.qos.values(MetricName::SimstepPeriod).iter().any(|&v| v > 0.0));
        // No scenario => every window quiescent.
        assert!(result.qos.phases.iter().all(|p| p.is_quiescent()));
    }

    #[test]
    fn tail_window_closes_when_run_ends_at_schedule_runtime() {
        // run_for shorter than the schedule => auto-extended to exactly
        // the schedule runtime, making the deadline coincide with the
        // last window's close time. The workers' post-loop tick (stamped
        // at the scheduled end) must still close every window.
        let (_, shards) = gc_shards(2, 4, 12);
        let n_channels: usize = shards.iter().map(|s| s.channels().len()).sum();
        let schedule = SnapshotSchedule::compressed(10 * MILLI, 20 * MILLI, 10 * MILLI, 3);
        let result = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(1),
                snapshots: Some(schedule),
                ..Default::default()
            },
            shards,
        );
        assert_eq!(
            result.windows.len(),
            n_channels * schedule.count,
            "every window of every channel must close, tail included"
        );
    }

    #[test]
    fn per_worker_spans_tighter_than_wall() {
        let (_, shards) = gc_shards(2, 4, 10);
        let result = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(60),
                ..Default::default()
            },
            shards,
        );
        assert_eq!(result.worker_spans.len(), result.threads);
        // Spans exclude spawn/join overhead, so the mean span can never
        // exceed the spawn-to-join wall time.
        assert!(result.elapsed <= result.wall_elapsed);
        assert!(result.elapsed > Duration::ZERO);
    }

    #[test]
    fn degrade_scenario_tags_windows_and_slows_shard() {
        // Shard 1 degraded from 25 ms to 95 ms with heavy extra spin and
        // a 60% link drop; windows 0–1 overlap the fault, window 2 is
        // past it.
        let scenario = FaultScenario::default().with(
            25 * MILLI,
            70 * MILLI,
            FaultKind::DegradeNode {
                node: 1,
                fault: NodeFault {
                    speed_factor: 16.0,
                    jitter_sigma: 0.0,
                    stall_mean_ns: 0.0,
                    latency_factor: 2.0,
                    extra_drop_prob: 0.6,
                },
            },
        );
        let (_, shards) = gc_shards(4, 4, 11);
        let result = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(140),
                snapshots: Some(SnapshotSchedule::compressed(
                    30 * MILLI,
                    40 * MILLI,
                    20 * MILLI,
                    3,
                )),
                scenario,
                degrade_spin_units: 20_000,
                ..Default::default()
            },
            shards,
        );
        let active = result.qos.values_where(MetricName::SimstepPeriod, |p| !p.is_quiescent());
        let quiet = result.qos.values_where(MetricName::SimstepPeriod, |p| p.is_quiescent());
        assert!(!active.is_empty(), "fault overlapped no window");
        assert!(!quiet.is_empty(), "no quiescent window");
        // Forced drops on links touching shard 1 must register as
        // delivery failures in fault-tagged windows.
        let fail_active =
            result.qos.mean_where(MetricName::DeliveryFailureRate, |p| !p.is_quiescent());
        let fail_quiet =
            result.qos.mean_where(MetricName::DeliveryFailureRate, |p| p.is_quiescent());
        assert!(
            fail_active > fail_quiet,
            "failure attribution: active {fail_active} vs quiet {fail_quiet}"
        );
        // Whole-run accounting sees the forced drops too.
        assert!(result.overall_failure_rate() > 0.0);
    }
}
