//! On-hardware multithread executor.
//!
//! Runs the same [`ShardWorkload`] shards as the DES, but on real
//! `std::thread`s with real wall clocks, real `std::sync::Barrier`s, and
//! shared-memory mutex ducts ([`crate::conduit::thread_duct`]) — the
//! multithreading modality of paper §III-A/E. Used by the quickstart
//! example and by integration tests that cross-validate the DES process
//! model; the paper-scale experiments run on the DES (this machine cannot
//! host 64 hardware threads).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use crate::conduit::{thread_duct, ChannelConfig, InletLike, OutletLike, ThreadInlet, ThreadOutlet};
use crate::qos::TouchCounter;
use crate::sim::AsyncMode;
use crate::util::rng::Xoshiro256;
use crate::workloads::{ShardWorkload, WorkUnitSpinner};

/// Message envelope carrying the touch counter (QoS latency protocol).
#[derive(Clone)]
struct Envelope<M> {
    touch: u64,
    payload: M,
}

/// Configuration for an on-hardware run.
#[derive(Clone, Debug)]
pub struct ThreadExecConfig {
    pub mode: AsyncMode,
    /// Real wall-clock run duration.
    pub run_for: Duration,
    /// Synthetic work units spun per update (real mt19937 calls).
    pub added_work_units: u64,
    /// Channel configuration (paper: capacity 2 benchmarking, 64 QoS).
    pub channel: ChannelConfig,
    /// Mode-1 chunk duration.
    pub rolling_chunk: Duration,
    /// Mode-2 epoch.
    pub fixed_epoch: Duration,
    pub seed: u64,
}

impl Default for ThreadExecConfig {
    fn default() -> Self {
        Self {
            mode: AsyncMode::BestEffort,
            run_for: Duration::from_millis(200),
            added_work_units: 0,
            channel: ChannelConfig::qos(),
            rolling_chunk: Duration::from_millis(10),
            fixed_epoch: Duration::from_secs(1),
            seed: 1,
        }
    }
}

/// Result of an on-hardware run.
pub struct ThreadExecResult<W> {
    pub shards: Vec<W>,
    pub updates: Vec<u64>,
    pub elapsed: Duration,
    pub attempted_sends: u64,
    pub successful_sends: u64,
}

impl<W> ThreadExecResult<W> {
    /// Mean per-thread update rate (updates per second of wall time).
    pub fn update_rate_per_cpu_hz(&self) -> f64 {
        if self.updates.is_empty() {
            return 0.0;
        }
        let mean = self.updates.iter().sum::<u64>() as f64 / self.updates.len() as f64;
        mean / self.elapsed.as_secs_f64()
    }

    pub fn overall_failure_rate(&self) -> f64 {
        if self.attempted_sends == 0 {
            0.0
        } else {
            1.0 - self.successful_sends as f64 / self.attempted_sends as f64
        }
    }
}

/// Run `shards` on one hardware thread each until the deadline.
pub fn run_threads<W>(cfg: ThreadExecConfig, shards: Vec<W>) -> ThreadExecResult<W>
where
    W: ShardWorkload + Send + 'static,
    W::Msg: Send + 'static,
{
    let n = shards.len();
    let specs: Vec<_> = shards.iter().map(|s| s.channels()).collect();

    // Build one duct per directed channel; distribute endpoints.
    // inlets[p][local_ch], outlets[p][local_ch in peer's spec order].
    let mut inlets: Vec<Vec<Option<ThreadInlet<Envelope<W::Msg>>>>> =
        (0..n).map(|p| (0..specs[p].len()).map(|_| None).collect()).collect();
    let mut outlets: Vec<Vec<Option<ThreadOutlet<Envelope<W::Msg>>>>> =
        (0..n).map(|p| (0..specs[p].len()).map(|_| None).collect()).collect();

    for (src, specs_p) in specs.iter().enumerate() {
        for (src_ch, spec) in specs_p.iter().enumerate() {
            let (inlet, outlet) = thread_duct::<Envelope<W::Msg>>(cfg.channel);
            inlets[src][src_ch] = Some(inlet);
            // The receiver reads this duct via its reciprocal channel slot.
            let dst_ch = specs[spec.peer]
                .iter()
                .position(|s| s.peer == src && s.layer == reciprocal_layer(spec.layer))
                .expect("reciprocal channel");
            outlets[spec.peer][dst_ch] = Some(outlet);
        }
    }

    let barrier = Arc::new(Barrier::new(n));
    let stop = Arc::new(AtomicBool::new(false));
    let decision = Arc::new(AtomicBool::new(false));
    let start = Instant::now();
    let deadline = start + cfg.run_for;

    let mut handles = Vec::with_capacity(n);
    for (rank, shard) in shards.into_iter().enumerate() {
        let my_inlets: Vec<_> = std::mem::take(&mut inlets[rank])
            .into_iter()
            .map(Option::unwrap)
            .collect();
        let my_outlets: Vec<_> = std::mem::take(&mut outlets[rank])
            .into_iter()
            .map(Option::unwrap)
            .collect();
        let barrier = Arc::clone(&barrier);
        let stop = Arc::clone(&stop);
        let decision = Arc::clone(&decision);
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            worker(rank, shard, my_inlets, my_outlets, barrier, stop, decision, cfg, deadline)
        }));
    }

    let mut shards_out: Vec<(usize, W)> = Vec::with_capacity(n);
    let mut updates = vec![0u64; n];
    let mut attempted = 0u64;
    let mut successful = 0u64;
    for h in handles {
        let out = h.join().expect("worker panicked");
        updates[out.rank] = out.updates;
        attempted += out.attempted;
        successful += out.successful;
        shards_out.push((out.rank, out.shard));
    }
    shards_out.sort_by_key(|(r, _)| *r);

    ThreadExecResult {
        shards: shards_out.into_iter().map(|(_, s)| s).collect(),
        updates,
        elapsed: start.elapsed(),
        attempted_sends: attempted,
        successful_sends: successful,
    }
}

struct WorkerOut<W> {
    rank: usize,
    shard: W,
    updates: u64,
    attempted: u64,
    successful: u64,
}

#[allow(clippy::too_many_arguments)]
fn worker<W>(
    rank: usize,
    mut shard: W,
    inlets: Vec<ThreadInlet<Envelope<W::Msg>>>,
    outlets: Vec<ThreadOutlet<Envelope<W::Msg>>>,
    barrier: Arc<Barrier>,
    stop: Arc<AtomicBool>,
    decision: Arc<AtomicBool>,
    cfg: ThreadExecConfig,
    deadline: Instant,
) -> WorkerOut<W>
where
    W: ShardWorkload,
{
    let mut rng = Xoshiro256::new(cfg.seed ^ (rank as u64).wrapping_mul(0x9E37_79B9));
    let mut spinner = WorkUnitSpinner::new(cfg.seed as u32 ^ rank as u32);
    let mut touch: Vec<TouchCounter> = vec![TouchCounter::default(); inlets.len()];
    let mut updates = 0u64;
    let mut chunk_start = Instant::now();
    let mut next_fixed = Instant::now() + cfg.fixed_epoch;
    let communicate = cfg.mode.communicates();
    // Both scratch buffers are reused across channels and iterations
    // (absorb drains `pull_scratch`; `env_scratch` is drained below), so
    // the pull path allocates nothing in steady state — the real-thread
    // counterpart of the DES engine's scratch buffer.
    let mut pull_scratch: Vec<W::Msg> = Vec::new();
    let mut env_scratch: Vec<Envelope<W::Msg>> = Vec::new();

    loop {
        // Pull/absorb phase.
        if communicate {
            for (ch, outlet) in outlets.iter().enumerate() {
                env_scratch.clear();
                outlet.pull_all_into(&mut env_scratch);
                if env_scratch.is_empty() {
                    continue;
                }
                let max_touch = env_scratch.iter().map(|e| e.touch).max().unwrap();
                touch[ch].on_receive(max_touch);
                pull_scratch.clear();
                pull_scratch.extend(env_scratch.drain(..).map(|e| e.payload));
                shard.absorb(ch, &mut pull_scratch);
            }
        }

        // Compute phase (real synthetic work + real algorithm step).
        if cfg.added_work_units > 0 {
            std::hint::black_box(spinner.spin(cfg.added_work_units));
        }
        let outputs = shard.step(&mut rng);

        // Send phase.
        if communicate {
            for (ch, payload) in outputs {
                inlets[ch].put(Envelope {
                    touch: touch[ch].outgoing(),
                    payload,
                });
            }
        }
        updates += 1;

        // Termination: any thread past the deadline raises the stop flag.
        if Instant::now() >= deadline {
            stop.store(true, Ordering::SeqCst);
        }

        if cfg.mode.uses_barriers() {
            // Deadlock-free exit protocol. A thread enters the barrier
            // when its mode calls for one OR when stop has been raised, so
            // all threads execute the same barrier sequence. Whether to
            // exit is decided by consensus: the barrier leader latches the
            // stop flag between two waits, so every thread observes the
            // identical decision for this generation. (A plain post-wait
            // `stop` check races: one thread can raise `stop` after its
            // release and re-enter the next barrier while a peer, reading
            // the freshly-raised flag after the *previous* release, exits
            // — deadlocking the re-entrant thread.)
            let stopping = stop.load(Ordering::SeqCst);
            let due = match cfg.mode {
                AsyncMode::Sync => true,
                AsyncMode::RollingBarrier => chunk_start.elapsed() >= cfg.rolling_chunk,
                AsyncMode::FixedBarrier => Instant::now() >= next_fixed,
                _ => unreachable!(),
            };
            if due || stopping {
                let res = barrier.wait();
                if res.is_leader() {
                    decision.store(stop.load(Ordering::SeqCst), Ordering::SeqCst);
                }
                barrier.wait();
                chunk_start = Instant::now();
                if cfg.mode == AsyncMode::FixedBarrier {
                    next_fixed += cfg.fixed_epoch;
                }
                if decision.load(Ordering::SeqCst) {
                    break;
                }
            }
        } else if stop.load(Ordering::SeqCst) {
            break;
        }
    }

    let mut totals = crate::conduit::CounterTranche::default();
    for inlet in &inlets {
        totals.add(&inlet.stats().tranche());
    }
    WorkerOut {
        rank,
        shard,
        updates,
        attempted: totals.attempted_sends,
        successful: totals.successful_sends,
    }
}

use crate::workloads::reciprocal_layer;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{PlacementKind, Topology};
    use crate::workloads::{GcConfig, GraphColoringShard};

    fn gc_shards(n: usize, simels: usize, seed: u64) -> (Topology, Vec<GraphColoringShard>) {
        let topo = Topology::new(n, PlacementKind::SingleNode);
        let mut rng = Xoshiro256::new(seed);
        let cfg = GcConfig {
            simels_per_proc: simels,
            ..GcConfig::default()
        };
        let shards = (0..n)
            .map(|r| GraphColoringShard::new(cfg, &topo, r, &mut rng))
            .collect();
        (topo, shards)
    }

    #[test]
    fn best_effort_two_threads() {
        let (_, shards) = gc_shards(2, 16, 1);
        let result = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(100),
                ..Default::default()
            },
            shards,
        );
        assert!(result.updates.iter().all(|&u| u > 10));
        assert!(result.attempted_sends > 0);
        assert!(result.update_rate_per_cpu_hz() > 10.0);
    }

    #[test]
    fn sync_mode_two_threads_lockstep() {
        let (_, shards) = gc_shards(2, 4, 2);
        let result = run_threads(
            ThreadExecConfig {
                mode: AsyncMode::Sync,
                run_for: Duration::from_millis(80),
                ..Default::default()
            },
            shards,
        );
        let d = result.updates[0].abs_diff(result.updates[1]);
        assert!(d <= 1, "updates={:?}", result.updates);
    }

    #[test]
    fn no_comm_mode_is_silent() {
        let (_, shards) = gc_shards(2, 4, 3);
        let result = run_threads(
            ThreadExecConfig {
                mode: AsyncMode::NoComm,
                run_for: Duration::from_millis(50),
                ..Default::default()
            },
            shards,
        );
        assert_eq!(result.attempted_sends, 0);
    }

    #[test]
    fn rolling_barrier_completes() {
        let (_, shards) = gc_shards(2, 4, 4);
        let result = run_threads(
            ThreadExecConfig {
                mode: AsyncMode::RollingBarrier,
                run_for: Duration::from_millis(60),
                rolling_chunk: Duration::from_millis(5),
                ..Default::default()
            },
            shards,
        );
        assert!(result.updates.iter().all(|&u| u > 0));
    }

    #[test]
    fn added_work_slows_update_rate() {
        let (_, shards_a) = gc_shards(1, 4, 5);
        let (_, shards_b) = gc_shards(1, 4, 5);
        let fast = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(60),
                ..Default::default()
            },
            shards_a,
        );
        let slow = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(60),
                added_work_units: 100_000,
                ..Default::default()
            },
            shards_b,
        );
        assert!(
            fast.update_rate_per_cpu_hz() > 3.0 * slow.update_rate_per_cpu_hz(),
            "fast={} slow={}",
            fast.update_rate_per_cpu_hz(),
            slow.update_rate_per_cpu_hz()
        );
    }

    #[test]
    fn converges_on_hardware_sync() {
        // Barrier-per-update gives perfect communication: the coloring
        // must actually settle.
        let (topo, shards) = gc_shards(2, 64, 6);
        let result = run_threads(
            ThreadExecConfig {
                mode: AsyncMode::Sync,
                run_for: Duration::from_millis(300),
                ..Default::default()
            },
            shards,
        );
        let conflicts =
            crate::workloads::graph_coloring::global_conflicts(&topo, &result.shards);
        assert!(conflicts < 20, "conflicts={conflicts}");
    }

    #[test]
    fn best_effort_on_one_core_still_beats_random() {
        // On a single hardware core, OS timeslices (~10 ms) make ghost
        // state extremely stale, so borders churn — the interesting
        // property is that best-effort still improves on the random
        // baseline (~2/3 of vertices conflicted for 3 colors) rather than
        // diverging. True concurrent-thread behaviour is exercised by the
        // DES, which models per-update message exchange.
        let (topo, shards) = gc_shards(2, 64, 6);
        let result = run_threads(
            ThreadExecConfig {
                run_for: Duration::from_millis(300),
                ..Default::default()
            },
            shards,
        );
        let conflicts =
            crate::workloads::graph_coloring::global_conflicts(&topo, &result.shards);
        let random_baseline = 128 * 2 / 3;
        assert!(conflicts < random_baseline + 10, "conflicts={conflicts}");
    }
}
