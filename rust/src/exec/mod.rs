//! Real `std::thread` executor over the same workload API as the DES.

pub mod threads;

pub use threads::{ThreadExecConfig, ThreadExecResult};
