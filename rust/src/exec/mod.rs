//! Real `std::thread` executors over the same workload API as the DES.
//!
//! The paper's measurements are taken on *hardware* — real threads,
//! real clocks, real mutex-mediated shared memory (§III-A/E) — while
//! the DES predicts the same quantities in virtual time. This module is
//! the hardware half of that cross-validation axis:
//!
//! * [`threads::run_threads`] drives [`crate::workloads::ShardWorkload`]
//!   shards on real threads, with windowed QoS capture (reusing the
//!   [`crate::qos`] types, so every metric query and report table works
//!   on hardware runs), shard-multiplexed oversubscription for 64–256
//!   shard runs on small-core boxes (`EBCOMM_THREADS` caps the real
//!   thread count), and scripted fault scenarios;
//! * [`multiproc::run_multiproc`] goes one step further down the paper's
//!   stack: shards partitioned across real OS *processes* wired by
//!   nonblocking unix-socket ducts ([`crate::conduit::socket`]), so
//!   best-effort sends genuinely fail against kernel buffers and dead
//!   peers, with sketch-merged QoS and a serialize/enqueue/transport/
//!   drain stage latency breakdown per message;
//! * [`hw_faults::HwFaultTimeline`] compiles a
//!   [`crate::faults::FaultScenario`] into wall-clock onset/expiry
//!   checkpoints the worker loops consult between simsteps.
//!
//! **Determinism contract** (see `rust/tests/golden/README.md`): DES
//! runs are bit-reproducible and golden-gated; hardware runs are
//! *never* golden-gated — wall clocks, OS scheduling, and mutex
//! contention make every run unique. Tests against hardware runs assert
//! ordinal relations (mode 0 slower than mode 3), structural facts
//! (window/phase-tag shapes, zero sync-mode drops), and tolerance-based
//! bounds only.

pub mod hw_faults;
pub mod multiproc;
pub mod threads;

pub use hw_faults::HwFaultTimeline;
pub use multiproc::{run_multiproc, ChildReport, MultiprocConfig, MultiprocResult};
pub use threads::{run_threads, ThreadExecConfig, ThreadExecResult};
