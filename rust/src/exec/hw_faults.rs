//! Wall-clock compilation of [`FaultScenario`]s for the real-thread
//! executor.
//!
//! The DES interprets scenarios through an event-driven overlay
//! ([`crate::faults::FaultRuntime`]): commands (`RestoreNode`/`Heal`) pop
//! currently-active degradations off a state machine advanced by
//! scheduler wakes. Real threads have no scheduler — workers consult a
//! wall clock between simsteps — so this module resolves the whole
//! timeline at compile time into *onset/expiry checkpoints*: each
//! windowed event gets an **effective end** (its natural window end,
//! truncated by the earliest command that would have deactivated it),
//! after which activity is the pure predicate `start <= t < end`. The
//! closed form is equivalent to replaying the overlay's `(time, index)`
//! event order — model-checked against an event-driven replay in
//! `python/hw_fault_timeline_fuzz.py` (4k randomized scenarios) before
//! this port, mirroring how the overlay itself was validated in PR 3.
//!
//! Interpretation on hardware:
//!
//! * scenario *node* indices address **shard ranks** (the thread executor
//!   places every shard on one host node, so the DES's node axis
//!   collapses onto the rank axis — like `PlacementKind::OnePerNode`);
//! * every shard↔shard link counts as *crossnode* for storms and
//!   partitions (there is no second hierarchy level to exempt);
//! * event times are **wall-clock nanoseconds from run start**;
//! * effects are realized by the worker loop (`exec/threads.rs`):
//!   `DegradeNode.speed_factor` becomes extra spin work on the degraded
//!   shard, link-fault `extra_drop_prob` becomes forced put failures, and
//!   link-fault `latency_factor` becomes a pre-send spin delay.
//!
//! Wall-clock runs are inherently non-reproducible (see
//! `rust/tests/golden/README.md`), but the timeline itself is pure data:
//! `phase_at`/`drop_prob`/`speed_factor` are deterministic functions of
//! `(scenario, t)`, so QoS attribution tags are exact even though the
//! metric values jitter.

use crate::faults::{clique_of, FaultKind, FaultScenario, ScenarioPhase};
use crate::util::Nanos;

/// One compiled scenario event: its activity window with commands
/// resolved. Commands themselves compile to empty windows (`start ==
/// end`) so event indices — and hence [`ScenarioPhase`] bits — stay
/// aligned with the source scenario.
#[derive(Clone, Copy, Debug)]
struct HwEvent {
    start: Nanos,
    /// Effective end: natural window end, truncated by the earliest
    /// `RestoreNode`/`Heal` at-or-after onset that targets this event.
    end: Nanos,
    kind: FaultKind,
}

impl HwEvent {
    #[inline]
    fn active(&self, t: Nanos) -> bool {
        t >= self.start && t < self.end
    }

    /// Is a flap event in its degraded sub-phase at `t`? (The DES starts
    /// flaps "on" and toggles every `on_for`/`off_for`; the closed form
    /// below reproduces that cadence.) Always true for non-flap events —
    /// their whole window is the degraded phase.
    #[inline]
    fn degraded_sub_phase(&self, t: Nanos) -> bool {
        if let FaultKind::FlapLink { on_for, off_for, .. } = self.kind {
            let period = on_for.saturating_add(off_for);
            if period == 0 {
                return true;
            }
            (t - self.start) % period < on_for
        } else {
            true
        }
    }
}

/// Does command `cmd` deactivate windowed event `kind` when active?
fn command_targets(cmd: &FaultKind, kind: &FaultKind) -> bool {
    match cmd {
        FaultKind::Heal => true,
        FaultKind::RestoreNode { node } => matches!(
            kind,
            FaultKind::DegradeNode { node: n, .. } | FaultKind::FlapLink { node: n, .. }
                if n == node
        ),
        _ => false,
    }
}

/// A [`FaultScenario`] compiled to wall-clock checkpoints for the
/// real-thread executor. Cheap to consult per worker pass: every query is
/// `O(events)` over a `<= 64`-entry table of pure arithmetic — orders of
/// magnitude below one workload step.
#[derive(Clone, Debug)]
pub struct HwFaultTimeline {
    events: Vec<HwEvent>,
    n_ranks: usize,
}

impl HwFaultTimeline {
    /// Compile `scenario` for an allocation of `n_ranks` shards.
    /// Validates the scenario (panics loudly on malformed input, like the
    /// DES path) and resolves commands into effective end times.
    pub fn compile(scenario: &FaultScenario, n_ranks: usize) -> Self {
        scenario.validate(n_ranks);
        let evs = &scenario.events;
        let events = evs
            .iter()
            .enumerate()
            .map(|(k, ev)| {
                if ev.kind.is_instant() {
                    // Commands hold no window of their own.
                    return HwEvent {
                        start: ev.start,
                        end: ev.start,
                        kind: ev.kind,
                    };
                }
                let mut end = ev.end();
                for (j, c) in evs.iter().enumerate() {
                    if !command_targets(&c.kind, &ev.kind) {
                        continue;
                    }
                    // A command deactivates only *currently active*
                    // events: it must fire at-or-after this event's
                    // onset. On a start-time tie the overlay fires in
                    // event-index order, so a lower-indexed command
                    // fires before the onset and misses it.
                    let after_onset =
                        c.start > ev.start || (c.start == ev.start && j > k);
                    if after_onset {
                        end = end.min(c.start);
                    }
                }
                HwEvent {
                    start: ev.start,
                    end,
                    kind: ev.kind,
                }
            })
            .collect();
        Self { events, n_ranks }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The set of scenario events active at wall offset `t` — the tag
    /// QoS windows carry for time-resolved attribution. Flap events count
    /// as active across their whole window (degraded or clean
    /// sub-phase), matching the DES overlay's phase semantics.
    pub fn phase_at(&self, t: Nanos) -> ScenarioPhase {
        let mut p = ScenarioPhase::QUIESCENT;
        for (k, ev) in self.events.iter().enumerate() {
            if ev.active(t) {
                p = p.union(ScenarioPhase::single(k));
            }
        }
        p
    }

    /// Earliest compiled checkpoint strictly after `t` (onset, expiry, or
    /// flap toggle), if any — lets callers cache derived state between
    /// transitions instead of recomputing per pass.
    pub fn next_checkpoint_after(&self, t: Nanos) -> Option<Nanos> {
        let mut next: Option<Nanos> = None;
        let mut fold = |c: Nanos| {
            if c > t {
                next = Some(next.map_or(c, |n| n.min(c)));
            }
        };
        for ev in &self.events {
            fold(ev.start);
            if ev.end != Nanos::MAX {
                fold(ev.end);
            }
            if let FaultKind::FlapLink { on_for, off_for, .. } = ev.kind {
                if ev.active(t) && t >= ev.start {
                    let period = on_for.saturating_add(off_for);
                    if period > 0 {
                        let into = (t - ev.start) % period;
                        let boundary = if into < on_for { on_for } else { period };
                        fold((t - into).saturating_add(boundary).min(ev.end));
                    }
                }
            }
        }
        next
    }

    /// Added per-send drop probability on the directed link `a -> b` at
    /// wall offset `t` (clamped to 1), folding every active link-scoped
    /// fault: node degradations and flaps touching either endpoint,
    /// storms on every link, partition cuts on clique-crossing links.
    pub fn drop_prob(&self, t: Nanos, a: usize, b: usize) -> f64 {
        let mut p = 0.0;
        for ev in &self.events {
            if !ev.active(t) {
                continue;
            }
            match ev.kind {
                FaultKind::DegradeNode { node, fault } if node == a || node == b => {
                    p += fault.extra_drop_prob;
                }
                FaultKind::FlapLink { node, fault, .. } if node == a || node == b => {
                    if ev.degraded_sub_phase(t) {
                        p += fault.extra_drop_prob;
                    }
                }
                FaultKind::CongestionStorm { fault } => {
                    p += fault.extra_drop_prob;
                }
                FaultKind::PartitionCliques { cliques, cut } => {
                    if clique_of(a, cliques, self.n_ranks)
                        != clique_of(b, cliques, self.n_ranks)
                    {
                        p += cut.extra_drop_prob;
                    }
                }
                _ => {}
            }
        }
        p.min(1.0)
    }

    /// Combined latency inflation on the directed link `a -> b` at wall
    /// offset `t` (`1.0` when quiescent). Matches the DES composition:
    /// node degradations fold multiplicatively *within* each endpoint
    /// and the link takes the **max** of the two endpoints' health
    /// (`sim/engine.rs` scales service/latency by
    /// `max(src_profile, dst_profile)`), while link-scoped modifiers
    /// (flap, storm, partition) stack multiplicatively on top. The
    /// worker realizes the result as pre-send spin.
    pub fn latency_factor(&self, t: Nanos, a: usize, b: usize) -> f64 {
        let mut health_a = 1.0;
        let mut health_b = 1.0;
        let mut mods = 1.0;
        for ev in &self.events {
            if !ev.active(t) {
                continue;
            }
            match ev.kind {
                FaultKind::DegradeNode { node, fault } => {
                    if node == a {
                        health_a *= fault.latency_factor;
                    }
                    if node == b {
                        health_b *= fault.latency_factor;
                    }
                }
                FaultKind::FlapLink { node, fault, .. } if node == a || node == b => {
                    if ev.degraded_sub_phase(t) {
                        mods *= fault.latency_factor;
                    }
                }
                FaultKind::CongestionStorm { fault } => {
                    mods *= fault.latency_factor;
                }
                FaultKind::PartitionCliques { cliques, cut } => {
                    if clique_of(a, cliques, self.n_ranks)
                        != clique_of(b, cliques, self.n_ranks)
                    {
                        mods *= cut.latency_factor;
                    }
                }
                _ => {}
            }
        }
        health_a.max(health_b) * mods
    }

    /// Combined compute slowdown for shard `rank` at wall offset `t`
    /// (product of active `DegradeNode.speed_factor`s; `1.0` when
    /// healthy). The worker realizes it as extra spin work per update.
    pub fn speed_factor(&self, t: Nanos, rank: usize) -> f64 {
        let mut f = 1.0;
        for ev in &self.events {
            if !ev.active(t) {
                continue;
            }
            if let FaultKind::DegradeNode { node, fault } = ev.kind {
                if node == rank {
                    f *= fault.speed_factor;
                }
            }
        }
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{LinkFault, NodeFault, ALWAYS};
    use crate::util::MILLI;

    #[test]
    fn empty_scenario_is_quiescent_everywhere() {
        let tl = HwFaultTimeline::compile(&FaultScenario::default(), 4);
        assert!(tl.is_empty());
        for t in [0, 1, MILLI, Nanos::MAX - 1] {
            assert!(tl.phase_at(t).is_quiescent());
            assert_eq!(tl.drop_prob(t, 0, 1), 0.0);
            assert_eq!(tl.speed_factor(t, 0), 1.0);
            assert_eq!(tl.latency_factor(t, 0, 1), 1.0);
        }
        assert_eq!(tl.next_checkpoint_after(0), None);
    }

    #[test]
    fn windowed_degrade_activates_and_expires() {
        let sc = FaultScenario::default().with(10, 20, FaultKind::DegradeNode {
            node: 1,
            fault: NodeFault::lac417(),
        });
        let tl = HwFaultTimeline::compile(&sc, 4);
        assert!(tl.phase_at(9).is_quiescent());
        assert!(tl.phase_at(10).contains(0));
        assert!(tl.phase_at(29).contains(0));
        assert!(tl.phase_at(30).is_quiescent(), "window end is exclusive");
        // Degrade effects: shard 1's compute and its links only.
        assert!(tl.speed_factor(15, 1) > 1.0);
        assert_eq!(tl.speed_factor(15, 0), 1.0);
        assert!(tl.drop_prob(15, 0, 1) > 0.0);
        assert!(tl.drop_prob(15, 1, 2) > 0.0);
        assert_eq!(tl.drop_prob(15, 0, 2), 0.0);
        assert_eq!(tl.next_checkpoint_after(0), Some(10));
        assert_eq!(tl.next_checkpoint_after(10), Some(30));
        assert_eq!(tl.next_checkpoint_after(30), None);
    }

    #[test]
    fn restore_truncates_always_on_degrade() {
        // degrade_recover: ALWAYS degrade at t=10 restored at t=50.
        let sc = FaultScenario::degrade_recover(2, 10, 40);
        let tl = HwFaultTimeline::compile(&sc, 4);
        assert!(tl.phase_at(10).contains(0));
        assert!(tl.phase_at(49).contains(0));
        assert!(tl.phase_at(50).is_quiescent(), "restore deactivates");
        // The command event itself never appears in a phase.
        assert!(!tl.phase_at(50).contains(1));
    }

    #[test]
    fn restore_only_hits_its_node_and_heal_hits_all() {
        let degrade = |node| FaultKind::DegradeNode {
            node,
            fault: NodeFault::lac417(),
        };
        let sc = FaultScenario::default()
            .with(0, ALWAYS, degrade(0))
            .with(0, ALWAYS, degrade(1))
            .with(20, 0, FaultKind::RestoreNode { node: 0 })
            .with(40, 0, FaultKind::Heal);
        let tl = HwFaultTimeline::compile(&sc, 4);
        assert!(tl.phase_at(10).contains(0) && tl.phase_at(10).contains(1));
        assert!(!tl.phase_at(25).contains(0), "restore hit node 0");
        assert!(tl.phase_at(25).contains(1), "node 1 untouched by restore");
        assert!(tl.phase_at(45).is_quiescent(), "heal hit everything");
    }

    #[test]
    fn command_before_onset_is_a_no_op() {
        let sc = FaultScenario::default()
            .with(5, 0, FaultKind::Heal)
            .with(10, ALWAYS, FaultKind::DegradeNode {
                node: 0,
                fault: NodeFault::fail_stop(),
            });
        let tl = HwFaultTimeline::compile(&sc, 2);
        assert!(tl.phase_at(100).contains(1), "later onset survives");
    }

    #[test]
    fn same_instant_tie_follows_event_index_order() {
        // Heal at the same instant as an onset: a higher-indexed command
        // fires after the onset and kills it; a lower-indexed one misses.
        let degrade = FaultKind::DegradeNode {
            node: 0,
            fault: NodeFault::lac417(),
        };
        let killed = FaultScenario::default()
            .with(10, ALWAYS, degrade)
            .with(10, 0, FaultKind::Heal);
        let tl = HwFaultTimeline::compile(&killed, 2);
        assert!(tl.phase_at(10).is_quiescent() && tl.phase_at(50).is_quiescent());

        let survives = FaultScenario::default()
            .with(10, 0, FaultKind::Heal)
            .with(10, ALWAYS, degrade);
        let tl = HwFaultTimeline::compile(&survives, 2);
        assert!(tl.phase_at(50).contains(1));
    }

    #[test]
    fn storm_hits_every_link_and_partition_only_crossings() {
        let sc = FaultScenario::default()
            .with(0, 100, FaultKind::CongestionStorm {
                fault: LinkFault::storm(),
            })
            .with(0, 100, FaultKind::PartitionCliques {
                cliques: 2,
                cut: LinkFault::cut(),
            });
        let tl = HwFaultTimeline::compile(&sc, 4);
        let storm_drop = LinkFault::storm().extra_drop_prob;
        // Ranks 0,1 vs 2,3 (contiguous cliques). Within a clique only the
        // storm applies; across, the cut (p=1) clamps the sum at 1.
        assert!((tl.drop_prob(5, 0, 1) - storm_drop).abs() < 1e-12);
        assert_eq!(tl.drop_prob(5, 0, 2), 1.0);
        assert!(tl.latency_factor(5, 0, 1) > 1.0);
        assert!(tl.phase_at(5).len() == 2);
    }

    #[test]
    fn degrade_latency_takes_endpoint_max_like_the_des() {
        let degrade = |node, lf| FaultKind::DegradeNode {
            node,
            fault: NodeFault {
                speed_factor: 1.0,
                jitter_sigma: 0.0,
                stall_mean_ns: 0.0,
                latency_factor: lf,
                extra_drop_prob: 0.0,
            },
        };
        let storm = FaultKind::CongestionStorm {
            fault: LinkFault {
                latency_factor: 5.0,
                extra_drop_prob: 0.0,
            },
        };
        let sc = FaultScenario::default()
            .with(0, 100, degrade(0, 2.0))
            .with(0, 100, degrade(1, 3.0))
            .with(0, 100, storm);
        let tl = HwFaultTimeline::compile(&sc, 4);
        // Endpoint healths take the max (DES: `max(src, dst)` profile
        // scaling), link mods multiply on top: max(2,3) * 5, not 2*3*5.
        assert_eq!(tl.latency_factor(10, 0, 1), 15.0);
        // One degraded endpoint: max(2, 1) * 5.
        assert_eq!(tl.latency_factor(10, 0, 2), 10.0);
        // Two degrades on the SAME node fold multiplicatively first.
        let sc2 = FaultScenario::default()
            .with(0, 100, degrade(0, 2.0))
            .with(0, 100, degrade(0, 4.0));
        let tl2 = HwFaultTimeline::compile(&sc2, 2);
        assert_eq!(tl2.latency_factor(10, 0, 1), 8.0);
    }

    #[test]
    fn flap_sub_phase_cadence_matches_overlay() {
        // on 10 / off 5 from t=100: degraded [100,110), clean [110,115)…
        let sc = FaultScenario::flapping_clique(1, 100, 60, 10, 5);
        let tl = HwFaultTimeline::compile(&sc, 4);
        for (t, on) in [
            (100, true),
            (109, true),
            (110, false),
            (114, false),
            (115, true),
            (129, false),
        ] {
            assert!(tl.phase_at(t).contains(0), "flap active across window");
            let p = tl.drop_prob(t, 0, 1);
            assert_eq!(p > 0.0, on, "t={t}: drop={p}");
        }
        // Whole window expires at 160.
        assert!(tl.phase_at(160).is_quiescent());
        // Next checkpoint from inside an on-phase is the toggle.
        assert_eq!(tl.next_checkpoint_after(101), Some(110));
        assert_eq!(tl.next_checkpoint_after(110), Some(115));
    }

    #[test]
    #[should_panic(expected = "node 9")]
    fn compile_validates_like_the_des_path() {
        HwFaultTimeline::compile(&FaultScenario::lac417(9), 4);
    }
}
