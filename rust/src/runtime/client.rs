//! PJRT client wrapper.
//!
//! One CPU PJRT client serves the whole process; compiled executables are
//! cached by artifact name. Python/JAX is involved only at build time
//! (`make artifacts`); at run time this module loads HLO *text* — the
//! interchange format that round-trips cleanly between jax ≥ 0.5 and the
//! `xla` crate's xla_extension 0.5.1 (serialized protos do not; see
//! DESIGN.md and /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::executor::CompiledKernel;

/// Process-wide PJRT runtime.
pub struct RuntimeClient {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, CompiledKernel>>,
}

impl RuntimeClient {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO-text artifact, or fetch it from the cache.
    pub fn load_hlo_text(&self, name: &str, path: impl AsRef<Path>) -> Result<CompiledKernel> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(k) = cache.get(name) {
                return Ok(k.clone());
            }
        }
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let kernel = CompiledKernel::new(name.to_string(), exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), kernel.clone());
        Ok(kernel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = RuntimeClient::cpu().expect("PJRT CPU client");
        assert!(rt.device_count() >= 1);
        assert!(!rt.platform_name().is_empty());
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let rt = RuntimeClient::cpu().unwrap();
        assert!(rt.load_hlo_text("nope", "/definitely/not/here.hlo.txt").is_err());
    }
}
