//! Artifact manifest: what `make artifacts` produced.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt`, one line per
//! lowered kernel:
//!
//! ```text
//! name<TAB>file<TAB>comma-separated-input-shapes<TAB>comma-separated-output-shapes
//! gc_update_64<TAB>gc_update_64.hlo.txt<TAB>u8[64],u8[64,4],f32[64,3],f32[64]<TAB>u8[64],f32[64,3],i32[]
//! ```
//!
//! Shapes are informational (consumed by integration tests and error
//! messages); the PJRT executable itself enforces them.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One artifact entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub input_shapes: Vec<String>,
    pub output_shapes: Vec<String>,
}

/// Parsed manifest, keyed by artifact name.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    dir: PathBuf,
    entries: BTreeMap<String, ArtifactSpec>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(dir: PathBuf, text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 4 {
                bail!(
                    "manifest line {}: expected 4 tab-separated fields, got {}",
                    lineno + 1,
                    fields.len()
                );
            }
            let spec = ArtifactSpec {
                name: fields[0].to_string(),
                file: dir.join(fields[1]),
                input_shapes: split_shapes(fields[2]),
                output_shapes: split_shapes(fields[3]),
            };
            if entries.insert(spec.name.clone(), spec).is_some() {
                bail!("manifest line {}: duplicate artifact name", lineno + 1);
            }
        }
        Ok(Self { dir, entries })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.entries.get(name)
    }

    /// Artifact entry or a descriptive error.
    pub fn require(&self, name: &str) -> Result<&ArtifactSpec> {
        self.entries.get(name).with_context(|| {
            format!(
                "artifact '{name}' not in manifest (have: {}) — run `make artifacts`",
                self.entries.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// Default artifact directory: `$EBCOMM_ARTIFACTS` or `artifacts/`
    /// relative to the crate root.
    pub fn default_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("EBCOMM_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        // CARGO_MANIFEST_DIR points at the crate root in tests/benches.
        let root = std::env::var("CARGO_MANIFEST_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("."));
        root.join("artifacts")
    }
}

/// Parse `u8[64],f32[64,3]` — commas inside brackets are dimension
/// separators, so split on commas *outside* brackets.
fn split_shapes(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '[' => {
                depth += 1;
                cur.push(c);
            }
            ']' => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if depth == 0 => {
                if !cur.trim().is_empty() {
                    out.push(cur.trim().to_string());
                }
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_lines() {
        let text = "# comment\n\
                    gc_update_64\tgc_update_64.hlo.txt\tu8[64],u8[64,4],f32[64,3],f32[64]\tu8[64],f32[64,3],i32[]\n\
                    \n\
                    cell_update_36\tcell_update_36.hlo.txt\tf32[36,8],f32[36,16],f32[36,8]\tf32[36,8],f32[36]\n";
        let m = ArtifactManifest::parse(PathBuf::from("/tmp/a"), text).unwrap();
        assert_eq!(m.len(), 2);
        let spec = m.get("gc_update_64").unwrap();
        assert_eq!(spec.file, PathBuf::from("/tmp/a/gc_update_64.hlo.txt"));
        assert_eq!(
            spec.input_shapes,
            vec!["u8[64]", "u8[64,4]", "f32[64,3]", "f32[64]"]
        );
        assert_eq!(spec.output_shapes.len(), 3);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ArtifactManifest::parse(PathBuf::new(), "just-one-field\n").is_err());
        let dup = "a\tf\tx[1]\ty[1]\na\tf\tx[1]\ty[1]\n";
        assert!(ArtifactManifest::parse(PathBuf::new(), dup).is_err());
    }

    #[test]
    fn require_reports_available_names() {
        let m = ArtifactManifest::parse(PathBuf::new(), "a\tf\tx[1]\ty[1]\n").unwrap();
        let err = m.require("zzz").unwrap_err().to_string();
        assert!(err.contains("zzz") && err.contains('a'), "{err}");
    }

    #[test]
    fn shape_splitting_handles_bracket_commas() {
        assert_eq!(
            split_shapes("u8[64,4],f32[3]"),
            vec!["u8[64,4]", "f32[3]"]
        );
        assert_eq!(split_shapes(""), Vec::<String>::new());
    }
}
