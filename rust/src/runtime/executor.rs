//! Typed execution helpers over a compiled PJRT executable.
//!
//! The AOT bridge lowers every kernel with `return_tuple=True`, so each
//! execution yields one tuple literal that we decompose into typed host
//! vectors. Supported element types mirror the `xla` crate's `NativeType`
//! set (f32/f64/i32/i64/u32/u64) — the Python side emits only f32 and i32
//! tensors (colors are i32, not u8, for exactly this reason).

use std::rc::Rc;

use anyhow::{Context, Result};

/// A host-side input tensor.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, dims: &[i64]) -> Self {
        assert_eq!(
            data.len() as i64,
            dims.iter().product::<i64>().max(1),
            "data/shape mismatch"
        );
        HostTensor::F32(data, dims.to_vec())
    }

    pub fn i32(data: Vec<i32>, dims: &[i64]) -> Self {
        assert_eq!(
            data.len() as i64,
            dims.iter().product::<i64>().max(1),
            "data/shape mismatch"
        );
        HostTensor::I32(data, dims.to_vec())
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32(data, dims) => xla::Literal::vec1(data)
                .reshape(dims)
                .context("reshaping f32 input")?,
            HostTensor::I32(data, dims) => xla::Literal::vec1(data)
                .reshape(dims)
                .context("reshaping i32 input")?,
        };
        Ok(lit)
    }
}

/// A host-side output tensor.
#[derive(Clone, Debug, PartialEq)]
pub enum HostOutput {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostOutput {
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostOutput::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            HostOutput::I32(v) => Some(v),
            _ => None,
        }
    }

    pub fn expect_f32(&self) -> &[f32] {
        self.as_f32().expect("expected f32 output")
    }

    pub fn expect_i32(&self) -> &[i32] {
        self.as_i32().expect("expected i32 output")
    }
}

/// Cached compiled kernel handle (cheaply clonable).
#[derive(Clone)]
pub struct CompiledKernel {
    name: String,
    exe: Rc<xla::PjRtLoadedExecutable>,
}

impl CompiledKernel {
    pub(crate) fn new(name: String, exe: xla::PjRtLoadedExecutable) -> Self {
        Self {
            name,
            exe: Rc::new(exe),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostOutput>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing kernel '{}'", self.name))?[0][0]
            .to_literal_sync()
            .context("sync output to host")?;
        // return_tuple=True: decompose the 1 result tuple.
        let parts = result
            .to_tuple()
            .with_context(|| format!("kernel '{}' output is not a tuple", self.name))?;
        parts
            .into_iter()
            .enumerate()
            .map(|(i, lit)| {
                let ty = lit
                    .ty()
                    .with_context(|| format!("output {i} element type"))?;
                match ty {
                    xla::ElementType::F32 => Ok(HostOutput::F32(lit.to_vec::<f32>()?)),
                    xla::ElementType::S32 => Ok(HostOutput::I32(lit.to_vec::<i32>()?)),
                    // Predicates surface as i8 buffers in XLA; the Python
                    // side converts to i32 before returning, so anything
                    // else is a contract violation.
                    other => anyhow::bail!(
                        "kernel '{}' output {i}: unsupported element type {other:?}",
                        self.name
                    ),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checked() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert!(matches!(t, HostTensor::F32(_, _)));
    }

    #[test]
    #[should_panic(expected = "data/shape mismatch")]
    fn host_tensor_shape_mismatch_panics() {
        let _ = HostTensor::i32(vec![1, 2, 3], &[2, 2]);
    }

    #[test]
    fn host_output_accessors() {
        let o = HostOutput::F32(vec![1.5]);
        assert_eq!(o.expect_f32(), &[1.5]);
        assert!(o.as_i32().is_none());
    }

    // End-to-end execution of a real artifact lives in
    // rust/tests/integration_runtime.rs (requires `make artifacts`).
}
