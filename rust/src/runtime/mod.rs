//! PJRT runtime: load and execute AOT-compiled JAX/Pallas artifacts.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{ArtifactManifest, ArtifactSpec};
pub use client::RuntimeClient;
pub use executor::{CompiledKernel, HostOutput, HostTensor};
