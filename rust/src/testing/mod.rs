//! Test-support utilities (in-repo property-testing mini-framework).

pub mod prop;
