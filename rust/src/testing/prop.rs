//! Minimal property-based testing framework.
//!
//! `proptest` is unavailable in the offline build environment (see
//! DESIGN.md §8), so this module provides the subset we need: seeded
//! random input generation, a configurable number of cases, and
//! counterexample shrinking for integer/vector inputs. Property tests on
//! coordinator invariants (routing, batching, buffer state) are written
//! against this API.
//!
//! ```no_run
//! # // no_run: doctest executables miss the xla rpath (lib tests cover this)
//! use ebcomm::testing::prop::{forall, prop_assert, Config};
//! forall(Config::default().cases(128), |g| {
//!     let n = g.u64_in(1, 100);
//!     prop_assert(n >= 1 && n <= 100, format!("n out of range: {n}"))
//! });
//! ```

use crate::util::rng::{Rng, Xoshiro256};

/// Result type of a property body: `Ok(())` or a failure message.
pub type PropResult = Result<(), String>;

/// Assert inside a property body.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 256,
            seed: 0xEBC0_77D5,
            max_shrink_iters: 512,
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Random input generator handed to property bodies.
///
/// Inputs are reproducible from `(seed, case_index)`; on failure the
/// framework reports both so the case can be replayed exactly.
pub struct Gen {
    rng: Xoshiro256,
    /// Shrink scale in (0, 1]; 1 = full-size inputs. During shrinking the
    /// framework replays the failing case with smaller scales so magnitude-
    /// dependent failures surface a smaller witness.
    scale: f64,
}

impl Gen {
    fn new(seed: u64, case: u64, scale: f64) -> Self {
        Self {
            rng: Xoshiro256::new(seed ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            scale,
        }
    }

    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64) * self.scale).ceil() as u64;
        lo + if scaled == 0 { 0 } else { self.rng.below(scaled + 1) }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_in(lo as u64, hi as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.u64_in(0, (hi - lo) as u64) as i64
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, lo + (hi - lo) * self.scale)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vector of length in `[0, max_len]` built from `f`.
    pub fn vec_of<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize_in(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    /// Access the underlying RNG for custom needs.
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

/// Run `body` against `config.cases` random inputs; panic with a replayable
/// counterexample description on the first failure (after shrinking).
pub fn forall(config: Config, body: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..config.cases as u64 {
        let mut g = Gen::new(config.seed, case, 1.0);
        if let Err(msg) = body(&mut g) {
            // Shrink: retry the same case stream at smaller scales to find
            // a smaller failing witness.
            let mut best: (f64, String) = (1.0, msg);
            let mut scale = 0.5;
            for _ in 0..config.max_shrink_iters {
                let mut g = Gen::new(config.seed, case, scale);
                match body(&mut g) {
                    Err(m) => {
                        best = (scale, m);
                        scale *= 0.5;
                        if scale < 1e-6 {
                            break;
                        }
                    }
                    Ok(()) => {
                        // Failure vanished at this scale; bisect back up.
                        scale = (scale + best.0) / 2.0;
                        if (best.0 - scale).abs() < 1e-6 {
                            break;
                        }
                    }
                }
            }
            panic!(
                "property failed (seed={:#x}, case={case}, scale={}): {}",
                config.seed, best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        // run is deterministic and side-effect observation is fine here
        let counter = std::cell::Cell::new(0usize);
        forall(Config::default().cases(50), |g| {
            counter.set(counter.get() + 1);
            let x = g.u64_in(0, 10);
            prop_assert(x <= 10, "bound")
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(Config::default().cases(64), |g| {
            let x = g.u64_in(0, 1000);
            prop_assert(x < 900, format!("x={x}"))
        });
    }

    #[test]
    fn generator_is_reproducible() {
        let mut a = Gen::new(1, 2, 1.0);
        let mut b = Gen::new(1, 2, 1.0);
        for _ in 0..32 {
            assert_eq!(a.u64_in(0, u64::MAX / 2), b.u64_in(0, u64::MAX / 2));
        }
    }

    #[test]
    fn vec_of_respects_max_len() {
        forall(Config::default().cases(64), |g| {
            let v = g.vec_of(17, |g| g.bool());
            prop_assert(v.len() <= 17, format!("len={}", v.len()))
        });
    }
}
