//! Process-to-node placement and the process mesh.
//!
//! Processes communicate on a 2-D toroidal process grid (the workloads'
//! simulation elements form a torus, partitioned into per-process tiles).
//! Placement determines which links are intranode vs internode:
//!
//! * benchmarking multiprocess runs put *each process on a distinct node*
//!   (§II-F1);
//! * weak-scaling QoS runs use either one CPU per node (homogeneous — all
//!   links internode) or four CPUs per node (heterogeneous mix, §III-F);
//! * multithread runs co-locate everything on one node.

/// How processes map onto physical nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementKind {
    /// All processes (threads) on a single node.
    SingleNode,
    /// One process per node — every link is internode.
    OnePerNode,
    /// `k` processes per node, filled in rank order.
    PerNode(usize),
}

/// Cluster topology: process count, placement, and the process mesh.
#[derive(Clone, Debug)]
pub struct Topology {
    n_procs: usize,
    placement: PlacementKind,
    rows: usize,
    cols: usize,
}

impl Topology {
    /// Build a topology for `n_procs` processes under `placement`.
    /// The process mesh is the most-square factorization of `n_procs`
    /// (rows ≤ cols), so e.g. 64 → 8×8, 2 → 1×2.
    pub fn new(n_procs: usize, placement: PlacementKind) -> Self {
        assert!(n_procs >= 1);
        let (rows, cols) = squarest_factors(n_procs);
        Self {
            n_procs,
            placement,
            rows,
            cols,
        }
    }

    pub fn n_procs(&self) -> usize {
        self.n_procs
    }

    pub fn mesh_dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn placement(&self) -> PlacementKind {
        self.placement
    }

    /// Node hosting process `p`.
    pub fn node_of(&self, p: usize) -> usize {
        debug_assert!(p < self.n_procs);
        match self.placement {
            PlacementKind::SingleNode => 0,
            PlacementKind::OnePerNode => p,
            PlacementKind::PerNode(k) => p / k.max(1),
        }
    }

    /// Number of nodes in the allocation.
    pub fn n_nodes(&self) -> usize {
        (0..self.n_procs).map(|p| self.node_of(p)).max().unwrap_or(0) + 1
    }

    /// Are two processes co-resident on one node?
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Processes resident on `p`'s node (including `p`).
    pub fn procs_on_node_of(&self, p: usize) -> usize {
        let node = self.node_of(p);
        (0..self.n_procs).filter(|&q| self.node_of(q) == node).count()
    }

    /// Mesh coordinates of process `p` (row, col).
    pub fn coords(&self, p: usize) -> (usize, usize) {
        (p / self.cols, p % self.cols)
    }

    /// Process at mesh coordinates (torus wraparound).
    pub fn at(&self, row: isize, col: isize) -> usize {
        let r = row.rem_euclid(self.rows as isize) as usize;
        let c = col.rem_euclid(self.cols as isize) as usize;
        r * self.cols + c
    }

    /// The four torus neighbors of `p` in order N, E, S, W. Degenerate
    /// meshes may repeat a neighbor or return `p` itself; callers skip
    /// self-channels.
    pub fn neighbors4(&self, p: usize) -> [usize; 4] {
        let (r, c) = self.coords(p);
        let (r, c) = (r as isize, c as isize);
        [
            self.at(r - 1, c),
            self.at(r, c + 1),
            self.at(r + 1, c),
            self.at(r, c - 1),
        ]
    }
}

/// Most-square factor pair (rows ≤ cols) of `n`.
pub fn squarest_factors(n: usize) -> (usize, usize) {
    let mut best = (1, n);
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            best = (d, n / d);
        }
        d += 1;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{forall, prop_assert, Config};

    #[test]
    fn squarest_factorizations() {
        assert_eq!(squarest_factors(64), (8, 8));
        assert_eq!(squarest_factors(2), (1, 2));
        assert_eq!(squarest_factors(16), (4, 4));
        assert_eq!(squarest_factors(256), (16, 16));
        assert_eq!(squarest_factors(7), (1, 7));
        assert_eq!(squarest_factors(12), (3, 4));
    }

    #[test]
    fn placement_node_assignment() {
        let t = Topology::new(8, PlacementKind::OnePerNode);
        assert_eq!(t.node_of(5), 5);
        assert_eq!(t.n_nodes(), 8);
        assert!(!t.same_node(0, 1));

        let t = Topology::new(8, PlacementKind::PerNode(4));
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert_eq!(t.n_nodes(), 2);
        assert!(t.same_node(0, 3));
        assert!(!t.same_node(3, 4));
        assert_eq!(t.procs_on_node_of(0), 4);

        let t = Topology::new(8, PlacementKind::SingleNode);
        assert_eq!(t.n_nodes(), 1);
        assert!(t.same_node(0, 7));
    }

    #[test]
    fn neighbors_on_8x8_mesh() {
        let t = Topology::new(64, PlacementKind::OnePerNode);
        // proc 0 at (0,0): N=(7,0)=56, E=(0,1)=1, S=(1,0)=8, W=(0,7)=7
        assert_eq!(t.neighbors4(0), [56, 1, 8, 7]);
        // center proc 27 at (3,3): N=19, E=28, S=35, W=26
        assert_eq!(t.neighbors4(27), [19, 28, 35, 26]);
    }

    #[test]
    fn degenerate_two_proc_mesh() {
        let t = Topology::new(2, PlacementKind::OnePerNode);
        assert_eq!(t.mesh_dims(), (1, 2));
        // N/S wrap to self; E/W wrap to the partner.
        assert_eq!(t.neighbors4(0), [0, 1, 0, 1]);
        assert_eq!(t.neighbors4(1), [1, 0, 1, 0]);
    }

    #[test]
    fn prop_neighbors_symmetric() {
        // q in neighbors(p) with direction d implies p in neighbors(q)
        // with the opposite direction — the torus is reciprocal (the
        // touch-counter protocol depends on this, §II-D.2).
        forall(Config::default().cases(64), |g| {
            let n = g.usize_in(1, 300);
            let t = Topology::new(n, PlacementKind::OnePerNode);
            let p = g.usize_in(0, n - 1);
            let nb = t.neighbors4(p);
            for (d, &q) in nb.iter().enumerate() {
                let back = t.neighbors4(q)[(d + 2) % 4];
                prop_assert(
                    back == p,
                    format!("n={n} p={p} d={d} q={q} back={back}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_coords_roundtrip() {
        forall(Config::default().cases(64), |g| {
            let n = g.usize_in(1, 400);
            let t = Topology::new(n, PlacementKind::SingleNode);
            let p = g.usize_in(0, n - 1);
            let (r, c) = t.coords(p);
            prop_assert(
                t.at(r as isize, c as isize) == p,
                format!("p={p} r={r} c={c}"),
            )
        });
    }
}
