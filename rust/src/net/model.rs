//! Link models: latency, service, coalescing, and drop behaviour.
//!
//! Each directed channel between two processes is governed by a
//! [`LinkModel`] chosen by placement (intranode / internode / inter-thread
//! shared memory). The model captures four empirically-grounded phenomena:
//!
//! * **Wire latency** — lognormal effective delivery latency. For
//!   internode MPI this is dominated by progress/buffering delays, not
//!   physical wire time; the paper measures ≈550 µs median internode vs
//!   ≈7 µs intranode (§III-D.3), and those measurements are our defaults.
//! * **Service interval** — minimum spacing at which messages drain out of
//!   the userspace send buffer. A send attempted while `capacity` messages
//!   are still undrained is *dropped* (the paper's only drop condition,
//!   §II-D.4).
//! * **Coalescing** — internode MPI progression delivers queued messages
//!   in bursts; arrivals within one coalescing window land together. This
//!   reproduces the paper's internode clumpiness ≈0.96 vs intranode ≈0.014
//!   (§III-D.4) and its decay to 0 under heavy compute (§III-C.4).
//! * **Baseline drop rate** — placement-specific residual drop
//!   probability. The paper measures ≈0.3 intranode-MPI delivery failure
//!   vs ≈0.0 internode (§III-D.5, acknowledged as counterintuitive —
//!   prompt internode backend buffering empties the userspace buffer);
//!   we inject it as a calibrated constant rather than modelling MPI
//!   shared-memory internals.

use crate::util::rng::{Rng, Xoshiro256};
use crate::util::{Nanos, MICRO};

/// Parameters of one link class.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// Median effective delivery latency (ns).
    pub wire_median_ns: f64,
    /// Lognormal sigma of delivery latency.
    pub wire_sigma: f64,
    /// Per-message send-buffer drain interval (ns).
    pub service_ns: f64,
    /// Arrival coalescing window (ns); 0 disables batching.
    pub coalesce_ns: Nanos,
    /// Residual per-send drop probability (calibrated; see module docs).
    pub base_drop_prob: f64,
    /// Probability that a delivery hits a pathological latency spike
    /// (descheduling, cache-invalidation storms — the paper's threading
    /// outliers of ~12 ms, SIII-E.2).
    pub spike_prob: f64,
    /// Mean spike duration (exponential), ns.
    pub spike_mean_ns: f64,
    /// Per-send CPU overhead charged to the sender (ns).
    pub send_overhead_ns: f64,
    /// Per-pull CPU overhead charged to the receiver (ns).
    pub pull_overhead_ns: f64,
}

impl LinkModel {
    /// Internode MPI link (defaults from paper §III-D measurements).
    pub fn internode() -> Self {
        Self {
            wire_median_ns: 230.0 * MICRO as f64,
            wire_sigma: 0.45,
            service_ns: 2.5 * MICRO as f64,
            coalesce_ns: 150 * MICRO,
            base_drop_prob: 0.0,
            spike_prob: 0.0,
            spike_mean_ns: 0.0,
            send_overhead_ns: 5.0 * MICRO as f64,
            pull_overhead_ns: 3.5 * MICRO as f64,
        }
    }

    /// Intranode MPI link (same-node processes).
    pub fn intranode() -> Self {
        Self {
            wire_median_ns: 1.8 * MICRO as f64,
            wire_sigma: 0.35,
            service_ns: 0.6 * MICRO as f64,
            coalesce_ns: 0,
            base_drop_prob: 0.30,
            spike_prob: 0.0,
            spike_mean_ns: 0.0,
            send_overhead_ns: 1.1 * MICRO as f64,
            pull_overhead_ns: 0.9 * MICRO as f64,
        }
    }

    /// Shared-memory mutex link (inter-thread). No send buffer, no drops,
    /// sub-microsecond handoff (§III-E).
    pub fn thread_shared_memory() -> Self {
        Self {
            wire_median_ns: 2.2 * MICRO as f64,
            wire_sigma: 0.30,
            service_ns: 0.0,
            coalesce_ns: 0,
            base_drop_prob: 0.0,
            spike_prob: 1.2e-4,
            spike_mean_ns: 6.0 * 1_000_000.0,
            send_overhead_ns: 0.55 * MICRO as f64,
            pull_overhead_ns: 0.45 * MICRO as f64,
        }
    }

    /// Sample one delivery latency.
    pub fn sample_latency(&self, rng: &mut Xoshiro256) -> Nanos {
        if self.spike_prob > 0.0 && rng.chance(self.spike_prob) {
            return rng.exponential(self.spike_mean_ns).max(1.0) as Nanos;
        }
        let mu = self.wire_median_ns.max(1.0).ln();
        rng.lognormal(mu, self.wire_sigma).max(1.0) as Nanos
    }

    /// Quantize an arrival time to the coalescing grid (batch boundary at
    /// the *end* of the window, so messages inside one window share an
    /// arrival instant).
    pub fn coalesce(&self, arrival: Nanos) -> Nanos {
        if self.coalesce_ns == 0 {
            arrival
        } else {
            arrival.div_ceil(self.coalesce_ns) * self.coalesce_ns
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_median_near_configured() {
        let m = LinkModel::internode();
        let mut rng = Xoshiro256::new(1);
        let mut xs: Vec<f64> = (0..20_000)
            .map(|_| m.sample_latency(&mut rng) as f64)
            .collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        let target = m.wire_median_ns;
        assert!(
            (median - target).abs() / target < 0.05,
            "median={median} target={target}"
        );
    }

    #[test]
    fn intranode_much_faster_than_internode() {
        let intra = LinkModel::intranode();
        let inter = LinkModel::internode();
        assert!(inter.wire_median_ns / intra.wire_median_ns > 25.0);
    }

    #[test]
    fn coalesce_quantizes_upward() {
        let mut m = LinkModel::internode();
        m.coalesce_ns = 100;
        assert_eq!(m.coalesce(1), 100);
        assert_eq!(m.coalesce(100), 100);
        assert_eq!(m.coalesce(101), 200);
        m.coalesce_ns = 0;
        assert_eq!(m.coalesce(101), 101);
    }

    #[test]
    fn thread_link_never_configured_to_drop() {
        let m = LinkModel::thread_shared_memory();
        assert_eq!(m.base_drop_prob, 0.0);
        assert_eq!(m.service_ns, 0.0);
    }
}
