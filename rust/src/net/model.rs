//! Link models: latency, service, coalescing, and drop behaviour.
//!
//! Each directed channel between two processes is governed by a
//! [`LinkModel`] chosen by placement (intranode / internode / inter-thread
//! shared memory). The model captures four empirically-grounded phenomena:
//!
//! * **Wire latency** — lognormal effective delivery latency. For
//!   internode MPI this is dominated by progress/buffering delays, not
//!   physical wire time; the paper measures ≈550 µs median internode vs
//!   ≈7 µs intranode (§III-D.3), and those measurements are our defaults.
//! * **Service interval** — minimum spacing at which messages drain out of
//!   the userspace send buffer. A send attempted while `capacity` messages
//!   are still undrained is *dropped* (the paper's only drop condition,
//!   §II-D.4).
//! * **Coalescing** — internode MPI progression delivers queued messages
//!   in bursts; arrivals within one coalescing window land together. This
//!   reproduces the paper's internode clumpiness ≈0.96 vs intranode ≈0.014
//!   (§III-D.4) and its decay to 0 under heavy compute (§III-C.4).
//! * **Baseline drop rate** — placement-specific residual drop
//!   probability. The paper measures ≈0.3 intranode-MPI delivery failure
//!   vs ≈0.0 internode (§III-D.5, acknowledged as counterintuitive —
//!   prompt internode backend buffering empties the userspace buffer);
//!   we inject it as a calibrated constant rather than modelling MPI
//!   shared-memory internals.

use crate::util::rng::{Rng, Xoshiro256};
use crate::util::{Nanos, MICRO};

/// Measured medians of the real multi-process wire path, one per
/// [`crate::conduit::socket::StageLatencies`] stage, used to calibrate a
/// [`LinkModel`] from hardware instead of the paper's published numbers.
///
/// The canonical source is `BENCH_multiproc.json` at the repo root
/// (entries named `multiproc stage serialize|enqueue|transport|drain`,
/// written by `bench_multiproc --json`); [`Self::builtin`] carries a
/// conservative localhost-TCP ballpark for trees without a measurement.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageMedians {
    /// Frame encoding time (ns).
    pub serialize_ns: f64,
    /// Send-window residence until the OS took the last byte (ns).
    pub enqueue_ns: f64,
    /// `t_sent` to parse completion on the receiving hub (ns).
    pub transport_ns: f64,
    /// Parse completion until the consumer pulled the message (ns).
    pub drain_ns: f64,
    /// Pooled p95/median ratio over the pre-delivery stages — the jitter
    /// handle for the lognormal latency fit.
    pub p95_over_median: f64,
}

impl StageMedians {
    /// Localhost-TCP ballpark for repos without a committed
    /// `BENCH_multiproc.json` yet (CI prints a note when this is used).
    pub fn builtin() -> Self {
        Self {
            serialize_ns: 650.0,
            enqueue_ns: 2_800.0,
            transport_ns: 28_000.0,
            drain_ns: 3_500.0,
            p95_over_median: 2.1,
        }
    }

    /// Stages a message traverses before it is visible to the receiver.
    pub fn pre_delivery_sum_ns(&self) -> f64 {
        self.serialize_ns + self.enqueue_ns + self.transport_ns
    }

    /// Parse stage medians out of a `BENCH_multiproc.json`. The file is
    /// the one-entry-per-line format of
    /// [`crate::util::benchjson::BenchJson`], so a line scan suffices —
    /// no JSON dependency. Returns `None` unless every stage is present
    /// with a finite median.
    pub fn from_bench_json(path: impl AsRef<std::path::Path>) -> Option<Self> {
        let text = std::fs::read_to_string(path).ok()?;
        Self::from_bench_text(&text)
    }

    /// [`Self::from_bench_json`] on already-loaded file contents.
    pub fn from_bench_text(text: &str) -> Option<Self> {
        let mut medians = [f64::NAN; 4];
        let mut p95s = [f64::NAN; 4];
        for line in text.lines() {
            for (i, stage) in ["serialize", "enqueue", "transport", "drain"]
                .iter()
                .enumerate()
            {
                if line.contains(&format!("\"multiproc stage {stage}\"")) {
                    medians[i] = json_field(line, "median")?;
                    p95s[i] = json_field(line, "p95")?;
                }
            }
        }
        if medians.iter().any(|m| !m.is_finite() || *m <= 0.0) {
            return None;
        }
        let pre_median: f64 = medians[..3].iter().sum();
        let pre_p95: f64 = p95s[..3].iter().sum();
        Some(Self {
            serialize_ns: medians[0],
            enqueue_ns: medians[1],
            transport_ns: medians[2],
            drain_ns: medians[3],
            p95_over_median: if pre_p95.is_finite() && pre_median > 0.0 {
                (pre_p95 / pre_median).max(1.0)
            } else {
                1.0
            },
        })
    }
}

/// Extract `"key": <number>` from one serialized bench-entry line.
fn json_field(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\": ");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find([',', '}']).unwrap_or(rest.len());
    rest[..end].trim().parse().ok()
}

/// Parameters of one link class.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// Median effective delivery latency (ns).
    pub wire_median_ns: f64,
    /// Lognormal sigma of delivery latency.
    pub wire_sigma: f64,
    /// Per-message send-buffer drain interval (ns).
    pub service_ns: f64,
    /// Arrival coalescing window (ns); 0 disables batching.
    pub coalesce_ns: Nanos,
    /// Residual per-send drop probability (calibrated; see module docs).
    pub base_drop_prob: f64,
    /// Probability that a delivery hits a pathological latency spike
    /// (descheduling, cache-invalidation storms — the paper's threading
    /// outliers of ~12 ms, SIII-E.2).
    pub spike_prob: f64,
    /// Mean spike duration (exponential), ns.
    pub spike_mean_ns: f64,
    /// Per-send CPU overhead charged to the sender (ns).
    pub send_overhead_ns: f64,
    /// Per-pull CPU overhead charged to the receiver (ns).
    pub pull_overhead_ns: f64,
}

impl LinkModel {
    /// Internode MPI link (defaults from paper §III-D measurements).
    pub fn internode() -> Self {
        Self {
            wire_median_ns: 230.0 * MICRO as f64,
            wire_sigma: 0.45,
            service_ns: 2.5 * MICRO as f64,
            coalesce_ns: 150 * MICRO,
            base_drop_prob: 0.0,
            spike_prob: 0.0,
            spike_mean_ns: 0.0,
            send_overhead_ns: 5.0 * MICRO as f64,
            pull_overhead_ns: 3.5 * MICRO as f64,
        }
    }

    /// Intranode MPI link (same-node processes).
    pub fn intranode() -> Self {
        Self {
            wire_median_ns: 1.8 * MICRO as f64,
            wire_sigma: 0.35,
            service_ns: 0.6 * MICRO as f64,
            coalesce_ns: 0,
            base_drop_prob: 0.30,
            spike_prob: 0.0,
            spike_mean_ns: 0.0,
            send_overhead_ns: 1.1 * MICRO as f64,
            pull_overhead_ns: 0.9 * MICRO as f64,
        }
    }

    /// Shared-memory mutex link (inter-thread). No send buffer, no drops,
    /// sub-microsecond handoff (§III-E).
    pub fn thread_shared_memory() -> Self {
        Self {
            wire_median_ns: 2.2 * MICRO as f64,
            wire_sigma: 0.30,
            service_ns: 0.0,
            coalesce_ns: 0,
            base_drop_prob: 0.0,
            spike_prob: 1.2e-4,
            spike_mean_ns: 6.0 * 1_000_000.0,
            send_overhead_ns: 0.55 * MICRO as f64,
            pull_overhead_ns: 0.45 * MICRO as f64,
        }
    }

    /// Link calibrated from measured multi-process stage medians
    /// (ROADMAP: close the loop from `bench_multiproc` hardware numbers
    /// back into the DES). Fixed latency is the pre-delivery stage sum;
    /// jitter comes from the pooled p95/median ratio via the lognormal
    /// identity `p95/median = exp(1.645 * sigma)`; the enqueue median
    /// doubles as the send-buffer drain interval, and the edge
    /// serialize/drain stages become the per-send/per-pull CPU
    /// overheads. Coalescing, residual drops, and spikes stay off: the
    /// socket hub delivers eagerly and losslessly, and whatever jitter
    /// the host injects is already in the measured ratio.
    pub fn calibrated(m: &StageMedians) -> Self {
        let sigma = m.p95_over_median.max(1.0).ln() / 1.645;
        Self {
            wire_median_ns: m.pre_delivery_sum_ns().max(1.0),
            wire_sigma: sigma.clamp(0.05, 2.0),
            service_ns: m.enqueue_ns.max(0.0),
            coalesce_ns: 0,
            base_drop_prob: 0.0,
            spike_prob: 0.0,
            spike_mean_ns: 0.0,
            send_overhead_ns: m.serialize_ns.max(0.0),
            pull_overhead_ns: m.drain_ns.max(0.0),
        }
    }

    /// Sample one delivery latency.
    pub fn sample_latency(&self, rng: &mut Xoshiro256) -> Nanos {
        if self.spike_prob > 0.0 && rng.chance(self.spike_prob) {
            return rng.exponential(self.spike_mean_ns).max(1.0) as Nanos;
        }
        let mu = self.wire_median_ns.max(1.0).ln();
        rng.lognormal(mu, self.wire_sigma).max(1.0) as Nanos
    }

    /// Quantize an arrival time to the coalescing grid (batch boundary at
    /// the *end* of the window, so messages inside one window share an
    /// arrival instant).
    pub fn coalesce(&self, arrival: Nanos) -> Nanos {
        if self.coalesce_ns == 0 {
            arrival
        } else {
            arrival.div_ceil(self.coalesce_ns) * self.coalesce_ns
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_median_near_configured() {
        let m = LinkModel::internode();
        let mut rng = Xoshiro256::new(1);
        let mut xs: Vec<f64> = (0..20_000)
            .map(|_| m.sample_latency(&mut rng) as f64)
            .collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        let target = m.wire_median_ns;
        assert!(
            (median - target).abs() / target < 0.05,
            "median={median} target={target}"
        );
    }

    #[test]
    fn intranode_much_faster_than_internode() {
        let intra = LinkModel::intranode();
        let inter = LinkModel::internode();
        assert!(inter.wire_median_ns / intra.wire_median_ns > 25.0);
    }

    #[test]
    fn coalesce_quantizes_upward() {
        let mut m = LinkModel::internode();
        m.coalesce_ns = 100;
        assert_eq!(m.coalesce(1), 100);
        assert_eq!(m.coalesce(100), 100);
        assert_eq!(m.coalesce(101), 200);
        m.coalesce_ns = 0;
        assert_eq!(m.coalesce(101), 101);
    }

    #[test]
    fn thread_link_never_configured_to_drop() {
        let m = LinkModel::thread_shared_memory();
        assert_eq!(m.base_drop_prob, 0.0);
        assert_eq!(m.service_ns, 0.0);
    }

    #[test]
    fn stage_medians_parse_bench_json_lines() {
        let text = r#"{
  "bench": "bench_multiproc",
  "schema": 1,
  "results": [
    {"name": "multiproc stage serialize", "unit": "ns", "mean": 700.000, "median": 600.000, "p95": 1200.000},
    {"name": "multiproc stage enqueue", "unit": "ns", "mean": 3000.000, "median": 2000.000, "p95": 5000.000},
    {"name": "multiproc stage transport", "unit": "ns", "mean": 30000.000, "median": 27400.000, "p95": 60000.000},
    {"name": "multiproc stage drain", "unit": "ns", "mean": 4000.000, "median": 3000.000, "p95": 9000.000},
    {"name": "multiproc rtt (4 procs)", "unit": "ns", "mean": 1.000, "median": 1.000, "p95": 1.000}
  ]
}"#;
        let m = StageMedians::from_bench_text(text).expect("parses");
        assert_eq!(m.serialize_ns, 600.0);
        assert_eq!(m.enqueue_ns, 2000.0);
        assert_eq!(m.transport_ns, 27400.0);
        assert_eq!(m.drain_ns, 3000.0);
        // Pooled pre-delivery ratio: (1200+5000+60000)/(600+2000+27400).
        assert!((m.p95_over_median - 66_200.0 / 30_000.0).abs() < 1e-9);
        assert_eq!(m.pre_delivery_sum_ns(), 30_000.0);
    }

    #[test]
    fn stage_medians_reject_incomplete_files() {
        assert!(StageMedians::from_bench_text("{}").is_none());
        let partial = r#"{"name": "multiproc stage serialize", "unit": "ns", "mean": 1.0, "median": 1.000, "p95": 2.000}"#;
        assert!(StageMedians::from_bench_text(partial).is_none());
        assert!(StageMedians::from_bench_json("/nonexistent/path.json").is_none());
    }

    #[test]
    fn calibrated_link_matches_stage_arithmetic() {
        let m = StageMedians::builtin();
        let link = LinkModel::calibrated(&m);
        assert_eq!(link.wire_median_ns, m.pre_delivery_sum_ns());
        assert_eq!(link.service_ns, m.enqueue_ns);
        assert_eq!(link.send_overhead_ns, m.serialize_ns);
        assert_eq!(link.pull_overhead_ns, m.drain_ns);
        assert_eq!(link.coalesce_ns, 0);
        assert_eq!(link.base_drop_prob, 0.0);
        // Lognormal identity: p95/median of samples ≈ configured ratio.
        let expected_sigma = m.p95_over_median.ln() / 1.645;
        assert!((link.wire_sigma - expected_sigma).abs() < 1e-12);
        // A degenerate ratio (p95 <= median) still yields a usable link.
        let flat = StageMedians {
            p95_over_median: 0.5,
            ..m
        };
        let l2 = LinkModel::calibrated(&flat);
        assert_eq!(l2.wire_sigma, 0.05, "sigma floor engages");
    }
}
