//! Cluster topology, link models, and node fault profiles.
//!
//! This is the simulated substrate standing in for the paper's testbed
//! (MSU HPCC: heterogeneous x86 nodes, InfiniBand, MPI). See DESIGN.md §2
//! for the substitution rationale and the calibration sources — every
//! default constant below is traceable to a measurement reported in the
//! paper itself.

pub mod faulty;
pub mod model;
pub mod topology;

pub use faulty::NodeProfile;
pub use model::{LinkModel, StageMedians};
pub use topology::{PlacementKind, Topology};
