//! Per-node performance/fault profiles.
//!
//! The paper's §III-G experiment contrasts a 256-process allocation
//! containing an apparently faulty node (`lac-417` — source of every
//! extreme QoS outlier in the weak-scaling data) against an allocation
//! without it. A [`NodeProfile`] captures the degradation knobs the DES
//! applies to a node's processes and links.
//!
//! Profiles here are *static* — fixed for a whole run. Time-varying
//! degradation (onset, recovery, flapping, storms, partitions) is layered
//! on top by the [`crate::faults`] scenario subsystem, whose overlay folds
//! [`crate::faults::NodeFault`] factors over these profiles mid-run; an
//! always-on `lac417` scenario reproduces this module's
//! [`NodeProfile::faulty_lac417`] exactly, and the static path remains
//! available and bit-identical.

use crate::util::rng::{Rng, Xoshiro256};
use crate::util::{Nanos, MICRO, MILLI};

/// Performance profile of one physical node.
#[derive(Clone, Copy, Debug)]
pub struct NodeProfile {
    /// Multiplier on compute durations (1.0 = nominal).
    pub speed_factor: f64,
    /// Lognormal sigma of per-update compute jitter.
    pub jitter_sigma: f64,
    /// Per-update probability of an OS-noise stall (descheduling, page
    /// fault storms, …).
    pub stall_prob: f64,
    /// Mean stall duration (exponential), ns.
    pub stall_mean_ns: f64,
    /// Multiplier on latency of links touching this node.
    pub latency_factor: f64,
    /// Additional per-send drop probability on links touching this node.
    pub extra_drop_prob: f64,
}

impl NodeProfile {
    /// A healthy cluster node. Stall parameters model ordinary OS noise:
    /// rare millisecond-scale preemptions — the per-update probability is
    /// scaled by update duration at simulation time so noise arrives per
    /// unit *time*, not per update.
    pub fn healthy() -> Self {
        Self {
            speed_factor: 1.0,
            jitter_sigma: 0.12,
            stall_prob: 0.0, // derived per-update from stall_rate_per_sec
            stall_mean_ns: 2.5 * MILLI as f64,
            latency_factor: 1.0,
            extra_drop_prob: 0.0,
        }
    }

    /// The faulty-node profile reproducing `lac-417` (§III-G): extreme
    /// latency spikes (walltime-latency outliers of seconds), heavy
    /// stalls, and elevated delivery failure among its clique.
    pub fn faulty_lac417() -> Self {
        Self {
            speed_factor: 1.35,
            jitter_sigma: 0.8,
            stall_prob: 0.0,
            stall_mean_ns: 180.0 * MILLI as f64,
            latency_factor: 400.0,
            extra_drop_prob: 0.35,
        }
    }

    /// Rate of OS-noise stall events per second of virtual busy time for a
    /// node hosting `procs_on_node` active processes on `cores` cores.
    /// Oversubscription raises the rate sharply (the multithread QoS
    /// erraticity of §III-E).
    pub fn stall_rate_per_sec(&self, procs_on_node: usize, cores: usize) -> f64 {
        let base = if self.is_faulty() { 40.0 } else { 0.9 };
        let oversub = (procs_on_node as f64 / cores.max(1) as f64).max(1.0);
        base * oversub
    }

    fn is_faulty(&self) -> bool {
        self.latency_factor > 10.0 || self.stall_mean_ns > 50.0 * MILLI as f64
    }

    /// Sample the extra stall time (possibly zero) incurred during an
    /// update of duration `busy_ns`.
    pub fn sample_stall(
        &self,
        busy_ns: f64,
        procs_on_node: usize,
        cores: usize,
        rng: &mut Xoshiro256,
    ) -> Nanos {
        let rate = self.stall_rate_per_sec(procs_on_node, cores);
        let p = (rate * busy_ns / 1e9).min(1.0);
        if rng.chance(p) {
            rng.exponential(self.stall_mean_ns).max(50.0 * MICRO as f64) as Nanos
        } else {
            0
        }
    }

    /// Sample one update's compute duration given a nominal cost.
    pub fn sample_compute(
        &self,
        nominal_ns: f64,
        contention: f64,
        procs_on_node: usize,
        cores: usize,
        rng: &mut Xoshiro256,
    ) -> Nanos {
        let jitter = rng.lognormal(0.0, self.jitter_sigma);
        let busy = nominal_ns * self.speed_factor * contention * jitter;
        let stall = self.sample_stall(busy, procs_on_node, cores, rng);
        busy.max(1.0) as Nanos + stall
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_profile_is_nominal() {
        let p = NodeProfile::healthy();
        assert_eq!(p.speed_factor, 1.0);
        assert_eq!(p.latency_factor, 1.0);
        assert_eq!(p.extra_drop_prob, 0.0);
        assert!(!p.is_faulty());
    }

    #[test]
    fn faulty_profile_detected() {
        assert!(NodeProfile::faulty_lac417().is_faulty());
    }

    #[test]
    fn faulty_stalls_much_more_often() {
        let h = NodeProfile::healthy();
        let f = NodeProfile::faulty_lac417();
        assert!(f.stall_rate_per_sec(1, 28) > 10.0 * h.stall_rate_per_sec(1, 28));
    }

    #[test]
    fn oversubscription_raises_stall_rate() {
        let p = NodeProfile::healthy();
        assert!(p.stall_rate_per_sec(64, 28) > 2.0 * p.stall_rate_per_sec(1, 28));
    }

    #[test]
    fn compute_sampling_centered_on_nominal() {
        let p = NodeProfile::healthy();
        let mut rng = Xoshiro256::new(3);
        let n = 20_000;
        let total: f64 = (0..n)
            .map(|_| p.sample_compute(10_000.0, 1.0, 1, 28, &mut rng) as f64)
            .sum();
        let mean = total / n as f64;
        // lognormal(0, 0.12) mean ~ 1.007; rare stalls add a little.
        assert!(
            mean > 9_500.0 && mean < 13_000.0,
            "mean={mean}"
        );
    }

    #[test]
    fn stalls_are_rare_but_large_for_healthy_nodes() {
        let p = NodeProfile::healthy();
        let mut rng = Xoshiro256::new(4);
        let mut n_stalls = 0;
        for _ in 0..100_000 {
            // 10µs updates: stall prob ~ 0.9 * 1e-5 per update
            if p.sample_stall(10_000.0, 1, 28, &mut rng) > 0 {
                n_stalls += 1;
            }
        }
        assert!(n_stalls < 50, "n_stalls={n_stalls}");
    }
}
