//! Shared-memory inter-thread duct (`Mutex<RingBuffer>` transport).
//!
//! This is the multithreading backend the paper benchmarks in §III-A and
//! characterizes in §III-E: "inter-thread communication occurring via
//! shared memory access mediated by a C++ `std::mutex`". With the default
//! latest-value configuration there is no send buffer to fill, so delivery
//! failures cannot occur (§III-E.5) — but pulls contend on the mutex, and
//! arrival can be clumpy when the reader is descheduled.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

use super::stats::ChannelStats;
use super::{ChannelConfig, Discipline, InletLike, OutletLike, SendOutcome};
use crate::util::ring::{PushOutcome, RingBuffer};
#[cfg(test)]
use crate::util::ring::Overflow;

struct Shared<T> {
    buffer: Mutex<RingBuffer<T>>,
    stats: Arc<ChannelStats>,
    /// Channel discipline, shared by both endpoints (relaxed atomics:
    /// a restamp only steers *future* pull/send gating decisions).
    discipline: AtomicU8,
}

impl<T> Shared<T> {
    fn discipline(&self) -> Discipline {
        Discipline::from_u8(self.discipline.load(Ordering::Relaxed))
            .unwrap_or(Discipline::BestEffort)
    }

    fn set_discipline(&self, d: Discipline) {
        self.discipline.store(d.as_u8(), Ordering::Relaxed);
    }
}

/// Sender endpoint of a thread duct.
pub struct ThreadInlet<T> {
    shared: Arc<Shared<T>>,
}

/// Receiver endpoint of a thread duct.
pub struct ThreadOutlet<T> {
    shared: Arc<Shared<T>>,
}

/// Create a connected inlet/outlet pair over a mutex-guarded ring buffer.
pub fn thread_duct<T>(config: ChannelConfig) -> (ThreadInlet<T>, ThreadOutlet<T>) {
    let shared = Arc::new(Shared {
        buffer: Mutex::new(RingBuffer::new(config.capacity, config.overflow)),
        stats: ChannelStats::new(),
        discipline: AtomicU8::new(Discipline::BestEffort.as_u8()),
    });
    (
        ThreadInlet {
            shared: Arc::clone(&shared),
        },
        ThreadOutlet { shared },
    )
}

impl<T> InletLike<T> for ThreadInlet<T> {
    fn put(&self, msg: T) -> SendOutcome {
        let outcome = {
            let mut buf = self.shared.buffer.lock().unwrap();
            buf.push(msg)
        };
        let outcome = match outcome {
            PushOutcome::Stored => SendOutcome::Accepted,
            PushOutcome::Displaced => SendOutcome::Displaced,
            PushOutcome::Rejected => SendOutcome::Dropped,
        };
        self.shared
            .stats
            .on_send_attempt(outcome.delivered_to_channel());
        outcome
    }

    fn stats(&self) -> &ChannelStats {
        &self.shared.stats
    }

    fn discipline(&self) -> Discipline {
        self.shared.discipline()
    }

    fn set_discipline(&self, d: Discipline) {
        self.shared.set_discipline(d);
    }
}

impl<T> OutletLike<T> for ThreadOutlet<T> {
    fn pull_all(&self) -> Vec<T> {
        let msgs = {
            let mut buf = self.shared.buffer.lock().unwrap();
            buf.drain_all()
        };
        self.shared.stats.on_pull(msgs.len() as u64);
        msgs
    }

    fn pull_all_into(&self, out: &mut Vec<T>) {
        let n = {
            let mut buf = self.shared.buffer.lock().unwrap();
            buf.drain_into(out)
        };
        self.shared.stats.on_pull(n as u64);
    }

    fn pull_latest(&self) -> Option<T> {
        let (latest, n) = {
            let mut buf = self.shared.buffer.lock().unwrap();
            let n = buf.len() as u64;
            buf.skip_to_latest();
            (buf.pop(), n)
        };
        self.shared.stats.on_pull(n);
        latest
    }

    fn stats(&self) -> &ChannelStats {
        &self.shared.stats
    }

    fn discipline(&self) -> Discipline {
        self.shared.discipline()
    }

    fn set_discipline(&self, d: Discipline) {
        self.shared.set_discipline(d);
    }
}

// No manual Send/Sync impls: `Arc<Mutex<RingBuffer<T>>>` already derives
// `Send + Sync` for `T: Send`, and the former `unsafe impl Send`s omitted
// `Sync`, blocking shared-reference use of endpoints across threads.
// (Compile-time regression guard below.)

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{forall, prop_assert, Config};

    #[test]
    fn endpoints_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ThreadInlet<u64>>();
        assert_send_sync::<ThreadOutlet<u64>>();
        assert_send_sync::<ThreadInlet<Vec<u8>>>();
        assert_send_sync::<ThreadOutlet<Vec<u8>>>();
    }

    #[test]
    fn shared_reference_use_across_threads() {
        // `&ThreadInlet` usable from a scoped thread: requires `Sync`,
        // which the deleted `unsafe impl Send`s never provided.
        let (inlet, outlet) = thread_duct::<u64>(ChannelConfig::qos());
        let inlet_ref = &inlet;
        std::thread::scope(|s| {
            s.spawn(move || {
                for i in 0..8 {
                    inlet_ref.put(i);
                }
            });
        });
        assert_eq!(outlet.pull_all().len(), 8);
    }

    #[test]
    fn discipline_is_shared_between_endpoints() {
        let (inlet, outlet) = thread_duct::<u64>(ChannelConfig::qos());
        assert_eq!(inlet.discipline(), Discipline::BestEffort);
        inlet.set_discipline(Discipline::Barriered);
        assert_eq!(outlet.discipline(), Discipline::Barriered);
        outlet.set_discipline(Discipline::Muted);
        assert_eq!(inlet.discipline(), Discipline::Muted);
        assert!(!inlet.discipline().carries_traffic());
    }

    #[test]
    fn roundtrip_preserves_order() {
        let (inlet, outlet) = thread_duct::<u32>(ChannelConfig::qos());
        for i in 0..5 {
            assert_eq!(inlet.put(i), SendOutcome::Accepted);
        }
        assert_eq!(outlet.pull_all(), vec![0, 1, 2, 3, 4]);
        assert!(outlet.pull_all().is_empty());
    }

    #[test]
    fn latest_value_never_drops() {
        let (inlet, outlet) = thread_duct::<u32>(ChannelConfig::latest_value());
        for i in 0..100 {
            assert!(inlet.put(i).delivered_to_channel());
        }
        assert_eq!(outlet.pull_latest(), Some(99));
        let t = inlet.stats().tranche();
        assert_eq!(t.attempted_sends, 100);
        assert_eq!(t.successful_sends, 100, "shared memory backend never drops");
    }

    #[test]
    fn reject_buffer_drops_when_full() {
        let (inlet, outlet) = thread_duct::<u32>(ChannelConfig::benchmarking());
        assert_eq!(inlet.put(1), SendOutcome::Accepted);
        assert_eq!(inlet.put(2), SendOutcome::Accepted);
        assert_eq!(inlet.put(3), SendOutcome::Dropped);
        let t = inlet.stats().tranche();
        assert_eq!(t.attempted_sends, 3);
        assert_eq!(t.successful_sends, 2);
        assert_eq!(outlet.pull_all(), vec![1, 2]);
    }

    #[test]
    fn pull_all_into_matches_pull_all() {
        let (inlet, outlet) = thread_duct::<u32>(ChannelConfig::qos());
        for i in 0..6 {
            inlet.put(i);
        }
        let mut out = vec![99];
        outlet.pull_all_into(&mut out);
        assert_eq!(out, vec![99, 0, 1, 2, 3, 4, 5], "appends in push order");
        // Instrumentation identical to a pull_all: one laden pull.
        let t = outlet.stats().tranche();
        assert_eq!(t.pull_attempts, 1);
        assert_eq!(t.laden_pulls, 1);
        assert_eq!(t.messages_received, 6);
        // Empty drain still counts a pull attempt.
        out.clear();
        outlet.pull_all_into(&mut out);
        assert!(out.is_empty());
        assert_eq!(outlet.stats().tranche().pull_attempts, 2);
    }

    #[test]
    fn pull_instrumentation() {
        let (inlet, outlet) = thread_duct::<u8>(ChannelConfig::qos());
        outlet.pull_all(); // empty pull
        inlet.put(1);
        inlet.put(2);
        outlet.pull_all(); // laden pull, 2 messages
        let t = outlet.stats().tranche();
        assert_eq!(t.pull_attempts, 2);
        assert_eq!(t.laden_pulls, 1);
        assert_eq!(t.messages_received, 2);
    }

    #[test]
    fn cross_thread_delivery() {
        let (inlet, outlet) = thread_duct::<u64>(ChannelConfig::qos());
        let producer = std::thread::spawn(move || {
            for i in 0..1000u64 {
                inlet.put(i);
            }
            inlet
        });
        let mut got = Vec::new();
        while got.len() < 1 {
            got.extend(outlet.pull_all());
        }
        let inlet = producer.join().unwrap();
        loop {
            let batch = outlet.pull_all();
            if batch.is_empty() {
                break;
            }
            got.extend(batch);
        }
        // Everything accepted must come out, in order.
        let t = inlet.stats().tranche();
        assert_eq!(got.len() as u64, t.successful_sends);
        assert!(got.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn prop_message_conservation() {
        // delivered + dropped == attempted for arbitrary interleavings.
        forall(Config::default().cases(128), |g| {
            let cap = g.usize_in(1, 16);
            let (inlet, outlet) = thread_duct::<u64>(ChannelConfig {
                capacity: cap,
                overflow: Overflow::Reject,
            });
            let ops = g.usize_in(1, 200);
            let mut delivered = 0u64;
            for i in 0..ops {
                if g.chance(0.6) {
                    inlet.put(i as u64);
                } else {
                    delivered += outlet.pull_all().len() as u64;
                }
            }
            delivered += outlet.pull_all().len() as u64;
            let t = inlet.stats().tranche();
            prop_assert(
                delivered == t.successful_sends,
                format!("delivered={delivered} successful={}", t.successful_sends),
            )?;
            prop_assert(
                t.successful_sends <= t.attempted_sends,
                "successful > attempted",
            )
        });
    }
}
