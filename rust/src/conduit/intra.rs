//! Intra-thread duct: serial-modality transport with no locking.
//!
//! Conduit's design goal of "uniform inter-operation of serial, parallel,
//! and distributed modalities" (paper §I) means the same Inlet/Outlet API
//! must also service elements co-resident on a single thread. This backend
//! uses `RefCell` storage — zero synchronization cost, same semantics and
//! instrumentation as the other ducts.

use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::Arc;

use super::stats::ChannelStats;
use super::{ChannelConfig, Discipline, SendOutcome};
use crate::util::ring::{PushOutcome, RingBuffer};

struct Shared<T> {
    buffer: RefCell<RingBuffer<T>>,
    stats: Arc<ChannelStats>,
    /// Channel discipline, shared by both (same-thread) endpoints.
    discipline: Cell<u8>,
}

/// Sender endpoint of an intra-thread duct (not `Send`).
pub struct IntraInlet<T> {
    shared: Rc<Shared<T>>,
}

/// Receiver endpoint of an intra-thread duct (not `Send`).
pub struct IntraOutlet<T> {
    shared: Rc<Shared<T>>,
}

/// Create a connected same-thread inlet/outlet pair.
pub fn intra_duct<T>(config: ChannelConfig) -> (IntraInlet<T>, IntraOutlet<T>) {
    let shared = Rc::new(Shared {
        buffer: RefCell::new(RingBuffer::new(config.capacity, config.overflow)),
        stats: ChannelStats::new(),
        discipline: Cell::new(Discipline::BestEffort.as_u8()),
    });
    (
        IntraInlet {
            shared: Rc::clone(&shared),
        },
        IntraOutlet { shared },
    )
}

impl<T> IntraInlet<T> {
    /// Best-effort put. Never blocks.
    pub fn put(&self, msg: T) -> SendOutcome {
        let outcome = match self.shared.buffer.borrow_mut().push(msg) {
            PushOutcome::Stored => SendOutcome::Accepted,
            PushOutcome::Displaced => SendOutcome::Displaced,
            PushOutcome::Rejected => SendOutcome::Dropped,
        };
        self.shared
            .stats
            .on_send_attempt(outcome.delivered_to_channel());
        outcome
    }

    pub fn stats(&self) -> &ChannelStats {
        &self.shared.stats
    }

    /// This channel's communication discipline.
    pub fn discipline(&self) -> Discipline {
        Discipline::from_u8(self.shared.discipline.get()).unwrap_or(Discipline::BestEffort)
    }

    /// Restamp the channel's discipline (visible to both endpoints).
    pub fn set_discipline(&self, d: Discipline) {
        self.shared.discipline.set(d.as_u8());
    }
}

impl<T> IntraOutlet<T> {
    /// Drain every buffered message.
    pub fn pull_all(&self) -> Vec<T> {
        let msgs = self.shared.buffer.borrow_mut().drain_all();
        self.shared.stats.on_pull(msgs.len() as u64);
        msgs
    }

    /// Keep only the freshest message.
    pub fn pull_latest(&self) -> Option<T> {
        let mut buf = self.shared.buffer.borrow_mut();
        let n = buf.len() as u64;
        buf.skip_to_latest();
        let latest = buf.pop();
        drop(buf);
        self.shared.stats.on_pull(n);
        latest
    }

    pub fn stats(&self) -> &ChannelStats {
        &self.shared.stats
    }

    /// This channel's communication discipline.
    pub fn discipline(&self) -> Discipline {
        Discipline::from_u8(self.shared.discipline.get()).unwrap_or(Discipline::BestEffort)
    }

    /// Restamp the channel's discipline (visible to both endpoints).
    pub fn set_discipline(&self, d: Discipline) {
        self.shared.discipline.set(d.as_u8());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discipline_restamp_is_shared() {
        let (inlet, outlet) = intra_duct::<u8>(ChannelConfig::qos());
        assert_eq!(outlet.discipline(), Discipline::BestEffort);
        inlet.set_discipline(Discipline::Barriered);
        assert_eq!(outlet.discipline(), Discipline::Barriered);
    }

    #[test]
    fn roundtrip() {
        let (inlet, outlet) = intra_duct::<&str>(ChannelConfig::qos());
        inlet.put("a");
        inlet.put("b");
        assert_eq!(outlet.pull_all(), vec!["a", "b"]);
    }

    #[test]
    fn latest_skips_backlog() {
        let (inlet, outlet) = intra_duct::<u32>(ChannelConfig::qos());
        for i in 0..10 {
            inlet.put(i);
        }
        assert_eq!(outlet.pull_latest(), Some(9));
        assert!(outlet.pull_all().is_empty());
        let t = outlet.stats().tranche();
        assert_eq!(t.messages_received, 10, "skipped messages still count as received");
    }

    #[test]
    fn drops_counted() {
        let (inlet, _outlet) = intra_duct::<u32>(ChannelConfig::benchmarking());
        inlet.put(0);
        inlet.put(1);
        assert_eq!(inlet.put(2), SendOutcome::Dropped);
        let t = inlet.stats().tranche();
        assert_eq!(t.attempted_sends - t.successful_sends, 1);
    }
}
