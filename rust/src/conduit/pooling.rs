//! Pooling: consolidate per-element values into one message per update.
//!
//! The paper's workloads use Conduit's "built-in pooling support" to merge
//! the per-simel payloads crossing a process pair into a single MPI
//! message each update (§II-A, §II-B: "we used Conduit's built-in pooling
//! feature to consolidate color information into a single MPI message
//! between pairs of communicating processes each update").
//!
//! A [`Pool`] has a fixed set of *slots* (one per border simulation
//! element). Each update, every slot is filled and the pool flushes one
//! `Vec<T>` message. On the receiving side [`unpool`] redistributes the
//! payload to per-slot values.

/// Fixed-slot pooled message builder.
#[derive(Clone, Debug)]
pub struct Pool<T> {
    slots: Vec<Option<T>>,
}

impl<T: Clone> Pool<T> {
    /// Create a pool with `n_slots` element slots.
    pub fn new(n_slots: usize) -> Self {
        Self {
            slots: vec![None; n_slots],
        }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Fill slot `i`; returns the previous value if the slot was already
    /// filled this round (double-fill indicates a workload bug upstream).
    pub fn fill(&mut self, i: usize, value: T) -> Option<T> {
        self.slots[i].replace(value)
    }

    /// True once every slot is filled.
    pub fn is_complete(&self) -> bool {
        self.slots.iter().all(Option::is_some)
    }

    /// Emit the pooled message and reset all slots. Panics if incomplete —
    /// pooled layers are handled on a fixed cadence, so an incomplete
    /// flush is a logic error, not a runtime condition.
    pub fn flush(&mut self) -> Vec<T> {
        assert!(self.is_complete(), "pool flushed while incomplete");
        self.slots.iter_mut().map(|s| s.take().unwrap()).collect()
    }

    /// Non-panicking flush for best-effort layers: emits whatever subset is
    /// filled (with slot indices) and resets.
    pub fn flush_partial(&mut self) -> Vec<(usize, T)> {
        let mut out = Vec::new();
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Some(v) = slot.take() {
                out.push((i, v));
            }
        }
        out
    }
}

/// Redistribute a pooled message to per-slot values. Returns `None` when
/// the payload arity does not match (corrupt/foreign message — best-effort
/// receivers skip it).
pub fn unpool<T>(payload: Vec<T>, expected_slots: usize) -> Option<Vec<T>> {
    if payload.len() == expected_slots {
        Some(payload)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_flush_roundtrip() {
        let mut pool = Pool::new(3);
        assert!(!pool.is_complete());
        pool.fill(0, 10);
        pool.fill(2, 30);
        pool.fill(1, 20);
        assert!(pool.is_complete());
        assert_eq!(pool.flush(), vec![10, 20, 30]);
        assert!(!pool.is_complete());
    }

    #[test]
    fn double_fill_returns_previous() {
        let mut pool = Pool::new(1);
        assert_eq!(pool.fill(0, 1), None);
        assert_eq!(pool.fill(0, 2), Some(1));
        assert_eq!(pool.flush(), vec![2]);
    }

    #[test]
    #[should_panic(expected = "incomplete")]
    fn incomplete_flush_panics() {
        let mut pool: Pool<u8> = Pool::new(2);
        pool.fill(0, 1);
        pool.flush();
    }

    #[test]
    fn partial_flush_keeps_indices() {
        let mut pool = Pool::new(4);
        pool.fill(1, "b");
        pool.fill(3, "d");
        assert_eq!(pool.flush_partial(), vec![(1, "b"), (3, "d")]);
        assert_eq!(pool.flush_partial(), vec![]);
    }

    #[test]
    fn unpool_checks_arity() {
        assert_eq!(unpool(vec![1, 2, 3], 3), Some(vec![1, 2, 3]));
        assert_eq!(unpool(vec![1, 2], 3), None);
    }
}
