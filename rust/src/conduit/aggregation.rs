//! Aggregation: batch arbitrarily many small packets into one message.
//!
//! The digital-evolution workload's spawn and cell-cell communication
//! layers dispatch "arbitrarily many" variable-size packets, handled every
//! 16 updates with "Conduit's built-in aggregation support for
//! inter-process transfer" (paper §II-A). An [`Aggregator`] accumulates
//! addressed packets between flushes; each flush emits one batch per
//! destination channel.

use std::collections::BTreeMap;

/// Accumulates `(destination, packet)` pairs between flushes.
#[derive(Clone, Debug)]
pub struct Aggregator<T> {
    pending: BTreeMap<usize, Vec<T>>,
    /// Total packets accumulated since the last flush.
    count: usize,
    /// Optional cap on buffered packets per destination; beyond it the
    /// oldest packets are discarded (aggregation buffers are best-effort
    /// too — unbounded accumulation on a stalled channel is exactly the
    /// snowball failure mode §II-F2 describes).
    per_dest_cap: usize,
}

impl<T> Aggregator<T> {
    pub fn new(per_dest_cap: usize) -> Self {
        assert!(per_dest_cap >= 1);
        Self {
            pending: BTreeMap::new(),
            count: 0,
            per_dest_cap,
        }
    }

    /// Queue a packet for `dest`. Returns `true` if an old packet was
    /// evicted to make room.
    pub fn push(&mut self, dest: usize, packet: T) -> bool {
        let q = self.pending.entry(dest).or_default();
        q.push(packet);
        self.count += 1;
        if q.len() > self.per_dest_cap {
            q.remove(0);
            self.count -= 1;
            true
        } else {
            false
        }
    }

    /// Packets currently pending across all destinations.
    pub fn pending_count(&self) -> usize {
        self.count
    }

    /// Emit one `(dest, batch)` message per destination and reset.
    pub fn flush(&mut self) -> Vec<(usize, Vec<T>)> {
        self.count = 0;
        std::mem::take(&mut self.pending).into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_by_destination() {
        let mut agg = Aggregator::new(16);
        agg.push(2, "x");
        agg.push(1, "y");
        agg.push(2, "z");
        assert_eq!(agg.pending_count(), 3);
        let batches = agg.flush();
        assert_eq!(batches, vec![(1, vec!["y"]), (2, vec!["x", "z"])]);
        assert_eq!(agg.pending_count(), 0);
        assert!(agg.flush().is_empty());
    }

    #[test]
    fn per_dest_cap_evicts_oldest() {
        let mut agg = Aggregator::new(2);
        assert!(!agg.push(0, 1));
        assert!(!agg.push(0, 2));
        assert!(agg.push(0, 3), "third push must evict");
        assert_eq!(agg.pending_count(), 2);
        assert_eq!(agg.flush(), vec![(0, vec![2, 3])]);
    }

    #[test]
    fn count_tracks_across_destinations() {
        let mut agg = Aggregator::new(4);
        for d in 0..5 {
            for p in 0..3 {
                agg.push(d, p);
            }
        }
        assert_eq!(agg.pending_count(), 15);
    }
}
