//! Best-effort communication channels (the Conduit-equivalent public API).
//!
//! A *conduit* is a directed, typed, bounded, best-effort message channel
//! between two simulation elements. Its two endpoints are an [`Inlet`]
//! (sender side) and an [`Outlet`] (receiver side). Delivery is
//! best-effort: the runtime "strives to minimize message latency and loss,
//! but guarantees elimination of neither" (paper §I). Messages that *are*
//! delivered retain contentual integrity.
//!
//! Two in-process duct backends are provided:
//!
//! * [`thread_duct`] — shared-memory `Mutex<RingBuffer>` transport, the
//!   multithreading backend of §III-E ("inter-thread communication via
//!   shared memory access mediated by a `std::mutex`"). Never drops when
//!   configured with `Overflow::Overwrite` latest-value semantics.
//! * [`intra_duct`] — same semantics, no mutex, for co-located elements
//!   serviced by one thread (serial modality).
//!
//! The simulated inter-process (MPI-model) transport lives in
//! [`crate::sim`], which reuses the same [`stats::ChannelStats`]
//! instrumentation and [`crate::util::ring::RingBuffer`] storage so the
//! QoS layer is backend-agnostic.
//!
//! [`socket`] provides the *real* inter-process transport: nonblocking
//! unix-domain stream sockets multiplexed by a per-process
//! [`SocketHub`], carrying [`WireEnvelope`]s between OS processes with
//! genuine best-effort drops (kernel buffer full, peer dead) and a
//! per-stage latency breakdown ([`StageLatencies`]) for calibrating the
//! DES link model.
//!
//! [`pooling`] and [`aggregation`] provide the message-consolidation
//! helpers the paper's workloads rely on (§II-A).

pub mod aggregation;
pub mod intra;
pub mod pooling;
pub mod socket;
pub mod stats;
pub mod thread;

pub use stats::{ChannelStats, CounterTranche, LocalChannelStats, StatsSink};

use crate::util::ring::Overflow;

/// Outcome of a best-effort send.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendOutcome {
    /// Message accepted into the channel.
    Accepted,
    /// Message accepted, displacing the oldest buffered message
    /// (latest-value channels).
    Displaced,
    /// Message dropped: the send buffer was full (MPI-model channels).
    Dropped,
}

impl SendOutcome {
    /// Did the message enter the channel at all?
    pub fn delivered_to_channel(self) -> bool {
        !matches!(self, SendOutcome::Dropped)
    }
}

/// Configuration for a conduit.
#[derive(Clone, Copy, Debug)]
pub struct ChannelConfig {
    /// Buffer capacity in messages. The paper uses 2 for the benchmarking
    /// experiments and 64 for the QoS experiments (§II-F).
    pub capacity: usize,
    /// Overflow policy: `Reject` models the MPI send buffer (drops);
    /// `Overwrite` models shared-memory latest-value exchange (no drops).
    pub overflow: Overflow,
}

impl Default for ChannelConfig {
    fn default() -> Self {
        Self {
            capacity: 64,
            overflow: Overflow::Reject,
        }
    }
}

impl ChannelConfig {
    /// Benchmark-experiment configuration (buffer size 2, §II-F1).
    pub fn benchmarking() -> Self {
        Self {
            capacity: 2,
            overflow: Overflow::Reject,
        }
    }

    /// QoS-experiment configuration (buffer size 64, §II-F2).
    pub fn qos() -> Self {
        Self {
            capacity: 64,
            overflow: Overflow::Reject,
        }
    }

    /// Shared-memory latest-value configuration (multithread backend).
    pub fn latest_value() -> Self {
        Self {
            capacity: 1,
            overflow: Overflow::Overwrite,
        }
    }
}

/// Per-channel communication discipline, as the transport layer sees
/// it. The DES derives one per channel from its `PolicyConfig`; the
/// thread and multi-process executors stamp one onto each duct endpoint
/// at setup (and the adaptive policy may restamp at runtime). The
/// `uniform(mode)` constructor lives in `crate::sim::policy`, next to
/// the mode vocabulary it maps from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Discipline {
    /// Endpoints of this channel take part in barrier synchronization.
    Barriered,
    /// The channel free-runs: sends may fail, pulls never block.
    BestEffort,
    /// The channel carries no traffic at all (mode 4).
    Muted,
}

impl Discipline {
    /// Stable numeric encoding for atomic / serialized storage.
    pub fn as_u8(self) -> u8 {
        match self {
            Discipline::Barriered => 0,
            Discipline::BestEffort => 1,
            Discipline::Muted => 2,
        }
    }

    pub fn from_u8(v: u8) -> Option<Discipline> {
        match v {
            0 => Some(Discipline::Barriered),
            1 => Some(Discipline::BestEffort),
            2 => Some(Discipline::Muted),
            _ => None,
        }
    }

    /// Does this channel carry traffic at all?
    pub fn carries_traffic(self) -> bool {
        self != Discipline::Muted
    }
}

/// Generic sender endpoint.
pub trait InletLike<T> {
    /// Best-effort put. Never blocks.
    fn put(&self, msg: T) -> SendOutcome;
    /// Instrumentation handle.
    fn stats(&self) -> &ChannelStats;
    /// This channel's communication discipline. Backends that do not
    /// store one report best-effort — the only semantics a conduit
    /// guarantees by itself.
    fn discipline(&self) -> Discipline {
        Discipline::BestEffort
    }
    /// Restamp the channel's discipline. Backends without storage for
    /// it ignore the call.
    fn set_discipline(&self, _d: Discipline) {}
}

/// Generic receiver endpoint.
pub trait OutletLike<T> {
    /// Drain every currently buffered message (bulk consumption;
    /// `MPI_Testsome`-equivalent).
    fn pull_all(&self) -> Vec<T>;
    /// Drain every currently buffered message into `out`, appending in
    /// push order. Semantically identical to [`OutletLike::pull_all`]
    /// (same instrumentation), but a caller-owned buffer lets pull loops
    /// reuse one allocation across channels and iterations. Backends
    /// override the default to drain storage directly.
    fn pull_all_into(&self, out: &mut Vec<T>) {
        out.extend(self.pull_all());
    }
    /// Keep only the freshest message, discarding the backlog.
    fn pull_latest(&self) -> Option<T>;
    /// Instrumentation handle.
    fn stats(&self) -> &ChannelStats;
    /// This channel's communication discipline (see [`InletLike`]).
    fn discipline(&self) -> Discipline {
        Discipline::BestEffort
    }
    /// Restamp the channel's discipline (ignored without storage).
    fn set_discipline(&self, _d: Discipline) {}
}

pub use intra::{intra_duct, IntraInlet, IntraOutlet};
pub use socket::{SocketHub, SocketInlet, SocketOutlet, StageLatencies, WireEnvelope};
pub use thread::{thread_duct, ThreadInlet, ThreadOutlet};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_presets_match_paper() {
        assert_eq!(ChannelConfig::benchmarking().capacity, 2);
        assert_eq!(ChannelConfig::qos().capacity, 64);
        assert_eq!(ChannelConfig::latest_value().capacity, 1);
        assert_eq!(ChannelConfig::latest_value().overflow, Overflow::Overwrite);
    }

    #[test]
    fn send_outcome_delivery() {
        assert!(SendOutcome::Accepted.delivered_to_channel());
        assert!(SendOutcome::Displaced.delivered_to_channel());
        assert!(!SendOutcome::Dropped.delivered_to_channel());
    }
}
