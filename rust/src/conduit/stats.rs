//! Per-channel instrumentation counters.
//!
//! The paper's QoS methodology (§II-D/E) derives every metric from counter
//! *tranches*: two reads of monotonically increasing counters bracketing an
//! unimpeded snapshot window. This module holds those counters, in two
//! tranches behind one API (the [`StatsSink`] trait):
//!
//! * [`ChannelStats`] — atomic counters, shared via `Arc` between the
//!   real-thread executor's endpoint wrappers and snapshot readers;
//! * [`LocalChannelStats`] — `Cell`-based counters for the single-threaded
//!   discrete-event engine, where every channel is owned by the engine and
//!   atomic RMW traffic on the send/pull hot path is pure overhead.
//!
//! Both mirror the Conduit library's compile-time-switchable Inlet/Outlet
//! instrumentation wrappers and produce identical [`CounterTranche`]s, so
//! the QoS layer is agnostic to which tranche recorded the run.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Monotonic event counters for one directed channel endpoint pair.
///
/// "Inlet" counters are written by the sending side, "outlet" counters by
/// the receiving side. A `ChannelStats` instance is shared (via `Arc`)
/// between the endpoint wrappers and any snapshot readers.
#[derive(Debug, Default)]
pub struct ChannelStats {
    /// Send attempts (inlet).
    pub attempted_sends: AtomicU64,
    /// Sends accepted into the channel (inlet). `attempted - successful`
    /// sends were dropped because the send buffer was full.
    pub successful_sends: AtomicU64,
    /// Pull attempts (outlet), laden or not.
    pub pull_attempts: AtomicU64,
    /// Pull attempts that retrieved >= 1 message (outlet).
    pub laden_pulls: AtomicU64,
    /// Total messages retrieved by pulls (outlet).
    pub messages_received: AtomicU64,
    /// Round-trip touch counter (see [`crate::qos::metrics`]): increments
    /// by two per completed round trip with the partner element.
    pub touches: AtomicU64,
}

impl ChannelStats {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    #[inline]
    pub fn on_send_attempt(&self, accepted: bool) {
        self.attempted_sends.fetch_add(1, Ordering::Relaxed);
        if accepted {
            self.successful_sends.fetch_add(1, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn on_pull(&self, n_messages: u64) {
        self.pull_attempts.fetch_add(1, Ordering::Relaxed);
        if n_messages > 0 {
            self.laden_pulls.fetch_add(1, Ordering::Relaxed);
            self.messages_received.fetch_add(n_messages, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn set_touches(&self, value: u64) {
        self.touches.store(value, Ordering::Relaxed);
    }

    /// Read a consistent-enough tranche of every counter. (Counters are
    /// independently monotone; the paper accepts minor "motion blur" from
    /// non-instantaneous reads, §II-E.)
    pub fn tranche(&self) -> CounterTranche {
        CounterTranche {
            attempted_sends: self.attempted_sends.load(Ordering::Relaxed),
            successful_sends: self.successful_sends.load(Ordering::Relaxed),
            pull_attempts: self.pull_attempts.load(Ordering::Relaxed),
            laden_pulls: self.laden_pulls.load(Ordering::Relaxed),
            messages_received: self.messages_received.load(Ordering::Relaxed),
            touches: self.touches.load(Ordering::Relaxed),
        }
    }
}

/// Common interface over the atomic and single-thread counter tranches.
///
/// Methods take `&self` in both implementations (atomics and `Cell`s are
/// interior-mutable), so instrumentation call sites are identical
/// whichever tranche backs them.
pub trait StatsSink {
    /// Record one send attempt and whether the channel accepted it.
    fn on_send_attempt(&self, accepted: bool);
    /// Record one pull attempt retrieving `n_messages` messages.
    fn on_pull(&self, n_messages: u64);
    /// Publish the current touch-counter value for this channel.
    fn set_touches(&self, value: u64);
    /// Read a tranche of every counter.
    fn tranche(&self) -> CounterTranche;
}

impl StatsSink for ChannelStats {
    #[inline]
    fn on_send_attempt(&self, accepted: bool) {
        ChannelStats::on_send_attempt(self, accepted);
    }

    #[inline]
    fn on_pull(&self, n_messages: u64) {
        ChannelStats::on_pull(self, n_messages);
    }

    #[inline]
    fn set_touches(&self, value: u64) {
        ChannelStats::set_touches(self, value);
    }

    fn tranche(&self) -> CounterTranche {
        ChannelStats::tranche(self)
    }
}

/// Single-threaded counter tranche: plain `Cell<u64>`s, no atomic RMW.
///
/// The discrete-event engine owns every channel it simulates, so its
/// counters never cross threads — `!Sync` by construction (the compiler
/// rejects accidental sharing). On the engine's send/pull hot path this
/// replaces six `lock xadd`-class operations per simstep-channel with
/// plain register arithmetic.
#[derive(Debug, Default)]
pub struct LocalChannelStats {
    attempted_sends: Cell<u64>,
    successful_sends: Cell<u64>,
    pull_attempts: Cell<u64>,
    laden_pulls: Cell<u64>,
    messages_received: Cell<u64>,
    touches: Cell<u64>,
}

impl LocalChannelStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a laden drain's yield without counting the attempt.
    ///
    /// The discrete-event engine derives `pull_attempts` at read time
    /// from the destination proc's update counter (exactly one attempt
    /// per incoming channel per simstep), which is what lets its
    /// idle-skip path avoid visiting clean channels entirely — an
    /// unvisited channel's drain would have observed nothing, so only
    /// the laden-side counters need hot-path writes. Engine-only; the
    /// atomic [`ChannelStats`] hardware path keeps counting attempts
    /// through [`StatsSink::on_pull`].
    #[inline]
    pub fn on_laden_pull(&self, n_messages: u64) {
        if n_messages > 0 {
            self.laden_pulls.set(self.laden_pulls.get() + 1);
            self.messages_received
                .set(self.messages_received.get() + n_messages);
        }
    }

    /// Rebuild counters from a previously captured tranche — engine
    /// checkpoint restore (the tranche is the counters' entire state).
    pub fn from_tranche(t: &CounterTranche) -> Self {
        let s = Self::default();
        s.attempted_sends.set(t.attempted_sends);
        s.successful_sends.set(t.successful_sends);
        s.pull_attempts.set(t.pull_attempts);
        s.laden_pulls.set(t.laden_pulls);
        s.messages_received.set(t.messages_received);
        s.touches.set(t.touches);
        s
    }
}

impl StatsSink for LocalChannelStats {
    #[inline]
    fn on_send_attempt(&self, accepted: bool) {
        self.attempted_sends.set(self.attempted_sends.get() + 1);
        if accepted {
            self.successful_sends.set(self.successful_sends.get() + 1);
        }
    }

    #[inline]
    fn on_pull(&self, n_messages: u64) {
        self.pull_attempts.set(self.pull_attempts.get() + 1);
        if n_messages > 0 {
            self.laden_pulls.set(self.laden_pulls.get() + 1);
            self.messages_received
                .set(self.messages_received.get() + n_messages);
        }
    }

    #[inline]
    fn set_touches(&self, value: u64) {
        self.touches.set(value);
    }

    fn tranche(&self) -> CounterTranche {
        CounterTranche {
            attempted_sends: self.attempted_sends.get(),
            successful_sends: self.successful_sends.get(),
            pull_attempts: self.pull_attempts.get(),
            laden_pulls: self.laden_pulls.get(),
            messages_received: self.messages_received.get(),
            touches: self.touches.get(),
        }
    }
}

/// A point-in-time read of [`ChannelStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterTranche {
    pub attempted_sends: u64,
    pub successful_sends: u64,
    pub pull_attempts: u64,
    pub laden_pulls: u64,
    pub messages_received: u64,
    pub touches: u64,
}

impl CounterTranche {
    /// Elementwise accumulate `other` into `self` — aggregating one
    /// tranche per channel into run totals (engine and thread-executor
    /// delivery accounting).
    pub fn add(&mut self, other: &CounterTranche) {
        self.attempted_sends += other.attempted_sends;
        self.successful_sends += other.successful_sends;
        self.pull_attempts += other.pull_attempts;
        self.laden_pulls += other.laden_pulls;
        self.messages_received += other.messages_received;
        self.touches += other.touches;
    }

    /// Elementwise difference `after - before` (saturating, to tolerate
    /// observation "motion blur" without panicking; the paper notes such
    /// minor invariant violations are possible and acceptable, §II-E).
    pub fn delta(&self, before: &CounterTranche) -> CounterTranche {
        CounterTranche {
            attempted_sends: self.attempted_sends.saturating_sub(before.attempted_sends),
            successful_sends: self
                .successful_sends
                .saturating_sub(before.successful_sends),
            pull_attempts: self.pull_attempts.saturating_sub(before.pull_attempts),
            laden_pulls: self.laden_pulls.saturating_sub(before.laden_pulls),
            messages_received: self
                .messages_received
                .saturating_sub(before.messages_received),
            touches: self.touches.saturating_sub(before.touches),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_attempt_accounting() {
        let s = ChannelStats::new();
        s.on_send_attempt(true);
        s.on_send_attempt(false);
        s.on_send_attempt(true);
        let t = s.tranche();
        assert_eq!(t.attempted_sends, 3);
        assert_eq!(t.successful_sends, 2);
    }

    #[test]
    fn pull_accounting_laden_vs_empty() {
        let s = ChannelStats::new();
        s.on_pull(0);
        s.on_pull(3);
        s.on_pull(0);
        s.on_pull(1);
        let t = s.tranche();
        assert_eq!(t.pull_attempts, 4);
        assert_eq!(t.laden_pulls, 2);
        assert_eq!(t.messages_received, 4);
    }

    #[test]
    fn tranche_add_accumulates_elementwise() {
        let mut total = CounterTranche::default();
        let a = CounterTranche {
            attempted_sends: 3,
            successful_sends: 2,
            pull_attempts: 5,
            laden_pulls: 1,
            messages_received: 4,
            touches: 7,
        };
        total.add(&a);
        total.add(&a);
        assert_eq!(total.attempted_sends, 6);
        assert_eq!(total.successful_sends, 4);
        assert_eq!(total.pull_attempts, 10);
        assert_eq!(total.laden_pulls, 2);
        assert_eq!(total.messages_received, 8);
        assert_eq!(total.touches, 14);
    }

    #[test]
    fn tranche_delta() {
        let s = ChannelStats::new();
        s.on_send_attempt(true);
        let before = s.tranche();
        s.on_send_attempt(true);
        s.on_send_attempt(false);
        s.on_pull(2);
        let after = s.tranche();
        let d = after.delta(&before);
        assert_eq!(d.attempted_sends, 2);
        assert_eq!(d.successful_sends, 1);
        assert_eq!(d.messages_received, 2);
        assert_eq!(d.laden_pulls, 1);
    }

    #[test]
    fn delta_saturates_rather_than_panics() {
        let a = CounterTranche {
            attempted_sends: 5,
            ..Default::default()
        };
        let b = CounterTranche {
            attempted_sends: 9,
            ..Default::default()
        };
        assert_eq!(a.delta(&b).attempted_sends, 0);
    }

    /// Drive a `StatsSink` through one scripted history.
    fn scripted<S: StatsSink>(s: &S) -> CounterTranche {
        s.on_send_attempt(true);
        s.on_send_attempt(false);
        s.on_send_attempt(true);
        s.on_pull(0);
        s.on_pull(3);
        s.set_touches(7);
        s.tranche()
    }

    #[test]
    fn local_tranche_matches_atomic_tranche() {
        let atomic = ChannelStats::new();
        let local = LocalChannelStats::new();
        assert_eq!(scripted(&*atomic), scripted(&local));
        let t = local.tranche();
        assert_eq!(t.attempted_sends, 3);
        assert_eq!(t.successful_sends, 2);
        assert_eq!(t.pull_attempts, 2);
        assert_eq!(t.laden_pulls, 1);
        assert_eq!(t.messages_received, 3);
        assert_eq!(t.touches, 7);
    }

    #[test]
    fn from_tranche_round_trips() {
        let s = LocalChannelStats::new();
        let t = scripted(&s);
        let restored = LocalChannelStats::from_tranche(&t);
        assert_eq!(restored.tranche(), t);
        // Restored counters keep counting from where they left off.
        restored.on_send_attempt(true);
        assert_eq!(restored.tranche().attempted_sends, t.attempted_sends + 1);
    }

    #[test]
    fn concurrent_updates_are_consistent() {
        let s = ChannelStats::new();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    s.on_send_attempt(true);
                    s.on_pull(1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let t = s.tranche();
        assert_eq!(t.attempted_sends, 4000);
        assert_eq!(t.successful_sends, 4000);
        assert_eq!(t.messages_received, 4000);
    }
}
