//! Socket-backed best-effort ducts for the multi-process executor.
//!
//! Where [`thread_duct`](super::thread_duct) moves messages between
//! threads of one process, this backend moves them between *real OS
//! processes* over nonblocking unix-domain stream sockets. It is the
//! hardware analogue of the DES's MPI-model transport: a best-effort
//! `put` genuinely fails when the peer's buffer is full (the kernel
//! socket buffer plus a small bounded send window) or the peer process
//! is gone (`EPIPE`), with no retry and no blocking — the paper's
//! "strives to minimize message latency and loss, but guarantees
//! elimination of neither".
//!
//! # Architecture
//!
//! One [`SocketHub`] per process owns every stream to peer processes
//! (*links*) and multiplexes many directed channels over them. Each
//! channel is identified by a globally unique `wire_id` agreed by both
//! ends. Messages travel as length-prefixed frames:
//!
//! ```text
//! [u32 len][u64 wire_id][u64 touch][u64 t_sent][payload…]   (little endian)
//! ```
//!
//! where `len` counts everything after itself (24 fixed bytes plus the
//! payload) and `t_sent` is a `CLOCK_REALTIME` nanosecond timestamp
//! patched in when the frame's first byte is accepted by the OS
//! (comparable across processes on one host).
//!
//! The send side keeps a bounded per-channel window of frames not yet
//! fully accepted by the OS. A `put` first flushes the link, then drops
//! (`SendOutcome::Dropped`) if the window still holds `capacity`
//! unflushed frames — the MPI-model "send buffer full" failure. The
//! flush/parse state machine (partial writes free a window slot only on
//! the frame's last byte; the parser consumes only complete frames) is
//! model-checked against an oracle in `python/socket_duct_model_fuzz.py`.
//! Socket ducts always reject on overflow; the `Overwrite` latest-value
//! policy is a shared-memory-only semantic and is ignored here.
//!
//! # Stage latency breakdown
//!
//! Following *Breaking Band*'s message-path decomposition, the hub
//! timestamps four stages per message into mergeable
//! [`QuantileSketch`]es ([`StageLatencies`]): **serialize** (frame
//! encode), **enqueue** (window entry until the OS accepts the last
//! byte), **transport** (`t_sent` to parse on the receiving hub), and
//! **drain** (parse until the consumer pulls it). These calibrate the
//! DES `LinkModel` from observed numbers instead of guessed constants.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use super::{ChannelConfig, ChannelStats, Discipline, InletLike, OutletLike, SendOutcome};
use crate::qos::QuantileSketch;

/// Fixed frame bytes after the length prefix: wire id, touch, t_sent.
const FIXED_REMAINDER: u32 = 24;
/// Byte offset of `t_sent` within an encoded frame.
const T_SENT_OFFSET: usize = 20;
/// Sanity bound on the frame remainder — anything larger means the
/// stream is corrupt (desynchronized), not merely carrying a big message.
const MAX_REMAINDER: u32 = 1 << 26;
/// Per-link read chunk size.
const READ_CHUNK: usize = 16 * 1024;

/// The message type socket ducts carry: an opaque serialized payload
/// plus the sender's touch-counter stamp (threaded through the frame
/// header so the receiver can advance its round-trip counter exactly as
/// the in-process executors do with their typed `Envelope`s).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireEnvelope {
    /// Sender-side touch counter value at send time.
    pub touch: u64,
    /// Serialized message bytes (workload-defined encoding).
    pub payload: Vec<u8>,
}

/// Per-stage message-path latency sketches, all in nanoseconds.
///
/// Mergeable across channels, links, and processes (each field is a
/// [`QuantileSketch`]); the coordinator folds every process's stages
/// into one breakdown for `BENCH_multiproc.json`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StageLatencies {
    /// Frame encoding time (message bytes to wire bytes).
    pub serialize: QuantileSketch,
    /// Send-window residence: put accepted until the OS took the last byte.
    pub enqueue: QuantileSketch,
    /// Wall-clock `t_sent` to parse completion on the receiving hub.
    pub transport: QuantileSketch,
    /// Parse completion until the consumer pulled the message.
    pub drain: QuantileSketch,
}

impl StageLatencies {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold another breakdown into this one (sketch merge per stage).
    pub fn merge(&mut self, other: &StageLatencies) {
        self.serialize.merge(&other.serialize);
        self.enqueue.merge(&other.enqueue);
        self.transport.merge(&other.transport);
        self.drain.merge(&other.drain);
    }

    /// No stage has recorded any sample yet.
    pub fn is_empty(&self) -> bool {
        self.serialize.is_empty()
            && self.enqueue.is_empty()
            && self.transport.is_empty()
            && self.drain.is_empty()
    }

    /// Stages in message-path order, labelled for reports.
    pub fn named(&self) -> [(&'static str, &QuantileSketch); 4] {
        [
            ("serialize", &self.serialize),
            ("enqueue", &self.enqueue),
            ("transport", &self.transport),
            ("drain", &self.drain),
        ]
    }
}

/// Encode one frame with a zeroed `t_sent` placeholder (stamped by the
/// flush loop when the first byte goes out).
fn encode_frame(wire_id: u64, touch: u64, payload: &[u8]) -> Vec<u8> {
    let remainder = FIXED_REMAINDER + payload.len() as u32;
    let mut buf = Vec::with_capacity(4 + remainder as usize);
    buf.extend_from_slice(&remainder.to_le_bytes());
    buf.extend_from_slice(&wire_id.to_le_bytes());
    buf.extend_from_slice(&touch.to_le_bytes());
    buf.extend_from_slice(&0u64.to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// A fully parsed frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) struct RawFrame {
    pub wire_id: u64,
    pub touch: u64,
    pub t_sent: u64,
    pub payload: Vec<u8>,
}

/// One parser step over the front of a receive buffer.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum FrameStep {
    /// Not enough bytes for a complete frame; consume nothing.
    Incomplete,
    /// A complete frame occupying the first `usize` bytes.
    Frame(usize, RawFrame),
    /// The stream is desynchronized (impossible length); kill the link.
    Corrupt,
}

fn read_u64(buf: &[u8], at: usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[at..at + 8]);
    u64::from_le_bytes(b)
}

/// Pure frame splitter: examines the front of `buf` without consuming.
/// A partial header or partial payload consumes nothing (mirrors the
/// fuzz model's `parse_frames`).
pub(crate) fn split_frame(buf: &[u8]) -> FrameStep {
    if buf.len() < 4 {
        return FrameStep::Incomplete;
    }
    let mut len_bytes = [0u8; 4];
    len_bytes.copy_from_slice(&buf[..4]);
    let remainder = u32::from_le_bytes(len_bytes);
    if !(FIXED_REMAINDER..=MAX_REMAINDER).contains(&remainder) {
        return FrameStep::Corrupt;
    }
    let total = 4 + remainder as usize;
    if buf.len() < total {
        return FrameStep::Incomplete;
    }
    let frame = RawFrame {
        wire_id: read_u64(buf, 4),
        touch: read_u64(buf, 12),
        t_sent: read_u64(buf, 20),
        payload: buf[4 + FIXED_REMAINDER as usize..total].to_vec(),
    };
    FrameStep::Frame(total, frame)
}

fn now_unix_nanos() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_nanos() as u64
}

/// A frame waiting (fully or partially) for the OS to accept it.
struct PendingFrame {
    tx: usize,
    bytes: Vec<u8>,
    written: usize,
    queued_at: Instant,
}

/// One stream to a peer process, plus its send backlog and read buffer.
struct LinkState {
    stream: UnixStream,
    backlog: VecDeque<PendingFrame>,
    rx_buf: Vec<u8>,
    alive: bool,
}

/// Sender side of one directed channel.
struct TxChan {
    link: usize,
    wire_id: u64,
    capacity: usize,
    pending: usize,
    stats: Arc<ChannelStats>,
}

/// Receiver side of one directed channel: parsed frames awaiting pull.
struct RxChan {
    queue: VecDeque<(u64, Vec<u8>, Instant)>,
    stats: Arc<ChannelStats>,
}

#[derive(Default)]
struct HubCore {
    links: Vec<LinkState>,
    tx: Vec<TxChan>,
    rx: Vec<RxChan>,
    route: HashMap<u64, usize>,
    stages: StageLatencies,
}

/// Drive the link's flush loop: write backlogged frames front-to-back,
/// tolerating partial acceptance; a frame's window slot frees only when
/// its last byte is accepted. Stamps `t_sent` just before the first
/// byte goes out. Kills the link on any hard write error.
fn flush_link(link: &mut LinkState, tx: &mut [TxChan], stages: &mut StageLatencies) {
    while link.alive {
        let Some(front) = link.backlog.front_mut() else {
            return;
        };
        if front.written == 0 {
            let stamp = now_unix_nanos().to_le_bytes();
            front.bytes[T_SENT_OFFSET..T_SENT_OFFSET + 8].copy_from_slice(&stamp);
        }
        match link.stream.write(&front.bytes[front.written..]) {
            Ok(0) => {
                kill_link(link, tx);
                return;
            }
            Ok(n) => {
                front.written += n;
                if front.written == front.bytes.len() {
                    stages
                        .enqueue
                        .insert(front.queued_at.elapsed().as_nanos() as f64);
                    let chan = front.tx;
                    link.backlog.pop_front();
                    tx[chan].pending -= 1;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                kill_link(link, tx);
                return;
            }
        }
    }
}

/// Peer is gone (or the stream broke): discard everything still
/// backlogged and stop touching the stream. Frames already fully
/// accepted by the OS may or may not arrive — that is the peer's
/// kernel's business now.
fn kill_link(link: &mut LinkState, tx: &mut [TxChan]) {
    link.alive = false;
    for frame in link.backlog.drain(..) {
        tx[frame.tx].pending -= 1;
    }
}

/// Per-process multiplexer over nonblocking streams to peer processes.
///
/// Clone-able handle; endpoints ([`SocketInlet`], [`SocketOutlet`])
/// share the hub's core. The owning executor calls [`SocketHub::poll`]
/// once per work-loop pass to flush send backlogs and parse inbound
/// bytes; endpoint operations themselves never block.
#[derive(Clone)]
pub struct SocketHub {
    core: Arc<Mutex<HubCore>>,
}

impl Default for SocketHub {
    fn default() -> Self {
        Self::new()
    }
}

impl SocketHub {
    pub fn new() -> Self {
        Self {
            core: Arc::new(Mutex::new(HubCore::default())),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HubCore> {
        self.core.lock().expect("socket hub poisoned")
    }

    /// Register a stream to a peer process; returns its link id.
    pub fn add_link(&self, stream: UnixStream) -> io::Result<usize> {
        stream.set_nonblocking(true)?;
        let mut core = self.lock();
        core.links.push(LinkState {
            stream,
            backlog: VecDeque::new(),
            rx_buf: Vec::new(),
            alive: true,
        });
        Ok(core.links.len() - 1)
    }

    /// Open the send side of directed channel `wire_id` over `link`.
    /// `config.capacity` bounds the send window; the overflow policy is
    /// ignored (socket ducts always reject — MPI-model semantics).
    pub fn open_sender(&self, link: usize, wire_id: u64, config: ChannelConfig) -> SocketInlet {
        let stats = ChannelStats::new();
        let mut core = self.lock();
        assert!(link < core.links.len(), "unknown link {link}");
        core.tx.push(TxChan {
            link,
            wire_id,
            capacity: config.capacity.max(1),
            pending: 0,
            stats: Arc::clone(&stats),
        });
        SocketInlet {
            core: Arc::clone(&self.core),
            tx: core.tx.len() - 1,
            stats,
            discipline: AtomicU8::new(Discipline::BestEffort.as_u8()),
        }
    }

    /// Open the receive side of directed channel `wire_id`. Inbound
    /// frames for unregistered wire ids are discarded on parse.
    pub fn open_receiver(&self, wire_id: u64) -> SocketOutlet {
        let stats = ChannelStats::new();
        let mut core = self.lock();
        core.rx.push(RxChan {
            queue: VecDeque::new(),
            stats: Arc::clone(&stats),
        });
        let idx = core.rx.len() - 1;
        core.route.insert(wire_id, idx);
        SocketOutlet {
            core: Arc::clone(&self.core),
            rx: idx,
            stats,
            discipline: AtomicU8::new(Discipline::BestEffort.as_u8()),
        }
    }

    /// One nonblocking service pass over every link: flush send
    /// backlogs, read inbound bytes, parse complete frames into their
    /// channel queues. Call once per executor work-loop pass.
    pub fn poll(&self) {
        let mut core = self.lock();
        let HubCore {
            links,
            tx,
            rx,
            route,
            stages,
        } = &mut *core;
        for link in links.iter_mut() {
            flush_link(link, tx, stages);
            if !link.alive {
                continue;
            }
            let mut chunk = [0u8; READ_CHUNK];
            loop {
                match link.stream.read(&mut chunk) {
                    Ok(0) => {
                        kill_link(link, tx);
                        break;
                    }
                    Ok(n) => {
                        link.rx_buf.extend_from_slice(&chunk[..n]);
                        if n < READ_CHUNK {
                            break;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        kill_link(link, tx);
                        break;
                    }
                }
            }
            let mut at = 0;
            loop {
                match split_frame(&link.rx_buf[at..]) {
                    FrameStep::Incomplete => break,
                    FrameStep::Corrupt => {
                        kill_link(link, tx);
                        break;
                    }
                    FrameStep::Frame(consumed, frame) => {
                        at += consumed;
                        stages
                            .transport
                            .insert(now_unix_nanos().saturating_sub(frame.t_sent) as f64);
                        if let Some(&idx) = route.get(&frame.wire_id) {
                            rx[idx]
                                .queue
                                .push_back((frame.touch, frame.payload, Instant::now()));
                        }
                    }
                }
            }
            link.rx_buf.drain(..at);
        }
    }

    /// Is the link still usable (peer reachable, stream intact)?
    pub fn link_alive(&self, link: usize) -> bool {
        let core = self.lock();
        core.links.get(link).is_some_and(|l| l.alive)
    }

    /// Snapshot the per-stage latency breakdown recorded so far.
    pub fn stage_latencies(&self) -> StageLatencies {
        self.lock().stages.clone()
    }
}

/// Sender endpoint of a socket duct.
///
/// Discipline is stored per endpoint (the peer endpoint lives in a
/// different OS process); each executor stamps its own side from the
/// same policy, so the two ends agree without wire traffic.
pub struct SocketInlet {
    core: Arc<Mutex<HubCore>>,
    tx: usize,
    stats: Arc<ChannelStats>,
    discipline: AtomicU8,
}

impl InletLike<WireEnvelope> for SocketInlet {
    fn put(&self, msg: WireEnvelope) -> SendOutcome {
        let mut core = self.core.lock().expect("socket hub poisoned");
        let HubCore {
            links, tx, stages, ..
        } = &mut *core;
        let chan = &tx[self.tx];
        let (link_idx, wire_id) = (chan.link, chan.wire_id);
        let t0 = Instant::now();
        let bytes = encode_frame(wire_id, msg.touch, &msg.payload);
        stages.serialize.insert(t0.elapsed().as_nanos() as f64);
        let link = &mut links[link_idx];
        flush_link(link, tx, stages);
        if !link.alive {
            self.stats.on_send_attempt(false);
            return SendOutcome::Dropped;
        }
        if tx[self.tx].pending >= tx[self.tx].capacity {
            self.stats.on_send_attempt(false);
            return SendOutcome::Dropped;
        }
        tx[self.tx].pending += 1;
        link.backlog.push_back(PendingFrame {
            tx: self.tx,
            bytes,
            written: 0,
            queued_at: Instant::now(),
        });
        flush_link(link, tx, stages);
        if !link.alive {
            // The peer died while this frame was (partially) backlogged:
            // the message did not enter the channel.
            self.stats.on_send_attempt(false);
            return SendOutcome::Dropped;
        }
        self.stats.on_send_attempt(true);
        SendOutcome::Accepted
    }

    fn stats(&self) -> &ChannelStats {
        &self.stats
    }

    fn discipline(&self) -> Discipline {
        Discipline::from_u8(self.discipline.load(Ordering::Relaxed))
            .unwrap_or(Discipline::BestEffort)
    }

    fn set_discipline(&self, d: Discipline) {
        self.discipline.store(d.as_u8(), Ordering::Relaxed);
    }
}

/// Receiver endpoint of a socket duct. [`SocketHub::poll`] moves parsed
/// frames into its queue; pulls never touch the stream. Discipline is
/// per-endpoint, like [`SocketInlet`]'s.
pub struct SocketOutlet {
    core: Arc<Mutex<HubCore>>,
    rx: usize,
    stats: Arc<ChannelStats>,
    discipline: AtomicU8,
}

impl SocketOutlet {
    fn drain<F: FnMut(WireEnvelope)>(&self, mut sink: F) -> u64 {
        let mut core = self.core.lock().expect("socket hub poisoned");
        let HubCore { rx, stages, .. } = &mut *core;
        let queue = &mut rx[self.rx].queue;
        let n = queue.len() as u64;
        for (touch, payload, parsed_at) in queue.drain(..) {
            stages.drain.insert(parsed_at.elapsed().as_nanos() as f64);
            sink(WireEnvelope { touch, payload });
        }
        n
    }
}

impl OutletLike<WireEnvelope> for SocketOutlet {
    fn pull_all(&self) -> Vec<WireEnvelope> {
        let mut out = Vec::new();
        self.pull_all_into(&mut out);
        out
    }

    fn pull_all_into(&self, out: &mut Vec<WireEnvelope>) {
        let n = self.drain(|env| out.push(env));
        self.stats.on_pull(n);
    }

    fn pull_latest(&self) -> Option<WireEnvelope> {
        let mut latest = None;
        let n = self.drain(|env| latest = Some(env));
        self.stats.on_pull(n);
        latest
    }

    fn stats(&self) -> &ChannelStats {
        &self.stats
    }

    fn discipline(&self) -> Discipline {
        Discipline::from_u8(self.discipline.load(Ordering::Relaxed))
            .unwrap_or(Discipline::BestEffort)
    }

    fn set_discipline(&self, d: Discipline) {
        self.discipline.store(d.as_u8(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linked_hubs() -> (SocketHub, usize, SocketHub, usize) {
        let (a, b) = UnixStream::pair().expect("socketpair");
        let hub_a = SocketHub::new();
        let la = hub_a.add_link(a).expect("add link a");
        let hub_b = SocketHub::new();
        let lb = hub_b.add_link(b).expect("add link b");
        (hub_a, la, hub_b, lb)
    }

    #[test]
    fn discipline_stamp_is_per_endpoint() {
        let (hub_a, la, hub_b, _lb) = linked_hubs();
        let inlet = hub_a.open_sender(la, 11, ChannelConfig::qos());
        let outlet = hub_b.open_receiver(11);
        assert_eq!(inlet.discipline(), Discipline::BestEffort);
        assert_eq!(outlet.discipline(), Discipline::BestEffort);
        inlet.set_discipline(Discipline::Barriered);
        assert_eq!(inlet.discipline(), Discipline::Barriered);
        // Cross-process endpoints do not share storage: each executor
        // stamps its own side.
        assert_eq!(outlet.discipline(), Discipline::BestEffort);
    }

    #[test]
    fn roundtrip_preserves_order_content_and_stats() {
        let (hub_a, la, hub_b, _lb) = linked_hubs();
        let inlet = hub_a.open_sender(la, 7, ChannelConfig::qos());
        let outlet = hub_b.open_receiver(7);
        for i in 0..10u64 {
            let env = WireEnvelope {
                touch: i,
                payload: vec![i as u8; 3 + i as usize],
            };
            assert_eq!(inlet.put(env), SendOutcome::Accepted);
        }
        hub_b.poll();
        let got = outlet.pull_all();
        assert_eq!(got.len(), 10);
        for (i, env) in got.iter().enumerate() {
            assert_eq!(env.touch, i as u64);
            assert_eq!(env.payload, vec![i as u8; 3 + i]);
        }
        let it = inlet.stats().tranche();
        assert_eq!(it.attempted_sends, 10);
        assert_eq!(it.successful_sends, 10);
        let ot = outlet.stats().tranche();
        assert_eq!(ot.pull_attempts, 1);
        assert_eq!(ot.laden_pulls, 1);
        assert_eq!(ot.messages_received, 10);
        // Stage breakdown: sender side records serialize+enqueue,
        // receiver side transport+drain.
        let sa = hub_a.stage_latencies();
        assert_eq!(sa.serialize.count(), 10);
        assert_eq!(sa.enqueue.count(), 10);
        let sb = hub_b.stage_latencies();
        assert_eq!(sb.transport.count(), 10);
        assert_eq!(sb.drain.count(), 10);
        let mut merged = sa.clone();
        merged.merge(&sb);
        assert_eq!(merged.transport.count(), 10);
        assert!(!merged.is_empty());
    }

    #[test]
    fn full_buffer_flood_drops_and_counts_delivery_failure() {
        let (a, b) = UnixStream::pair().expect("socketpair");
        let hub = SocketHub::new();
        let link = hub.add_link(a).expect("add link");
        let inlet = hub.open_sender(
            link,
            1,
            ChannelConfig {
                capacity: 2,
                overflow: crate::util::ring::Overflow::Reject,
            },
        );
        // Nobody reads from `b`: the kernel buffer fills, then the
        // 2-frame send window, then puts must genuinely drop.
        let payload = vec![0xABu8; 32 * 1024];
        let mut dropped = 0u64;
        for i in 0..64u64 {
            let env = WireEnvelope {
                touch: i,
                payload: payload.clone(),
            };
            if !inlet.put(env).delivered_to_channel() {
                dropped += 1;
            }
        }
        assert!(dropped > 0, "flood never filled the send buffer");
        let t = inlet.stats().tranche();
        assert_eq!(t.attempted_sends, 64);
        assert_eq!(t.successful_sends, 64 - dropped);
        assert!(hub.link_alive(link), "flood must not kill the link");
        drop(b);
    }

    #[test]
    fn peer_death_fails_subsequent_puts() {
        let (a, b) = UnixStream::pair().expect("socketpair");
        let hub = SocketHub::new();
        let link = hub.add_link(a).expect("add link");
        let inlet = hub.open_sender(link, 1, ChannelConfig::qos());
        let env = WireEnvelope {
            touch: 0,
            payload: vec![1, 2, 3],
        };
        assert_eq!(inlet.put(env.clone()), SendOutcome::Accepted);
        drop(b); // peer process dies
        let mut saw_drop = false;
        for _ in 0..4 {
            if inlet.put(env.clone()) == SendOutcome::Dropped {
                saw_drop = true;
                break;
            }
        }
        assert!(saw_drop, "puts to a dead peer must fail");
        assert!(!hub.link_alive(link));
        // Once dead, every further put is a counted delivery failure.
        assert_eq!(inlet.put(env), SendOutcome::Dropped);
        let t = inlet.stats().tranche();
        assert!(t.attempted_sends > t.successful_sends);
    }

    #[test]
    fn partial_frames_parse_only_when_complete() {
        let mut frame = encode_frame(42, 9, &[0xDE, 0xAD, 0xBE]);
        frame[T_SENT_OFFSET..T_SENT_OFFSET + 8].copy_from_slice(&777u64.to_le_bytes());
        let mut buf = Vec::new();
        for (i, byte) in frame.iter().enumerate() {
            buf.push(*byte);
            if i + 1 < frame.len() {
                assert_eq!(
                    split_frame(&buf),
                    FrameStep::Incomplete,
                    "byte {i}: partial frame must consume nothing"
                );
            }
        }
        match split_frame(&buf) {
            FrameStep::Frame(consumed, raw) => {
                assert_eq!(consumed, frame.len());
                assert_eq!(raw.wire_id, 42);
                assert_eq!(raw.touch, 9);
                assert_eq!(raw.t_sent, 777);
                assert_eq!(raw.payload, vec![0xDE, 0xAD, 0xBE]);
            }
            other => panic!("expected a complete frame, got {other:?}"),
        }
        // A length below the fixed header size means desynchronization.
        let corrupt = 5u32.to_le_bytes().to_vec();
        assert_eq!(split_frame(&corrupt), FrameStep::Corrupt);
    }

    #[test]
    fn pull_latest_keeps_freshest_message() {
        let (hub_a, la, hub_b, _lb) = linked_hubs();
        let inlet = hub_a.open_sender(la, 3, ChannelConfig::qos());
        let outlet = hub_b.open_receiver(3);
        for i in 0..5u64 {
            let env = WireEnvelope {
                touch: i,
                payload: vec![i as u8],
            };
            assert_eq!(inlet.put(env), SendOutcome::Accepted);
        }
        hub_b.poll();
        let latest = outlet.pull_latest().expect("one message kept");
        assert_eq!(latest.touch, 4);
        assert_eq!(outlet.pull_latest(), None);
        let t = outlet.stats().tranche();
        assert_eq!(t.pull_attempts, 2);
        assert_eq!(t.messages_received, 5);
    }

    #[test]
    fn frames_for_unknown_wire_ids_are_discarded() {
        let (hub_a, la, hub_b, _lb) = linked_hubs();
        let inlet = hub_a.open_sender(la, 99, ChannelConfig::qos());
        let outlet = hub_b.open_receiver(7);
        let env = WireEnvelope {
            touch: 1,
            payload: vec![0],
        };
        assert_eq!(inlet.put(env), SendOutcome::Accepted);
        hub_b.poll();
        assert!(outlet.pull_all().is_empty());
    }
}
