//! Asynchronicity modes 0–4 (paper Table I).
//!
//! | mode | description |
//! |---|---|
//! | 0 | Barrier sync every update |
//! | 1 | Rolling barrier sync (fixed-length work chunks between barriers) |
//! | 2 | Fixed barrier sync (barriers at predetermined epoch timepoints) |
//! | 3 | No barrier sync (fully best-effort) |
//! | 4 | No inter-CPU communication at all |

use crate::util::{Nanos, MILLI, SECOND};

/// Synchronization discipline of a run, most- to least-synchronized.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AsyncMode {
    /// Mode 0: full barrier between every computational update.
    Sync = 0,
    /// Mode 1: work for a fixed-duration chunk, then barrier, repeat.
    /// (Paper: 10 ms chunks for graph coloring, 100 ms for digital
    /// evolution.)
    RollingBarrier = 1,
    /// Mode 2: barrier at predetermined epoch timepoints (paper: every
    /// elapsed second of epoch time — vulnerable to the startup-offset
    /// race of §III-B).
    FixedBarrier = 2,
    /// Mode 3: fully asynchronous best-effort communication.
    BestEffort = 3,
    /// Mode 4: all inter-CPU communication disabled (isolates
    /// communication costs from e.g. cache crowding).
    NoComm = 4,
}

impl AsyncMode {
    pub const ALL: [AsyncMode; 5] = [
        AsyncMode::Sync,
        AsyncMode::RollingBarrier,
        AsyncMode::FixedBarrier,
        AsyncMode::BestEffort,
        AsyncMode::NoComm,
    ];

    pub fn from_index(i: usize) -> Option<AsyncMode> {
        Self::ALL.get(i).copied()
    }

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn label(self) -> &'static str {
        match self {
            AsyncMode::Sync => "mode 0 (barrier every update)",
            AsyncMode::RollingBarrier => "mode 1 (rolling barrier)",
            AsyncMode::FixedBarrier => "mode 2 (fixed barrier)",
            AsyncMode::BestEffort => "mode 3 (no barrier)",
            AsyncMode::NoComm => "mode 4 (no communication)",
        }
    }

    /// Does this mode exchange inter-CPU messages?
    pub fn communicates(self) -> bool {
        self != AsyncMode::NoComm
    }

    /// Does this mode ever execute barriers?
    pub fn uses_barriers(self) -> bool {
        matches!(
            self,
            AsyncMode::Sync | AsyncMode::RollingBarrier | AsyncMode::FixedBarrier
        )
    }
}

/// Mode-specific timing parameters.
#[derive(Clone, Copy, Debug)]
pub struct ModeTiming {
    /// Mode-1 work-chunk duration.
    pub rolling_chunk: Nanos,
    /// Mode-2 epoch between predetermined sync points.
    pub fixed_epoch: Nanos,
    /// Mode-2 maximum per-process startup skew. Nonzero skew reproduces
    /// the race the paper suspects behind mode 2's poor 64-process
    /// solution quality (§III-B: "workers would assign sync points to
    /// different fixed points based on slightly different startup times").
    pub fixed_skew_max: Nanos,
}

impl ModeTiming {
    /// Graph-coloring benchmark timing (10 ms chunks, §II-C).
    pub fn graph_coloring(n_procs: usize) -> Self {
        Self {
            rolling_chunk: 10 * MILLI,
            fixed_epoch: SECOND,
            fixed_skew_max: skew_for(n_procs),
        }
    }

    /// Digital-evolution benchmark timing (100 ms chunks, §II-C).
    pub fn digital_evolution(n_procs: usize) -> Self {
        Self {
            rolling_chunk: 100 * MILLI,
            fixed_epoch: SECOND,
            fixed_skew_max: skew_for(n_procs),
        }
    }
}

/// Startup skew grows with job size (staggered process launch), saturating
/// at a full epoch.
fn skew_for(n_procs: usize) -> Nanos {
    let frac = (n_procs as f64 / 64.0).min(1.0);
    (frac * SECOND as f64) as Nanos
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_indices_match_paper_table() {
        for (i, m) in AsyncMode::ALL.iter().enumerate() {
            assert_eq!(m.index(), i);
            assert_eq!(AsyncMode::from_index(i), Some(*m));
        }
        assert_eq!(AsyncMode::from_index(5), None);
    }

    #[test]
    fn communication_and_barrier_flags() {
        assert!(AsyncMode::Sync.uses_barriers());
        assert!(AsyncMode::RollingBarrier.uses_barriers());
        assert!(AsyncMode::FixedBarrier.uses_barriers());
        assert!(!AsyncMode::BestEffort.uses_barriers());
        assert!(!AsyncMode::NoComm.uses_barriers());
        assert!(AsyncMode::BestEffort.communicates());
        assert!(!AsyncMode::NoComm.communicates());
    }

    #[test]
    fn paper_chunk_durations() {
        assert_eq!(ModeTiming::graph_coloring(64).rolling_chunk, 10 * MILLI);
        assert_eq!(ModeTiming::digital_evolution(64).rolling_chunk, 100 * MILLI);
        assert_eq!(ModeTiming::graph_coloring(64).fixed_epoch, SECOND);
    }

    #[test]
    fn skew_scales_and_saturates() {
        assert!(
            ModeTiming::graph_coloring(4).fixed_skew_max
                < ModeTiming::graph_coloring(64).fixed_skew_max
        );
        assert_eq!(
            ModeTiming::graph_coloring(64).fixed_skew_max,
            ModeTiming::graph_coloring(256).fixed_skew_max
        );
    }
}
