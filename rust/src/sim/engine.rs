//! Deterministic discrete-event simulation of a multi-node allocation.
//!
//! The engine stands in for the paper's testbed (see DESIGN.md §2). Each
//! simulated process owns a [`ShardWorkload`] and advances through
//! simsteps — pull/absorb, compute, send — on its own virtual clock.
//! **Workload state updates are real computation; only time is virtual**,
//! so solution quality (graph-coloring conflicts, evolutionary fitness) is
//! genuinely produced by the simulated communication regime, not modelled.
//!
//! Cost model per simstep:
//!
//! * compute: `(workload.step_cost_ns() + work_units × 35 ns)` scaled by
//!   the node profile (speed, lognormal jitter, rare OS-noise stalls) and
//!   a contention factor for co-scheduled CPUs;
//! * per-channel send/pull CPU overheads from the [`LinkModel`];
//! * message delivery at `depart + latency`, where departures drain from
//!   a bounded send buffer at the link's service interval — a send
//!   attempted against a full buffer is **dropped**, the paper's only
//!   loss condition;
//! * barrier semantics per asynchronicity mode (Table I), with barrier
//!   cost growing logarithmically in process count.

use super::calendar::{SchedKind, Scheduler};
use super::checkpoint::{Persist, SnapError, SnapReader, SnapWriter};
use super::lanes::EnvelopeLanes;
use super::modes::{AsyncMode, ModeTiming};
use crate::conduit::{CounterTranche, LocalChannelStats, SendOutcome, StatsSink};
use crate::faults::{FaultKind, FaultRuntime, FaultScenario, ScenarioPhase};
use crate::net::{LinkModel, NodeProfile, PlacementKind, Topology};
use crate::qos::{QosObservation, ReplicateQos, SnapshotSchedule, SnapshotWindow, TouchCounter};
use crate::util::rng::{Rng, Xoshiro256};
use crate::util::{Nanos, MICRO};
use crate::workloads::{ChannelSpec, ShardWorkload, SpecIndex};

/// Which transport backs inter-CPU channels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommBackend {
    /// MPI-model links: intranode or internode per placement.
    Mpi,
    /// Shared-memory mutex links (multithreading, §III-E).
    SharedMemory,
}

/// Contention factor for co-scheduled CPUs on one node:
/// `1 + a * (k - 1)^b` for `k` co-resident processes/threads.
///
/// The paper observes severe per-CPU slowdown under multithreading even
/// with communication disabled (mode 4) — 61 % loss from 1→4 threads on
/// graph coloring — attributing it to "strain on a limited system resource
/// like memory cache or access to the system clock" (§III-A). The (a, b)
/// constants below are calibrated to those mode-4 measurements.
#[derive(Clone, Copy, Debug)]
pub struct ContentionModel {
    pub a: f64,
    pub b: f64,
}

impl ContentionModel {
    /// No contention (distinct-node multiprocessing).
    pub fn none() -> Self {
        Self { a: 0.0, b: 1.0 }
    }

    /// Graph-coloring multithread calibration: f(4) ≈ 2.56, f(64) ≈ 10.
    pub fn graph_coloring_threads() -> Self {
        Self { a: 0.82, b: 0.58 }
    }

    /// Digital-evolution multithread calibration: f(64) ≈ 1.64
    /// (mode-4 update rate 61 % of lone thread at 64 threads, §III-A).
    pub fn digital_evolution_threads() -> Self {
        Self { a: 0.045, b: 0.63 }
    }

    pub fn factor(&self, co_resident: usize) -> f64 {
        if co_resident <= 1 {
            1.0
        } else {
            1.0 + self.a * ((co_resident - 1) as f64).powf(self.b)
        }
    }
}

/// Simulation run configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub mode: AsyncMode,
    pub timing: ModeTiming,
    pub backend: CommBackend,
    pub seed: u64,
    /// Virtual runtime.
    pub run_for: Nanos,
    /// Synthetic per-update compute work (paper work units, 35 ns each).
    pub added_work_units: u64,
    /// Send-buffer capacity in messages (paper: 2 benchmarking, 64 QoS).
    pub send_buffer: usize,
    /// Physical cores per node (paper lac nodes: 28).
    pub cores_per_node: usize,
    pub contention: ContentionModel,
    /// Barrier cost: `base + per_log2 * log2(P)` ns, plus an exponential
    /// tail of mean `tail * log2(P)` sampled per release — collective
    /// operations on real clusters have heavy-tailed completion times
    /// (network contention, OS noise on any participant).
    pub barrier_base_ns: f64,
    pub barrier_per_log2_ns: f64,
    pub barrier_tail_ns: f64,
    /// Optional QoS snapshot schedule.
    pub snapshots: Option<SnapshotSchedule>,
    /// Override the link coalescing window (ablation hook): `Some(0)`
    /// disables arrival batching entirely.
    pub coalesce_override: Option<Nanos>,
    /// Which event scheduler backs the wake queue. Defaults from the
    /// `EBCOMM_SCHED` env var (`"heap"` / `"calendar"`); both produce
    /// bit-identical simulations — see `sim::calendar`.
    pub sched: SchedKind,
    /// Scripted time-varying fault timeline (see [`crate::faults`]).
    /// Compiled into calendar-queue wake events at construction; the
    /// default empty scenario leaves the engine on the static-profile
    /// path, bit-identically.
    pub scenario: FaultScenario,
}

impl SimConfig {
    pub fn new(mode: AsyncMode, timing: ModeTiming, run_for: Nanos) -> Self {
        Self {
            mode,
            timing,
            backend: CommBackend::Mpi,
            seed: 1,
            run_for,
            added_work_units: 0,
            send_buffer: 2,
            cores_per_node: 28,
            contention: ContentionModel::none(),
            barrier_base_ns: 4.0 * MICRO as f64,
            barrier_per_log2_ns: 30.0 * MICRO as f64,
            barrier_tail_ns: 100.0 * MICRO as f64,
            snapshots: None,
            coalesce_override: None,
            sched: SchedKind::from_env(),
            scenario: FaultScenario::default(),
        }
    }

    fn barrier_cost(&self, n_procs: usize, rng: &mut Xoshiro256) -> Nanos {
        let log2 = (n_procs.max(1) as f64).log2();
        let tail = rng.exponential(self.barrier_tail_ns * log2.max(1.0));
        (self.barrier_base_ns + self.barrier_per_log2_ns * log2 + tail) as Nanos
    }
}

/// One directed inter-process channel.
struct SimChannel<M> {
    src: usize,
    dst: usize,
    /// Channel index within the source's channel list.
    src_ch: usize,
    /// Channel index within the destination's channel list (reciprocal).
    dst_ch: usize,
    /// Workload layer tag of the source's spec — retained so membership
    /// rejoin can re-derive the reciprocal wiring through the
    /// [`SpecIndex`] instead of trusting possibly-stale cached indices.
    layer: usize,
    /// Hosting nodes of the endpoints (cached off the topology so the
    /// fault overlay's per-send effective-parameter lookup is O(1)).
    src_node: usize,
    dst_node: usize,
    /// Endpoints on distinct nodes (storms/partitions only touch these).
    crossnode: bool,
    link: LinkModel,
    /// `link.service_ns` before the static endpoint-health scaling — the
    /// fault overlay rescales from this base when effective health
    /// changes mid-run.
    service_unscaled_ns: f64,
    latency_factor: f64,
    extra_drop: f64,
    last_depart: Nanos,
    last_arrival: Nanos,
    /// In-flight envelopes in push order, stored SoA (parallel
    /// depart/arrival/touch/payload lanes). Departure times are monotone
    /// non-decreasing front to back (each departure is scheduled at
    /// `now.max(last_depart + service)`), which is what makes O(1)
    /// occupancy tracking below sound; arrivals are monotone too, so
    /// pulls drain a prefix as one batched lane splice.
    lanes: EnvelopeLanes<M>,
    /// Envelopes ever accepted into the channel.
    pushed: u64,
    /// Envelopes drained by the receiver (prefix of push order).
    pulled: u64,
    /// Monotone departed-prefix counter: how many envelopes, in push
    /// order, are known to have left the send buffer (`depart <= t` for
    /// the latest occupancy query time `t`). Each envelope is stepped
    /// over at most once, so occupancy is amortized O(1) instead of the
    /// former O(queue) reverse scan per send.
    departed: u64,
    stats: LocalChannelStats,
}

impl<M> SimChannel<M> {
    /// Messages still occupying the send buffer at time `now`.
    ///
    /// Occupants are the envelopes that neither departed (`depart <=
    /// now`) nor were already pulled by the receiver; both sets are
    /// prefixes of push order (departures because departure times are
    /// monotone, pulls because the receiver drains front to back), so
    /// the count is `pushed - max(departed, pulled)`. Queries for one
    /// channel come from its single source process, whose clock is
    /// monotone — the departed prefix only ever advances.
    fn occupancy(&mut self, now: Nanos) -> usize {
        let mut done = self.departed.max(self.pulled);
        while done < self.pushed {
            let idx = (done - self.pulled) as usize;
            if self.lanes.depart_at(idx) <= now {
                done += 1;
            } else {
                break;
            }
        }
        self.departed = done;
        (self.pushed - done) as usize
    }
}

/// Per-process simulation state.
struct ProcState<W: ShardWorkload> {
    workload: W,
    rng: Xoshiro256,
    clock: Nanos,
    updates: u64,
    /// Outgoing channel ids (into `Engine::channels`), by workload
    /// channel index.
    outgoing: Vec<usize>,
    /// Incoming channel ids, paired with the local workload channel index
    /// they deliver to.
    incoming: Vec<(usize, usize)>,
    /// For each incoming entry, the index (into `outgoing`/`touch`) of the
    /// reciprocal outgoing channel — precomputed so the touch-counter
    /// update is O(1) per laden pull (SPerf iteration 5).
    reciprocal_out: Vec<Option<usize>>,
    /// Touch counter per outgoing channel (tracks the peer relationship).
    touch: Vec<TouchCounter>,
    /// Mode-1 chunk start.
    chunk_start: Nanos,
    /// Mode-2 next fixed sync point.
    next_fixed_sync: Nanos,
    finished: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Ev {
    SnapOpen(usize),
    SnapClose(usize),
    Wake(usize),
    /// Scenario-event transition (index into `SimConfig::scenario`):
    /// window open/close or a flap toggle, driven by the fault overlay's
    /// state machine.
    Fault(usize),
}

/// Result of one simulated replicate.
pub struct SimResult<W> {
    /// Final workload shards (for solution-quality assessment).
    pub shards: Vec<W>,
    /// Updates completed per process.
    pub updates: Vec<u64>,
    /// Virtual runtime simulated.
    pub run_for: Nanos,
    /// All QoS snapshot metrics (per channel per window, inlet/outlet
    /// averaged).
    pub qos: ReplicateQos,
    /// Per-window per-channel raw windows (for mean/median splits).
    pub windows: Vec<SnapshotWindow>,
    /// Global delivery accounting.
    pub attempted_sends: u64,
    pub successful_sends: u64,
    /// Messages actually retrieved by receiver pulls.
    pub messages_delivered: u64,
    /// Messages discarded from channels when their receiver departed the
    /// allocation (membership churn). Zero for churn-free runs.
    pub messages_purged: u64,
    /// Messages still queued in channels at run end.
    pub messages_in_flight: u64,
}

impl<W> SimResult<W> {
    /// Mean per-CPU update rate in updates/second of virtual time.
    pub fn update_rate_per_cpu_hz(&self) -> f64 {
        if self.updates.is_empty() || self.run_for == 0 {
            return 0.0;
        }
        let mean_updates =
            self.updates.iter().sum::<u64>() as f64 / self.updates.len() as f64;
        mean_updates / (self.run_for as f64 / 1e9)
    }

    /// Global delivery failure fraction over the whole run.
    pub fn overall_failure_rate(&self) -> f64 {
        if self.attempted_sends == 0 {
            0.0
        } else {
            1.0 - self.successful_sends as f64 / self.attempted_sends as f64
        }
    }

    /// Message-conservation invariant: every send accepted into a channel
    /// was delivered, purged on receiver departure, or is still in
    /// flight. Cross-checks the per-channel stats cells against the lane
    /// bookkeeping; chaos campaigns assert this on every timeline.
    pub fn conserves_messages(&self) -> bool {
        self.successful_sends
            == self.messages_delivered + self.messages_purged + self.messages_in_flight
    }
}

/// The discrete-event engine.
pub struct Engine<W: ShardWorkload> {
    cfg: SimConfig,
    topo: Topology,
    profiles: Vec<NodeProfile>,
    procs: Vec<ProcState<W>>,
    channels: Vec<SimChannel<W::Msg>>,
    sched: Box<dyn Scheduler<Ev> + Send>,
    seq: u64,
    /// Barrier bookkeeping: arrivals and max arrival time.
    barrier_waiting: Vec<bool>,
    barrier_count: usize,
    barrier_max_arrival: Nanos,
    /// Snapshot capture: per-channel observations at window open.
    snap_open: Vec<(QosObservation, QosObservation)>,
    windows: Vec<SnapshotWindow>,
    /// Fault-scenario overlay; `None` for empty scenarios, which keeps
    /// the static-profile path bit-identical (no overlay reads, no extra
    /// scheduled events).
    faults: Option<FaultRuntime>,
    /// Union of fault phases observed while the current snapshot window
    /// is open (folds mid-window transitions into the window tag).
    window_phase: ScenarioPhase,
    /// Engine-level randomness (barrier tails etc.).
    engine_rng: Xoshiro256,
    /// Reusable pull-phase message buffer: one allocation serves every
    /// channel of every simstep (absorb drains it), instead of a fresh
    /// `Vec` per laden channel per simstep.
    pull_scratch: Vec<W::Msg>,
    /// Reusable barrier-release buffer: the N same-timestamp wakes of a
    /// release are staged here and handed to the scheduler as one
    /// [`Scheduler::push_batch_same_t`] call (which drains it back to
    /// empty), instead of N independent pushes per barrier.
    wake_batch: Vec<Ev>,
    /// Membership: is process `p` currently part of the allocation?
    /// All-true for churn-free scenarios (and never consulted on their
    /// hot paths in a way that changes behaviour).
    live: Vec<bool>,
    /// `live.iter().filter(|&&l| l).count()`, maintained incrementally —
    /// barrier releases wait for exactly the live participants.
    live_count: usize,
    /// Messages discarded from channels whose receiver departed.
    purged: u64,
    /// Is a `Ev::Wake(p)` currently in the scheduler (or an arrival
    /// recorded at the barrier)? Rejoin schedules a wake only when this
    /// is false, so a process can never hold two wake events at once.
    wake_armed: Vec<bool>,
    /// Processes named by any churn event, sorted and deduplicated —
    /// the only ones membership reconciliation must inspect. Empty for
    /// churn-free scenarios, which short-circuits reconciliation.
    churn_procs: Vec<usize>,
    /// Retained channel-spec index: rejoin re-derives reciprocal wiring
    /// through it (the same CSR lookup construction used).
    spec_index: SpecIndex,
}

impl<W: ShardWorkload> Engine<W> {
    /// Build an engine over pre-constructed shards (one per process).
    /// `profiles` has one entry per node (see [`Topology::n_nodes`]).
    pub fn new(
        cfg: SimConfig,
        topo: Topology,
        profiles: Vec<NodeProfile>,
        shards: Vec<W>,
    ) -> Self {
        assert_eq!(shards.len(), topo.n_procs());
        assert_eq!(profiles.len(), topo.n_nodes(), "one profile per node");
        cfg.scenario.validate_procs(topo.n_procs());
        let mut seed_rng = Xoshiro256::new(cfg.seed);

        // Processes named by churn events: the only ones membership
        // reconciliation ever inspects after a fault transition.
        let churn_procs = churn_procs_of(&cfg.scenario);

        // Gather channel specs per process.
        let specs: Vec<Vec<ChannelSpec>> = shards.iter().map(|s| s.channels()).collect();
        let total_specs: usize = specs.iter().map(|s| s.len()).sum();

        // Flat sorted spec index replacing the former per-process
        // HashMaps — see [`SpecIndex`] (shared with the real-thread
        // executor's wiring): `partition_point` lower-bound lookup with
        // the same first-match semantics as the `or_insert` build it
        // replaces, no per-process allocations, no hashing, which at
        // 1024–4096 procs made construction the dominant cost of
        // short-run sweep cells.
        let spec_index = SpecIndex::build(&specs);

        // Create directed channels and index them, sized in one pass:
        // the channel count is exactly the spec count, and each source's
        // outgoing list is exactly its spec list's length.
        let mut channels: Vec<SimChannel<W::Msg>> = Vec::with_capacity(total_specs);
        let mut outgoing: Vec<Vec<usize>> = specs
            .iter()
            .map(|specs_p| Vec::with_capacity(specs_p.len()))
            .collect();
        for (src, specs_p) in specs.iter().enumerate() {
            for (src_ch, spec) in specs_p.iter().enumerate() {
                // Find the reciprocal channel index on the destination.
                let dst_ch = spec_index
                    .lookup(spec.peer, src, reciprocal_layer(spec.layer))
                    .unwrap_or_else(|| {
                        panic!(
                            "no reciprocal channel: src={src} spec={spec:?}"
                        )
                    });
                let mut link = link_for(&cfg, &topo, src, spec.peer);
                let service_unscaled_ns = link.service_ns;
                let pf_src = profiles[topo.node_of(src)];
                let pf_dst = profiles[topo.node_of(spec.peer)];
                // A degraded endpoint slows the send-buffer drain too: MPI
                // progress (and hence request completion) is tied to the
                // peer actually keeping up, so occupancy-driven drops
                // emerge once `service x buffer` lags the send rate.
                let health = pf_src.latency_factor.max(pf_dst.latency_factor);
                link.service_ns *= health;
                channels.push(SimChannel {
                    src,
                    dst: spec.peer,
                    src_ch,
                    dst_ch,
                    layer: spec.layer,
                    src_node: topo.node_of(src),
                    dst_node: topo.node_of(spec.peer),
                    crossnode: !topo.same_node(src, spec.peer),
                    link,
                    service_unscaled_ns,
                    latency_factor: pf_src.latency_factor.max(pf_dst.latency_factor),
                    extra_drop: (pf_src.extra_drop_prob + pf_dst.extra_drop_prob).min(1.0),
                    last_depart: 0,
                    last_arrival: 0,
                    lanes: EnvelopeLanes::new(),
                    pushed: 0,
                    pulled: 0,
                    departed: 0,
                    stats: LocalChannelStats::new(),
                });
                outgoing[src].push(channels.len() - 1);
            }
        }

        // Incoming lists, sized by a degree-count pass before filling.
        let mut in_degree = vec![0usize; shards.len()];
        for ch in &channels {
            in_degree[ch.dst] += 1;
        }
        let mut incoming: Vec<Vec<(usize, usize)>> = in_degree
            .iter()
            .map(|&d| Vec::with_capacity(d))
            .collect();
        for (cid, ch) in channels.iter().enumerate() {
            incoming[ch.dst].push((cid, ch.dst_ch));
        }

        let n = shards.len();
        let procs: Vec<ProcState<W>> = shards
            .into_iter()
            .enumerate()
            .map(|(p, workload)| {
                let mut rng = seed_rng.split(p as u64);
                let skew = if cfg.timing.fixed_skew_max > 0 {
                    rng.below(cfg.timing.fixed_skew_max) as Nanos
                } else {
                    0
                };
                let n_out = outgoing[p].len();
                let my_outgoing = std::mem::take(&mut outgoing[p]);
                let my_incoming = std::mem::take(&mut incoming[p]);
                // Sorted `(dst, src_ch, oi)` index for the reciprocal
                // lookup: lower-bound on the unique (dst, src_ch) key
                // (ascending `oi` on the impossible duplicate keeps the
                // first-match semantics of the HashMap `or_insert` and
                // the scan before it).
                let mut out_index: Vec<(usize, usize, usize)> = my_outgoing
                    .iter()
                    .enumerate()
                    .map(|(oi, &oc)| (channels[oc].dst, channels[oc].src_ch, oi))
                    .collect();
                out_index.sort_unstable();
                let reciprocal_out = my_incoming
                    .iter()
                    .map(|&(cid, _)| {
                        let key = (channels[cid].src, channels[cid].dst_ch);
                        let at =
                            out_index.partition_point(|&(d, c, _)| (d, c) < key);
                        match out_index.get(at) {
                            Some(&(d, c, oi)) if (d, c) == key => Some(oi),
                            _ => None,
                        }
                    })
                    .collect();
                ProcState {
                    workload,
                    rng,
                    clock: 0,
                    updates: 0,
                    outgoing: my_outgoing,
                    incoming: my_incoming,
                    reciprocal_out,
                    touch: vec![TouchCounter::default(); n_out],
                    chunk_start: 0,
                    next_fixed_sync: skew + cfg.timing.fixed_epoch,
                    finished: false,
                }
            })
            .collect();

        let mut sched = cfg.sched.make::<Ev>();
        let mut seq = 0u64;

        // Compile the fault scenario: one initial wake per event (the
        // overlay chains follow-up wakes — window ends, flap toggles —
        // through `Ev::Fault` reschedules). Fault wakes are pushed
        // *before* process wakes so an onset at t=0 — e.g. the always-on
        // lac-417 scenario — is in force for the very first simstep,
        // matching the static-profile path's semantics. Empty scenarios
        // compile to nothing at all, keeping the wake/seq stream
        // bit-identical to pre-scenario engines.
        let faults = if cfg.scenario.is_empty() {
            None
        } else {
            let rt = FaultRuntime::new(cfg.scenario.clone(), profiles.clone());
            for (k, ev) in rt.scenario().events.iter().enumerate() {
                sched.push(ev.start, seq, Ev::Fault(k));
                seq += 1;
            }
            Some(rt)
        };

        // Initial wakes: one batch at t=0 — the same same-timestamp
        // burst shape as a barrier release, with the same seq stream as
        // the loop it replaces. The drained vector is kept as the
        // engine's reusable release scratch.
        let mut wake_batch: Vec<Ev> = (0..n).map(Ev::Wake).collect();
        sched.push_batch_same_t(0, seq, &mut wake_batch);
        seq += n as u64;
        if let Some(s) = cfg.snapshots {
            for i in 0..s.count {
                sched.push(s.open_at(i), seq, Ev::SnapOpen(i));
                seq += 1;
                sched.push(s.close_at(i), seq, Ev::SnapClose(i));
                seq += 1;
            }
        }

        let engine_rng = Xoshiro256::new(cfg.seed ^ 0xBA44_1E44);
        Self {
            cfg,
            topo,
            profiles,
            procs,
            channels,
            sched,
            seq,
            barrier_waiting: vec![false; n],
            barrier_count: 0,
            barrier_max_arrival: 0,
            snap_open: Vec::new(),
            windows: Vec::new(),
            faults,
            window_phase: ScenarioPhase::QUIESCENT,
            engine_rng,
            pull_scratch: Vec::new(),
            wake_batch,
            live: vec![true; n],
            live_count: n,
            purged: 0,
            // Every process has its t=0 wake in the scheduler.
            wake_armed: vec![true; n],
            churn_procs,
            spec_index,
        }
    }

    fn schedule(&mut self, t: Nanos, ev: Ev) {
        self.sched.push(t, self.seq, ev);
        self.seq += 1;
    }

    /// Run to completion and return results.
    pub fn run(mut self) -> SimResult<W> {
        self.run_until(Nanos::MAX);
        self.finish()
    }

    /// Advance the event loop until the next event would fire at or after
    /// `until` (that event stays queued, untouched) or the run ends.
    /// Returns `true` when the run is over — the queue drained or the
    /// next event lay beyond `run_for` (dropped, exactly as [`Self::run`]
    /// drops the boundary event). Checkpoints are taken at the quiescent
    /// point this leaves the engine in: strictly between events.
    pub fn run_until(&mut self, until: Nanos) -> bool {
        while let Some((t, sq, ev)) = self.sched.pop() {
            if t > self.cfg.run_for {
                return true;
            }
            if t >= until {
                // Re-queue with its original key: the (t, seq) stream —
                // and hence the simulation — is unchanged by the pause.
                self.sched.push(t, sq, ev);
                return false;
            }
            match ev {
                Ev::Wake(p) => {
                    self.wake_armed[p] = false;
                    self.step_process(p, t);
                }
                Ev::SnapOpen(_) => self.snapshot_open(t),
                Ev::SnapClose(_) => self.snapshot_close(t),
                Ev::Fault(k) => self.fault_event(k, t),
            }
        }
        true
    }

    /// Consume the engine and assemble the replicate result.
    pub fn finish(self) -> SimResult<W> {
        let qos = ReplicateQos::from_windows(&self.windows);
        let mut totals = CounterTranche::default();
        let mut in_flight = 0u64;
        for ch in &self.channels {
            totals.add(&ch.stats.tranche());
            in_flight += ch.lanes.len() as u64;
        }
        SimResult {
            updates: self.procs.iter().map(|p| p.updates).collect(),
            shards: self.procs.into_iter().map(|p| p.workload).collect(),
            run_for: self.cfg.run_for,
            qos,
            windows: self.windows,
            attempted_sends: totals.attempted_sends,
            successful_sends: totals.successful_sends,
            messages_delivered: totals.messages_received,
            messages_purged: self.purged,
            messages_in_flight: in_flight,
        }
    }

    /// Execute one full simstep for process `p`, waking at time `t`.
    fn step_process(&mut self, p: usize, t: Nanos) {
        if self.procs[p].finished {
            return;
        }
        // A departed process does nothing — its wake lapses (disarmed by
        // the pop) and rejoin re-arms one.
        if !self.live[p] {
            return;
        }
        let mut now = t;

        // ---- Pull phase: drain every arrived message, oldest first. ----
        if self.cfg.mode.communicates() {
            // Index-based iteration: `incoming` is construction-time
            // immutable, and cloning it per simstep was the #1 allocation
            // in the DES hot loop (see EXPERIMENTS.md SPerf). Arrived
            // payloads land in the engine-owned scratch buffer — absorb
            // drains it, so one allocation serves the whole run.
            let mut msgs = std::mem::take(&mut self.pull_scratch);
            for k in 0..self.procs[p].incoming.len() {
                let (cid, local_ch) = self.procs[p].incoming[k];
                msgs.clear();
                let summary = {
                    let ch = &mut self.channels[cid];
                    // Batched SoA drain: one arrival-lane prefix scan,
                    // then lane splices into the engine scratch buffer.
                    let summary = ch.lanes.drain_arrived_into(now, &mut msgs);
                    ch.pulled += summary.drained;
                    ch.stats.on_pull(summary.drained);
                    now += ch.link.pull_overhead_ns as Nanos;
                    summary
                };
                if let Some(bundled) = summary.max_touch {
                    // Update p's touch counter for this peer via the
                    // precomputed reciprocal-channel index.
                    if let Some(oi) = self.procs[p].reciprocal_out[k] {
                        self.procs[p].touch[oi].on_receive(bundled);
                        let v = self.procs[p].touch[oi].value();
                        self.channels[self.procs[p].outgoing[oi]]
                            .stats
                            .set_touches(v);
                    }
                }
                if !msgs.is_empty() {
                    self.procs[p].workload.absorb(local_ch, &mut msgs);
                }
            }
            self.pull_scratch = msgs;
        }

        // ---- Compute phase. ----
        let node = self.topo.node_of(p);
        // The fault overlay's effective profile when a scenario is
        // loaded; the static table otherwise (bit-identical paths when
        // nothing is active — the overlay caches equal the statics).
        let profile = match &self.faults {
            Some(rt) => *rt.node_profile(node),
            None => self.profiles[node],
        };
        let co_resident = self.topo.procs_on_node_of(p);
        let mut nominal = self.procs[p].workload.step_cost_ns()
            + self.cfg.added_work_units as f64 * crate::workloads::workunit::WORK_UNIT_WALL_NS;
        // Membership churn re-partitions the global workload over the
        // live set: with fewer participants each survivor owns a larger
        // share, so per-update cost scales up proportionally. Strict
        // inequality keeps churn-free runs on the untouched path,
        // bit-identically.
        if self.live_count < self.procs.len() {
            nominal *= self.procs.len() as f64 / self.live_count as f64;
        }
        let contention = self.cfg.contention.factor(co_resident);
        let dur = {
            let rng = &mut self.procs[p].rng;
            profile.sample_compute(nominal, contention, co_resident, self.cfg.cores_per_node, rng)
        };
        now += dur;

        let outputs = {
            let proc = &mut self.procs[p];
            proc.workload.step(&mut proc.rng)
        };

        // ---- Send phase. ----
        if self.cfg.mode.communicates() {
            for (local_ch, payload) in outputs {
                let cid = self.procs[p].outgoing[local_ch];
                let touch = self.procs[p].touch[local_ch].outgoing();
                let outcome = {
                    let ch = &mut self.channels[cid];
                    now += ch.link.send_overhead_ns as Nanos;
                    if !self.live[ch.dst] {
                        // Departed receiver: the channel stops accepting
                        // sends. Best-effort modes count these as
                        // delivery failures like any other drop; sync
                        // modes never deadlock on them because barriers
                        // exclude departed participants.
                        ch.stats.on_send_attempt(false);
                        continue;
                    }
                    // Effective link parameters: the static bake, or the
                    // fault overlay's current view when a scenario is
                    // loaded (degraded endpoints slow the send-buffer
                    // drain exactly like the static path's health
                    // scaling, so occupancy-driven drops emerge mid-run
                    // when a node degrades).
                    let (latency_factor, extra_drop, service_ns) = match &self.faults {
                        None => (ch.latency_factor, ch.extra_drop, ch.link.service_ns),
                        Some(rt) => {
                            let ps = rt.node_profile(ch.src_node);
                            let pd = rt.node_profile(ch.dst_node);
                            let health = ps.latency_factor.max(pd.latency_factor);
                            let mods = rt.link_mods(ch.src_node, ch.dst_node, ch.crossnode);
                            (
                                health * mods.latency_factor,
                                (ps.extra_drop_prob + pd.extra_drop_prob).min(1.0)
                                    + mods.extra_drop_prob,
                                ch.service_unscaled_ns * health,
                            )
                        }
                    };
                    let full = ch.occupancy(now) >= self.cfg.send_buffer;
                    let dropped = full
                        || self.procs[p]
                            .rng
                            .chance(ch.link.base_drop_prob + extra_drop);
                    if dropped {
                        SendOutcome::Dropped
                    } else {
                        let depart = now.max(ch.last_depart + service_ns as Nanos);
                        let latency = (ch.link.sample_latency(&mut self.procs[p].rng) as f64
                            * latency_factor) as Nanos;
                        let arrival = ch.link.coalesce(depart + latency).max(ch.last_arrival);
                        ch.last_depart = depart;
                        ch.last_arrival = arrival;
                        ch.lanes.push(depart, arrival, touch, payload);
                        ch.pushed += 1;
                        SendOutcome::Accepted
                    }
                };
                self.channels[cid]
                    .stats
                    .on_send_attempt(outcome.delivered_to_channel());
            }
        }

        self.procs[p].updates += 1;
        self.procs[p].clock = now;

        // ---- Barrier / reschedule. ----
        let enter_barrier = match self.cfg.mode {
            AsyncMode::Sync => true,
            AsyncMode::RollingBarrier => {
                now.saturating_sub(self.procs[p].chunk_start) >= self.cfg.timing.rolling_chunk
            }
            AsyncMode::FixedBarrier => now >= self.procs[p].next_fixed_sync,
            AsyncMode::BestEffort | AsyncMode::NoComm => false,
        };

        if enter_barrier {
            self.arrive_barrier(p, now);
        } else {
            self.wake_armed[p] = true;
            self.schedule(now, Ev::Wake(p));
        }
    }

    fn arrive_barrier(&mut self, p: usize, t: Nanos) {
        debug_assert!(!self.barrier_waiting[p]);
        self.barrier_waiting[p] = true;
        self.barrier_count += 1;
        self.barrier_max_arrival = self.barrier_max_arrival.max(t);
        self.maybe_release_barrier(t);
    }

    /// Release the barrier when every *live* participant has arrived.
    /// Called on each arrival and on each departure — a process leaving
    /// mid-epoch can be the event that completes the barrier, so sync
    /// modes never deadlock on departed participants.
    fn maybe_release_barrier(&mut self, t: Nanos) {
        if self.barrier_count == 0 || self.barrier_count != self.live_count {
            return;
        }
        // Release everyone waiting: N wakes at one timestamp with
        // consecutive seqs — handed to the scheduler as a single
        // batch (same seq stream as the former push loop, so the
        // event order is bit-identical; the batched-vs-looped
        // equivalence is pinned by `tests/prop_calendar.rs` and the
        // 1024-proc barrier-storm signature test). `max(t)` matters only
        // on departure-triggered releases, where the departure time can
        // exceed every recorded arrival.
        let release = self.barrier_max_arrival.max(t)
            + self.cfg.barrier_cost(self.live_count, &mut self.engine_rng);
        self.barrier_count = 0;
        self.barrier_max_arrival = 0;
        let mut batch = std::mem::take(&mut self.wake_batch);
        debug_assert!(batch.is_empty());
        for q in 0..self.procs.len() {
            if !self.barrier_waiting[q] {
                continue;
            }
            self.barrier_waiting[q] = false;
            self.wake_armed[q] = true;
            let proc = &mut self.procs[q];
            proc.clock = release;
            proc.chunk_start = release;
            // Advance the fixed sync point past the release.
            while proc.next_fixed_sync <= release {
                proc.next_fixed_sync += self.cfg.timing.fixed_epoch;
            }
            batch.push(Ev::Wake(q));
        }
        let n = batch.len() as u64;
        self.sched.push_batch_same_t(release, self.seq, &mut batch);
        self.seq += n;
        self.wake_batch = batch;
    }

    fn snapshot_open(&mut self, t: Nanos) {
        // Start accumulating the window's fault-phase tag from the
        // instantaneous phase; `fault_event` folds in any transition that
        // fires while the window is open.
        self.window_phase = self
            .faults
            .as_ref()
            .map(|rt| rt.phase())
            .unwrap_or(ScenarioPhase::QUIESCENT);
        let phase = self.window_phase;
        self.snap_open = self
            .channels
            .iter()
            .map(|ch| {
                let counters = ch.stats.tranche();
                (
                    QosObservation::capture_phased(counters, self.procs[ch.src].updates, t, phase),
                    QosObservation::capture_phased(counters, self.procs[ch.dst].updates, t, phase),
                )
            })
            .collect();
    }

    fn snapshot_close(&mut self, t: Nanos) {
        if self.snap_open.is_empty() {
            return;
        }
        // Closing observations carry the union of everything active at
        // any point during the window, so `SnapshotWindow::phase()` (the
        // union over all four observations) attributes the window to
        // every fault that overlapped it.
        let phase = match &self.faults {
            Some(rt) => self.window_phase.union(rt.phase()),
            None => ScenarioPhase::QUIESCENT,
        };
        for (cid, ch) in self.channels.iter().enumerate() {
            let counters = ch.stats.tranche();
            let (inlet_before, outlet_before) = self.snap_open[cid];
            self.windows.push(SnapshotWindow {
                inlet_before,
                inlet_after: QosObservation::capture_phased(
                    counters,
                    self.procs[ch.src].updates,
                    t,
                    phase,
                ),
                outlet_before,
                outlet_after: QosObservation::capture_phased(
                    counters,
                    self.procs[ch.dst].updates,
                    t,
                    phase,
                ),
            });
        }
        self.snap_open.clear();
    }

    /// Advance scenario event `k`'s overlay state machine and schedule
    /// its next transition, folding the phase change into any open
    /// snapshot window.
    fn fault_event(&mut self, k: usize, t: Nanos) {
        let window_open = !self.snap_open.is_empty();
        let Some(rt) = self.faults.as_mut() else {
            return;
        };
        let pre = rt.phase();
        let next = rt.on_event(k, t);
        let post = rt.phase();
        if window_open {
            self.window_phase = self.window_phase.union(pre).union(post);
        }
        if let Some(tn) = next {
            self.schedule(tn, Ev::Fault(k));
        }
        self.reconcile_membership(t);
    }

    /// Sync the engine's live set with the overlay's view of departed
    /// processes after a fault transition. No-op (and not even a scan)
    /// for churn-free scenarios.
    fn reconcile_membership(&mut self, t: Nanos) {
        for i in 0..self.churn_procs.len() {
            let p = self.churn_procs[i];
            let departed = self
                .faults
                .as_ref()
                .is_some_and(|rt| rt.is_departed(p));
            if departed && self.live[p] {
                self.leave_proc(p, t);
            } else if !departed && !self.live[p] {
                self.join_proc(p, t);
            }
        }
    }

    /// Process `p` departs the allocation at time `t`: its channels stop
    /// accepting sends (see the send phase), queued messages addressed to
    /// it are purged, and barrier protocols exclude it — releasing any
    /// barrier its departure completes.
    fn leave_proc(&mut self, p: usize, t: Nanos) {
        self.live[p] = false;
        self.live_count -= 1;
        if self.barrier_waiting[p] {
            self.barrier_waiting[p] = false;
            self.barrier_count -= 1;
        }
        // Purge everything queued toward the departed process. The purge
        // is deliberately NOT a pull (no `on_pull` stats): the messages
        // were never received — `SimResult::messages_purged` accounts
        // for them so conservation stays checkable.
        let mut scratch = std::mem::take(&mut self.pull_scratch);
        for k in 0..self.procs[p].incoming.len() {
            let (cid, _) = self.procs[p].incoming[k];
            let ch = &mut self.channels[cid];
            scratch.clear();
            let summary = ch.lanes.drain_arrived_into(Nanos::MAX, &mut scratch);
            ch.pulled += summary.drained;
            self.purged += summary.drained;
        }
        scratch.clear();
        self.pull_scratch = scratch;
        self.maybe_release_barrier(t);
    }

    /// Process `p` rejoins the allocation at time `t`: clocks and sync
    /// points move to the join instant, reciprocal wiring is re-derived
    /// from the [`SpecIndex`], touch counters restart from zero (the
    /// crash lost their state), and a wake is armed if none is pending.
    fn join_proc(&mut self, p: usize, t: Nanos) {
        self.live[p] = true;
        self.live_count += 1;
        let proc = &mut self.procs[p];
        proc.clock = t;
        proc.chunk_start = t;
        while proc.next_fixed_sync <= t {
            proc.next_fixed_sync += self.cfg.timing.fixed_epoch;
        }
        self.rewire_proc(p);
        if !self.wake_armed[p] {
            self.wake_armed[p] = true;
            self.schedule(t, Ev::Wake(p));
        }
    }

    /// Re-derive `p`'s reciprocal-channel wiring through the CSR spec
    /// index (the construction-time lookup, re-run), and reset its touch
    /// counters — a rejoining process starts its QoS relationships fresh.
    fn rewire_proc(&mut self, p: usize) {
        for k in 0..self.procs[p].incoming.len() {
            let (cid, _) = self.procs[p].incoming[k];
            let src = self.channels[cid].src;
            let layer = self.channels[cid].layer;
            self.procs[p].reciprocal_out[k] =
                self.spec_index.lookup(p, src, reciprocal_layer(layer));
        }
        for tc in &mut self.procs[p].touch {
            *tc = TouchCounter::default();
        }
    }
}

use crate::workloads::reciprocal_layer;

/// Processes named by any churn event of `scenario`, sorted + deduped —
/// shared by construction and restore so both agree on the churn set.
fn churn_procs_of(scenario: &FaultScenario) -> Vec<usize> {
    let mut churn_procs: Vec<usize> = scenario
        .events
        .iter()
        .filter_map(|ev| match ev.kind {
            FaultKind::ProcLeave { proc } | FaultKind::ProcJoin { proc } => Some(proc),
            _ => None,
        })
        .collect();
    churn_procs.sort_unstable();
    churn_procs.dedup();
    churn_procs
}

// ---- checkpoint encodings of engine-local types --------------------

impl Persist for Ev {
    fn save(&self, w: &mut SnapWriter) {
        match *self {
            Ev::SnapOpen(i) => {
                w.put_u8(0);
                i.save(w);
            }
            Ev::SnapClose(i) => {
                w.put_u8(1);
                i.save(w);
            }
            Ev::Wake(p) => {
                w.put_u8(2);
                p.save(w);
            }
            Ev::Fault(k) => {
                w.put_u8(3);
                k.save(w);
            }
        }
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        let tag = r.get_u8()?;
        let v = usize::load(r)?;
        Ok(match tag {
            0 => Ev::SnapOpen(v),
            1 => Ev::SnapClose(v),
            2 => Ev::Wake(v),
            3 => Ev::Fault(v),
            _ => return Err(SnapError::Corrupt("Ev tag")),
        })
    }
}

impl Persist for CommBackend {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u8(match self {
            CommBackend::Mpi => 0,
            CommBackend::SharedMemory => 1,
        });
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        match r.get_u8()? {
            0 => Ok(CommBackend::Mpi),
            1 => Ok(CommBackend::SharedMemory),
            _ => Err(SnapError::Corrupt("CommBackend tag")),
        }
    }
}

impl Persist for ContentionModel {
    fn save(&self, w: &mut SnapWriter) {
        self.a.save(w);
        self.b.save(w);
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            a: f64::load(r)?,
            b: f64::load(r)?,
        })
    }
}

impl Persist for SimConfig {
    fn save(&self, w: &mut SnapWriter) {
        self.mode.save(w);
        self.timing.save(w);
        self.backend.save(w);
        self.seed.save(w);
        self.run_for.save(w);
        self.added_work_units.save(w);
        self.send_buffer.save(w);
        self.cores_per_node.save(w);
        self.contention.save(w);
        self.barrier_base_ns.save(w);
        self.barrier_per_log2_ns.save(w);
        self.barrier_tail_ns.save(w);
        self.snapshots.save(w);
        self.coalesce_override.save(w);
        self.sched.save(w);
        self.scenario.save(w);
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            mode: AsyncMode::load(r)?,
            timing: ModeTiming::load(r)?,
            backend: CommBackend::load(r)?,
            seed: u64::load(r)?,
            run_for: u64::load(r)?,
            added_work_units: u64::load(r)?,
            send_buffer: usize::load(r)?,
            cores_per_node: usize::load(r)?,
            contention: ContentionModel::load(r)?,
            barrier_base_ns: f64::load(r)?,
            barrier_per_log2_ns: f64::load(r)?,
            barrier_tail_ns: f64::load(r)?,
            snapshots: Option::<SnapshotSchedule>::load(r)?,
            coalesce_override: Option::<Nanos>::load(r)?,
            sched: SchedKind::load(r)?,
            scenario: FaultScenario::load(r)?,
        })
    }
}

// ---- engine checkpoint / restore -----------------------------------

impl<W> Engine<W>
where
    W: ShardWorkload + Persist,
    W::Msg: Persist,
{
    /// Serialize the complete engine state to a versioned binary blob.
    ///
    /// Must be called strictly between events — i.e. after
    /// [`Self::run_until`] paused the loop (or before the first event).
    /// Takes `&mut self` because the scheduler's contents can only be
    /// observed by draining: every entry is popped, recorded, and pushed
    /// back with its original `(t, seq)` key. Dequeue order depends only
    /// on those keys, so the drain round-trip leaves the simulation
    /// bit-identical — and two consecutive checkpoints are byte-equal.
    pub fn checkpoint(&mut self) -> Vec<u8> {
        let mut w = SnapWriter::new();
        self.cfg.save(&mut w);
        self.topo.n_procs().save(&mut w);
        self.topo.placement().save(&mut w);
        self.profiles.save(&mut w);

        self.procs.len().save(&mut w);
        for p in &self.procs {
            p.workload.save(&mut w);
            p.rng.state().save(&mut w);
            p.clock.save(&mut w);
            p.updates.save(&mut w);
            p.outgoing.save(&mut w);
            p.incoming.save(&mut w);
            p.reciprocal_out.save(&mut w);
            let touch: Vec<u64> = p.touch.iter().map(|t| t.value()).collect();
            touch.save(&mut w);
            p.chunk_start.save(&mut w);
            p.next_fixed_sync.save(&mut w);
            p.finished.save(&mut w);
        }

        self.channels.len().save(&mut w);
        for ch in &self.channels {
            ch.src.save(&mut w);
            ch.dst.save(&mut w);
            ch.src_ch.save(&mut w);
            ch.dst_ch.save(&mut w);
            ch.layer.save(&mut w);
            ch.src_node.save(&mut w);
            ch.dst_node.save(&mut w);
            ch.crossnode.save(&mut w);
            ch.link.save(&mut w);
            ch.service_unscaled_ns.save(&mut w);
            ch.latency_factor.save(&mut w);
            ch.extra_drop.save(&mut w);
            ch.last_depart.save(&mut w);
            ch.last_arrival.save(&mut w);
            ch.lanes.len().save(&mut w);
            for (depart, arrival, touch, msg) in ch.lanes.iter() {
                depart.save(&mut w);
                arrival.save(&mut w);
                touch.save(&mut w);
                msg.save(&mut w);
            }
            ch.pushed.save(&mut w);
            ch.pulled.save(&mut w);
            ch.departed.save(&mut w);
            ch.stats.tranche().save(&mut w);
        }

        // Scheduler: drain-and-restore. Entries come out in dequeue
        // order, which is a pure function of the (t, seq) keys — pushing
        // them straight back reproduces the identical stream.
        let mut entries: Vec<(Nanos, u64, Ev)> = Vec::with_capacity(self.sched.len());
        while let Some(e) = self.sched.pop() {
            entries.push(e);
        }
        entries.save(&mut w);
        for &(t, sq, ev) in &entries {
            self.sched.push(t, sq, ev);
        }

        self.seq.save(&mut w);
        self.barrier_waiting.save(&mut w);
        self.barrier_count.save(&mut w);
        self.barrier_max_arrival.save(&mut w);
        self.snap_open.save(&mut w);
        self.windows.save(&mut w);
        let overlay: Option<Vec<u8>> = self.faults.as_ref().map(|rt| rt.export_states());
        overlay.save(&mut w);
        self.window_phase.save(&mut w);
        self.engine_rng.state().save(&mut w);
        self.live.save(&mut w);
        self.live_count.save(&mut w);
        self.purged.save(&mut w);
        self.wake_armed.save(&mut w);
        w.finish()
    }

    /// Rebuild an engine from a [`Self::checkpoint`] blob. Resuming the
    /// restored engine is bit-identical to never having paused.
    pub fn restore(bytes: &[u8]) -> Result<Self, SnapError> {
        Self::restore_impl(bytes, None)
    }

    /// Restore, but back the wake queue with scheduler `kind` regardless
    /// of what the checkpointed config says. Both kinds dequeue the
    /// same (t, seq) stream, so cross-kind restores stay bit-identical —
    /// pinned by `tests/integration_checkpoint.rs`.
    pub fn restore_with_sched(bytes: &[u8], kind: SchedKind) -> Result<Self, SnapError> {
        Self::restore_impl(bytes, Some(kind))
    }

    fn restore_impl(
        bytes: &[u8],
        sched_override: Option<SchedKind>,
    ) -> Result<Self, SnapError> {
        let mut r = SnapReader::new(bytes)?;
        let mut cfg = SimConfig::load(&mut r)?;
        let n_procs = usize::load(&mut r)?;
        let placement = PlacementKind::load(&mut r)?;
        let topo = Topology::new(n_procs, placement);
        let profiles = Vec::<NodeProfile>::load(&mut r)?;
        if profiles.len() != topo.n_nodes() {
            return Err(SnapError::Corrupt("profile count"));
        }

        let n = usize::load(&mut r)?;
        if n != n_procs {
            return Err(SnapError::Corrupt("proc count"));
        }
        let mut procs: Vec<ProcState<W>> = Vec::with_capacity(n);
        for _ in 0..n {
            let workload = W::load(&mut r)?;
            let rng = Xoshiro256::from_state(<[u64; 4]>::load(&mut r)?);
            let clock = Nanos::load(&mut r)?;
            let updates = u64::load(&mut r)?;
            let outgoing = Vec::<usize>::load(&mut r)?;
            let incoming = Vec::<(usize, usize)>::load(&mut r)?;
            let reciprocal_out = Vec::<Option<usize>>::load(&mut r)?;
            let touch_vals = Vec::<u64>::load(&mut r)?;
            if touch_vals.len() != outgoing.len() {
                return Err(SnapError::Corrupt("touch counter count"));
            }
            let touch = touch_vals.into_iter().map(TouchCounter::from_value).collect();
            let chunk_start = Nanos::load(&mut r)?;
            let next_fixed_sync = Nanos::load(&mut r)?;
            let finished = bool::load(&mut r)?;
            procs.push(ProcState {
                workload,
                rng,
                clock,
                updates,
                outgoing,
                incoming,
                reciprocal_out,
                touch,
                chunk_start,
                next_fixed_sync,
                finished,
            });
        }

        let n_ch = usize::load(&mut r)?;
        let mut channels: Vec<SimChannel<W::Msg>> = Vec::with_capacity(n_ch);
        for _ in 0..n_ch {
            let src = usize::load(&mut r)?;
            let dst = usize::load(&mut r)?;
            let src_ch = usize::load(&mut r)?;
            let dst_ch = usize::load(&mut r)?;
            let layer = usize::load(&mut r)?;
            let src_node = usize::load(&mut r)?;
            let dst_node = usize::load(&mut r)?;
            let crossnode = bool::load(&mut r)?;
            let link = LinkModel::load(&mut r)?;
            let service_unscaled_ns = f64::load(&mut r)?;
            let latency_factor = f64::load(&mut r)?;
            let extra_drop = f64::load(&mut r)?;
            let last_depart = Nanos::load(&mut r)?;
            let last_arrival = Nanos::load(&mut r)?;
            let n_lanes = usize::load(&mut r)?;
            let mut lanes = EnvelopeLanes::new();
            for _ in 0..n_lanes {
                let depart = Nanos::load(&mut r)?;
                let arrival = Nanos::load(&mut r)?;
                let touch = u64::load(&mut r)?;
                let msg = W::Msg::load(&mut r)?;
                lanes.push(depart, arrival, touch, msg);
            }
            let pushed = u64::load(&mut r)?;
            let pulled = u64::load(&mut r)?;
            let departed = u64::load(&mut r)?;
            let tranche = CounterTranche::load(&mut r)?;
            if src >= n || dst >= n {
                return Err(SnapError::Corrupt("channel endpoint"));
            }
            channels.push(SimChannel {
                src,
                dst,
                src_ch,
                dst_ch,
                layer,
                src_node,
                dst_node,
                crossnode,
                link,
                service_unscaled_ns,
                latency_factor,
                extra_drop,
                last_depart,
                last_arrival,
                lanes,
                pushed,
                pulled,
                departed,
                stats: LocalChannelStats::from_tranche(&tranche),
            });
        }

        let entries = Vec::<(Nanos, u64, Ev)>::load(&mut r)?;
        let seq = u64::load(&mut r)?;
        let barrier_waiting = Vec::<bool>::load(&mut r)?;
        let barrier_count = usize::load(&mut r)?;
        let barrier_max_arrival = Nanos::load(&mut r)?;
        let snap_open = Vec::<(QosObservation, QosObservation)>::load(&mut r)?;
        let windows = Vec::<SnapshotWindow>::load(&mut r)?;
        let overlay_states = Option::<Vec<u8>>::load(&mut r)?;
        let window_phase = ScenarioPhase::load(&mut r)?;
        let engine_rng = Xoshiro256::from_state(<[u64; 4]>::load(&mut r)?);
        let live = Vec::<bool>::load(&mut r)?;
        let live_count = usize::load(&mut r)?;
        let purged = u64::load(&mut r)?;
        let wake_armed = Vec::<bool>::load(&mut r)?;
        if !r.is_exhausted() {
            return Err(SnapError::Corrupt("trailing bytes"));
        }
        if live.len() != n
            || wake_armed.len() != n
            || barrier_waiting.len() != n
            || live.iter().filter(|&&l| l).count() != live_count
        {
            return Err(SnapError::Corrupt("membership vectors"));
        }

        if let Some(kind) = sched_override {
            cfg.sched = kind;
        }
        let mut sched = cfg.sched.make::<Ev>();
        for &(t, sq, ev) in &entries {
            sched.push(t, sq, ev);
        }

        // Overlay presence must match the config's scenario exactly, and
        // the exported per-event machine states must fit it.
        let faults = match (overlay_states, cfg.scenario.is_empty()) {
            (None, true) => None,
            (Some(states), false) => {
                let mut rt = FaultRuntime::new(cfg.scenario.clone(), profiles.clone());
                if !rt.restore_states(&states) {
                    return Err(SnapError::Corrupt("overlay states"));
                }
                Some(rt)
            }
            _ => return Err(SnapError::Corrupt("overlay/scenario mismatch")),
        };

        // Derived structures: rebuilt from restored state, exactly as
        // construction builds them from fresh state.
        let specs: Vec<Vec<ChannelSpec>> =
            procs.iter().map(|p| p.workload.channels()).collect();
        let spec_index = SpecIndex::build(&specs);
        let churn_procs = churn_procs_of(&cfg.scenario);

        Ok(Self {
            cfg,
            topo,
            profiles,
            procs,
            channels,
            sched,
            seq,
            barrier_waiting,
            barrier_count,
            barrier_max_arrival,
            snap_open,
            windows,
            faults,
            window_phase,
            engine_rng,
            pull_scratch: Vec::new(),
            wake_batch: Vec::new(),
            live,
            live_count,
            purged,
            wake_armed,
            churn_procs,
            spec_index,
        })
    }
}

fn link_for(cfg: &SimConfig, topo: &Topology, a: usize, b: usize) -> LinkModel {
    let mut link = match cfg.backend {
        CommBackend::SharedMemory => LinkModel::thread_shared_memory(),
        CommBackend::Mpi => {
            if topo.same_node(a, b) {
                LinkModel::intranode()
            } else {
                LinkModel::internode()
            }
        }
    };
    if let Some(c) = cfg.coalesce_override {
        link.coalesce_ns = c;
    }
    link
}

/// Convenience: build healthy profiles for every node of `topo`.
pub fn healthy_profiles(topo: &Topology) -> Vec<NodeProfile> {
    vec![NodeProfile::healthy(); topo.n_nodes()]
}

/// Heterogeneous healthy profiles: persistent per-node speed factors
/// drawn lognormal(0, `speed_sigma`) with raised per-update jitter.
///
/// The paper's testbed is "a cluster of hundreds of heterogeneous x86
/// nodes" (SII-F1); persistent node-speed spread plus per-update jitter is
/// what makes barrier-per-update synchronization collapse at scale — each
/// superstep waits for the most laggardly draw (the double-dutch effect of
/// SI). Benchmark experiments use these profiles; QoS experiments (which
/// compare same-allocation treatments) default to homogeneous ones.
pub fn heterogeneous_profiles(
    topo: &Topology,
    seed: u64,
    speed_sigma: f64,
) -> Vec<NodeProfile> {
    let mut rng = Xoshiro256::new(seed ^ 0x8E7E_0906);
    (0..topo.n_nodes())
        .map(|_| {
            let mut p = NodeProfile::healthy();
            p.speed_factor = rng.lognormal(0.0, speed_sigma);
            p.jitter_sigma = 0.35;
            p
        })
        .collect()
}

/// Convenience: healthy profiles with one faulty node at `faulty_node`.
pub fn profiles_with_faulty(topo: &Topology, faulty_node: usize) -> Vec<NodeProfile> {
    let mut v = healthy_profiles(topo);
    if faulty_node < v.len() {
        v[faulty_node] = NodeProfile::faulty_lac417();
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{MILLI, SECOND};
    use crate::workloads::{GcConfig, GraphColoringShard};

    fn gc_engine(
        n_procs: usize,
        simels: usize,
        mode: AsyncMode,
        run_for: Nanos,
        seed: u64,
    ) -> Engine<GraphColoringShard> {
        let topo = Topology::new(n_procs, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(seed);
        let cfg_gc = GcConfig {
            simels_per_proc: simels,
            ..GcConfig::default()
        };
        let shards: Vec<_> = (0..n_procs)
            .map(|r| GraphColoringShard::new(cfg_gc, &topo, r, &mut rng))
            .collect();
        let mut cfg = SimConfig::new(mode, ModeTiming::graph_coloring(n_procs), run_for);
        cfg.seed = seed;
        cfg.send_buffer = 64;
        let profiles = healthy_profiles(&topo);
        Engine::new(cfg, topo, profiles, shards)
    }

    /// The O(1) departed-prefix occupancy must agree with a reference
    /// O(queue) reverse scan on arbitrary interleavings of monotone
    /// pushes, prefix pulls, and monotone queries — including receivers
    /// that race ahead and pull envelopes before they "depart". Runs over
    /// the SoA lanes, with a shadow AoS departure list as the reference.
    #[test]
    fn occupancy_matches_reference_scan() {
        let mut ch = SimChannel::<u8> {
            src: 0,
            dst: 1,
            src_ch: 0,
            dst_ch: 0,
            layer: 0,
            src_node: 0,
            dst_node: 1,
            crossnode: true,
            link: LinkModel::intranode(),
            service_unscaled_ns: LinkModel::intranode().service_ns,
            latency_factor: 1.0,
            extra_drop: 0.0,
            last_depart: 0,
            last_arrival: 0,
            lanes: EnvelopeLanes::new(),
            pushed: 0,
            pulled: 0,
            departed: 0,
            stats: LocalChannelStats::new(),
        };
        // Shadow copy of the queued departure times, AoS-style.
        let mut shadow: std::collections::VecDeque<Nanos> = std::collections::VecDeque::new();
        let mut rng = Xoshiro256::new(0x0CC);
        let mut now: Nanos = 0;
        let mut last_depart: Nanos = 0;
        let mut checks = 0usize;
        let mut sink = Vec::new();
        for _ in 0..5_000 {
            now += rng.below(50);
            match rng.below(3) {
                0 => {
                    // Push: departures are monotone non-decreasing, and
                    // may land in the future relative to `now`.
                    let depart = now.max(last_depart) + rng.below(25);
                    last_depart = depart;
                    ch.lanes.push(depart, depart + 5, 0, 0);
                    shadow.push_back(depart);
                    ch.pushed += 1;
                }
                1 => {
                    // Receiver drains the arrived prefix, possibly ahead
                    // of the sender's clock.
                    let horizon = now + rng.below(60);
                    sink.clear();
                    let s = ch.lanes.drain_arrived_into(horizon, &mut sink);
                    for _ in 0..s.drained {
                        shadow.pop_front();
                    }
                    ch.pulled += s.drained;
                }
                _ => {
                    let reference =
                        shadow.iter().rev().take_while(|&&d| d > now).count();
                    assert_eq!(ch.occupancy(now), reference, "at t={now}");
                    checks += 1;
                }
            }
        }
        assert!(checks > 1_000, "degenerate schedule: {checks} checks");
    }

    #[test]
    fn best_effort_runs_and_counts_updates() {
        let result = gc_engine(4, 16, AsyncMode::BestEffort, 50 * MILLI, 1).run();
        assert_eq!(result.updates.len(), 4);
        for &u in &result.updates {
            assert!(u > 100, "updates={u}");
        }
        assert!(result.update_rate_per_cpu_hz() > 1000.0);
    }

    #[test]
    fn sync_mode_lockstep_updates() {
        let result = gc_engine(4, 16, AsyncMode::Sync, 50 * MILLI, 2).run();
        // Barrier every update: all procs complete the same update count
        // (+-1 for the cut at run end).
        let min = *result.updates.iter().min().unwrap();
        let max = *result.updates.iter().max().unwrap();
        assert!(max - min <= 1, "lockstep violated: {:?}", result.updates);
    }

    #[test]
    fn best_effort_faster_than_sync() {
        let sync = gc_engine(16, 1, AsyncMode::Sync, 100 * MILLI, 3).run();
        let be = gc_engine(16, 1, AsyncMode::BestEffort, 100 * MILLI, 3).run();
        assert!(
            be.update_rate_per_cpu_hz() > 1.5 * sync.update_rate_per_cpu_hz(),
            "best-effort {} vs sync {}",
            be.update_rate_per_cpu_hz(),
            sync.update_rate_per_cpu_hz()
        );
    }

    #[test]
    fn no_comm_mode_sends_nothing() {
        let result = gc_engine(4, 16, AsyncMode::NoComm, 20 * MILLI, 4).run();
        assert_eq!(result.attempted_sends, 0);
    }

    #[test]
    fn messages_flow_in_best_effort_mode() {
        let result = gc_engine(4, 16, AsyncMode::BestEffort, 50 * MILLI, 5).run();
        assert!(result.attempted_sends > 0);
        assert!(result.successful_sends > 0);
    }

    #[test]
    fn conflicts_converge_under_simulated_best_effort() {
        let result = gc_engine(4, 64, AsyncMode::BestEffort, SECOND, 6).run();
        let conflicts =
            crate::workloads::graph_coloring::global_conflicts(
                &Topology::new(4, PlacementKind::OnePerNode),
                &result.shards,
            );
        // 256 vertices: conflicts should be well below random (~2/3 * 256).
        assert!(conflicts < 40, "conflicts={conflicts}");
    }

    #[test]
    fn snapshots_produce_qos_windows() {
        let topo = Topology::new(2, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(7);
        let shards: Vec<_> = (0..2)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 1,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let mut cfg = SimConfig::new(
            AsyncMode::BestEffort,
            ModeTiming::graph_coloring(2),
            200 * MILLI,
        );
        cfg.send_buffer = 64;
        cfg.snapshots = Some(SnapshotSchedule::compressed(
            50 * MILLI,
            50 * MILLI,
            10 * MILLI,
            3,
        ));
        let result = Engine::new(cfg, topo, vec![NodeProfile::healthy(); 2], shards).run();
        // 2 procs x 2 channels each (1x2 mesh: E+W) x 3 windows = 12.
        assert_eq!(result.windows.len(), 12);
        for m in &result.qos.snapshots {
            assert!(m.simstep_period_ns > 0.0);
            assert!((0.0..=1.0).contains(&m.delivery_failure_rate));
            assert!((0.0..=1.0).contains(&m.delivery_clumpiness));
        }
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let a = gc_engine(4, 16, AsyncMode::BestEffort, 30 * MILLI, 42).run();
        let b = gc_engine(4, 16, AsyncMode::BestEffort, 30 * MILLI, 42).run();
        assert_eq!(a.updates, b.updates);
        assert_eq!(a.attempted_sends, b.attempted_sends);
        assert_eq!(a.successful_sends, b.successful_sends);
        let ca: Vec<u8> = a.shards.iter().flat_map(|s| s.colors().to_vec()).collect();
        let cb: Vec<u8> = b.shards.iter().flat_map(|s| s.colors().to_vec()).collect();
        assert_eq!(ca, cb);
    }

    #[test]
    fn different_seeds_differ() {
        let a = gc_engine(4, 16, AsyncMode::BestEffort, 30 * MILLI, 1).run();
        let b = gc_engine(4, 16, AsyncMode::BestEffort, 30 * MILLI, 2).run();
        assert_ne!(
            (a.updates.clone(), a.attempted_sends),
            (b.updates.clone(), b.attempted_sends)
        );
    }

    #[test]
    fn faulty_node_degrades_its_own_clique_only() {
        let topo = Topology::new(16, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(9);
        let mk_shards = |rng: &mut Xoshiro256| -> Vec<_> {
            (0..16)
                .map(|r| {
                    GraphColoringShard::new(
                        GcConfig {
                            simels_per_proc: 1,
                            ..GcConfig::default()
                        },
                        &topo,
                        r,
                        rng,
                    )
                })
                .collect()
        };
        let mut cfg = SimConfig::new(
            AsyncMode::BestEffort,
            ModeTiming::graph_coloring(16),
            300 * MILLI,
        );
        cfg.send_buffer = 64;
        let healthy = Engine::new(
            cfg.clone(),
            topo.clone(),
            healthy_profiles(&topo),
            mk_shards(&mut rng),
        )
        .run();
        let faulty = Engine::new(
            cfg,
            topo.clone(),
            profiles_with_faulty(&topo, 5),
            mk_shards(&mut rng),
        )
        .run();
        // Faulty node's own process does far fewer updates...
        assert!(
            (faulty.updates[5] as f64) < 0.7 * (healthy.updates[5] as f64),
            "faulty={} healthy={}",
            faulty.updates[5],
            healthy.updates[5]
        );
        // ...while the median process stays healthy.
        let mut h: Vec<u64> = healthy.updates.clone();
        let mut f: Vec<u64> = faulty.updates.clone();
        h.sort_unstable();
        f.sort_unstable();
        let (hm, fm) = (h[8] as f64, f[8] as f64);
        assert!(fm > 0.8 * hm, "median degraded: healthy={hm} faulty={fm}");
    }

    /// Loading a scenario routes every hot-path read through the fault
    /// overlay; with nothing active the overlay caches equal the static
    /// tables, so results must stay bit-identical — the overlay is free
    /// until a fault actually fires.
    #[test]
    fn never_active_scenario_is_bit_identical_to_static() {
        let run = |scenario: FaultScenario| {
            let topo = Topology::new(4, PlacementKind::OnePerNode);
            let mut rng = Xoshiro256::new(0xFA17);
            let shards: Vec<_> = (0..4)
                .map(|r| {
                    GraphColoringShard::new(
                        GcConfig {
                            simels_per_proc: 16,
                            ..GcConfig::default()
                        },
                        &topo,
                        r,
                        &mut rng,
                    )
                })
                .collect();
            let mut cfg = SimConfig::new(
                AsyncMode::BestEffort,
                ModeTiming::graph_coloring(4),
                30 * MILLI,
            );
            cfg.seed = 0xFA17;
            cfg.send_buffer = 4;
            cfg.scenario = scenario;
            Engine::new(cfg, topo.clone(), heterogeneous_profiles(&topo, 0xFA17, 0.20), shards)
                .run()
        };
        let a = run(FaultScenario::default());
        // Fires 10 s in — far beyond the 30 ms run window.
        let b = run(FaultScenario::midrun_failure(2, 10 * SECOND));
        assert_eq!(a.updates, b.updates);
        assert_eq!(a.attempted_sends, b.attempted_sends);
        assert_eq!(a.successful_sends, b.successful_sends);
        let ca: Vec<u8> = a.shards.iter().flat_map(|s| s.colors().to_vec()).collect();
        let cb: Vec<u8> = b.shards.iter().flat_map(|s| s.colors().to_vec()).collect();
        assert_eq!(ca, cb);
    }

    #[test]
    fn reciprocal_layer_roundtrip() {
        use crate::workloads::DE_LAYER_BASE;
        assert_eq!(reciprocal_layer(0), 2);
        // dir1,kind0 -> dir3,kind0
        assert_eq!(reciprocal_layer(DE_LAYER_BASE + 5), DE_LAYER_BASE + 15);
    }

    #[test]
    fn contention_model_calibration() {
        let gc = ContentionModel::graph_coloring_threads();
        assert!((gc.factor(4) - 2.56).abs() < 0.35, "{}", gc.factor(4));
        assert!((gc.factor(64) - 10.0).abs() < 2.0, "{}", gc.factor(64));
        assert_eq!(gc.factor(1), 1.0);
        let de = ContentionModel::digital_evolution_threads();
        assert!((de.factor(64) - 1.64).abs() < 0.25, "{}", de.factor(64));
        assert_eq!(ContentionModel::none().factor(64), 1.0);
    }

    // ---- membership churn ------------------------------------------

    use crate::faults::ALWAYS;

    fn churn_engine(
        n_procs: usize,
        mode: AsyncMode,
        run_for: Nanos,
        seed: u64,
        scenario: FaultScenario,
    ) -> Engine<GraphColoringShard> {
        let topo = Topology::new(n_procs, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(seed);
        let shards: Vec<_> = (0..n_procs)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 8,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let mut cfg = SimConfig::new(mode, ModeTiming::graph_coloring(n_procs), run_for);
        cfg.seed = seed;
        cfg.send_buffer = 8;
        cfg.scenario = scenario;
        let profiles = healthy_profiles(&topo);
        Engine::new(cfg, topo, profiles, shards)
    }

    #[test]
    fn departed_proc_stops_updating() {
        let scenario = FaultScenario::default().with(
            20 * MILLI,
            ALWAYS,
            FaultKind::ProcLeave { proc: 1 },
        );
        let churned = churn_engine(4, AsyncMode::BestEffort, 60 * MILLI, 11, scenario).run();
        let baseline =
            churn_engine(4, AsyncMode::BestEffort, 60 * MILLI, 11, FaultScenario::default())
                .run();
        // Proc 1 froze a third of the way in; peers kept running.
        assert!(
            (churned.updates[1] as f64) < 0.55 * (baseline.updates[1] as f64),
            "departed proc kept updating: {} vs baseline {}",
            churned.updates[1],
            baseline.updates[1]
        );
        assert!(churned.updates[0] > churned.updates[1]);
        assert!(churned.conserves_messages(), "conservation violated");
    }

    #[test]
    fn rejoining_proc_resumes_updates() {
        let windowed = FaultScenario::default().with(
            15 * MILLI,
            15 * MILLI,
            FaultKind::ProcLeave { proc: 1 },
        );
        let permanent = FaultScenario::default().with(
            15 * MILLI,
            ALWAYS,
            FaultKind::ProcLeave { proc: 1 },
        );
        let back = churn_engine(4, AsyncMode::BestEffort, 60 * MILLI, 12, windowed).run();
        let gone = churn_engine(4, AsyncMode::BestEffort, 60 * MILLI, 12, permanent).run();
        assert!(
            back.updates[1] > gone.updates[1] + 50,
            "rejoin did not resume: windowed={} permanent={}",
            back.updates[1],
            gone.updates[1]
        );
        assert!(back.conserves_messages());
        assert!(gone.conserves_messages());
    }

    /// Sync-mode barriers must exclude departed participants: a leave
    /// mid-epoch cannot deadlock the survivors, and a leave while the
    /// barrier is already partially filled must itself release it.
    #[test]
    fn sync_mode_survives_permanent_departure() {
        let scenario = FaultScenario::default().with(
            10 * MILLI,
            ALWAYS,
            FaultKind::ProcLeave { proc: 2 },
        );
        let result = churn_engine(4, AsyncMode::Sync, 40 * MILLI, 13, scenario).run();
        // Run completed (no deadlock) and survivors stayed in lockstep.
        let live = [0usize, 1, 3];
        let min = live.iter().map(|&p| result.updates[p]).min().unwrap();
        let max = live.iter().map(|&p| result.updates[p]).max().unwrap();
        assert!(max - min <= 1, "live lockstep violated: {:?}", result.updates);
        assert!(min > 5, "survivors stalled: {:?}", result.updates);
        assert!(result.updates[2] < min, "departed proc outran survivors");
        assert!(result.conserves_messages());
    }

    #[test]
    fn sync_mode_survives_leave_then_rejoin() {
        let scenario = FaultScenario::default().with(
            10 * MILLI,
            10 * MILLI,
            FaultKind::ProcLeave { proc: 2 },
        );
        let result = churn_engine(4, AsyncMode::Sync, 40 * MILLI, 14, scenario).run();
        let min = *result.updates.iter().min().unwrap();
        assert!(min > 5, "rejoin stalled the allocation: {:?}", result.updates);
        assert!(result.conserves_messages());
    }

    #[test]
    fn leave_join_storm_conserves_messages() {
        let scenario = FaultScenario::leave_join_storm(8, 10 * MILLI, 20 * MILLI, 4);
        let result = churn_engine(8, AsyncMode::BestEffort, 50 * MILLI, 15, scenario).run();
        assert!(result.conserves_messages());
        assert!(result.attempted_sends > 0);
    }

    // ---- checkpoint / restore --------------------------------------

    fn ckpt_engine(
        seed: u64,
        sched: SchedKind,
        scenario: FaultScenario,
    ) -> Engine<GraphColoringShard> {
        let topo = Topology::new(4, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(seed);
        let shards: Vec<_> = (0..4)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 8,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let mut cfg =
            SimConfig::new(AsyncMode::BestEffort, ModeTiming::graph_coloring(4), 60 * MILLI);
        cfg.seed = seed;
        cfg.send_buffer = 8;
        cfg.sched = sched;
        cfg.scenario = scenario;
        let profiles = healthy_profiles(&topo);
        Engine::new(cfg, topo, profiles, shards)
    }

    fn snap_scenario_engine(
        seed: u64,
        sched: SchedKind,
        scenario: FaultScenario,
    ) -> Engine<GraphColoringShard> {
        let topo = Topology::new(4, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(seed);
        let shards: Vec<_> = (0..4)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 8,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let mut cfg =
            SimConfig::new(AsyncMode::BestEffort, ModeTiming::graph_coloring(4), 60 * MILLI);
        cfg.seed = seed;
        cfg.send_buffer = 8;
        cfg.sched = sched;
        cfg.snapshots = Some(SnapshotSchedule::compressed(10 * MILLI, 15 * MILLI, 8 * MILLI, 3));
        cfg.scenario = scenario;
        let profiles = healthy_profiles(&topo);
        Engine::new(cfg, topo, profiles, shards)
    }

    fn fingerprint(
        r: &SimResult<GraphColoringShard>,
    ) -> (Vec<u64>, u64, u64, u64, u64, u64, Vec<u8>) {
        (
            r.updates.clone(),
            r.attempted_sends,
            r.successful_sends,
            r.messages_delivered,
            r.messages_purged,
            r.messages_in_flight,
            r.shards.iter().flat_map(|s| s.colors().to_vec()).collect(),
        )
    }

    /// Core tentpole property: checkpoint at t + restore + run == the
    /// straight-through run, bit-identically — including QoS windows and
    /// the mid-run fault overlay. And the checkpointed engine itself is
    /// unperturbed by the drain round-trip.
    #[test]
    fn checkpoint_restore_is_bit_identical() {
        let scenario = FaultScenario::degrade_recover(1, 15 * MILLI, 20 * MILLI);
        for sched in [SchedKind::Heap, SchedKind::Calendar] {
            let straight = snap_scenario_engine(21, sched, scenario.clone()).run();
            let mut e = snap_scenario_engine(21, sched, scenario.clone());
            let over = e.run_until(25 * MILLI);
            assert!(!over, "run ended before the checkpoint instant");
            let blob = e.checkpoint();
            let resumed_orig = e.run();
            let restored = Engine::<GraphColoringShard>::restore(&blob).unwrap();
            let resumed = restored.run();
            assert_eq!(fingerprint(&straight), fingerprint(&resumed_orig));
            assert_eq!(fingerprint(&straight), fingerprint(&resumed));
            assert_eq!(straight.qos, resumed.qos, "QoS windows diverged after restore");
            assert_eq!(straight.qos, resumed_orig.qos);
        }
    }

    /// Two checkpoints with no events in between must be byte-equal:
    /// the scheduler drain round-trip is lossless.
    #[test]
    fn double_checkpoint_is_byte_equal() {
        let mut e = ckpt_engine(22, SchedKind::Calendar, FaultScenario::default());
        assert!(!e.run_until(20 * MILLI));
        let a = e.checkpoint();
        let b = e.checkpoint();
        assert_eq!(a, b, "checkpoint is not a pure observation");
    }

    /// A heap-scheduler checkpoint restored onto a calendar queue (and
    /// vice versa) resumes bit-identically: dequeue order is a pure
    /// function of the (t, seq) keys.
    #[test]
    fn cross_sched_restore_is_bit_identical() {
        let scenario = FaultScenario::congestion_storm(15 * MILLI, 20 * MILLI);
        let straight = snap_scenario_engine(23, SchedKind::Heap, scenario.clone()).run();
        let mut e = snap_scenario_engine(23, SchedKind::Heap, scenario);
        assert!(!e.run_until(25 * MILLI));
        let blob = e.checkpoint();
        let restored =
            Engine::<GraphColoringShard>::restore_with_sched(&blob, SchedKind::Calendar)
                .unwrap();
        let resumed = restored.run();
        assert_eq!(fingerprint(&straight), fingerprint(&resumed));
        assert_eq!(straight.qos, resumed.qos);
    }

    /// Churn state (live set, purge counters, armed wakes) survives the
    /// round trip: checkpoint mid-departure, restore, and the rejoin
    /// still happens on schedule.
    #[test]
    fn checkpoint_mid_churn_round_trips() {
        let scenario = FaultScenario::default()
            .with(15 * MILLI, 25 * MILLI, FaultKind::ProcLeave { proc: 1 });
        let straight = ckpt_engine(24, SchedKind::Heap, scenario.clone()).run();
        let mut e = ckpt_engine(24, SchedKind::Heap, scenario);
        // 20 ms: proc 1 is departed, rejoin is still queued.
        assert!(!e.run_until(20 * MILLI));
        let blob = e.checkpoint();
        let resumed = Engine::<GraphColoringShard>::restore(&blob).unwrap().run();
        assert_eq!(fingerprint(&straight), fingerprint(&resumed));
        assert!(resumed.conserves_messages());
    }

    #[test]
    fn restore_rejects_malformed_blobs() {
        let mut e = ckpt_engine(25, SchedKind::Heap, FaultScenario::default());
        assert!(!e.run_until(10 * MILLI));
        let blob = e.checkpoint();
        assert!(Engine::<GraphColoringShard>::restore(&[]).is_err());
        assert!(
            Engine::<GraphColoringShard>::restore(&blob[..blob.len() - 1]).is_err(),
            "truncated blob loaded"
        );
        let mut wrong_magic = blob.clone();
        wrong_magic[0] ^= 0xFF;
        assert_eq!(
            Engine::<GraphColoringShard>::restore(&wrong_magic).err(),
            Some(SnapError::BadMagic)
        );
        let mut wrong_version = blob;
        wrong_version[4] = 0xEE;
        assert!(matches!(
            Engine::<GraphColoringShard>::restore(&wrong_version),
            Err(SnapError::BadVersion(_))
        ));
    }
}
