//! Deterministic discrete-event simulation of a multi-node allocation.
//!
//! The engine stands in for the paper's testbed (see DESIGN.md §2). Each
//! simulated process owns a [`ShardWorkload`] and advances through
//! simsteps — pull/absorb, compute, send — on its own virtual clock.
//! **Workload state updates are real computation; only time is virtual**,
//! so solution quality (graph-coloring conflicts, evolutionary fitness) is
//! genuinely produced by the simulated communication regime, not modelled.
//!
//! Cost model per simstep:
//!
//! * compute: `(workload.step_cost_ns() + work_units × 35 ns)` scaled by
//!   the node profile (speed, lognormal jitter, rare OS-noise stalls) and
//!   a contention factor for co-scheduled CPUs;
//! * per-channel send/pull CPU overheads from the [`LinkModel`];
//! * message delivery at `depart + latency`, where departures drain from
//!   a bounded send buffer at the link's service interval — a send
//!   attempted against a full buffer is **dropped**, the paper's only
//!   loss condition;
//! * barrier semantics per asynchronicity mode (Table I), with barrier
//!   cost growing logarithmically in process count.
//!
//! # Cost scales with activity, not with population
//!
//! Two structural choices keep per-simstep cost O(active events) rather
//! than O(procs), which is what lets replicates reach 10⁵–10⁶ processes:
//!
//! * **Idle-skip pulls** ([`StepPath::IdleSkip`], the default): a waking
//!   process drains only the incoming channels a sender has marked dirty
//!   since its last visit, instead of scanning its whole in-degree. A
//!   clean channel's drain would have observed nothing, so skipping it is
//!   invisible — `pull_attempts` is derived from the update counter at
//!   read time (exactly one attempt per incoming channel per simstep)
//!   rather than counted on the hot path. Both paths are bit-identical;
//!   `EBCOMM_STEP=dense` forces the reference scan and the parity is
//!   pinned by unit, integration, and randomized property tests.
//! * **Incremental snapshot capture**: window opens/closes re-read only
//!   channels adjacent to processes that stepped since the last capture
//!   (tracked by a per-proc touched flag); untouched channels reuse their
//!   cached observation, which still equals a live read.
//!
//! Per-channel state is split hot/cold ([`ChanHot`]/[`ChanCold`]) with
//! link models interned into a shared table, shrinking the resident
//! bytes/proc that [`Engine::memory_footprint`] reports.

use super::calendar::{SchedKind, Scheduler};
use super::checkpoint::{Persist, SnapError, SnapReader, SnapWriter};
use super::lanes::EnvelopeLanes;
use super::modes::{AsyncMode, ModeTiming};
use super::policy::{AdaptiveController, PolicyConfig};
use crate::conduit::{CounterTranche, LocalChannelStats, SendOutcome, StatsSink};
use crate::faults::{FaultKind, FaultRuntime, FaultScenario, ScenarioPhase};
use crate::net::{LinkModel, NodeProfile, PlacementKind, Topology};
use crate::qos::{
    QosObservation, QosStorage, ReplicateQos, SketchQos, SnapshotSchedule, SnapshotWindow,
    TouchCounter,
};
use crate::util::rng::{Rng, Xoshiro256};
use crate::util::{Nanos, MICRO};
use crate::workloads::{ChannelSpec, ShardWorkload, SpecIndex};

/// Which transport backs inter-CPU channels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommBackend {
    /// MPI-model links: intranode or internode per placement.
    Mpi,
    /// Shared-memory mutex links (multithreading, §III-E).
    SharedMemory,
}

/// Contention factor for co-scheduled CPUs on one node:
/// `1 + a * (k - 1)^b` for `k` co-resident processes/threads.
///
/// The paper observes severe per-CPU slowdown under multithreading even
/// with communication disabled (mode 4) — 61 % loss from 1→4 threads on
/// graph coloring — attributing it to "strain on a limited system resource
/// like memory cache or access to the system clock" (§III-A). The (a, b)
/// constants below are calibrated to those mode-4 measurements.
#[derive(Clone, Copy, Debug)]
pub struct ContentionModel {
    pub a: f64,
    pub b: f64,
}

impl ContentionModel {
    /// No contention (distinct-node multiprocessing).
    pub fn none() -> Self {
        Self { a: 0.0, b: 1.0 }
    }

    /// Graph-coloring multithread calibration: f(4) ≈ 2.56, f(64) ≈ 10.
    pub fn graph_coloring_threads() -> Self {
        Self { a: 0.82, b: 0.58 }
    }

    /// Digital-evolution multithread calibration: f(64) ≈ 1.64
    /// (mode-4 update rate 61 % of lone thread at 64 threads, §III-A).
    pub fn digital_evolution_threads() -> Self {
        Self { a: 0.045, b: 0.63 }
    }

    pub fn factor(&self, co_resident: usize) -> f64 {
        if co_resident <= 1 {
            1.0
        } else {
            1.0 + self.a * ((co_resident - 1) as f64).powf(self.b)
        }
    }
}

/// Which main-loop stepping strategy drives the pull phase.
///
/// Both paths produce bit-identical simulations — same golden signature,
/// same QoS windows, same checkpoint stream — under either scheduler
/// kind; idle-skip is the default because its cost is O(laden channels)
/// instead of O(in-degree) per simstep. Pinned by
/// `dense_and_idle_skip_paths_are_bit_identical` below, the golden parity
/// test in `tests/integration_sim.rs`, and the randomized grids in
/// `tests/prop_stepping.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPath {
    /// Scan every incoming channel of a waking process — the original
    /// reference pull loop.
    Dense,
    /// Drain only the incoming channels marked dirty by a sender since
    /// the receiver's last visit (arrival-driven dirty lists).
    IdleSkip,
}

impl StepPath {
    /// Resolve from the `EBCOMM_STEP` env var: `"dense"` or `"skip"`
    /// (case-insensitive); unset means [`StepPath::IdleSkip`]. Panics on
    /// anything else — a misspelled selector silently falling back would
    /// invalidate a parity experiment.
    pub fn from_env() -> Self {
        match std::env::var("EBCOMM_STEP") {
            Ok(v) if v.eq_ignore_ascii_case("dense") => StepPath::Dense,
            Ok(v) if v.eq_ignore_ascii_case("skip") => StepPath::IdleSkip,
            Ok(v) => panic!("EBCOMM_STEP must be \"dense\" or \"skip\", got {v:?}"),
            Err(_) => StepPath::IdleSkip,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            StepPath::Dense => "dense",
            StepPath::IdleSkip => "skip",
        }
    }
}

/// Simulation run configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub mode: AsyncMode,
    pub timing: ModeTiming,
    pub backend: CommBackend,
    pub seed: u64,
    /// Virtual runtime.
    pub run_for: Nanos,
    /// Synthetic per-update compute work (paper work units, 35 ns each).
    pub added_work_units: u64,
    /// Send-buffer capacity in messages (paper: 2 benchmarking, 64 QoS).
    pub send_buffer: usize,
    /// Physical cores per node (paper lac nodes: 28).
    pub cores_per_node: usize,
    pub contention: ContentionModel,
    /// Barrier cost: `base + per_log2 * log2(P)` ns, plus an exponential
    /// tail of mean `tail * log2(P)` sampled per release — collective
    /// operations on real clusters have heavy-tailed completion times
    /// (network contention, OS noise on any participant).
    pub barrier_base_ns: f64,
    pub barrier_per_log2_ns: f64,
    pub barrier_tail_ns: f64,
    /// Optional QoS snapshot schedule.
    pub snapshots: Option<SnapshotSchedule>,
    /// Override the link coalescing window (ablation hook): `Some(0)`
    /// disables arrival batching entirely.
    pub coalesce_override: Option<Nanos>,
    /// Which event scheduler backs the wake queue. Defaults from the
    /// `EBCOMM_SCHED` env var (`"heap"` / `"calendar"`); both produce
    /// bit-identical simulations — see `sim::calendar`.
    pub sched: SchedKind,
    /// Which pull-phase stepping strategy the main loop uses. Defaults
    /// from the `EBCOMM_STEP` env var (`"dense"` / `"skip"`); both
    /// produce bit-identical simulations — see [`StepPath`].
    pub step: StepPath,
    /// Scripted time-varying fault timeline (see [`crate::faults`]).
    /// Compiled into calendar-queue wake events at construction; the
    /// default empty scenario leaves the engine on the static-profile
    /// path, bit-identically.
    pub scenario: FaultScenario,
    /// How QoS observations are stored: exact per-channel windows (the
    /// default; O(channels × windows) memory) or mergeable streaming
    /// sketches (O(1) per window per metric — the 10⁴⁺-proc mode).
    /// Defaults from the `EBCOMM_QOS` env var (`"exact"` / `"sketch"`).
    /// The simulation itself is bit-identical either way: storage only
    /// decides what the capture path retains.
    pub qos_storage: QosStorage,
    /// Per-channel communication policy. `Uniform(mode)` (the default,
    /// kept in lockstep with `mode`) reproduces the pre-policy engine
    /// bit-identically; `Adaptive` layers the per-channel controller of
    /// [`crate::sim::policy`] on top of the barriered base mode. Set via
    /// [`SimConfig::with_policy`], which also syncs `mode`.
    pub policy: PolicyConfig,
    /// Replace every channel's preset [`LinkModel`] with this one —
    /// the hook for calibrated models measured off the multi-process
    /// executor (`LinkModel::calibrated`). `coalesce_override` still
    /// applies on top.
    pub link_override: Option<LinkModel>,
}

impl SimConfig {
    /// Pure-default configuration: **no environment is consulted.**
    /// Scheduler, step path, and QoS storage take their documented
    /// defaults (calendar / idle-skip / exact); use
    /// [`SimConfig::from_env`] to honor the `EBCOMM_*` selector
    /// variables, or the `with_*` builders to pick explicitly.
    pub fn new(mode: AsyncMode, timing: ModeTiming, run_for: Nanos) -> Self {
        Self {
            mode,
            timing,
            backend: CommBackend::Mpi,
            seed: 1,
            run_for,
            added_work_units: 0,
            send_buffer: 2,
            cores_per_node: 28,
            contention: ContentionModel::none(),
            barrier_base_ns: 4.0 * MICRO as f64,
            barrier_per_log2_ns: 30.0 * MICRO as f64,
            barrier_tail_ns: 100.0 * MICRO as f64,
            snapshots: None,
            coalesce_override: None,
            sched: SchedKind::Calendar,
            step: StepPath::IdleSkip,
            scenario: FaultScenario::default(),
            qos_storage: QosStorage::Exact,
            policy: PolicyConfig::Uniform(mode),
            link_override: None,
        }
    }

    /// The single entry point that reads the environment: [`Self::new`]
    /// plus the `EBCOMM_SCHED` / `EBCOMM_STEP` / `EBCOMM_QOS` selector
    /// variables (each panics on an unrecognized value; unset keeps the
    /// pure default). Tests, benches, and the CLI go through here so the
    /// CI parity lanes can steer every run from the environment; library
    /// callers that want full isolation use `new()` + builders instead.
    pub fn from_env(mode: AsyncMode, timing: ModeTiming, run_for: Nanos) -> Self {
        Self::new(mode, timing, run_for)
            .with_sched(SchedKind::from_env())
            .with_step(StepPath::from_env())
            .with_qos_storage(QosStorage::from_env())
    }

    /// Pick the wake-queue scheduler (bit-invisible; see `sim::calendar`).
    pub fn with_sched(mut self, sched: SchedKind) -> Self {
        self.sched = sched;
        self
    }

    /// Pick the pull-phase stepping strategy (bit-invisible).
    pub fn with_step(mut self, step: StepPath) -> Self {
        self.step = step;
        self
    }

    /// Pick the QoS observation storage (bit-invisible to the sim).
    pub fn with_qos_storage(mut self, qos_storage: QosStorage) -> Self {
        self.qos_storage = qos_storage;
        self
    }

    /// Install a communication policy. Also syncs `mode` to the policy's
    /// base mode — the two must never disagree (the engine asserts it).
    pub fn with_policy(mut self, policy: PolicyConfig) -> Self {
        self.mode = policy.base_mode();
        self.policy = policy;
        self
    }

    fn barrier_cost(&self, n_procs: usize, rng: &mut Xoshiro256) -> Nanos {
        let log2 = (n_procs.max(1) as f64).log2();
        let tail = rng.exponential(self.barrier_tail_ns * log2.max(1.0));
        (self.barrier_base_ns + self.barrier_per_log2_ns * log2 + tail) as Nanos
    }
}

/// Construction-time-immutable wiring of one directed channel, packed to
/// narrow integers and kept out of the hot counter cache lines. One copy
/// per channel; the link model itself lives once per distinct model in
/// the engine's interned [`LinkModel`] table.
#[derive(Clone, Copy)]
struct ChanCold {
    src: u32,
    dst: u32,
    /// Channel index within the source's channel list.
    src_ch: u32,
    /// Channel index within the destination's channel list (reciprocal).
    dst_ch: u32,
    /// Index of this channel's entry in `procs[dst].incoming` — what a
    /// sender pushes onto the destination's dirty list when it lades a
    /// clean channel (idle-skip stepping).
    dst_in_idx: u32,
    /// Workload layer tag of the source's spec — retained so membership
    /// rejoin can re-derive the reciprocal wiring through the
    /// [`SpecIndex`] instead of trusting possibly-stale cached indices.
    layer: u32,
    /// Hosting nodes of the endpoints (cached off the topology so the
    /// fault overlay's per-send effective-parameter lookup is O(1)).
    src_node: u32,
    dst_node: u32,
    /// Index into the engine's interned link-model table.
    link_id: u16,
    /// Endpoints on distinct nodes (storms/partitions only touch these).
    crossnode: bool,
}

/// Mutable per-channel state: the counters and lanes every send and pull
/// actually touches, with nothing else sharing their cache lines.
struct ChanHot<M> {
    last_depart: Nanos,
    last_arrival: Nanos,
    /// In-flight envelopes in push order, stored SoA (parallel
    /// depart/arrival/touch/payload lanes). Departure times are monotone
    /// non-decreasing front to back (each departure is scheduled at
    /// `now.max(last_depart + service)`), which is what makes O(1)
    /// occupancy tracking below sound; arrivals are monotone too, so
    /// pulls drain a prefix as one batched lane splice.
    lanes: EnvelopeLanes<M>,
    /// Envelopes ever accepted into the channel.
    pushed: u64,
    /// Envelopes drained out of the lanes — receiver pulls plus
    /// departure purges (prefix of push order).
    pulled: u64,
    /// Monotone departed-prefix counter: how many envelopes, in push
    /// order, are known to have left the send buffer (`depart <= t` for
    /// the latest occupancy query time `t`). Each envelope is stepped
    /// over at most once, so occupancy is amortized O(1) instead of the
    /// former O(queue) reverse scan per send.
    departed: u64,
    /// Of `pulled`, how many were discarded by a receiver-departure
    /// purge rather than delivered — the per-channel side of the
    /// send-conservation invariant (`pushed == delivered + purged +
    /// lanes.len()`).
    purged: u64,
    /// Is this channel on its destination's dirty list? Set by the first
    /// send that lades a clean channel, cleared when a drain leaves the
    /// lanes empty. Maintained only under [`StepPath::IdleSkip`].
    dirty: bool,
    stats: LocalChannelStats,
}

impl<M> ChanHot<M> {
    fn new() -> Self {
        Self {
            last_depart: 0,
            last_arrival: 0,
            lanes: EnvelopeLanes::new(),
            pushed: 0,
            pulled: 0,
            departed: 0,
            purged: 0,
            dirty: false,
            stats: LocalChannelStats::new(),
        }
    }

    /// Messages still occupying the send buffer at time `now`.
    ///
    /// Occupants are the envelopes that neither departed (`depart <=
    /// now`) nor were already pulled by the receiver; both sets are
    /// prefixes of push order (departures because departure times are
    /// monotone, pulls because the receiver drains front to back), so
    /// the count is `pushed - max(departed, pulled)`. Queries for one
    /// channel come from its single source process, whose clock is
    /// monotone — the departed prefix only ever advances.
    fn occupancy(&mut self, now: Nanos) -> usize {
        let mut done = self.departed.max(self.pulled);
        while done < self.pushed {
            let idx = (done - self.pulled) as usize;
            if self.lanes.depart_at(idx) <= now {
                done += 1;
            } else {
                break;
            }
        }
        self.departed = done;
        (self.pushed - done) as usize
    }
}

/// Construction-time link-model interner: channels reference models by
/// table index instead of embedding ~80 bytes apiece. Keyed on the exact
/// serialized bit pattern (via [`Persist`]), so two models are conflated
/// only when no downstream computation could ever distinguish them.
struct LinkInterner {
    links: Vec<LinkModel>,
    keys: Vec<Vec<u8>>,
}

impl LinkInterner {
    fn new() -> Self {
        Self {
            links: Vec::new(),
            keys: Vec::new(),
        }
    }

    fn intern(&mut self, link: LinkModel) -> u16 {
        let key = {
            let mut w = SnapWriter::new();
            link.save(&mut w);
            w.finish()
        };
        for (i, k) in self.keys.iter().enumerate() {
            if *k == key {
                return i as u16;
            }
        }
        assert!(
            self.links.len() < u16::MAX as usize,
            "link-model table overflow"
        );
        self.links.push(link);
        self.keys.push(key);
        (self.links.len() - 1) as u16
    }
}

/// Per-process simulation state.
struct ProcState<W: ShardWorkload> {
    workload: W,
    rng: Xoshiro256,
    clock: Nanos,
    updates: u64,
    /// Outgoing channel ids (into `Engine::{cold,hot}`), by workload
    /// channel index.
    outgoing: Vec<usize>,
    /// Incoming channel ids, paired with the local workload channel index
    /// they deliver to.
    incoming: Vec<(usize, usize)>,
    /// For each incoming entry, the index (into `outgoing`/`touch`) of the
    /// reciprocal outgoing channel — precomputed so the touch-counter
    /// update is O(1) per laden pull (SPerf iteration 5).
    reciprocal_out: Vec<Option<usize>>,
    /// Touch counter per outgoing channel (tracks the peer relationship).
    touch: Vec<TouchCounter>,
    /// Prefix sums of incoming pull overheads: `pull_cum[k]` is the
    /// virtual-time offset at which incoming channel `k` is drained
    /// within a simstep. Derived from the wiring + link table (rebuilt on
    /// restore, never persisted); what lets the idle-skip path drain an
    /// arbitrary subset of channels at exactly the horizons the dense
    /// scan would have used.
    pull_cum: Vec<Nanos>,
    /// Total pull-phase overhead: the dense scan's end-of-phase clock
    /// advance, identical no matter how many channels were actually
    /// visited.
    pull_total: Nanos,
    /// Indices into `incoming` of channels currently marked dirty —
    /// pushed by senders, drained (sorted, to preserve the dense scan's
    /// ascending visit order) by this process's next pull phase.
    /// Maintained only under [`StepPath::IdleSkip`].
    dirty_in: Vec<u32>,
    /// Mode-1 chunk start.
    chunk_start: Nanos,
    /// Mode-2 next fixed sync point.
    next_fixed_sync: Nanos,
    finished: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Ev {
    SnapOpen(usize),
    SnapClose(usize),
    Wake(usize),
    /// Scenario-event transition (index into `SimConfig::scenario`):
    /// window open/close or a flap toggle, driven by the fault overlay's
    /// state machine.
    Fault(usize),
}

/// Cached observation state for one channel: its assembled counters and
/// both endpoints' update counts as of the channel's last capture event.
/// Valid (equal to a live read) for as long as neither endpoint steps —
/// which is what lets snapshot opens/closes skip untouched channels.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct ChanSnapState {
    counters: CounterTranche,
    upd_src: u64,
    upd_dst: u64,
}

/// Result of one simulated replicate.
pub struct SimResult<W> {
    /// Final workload shards (for solution-quality assessment).
    pub shards: Vec<W>,
    /// Updates completed per process.
    pub updates: Vec<u64>,
    /// Virtual runtime simulated.
    pub run_for: Nanos,
    /// All QoS snapshot metrics (per channel per window, inlet/outlet
    /// averaged). Empty under [`QosStorage::Sketch`] — query
    /// [`Self::qos_sketch`] instead.
    pub qos: ReplicateQos,
    /// Per-window per-channel raw windows (for mean/median splits).
    /// Empty under [`QosStorage::Sketch`].
    pub windows: Vec<SnapshotWindow>,
    /// Sketch-backed QoS aggregation — `Some` exactly when the run used
    /// [`QosStorage::Sketch`] with a snapshot schedule.
    pub qos_sketch: Option<SketchQos>,
    /// Global delivery accounting.
    pub attempted_sends: u64,
    pub successful_sends: u64,
    /// Messages actually retrieved by receiver pulls.
    pub messages_delivered: u64,
    /// Messages discarded from channels when their receiver departed the
    /// allocation (membership churn). Zero for churn-free runs.
    pub messages_purged: u64,
    /// Messages still queued in channels at run end.
    pub messages_in_flight: u64,
    /// Channels whose individual conservation check failed at finish:
    /// `pushed != delivered + purged + still-queued` for that channel.
    /// The global [`Self::conserves_messages`] invariant can mask
    /// compensating per-channel errors (e.g. a purge credited to the
    /// wrong channel); chaos campaigns assert this count is zero on
    /// every timeline.
    pub channel_conservation_violations: u64,
    /// Adaptive-policy telemetry: lifetime channel escalations to
    /// best-effort, lifetime heals back to the barriered base, and the
    /// channels still escalated at run end. All zero under uniform
    /// policies.
    pub policy_flips: u64,
    pub policy_heals: u64,
    pub policy_escalated_final: u64,
}

impl<W> SimResult<W> {
    /// Mean per-CPU update rate in updates/second of virtual time.
    pub fn update_rate_per_cpu_hz(&self) -> f64 {
        if self.updates.is_empty() || self.run_for == 0 {
            return 0.0;
        }
        let mean_updates =
            self.updates.iter().sum::<u64>() as f64 / self.updates.len() as f64;
        mean_updates / (self.run_for as f64 / 1e9)
    }

    /// Global delivery failure fraction over the whole run.
    pub fn overall_failure_rate(&self) -> f64 {
        if self.attempted_sends == 0 {
            0.0
        } else {
            1.0 - self.successful_sends as f64 / self.attempted_sends as f64
        }
    }

    /// Message-conservation invariant: every send accepted into a channel
    /// was delivered, purged on receiver departure, or is still in
    /// flight. Cross-checks the per-channel stats cells against the lane
    /// bookkeeping; chaos campaigns assert this on every timeline.
    pub fn conserves_messages(&self) -> bool {
        self.successful_sends
            == self.messages_delivered + self.messages_purged + self.messages_in_flight
    }
}

/// Resident-memory accounting for one engine instance, by section —
/// capacity × element size for every engine-owned allocation, plus the
/// inline size of each element (so shard state embedded in `ProcState`
/// counts, while heap owned by workload internals or queued payloads
/// does not). Published by `bench_weak_scaling` as bytes/proc from 10³
/// up to the 10⁵–10⁶-proc rungs, the DES analogue of the best-effort
/// digital-evolution study's ~104 bytes/node envelope.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryFootprint {
    pub n_procs: usize,
    pub n_channels: usize,
    /// Cold channel wiring plus the interned link-model table.
    pub chan_cold_bytes: usize,
    /// Hot per-channel counters/lanes headers (inline).
    pub chan_hot_bytes: usize,
    /// Heap reserved by in-flight envelope lanes.
    pub lane_heap_bytes: usize,
    /// Per-process state: inline struct (embedded shard included) plus
    /// wiring/touch/dirty vectors.
    pub proc_bytes: usize,
    /// Event-scheduler backing storage.
    pub sched_bytes: usize,
    /// Snapshot cache, touched flags, and completed windows (the exact
    /// path's O(channels × windows) retention shows up here).
    pub qos_bytes: usize,
    /// Sketch-backed QoS state: fixed-size bucket arrays + HLL registers,
    /// O(1) per window per metric. Zero on exact-storage runs.
    pub qos_sketch_bytes: usize,
    /// Membership, barrier, and scratch vectors.
    pub misc_bytes: usize,
    pub total_bytes: usize,
}

impl MemoryFootprint {
    pub fn bytes_per_proc(&self) -> f64 {
        if self.n_procs == 0 {
            0.0
        } else {
            self.total_bytes as f64 / self.n_procs as f64
        }
    }
}

/// The discrete-event engine.
pub struct Engine<W: ShardWorkload> {
    cfg: SimConfig,
    topo: Topology,
    profiles: Vec<NodeProfile>,
    procs: Vec<ProcState<W>>,
    /// Per-channel wiring (parallel to `hot`), immutable after
    /// construction.
    cold: Vec<ChanCold>,
    /// Per-channel mutable counters and lanes (parallel to `cold`).
    hot: Vec<ChanHot<W::Msg>>,
    /// Interned link models; `ChanCold::link_id` indexes here.
    links: Vec<LinkModel>,
    sched: Box<dyn Scheduler<Ev> + Send>,
    seq: u64,
    /// Barrier bookkeeping: arrivals and max arrival time.
    barrier_waiting: Vec<bool>,
    barrier_count: usize,
    barrier_max_arrival: Nanos,
    /// Is a snapshot window currently open?
    window_open: bool,
    /// Virtual time and fault phase at the current window's opening —
    /// the open-side observation fields are reconstructed from these plus
    /// the per-channel cache at close.
    open_t: Nanos,
    open_phase: ScenarioPhase,
    /// Per-channel cached observation state, valid while neither endpoint
    /// steps (empty when no snapshot schedule is configured).
    chan_snap: Vec<ChanSnapState>,
    /// Has process `p` stepped since its adjacent channels were last
    /// captured? Capture events refresh exactly the channels adjacent to
    /// touched procs and clear the flags.
    touched: Vec<bool>,
    windows: Vec<SnapshotWindow>,
    /// Sketch-backed QoS aggregation ([`QosStorage::Sketch`] with a
    /// snapshot schedule): closed windows fold in here instead of
    /// accumulating in `windows`. Boxed — ~100 KB of fixed bucket arrays
    /// that only sketch-mode runs pay for.
    sketch: Option<Box<SketchQos>>,
    /// Fault-scenario overlay; `None` for empty scenarios, which keeps
    /// the static-profile path bit-identical (no overlay reads, no extra
    /// scheduled events).
    faults: Option<FaultRuntime>,
    /// Union of fault phases observed while the current snapshot window
    /// is open (folds mid-window transitions into the window tag).
    window_phase: ScenarioPhase,
    /// Engine-level randomness (barrier tails etc.).
    engine_rng: Xoshiro256,
    /// Reusable pull-phase message buffer: one allocation serves every
    /// channel of every simstep (absorb drains it), instead of a fresh
    /// `Vec` per laden channel per simstep.
    pull_scratch: Vec<W::Msg>,
    /// Reusable barrier-release buffer: the N same-timestamp wakes of a
    /// release are staged here and handed to the scheduler as one
    /// [`Scheduler::push_batch_same_t`] call (which drains it back to
    /// empty), instead of N independent pushes per barrier.
    wake_batch: Vec<Ev>,
    /// Reusable idle-skip retain buffer: the dirty entries a pull phase
    /// keeps (channels drained but still laden) are staged here while
    /// the taken dirty list is walked, then swapped back in.
    dirty_scratch: Vec<u32>,
    /// Membership: is process `p` currently part of the allocation?
    /// All-true for churn-free scenarios (and never consulted on their
    /// hot paths in a way that changes behaviour).
    live: Vec<bool>,
    /// `live.iter().filter(|&&l| l).count()`, maintained incrementally —
    /// barrier releases wait for exactly the live participants.
    live_count: usize,
    /// Messages discarded from channels whose receiver departed.
    purged: u64,
    /// Is a `Ev::Wake(p)` currently in the scheduler (or an arrival
    /// recorded at the barrier)? Rejoin schedules a wake only when this
    /// is false, so a process can never hold two wake events at once.
    wake_armed: Vec<bool>,
    /// Processes named by any churn event, sorted and deduplicated —
    /// the only ones membership reconciliation must inspect. Empty for
    /// churn-free scenarios, which short-circuits reconciliation.
    churn_procs: Vec<usize>,
    /// Retained channel-spec index: rejoin re-derives reciprocal wiring
    /// through it (the same CSR lookup construction used).
    spec_index: SpecIndex,
    /// Adaptive per-channel policy controller; `None` under
    /// [`PolicyConfig::Uniform`], which keeps every uniform run on the
    /// exact pre-policy path (no allocations, no extra branches taken).
    policy_rt: Option<AdaptiveController>,
    /// Barrier membership under the adaptive policy: process `p`
    /// participates in barriers while any of its incident channels still
    /// follows the barriered base discipline. Empty under uniform
    /// policies (all live processes are members).
    barrier_member: Vec<bool>,
    /// Live *members* — the adaptive barrier quorum. Equals `live_count`
    /// under uniform policies.
    member_live: usize,
}

impl<W: ShardWorkload> Engine<W> {
    /// Build an engine over pre-constructed shards (one per process).
    /// `profiles` has one entry per node (see [`Topology::n_nodes`]).
    pub fn new(
        cfg: SimConfig,
        topo: Topology,
        profiles: Vec<NodeProfile>,
        shards: Vec<W>,
    ) -> Self {
        assert_eq!(shards.len(), topo.n_procs());
        assert_eq!(profiles.len(), topo.n_nodes(), "one profile per node");
        cfg.scenario.validate_procs(topo.n_procs());
        let mut seed_rng = Xoshiro256::new(cfg.seed);

        // Processes named by churn events: the only ones membership
        // reconciliation ever inspects after a fault transition.
        let churn_procs = churn_procs_of(&cfg.scenario);

        // Gather channel specs per process.
        let specs: Vec<Vec<ChannelSpec>> = shards.iter().map(|s| s.channels()).collect();
        let total_specs: usize = specs.iter().map(|s| s.len()).sum();

        // Flat sorted spec index replacing the former per-process
        // HashMaps — see [`SpecIndex`] (shared with the real-thread
        // executor's wiring): `partition_point` lower-bound lookup with
        // the same first-match semantics as the `or_insert` build it
        // replaces, no per-process allocations, no hashing, which at
        // 1024–4096 procs made construction the dominant cost of
        // short-run sweep cells.
        let spec_index = SpecIndex::build(&specs);

        // Create directed channels and index them, sized in one pass:
        // the channel count is exactly the spec count, and each source's
        // outgoing list is exactly its spec list's length. Wiring goes in
        // `cold`, counters/lanes in the parallel `hot`, and link models
        // are interned into a shared table — endpoint-health scaling of
        // the service interval is recomputed per send from the table's
        // unscaled model (bit-identical IEEE ops to the former
        // construction-time bake).
        let mut interner = LinkInterner::new();
        let mut cold: Vec<ChanCold> = Vec::with_capacity(total_specs);
        let mut hot: Vec<ChanHot<W::Msg>> = Vec::with_capacity(total_specs);
        let mut outgoing: Vec<Vec<usize>> = specs
            .iter()
            .map(|specs_p| Vec::with_capacity(specs_p.len()))
            .collect();
        for (src, specs_p) in specs.iter().enumerate() {
            for (src_ch, spec) in specs_p.iter().enumerate() {
                // Find the reciprocal channel index on the destination.
                let dst_ch = spec_index
                    .lookup(spec.peer, src, reciprocal_layer(spec.layer))
                    .unwrap_or_else(|| {
                        panic!(
                            "no reciprocal channel: src={src} spec={spec:?}"
                        )
                    });
                let link_id = interner.intern(link_for(&cfg, &topo, src, spec.peer));
                cold.push(ChanCold {
                    src: src as u32,
                    dst: spec.peer as u32,
                    src_ch: src_ch as u32,
                    dst_ch: dst_ch as u32,
                    dst_in_idx: 0, // filled once incoming lists exist
                    layer: spec.layer as u32,
                    src_node: topo.node_of(src) as u32,
                    dst_node: topo.node_of(spec.peer) as u32,
                    link_id,
                    crossnode: !topo.same_node(src, spec.peer),
                });
                hot.push(ChanHot::new());
                outgoing[src].push(cold.len() - 1);
            }
        }
        let links = interner.links;

        // Incoming lists, sized by a degree-count pass before filling.
        let mut in_degree = vec![0usize; shards.len()];
        for c in &cold {
            in_degree[c.dst as usize] += 1;
        }
        let mut incoming: Vec<Vec<(usize, usize)>> = in_degree
            .iter()
            .map(|&d| Vec::with_capacity(d))
            .collect();
        for (cid, c) in cold.iter().enumerate() {
            incoming[c.dst as usize].push((cid, c.dst_ch as usize));
        }
        // Back-pointers: each channel knows its slot in the destination's
        // incoming list, so a sender can push that slot onto the dirty
        // list without any lookup.
        for list in &incoming {
            for (k, &(cid, _)) in list.iter().enumerate() {
                cold[cid].dst_in_idx = k as u32;
            }
        }

        let n = shards.len();
        let procs: Vec<ProcState<W>> = shards
            .into_iter()
            .enumerate()
            .map(|(p, workload)| {
                let mut rng = seed_rng.split(p as u64);
                let skew = if cfg.timing.fixed_skew_max > 0 {
                    rng.below(cfg.timing.fixed_skew_max) as Nanos
                } else {
                    0
                };
                let n_out = outgoing[p].len();
                let my_outgoing = std::mem::take(&mut outgoing[p]);
                let my_incoming = std::mem::take(&mut incoming[p]);
                // Sorted `(dst, src_ch, oi)` index for the reciprocal
                // lookup: lower-bound on the unique (dst, src_ch) key
                // (ascending `oi` on the impossible duplicate keeps the
                // first-match semantics of the HashMap `or_insert` and
                // the scan before it).
                let mut out_index: Vec<(usize, usize, usize)> = my_outgoing
                    .iter()
                    .enumerate()
                    .map(|(oi, &oc)| {
                        (cold[oc].dst as usize, cold[oc].src_ch as usize, oi)
                    })
                    .collect();
                out_index.sort_unstable();
                let reciprocal_out = my_incoming
                    .iter()
                    .map(|&(cid, _)| {
                        let key = (cold[cid].src as usize, cold[cid].dst_ch as usize);
                        let at =
                            out_index.partition_point(|&(d, c, _)| (d, c) < key);
                        match out_index.get(at) {
                            Some(&(d, c, oi)) if (d, c) == key => Some(oi),
                            _ => None,
                        }
                    })
                    .collect();
                // Pull-overhead prefix sums: the virtual-time drain
                // horizon of each incoming channel within a simstep.
                let mut pull_cum = Vec::with_capacity(my_incoming.len());
                let mut pull_total: Nanos = 0;
                for &(cid, _) in &my_incoming {
                    pull_cum.push(pull_total);
                    pull_total +=
                        links[cold[cid].link_id as usize].pull_overhead_ns as Nanos;
                }
                ProcState {
                    workload,
                    rng,
                    clock: 0,
                    updates: 0,
                    outgoing: my_outgoing,
                    incoming: my_incoming,
                    reciprocal_out,
                    touch: vec![TouchCounter::default(); n_out],
                    pull_cum,
                    pull_total,
                    dirty_in: Vec::new(),
                    chunk_start: 0,
                    next_fixed_sync: skew + cfg.timing.fixed_epoch,
                    finished: false,
                }
            })
            .collect();

        let mut sched = cfg.sched.make::<Ev>();
        let mut seq = 0u64;

        // Compile the fault scenario: one initial wake per event (the
        // overlay chains follow-up wakes — window ends, flap toggles —
        // through `Ev::Fault` reschedules). Fault wakes are pushed
        // *before* process wakes so an onset at t=0 — e.g. the always-on
        // lac-417 scenario — is in force for the very first simstep,
        // matching the static-profile path's semantics. Empty scenarios
        // compile to nothing at all, keeping the wake/seq stream
        // bit-identical to pre-scenario engines.
        let faults = if cfg.scenario.is_empty() {
            None
        } else {
            let rt = FaultRuntime::new(cfg.scenario.clone(), profiles.clone());
            for (k, ev) in rt.scenario().events.iter().enumerate() {
                sched.push(ev.start, seq, Ev::Fault(k));
                seq += 1;
            }
            Some(rt)
        };

        // Initial wakes: one batch at t=0 — the same same-timestamp
        // burst shape as a barrier release, with the same seq stream as
        // the loop it replaces. The drained vector is kept as the
        // engine's reusable release scratch.
        let mut wake_batch: Vec<Ev> = (0..n).map(Ev::Wake).collect();
        sched.push_batch_same_t(0, seq, &mut wake_batch);
        seq += n as u64;
        if let Some(s) = cfg.snapshots {
            for i in 0..s.count {
                sched.push(s.open_at(i), seq, Ev::SnapOpen(i));
                seq += 1;
                sched.push(s.close_at(i), seq, Ev::SnapClose(i));
                seq += 1;
            }
        }

        let chan_snap = if cfg.snapshots.is_some() {
            vec![ChanSnapState::default(); cold.len()]
        } else {
            Vec::new()
        };
        let sketch = if cfg.snapshots.is_some() && cfg.qos_storage == QosStorage::Sketch {
            Some(Box::new(SketchQos::new()))
        } else {
            None
        };
        let engine_rng = Xoshiro256::new(cfg.seed ^ 0xBA44_1E44);
        assert_eq!(
            cfg.mode,
            cfg.policy.base_mode(),
            "SimConfig::mode must equal the policy base mode (use with_policy)"
        );
        let policy_rt = match cfg.policy {
            PolicyConfig::Uniform(_) => None,
            PolicyConfig::Adaptive(a) => {
                Some(AdaptiveController::new(a, cold.len(), cfg.seed))
            }
        };
        let mut eng = Self {
            cfg,
            topo,
            profiles,
            procs,
            cold,
            hot,
            links,
            sched,
            seq,
            barrier_waiting: vec![false; n],
            barrier_count: 0,
            barrier_max_arrival: 0,
            window_open: false,
            open_t: 0,
            open_phase: ScenarioPhase::QUIESCENT,
            chan_snap,
            touched: vec![false; n],
            windows: Vec::new(),
            sketch,
            faults,
            window_phase: ScenarioPhase::QUIESCENT,
            engine_rng,
            pull_scratch: Vec::new(),
            wake_batch,
            dirty_scratch: Vec::new(),
            live: vec![true; n],
            live_count: n,
            purged: 0,
            // Every process has its t=0 wake in the scheduler.
            wake_armed: vec![true; n],
            churn_procs,
            spec_index,
            policy_rt,
            barrier_member: Vec::new(),
            member_live: n,
        };
        if eng.policy_rt.is_some() {
            eng.derive_barrier_membership();
        }
        eng
    }

    fn schedule(&mut self, t: Nanos, ev: Ev) {
        self.sched.push(t, self.seq, ev);
        self.seq += 1;
    }

    /// Run to completion and return results.
    pub fn run(mut self) -> SimResult<W> {
        self.run_until(Nanos::MAX);
        self.finish()
    }

    /// Advance the event loop until the next event would fire at or after
    /// `until` (that event stays queued, untouched) or the run ends.
    /// Returns `true` when the run is over — the queue drained or the
    /// next event lay beyond `run_for` (dropped, exactly as [`Self::run`]
    /// drops the boundary event). Checkpoints are taken at the quiescent
    /// point this leaves the engine in: strictly between events.
    pub fn run_until(&mut self, until: Nanos) -> bool {
        while let Some((t, sq, ev)) = self.sched.pop() {
            if t > self.cfg.run_for {
                return true;
            }
            if t >= until {
                // Re-queue with its original key: the (t, seq) stream —
                // and hence the simulation — is unchanged by the pause.
                self.sched.push(t, sq, ev);
                return false;
            }
            match ev {
                Ev::Wake(p) => {
                    self.wake_armed[p] = false;
                    self.step_process(p, t);
                }
                Ev::SnapOpen(_) => self.snapshot_open(t),
                Ev::SnapClose(_) => self.snapshot_close(t),
                Ev::Fault(k) => self.fault_event(k, t),
            }
        }
        true
    }

    /// Switch stepping path between events. The path is observationally
    /// invisible (pinned by `tests/prop_stepping.rs`), so this is legal
    /// at any pause point: the dirty lists are derived state, rebuilt
    /// here from lane occupancy exactly as restore rebuilds them —
    /// between events every laden channel is pending for its receiver
    /// and vice versa.
    pub fn set_step_path(&mut self, step: StepPath) {
        self.cfg.step = step;
        for ch in &mut self.hot {
            ch.dirty = false;
        }
        for p in &mut self.procs {
            p.dirty_in.clear();
        }
        if step == StepPath::IdleSkip {
            for cid in 0..self.cold.len() {
                if !self.hot[cid].lanes.is_empty() {
                    self.hot[cid].dirty = true;
                    self.procs[self.cold[cid].dst as usize]
                        .dirty_in
                        .push(self.cold[cid].dst_in_idx);
                }
            }
        }
    }

    /// Consume the engine and assemble the replicate result.
    pub fn finish(mut self) -> SimResult<W> {
        // Tail-window close (bugfix): `run_until` returns when the next
        // event lies beyond `run_for`, which can leave the final snapshot
        // window open with its close event past the end of the run.
        // Formerly that partially-elapsed window was silently discarded,
        // biasing end-of-run QoS aggregates toward the earlier windows.
        // Close it at the run boundary instead — the observations are as
        // real at `run_for` as at the scheduled close.
        if self.window_open {
            self.snapshot_close(self.cfg.run_for);
        }
        let qos = ReplicateQos::from_windows(&self.windows);
        let mut totals = CounterTranche::default();
        let mut in_flight = 0u64;
        let mut channel_conservation_violations = 0u64;
        for cid in 0..self.cold.len() {
            let tranche = self.assembled_tranche(cid);
            let ch = &self.hot[cid];
            in_flight += ch.lanes.len() as u64;
            // Per-channel conservation: every envelope this channel ever
            // accepted was delivered, purged, or is still queued. The
            // global sum can hide compensating per-channel errors.
            if ch.pushed != tranche.messages_received + ch.purged + ch.lanes.len() as u64
            {
                channel_conservation_violations += 1;
            }
            totals.add(&tranche);
        }
        SimResult {
            updates: self.procs.iter().map(|p| p.updates).collect(),
            shards: self.procs.into_iter().map(|p| p.workload).collect(),
            run_for: self.cfg.run_for,
            qos,
            windows: self.windows,
            qos_sketch: self.sketch.map(|b| *b),
            attempted_sends: totals.attempted_sends,
            successful_sends: totals.successful_sends,
            messages_delivered: totals.messages_received,
            messages_purged: self.purged,
            messages_in_flight: in_flight,
            channel_conservation_violations,
            policy_flips: self.policy_rt.as_ref().map_or(0, |c| c.flips),
            policy_heals: self.policy_rt.as_ref().map_or(0, |c| c.heals),
            policy_escalated_final: self
                .policy_rt
                .as_ref()
                .map_or(0, |c| c.escalated_count() as u64),
        }
    }

    /// Drain incoming channel `k` of process `p` at its in-step horizon
    /// `t + pull_cum[k]`, updating counters, touch tracking, and the
    /// workload — the shared body of both stepping paths. An empty drain
    /// leaves every observable untouched, which is exactly why idle-skip
    /// may omit the call for clean channels.
    fn pull_channel(&mut self, p: usize, k: usize, t: Nanos, msgs: &mut Vec<W::Msg>) {
        let (cid, local_ch) = self.procs[p].incoming[k];
        let horizon = t + self.procs[p].pull_cum[k];
        msgs.clear();
        let summary = {
            let ch = &mut self.hot[cid];
            // Batched SoA drain: one arrival-lane prefix scan, then lane
            // splices into the engine scratch buffer.
            let summary = ch.lanes.drain_arrived_into(horizon, msgs);
            ch.pulled += summary.drained;
            // `pull_attempts` is not counted here — it is derived from
            // the destination's update counter at read time (one attempt
            // per incoming channel per simstep), see `assembled_tranche`.
            ch.stats.on_laden_pull(summary.drained);
            summary
        };
        if let Some(bundled) = summary.max_touch {
            // Update p's touch counter for this peer via the
            // precomputed reciprocal-channel index.
            if let Some(oi) = self.procs[p].reciprocal_out[k] {
                self.procs[p].touch[oi].on_receive(bundled);
                let v = self.procs[p].touch[oi].value();
                self.hot[self.procs[p].outgoing[oi]].stats.set_touches(v);
            }
        }
        if !msgs.is_empty() {
            self.procs[p].workload.absorb(local_ch, msgs);
        }
    }

    /// Execute one full simstep for process `p`, waking at time `t`.
    fn step_process(&mut self, p: usize, t: Nanos) {
        if self.procs[p].finished {
            return;
        }
        // A departed process does nothing — its wake lapses (disarmed by
        // the pop) and rejoin re-arms one.
        if !self.live[p] {
            return;
        }
        // Adjacent channel counters are about to move: snapshot capture
        // must re-read them instead of trusting its cache.
        self.touched[p] = true;
        let mut now = t;

        // ---- Pull phase: drain every arrived message, oldest first. ----
        if self.cfg.mode.communicates() {
            // Arrived payloads land in the engine-owned scratch buffer —
            // absorb drains it, so one allocation serves the whole run.
            let mut msgs = std::mem::take(&mut self.pull_scratch);
            match self.cfg.step {
                StepPath::Dense => {
                    // Reference scan: every incoming channel, ascending.
                    for k in 0..self.procs[p].incoming.len() {
                        self.pull_channel(p, k, t, &mut msgs);
                    }
                }
                StepPath::IdleSkip => {
                    // Only channels a sender marked dirty since the last
                    // visit. Sorting restores the dense scan's ascending
                    // visit order; each drain happens at the same
                    // `t + pull_cum[k]` horizon the dense path would
                    // have used, so the two are bit-identical. Entries
                    // whose lanes emptied (including stale entries left
                    // by a churn purge) are dropped; still-laden
                    // channels (arrivals beyond the horizon) stay
                    // listed for the next visit.
                    let mut pending = std::mem::take(&mut self.procs[p].dirty_in);
                    pending.sort_unstable();
                    let mut retained = std::mem::take(&mut self.dirty_scratch);
                    debug_assert!(retained.is_empty());
                    for &ki in &pending {
                        let k = ki as usize;
                        self.pull_channel(p, k, t, &mut msgs);
                        let cid = self.procs[p].incoming[k].0;
                        if self.hot[cid].lanes.is_empty() {
                            self.hot[cid].dirty = false;
                        } else {
                            retained.push(ki);
                        }
                    }
                    pending.clear();
                    self.dirty_scratch = pending;
                    self.procs[p].dirty_in = retained;
                }
            }
            // The pull phase costs the full in-degree's overhead in
            // virtual time regardless of how many channels were laden —
            // the CPU walks its channel list either way.
            now += self.procs[p].pull_total;
            self.pull_scratch = msgs;
        }

        // ---- Compute phase. ----
        let node = self.topo.node_of(p);
        // The fault overlay's effective profile when a scenario is
        // loaded; the static table otherwise (bit-identical paths when
        // nothing is active — the overlay caches equal the statics).
        let profile = match &self.faults {
            Some(rt) => *rt.node_profile(node),
            None => self.profiles[node],
        };
        let co_resident = self.topo.procs_on_node_of(p);
        let mut nominal = self.procs[p].workload.step_cost_ns()
            + self.cfg.added_work_units as f64 * crate::workloads::workunit::WORK_UNIT_WALL_NS;
        // Membership churn re-partitions the global workload over the
        // live set: with fewer participants each survivor owns a larger
        // share, so per-update cost scales up proportionally. Strict
        // inequality keeps churn-free runs on the untouched path,
        // bit-identically.
        if self.live_count < self.procs.len() {
            nominal *= self.procs.len() as f64 / self.live_count as f64;
        }
        let contention = self.cfg.contention.factor(co_resident);
        let dur = {
            let rng = &mut self.procs[p].rng;
            profile.sample_compute(nominal, contention, co_resident, self.cfg.cores_per_node, rng)
        };
        now += dur;

        let outputs = {
            let proc = &mut self.procs[p];
            proc.workload.step(&mut proc.rng)
        };

        // ---- Send phase. ----
        if self.cfg.mode.communicates() {
            let mark_dirty = self.cfg.step == StepPath::IdleSkip;
            for (local_ch, payload) in outputs {
                let cid = self.procs[p].outgoing[local_ch];
                let touch = self.procs[p].touch[local_ch].outgoing();
                let cold = self.cold[cid];
                let link = &self.links[cold.link_id as usize];
                now += link.send_overhead_ns as Nanos;
                if !self.live[cold.dst as usize] {
                    // Departed receiver: the channel stops accepting
                    // sends. Best-effort modes count these as
                    // delivery failures like any other drop; sync
                    // modes never deadlock on them because barriers
                    // exclude departed participants.
                    self.hot[cid].stats.on_send_attempt(false);
                    continue;
                }
                // Effective link parameters: recomputed per send from
                // the unscaled interned model. Static path: the same
                // endpoint-health scaling the construction-time bake
                // used to apply (same IEEE ops on the same inputs, so
                // bit-identical results). Overlay path: the fault
                // overlay's current view (degraded endpoints slow the
                // send-buffer drain, so occupancy-driven drops emerge
                // mid-run when a node degrades).
                let (latency_factor, extra_drop, service_ns) = match &self.faults {
                    None => {
                        let ps = self.profiles[cold.src_node as usize];
                        let pd = self.profiles[cold.dst_node as usize];
                        let health = ps.latency_factor.max(pd.latency_factor);
                        (
                            health,
                            (ps.extra_drop_prob + pd.extra_drop_prob).min(1.0),
                            link.service_ns * health,
                        )
                    }
                    Some(rt) => {
                        let ps = rt.node_profile(cold.src_node as usize);
                        let pd = rt.node_profile(cold.dst_node as usize);
                        let health = ps.latency_factor.max(pd.latency_factor);
                        let mods = rt.link_mods(
                            cold.src_node as usize,
                            cold.dst_node as usize,
                            cold.crossnode,
                        );
                        (
                            health * mods.latency_factor,
                            (ps.extra_drop_prob + pd.extra_drop_prob).min(1.0)
                                + mods.extra_drop_prob,
                            link.service_ns * health,
                        )
                    }
                };
                let mut newly_dirty = false;
                let outcome = {
                    let ch = &mut self.hot[cid];
                    let full = ch.occupancy(now) >= self.cfg.send_buffer;
                    let dropped = full
                        || self.procs[p]
                            .rng
                            .chance(link.base_drop_prob + extra_drop);
                    if dropped {
                        SendOutcome::Dropped
                    } else {
                        let depart = now.max(ch.last_depart + service_ns as Nanos);
                        let latency = (link.sample_latency(&mut self.procs[p].rng) as f64
                            * latency_factor) as Nanos;
                        let arrival = link.coalesce(depart + latency).max(ch.last_arrival);
                        ch.last_depart = depart;
                        ch.last_arrival = arrival;
                        ch.lanes.push(depart, arrival, touch, payload);
                        ch.pushed += 1;
                        if mark_dirty && !ch.dirty {
                            ch.dirty = true;
                            newly_dirty = true;
                        }
                        SendOutcome::Accepted
                    }
                };
                if newly_dirty {
                    // First envelope into a clean channel: tell the
                    // receiver's next pull phase to visit it.
                    self.procs[cold.dst as usize].dirty_in.push(cold.dst_in_idx);
                }
                self.hot[cid]
                    .stats
                    .on_send_attempt(outcome.delivered_to_channel());
            }
        }

        self.procs[p].updates += 1;
        self.procs[p].clock = now;

        // ---- Barrier / reschedule. ----
        // Under the adaptive policy a process whose every incident
        // channel has escalated to best-effort free-runs; everyone else
        // follows the base mode's cadence exactly. `barrier_member` is
        // empty under uniform policies, so that path is untouched.
        let member = self.barrier_member.is_empty() || self.barrier_member[p];
        let enter_barrier = member
            && match self.cfg.mode {
                AsyncMode::Sync => true,
                AsyncMode::RollingBarrier => {
                    now.saturating_sub(self.procs[p].chunk_start)
                        >= self.cfg.timing.rolling_chunk
                }
                AsyncMode::FixedBarrier => now >= self.procs[p].next_fixed_sync,
                AsyncMode::BestEffort | AsyncMode::NoComm => false,
            };

        if enter_barrier {
            self.arrive_barrier(p, now);
        } else {
            self.wake_armed[p] = true;
            self.schedule(now, Ev::Wake(p));
        }
    }

    fn arrive_barrier(&mut self, p: usize, t: Nanos) {
        debug_assert!(!self.barrier_waiting[p]);
        self.barrier_waiting[p] = true;
        self.barrier_count += 1;
        self.barrier_max_arrival = self.barrier_max_arrival.max(t);
        self.maybe_release_barrier(t);
    }

    /// Release the barrier when every *live* participant has arrived.
    /// Called on each arrival and on each departure — a process leaving
    /// mid-epoch can be the event that completes the barrier, so sync
    /// modes never deadlock on departed participants.
    fn maybe_release_barrier(&mut self, t: Nanos) {
        let quorum = self.barrier_quorum();
        if self.barrier_count == 0 || self.barrier_count != quorum {
            return;
        }
        // Release everyone waiting: N wakes at one timestamp with
        // consecutive seqs — handed to the scheduler as a single
        // batch (same seq stream as the former push loop, so the
        // event order is bit-identical; the batched-vs-looped
        // equivalence is pinned by `tests/prop_calendar.rs` and the
        // 1024-proc barrier-storm signature test). `max(t)` matters only
        // on departure-triggered releases, where the departure time can
        // exceed every recorded arrival.
        let release = self.barrier_max_arrival.max(t)
            + self.cfg.barrier_cost(quorum, &mut self.engine_rng);
        self.barrier_count = 0;
        self.barrier_max_arrival = 0;
        let mut batch = std::mem::take(&mut self.wake_batch);
        debug_assert!(batch.is_empty());
        for q in 0..self.procs.len() {
            if !self.barrier_waiting[q] {
                continue;
            }
            self.barrier_waiting[q] = false;
            self.wake_armed[q] = true;
            let proc = &mut self.procs[q];
            proc.clock = release;
            proc.chunk_start = release;
            // Advance the fixed sync point past the release.
            while proc.next_fixed_sync <= release {
                proc.next_fixed_sync += self.cfg.timing.fixed_epoch;
            }
            batch.push(Ev::Wake(q));
        }
        let n = batch.len() as u64;
        self.sched.push_batch_same_t(release, self.seq, &mut batch);
        self.seq += n;
        self.wake_batch = batch;
    }

    /// Channel `cid`'s counters as an external observer sees them:
    /// the live stats cells plus the derived `pull_attempts`. The dense
    /// reference loop attempted one pull per incoming channel per
    /// simstep, so at any between-events observation point the attempt
    /// count *is* the destination's update count (zero when the mode
    /// never communicates) — deriving it here is what frees the
    /// idle-skip path from visiting clean channels at all.
    fn assembled_tranche(&self, cid: usize) -> CounterTranche {
        let mut t = self.hot[cid].stats.tranche();
        t.pull_attempts = if self.cfg.mode.communicates() {
            self.procs[self.cold[cid].dst as usize].updates
        } else {
            0
        };
        t
    }

    /// Live observation state of channel `cid` (both endpoints' views).
    fn capture_chan(&self, cid: usize) -> ChanSnapState {
        ChanSnapState {
            counters: self.assembled_tranche(cid),
            upd_src: self.procs[self.cold[cid].src as usize].updates,
            upd_dst: self.procs[self.cold[cid].dst as usize].updates,
        }
    }

    /// Bring the per-channel observation cache up to date and clear the
    /// touched flags. A channel's observables move only inside a step of
    /// one of its endpoints, so the channels adjacent to touched procs
    /// are exactly the stale ones — everything else still caches a value
    /// equal to a live read.
    fn refresh_snap_cache(&mut self) {
        for p in 0..self.procs.len() {
            if !self.touched[p] {
                continue;
            }
            self.touched[p] = false;
            for &(cid, _) in &self.procs[p].incoming {
                let st = self.capture_chan(cid);
                self.chan_snap[cid] = st;
            }
            for &cid in &self.procs[p].outgoing {
                let st = self.capture_chan(cid);
                self.chan_snap[cid] = st;
            }
        }
    }

    fn snapshot_open(&mut self, t: Nanos) {
        // Start accumulating the window's fault-phase tag from the
        // instantaneous phase; `fault_event` folds in any transition that
        // fires while the window is open.
        self.window_phase = self
            .faults
            .as_ref()
            .map(|rt| rt.phase())
            .unwrap_or(ScenarioPhase::QUIESCENT);
        self.open_phase = self.window_phase;
        self.open_t = t;
        self.window_open = true;
        // The refreshed cache *is* the opening observation for every
        // channel — untouched channels reuse their previous capture,
        // which still equals the live read the dense open would take.
        self.refresh_snap_cache();
    }

    fn snapshot_close(&mut self, t: Nanos) {
        if !self.window_open {
            return;
        }
        // Closing observations carry the union of everything active at
        // any point during the window, so `SnapshotWindow::phase()` (the
        // union over all four observations) attributes the window to
        // every fault that overlapped it.
        let phase = match &self.faults {
            Some(rt) => self.window_phase.union(rt.phase()),
            None => ScenarioPhase::QUIESCENT,
        };
        let open_t = self.open_t;
        let open_phase = self.open_phase;
        // The adaptive controller is fed from the same per-channel
        // windows the QoS capture produces — taken out of `self` for the
        // loop so the borrow does not overlap the capture state.
        let mut ctl = self.policy_rt.take();
        let mut policy_changed = false;
        for cid in 0..self.cold.len() {
            let cold = self.cold[cid];
            // Stale iff an endpoint stepped while the window was open;
            // otherwise the cached state still equals a live read.
            let stale =
                self.touched[cold.src as usize] || self.touched[cold.dst as usize];
            let before = self.chan_snap[cid];
            let after = if stale { self.capture_chan(cid) } else { before };
            let window = SnapshotWindow {
                inlet_before: QosObservation::capture_phased(
                    before.counters,
                    before.upd_src,
                    open_t,
                    open_phase,
                ),
                inlet_after: QosObservation::capture_phased(
                    after.counters,
                    after.upd_src,
                    t,
                    phase,
                ),
                outlet_before: QosObservation::capture_phased(
                    before.counters,
                    before.upd_dst,
                    open_t,
                    open_phase,
                ),
                outlet_after: QosObservation::capture_phased(
                    after.counters,
                    after.upd_dst,
                    t,
                    phase,
                ),
            };
            // Adaptive policy: every closed window is a controller
            // observation. This loop always visits all channels in cid
            // order regardless of step path or storage mode, so the
            // controller's decision stream is identical across them.
            if let Some(c) = ctl.as_mut() {
                policy_changed |= c.observe_window(cid, &window.metrics());
            }
            // Storage mode decides what the capture retains: the exact
            // path accumulates the raw window, the sketch path folds the
            // identical window into fixed-size sketches and drops it.
            match &mut self.sketch {
                Some(sk) => sk.absorb_window(&window, cid as u64, cold.src as u64),
                None => self.windows.push(window),
            }
            self.chan_snap[cid] = after;
        }
        self.policy_rt = ctl;
        self.touched.fill(false);
        self.window_open = false;
        // Structural reset (bugfix hardening): the union accumulated for
        // this window must not leak into a later window's tag — the
        // accumulator only has meaning while a window is open, and
        // checkpoints persist it, so park it at quiescent between
        // windows. (`snapshot_open` also re-seeds it, so the reset is
        // what keeps the between-windows state canonical.)
        self.window_phase = ScenarioPhase::QUIESCENT;
        if policy_changed {
            self.apply_policy_pass(t);
        }
    }

    /// The number of arrivals that completes a barrier: every live
    /// process under uniform policies, every live *member* under the
    /// adaptive policy.
    fn barrier_quorum(&self) -> usize {
        if self.barrier_member.is_empty() {
            self.live_count
        } else {
            self.member_live
        }
    }

    /// Recompute adaptive barrier membership from the controller's
    /// escalation flags: a process stays in the barrier set while any of
    /// its incident channels still follows the barriered base
    /// discipline. Pure derivation — no events, no evictions — shared by
    /// construction, restore, and the event-time policy pass.
    fn derive_barrier_membership(&mut self) {
        let Some(ctl) = &self.policy_rt else {
            self.barrier_member = Vec::new();
            self.member_live = self.live_count;
            return;
        };
        let n = self.procs.len();
        if self.barrier_member.len() != n {
            self.barrier_member = vec![false; n];
        } else {
            self.barrier_member.fill(false);
        }
        for (cid, c) in self.cold.iter().enumerate() {
            if !ctl.escalated(cid) {
                self.barrier_member[c.src as usize] = true;
                self.barrier_member[c.dst as usize] = true;
            }
        }
        self.member_live = (0..n)
            .filter(|&p| self.live[p] && self.barrier_member[p])
            .count();
    }

    /// Apply a controller decision at event time `t`: re-derive the
    /// barrier membership, evict waiters that just lost membership (they
    /// resume free-running immediately instead of blocking a barrier
    /// they no longer belong to), and release the barrier if the new
    /// quorum is already met.
    fn apply_policy_pass(&mut self, t: Nanos) {
        self.derive_barrier_membership();
        for q in 0..self.procs.len() {
            if self.barrier_waiting[q] && !self.barrier_member[q] {
                self.barrier_waiting[q] = false;
                self.barrier_count -= 1;
                self.wake_armed[q] = true;
                self.procs[q].clock = t;
                self.procs[q].chunk_start = t;
                self.schedule(t, Ev::Wake(q));
            }
        }
        self.maybe_release_barrier(t);
    }

    /// Advance scenario event `k`'s overlay state machine and schedule
    /// its next transition, folding the phase change into any open
    /// snapshot window.
    fn fault_event(&mut self, k: usize, t: Nanos) {
        let window_open = self.window_open;
        let Some(rt) = self.faults.as_mut() else {
            return;
        };
        let pre = rt.phase();
        let next = rt.on_event(k, t);
        let post = rt.phase();
        if window_open {
            self.window_phase = self.window_phase.union(pre).union(post);
        }
        if let Some(tn) = next {
            self.schedule(tn, Ev::Fault(k));
        }
        self.reconcile_membership(t);
    }

    /// Sync the engine's live set with the overlay's view of departed
    /// processes after a fault transition. No-op (and not even a scan)
    /// for churn-free scenarios.
    fn reconcile_membership(&mut self, t: Nanos) {
        for i in 0..self.churn_procs.len() {
            let p = self.churn_procs[i];
            let departed = self
                .faults
                .as_ref()
                .is_some_and(|rt| rt.is_departed(p));
            if departed && self.live[p] {
                self.leave_proc(p, t);
            } else if !departed && !self.live[p] {
                self.join_proc(p, t);
            }
        }
    }

    /// Process `p` departs the allocation at time `t`: its channels stop
    /// accepting sends (see the send phase), queued messages addressed to
    /// it are purged, and barrier protocols exclude it — releasing any
    /// barrier its departure completes.
    fn leave_proc(&mut self, p: usize, t: Nanos) {
        self.live[p] = false;
        self.live_count -= 1;
        if !self.barrier_member.is_empty() && self.barrier_member[p] {
            self.member_live -= 1;
        }
        if self.barrier_waiting[p] {
            self.barrier_waiting[p] = false;
            self.barrier_count -= 1;
        }
        // Purge everything queued toward the departed process. The purge
        // is deliberately NOT a pull (no received-message stats): the
        // messages were never received — the global and per-channel
        // purge counters account for them so conservation stays
        // checkable at both granularities. Dirty flags are left as-is:
        // a stale dirty entry drains nothing and clears itself on the
        // receiver's next visit.
        let mut scratch = std::mem::take(&mut self.pull_scratch);
        for k in 0..self.procs[p].incoming.len() {
            let (cid, _) = self.procs[p].incoming[k];
            let ch = &mut self.hot[cid];
            scratch.clear();
            let summary = ch.lanes.drain_arrived_into(Nanos::MAX, &mut scratch);
            ch.pulled += summary.drained;
            ch.purged += summary.drained;
            self.purged += summary.drained;
        }
        scratch.clear();
        self.pull_scratch = scratch;
        self.maybe_release_barrier(t);
    }

    /// Process `p` rejoins the allocation at time `t`: clocks and sync
    /// points move to the join instant, reciprocal wiring is re-derived
    /// from the [`SpecIndex`], touch counters restart from zero (the
    /// crash lost their state), and a wake is armed if none is pending.
    fn join_proc(&mut self, p: usize, t: Nanos) {
        self.live[p] = true;
        self.live_count += 1;
        if !self.barrier_member.is_empty() && self.barrier_member[p] {
            self.member_live += 1;
        }
        let proc = &mut self.procs[p];
        proc.clock = t;
        proc.chunk_start = t;
        while proc.next_fixed_sync <= t {
            proc.next_fixed_sync += self.cfg.timing.fixed_epoch;
        }
        self.rewire_proc(p);
        if !self.wake_armed[p] {
            self.wake_armed[p] = true;
            self.schedule(t, Ev::Wake(p));
        }
    }

    /// Re-derive `p`'s reciprocal-channel wiring through the CSR spec
    /// index (the construction-time lookup, re-run), and reset its touch
    /// counters — a rejoining process starts its QoS relationships fresh.
    fn rewire_proc(&mut self, p: usize) {
        for k in 0..self.procs[p].incoming.len() {
            let (cid, _) = self.procs[p].incoming[k];
            let src = self.cold[cid].src as usize;
            let layer = self.cold[cid].layer as usize;
            self.procs[p].reciprocal_out[k] =
                self.spec_index.lookup(p, src, reciprocal_layer(layer));
        }
        for tc in &mut self.procs[p].touch {
            *tc = TouchCounter::default();
        }
    }

    /// Measure the engine's resident memory by section: capacity ×
    /// element size over every engine-owned allocation, plus inline
    /// element sizes. Heap owned by workload internals or by queued
    /// payload values (`W::Msg` with owned storage) is not visible from
    /// here and is excluded — the report is the *engine's* footprint,
    /// the part the hot/cold split and link interning shrink.
    pub fn memory_footprint(&self) -> MemoryFootprint {
        use std::mem::size_of;
        let chan_cold_bytes = self.cold.capacity() * size_of::<ChanCold>()
            + self.links.capacity() * size_of::<LinkModel>();
        let chan_hot_bytes = self.hot.capacity() * size_of::<ChanHot<W::Msg>>();
        let lane_heap_bytes: usize =
            self.hot.iter().map(|ch| ch.lanes.heap_bytes()).sum();
        let mut proc_bytes = self.procs.capacity() * size_of::<ProcState<W>>();
        for p in &self.procs {
            proc_bytes += p.outgoing.capacity() * size_of::<usize>()
                + p.incoming.capacity() * size_of::<(usize, usize)>()
                + p.reciprocal_out.capacity() * size_of::<Option<usize>>()
                + p.touch.capacity() * size_of::<TouchCounter>()
                + p.pull_cum.capacity() * size_of::<Nanos>()
                + p.dirty_in.capacity() * size_of::<u32>();
        }
        let sched_bytes = self.sched.heap_bytes();
        let qos_bytes = self.chan_snap.capacity() * size_of::<ChanSnapState>()
            + self.touched.capacity() * size_of::<bool>()
            + self.windows.capacity() * size_of::<SnapshotWindow>();
        let qos_sketch_bytes = self
            .sketch
            .as_ref()
            .map(|s| size_of::<SketchQos>() + s.heap_bytes())
            .unwrap_or(0);
        let misc_bytes = self.barrier_waiting.capacity() * size_of::<bool>()
            + self.live.capacity() * size_of::<bool>()
            + self.wake_armed.capacity() * size_of::<bool>()
            + self.churn_procs.capacity() * size_of::<usize>()
            + self.wake_batch.capacity() * size_of::<Ev>()
            + self.dirty_scratch.capacity() * size_of::<u32>()
            + self.pull_scratch.capacity() * size_of::<W::Msg>();
        let total_bytes = chan_cold_bytes
            + chan_hot_bytes
            + lane_heap_bytes
            + proc_bytes
            + sched_bytes
            + qos_bytes
            + qos_sketch_bytes
            + misc_bytes;
        MemoryFootprint {
            n_procs: self.procs.len(),
            n_channels: self.cold.len(),
            chan_cold_bytes,
            chan_hot_bytes,
            lane_heap_bytes,
            proc_bytes,
            sched_bytes,
            qos_bytes,
            qos_sketch_bytes,
            misc_bytes,
            total_bytes,
        }
    }

    /// Live view of the sketch-backed QoS state (`None` on exact-storage
    /// runs or when no snapshot schedule is configured). Valid between
    /// events — the dashboard tails this while `run_until` slices the
    /// run.
    pub fn qos_sketch(&self) -> Option<&SketchQos> {
        self.sketch.as_deref()
    }
}

use crate::workloads::reciprocal_layer;

/// Processes named by any churn event of `scenario`, sorted + deduped —
/// shared by construction and restore so both agree on the churn set.
fn churn_procs_of(scenario: &FaultScenario) -> Vec<usize> {
    let mut churn_procs: Vec<usize> = scenario
        .events
        .iter()
        .filter_map(|ev| match ev.kind {
            FaultKind::ProcLeave { proc } | FaultKind::ProcJoin { proc } => Some(proc),
            _ => None,
        })
        .collect();
    churn_procs.sort_unstable();
    churn_procs.dedup();
    churn_procs
}

// ---- checkpoint encodings of engine-local types --------------------

impl Persist for Ev {
    fn save(&self, w: &mut SnapWriter) {
        match *self {
            Ev::SnapOpen(i) => {
                w.put_u8(0);
                i.save(w);
            }
            Ev::SnapClose(i) => {
                w.put_u8(1);
                i.save(w);
            }
            Ev::Wake(p) => {
                w.put_u8(2);
                p.save(w);
            }
            Ev::Fault(k) => {
                w.put_u8(3);
                k.save(w);
            }
        }
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        let tag = r.get_u8()?;
        let v = usize::load(r)?;
        Ok(match tag {
            0 => Ev::SnapOpen(v),
            1 => Ev::SnapClose(v),
            2 => Ev::Wake(v),
            3 => Ev::Fault(v),
            _ => return Err(SnapError::Corrupt("Ev tag")),
        })
    }
}

impl Persist for CommBackend {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u8(match self {
            CommBackend::Mpi => 0,
            CommBackend::SharedMemory => 1,
        });
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        match r.get_u8()? {
            0 => Ok(CommBackend::Mpi),
            1 => Ok(CommBackend::SharedMemory),
            _ => Err(SnapError::Corrupt("CommBackend tag")),
        }
    }
}

impl Persist for StepPath {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u8(match self {
            StepPath::Dense => 0,
            StepPath::IdleSkip => 1,
        });
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        match r.get_u8()? {
            0 => Ok(StepPath::Dense),
            1 => Ok(StepPath::IdleSkip),
            _ => Err(SnapError::Corrupt("StepPath tag")),
        }
    }
}

impl Persist for ContentionModel {
    fn save(&self, w: &mut SnapWriter) {
        self.a.save(w);
        self.b.save(w);
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            a: f64::load(r)?,
            b: f64::load(r)?,
        })
    }
}

impl Persist for ChanSnapState {
    fn save(&self, w: &mut SnapWriter) {
        self.counters.save(w);
        self.upd_src.save(w);
        self.upd_dst.save(w);
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            counters: CounterTranche::load(r)?,
            upd_src: u64::load(r)?,
            upd_dst: u64::load(r)?,
        })
    }
}

impl Persist for SimConfig {
    fn save(&self, w: &mut SnapWriter) {
        self.mode.save(w);
        self.timing.save(w);
        self.backend.save(w);
        self.seed.save(w);
        self.run_for.save(w);
        self.added_work_units.save(w);
        self.send_buffer.save(w);
        self.cores_per_node.save(w);
        self.contention.save(w);
        self.barrier_base_ns.save(w);
        self.barrier_per_log2_ns.save(w);
        self.barrier_tail_ns.save(w);
        self.snapshots.save(w);
        self.coalesce_override.save(w);
        self.sched.save(w);
        self.step.save(w);
        self.scenario.save(w);
        self.qos_storage.save(w);
        // v4 config fields.
        self.policy.save(w);
        self.link_override.save(w);
    }

    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            mode: AsyncMode::load(r)?,
            timing: ModeTiming::load(r)?,
            backend: CommBackend::load(r)?,
            seed: u64::load(r)?,
            run_for: u64::load(r)?,
            added_work_units: u64::load(r)?,
            send_buffer: usize::load(r)?,
            cores_per_node: usize::load(r)?,
            contention: ContentionModel::load(r)?,
            barrier_base_ns: f64::load(r)?,
            barrier_per_log2_ns: f64::load(r)?,
            barrier_tail_ns: f64::load(r)?,
            snapshots: Option::<SnapshotSchedule>::load(r)?,
            coalesce_override: Option::<Nanos>::load(r)?,
            sched: SchedKind::load(r)?,
            step: StepPath::load(r)?,
            scenario: FaultScenario::load(r)?,
            qos_storage: QosStorage::load(r)?,
            policy: PolicyConfig::load(r)?,
            link_override: Option::<LinkModel>::load(r)?,
        })
    }
}

/// Range-checked narrowing for wiring fields stored as `usize` in the
/// checkpoint stream.
fn u32_field(v: usize) -> Result<u32, SnapError> {
    u32::try_from(v).map_err(|_| SnapError::Corrupt("u32 field range"))
}

fn u16_field(v: usize) -> Result<u16, SnapError> {
    u16::try_from(v).map_err(|_| SnapError::Corrupt("u16 field range"))
}

// ---- engine checkpoint / restore -----------------------------------

impl<W> Engine<W>
where
    W: ShardWorkload + Persist,
    W::Msg: Persist,
{
    /// Serialize the complete engine state to a versioned binary blob.
    ///
    /// Must be called strictly between events — i.e. after
    /// [`Self::run_until`] paused the loop (or before the first event).
    /// Takes `&mut self` because the scheduler's contents can only be
    /// observed by draining: every entry is popped, recorded, and pushed
    /// back with its original `(t, seq)` key. Dequeue order depends only
    /// on those keys, so the drain round-trip leaves the simulation
    /// bit-identical — and two consecutive checkpoints are byte-equal.
    ///
    /// Derived state is never persisted: pull prefix sums, dirty flags,
    /// and dirty lists are rebuilt from the wiring at restore (channel
    /// tranches are saved *assembled*, with the derived `pull_attempts`
    /// folded in, so older observers of the blob see final counters).
    pub fn checkpoint(&mut self) -> Vec<u8> {
        let mut w = SnapWriter::new();
        self.cfg.save(&mut w);
        self.topo.n_procs().save(&mut w);
        self.topo.placement().save(&mut w);
        self.profiles.save(&mut w);

        self.procs.len().save(&mut w);
        for p in &self.procs {
            p.workload.save(&mut w);
            p.rng.state().save(&mut w);
            p.clock.save(&mut w);
            p.updates.save(&mut w);
            p.outgoing.save(&mut w);
            p.incoming.save(&mut w);
            p.reciprocal_out.save(&mut w);
            let touch: Vec<u64> = p.touch.iter().map(|t| t.value()).collect();
            touch.save(&mut w);
            p.chunk_start.save(&mut w);
            p.next_fixed_sync.save(&mut w);
            p.finished.save(&mut w);
        }

        self.links.save(&mut w);
        self.cold.len().save(&mut w);
        for cid in 0..self.cold.len() {
            let c = &self.cold[cid];
            (c.src as usize).save(&mut w);
            (c.dst as usize).save(&mut w);
            (c.src_ch as usize).save(&mut w);
            (c.dst_ch as usize).save(&mut w);
            (c.dst_in_idx as usize).save(&mut w);
            (c.layer as usize).save(&mut w);
            (c.src_node as usize).save(&mut w);
            (c.dst_node as usize).save(&mut w);
            (c.link_id as usize).save(&mut w);
            c.crossnode.save(&mut w);
            let ch = &self.hot[cid];
            ch.last_depart.save(&mut w);
            ch.last_arrival.save(&mut w);
            ch.lanes.len().save(&mut w);
            for (depart, arrival, touch, msg) in ch.lanes.iter() {
                depart.save(&mut w);
                arrival.save(&mut w);
                touch.save(&mut w);
                msg.save(&mut w);
            }
            ch.pushed.save(&mut w);
            ch.pulled.save(&mut w);
            ch.departed.save(&mut w);
            ch.purged.save(&mut w);
            self.assembled_tranche(cid).save(&mut w);
        }

        // Scheduler: drain-and-restore. Entries come out in dequeue
        // order, which is a pure function of the (t, seq) keys — pushing
        // them straight back reproduces the identical stream.
        let mut entries: Vec<(Nanos, u64, Ev)> = Vec::with_capacity(self.sched.len());
        while let Some(e) = self.sched.pop() {
            entries.push(e);
        }
        entries.save(&mut w);
        for &(t, sq, ev) in &entries {
            self.sched.push(t, sq, ev);
        }

        self.seq.save(&mut w);
        self.barrier_waiting.save(&mut w);
        self.barrier_count.save(&mut w);
        self.barrier_max_arrival.save(&mut w);
        self.window_open.save(&mut w);
        self.open_t.save(&mut w);
        self.open_phase.save(&mut w);
        self.chan_snap.save(&mut w);
        self.touched.save(&mut w);
        self.windows.save(&mut w);
        // v3: sketch-backed QoS state rides the checkpoint verbatim (all
        // integral, so restore is bitwise by construction).
        self.sketch.is_some().save(&mut w);
        if let Some(sk) = &self.sketch {
            sk.save(&mut w);
        }
        let overlay: Option<Vec<u8>> = self.faults.as_ref().map(|rt| rt.export_states());
        overlay.save(&mut w);
        self.window_phase.save(&mut w);
        self.engine_rng.state().save(&mut w);
        self.live.save(&mut w);
        self.live_count.save(&mut w);
        self.purged.save(&mut w);
        self.wake_armed.save(&mut w);
        // v4: adaptive-controller state (barrier membership is derived
        // from it at restore, never persisted).
        self.policy_rt.is_some().save(&mut w);
        if let Some(ctl) = &self.policy_rt {
            ctl.save(&mut w);
        }
        w.finish()
    }

    /// Rebuild an engine from a [`Self::checkpoint`] blob. Resuming the
    /// restored engine is bit-identical to never having paused.
    pub fn restore(bytes: &[u8]) -> Result<Self, SnapError> {
        Self::restore_impl(bytes, None)
    }

    /// Restore, but back the wake queue with scheduler `kind` regardless
    /// of what the checkpointed config says. Both kinds dequeue the
    /// same (t, seq) stream, so cross-kind restores stay bit-identical —
    /// pinned by `tests/integration_checkpoint.rs`.
    pub fn restore_with_sched(bytes: &[u8], kind: SchedKind) -> Result<Self, SnapError> {
        Self::restore_impl(bytes, Some(kind))
    }

    fn restore_impl(
        bytes: &[u8],
        sched_override: Option<SchedKind>,
    ) -> Result<Self, SnapError> {
        let mut r = SnapReader::new(bytes)?;
        let mut cfg = SimConfig::load(&mut r)?;
        let n_procs = usize::load(&mut r)?;
        let placement = PlacementKind::load(&mut r)?;
        let topo = Topology::new(n_procs, placement);
        let profiles = Vec::<NodeProfile>::load(&mut r)?;
        if profiles.len() != topo.n_nodes() {
            return Err(SnapError::Corrupt("profile count"));
        }

        let n = usize::load(&mut r)?;
        if n != n_procs {
            return Err(SnapError::Corrupt("proc count"));
        }
        let mut procs: Vec<ProcState<W>> = Vec::with_capacity(n);
        for _ in 0..n {
            let workload = W::load(&mut r)?;
            let rng = Xoshiro256::from_state(<[u64; 4]>::load(&mut r)?);
            let clock = Nanos::load(&mut r)?;
            let updates = u64::load(&mut r)?;
            let outgoing = Vec::<usize>::load(&mut r)?;
            let incoming = Vec::<(usize, usize)>::load(&mut r)?;
            let reciprocal_out = Vec::<Option<usize>>::load(&mut r)?;
            let touch_vals = Vec::<u64>::load(&mut r)?;
            if touch_vals.len() != outgoing.len() {
                return Err(SnapError::Corrupt("touch counter count"));
            }
            let touch = touch_vals.into_iter().map(TouchCounter::from_value).collect();
            let chunk_start = Nanos::load(&mut r)?;
            let next_fixed_sync = Nanos::load(&mut r)?;
            let finished = bool::load(&mut r)?;
            procs.push(ProcState {
                workload,
                rng,
                clock,
                updates,
                outgoing,
                incoming,
                reciprocal_out,
                touch,
                chunk_start,
                next_fixed_sync,
                finished,
                pull_cum: Vec::new(),
                pull_total: 0,
                dirty_in: Vec::new(),
            });
        }

        let links = Vec::<LinkModel>::load(&mut r)?;
        let n_ch = usize::load(&mut r)?;
        let mut cold: Vec<ChanCold> = Vec::with_capacity(n_ch);
        let mut hot: Vec<ChanHot<W::Msg>> = Vec::with_capacity(n_ch);
        for _ in 0..n_ch {
            let src = usize::load(&mut r)?;
            let dst = usize::load(&mut r)?;
            let src_ch = usize::load(&mut r)?;
            let dst_ch = usize::load(&mut r)?;
            let dst_in_idx = usize::load(&mut r)?;
            let layer = usize::load(&mut r)?;
            let src_node = usize::load(&mut r)?;
            let dst_node = usize::load(&mut r)?;
            let link_id = usize::load(&mut r)?;
            let crossnode = bool::load(&mut r)?;
            let last_depart = Nanos::load(&mut r)?;
            let last_arrival = Nanos::load(&mut r)?;
            let n_lanes = usize::load(&mut r)?;
            let mut lanes = EnvelopeLanes::new();
            for _ in 0..n_lanes {
                let depart = Nanos::load(&mut r)?;
                let arrival = Nanos::load(&mut r)?;
                let touch = u64::load(&mut r)?;
                let msg = W::Msg::load(&mut r)?;
                lanes.push(depart, arrival, touch, msg);
            }
            let pushed = u64::load(&mut r)?;
            let pulled = u64::load(&mut r)?;
            let departed = u64::load(&mut r)?;
            let purged = u64::load(&mut r)?;
            let tranche = CounterTranche::load(&mut r)?;
            if src >= n || dst >= n {
                return Err(SnapError::Corrupt("channel endpoint"));
            }
            if link_id >= links.len() {
                return Err(SnapError::Corrupt("link id"));
            }
            cold.push(ChanCold {
                src: u32_field(src)?,
                dst: u32_field(dst)?,
                src_ch: u32_field(src_ch)?,
                dst_ch: u32_field(dst_ch)?,
                dst_in_idx: u32_field(dst_in_idx)?,
                layer: u32_field(layer)?,
                src_node: u32_field(src_node)?,
                dst_node: u32_field(dst_node)?,
                link_id: u16_field(link_id)?,
                crossnode,
            });
            hot.push(ChanHot {
                last_depart,
                last_arrival,
                lanes,
                pushed,
                pulled,
                departed,
                purged,
                dirty: false,
                stats: LocalChannelStats::from_tranche(&tranche),
            });
        }

        let entries = Vec::<(Nanos, u64, Ev)>::load(&mut r)?;
        let seq = u64::load(&mut r)?;
        let barrier_waiting = Vec::<bool>::load(&mut r)?;
        let barrier_count = usize::load(&mut r)?;
        let barrier_max_arrival = Nanos::load(&mut r)?;
        let window_open = bool::load(&mut r)?;
        let open_t = Nanos::load(&mut r)?;
        let open_phase = ScenarioPhase::load(&mut r)?;
        let chan_snap = Vec::<ChanSnapState>::load(&mut r)?;
        let touched = Vec::<bool>::load(&mut r)?;
        let windows = Vec::<SnapshotWindow>::load(&mut r)?;
        let sketch = if bool::load(&mut r)? {
            Some(Box::new(SketchQos::load(&mut r)?))
        } else {
            None
        };
        let overlay_states = Option::<Vec<u8>>::load(&mut r)?;
        let window_phase = ScenarioPhase::load(&mut r)?;
        let engine_rng = Xoshiro256::from_state(<[u64; 4]>::load(&mut r)?);
        let live = Vec::<bool>::load(&mut r)?;
        let live_count = usize::load(&mut r)?;
        let purged = u64::load(&mut r)?;
        let wake_armed = Vec::<bool>::load(&mut r)?;
        // v4: adaptive-controller state.
        let policy_rt = if bool::load(&mut r)? {
            Some(AdaptiveController::load(&mut r)?)
        } else {
            None
        };
        if !r.is_exhausted() {
            return Err(SnapError::Corrupt("trailing bytes"));
        }
        if cfg.mode != cfg.policy.base_mode() {
            return Err(SnapError::Corrupt("mode/policy base mismatch"));
        }
        if policy_rt.is_some() != cfg.policy.is_adaptive() {
            return Err(SnapError::Corrupt("controller presence/policy mismatch"));
        }
        if let Some(ctl) = &policy_rt {
            if ctl.n_channels() != n_ch {
                return Err(SnapError::Corrupt("controller channel count"));
            }
        }
        if live.len() != n
            || wake_armed.len() != n
            || barrier_waiting.len() != n
            || live.iter().filter(|&&l| l).count() != live_count
        {
            return Err(SnapError::Corrupt("membership vectors"));
        }
        if touched.len() != n {
            return Err(SnapError::Corrupt("touched flags"));
        }
        let want_snap = if cfg.snapshots.is_some() { n_ch } else { 0 };
        if chan_snap.len() != want_snap {
            return Err(SnapError::Corrupt("snapshot cache size"));
        }
        if window_open && cfg.snapshots.is_none() {
            return Err(SnapError::Corrupt("open window without schedule"));
        }
        let want_sketch = cfg.snapshots.is_some() && cfg.qos_storage == QosStorage::Sketch;
        if sketch.is_some() != want_sketch {
            return Err(SnapError::Corrupt("sketch presence/storage mismatch"));
        }
        if want_sketch && !windows.is_empty() {
            return Err(SnapError::Corrupt("raw windows under sketch storage"));
        }
        for p in &procs {
            for &cid in &p.outgoing {
                if cid >= n_ch {
                    return Err(SnapError::Corrupt("outgoing channel id"));
                }
            }
            for &(cid, _) in &p.incoming {
                if cid >= n_ch {
                    return Err(SnapError::Corrupt("incoming channel id"));
                }
            }
        }
        for (cid, c) in cold.iter().enumerate() {
            let expect = Some(&(cid, c.dst_ch as usize));
            if procs[c.dst as usize].incoming.get(c.dst_in_idx as usize) != expect {
                return Err(SnapError::Corrupt("incoming index"));
            }
        }

        // Derived pull costs: rebuilt from restored wiring exactly as
        // construction builds them.
        for p in procs.iter_mut() {
            let mut acc: Nanos = 0;
            p.pull_cum = p
                .incoming
                .iter()
                .map(|&(cid, _)| {
                    acc += links[cold[cid].link_id as usize].pull_overhead_ns as Nanos;
                    acc
                })
                .collect();
            p.pull_total = acc;
        }
        // Derived dirty lists: any laden channel is pending for its
        // receiver (a superset of what a live run would carry is never
        // possible — dense pulls drain every laden channel they visit, so
        // "laden" and "pending" coincide between events).
        if cfg.step == StepPath::IdleSkip {
            for cid in 0..n_ch {
                if !hot[cid].lanes.is_empty() {
                    hot[cid].dirty = true;
                    procs[cold[cid].dst as usize].dirty_in.push(cold[cid].dst_in_idx);
                }
            }
        }

        if let Some(kind) = sched_override {
            cfg.sched = kind;
        }
        let mut sched = cfg.sched.make::<Ev>();
        for &(t, sq, ev) in &entries {
            sched.push(t, sq, ev);
        }

        // Overlay presence must match the config's scenario exactly, and
        // the exported per-event machine states must fit it.
        let faults = match (overlay_states, cfg.scenario.is_empty()) {
            (None, true) => None,
            (Some(states), false) => {
                let mut rt = FaultRuntime::new(cfg.scenario.clone(), profiles.clone());
                if !rt.restore_states(&states) {
                    return Err(SnapError::Corrupt("overlay states"));
                }
                Some(rt)
            }
            _ => return Err(SnapError::Corrupt("overlay/scenario mismatch")),
        };

        // Derived structures: rebuilt from restored state, exactly as
        // construction builds them from fresh state.
        let specs: Vec<Vec<ChannelSpec>> =
            procs.iter().map(|p| p.workload.channels()).collect();
        let spec_index = SpecIndex::build(&specs);
        let churn_procs = churn_procs_of(&cfg.scenario);

        let member_live = live_count;
        let mut eng = Self {
            cfg,
            topo,
            profiles,
            procs,
            cold,
            hot,
            links,
            sched,
            seq,
            barrier_waiting,
            barrier_count,
            barrier_max_arrival,
            window_open,
            open_t,
            open_phase,
            chan_snap,
            touched,
            windows,
            sketch,
            faults,
            window_phase,
            engine_rng,
            pull_scratch: Vec::new(),
            wake_batch: Vec::new(),
            dirty_scratch: Vec::new(),
            live,
            live_count,
            purged,
            wake_armed,
            churn_procs,
            spec_index,
            policy_rt,
            barrier_member: Vec::new(),
            member_live,
        };
        // Adaptive barrier membership is derived, never persisted: the
        // same pure recomputation construction uses (no evictions — the
        // persisted barrier state is already consistent with it).
        if eng.policy_rt.is_some() {
            eng.derive_barrier_membership();
        }
        Ok(eng)
    }
}

fn link_for(cfg: &SimConfig, topo: &Topology, a: usize, b: usize) -> LinkModel {
    let mut link = match cfg.link_override {
        // Calibrated (or otherwise user-fixed) model: every channel gets
        // it, replacing the placement-derived preset.
        Some(m) => m,
        None => match cfg.backend {
            CommBackend::SharedMemory => LinkModel::thread_shared_memory(),
            CommBackend::Mpi => {
                if topo.same_node(a, b) {
                    LinkModel::intranode()
                } else {
                    LinkModel::internode()
                }
            }
        },
    };
    if let Some(c) = cfg.coalesce_override {
        link.coalesce_ns = c;
    }
    link
}

/// Convenience: build healthy profiles for every node of `topo`.
pub fn healthy_profiles(topo: &Topology) -> Vec<NodeProfile> {
    vec![NodeProfile::healthy(); topo.n_nodes()]
}

/// Heterogeneous healthy profiles: persistent per-node speed factors
/// drawn lognormal(0, `speed_sigma`) with raised per-update jitter.
///
/// The paper's testbed is "a cluster of hundreds of heterogeneous x86
/// nodes" (SII-F1); persistent node-speed spread plus per-update jitter is
/// what makes barrier-per-update synchronization collapse at scale — each
/// superstep waits for the most laggardly draw (the double-dutch effect of
/// SI). Benchmark experiments use these profiles; QoS experiments (which
/// compare same-allocation treatments) default to homogeneous ones.
pub fn heterogeneous_profiles(
    topo: &Topology,
    seed: u64,
    speed_sigma: f64,
) -> Vec<NodeProfile> {
    let mut rng = Xoshiro256::new(seed ^ 0x8E7E_0906);
    (0..topo.n_nodes())
        .map(|_| {
            let mut p = NodeProfile::healthy();
            p.speed_factor = rng.lognormal(0.0, speed_sigma);
            p.jitter_sigma = 0.35;
            p
        })
        .collect()
}

/// Convenience: healthy profiles with one faulty node at `faulty_node`.
pub fn profiles_with_faulty(topo: &Topology, faulty_node: usize) -> Vec<NodeProfile> {
    let mut v = healthy_profiles(topo);
    if faulty_node < v.len() {
        v[faulty_node] = NodeProfile::faulty_lac417();
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::{MetricName, QUANTILE_REL_ERROR_BOUND};
    use crate::util::{MILLI, SECOND};
    use crate::workloads::{GcConfig, GraphColoringShard};

    fn gc_engine(
        n_procs: usize,
        simels: usize,
        mode: AsyncMode,
        run_for: Nanos,
        seed: u64,
    ) -> Engine<GraphColoringShard> {
        let topo = Topology::new(n_procs, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(seed);
        let cfg_gc = GcConfig {
            simels_per_proc: simels,
            ..GcConfig::default()
        };
        let shards: Vec<_> = (0..n_procs)
            .map(|r| GraphColoringShard::new(cfg_gc, &topo, r, &mut rng))
            .collect();
        let mut cfg = SimConfig::from_env(mode, ModeTiming::graph_coloring(n_procs), run_for);
        cfg.seed = seed;
        cfg.send_buffer = 64;
        let profiles = healthy_profiles(&topo);
        Engine::new(cfg, topo, profiles, shards)
    }

    /// The O(1) departed-prefix occupancy must agree with a reference
    /// O(queue) reverse scan on arbitrary interleavings of monotone
    /// pushes, prefix pulls, and monotone queries — including receivers
    /// that race ahead and pull envelopes before they "depart". Runs over
    /// the SoA lanes, with a shadow AoS departure list as the reference.
    #[test]
    fn occupancy_matches_reference_scan() {
        let mut ch = ChanHot::<u8>::new();
        // Shadow copy of the queued departure times, AoS-style.
        let mut shadow: std::collections::VecDeque<Nanos> = std::collections::VecDeque::new();
        let mut rng = Xoshiro256::new(0x0CC);
        let mut now: Nanos = 0;
        let mut last_depart: Nanos = 0;
        let mut checks = 0usize;
        let mut sink = Vec::new();
        for _ in 0..5_000 {
            now += rng.below(50);
            match rng.below(3) {
                0 => {
                    // Push: departures are monotone non-decreasing, and
                    // may land in the future relative to `now`.
                    let depart = now.max(last_depart) + rng.below(25);
                    last_depart = depart;
                    ch.lanes.push(depart, depart + 5, 0, 0);
                    shadow.push_back(depart);
                    ch.pushed += 1;
                }
                1 => {
                    // Receiver drains the arrived prefix, possibly ahead
                    // of the sender's clock.
                    let horizon = now + rng.below(60);
                    sink.clear();
                    let s = ch.lanes.drain_arrived_into(horizon, &mut sink);
                    for _ in 0..s.drained {
                        shadow.pop_front();
                    }
                    ch.pulled += s.drained;
                }
                _ => {
                    let reference =
                        shadow.iter().rev().take_while(|&&d| d > now).count();
                    assert_eq!(ch.occupancy(now), reference, "at t={now}");
                    checks += 1;
                }
            }
        }
        assert!(checks > 1_000, "degenerate schedule: {checks} checks");
    }

    #[test]
    fn best_effort_runs_and_counts_updates() {
        let result = gc_engine(4, 16, AsyncMode::BestEffort, 50 * MILLI, 1).run();
        assert_eq!(result.updates.len(), 4);
        for &u in &result.updates {
            assert!(u > 100, "updates={u}");
        }
        assert!(result.update_rate_per_cpu_hz() > 1000.0);
    }

    #[test]
    fn sync_mode_lockstep_updates() {
        let result = gc_engine(4, 16, AsyncMode::Sync, 50 * MILLI, 2).run();
        // Barrier every update: all procs complete the same update count
        // (+-1 for the cut at run end).
        let min = *result.updates.iter().min().unwrap();
        let max = *result.updates.iter().max().unwrap();
        assert!(max - min <= 1, "lockstep violated: {:?}", result.updates);
    }

    #[test]
    fn best_effort_faster_than_sync() {
        let sync = gc_engine(16, 1, AsyncMode::Sync, 100 * MILLI, 3).run();
        let be = gc_engine(16, 1, AsyncMode::BestEffort, 100 * MILLI, 3).run();
        assert!(
            be.update_rate_per_cpu_hz() > 1.5 * sync.update_rate_per_cpu_hz(),
            "best-effort {} vs sync {}",
            be.update_rate_per_cpu_hz(),
            sync.update_rate_per_cpu_hz()
        );
    }

    #[test]
    fn no_comm_mode_sends_nothing() {
        let result = gc_engine(4, 16, AsyncMode::NoComm, 20 * MILLI, 4).run();
        assert_eq!(result.attempted_sends, 0);
    }

    #[test]
    fn messages_flow_in_best_effort_mode() {
        let result = gc_engine(4, 16, AsyncMode::BestEffort, 50 * MILLI, 5).run();
        assert!(result.attempted_sends > 0);
        assert!(result.successful_sends > 0);
    }

    #[test]
    fn conflicts_converge_under_simulated_best_effort() {
        let result = gc_engine(4, 64, AsyncMode::BestEffort, SECOND, 6).run();
        let conflicts =
            crate::workloads::graph_coloring::global_conflicts(
                &Topology::new(4, PlacementKind::OnePerNode),
                &result.shards,
            );
        // 256 vertices: conflicts should be well below random (~2/3 * 256).
        assert!(conflicts < 40, "conflicts={conflicts}");
    }

    #[test]
    fn snapshots_produce_qos_windows() {
        let topo = Topology::new(2, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(7);
        let shards: Vec<_> = (0..2)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 1,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let mut cfg = SimConfig::from_env(
            AsyncMode::BestEffort,
            ModeTiming::graph_coloring(2),
            200 * MILLI,
        );
        cfg.send_buffer = 64;
        // Asserts exact window contents: pin the storage mode so an
        // `EBCOMM_QOS=sketch` environment cannot empty `windows`.
        cfg.qos_storage = QosStorage::Exact;
        cfg.snapshots = Some(SnapshotSchedule::compressed(
            50 * MILLI,
            50 * MILLI,
            10 * MILLI,
            3,
        ));
        let result = Engine::new(cfg, topo, vec![NodeProfile::healthy(); 2], shards).run();
        // 2 procs x 2 channels each (1x2 mesh: E+W) x 3 windows = 12.
        assert_eq!(result.windows.len(), 12);
        for m in &result.qos.snapshots {
            assert!(m.simstep_period_ns > 0.0);
            assert!((0.0..=1.0).contains(&m.delivery_failure_rate));
            assert!((0.0..=1.0).contains(&m.delivery_clumpiness));
        }
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let a = gc_engine(4, 16, AsyncMode::BestEffort, 30 * MILLI, 42).run();
        let b = gc_engine(4, 16, AsyncMode::BestEffort, 30 * MILLI, 42).run();
        assert_eq!(a.updates, b.updates);
        assert_eq!(a.attempted_sends, b.attempted_sends);
        assert_eq!(a.successful_sends, b.successful_sends);
        let ca: Vec<u8> = a.shards.iter().flat_map(|s| s.colors().to_vec()).collect();
        let cb: Vec<u8> = b.shards.iter().flat_map(|s| s.colors().to_vec()).collect();
        assert_eq!(ca, cb);
    }

    #[test]
    fn different_seeds_differ() {
        let a = gc_engine(4, 16, AsyncMode::BestEffort, 30 * MILLI, 1).run();
        let b = gc_engine(4, 16, AsyncMode::BestEffort, 30 * MILLI, 2).run();
        assert_ne!(
            (a.updates.clone(), a.attempted_sends),
            (b.updates.clone(), b.attempted_sends)
        );
    }

    #[test]
    fn faulty_node_degrades_its_own_clique_only() {
        let topo = Topology::new(16, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(9);
        let mk_shards = |rng: &mut Xoshiro256| -> Vec<_> {
            (0..16)
                .map(|r| {
                    GraphColoringShard::new(
                        GcConfig {
                            simels_per_proc: 1,
                            ..GcConfig::default()
                        },
                        &topo,
                        r,
                        rng,
                    )
                })
                .collect()
        };
        let mut cfg = SimConfig::from_env(
            AsyncMode::BestEffort,
            ModeTiming::graph_coloring(16),
            300 * MILLI,
        );
        cfg.send_buffer = 64;
        let healthy = Engine::new(
            cfg.clone(),
            topo.clone(),
            healthy_profiles(&topo),
            mk_shards(&mut rng),
        )
        .run();
        let faulty = Engine::new(
            cfg,
            topo.clone(),
            profiles_with_faulty(&topo, 5),
            mk_shards(&mut rng),
        )
        .run();
        // Faulty node's own process does far fewer updates...
        assert!(
            (faulty.updates[5] as f64) < 0.7 * (healthy.updates[5] as f64),
            "faulty={} healthy={}",
            faulty.updates[5],
            healthy.updates[5]
        );
        // ...while the median process stays healthy.
        let mut h: Vec<u64> = healthy.updates.clone();
        let mut f: Vec<u64> = faulty.updates.clone();
        h.sort_unstable();
        f.sort_unstable();
        let (hm, fm) = (h[8] as f64, f[8] as f64);
        assert!(fm > 0.8 * hm, "median degraded: healthy={hm} faulty={fm}");
    }

    /// Loading a scenario routes every hot-path read through the fault
    /// overlay; with nothing active the overlay caches equal the static
    /// tables, so results must stay bit-identical — the overlay is free
    /// until a fault actually fires.
    #[test]
    fn never_active_scenario_is_bit_identical_to_static() {
        let run = |scenario: FaultScenario| {
            let topo = Topology::new(4, PlacementKind::OnePerNode);
            let mut rng = Xoshiro256::new(0xFA17);
            let shards: Vec<_> = (0..4)
                .map(|r| {
                    GraphColoringShard::new(
                        GcConfig {
                            simels_per_proc: 16,
                            ..GcConfig::default()
                        },
                        &topo,
                        r,
                        &mut rng,
                    )
                })
                .collect();
            let mut cfg = SimConfig::from_env(
                AsyncMode::BestEffort,
                ModeTiming::graph_coloring(4),
                30 * MILLI,
            );
            cfg.seed = 0xFA17;
            cfg.send_buffer = 4;
            cfg.scenario = scenario;
            Engine::new(cfg, topo.clone(), heterogeneous_profiles(&topo, 0xFA17, 0.20), shards)
                .run()
        };
        let a = run(FaultScenario::default());
        // Fires 10 s in — far beyond the 30 ms run window.
        let b = run(FaultScenario::midrun_failure(2, 10 * SECOND));
        assert_eq!(a.updates, b.updates);
        assert_eq!(a.attempted_sends, b.attempted_sends);
        assert_eq!(a.successful_sends, b.successful_sends);
        let ca: Vec<u8> = a.shards.iter().flat_map(|s| s.colors().to_vec()).collect();
        let cb: Vec<u8> = b.shards.iter().flat_map(|s| s.colors().to_vec()).collect();
        assert_eq!(ca, cb);
    }

    #[test]
    fn reciprocal_layer_roundtrip() {
        use crate::workloads::DE_LAYER_BASE;
        assert_eq!(reciprocal_layer(0), 2);
        // dir1,kind0 -> dir3,kind0
        assert_eq!(reciprocal_layer(DE_LAYER_BASE + 5), DE_LAYER_BASE + 15);
    }

    #[test]
    fn contention_model_calibration() {
        let gc = ContentionModel::graph_coloring_threads();
        assert!((gc.factor(4) - 2.56).abs() < 0.35, "{}", gc.factor(4));
        assert!((gc.factor(64) - 10.0).abs() < 2.0, "{}", gc.factor(64));
        assert_eq!(gc.factor(1), 1.0);
        let de = ContentionModel::digital_evolution_threads();
        assert!((de.factor(64) - 1.64).abs() < 0.25, "{}", de.factor(64));
        assert_eq!(ContentionModel::none().factor(64), 1.0);
    }

    // ---- membership churn ------------------------------------------

    use crate::faults::ALWAYS;

    fn churn_engine(
        n_procs: usize,
        mode: AsyncMode,
        run_for: Nanos,
        seed: u64,
        scenario: FaultScenario,
    ) -> Engine<GraphColoringShard> {
        let topo = Topology::new(n_procs, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(seed);
        let shards: Vec<_> = (0..n_procs)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 8,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let mut cfg = SimConfig::from_env(mode, ModeTiming::graph_coloring(n_procs), run_for);
        cfg.seed = seed;
        cfg.send_buffer = 8;
        cfg.scenario = scenario;
        let profiles = healthy_profiles(&topo);
        Engine::new(cfg, topo, profiles, shards)
    }

    #[test]
    fn departed_proc_stops_updating() {
        let scenario = FaultScenario::default().with(
            20 * MILLI,
            ALWAYS,
            FaultKind::ProcLeave { proc: 1 },
        );
        let churned = churn_engine(4, AsyncMode::BestEffort, 60 * MILLI, 11, scenario).run();
        let baseline =
            churn_engine(4, AsyncMode::BestEffort, 60 * MILLI, 11, FaultScenario::default())
                .run();
        // Proc 1 froze a third of the way in; peers kept running.
        assert!(
            (churned.updates[1] as f64) < 0.55 * (baseline.updates[1] as f64),
            "departed proc kept updating: {} vs baseline {}",
            churned.updates[1],
            baseline.updates[1]
        );
        assert!(churned.updates[0] > churned.updates[1]);
        assert!(churned.conserves_messages(), "conservation violated");
    }

    #[test]
    fn rejoining_proc_resumes_updates() {
        let windowed = FaultScenario::default().with(
            15 * MILLI,
            15 * MILLI,
            FaultKind::ProcLeave { proc: 1 },
        );
        let permanent = FaultScenario::default().with(
            15 * MILLI,
            ALWAYS,
            FaultKind::ProcLeave { proc: 1 },
        );
        let back = churn_engine(4, AsyncMode::BestEffort, 60 * MILLI, 12, windowed).run();
        let gone = churn_engine(4, AsyncMode::BestEffort, 60 * MILLI, 12, permanent).run();
        assert!(
            back.updates[1] > gone.updates[1] + 50,
            "rejoin did not resume: windowed={} permanent={}",
            back.updates[1],
            gone.updates[1]
        );
        assert!(back.conserves_messages());
        assert!(gone.conserves_messages());
    }

    /// Sync-mode barriers must exclude departed participants: a leave
    /// mid-epoch cannot deadlock the survivors, and a leave while the
    /// barrier is already partially filled must itself release it.
    #[test]
    fn sync_mode_survives_permanent_departure() {
        let scenario = FaultScenario::default().with(
            10 * MILLI,
            ALWAYS,
            FaultKind::ProcLeave { proc: 2 },
        );
        let result = churn_engine(4, AsyncMode::Sync, 40 * MILLI, 13, scenario).run();
        // Run completed (no deadlock) and survivors stayed in lockstep.
        let live = [0usize, 1, 3];
        let min = live.iter().map(|&p| result.updates[p]).min().unwrap();
        let max = live.iter().map(|&p| result.updates[p]).max().unwrap();
        assert!(max - min <= 1, "live lockstep violated: {:?}", result.updates);
        assert!(min > 5, "survivors stalled: {:?}", result.updates);
        assert!(result.updates[2] < min, "departed proc outran survivors");
        assert!(result.conserves_messages());
    }

    #[test]
    fn sync_mode_survives_leave_then_rejoin() {
        let scenario = FaultScenario::default().with(
            10 * MILLI,
            10 * MILLI,
            FaultKind::ProcLeave { proc: 2 },
        );
        let result = churn_engine(4, AsyncMode::Sync, 40 * MILLI, 14, scenario).run();
        let min = *result.updates.iter().min().unwrap();
        assert!(min > 5, "rejoin stalled the allocation: {:?}", result.updates);
        assert!(result.conserves_messages());
    }

    #[test]
    fn leave_join_storm_conserves_messages() {
        let scenario = FaultScenario::leave_join_storm(8, 10 * MILLI, 20 * MILLI, 4);
        let result = churn_engine(8, AsyncMode::BestEffort, 50 * MILLI, 15, scenario).run();
        assert!(result.conserves_messages());
        assert!(result.attempted_sends > 0);
    }

    /// The global send-conservation ledger must also balance channel by
    /// channel under a leave/join storm: for every channel,
    /// `pushed == delivered + purged + in_flight`. A counter that merely
    /// nets out globally (one channel over, another under) is caught
    /// here and surfaced through `channel_conservation_violations`.
    #[test]
    fn churn_storm_conserves_messages_per_channel() {
        let scenario = FaultScenario::leave_join_storm(8, 10 * MILLI, 20 * MILLI, 4);
        let result = churn_engine(8, AsyncMode::BestEffort, 50 * MILLI, 15, scenario).run();
        assert!(result.conserves_messages());
        assert_eq!(
            result.channel_conservation_violations, 0,
            "per-channel ledger violated on {} channels",
            result.channel_conservation_violations
        );
        assert!(result.messages_purged > 0, "storm purged nothing");
    }

    // ---- checkpoint / restore --------------------------------------

    fn ckpt_engine(
        seed: u64,
        sched: SchedKind,
        scenario: FaultScenario,
    ) -> Engine<GraphColoringShard> {
        let topo = Topology::new(4, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(seed);
        let shards: Vec<_> = (0..4)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 8,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let mut cfg =
            SimConfig::from_env(AsyncMode::BestEffort, ModeTiming::graph_coloring(4), 60 * MILLI);
        cfg.seed = seed;
        cfg.send_buffer = 8;
        cfg.sched = sched;
        cfg.scenario = scenario;
        let profiles = healthy_profiles(&topo);
        Engine::new(cfg, topo, profiles, shards)
    }

    fn snap_scenario_engine(
        seed: u64,
        sched: SchedKind,
        scenario: FaultScenario,
    ) -> Engine<GraphColoringShard> {
        // The checkpoint tests below assert on exact window/QoS content;
        // pin the storage mode so `EBCOMM_QOS=sketch` cannot empty them.
        // Sketch-mode round-trips get their own dedicated tests.
        snap_engine_with_storage(seed, sched, scenario, QosStorage::Exact)
    }

    fn snap_engine_with_storage(
        seed: u64,
        sched: SchedKind,
        scenario: FaultScenario,
        storage: QosStorage,
    ) -> Engine<GraphColoringShard> {
        let topo = Topology::new(4, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(seed);
        let shards: Vec<_> = (0..4)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 8,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let mut cfg =
            SimConfig::from_env(AsyncMode::BestEffort, ModeTiming::graph_coloring(4), 60 * MILLI);
        cfg.seed = seed;
        cfg.send_buffer = 8;
        cfg.sched = sched;
        cfg.qos_storage = storage;
        cfg.snapshots = Some(SnapshotSchedule::compressed(10 * MILLI, 15 * MILLI, 8 * MILLI, 3));
        cfg.scenario = scenario;
        let profiles = healthy_profiles(&topo);
        Engine::new(cfg, topo, profiles, shards)
    }

    fn fingerprint(
        r: &SimResult<GraphColoringShard>,
    ) -> (Vec<u64>, u64, u64, u64, u64, u64, Vec<u8>) {
        (
            r.updates.clone(),
            r.attempted_sends,
            r.successful_sends,
            r.messages_delivered,
            r.messages_purged,
            r.messages_in_flight,
            r.shards.iter().flat_map(|s| s.colors().to_vec()).collect(),
        )
    }

    /// Core tentpole property: checkpoint at t + restore + run == the
    /// straight-through run, bit-identically — including QoS windows and
    /// the mid-run fault overlay. And the checkpointed engine itself is
    /// unperturbed by the drain round-trip.
    #[test]
    fn checkpoint_restore_is_bit_identical() {
        let scenario = FaultScenario::degrade_recover(1, 15 * MILLI, 20 * MILLI);
        for sched in [SchedKind::Heap, SchedKind::Calendar] {
            let straight = snap_scenario_engine(21, sched, scenario.clone()).run();
            let mut e = snap_scenario_engine(21, sched, scenario.clone());
            let over = e.run_until(25 * MILLI);
            assert!(!over, "run ended before the checkpoint instant");
            let blob = e.checkpoint();
            let resumed_orig = e.run();
            let restored = Engine::<GraphColoringShard>::restore(&blob).unwrap();
            let resumed = restored.run();
            assert_eq!(fingerprint(&straight), fingerprint(&resumed_orig));
            assert_eq!(fingerprint(&straight), fingerprint(&resumed));
            assert_eq!(straight.qos, resumed.qos, "QoS windows diverged after restore");
            assert_eq!(straight.qos, resumed_orig.qos);
        }
    }

    /// Two checkpoints with no events in between must be byte-equal:
    /// the scheduler drain round-trip is lossless.
    #[test]
    fn double_checkpoint_is_byte_equal() {
        let mut e = ckpt_engine(22, SchedKind::Calendar, FaultScenario::default());
        assert!(!e.run_until(20 * MILLI));
        let a = e.checkpoint();
        let b = e.checkpoint();
        assert_eq!(a, b, "checkpoint is not a pure observation");
    }

    /// A heap-scheduler checkpoint restored onto a calendar queue (and
    /// vice versa) resumes bit-identically: dequeue order is a pure
    /// function of the (t, seq) keys.
    #[test]
    fn cross_sched_restore_is_bit_identical() {
        let scenario = FaultScenario::congestion_storm(15 * MILLI, 20 * MILLI);
        let straight = snap_scenario_engine(23, SchedKind::Heap, scenario.clone()).run();
        let mut e = snap_scenario_engine(23, SchedKind::Heap, scenario);
        assert!(!e.run_until(25 * MILLI));
        let blob = e.checkpoint();
        let restored =
            Engine::<GraphColoringShard>::restore_with_sched(&blob, SchedKind::Calendar)
                .unwrap();
        let resumed = restored.run();
        assert_eq!(fingerprint(&straight), fingerprint(&resumed));
        assert_eq!(straight.qos, resumed.qos);
    }

    /// Churn state (live set, purge counters, armed wakes) survives the
    /// round trip: checkpoint mid-departure, restore, and the rejoin
    /// still happens on schedule.
    #[test]
    fn checkpoint_mid_churn_round_trips() {
        let scenario = FaultScenario::default()
            .with(15 * MILLI, 25 * MILLI, FaultKind::ProcLeave { proc: 1 });
        let straight = ckpt_engine(24, SchedKind::Heap, scenario.clone()).run();
        let mut e = ckpt_engine(24, SchedKind::Heap, scenario);
        // 20 ms: proc 1 is departed, rejoin is still queued.
        assert!(!e.run_until(20 * MILLI));
        let blob = e.checkpoint();
        let resumed = Engine::<GraphColoringShard>::restore(&blob).unwrap().run();
        assert_eq!(fingerprint(&straight), fingerprint(&resumed));
        assert!(resumed.conserves_messages());
    }

    #[test]
    fn restore_rejects_malformed_blobs() {
        let mut e = ckpt_engine(25, SchedKind::Heap, FaultScenario::default());
        assert!(!e.run_until(10 * MILLI));
        let blob = e.checkpoint();
        assert!(Engine::<GraphColoringShard>::restore(&[]).is_err());
        assert!(
            Engine::<GraphColoringShard>::restore(&blob[..blob.len() - 1]).is_err(),
            "truncated blob loaded"
        );
        let mut wrong_magic = blob.clone();
        wrong_magic[0] ^= 0xFF;
        assert_eq!(
            Engine::<GraphColoringShard>::restore(&wrong_magic).err(),
            Some(SnapError::BadMagic)
        );
        let mut wrong_version = blob;
        wrong_version[4] = 0xEE;
        assert!(matches!(
            Engine::<GraphColoringShard>::restore(&wrong_version),
            Err(SnapError::BadVersion(_))
        ));
    }

    // ---- sketch-backed QoS storage ---------------------------------

    /// Storage mode only decides what the capture path retains: a
    /// sketch-mode run is bit-identical to the exact run on every
    /// simulation output, keeps no raw windows, and its sketch saw
    /// exactly the windows the exact run retained — with per-metric
    /// medians inside the documented relative-error bound of the exact
    /// nearest-rank medians.
    #[test]
    fn sketch_storage_is_simulation_invariant_and_cross_checks() {
        let scenario = FaultScenario::degrade_recover(1, 15 * MILLI, 20 * MILLI);
        let exact = snap_scenario_engine(41, SchedKind::Heap, scenario.clone()).run();
        let mut engine =
            snap_engine_with_storage(41, SchedKind::Heap, scenario, QosStorage::Sketch);
        let fp = engine.memory_footprint();
        assert!(fp.qos_sketch_bytes > 0, "sketch census line missing");
        engine.run_until(Nanos::MAX);
        let sk = engine.finish();
        assert_eq!(
            fingerprint(&exact),
            fingerprint(&sk),
            "storage mode perturbed the simulation"
        );
        assert!(sk.windows.is_empty(), "sketch mode retained raw windows");
        assert!(sk.qos.snapshots.is_empty());
        let sketch = sk.qos_sketch.expect("sketch storage produced no sketch");
        assert_eq!(sketch.window_count(), exact.windows.len() as u64);
        for m in MetricName::ALL {
            let mut vals = exact.qos.values(m);
            vals.sort_by(f64::total_cmp);
            assert!(!vals.is_empty());
            let rank = ((0.5 * vals.len() as f64).ceil() as usize).clamp(1, vals.len());
            let ex = vals[rank - 1];
            let est = sketch.median(m);
            assert!(
                (est - ex).abs() <= QUANTILE_REL_ERROR_BOUND * ex.abs() + 1e-12,
                "{m:?}: sketch median {est} vs exact nearest-rank {ex}"
            );
        }
    }

    /// Sketch state rides the checkpoint: resume-after-restore equals
    /// the straight-through run bit for bit (`SketchQos` is `Eq`; all
    /// state is integer) under both scheduler kinds.
    #[test]
    fn sketch_checkpoint_resume_matches_straight_through() {
        let scenario = FaultScenario::congestion_storm(15 * MILLI, 20 * MILLI);
        for sched in [SchedKind::Heap, SchedKind::Calendar] {
            let straight =
                snap_engine_with_storage(42, sched, scenario.clone(), QosStorage::Sketch).run();
            let mut e =
                snap_engine_with_storage(42, sched, scenario.clone(), QosStorage::Sketch);
            assert!(!e.run_until(25 * MILLI), "run ended before the checkpoint instant");
            let blob = e.checkpoint();
            let resumed = Engine::<GraphColoringShard>::restore(&blob).unwrap().run();
            assert_eq!(fingerprint(&straight), fingerprint(&resumed), "sched {sched:?}");
            assert_eq!(
                straight.qos_sketch, resumed.qos_sketch,
                "sketch state diverged after restore on {sched:?}"
            );
            assert!(
                straight.qos_sketch.as_ref().is_some_and(|s| !s.is_empty()),
                "straight-through sketch run captured nothing"
            );
        }
    }

    // ---- idle-skip stepping / memory diet --------------------------

    /// Tentpole gate: the idle-skip path must be observationally
    /// indistinguishable from dense stepping — same fingerprint, same
    /// snapshot windows bit for bit, under both scheduler kinds, through
    /// a mid-run leave/rejoin that exercises dirty-list purges.
    #[test]
    fn dense_and_idle_skip_paths_are_bit_identical() {
        let scenario = FaultScenario::default().with(
            15 * MILLI,
            15 * MILLI,
            FaultKind::ProcLeave { proc: 1 },
        );
        for sched in [SchedKind::Heap, SchedKind::Calendar] {
            let mut a = snap_scenario_engine(31, sched, scenario.clone());
            let mut b = snap_scenario_engine(31, sched, scenario.clone());
            a.cfg.step = StepPath::Dense;
            b.cfg.step = StepPath::IdleSkip;
            let ra = a.run();
            let rb = b.run();
            assert_eq!(fingerprint(&ra), fingerprint(&rb), "sched {sched:?}");
            assert_eq!(ra.windows, rb.windows, "windows diverged on {sched:?}");
            assert_eq!(ra.qos, rb.qos);
            assert_eq!(ra.channel_conservation_violations, 0);
            assert_eq!(rb.channel_conservation_violations, 0);
        }
    }

    /// Bugfix pin: a window whose close event lands past `run_for` used
    /// to be dropped entirely (the open-side tranche was captured, then
    /// the loop exited before the close event fired). `finish()` must
    /// close it at `run_for` — on the pre-fix engine this produces zero
    /// windows and fails.
    #[test]
    fn tail_window_straddling_run_end_closes_at_run_for() {
        let topo = Topology::new(2, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(33);
        let shards: Vec<_> = (0..2)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 4,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let mut cfg = SimConfig::from_env(
            AsyncMode::BestEffort,
            ModeTiming::graph_coloring(2),
            15 * MILLI,
        );
        cfg.seed = 33;
        cfg.send_buffer = 8;
        cfg.qos_storage = QosStorage::Exact; // asserts exact window contents
        // One window: opens at 10 ms, scheduled to close at 20 ms — past
        // the 15 ms end of run.
        cfg.snapshots = Some(SnapshotSchedule::compressed(
            10 * MILLI,
            10 * MILLI,
            10 * MILLI,
            1,
        ));
        let result = Engine::new(cfg, topo, vec![NodeProfile::healthy(); 2], shards).run();
        // 2 procs x 2 channels: the straddling window must still appear.
        assert_eq!(result.windows.len(), 4, "tail window dropped");
        for w in &result.windows {
            assert_eq!(w.inlet_before.wall_ns, 10 * MILLI);
            assert_eq!(w.inlet_after.wall_ns, 15 * MILLI, "not closed at run_for");
            assert!(
                w.inlet_after.update_count > w.inlet_before.update_count,
                "truncated window observed no progress"
            );
        }
    }

    /// Bugfix pin: the fault-phase accumulator must reset between
    /// windows. A fault active only during window 0 must not tag window
    /// 1 — two windows bracketing a degrade/recover flap get distinct
    /// phases.
    #[test]
    fn window_phase_does_not_leak_across_windows() {
        let topo = Topology::new(4, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(34);
        let shards: Vec<_> = (0..4)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 8,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let mut cfg = SimConfig::from_env(
            AsyncMode::BestEffort,
            ModeTiming::graph_coloring(4),
            50 * MILLI,
        );
        cfg.seed = 34;
        cfg.send_buffer = 8;
        cfg.qos_storage = QosStorage::Exact; // asserts exact window phases
        // Windows [10,20] and [30,40] ms; fault active 12–18 ms, i.e.
        // wholly inside the first window.
        cfg.snapshots = Some(SnapshotSchedule::compressed(
            10 * MILLI,
            20 * MILLI,
            10 * MILLI,
            2,
        ));
        cfg.scenario = FaultScenario::degrade_recover(1, 12 * MILLI, 6 * MILLI);
        let result =
            Engine::new(cfg, topo.clone(), healthy_profiles(&topo), shards).run();
        let n_ch = result.windows.len() / 2;
        assert!(n_ch > 0, "no windows produced");
        for (i, w) in result.windows.iter().enumerate() {
            if i < n_ch {
                assert!(
                    w.phase().contains(0),
                    "window 0 missed the active fault (channel {i})"
                );
            } else {
                assert!(
                    w.phase().is_quiescent(),
                    "fault phase leaked into window 1 (index {i}): {:?}",
                    w.phase()
                );
            }
        }
    }

    /// Every section of the memory footprint must be accounted: the
    /// per-section byte counts sum exactly to the published total, and
    /// the cold wiring record stays within its cache-dense budget.
    #[test]
    fn memory_footprint_accounts_every_section() {
        let engine = gc_engine(8, 4, AsyncMode::BestEffort, MILLI, 77);
        let fp = engine.memory_footprint();
        assert_eq!(fp.n_procs, 8);
        assert!(fp.n_channels > 0);
        let section_sum = fp.chan_cold_bytes
            + fp.chan_hot_bytes
            + fp.lane_heap_bytes
            + fp.proc_bytes
            + fp.sched_bytes
            + fp.qos_bytes
            + fp.qos_sketch_bytes
            + fp.misc_bytes;
        assert_eq!(section_sum, fp.total_bytes, "unaccounted section");
        assert!(fp.bytes_per_proc() > 0.0);
        assert!(
            std::mem::size_of::<ChanCold>() <= 48,
            "cold wiring record grew past its cache budget: {} B",
            std::mem::size_of::<ChanCold>()
        );
    }
}
