//! Structure-of-arrays storage for in-flight channel envelopes.
//!
//! The engine's channels formerly queued `Envelope { depart, arrival,
//! touch, payload }` structs AoS-style in one `VecDeque`. Every occupancy
//! query walks departure times and every pull walks arrival times — with
//! AoS layout each step drags the payload (often a pooled `Vec`) through
//! cache for no reason. Splitting the envelope into parallel lanes keeps
//! those scans dense in the two `u64` time lanes, and lets a drain move
//! payloads as one batched `VecDeque::drain` splice into the engine's
//! reusable scratch buffer instead of a pop-per-message loop.
//!
//! Invariants (guaranteed by the engine, checked by the property tests in
//! `tests/prop_calendar.rs` against an AoS reference model):
//!
//! * lanes advance in lockstep — one `push` appends to all four;
//! * `depart` and `arrival` are monotone non-decreasing front to back
//!   (each departure is scheduled at `now.max(last_depart + service)`,
//!   each arrival at `coalesce(..).max(last_arrival)`), which is what
//!   makes prefix drains and prefix occupancy counts sound.

use std::collections::VecDeque;

use crate::util::Nanos;

/// Summary of one batched drain: how many envelopes left the queue and
/// the largest touch-counter value they carried.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DrainSummary {
    pub drained: u64,
    pub max_touch: Option<u64>,
}

/// Parallel per-field queues for one channel's in-flight envelopes.
#[derive(Clone, Debug, Default)]
pub struct EnvelopeLanes<M> {
    depart: VecDeque<Nanos>,
    arrival: VecDeque<Nanos>,
    touch: VecDeque<u64>,
    payload: VecDeque<M>,
}

impl<M> EnvelopeLanes<M> {
    pub fn new() -> Self {
        Self {
            depart: VecDeque::new(),
            arrival: VecDeque::new(),
            touch: VecDeque::new(),
            payload: VecDeque::new(),
        }
    }

    /// Envelopes currently in flight or awaiting pull.
    pub fn len(&self) -> usize {
        self.payload.len()
    }

    pub fn is_empty(&self) -> bool {
        self.payload.is_empty()
    }

    /// Append one envelope to every lane.
    pub fn push(&mut self, depart: Nanos, arrival: Nanos, touch: u64, payload: M) {
        self.depart.push_back(depart);
        self.arrival.push_back(arrival);
        self.touch.push_back(touch);
        self.payload.push_back(payload);
    }

    /// Departure time of the `i`-th queued envelope (front = oldest).
    /// Occupancy tracking steps through this lane only — the payload
    /// lane stays cold.
    pub fn depart_at(&self, i: usize) -> Nanos {
        self.depart[i]
    }

    /// Arrival time of the oldest queued envelope, if any.
    pub fn front_arrival(&self) -> Option<Nanos> {
        self.arrival.front().copied()
    }

    /// Number of queued envelopes with `arrival <= now` — a prefix, by
    /// the arrival-monotonicity invariant. Scans only the arrival lane.
    pub fn arrived_prefix(&self, now: Nanos) -> usize {
        self.arrival.iter().take_while(|&&a| a <= now).count()
    }

    /// Iterate queued envelopes front (oldest) to back as
    /// `(depart, arrival, touch, &payload)` tuples — checkpoint
    /// serialization reads lanes through this; restore rebuilds them with
    /// [`EnvelopeLanes::push`] in the same order, preserving the
    /// monotonicity invariants by construction.
    pub fn iter(&self) -> impl Iterator<Item = (Nanos, Nanos, u64, &M)> + '_ {
        self.depart
            .iter()
            .zip(&self.arrival)
            .zip(&self.touch)
            .zip(&self.payload)
            .map(|(((&d, &a), &t), p)| (d, a, t, p))
    }

    /// Heap bytes currently reserved by the four lanes (capacity, not
    /// length — what the allocator actually holds). Feeds the engine's
    /// [`memory_footprint`](crate::sim::Engine::memory_footprint)
    /// bytes/proc accounting; the payload term uses `size_of::<M>()`, so
    /// payload-owned heap (e.g. pooled `Vec`s) is not visible here.
    pub fn heap_bytes(&self) -> usize {
        self.depart.capacity() * std::mem::size_of::<Nanos>()
            + self.arrival.capacity() * std::mem::size_of::<Nanos>()
            + self.touch.capacity() * std::mem::size_of::<u64>()
            + self.payload.capacity() * std::mem::size_of::<M>()
    }

    /// Drain every envelope with `arrival <= now`, appending payloads to
    /// `out` in push order, and report the count plus the maximum touch
    /// value among the drained prefix (`None` when nothing had arrived).
    pub fn drain_arrived_into(&mut self, now: Nanos, out: &mut Vec<M>) -> DrainSummary {
        let k = self.arrived_prefix(now);
        if k == 0 {
            return DrainSummary {
                drained: 0,
                max_touch: None,
            };
        }
        self.depart.drain(..k);
        self.arrival.drain(..k);
        let max_touch = self.touch.drain(..k).max();
        out.extend(self.payload.drain(..k));
        DrainSummary {
            drained: k as u64,
            max_touch,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn laden() -> EnvelopeLanes<u32> {
        let mut l = EnvelopeLanes::new();
        l.push(10, 15, 0, 100);
        l.push(20, 25, 3, 101);
        l.push(30, 42, 1, 102);
        l
    }

    #[test]
    fn lanes_advance_in_lockstep() {
        let l = laden();
        assert_eq!(l.len(), 3);
        assert_eq!(l.depart_at(0), 10);
        assert_eq!(l.depart_at(2), 30);
        assert_eq!(l.front_arrival(), Some(15));
    }

    #[test]
    fn arrived_prefix_counts_only_arrivals_due() {
        let l = laden();
        assert_eq!(l.arrived_prefix(14), 0);
        assert_eq!(l.arrived_prefix(15), 1);
        assert_eq!(l.arrived_prefix(41), 2);
        assert_eq!(l.arrived_prefix(1000), 3);
    }

    #[test]
    fn drain_moves_prefix_in_push_order_with_max_touch() {
        let mut l = laden();
        let mut out = Vec::new();
        let s = l.drain_arrived_into(25, &mut out);
        assert_eq!(s, DrainSummary { drained: 2, max_touch: Some(3) });
        assert_eq!(out, vec![100, 101]);
        assert_eq!(l.len(), 1);
        assert_eq!(l.front_arrival(), Some(42));
        // Remaining envelope keeps its lanes aligned.
        assert_eq!(l.depart_at(0), 30);
    }

    #[test]
    fn drain_nothing_arrived_is_a_noop() {
        let mut l = laden();
        let mut out = vec![7u32];
        let s = l.drain_arrived_into(5, &mut out);
        assert_eq!(s.drained, 0);
        assert_eq!(s.max_touch, None);
        assert_eq!(out, vec![7], "out must be untouched");
        assert_eq!(l.len(), 3);
    }

    #[test]
    fn iter_reads_all_lanes_in_push_order() {
        let l = laden();
        let got: Vec<(Nanos, Nanos, u64, u32)> =
            l.iter().map(|(d, a, t, &p)| (d, a, t, p)).collect();
        assert_eq!(got, vec![(10, 15, 0, 100), (20, 25, 3, 101), (30, 42, 1, 102)]);
        // Rebuilding via push reproduces the lanes exactly.
        let mut rebuilt = EnvelopeLanes::new();
        for (d, a, t, &p) in l.iter() {
            rebuilt.push(d, a, t, p);
        }
        let again: Vec<(Nanos, Nanos, u64, u32)> =
            rebuilt.iter().map(|(d, a, t, &p)| (d, a, t, p)).collect();
        assert_eq!(got, again);
    }

    #[test]
    fn drain_appends_rather_than_overwrites() {
        let mut l = laden();
        let mut out = vec![1u32];
        l.drain_arrived_into(1000, &mut out);
        assert_eq!(out, vec![1, 100, 101, 102]);
        assert!(l.is_empty());
    }
}
