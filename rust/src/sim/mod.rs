//! Discrete-event simulation of a multi-node allocation (DESIGN.md §2).

pub mod calendar;
pub mod checkpoint;
pub mod engine;
pub mod lanes;
pub mod modes;
pub mod policy;

pub use calendar::{CalendarQueue, HeapScheduler, SchedKind, Scheduler};
pub use checkpoint::{Persist, SnapError, SnapReader, SnapWriter, SNAP_MAGIC, SNAP_VERSION};
pub use engine::{
    healthy_profiles, heterogeneous_profiles, profiles_with_faulty, CommBackend, ContentionModel,
    Engine, MemoryFootprint, SimConfig, SimResult, StepPath,
};
pub use lanes::{DrainSummary, EnvelopeLanes};
pub use modes::{AsyncMode, ModeTiming};
pub use policy::{AdaptiveConfig, AdaptiveController, Discipline, PolicyConfig};
