//! Discrete-event simulation of a multi-node allocation (DESIGN.md §2).

pub mod engine;
pub mod modes;

pub use engine::{
    healthy_profiles, heterogeneous_profiles, profiles_with_faulty, CommBackend, ContentionModel, Engine, SimConfig,
    SimResult,
};
pub use modes::{AsyncMode, ModeTiming};
