//! Event schedulers for the discrete-event engine: binary heap and
//! calendar queue.
//!
//! The engine dispatches events in strict `(time, seq)` order, where `seq`
//! is a unique monotone tie-breaker assigned at scheduling time. Both
//! schedulers here implement exactly that total order, so swapping one for
//! the other is *bit-invisible* to the simulation — the golden-signature
//! and property tests enforce it (`tests/prop_calendar.rs`,
//! `tests/integration_sim.rs`).
//!
//! * [`HeapScheduler`] — the reference `BinaryHeap` implementation:
//!   O(log n) per operation, no tuning, always correct.
//! * [`CalendarQueue`] — Brown's calendar queue (CACM 1988) specialized
//!   for the engine's near-uniform wake cadence: power-of-two-width time
//!   buckets, a rotating day cursor, and lazy power-of-two resizing keyed
//!   to load-factor thresholds. Amortized ~O(1) push/pop when bucket
//!   width tracks the observed inter-event gap, which resizing recomputes
//!   from queue contents — so cadence drift (barrier releases, QoS
//!   snapshots, 1024-proc fan-in) re-tunes the structure automatically.
//!
//! Selection is per-run via [`SchedKind`]: `EBCOMM_SCHED=heap` /
//! `EBCOMM_SCHED=calendar` (the default) for A/B comparison, or set
//! [`crate::sim::SimConfig::sched`] programmatically.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::Nanos;

/// Priority-queue interface the engine schedules events through.
///
/// Entries are dequeued in ascending `(t, seq)` order. Callers must hand
/// every push a `seq` unique within the queue's lifetime (the engine's
/// monotone event counter), which makes the order total and deterministic
/// regardless of the backing structure.
pub trait Scheduler<T> {
    /// Enqueue `item` at time `t` with tie-breaker `seq`.
    fn push(&mut self, t: Nanos, seq: u64, item: T);
    /// Enqueue every item of `batch` at the single time `t`, draining the
    /// vector; the `i`-th drained item takes seq `first_seq + i`.
    ///
    /// Semantically identical to the push loop the default impl is —
    /// pinned by batch-vs-loop property schedules in
    /// `tests/prop_calendar.rs` — but overridable so a bucketed scheduler
    /// can splice the whole block in one operation. This is the barrier
    /// release's shape: N wakes at one release timestamp with
    /// consecutive fresh seqs, the per-wake cost of which dominates
    /// 1024+-proc synchronous sweeps.
    ///
    /// Contract (the engine's monotone event counter satisfies it): the
    /// batch's seqs `first_seq..first_seq + batch.len()` are fresh —
    /// strictly greater than every seq previously pushed — so the block
    /// occupies contiguous positions in `(t, seq)` order.
    fn push_batch_same_t(&mut self, t: Nanos, first_seq: u64, batch: &mut Vec<T>) {
        for (i, item) in batch.drain(..).enumerate() {
            self.push(t, first_seq + i as u64, item);
        }
    }
    /// Dequeue the entry with the smallest `(t, seq)`.
    fn pop(&mut self) -> Option<(Nanos, u64, T)>;
    /// Entries currently queued.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Heap bytes currently reserved by the queue's backing storage
    /// (capacity, not length). Defaults to 0 so ad-hoc test schedulers
    /// need not account; both real schedulers override. Feeds the
    /// engine's bytes/proc memory accounting.
    fn heap_bytes(&self) -> usize {
        0
    }
}

/// Which scheduler backs the engine's event queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedKind {
    /// Reference `BinaryHeap` scheduler.
    Heap,
    /// Bucketed calendar-queue scheduler (default).
    Calendar,
}

impl SchedKind {
    /// Read `EBCOMM_SCHED` (`"heap"` or `"calendar"`); unset selects the
    /// calendar queue. Any other value panics — a silently mis-spelled
    /// A/B run (`EBCOMM_SCHED=haep`) would compare a scheduler against
    /// itself and wrongly rule bugs out.
    pub fn from_env() -> Self {
        match std::env::var("EBCOMM_SCHED") {
            Ok(v) if v.eq_ignore_ascii_case("heap") => SchedKind::Heap,
            Ok(v) if v.eq_ignore_ascii_case("calendar") => SchedKind::Calendar,
            Ok(v) => panic!("EBCOMM_SCHED must be \"heap\" or \"calendar\", got {v:?}"),
            Err(_) => SchedKind::Calendar,
        }
    }

    /// Instantiate the selected scheduler.
    pub fn make<T: Send + 'static>(self) -> Box<dyn Scheduler<T> + Send> {
        match self {
            SchedKind::Heap => Box::new(HeapScheduler::new()),
            SchedKind::Calendar => Box::new(CalendarQueue::new()),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SchedKind::Heap => "heap",
            SchedKind::Calendar => "calendar",
        }
    }
}

/// Min-heap entry ordered by `(t, seq)` only, freeing the payload from an
/// `Ord` bound (the former engine heap ordered whole `(t, seq, Ev)`
/// tuples, but unique `seq` means the payload never decided a
/// comparison).
struct HeapEntry<T> {
    t: Nanos,
    seq: u64,
    item: T,
}

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}

impl<T> Eq for HeapEntry<T> {}

impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for HeapEntry<T> {
    /// Reversed so `BinaryHeap`'s max-heap pops the minimum `(t, seq)`.
    fn cmp(&self, other: &Self) -> Ordering {
        (other.t, other.seq).cmp(&(self.t, self.seq))
    }
}

/// The reference scheduler: `BinaryHeap`, O(log n) per operation.
pub struct HeapScheduler<T> {
    heap: BinaryHeap<HeapEntry<T>>,
}

impl<T> HeapScheduler<T> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
        }
    }
}

impl<T> Default for HeapScheduler<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Scheduler<T> for HeapScheduler<T> {
    fn push(&mut self, t: Nanos, seq: u64, item: T) {
        self.heap.push(HeapEntry { t, seq, item });
    }

    fn pop(&mut self) -> Option<(Nanos, u64, T)> {
        self.heap.pop().map(|e| (e.t, e.seq, e.item))
    }

    fn len(&self) -> usize {
        self.heap.len()
    }

    fn heap_bytes(&self) -> usize {
        self.heap.capacity() * std::mem::size_of::<HeapEntry<T>>()
    }
}

/// Floor of the calendar's bucket-count ladder.
const MIN_BUCKETS: usize = 4;
/// Bucket widths are clamped to `[2^0, 2^MAX_WIDTH_LOG2]` ns.
const MAX_WIDTH_LOG2: u32 = 40;

/// Bucketed calendar-queue scheduler.
///
/// Events live in `buckets[day(t) & mask]` where `day(t) = t >>
/// width_log2`; each bucket is kept sorted *descending* by `(t, seq)` so
/// the bucket minimum pops from the back in O(1). A `cur_day` cursor
/// tracks the earliest day any queued event can occupy; `pop` walks at
/// most one full lap of buckets looking for an event in the cursor's day,
/// then falls back to a direct minimum search (events far beyond one
/// bucket lap, e.g. QoS snapshot openings scheduled upfront).
///
/// Buckets are `VecDeque`s, not `Vec`s, deliberately: a barrier release
/// pushes one wake per process at a single timestamp with ascending
/// seqs, and in a descending bucket each of those lands at the *front* —
/// O(1) on a deque, but an O(bucket) shift-per-push (O(P²) per barrier)
/// on a vector.
pub struct CalendarQueue<T> {
    buckets: Vec<std::collections::VecDeque<(Nanos, u64, T)>>,
    /// log2 of the bucket width in ns.
    width_log2: u32,
    len: usize,
    /// Earliest day (t >> width_log2) that may hold a queued event.
    cur_day: u64,
}

impl<T> CalendarQueue<T> {
    /// Default sizing: 16 buckets of 2^13 ns ≈ 8 µs, the simstep cadence
    /// of the graph-coloring workload. Resizing re-derives both from live
    /// contents, so the initial guess only matters for the first handful
    /// of events.
    pub fn new() -> Self {
        Self::with_params(16, 13)
    }

    /// Explicit initial geometry (tests drive resize boundaries with
    /// deliberately bad guesses). `nbuckets` must be a power of two.
    pub fn with_params(nbuckets: usize, width_log2: u32) -> Self {
        assert!(
            nbuckets.is_power_of_two() && nbuckets >= 1,
            "bucket count must be a power of two"
        );
        assert!(width_log2 <= MAX_WIDTH_LOG2);
        Self {
            buckets: (0..nbuckets)
                .map(|_| std::collections::VecDeque::new())
                .collect(),
            width_log2,
            len: 0,
            cur_day: 0,
        }
    }

    #[inline]
    fn day(&self, t: Nanos) -> u64 {
        t >> self.width_log2
    }

    /// Insert into the home bucket, keeping it sorted descending by
    /// `(t, seq)`. `seq` uniqueness makes the search key distinct, so
    /// `binary_search_by` never reports an exact match to worry about.
    fn insert(&mut self, t: Nanos, seq: u64, item: T) {
        let day = self.day(t);
        let mask = self.buckets.len() - 1;
        let b = &mut self.buckets[(day & mask as u64) as usize];
        let idx = match b.binary_search_by(|probe| (t, seq).cmp(&(probe.0, probe.1))) {
            Ok(i) | Err(i) => i,
        };
        b.insert(idx, (t, seq, item));
    }

    /// Rebuild with `new_count` buckets, re-deriving the bucket width
    /// from the observed event span (≈ mean inter-event gap, rounded to a
    /// power of two). Deterministic: depends only on queue contents.
    fn resize(&mut self, new_count: usize) {
        let entries: Vec<(Nanos, u64, T)> = self
            .buckets
            .iter_mut()
            .flat_map(|b| std::mem::take(b))
            .collect();
        debug_assert_eq!(entries.len(), self.len);
        if self.len >= 2 {
            let tmin = entries.iter().map(|e| e.0).min().unwrap();
            let tmax = entries.iter().map(|e| e.0).max().unwrap();
            let span = tmax - tmin;
            if span > 0 {
                let gap = (span / self.len as u64).max(1);
                // bit length of `gap`: buckets at least as wide as the
                // mean gap keep ~one event per live bucket.
                let bits = u64::BITS - gap.leading_zeros();
                self.width_log2 = bits.min(MAX_WIDTH_LOG2);
            }
        }
        self.buckets = (0..new_count)
            .map(|_| std::collections::VecDeque::new())
            .collect();
        let mut min_key: Option<(Nanos, u64)> = None;
        for (t, seq, item) in entries {
            if min_key.map(|k| (t, seq) < k).unwrap_or(true) {
                min_key = Some((t, seq));
            }
            self.insert(t, seq, item);
        }
        if let Some((t, _)) = min_key {
            self.cur_day = self.day(t);
        }
    }

    /// Shrink check shared by both pop paths.
    fn maybe_shrink(&mut self) {
        let nb = self.buckets.len();
        if self.len < nb / 2 && nb > MIN_BUCKETS {
            self.resize(nb / 2);
        }
    }
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Scheduler<T> for CalendarQueue<T> {
    fn push(&mut self, t: Nanos, seq: u64, item: T) {
        let day = self.day(t);
        // Maintain the invariant cur_day <= day(min event): an empty
        // queue re-anchors the cursor, and a push into the past (the
        // engine never does this, but the property tests do) rewinds it.
        if self.len == 0 || day < self.cur_day {
            self.cur_day = day;
        }
        self.insert(t, seq, item);
        self.len += 1;
        if self.len > 2 * self.buckets.len() {
            self.resize(self.buckets.len() * 2);
        }
    }

    /// One bucket lookup + one binary search + one block splice for the
    /// whole batch, instead of N independent pushes (N searches, N
    /// threshold checks, and up to log N incremental grow-resizes during
    /// a 1024-proc release burst). The freshness contract means every
    /// batch key is strictly greater than any queued key at time `t`, so
    /// the block is contiguous in the bucket's descending order; any
    /// grow happens once, straight to the final bucket count.
    ///
    /// Dequeue order is identical to the default's push loop whatever
    /// the intermediate geometry — order depends only on `(t, seq)` —
    /// pinned by batch-vs-loop schedules in `tests/prop_calendar.rs` and
    /// pre-validated in `python/batch_push_model_fuzz.py`.
    fn push_batch_same_t(&mut self, t: Nanos, first_seq: u64, batch: &mut Vec<T>) {
        let k = batch.len();
        if k == 0 {
            return;
        }
        let day = self.day(t);
        if self.len == 0 || day < self.cur_day {
            self.cur_day = day;
        }
        let mask = (self.buckets.len() - 1) as u64;
        let b = &mut self.buckets[(day & mask) as usize];
        // The block's largest key leads it in the descending bucket.
        let hi = (t, first_seq + (k as u64 - 1));
        let idx = match b.binary_search_by(|probe| hi.cmp(&(probe.0, probe.1))) {
            Ok(i) | Err(i) => i,
        };
        // Splice: rotate the insertion point to the front, push the
        // batch (ascending drain ⇒ descending block), rotate back —
        // O(min(idx, len-idx) + k), with idx = 0 in the common barrier
        // case (the release is the bucket's latest timestamp).
        b.rotate_left(idx);
        for (i, item) in batch.drain(..).enumerate() {
            b.push_front((t, first_seq + i as u64, item));
        }
        b.rotate_right(idx);
        self.len += k;
        if self.len > 2 * self.buckets.len() {
            let mut target = self.buckets.len();
            while self.len > 2 * target {
                target *= 2;
            }
            self.resize(target);
        }
    }

    fn pop(&mut self) -> Option<(Nanos, u64, T)> {
        if self.len == 0 {
            return None;
        }
        let nb = self.buckets.len();
        let mask = (nb - 1) as u64;
        // Lap scan: the first day with a queued event is the minimum day
        // (cursor invariant), and all events of one day share a bucket
        // whose back holds that day's (t, seq) minimum.
        for _ in 0..nb {
            let day = self.cur_day;
            let width = self.width_log2;
            let b = &mut self.buckets[(day & mask) as usize];
            if let Some(&(t, _, _)) = b.back() {
                if t >> width == day {
                    let e = b.pop_back().unwrap();
                    self.len -= 1;
                    self.maybe_shrink();
                    return Some(e);
                }
            }
            self.cur_day += 1;
        }
        // Every event is > one lap ahead of the cursor: direct search for
        // the global minimum, then re-anchor the cursor on its day.
        let mut best: Option<(usize, Nanos, u64)> = None;
        for (i, b) in self.buckets.iter().enumerate() {
            if let Some(&(t, seq, _)) = b.back() {
                if best.map(|(_, bt, bs)| (t, seq) < (bt, bs)).unwrap_or(true) {
                    best = Some((i, t, seq));
                }
            }
        }
        let (i, t, _) = best.expect("len > 0 but no bucket holds an event");
        self.cur_day = t >> self.width_log2;
        let e = self.buckets[i].pop_back().unwrap();
        self.len -= 1;
        self.maybe_shrink();
        Some(e)
    }

    fn len(&self) -> usize {
        self.len
    }

    fn heap_bytes(&self) -> usize {
        let per_entry = std::mem::size_of::<(Nanos, u64, T)>();
        self.buckets.capacity()
            * std::mem::size_of::<std::collections::VecDeque<(Nanos, u64, T)>>()
            + self
                .buckets
                .iter()
                .map(|b| b.capacity() * per_entry)
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain a scheduler fully.
    fn drain<T, S: Scheduler<T>>(s: &mut S) -> Vec<(Nanos, u64, T)> {
        let mut out = Vec::new();
        while let Some(e) = s.pop() {
            out.push(e);
        }
        out
    }

    #[test]
    fn heap_pops_in_time_seq_order() {
        let mut s = HeapScheduler::new();
        s.push(30, 0, 'a');
        s.push(10, 1, 'b');
        s.push(10, 2, 'c');
        s.push(20, 3, 'd');
        let order: Vec<_> = drain(&mut s).into_iter().map(|e| e.2).collect();
        assert_eq!(order, vec!['b', 'c', 'd', 'a']);
    }

    #[test]
    fn calendar_pops_in_time_seq_order() {
        let mut s = CalendarQueue::new();
        s.push(30, 0, 'a');
        s.push(10, 1, 'b');
        s.push(10, 2, 'c');
        s.push(20, 3, 'd');
        let order: Vec<_> = drain(&mut s).into_iter().map(|e| e.2).collect();
        assert_eq!(order, vec!['b', 'c', 'd', 'a']);
    }

    #[test]
    fn tie_breaks_by_seq_regardless_of_push_order() {
        for kind in [SchedKind::Heap, SchedKind::Calendar] {
            let mut s = kind.make::<u64>();
            // Same timestamp, seqs pushed out of order.
            for &seq in &[5u64, 1, 4, 2, 3, 0] {
                s.push(77, seq, seq);
            }
            let mut got = Vec::new();
            while let Some((t, seq, item)) = s.pop() {
                assert_eq!(t, 77);
                assert_eq!(seq, item);
                got.push(seq);
            }
            assert_eq!(got, vec![0, 1, 2, 3, 4, 5], "{}", kind.label());
        }
    }

    #[test]
    fn calendar_matches_heap_through_resize_boundaries() {
        // Deliberately tiny initial geometry: growth triggers at 9
        // entries, shrink on the way back down.
        let mut cal = CalendarQueue::with_params(4, 0);
        let mut heap = HeapScheduler::new();
        for seq in 0..1000u64 {
            let t = (seq * 37) % 4096;
            cal.push(t, seq, seq);
            heap.push(t, seq, seq);
        }
        assert_eq!(cal.len(), 1000);
        let c = drain(&mut cal);
        let h = drain(&mut heap);
        assert_eq!(c, h);
        assert!(cal.is_empty());
    }

    #[test]
    fn far_future_events_survive_lap_fallback() {
        // One event far beyond a full bucket lap forces the direct-search
        // path.
        let mut s = CalendarQueue::with_params(4, 0);
        s.push(1 << 30, 0, 'z');
        s.push(3, 1, 'a');
        assert_eq!(s.pop(), Some((3, 1, 'a')));
        assert_eq!(s.pop(), Some((1 << 30, 0, 'z')));
        assert_eq!(s.pop(), None);
    }

    #[test]
    fn push_into_past_rewinds_cursor() {
        let mut s = CalendarQueue::with_params(4, 2);
        s.push(1000, 0, 0u8);
        assert_eq!(s.pop(), Some((1000, 0, 0)));
        // Cursor now sits at day(1000); a past push must still pop first.
        s.push(2000, 1, 1);
        s.push(5, 2, 2);
        assert_eq!(s.pop(), Some((5, 2, 2)));
        assert_eq!(s.pop(), Some((2000, 1, 1)));
    }

    #[test]
    fn empty_queue_reanchors_on_next_push() {
        let mut s = CalendarQueue::with_params(4, 0);
        s.push(9999, 0, ());
        assert!(s.pop().is_some());
        assert!(s.pop().is_none());
        // Re-anchor far behind the previous cursor position.
        s.push(1, 1, ());
        assert_eq!(s.pop(), Some((1, 1, ())));
    }

    #[test]
    fn barrier_release_burst_pops_in_seq_order() {
        // A barrier release schedules every process at one timestamp with
        // ascending seqs — the front-insert pattern the deque buckets
        // exist for. 4096 same-t pushes, then interleave with later work.
        let mut cal = CalendarQueue::new();
        let mut heap = HeapScheduler::new();
        let release: Nanos = 123_456_789;
        for seq in 0..4096u64 {
            cal.push(release, seq, seq);
            heap.push(release, seq, seq);
        }
        for seq in 4096..4160u64 {
            cal.push(release + (seq % 7) * 1000, seq, seq);
            heap.push(release + (seq % 7) * 1000, seq, seq);
        }
        assert_eq!(drain(&mut cal), drain(&mut heap));
    }

    #[test]
    fn interleaved_steady_state_cadence() {
        // The engine's actual usage pattern: pop one wake, push the next
        // a near-constant stride ahead.
        let mut cal = CalendarQueue::new();
        let mut heap = HeapScheduler::new();
        let mut seq = 0u64;
        for p in 0..64u64 {
            cal.push(p * 13, seq, p);
            heap.push(p * 13, seq, p);
            seq += 1;
        }
        for i in 0..10_000 {
            let a = cal.pop().unwrap();
            let b = heap.pop().unwrap();
            assert_eq!(a, b, "iter {i}");
            let (t, _, p) = a;
            let next = t + 8_000 + (p * 97) % 512;
            cal.push(next, seq, p);
            heap.push(next, seq, p);
            seq += 1;
        }
        assert_eq!(drain(&mut cal), drain(&mut heap));
    }

    #[test]
    fn sparse_control_events_amid_dense_wakes() {
        // The fault-scenario compile pattern: a handful of far-future
        // control events (fault windows, snapshot edges) pushed up front,
        // then dense steady-cadence wakes churning beneath them. The
        // controls must surface in exact (t, seq) order on both
        // schedulers despite living many bucket-laps ahead of the cursor.
        let mut cal = CalendarQueue::new();
        let mut heap = HeapScheduler::new();
        let mut seq = 0u64;
        // Sparse controls: 40 ms, 70 ms, 10 s (a dormant fault).
        for &t in &[40_000_000u64, 70_000_000, 10_000_000_000] {
            cal.push(t, seq, u64::MAX - t);
            heap.push(t, seq, u64::MAX - t);
            seq += 1;
        }
        // Dense wakes: 64 processes at ~8 µs cadence.
        for p in 0..64u64 {
            cal.push(p * 13, seq, p);
            heap.push(p * 13, seq, p);
            seq += 1;
        }
        for i in 0..20_000 {
            let a = cal.pop().unwrap();
            let b = heap.pop().unwrap();
            assert_eq!(a, b, "iter {i}");
            let (t, _, p) = a;
            if p < 64 {
                // Only process wakes reschedule; controls are one-shot.
                let next = t + 8_000 + (p * 97) % 512;
                cal.push(next, seq, p);
                heap.push(next, seq, p);
                seq += 1;
            }
        }
        assert_eq!(drain(&mut cal), drain(&mut heap));
    }

    /// Batch and loop must yield identical pop streams on identically
    /// pre-loaded queues — including a splice into the middle of a
    /// bucket that already holds a same-time smaller seq *and* a later
    /// timestamp (width 1 ns, 4 buckets: 100/104/108 all map to bucket
    /// 0, so the t=104 block lands at interior index 1).
    #[test]
    fn batch_push_matches_loop_mid_bucket_splice() {
        let mut batched = CalendarQueue::with_params(4, 0);
        let mut looped = CalendarQueue::with_params(4, 0);
        let mut heap = HeapScheduler::new();
        for (seq, t) in [100u64, 104, 108].into_iter().enumerate() {
            batched.push(t, seq as u64, seq as u64);
            looped.push(t, seq as u64, seq as u64);
            heap.push(t, seq as u64, seq as u64);
        }
        let mut block: Vec<u64> = vec![10, 11, 12];
        batched.push_batch_same_t(104, 10, &mut block);
        assert!(block.is_empty(), "batch must drain its input");
        for seq in 10u64..13 {
            looped.push(104, seq, seq);
            heap.push(104, seq, seq);
        }
        let b = drain(&mut batched);
        assert_eq!(b, drain(&mut looped));
        assert_eq!(b, drain(&mut heap));
    }

    /// A batch pushed behind the day cursor (after the queue emptied far
    /// in the future) must rewind it, exactly like a single past push.
    #[test]
    fn batch_push_rewinds_cursor_like_single_push() {
        let mut cal = CalendarQueue::with_params(4, 2);
        let mut heap = HeapScheduler::new();
        cal.push(4000, 0, 0u64);
        heap.push(4000, 0, 0u64);
        assert_eq!(cal.pop(), heap.pop());
        let mut block: Vec<u64> = vec![1, 2, 3, 4];
        cal.push_batch_same_t(8, 1, &mut block);
        heap.push_batch_same_t(8, 1, &mut vec![1, 2, 3, 4]);
        cal.push(4000, 5, 5);
        heap.push(4000, 5, 5);
        assert_eq!(drain(&mut cal), drain(&mut heap));
    }

    /// A 4096-wake release from a small queue grows in ONE resize to the
    /// final geometry, and still drains in exact (t, seq) order.
    #[test]
    fn giant_batch_resizes_once_to_target() {
        let mut cal = CalendarQueue::with_params(4, 0);
        let mut heap = HeapScheduler::new();
        let mut block: Vec<u64> = (0..4096).collect();
        let mut block_ref: Vec<u64> = (0..4096).collect();
        cal.push_batch_same_t(123_456, 0, &mut block);
        heap.push_batch_same_t(123_456, 0, &mut block_ref);
        assert_eq!(cal.len(), 4096);
        assert_eq!(drain(&mut cal), drain(&mut heap));
    }

    /// Empty batches are no-ops; the trait-object path (the engine's
    /// view) dispatches the override for the calendar and the push loop
    /// for the heap.
    #[test]
    fn batch_push_through_trait_objects() {
        for kind in [SchedKind::Heap, SchedKind::Calendar] {
            let mut s = kind.make::<u64>();
            s.push_batch_same_t(50, 0, &mut Vec::new());
            assert!(s.is_empty(), "{}", kind.label());
            s.push(99, 0, 0);
            let mut block: Vec<u64> = vec![1, 2, 3];
            s.push_batch_same_t(70, 1, &mut block);
            let mut got = Vec::new();
            while let Some((t, seq, item)) = s.pop() {
                assert_eq!(seq, item);
                got.push((t, seq));
            }
            assert_eq!(
                got,
                vec![(70, 1), (70, 2), (70, 3), (99, 0)],
                "{}",
                kind.label()
            );
        }
    }

    #[test]
    fn sched_kind_env_selection() {
        // from_env defaults to calendar when unset or unrecognized; the
        // explicit constructors cover both arms without touching the
        // process environment (tests run concurrently).
        assert_eq!(SchedKind::Calendar.label(), "calendar");
        assert_eq!(SchedKind::Heap.label(), "heap");
        let mut s = SchedKind::Heap.make::<()>();
        s.push(1, 0, ());
        assert_eq!(s.len(), 1);
        let mut c = SchedKind::Calendar.make::<()>();
        c.push(1, 0, ());
        assert_eq!(c.pop(), Some((1, 0, ())));
    }
}
