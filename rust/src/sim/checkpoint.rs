//! Versioned binary checkpoint format for mid-run engine snapshots.
//!
//! A checkpoint captures the *complete* deterministic state of a
//! [`crate::sim::Engine`] between events — scheduler contents, in-flight
//! envelopes, per-process RNG streams and clocks, fault-overlay state,
//! QoS windows — such that `checkpoint at t` + `restore` + `run to end`
//! is **bit-identical** to the straight-through run (same QoS values,
//! same counters, same golden signature). The property holds under both
//! scheduler kinds because dequeue order depends only on `(t, seq)` keys.
//!
//! The format is deliberately hand-rolled (the offline environment ships
//! no serde): a `b"EBCK"` magic, a `u32` format version, then a flat
//! little-endian field stream written and read in one fixed order by the
//! [`Persist`] implementations. There is no per-field tagging — version
//! bumps are the only compatibility mechanism (see
//! `rust/tests/golden/README.md` for the bump rules). Floats round-trip
//! via `to_bits`/`from_bits` so restores are bitwise, not approximate.
//!
//! Only the discrete-event engine is checkpointable. Real-thread
//! (`exec/`) runs are deliberately not: their state lives in OS thread
//! schedules and wall-clock time, which cannot be serialized or
//! deterministically resumed.

use crate::conduit::{CounterTranche, StageLatencies};
use crate::faults::{
    FaultEvent, FaultKind, FaultScenario, LinkFault, NodeFault, ScenarioPhase,
};
use crate::net::{LinkModel, NodeProfile, PlacementKind};
use crate::qos::{
    CardinalitySketch, QosObservation, QosStorage, QuantileSketch, SketchQos, SnapshotSchedule,
    SnapshotWindow,
};
use crate::sim::calendar::SchedKind;
use crate::sim::modes::{AsyncMode, ModeTiming};
use crate::workloads::{ChannelSpec, TilePartition};

/// Format magic: identifies a byte blob as an engine checkpoint.
pub const SNAP_MAGIC: [u8; 4] = *b"EBCK";

/// Current checkpoint format version. Bump on ANY change to what is
/// serialized or in what order (there is no per-field tagging to absorb
/// drift); readers reject other versions outright.
///
/// History: v1 = dense per-channel records with baked link parameters;
/// v2 = hot/cold channel split (interned link table, `dst_in_idx`,
/// per-channel `purged` counter), `StepPath` in the config, incremental
/// snapshot cache (`window_open`/`open_t`/`open_phase`/per-channel
/// cached observations/`touched` flags) replacing the open-observation
/// pair list; v3 = `QosStorage` in the config plus sketch-backed QoS
/// state (per-metric quantile sketches, per-phase split, HLL distinct
/// counters) after the window list — sketch-mode resumes are bitwise
/// because the sketches are pure integer state; v4 = per-channel
/// communication policy (`PolicyConfig` + optional `LinkModel` override
/// in the config, adaptive-controller state — escalation flags,
/// per-channel baselines, hysteresis streaks, controller RNG — after
/// the engine's membership state). Barrier-membership vectors are
/// derived at restore, so adaptive resumes stay bitwise too.
pub const SNAP_VERSION: u32 = 4;

/// Why a checkpoint blob could not be decoded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapError {
    /// Byte stream ended before the expected field.
    Truncated,
    /// Leading bytes are not [`SNAP_MAGIC`] — not a checkpoint at all.
    BadMagic,
    /// Checkpoint written by a different format version.
    BadVersion(u32),
    /// Structurally invalid content (bad enum tag, absurd length, …).
    Corrupt(&'static str),
}

impl std::fmt::Display for SnapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapError::Truncated => write!(f, "checkpoint truncated"),
            SnapError::BadMagic => write!(f, "not an engine checkpoint (bad magic)"),
            SnapError::BadVersion(v) => {
                write!(f, "checkpoint version {v} != supported {SNAP_VERSION}")
            }
            SnapError::Corrupt(what) => write!(f, "checkpoint corrupt: {what}"),
        }
    }
}

impl std::error::Error for SnapError {}

/// Append-only little-endian byte sink. [`SnapWriter::new`] stamps the
/// magic + version header.
pub struct SnapWriter {
    buf: Vec<u8>,
}

impl SnapWriter {
    pub fn new() -> Self {
        let mut w = Self { buf: Vec::with_capacity(4096) };
        w.buf.extend_from_slice(&SNAP_MAGIC);
        w.buf.extend_from_slice(&SNAP_VERSION.to_le_bytes());
        w
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

impl Default for SnapWriter {
    fn default() -> Self {
        Self::new()
    }
}

/// Cursor over a checkpoint byte blob. [`SnapReader::new`] validates the
/// magic + version header before any field is read.
pub struct SnapReader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> SnapReader<'a> {
    pub fn new(buf: &'a [u8]) -> Result<Self, SnapError> {
        let mut r = Self { buf, at: 0 };
        let magic = r.take(4)?;
        if magic != SNAP_MAGIC {
            return Err(SnapError::BadMagic);
        }
        let version = r.get_u32()?;
        if version != SNAP_VERSION {
            return Err(SnapError::BadVersion(version));
        }
        Ok(r)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapError> {
        let end = self.at.checked_add(n).ok_or(SnapError::Truncated)?;
        if end > self.buf.len() {
            return Err(SnapError::Truncated);
        }
        let s = &self.buf[self.at..end];
        self.at = end;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8, SnapError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32, SnapError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64, SnapError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8], SnapError> {
        let n = self.get_u64()?;
        let n = usize::try_from(n).map_err(|_| SnapError::Corrupt("byte run too long"))?;
        self.take(n)
    }

    /// All header + fields consumed? Engine restore asserts this so a
    /// trailing-garbage blob fails loudly instead of loading.
    pub fn is_exhausted(&self) -> bool {
        self.at == self.buf.len()
    }
}

/// A type with a fixed binary checkpoint encoding. `save` and `load`
/// must agree exactly on field order; round-trips are bitwise.
pub trait Persist: Sized {
    fn save(&self, w: &mut SnapWriter);
    fn load(r: &mut SnapReader) -> Result<Self, SnapError>;
}

// ---- primitives ----------------------------------------------------

impl Persist for u8 {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u8(*self);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        r.get_u8()
    }
}

impl Persist for u32 {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u32(*self);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        r.get_u32()
    }
}

impl Persist for u64 {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u64(*self);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        r.get_u64()
    }
}

impl Persist for usize {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u64(*self as u64);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        usize::try_from(r.get_u64()?).map_err(|_| SnapError::Corrupt("usize overflow"))
    }
}

impl Persist for f64 {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u64(self.to_bits());
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(f64::from_bits(r.get_u64()?))
    }
}

impl Persist for bool {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u8(*self as u8);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        match r.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(SnapError::Corrupt("bool tag")),
        }
    }
}

impl Persist for [u64; 4] {
    fn save(&self, w: &mut SnapWriter) {
        for v in self {
            w.put_u64(*v);
        }
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok([r.get_u64()?, r.get_u64()?, r.get_u64()?, r.get_u64()?])
    }
}

impl<T: Persist> Persist for Vec<T> {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u64(self.len() as u64);
        for x in self {
            x.save(w);
        }
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        let n = usize::try_from(r.get_u64()?)
            .map_err(|_| SnapError::Corrupt("vec too long"))?;
        // A corrupt length would otherwise make with_capacity abort on
        // OOM before the element loop hits Truncated.
        let mut v = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            v.push(T::load(r)?);
        }
        Ok(v)
    }
}

impl<T: Persist> Persist for Option<T> {
    fn save(&self, w: &mut SnapWriter) {
        match self {
            None => w.put_u8(0),
            Some(x) => {
                w.put_u8(1);
                x.save(w);
            }
        }
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        match r.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::load(r)?)),
            _ => Err(SnapError::Corrupt("option tag")),
        }
    }
}

impl<A: Persist, B: Persist> Persist for (A, B) {
    fn save(&self, w: &mut SnapWriter) {
        self.0.save(w);
        self.1.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok((A::load(r)?, B::load(r)?))
    }
}

impl<A: Persist, B: Persist, C: Persist> Persist for (A, B, C) {
    fn save(&self, w: &mut SnapWriter) {
        self.0.save(w);
        self.1.save(w);
        self.2.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok((A::load(r)?, B::load(r)?, C::load(r)?))
    }
}

// ---- fault-subsystem types ------------------------------------------

impl Persist for ScenarioPhase {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u64(self.bits());
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        let bits = r.get_u64()?;
        // No public from-bits constructor: rebuild by unioning singles.
        Ok((0..64)
            .filter(|&i| bits & (1u64 << i) != 0)
            .fold(ScenarioPhase::QUIESCENT, |p, i| {
                p.union(ScenarioPhase::single(i))
            }))
    }
}

impl Persist for NodeFault {
    fn save(&self, w: &mut SnapWriter) {
        self.speed_factor.save(w);
        self.jitter_sigma.save(w);
        self.stall_mean_ns.save(w);
        self.latency_factor.save(w);
        self.extra_drop_prob.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            speed_factor: f64::load(r)?,
            jitter_sigma: f64::load(r)?,
            stall_mean_ns: f64::load(r)?,
            latency_factor: f64::load(r)?,
            extra_drop_prob: f64::load(r)?,
        })
    }
}

impl Persist for LinkFault {
    fn save(&self, w: &mut SnapWriter) {
        self.latency_factor.save(w);
        self.extra_drop_prob.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            latency_factor: f64::load(r)?,
            extra_drop_prob: f64::load(r)?,
        })
    }
}

impl Persist for FaultKind {
    fn save(&self, w: &mut SnapWriter) {
        match self {
            FaultKind::DegradeNode { node, fault } => {
                w.put_u8(0);
                node.save(w);
                fault.save(w);
            }
            FaultKind::RestoreNode { node } => {
                w.put_u8(1);
                node.save(w);
            }
            FaultKind::FlapLink { node, on_for, off_for, fault } => {
                w.put_u8(2);
                node.save(w);
                on_for.save(w);
                off_for.save(w);
                fault.save(w);
            }
            FaultKind::CongestionStorm { fault } => {
                w.put_u8(3);
                fault.save(w);
            }
            FaultKind::PartitionCliques { cliques, cut } => {
                w.put_u8(4);
                cliques.save(w);
                cut.save(w);
            }
            FaultKind::Heal => w.put_u8(5),
            FaultKind::ProcLeave { proc } => {
                w.put_u8(6);
                proc.save(w);
            }
            FaultKind::ProcJoin { proc } => {
                w.put_u8(7);
                proc.save(w);
            }
        }
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(match r.get_u8()? {
            0 => FaultKind::DegradeNode {
                node: usize::load(r)?,
                fault: NodeFault::load(r)?,
            },
            1 => FaultKind::RestoreNode { node: usize::load(r)? },
            2 => FaultKind::FlapLink {
                node: usize::load(r)?,
                on_for: u64::load(r)?,
                off_for: u64::load(r)?,
                fault: LinkFault::load(r)?,
            },
            3 => FaultKind::CongestionStorm { fault: LinkFault::load(r)? },
            4 => FaultKind::PartitionCliques {
                cliques: usize::load(r)?,
                cut: LinkFault::load(r)?,
            },
            5 => FaultKind::Heal,
            6 => FaultKind::ProcLeave { proc: usize::load(r)? },
            7 => FaultKind::ProcJoin { proc: usize::load(r)? },
            _ => return Err(SnapError::Corrupt("fault-kind tag")),
        })
    }
}

impl Persist for FaultEvent {
    fn save(&self, w: &mut SnapWriter) {
        self.start.save(w);
        self.duration.save(w);
        self.kind.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            start: u64::load(r)?,
            duration: u64::load(r)?,
            kind: FaultKind::load(r)?,
        })
    }
}

impl Persist for FaultScenario {
    fn save(&self, w: &mut SnapWriter) {
        self.events.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self { events: Vec::load(r)? })
    }
}

// ---- net / topology types -------------------------------------------

impl Persist for NodeProfile {
    fn save(&self, w: &mut SnapWriter) {
        self.speed_factor.save(w);
        self.jitter_sigma.save(w);
        self.stall_prob.save(w);
        self.stall_mean_ns.save(w);
        self.latency_factor.save(w);
        self.extra_drop_prob.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            speed_factor: f64::load(r)?,
            jitter_sigma: f64::load(r)?,
            stall_prob: f64::load(r)?,
            stall_mean_ns: f64::load(r)?,
            latency_factor: f64::load(r)?,
            extra_drop_prob: f64::load(r)?,
        })
    }
}

impl Persist for LinkModel {
    fn save(&self, w: &mut SnapWriter) {
        self.wire_median_ns.save(w);
        self.wire_sigma.save(w);
        self.service_ns.save(w);
        self.coalesce_ns.save(w);
        self.base_drop_prob.save(w);
        self.spike_prob.save(w);
        self.spike_mean_ns.save(w);
        self.send_overhead_ns.save(w);
        self.pull_overhead_ns.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            wire_median_ns: f64::load(r)?,
            wire_sigma: f64::load(r)?,
            service_ns: f64::load(r)?,
            coalesce_ns: u64::load(r)?,
            base_drop_prob: f64::load(r)?,
            spike_prob: f64::load(r)?,
            spike_mean_ns: f64::load(r)?,
            send_overhead_ns: f64::load(r)?,
            pull_overhead_ns: f64::load(r)?,
        })
    }
}

impl Persist for PlacementKind {
    fn save(&self, w: &mut SnapWriter) {
        match self {
            PlacementKind::SingleNode => w.put_u8(0),
            PlacementKind::OnePerNode => w.put_u8(1),
            PlacementKind::PerNode(k) => {
                w.put_u8(2);
                k.save(w);
            }
        }
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(match r.get_u8()? {
            0 => PlacementKind::SingleNode,
            1 => PlacementKind::OnePerNode,
            2 => PlacementKind::PerNode(usize::load(r)?),
            _ => return Err(SnapError::Corrupt("placement tag")),
        })
    }
}

// ---- qos types -------------------------------------------------------

impl Persist for CounterTranche {
    fn save(&self, w: &mut SnapWriter) {
        self.attempted_sends.save(w);
        self.successful_sends.save(w);
        self.pull_attempts.save(w);
        self.laden_pulls.save(w);
        self.messages_received.save(w);
        self.touches.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            attempted_sends: u64::load(r)?,
            successful_sends: u64::load(r)?,
            pull_attempts: u64::load(r)?,
            laden_pulls: u64::load(r)?,
            messages_received: u64::load(r)?,
            touches: u64::load(r)?,
        })
    }
}

impl Persist for QosObservation {
    fn save(&self, w: &mut SnapWriter) {
        self.counters.save(w);
        self.update_count.save(w);
        self.wall_ns.save(w);
        self.phase.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            counters: CounterTranche::load(r)?,
            update_count: u64::load(r)?,
            wall_ns: u64::load(r)?,
            phase: ScenarioPhase::load(r)?,
        })
    }
}

impl Persist for SnapshotWindow {
    fn save(&self, w: &mut SnapWriter) {
        self.inlet_before.save(w);
        self.inlet_after.save(w);
        self.outlet_before.save(w);
        self.outlet_after.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            inlet_before: QosObservation::load(r)?,
            inlet_after: QosObservation::load(r)?,
            outlet_before: QosObservation::load(r)?,
            outlet_after: QosObservation::load(r)?,
        })
    }
}

impl Persist for SnapshotSchedule {
    fn save(&self, w: &mut SnapWriter) {
        self.first_at.save(w);
        self.every.save(w);
        self.window.save(w);
        self.count.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            first_at: u64::load(r)?,
            every: u64::load(r)?,
            window: u64::load(r)?,
            count: usize::load(r)?,
        })
    }
}

impl Persist for QosStorage {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u8(match self {
            QosStorage::Exact => 0,
            QosStorage::Sketch => 1,
        });
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        match r.get_u8()? {
            0 => Ok(QosStorage::Exact),
            1 => Ok(QosStorage::Sketch),
            _ => Err(SnapError::Corrupt("qos-storage tag")),
        }
    }
}

/// Sparse encoding: the ledger counters, then `(bucket, count)` pairs in
/// ascending bucket order — checkpoint size scales with *occupied*
/// buckets, not the fixed array.
impl Persist for QuantileSketch {
    fn save(&self, w: &mut SnapWriter) {
        self.zero.save(w);
        self.skipped.save(w);
        self.total.save(w);
        let nonzero = self.counts.iter().filter(|&&c| c != 0).count();
        nonzero.save(w);
        for (i, &c) in self.counts.iter().enumerate() {
            if c != 0 {
                w.put_u32(i as u32);
                c.save(w);
            }
        }
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        let zero = u64::load(r)?;
        let skipped = u64::load(r)?;
        let total = u64::load(r)?;
        let n = usize::load(r)?;
        let mut pairs = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let idx = r.get_u32()?;
            let c = u64::load(r)?;
            pairs.push((idx, c));
        }
        QuantileSketch::from_parts(zero, skipped, total, &pairs).map_err(SnapError::Corrupt)
    }
}

impl Persist for CardinalitySketch {
    fn save(&self, w: &mut SnapWriter) {
        self.regs.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        CardinalitySketch::from_registers(Vec::<u8>::load(r)?).map_err(SnapError::Corrupt)
    }
}

fn load_metric_sketches(r: &mut SnapReader) -> Result<[QuantileSketch; 5], SnapError> {
    Ok([
        QuantileSketch::load(r)?,
        QuantileSketch::load(r)?,
        QuantileSketch::load(r)?,
        QuantileSketch::load(r)?,
        QuantileSketch::load(r)?,
    ])
}

impl Persist for SketchQos {
    fn save(&self, w: &mut SnapWriter) {
        self.windows.save(w);
        for sk in &self.overall {
            sk.save(w);
        }
        self.by_phase.len().save(w);
        for (bits, set) in &self.by_phase {
            bits.save(w);
            for sk in set {
                sk.save(w);
            }
        }
        self.distinct_channels.save(w);
        self.distinct_senders.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        let windows = u64::load(r)?;
        let overall = load_metric_sketches(r)?;
        let n_phases = usize::load(r)?;
        // One entry per *observed* scenario-event subset; even a long
        // chaos timeline transitions through a tiny fraction of the
        // possible subsets, and every entry needs at least one window.
        if n_phases as u64 > windows {
            return Err(SnapError::Corrupt("sketch phase count"));
        }
        let mut by_phase = Vec::with_capacity(n_phases.min(4096));
        let mut prev: Option<u64> = None;
        for _ in 0..n_phases {
            let bits = u64::load(r)?;
            if prev.is_some_and(|p| bits <= p) {
                return Err(SnapError::Corrupt("sketch phase order"));
            }
            prev = Some(bits);
            by_phase.push((bits, load_metric_sketches(r)?));
        }
        Ok(Self {
            windows,
            overall,
            by_phase,
            distinct_channels: CardinalitySketch::load(r)?,
            distinct_senders: CardinalitySketch::load(r)?,
        })
    }
}

/// Four stage sketches in message-path order (serialize, enqueue,
/// transport, drain) — the multiprocess executor's wire blob for
/// shipping per-process latency breakdowns to the coordinator.
impl Persist for StageLatencies {
    fn save(&self, w: &mut SnapWriter) {
        self.serialize.save(w);
        self.enqueue.save(w);
        self.transport.save(w);
        self.drain.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            serialize: QuantileSketch::load(r)?,
            enqueue: QuantileSketch::load(r)?,
            transport: QuantileSketch::load(r)?,
            drain: QuantileSketch::load(r)?,
        })
    }
}

// ---- sim / workload types --------------------------------------------

impl Persist for AsyncMode {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u8(self.index() as u8);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        AsyncMode::from_index(r.get_u8()? as usize).ok_or(SnapError::Corrupt("async-mode tag"))
    }
}

impl Persist for ModeTiming {
    fn save(&self, w: &mut SnapWriter) {
        self.rolling_chunk.save(w);
        self.fixed_epoch.save(w);
        self.fixed_skew_max.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            rolling_chunk: u64::load(r)?,
            fixed_epoch: u64::load(r)?,
            fixed_skew_max: u64::load(r)?,
        })
    }
}

impl Persist for SchedKind {
    fn save(&self, w: &mut SnapWriter) {
        w.put_u8(match self {
            SchedKind::Heap => 0,
            SchedKind::Calendar => 1,
        });
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(match r.get_u8()? {
            0 => SchedKind::Heap,
            1 => SchedKind::Calendar,
            _ => return Err(SnapError::Corrupt("sched-kind tag")),
        })
    }
}

impl Persist for ChannelSpec {
    fn save(&self, w: &mut SnapWriter) {
        self.peer.save(w);
        self.layer.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            peer: usize::load(r)?,
            layer: usize::load(r)?,
        })
    }
}

impl Persist for TilePartition {
    fn save(&self, w: &mut SnapWriter) {
        self.mesh_rows.save(w);
        self.mesh_cols.save(w);
        self.tile_h.save(w);
        self.tile_w.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            mesh_rows: usize::load(r)?,
            mesh_cols: usize::load(r)?,
            tile_h: usize::load(r)?,
            tile_w: usize::load(r)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip<T: Persist + std::fmt::Debug + PartialEq>(x: T) {
        let mut w = SnapWriter::new();
        x.save(&mut w);
        let bytes = w.finish();
        let mut r = SnapReader::new(&bytes).unwrap();
        let y = T::load(&mut r).unwrap();
        assert_eq!(x, y);
        assert!(r.is_exhausted());
    }

    #[test]
    fn header_validated() {
        let empty = SnapWriter::new().finish();
        assert!(SnapReader::new(&empty).is_ok());
        assert_eq!(SnapReader::new(b"NOPE1234"), err_kind(SnapError::BadMagic));
        assert_eq!(SnapReader::new(b"EB"), err_kind(SnapError::Truncated));
        let mut bad_ver = empty.clone();
        bad_ver[4..8].copy_from_slice(&99u32.to_le_bytes());
        assert_eq!(
            SnapReader::new(&bad_ver),
            err_kind(SnapError::BadVersion(99))
        );
    }

    /// Blobs from previous format generations are rejected outright —
    /// v2 restructured the channel section (hot/cold split, interned
    /// links) relative to v1, and v3 appended the `QosStorage` config
    /// field + sketch section, so neither older stream can be decoded
    /// field-by-field.
    #[test]
    fn prior_version_rejected() {
        for old in [1u32, 2] {
            let mut blob = SnapWriter::new().finish();
            blob[4..8].copy_from_slice(&old.to_le_bytes());
            assert_eq!(SnapReader::new(&blob), err_kind(SnapError::BadVersion(old)));
        }
    }

    fn err_kind<T>(e: SnapError) -> Result<T, SnapError> {
        Err(e)
    }

    impl<'a> std::fmt::Debug for SnapReader<'a> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "SnapReader(at {}/{})", self.at, self.buf.len())
        }
    }

    impl<'a> PartialEq for SnapReader<'a> {
        fn eq(&self, _: &Self) -> bool {
            false // only used for asserting Err cases above
        }
    }

    #[test]
    fn primitive_round_trips() {
        round_trip(0u8);
        round_trip(255u8);
        round_trip(u32::MAX);
        round_trip(u64::MAX);
        round_trip(usize::MAX);
        round_trip(true);
        round_trip(false);
        round_trip(-0.0f64); // bitwise: -0.0 stays -0.0
        round_trip(f64::INFINITY);
        round_trip(vec![1u64, 2, 3]);
        round_trip(Vec::<u64>::new());
        round_trip(Some(7usize));
        round_trip(None::<u64>);
        round_trip((1u64, 2usize));
        round_trip((1u64, 2usize, true));
        round_trip([1u64, 2, 3, 4]);
    }

    #[test]
    fn nan_round_trips_bitwise() {
        let x = f64::NAN;
        let mut w = SnapWriter::new();
        x.save(&mut w);
        let bytes = w.finish();
        let mut r = SnapReader::new(&bytes).unwrap();
        let y = f64::load(&mut r).unwrap();
        assert_eq!(x.to_bits(), y.to_bits());
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = SnapWriter::new();
        vec![1u64, 2, 3].save(&mut w);
        let bytes = w.finish();
        // Cut the blob mid-element.
        let cut = &bytes[..bytes.len() - 4];
        let mut r = SnapReader::new(cut).unwrap();
        assert_eq!(Vec::<u64>::load(&mut r), Err(SnapError::Truncated));
    }

    #[test]
    fn bad_tags_are_corrupt() {
        let mut w = SnapWriter::new();
        w.put_u8(9);
        let bytes = w.finish();
        let mut r = SnapReader::new(&bytes).unwrap();
        assert_eq!(bool::load(&mut r), Err(SnapError::Corrupt("bool tag")));
        let mut r = SnapReader::new(&bytes).unwrap();
        assert_eq!(
            FaultKind::load(&mut r),
            Err(SnapError::Corrupt("fault-kind tag"))
        );
    }

    #[test]
    fn domain_round_trips() {
        round_trip(ScenarioPhase::single(0).union(ScenarioPhase::single(63)));
        round_trip(ScenarioPhase::QUIESCENT);
        round_trip(NodeFault::lac417());
        round_trip(LinkFault::storm());
        round_trip(FaultKind::DegradeNode { node: 3, fault: NodeFault::fail_stop() });
        round_trip(FaultKind::FlapLink {
            node: 1,
            on_for: 5,
            off_for: 7,
            fault: LinkFault::flap(),
        });
        round_trip(FaultKind::Heal);
        round_trip(FaultKind::ProcLeave { proc: 17 });
        round_trip(FaultKind::ProcJoin { proc: 17 });
        round_trip(FaultScenario::leave_join_storm(64, 100, 1_000, 8));
        round_trip(FaultScenario::default());
        round_trip(NodeProfile::healthy());
        round_trip(CounterTranche {
            attempted_sends: 1,
            successful_sends: 2,
            pull_attempts: 3,
            laden_pulls: 4,
            messages_received: 5,
            touches: 6,
        });
        round_trip(ChannelSpec { peer: 9, layer: 102 });
        round_trip(TilePartition {
            mesh_rows: 8,
            mesh_cols: 8,
            tile_h: 4,
            tile_w: 4,
        });
        round_trip(QosStorage::Exact);
        round_trip(QosStorage::Sketch);
    }

    #[test]
    fn sketch_round_trips_bitwise() {
        let mut q = QuantileSketch::new();
        for x in [0.0, 1.5e6, 1.5e6, 2.0e9, 0.25, f64::NAN, -1.0] {
            q.insert(x);
        }
        round_trip(q);
        round_trip(QuantileSketch::new());

        let mut c = CardinalitySketch::new();
        for i in 0..500u64 {
            c.insert(i);
        }
        round_trip(c);
        round_trip(CardinalitySketch::new());

        let mut sq = SketchQos::new();
        let obs = |updates, wall, phase| QosObservation {
            counters: CounterTranche::default(),
            update_count: updates,
            wall_ns: wall,
            phase,
        };
        let storm = ScenarioPhase::single(5);
        sq.absorb_window(
            &SnapshotWindow {
                inlet_before: obs(0, 0, ScenarioPhase::QUIESCENT),
                inlet_after: obs(12, 2_000, ScenarioPhase::QUIESCENT),
                outlet_before: obs(0, 0, ScenarioPhase::QUIESCENT),
                outlet_after: obs(12, 2_000, ScenarioPhase::QUIESCENT),
            },
            3,
            1,
        );
        sq.absorb_window(
            &SnapshotWindow {
                inlet_before: obs(0, 0, ScenarioPhase::QUIESCENT),
                inlet_after: obs(7, 9_000, storm),
                outlet_before: obs(0, 0, ScenarioPhase::QUIESCENT),
                outlet_after: obs(7, 9_000, storm),
            },
            4,
            2,
        );
        round_trip(sq);
        round_trip(SketchQos::new());
    }

    #[test]
    fn enum_like_round_trips() {
        // These types lack PartialEq; compare re-serialized bytes.
        fn bytes_of<T: Persist>(x: &T) -> Vec<u8> {
            let mut w = SnapWriter::new();
            x.save(&mut w);
            w.finish()
        }
        for mode in AsyncMode::ALL {
            let b = bytes_of(&mode);
            let mut r = SnapReader::new(&b).unwrap();
            let back = AsyncMode::load(&mut r).unwrap();
            assert_eq!(mode, back);
        }
        for kind in [SchedKind::Heap, SchedKind::Calendar] {
            let b = bytes_of(&kind);
            let mut r = SnapReader::new(&b).unwrap();
            let back = SchedKind::load(&mut r).unwrap();
            assert_eq!(bytes_of(&back), b);
        }
        for p in [
            PlacementKind::SingleNode,
            PlacementKind::OnePerNode,
            PlacementKind::PerNode(4),
        ] {
            let b = bytes_of(&p);
            let mut r = SnapReader::new(&b).unwrap();
            let back = PlacementKind::load(&mut r).unwrap();
            assert_eq!(bytes_of(&back), b);
        }
        for x in [
            LinkModel::internode(),
            LinkModel::intranode(),
            LinkModel::thread_shared_memory(),
        ] {
            let b = bytes_of(&x);
            let mut r = SnapReader::new(&b).unwrap();
            let back = LinkModel::load(&mut r).unwrap();
            assert_eq!(bytes_of(&back), b);
        }
        let sched = SnapshotSchedule::paper();
        let b = bytes_of(&sched);
        let mut r = SnapReader::new(&b).unwrap();
        let back = SnapshotSchedule::load(&mut r).unwrap();
        assert_eq!(bytes_of(&back), b);
        let t = ModeTiming::graph_coloring(64);
        let b = bytes_of(&t);
        let mut r = SnapReader::new(&b).unwrap();
        let back = ModeTiming::load(&mut r).unwrap();
        assert_eq!(bytes_of(&back), b);
    }
}
