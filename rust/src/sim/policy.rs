//! Per-channel communication policy.
//!
//! The paper's modes 0–4 pick one global discipline for every channel in
//! the allocation. [`PolicyConfig`] generalizes that: `Uniform(mode)` is
//! the paper's setup (and is bit-identical to the pre-policy engine),
//! while `Adaptive` layers a deterministic controller on top of a
//! barriered base mode that flips *individual channels* to best-effort
//! when their windowed QoS degrades, and back when the link heals.
//!
//! The controller is driven entirely by the engine's incremental QoS
//! capture: every snapshot-window close feeds each channel's windowed
//! metrics to [`AdaptiveController::observe_window`]. Decisions are a
//! pure function of (windowed QoS, seeded RNG stream), with zero
//! wall-clock input — adaptive runs are exactly as deterministic and
//! golden-eligible as static ones, and the whole controller state rides
//! the `EBCK` checkpoint so checkpoint-at-t + resume stays bit-identical.
//!
//! Escalation is per-channel and *relative to the channel's own
//! baseline*: the first finite delivery-latency window a channel
//! observes becomes its reference cost, making the trigger
//! topology-aware (an internode link is judged against internode cost,
//! an intranode link against intranode cost) in the spirit of Bienz et
//! al.'s node-aware P2P models.

use crate::conduit::Discipline;
use crate::qos::QosMetrics;
use crate::sim::checkpoint::{Persist, SnapError, SnapReader, SnapWriter};
use crate::sim::modes::AsyncMode;
use crate::util::rng::{Rng, Xoshiro256};

impl Discipline {
    /// The discipline every channel gets under a uniform global mode.
    /// (Defined here rather than in `conduit` so the transport layer
    /// stays independent of the simulation's mode vocabulary.)
    pub fn uniform(mode: AsyncMode) -> Discipline {
        if !mode.communicates() {
            Discipline::Muted
        } else if mode.uses_barriers() {
            Discipline::Barriered
        } else {
            Discipline::BestEffort
        }
    }
}

/// Per-run communication policy.
#[derive(Clone, Copy, Debug)]
pub enum PolicyConfig {
    /// Every channel follows one global [`AsyncMode`] — the paper's
    /// setup. Bit-identical to the pre-policy engine for all five modes.
    Uniform(AsyncMode),
    /// A barriered base mode plus the adaptive per-channel controller.
    Adaptive(AdaptiveConfig),
}

impl PolicyConfig {
    /// The global mode the engine's send/pull/barrier cadence is built
    /// on. `SimConfig::mode` always equals this; the adaptive layer only
    /// subtracts channels (and their endpoints) from the barrier set.
    pub fn base_mode(&self) -> AsyncMode {
        match self {
            PolicyConfig::Uniform(m) => *m,
            PolicyConfig::Adaptive(a) => a.base,
        }
    }

    pub fn is_adaptive(&self) -> bool {
        matches!(self, PolicyConfig::Adaptive(_))
    }

    pub fn label(&self) -> String {
        match self {
            PolicyConfig::Uniform(m) => m.label().to_string(),
            PolicyConfig::Adaptive(a) => format!("adaptive (base {})", a.base.label()),
        }
    }
}

/// Thresholds and hysteresis for the adaptive controller.
///
/// A channel escalates to best-effort when a closed QoS window shows
/// either delivery latency above `latency_ratio` × the channel's own
/// baseline, delivery failure above `failure_threshold`, or coagulation
/// (clumpiness) above `clumpiness_threshold`. It heals back to the
/// barriered base discipline only after `heal_windows` consecutive
/// healthy windows plus a small seeded jitter (anti-flap hysteresis).
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveConfig {
    /// The barriered mode healthy channels follow. Best-effort or
    /// no-comm bases are legal but inert (nothing to escalate from).
    pub base: AsyncMode,
    /// Escalate when windowed delivery latency exceeds this multiple of
    /// the channel's first observed (baseline) latency.
    pub latency_ratio: f64,
    /// Escalate when windowed delivery failure rate exceeds this.
    pub failure_threshold: f64,
    /// Escalate when windowed delivery clumpiness exceeds this.
    /// Defaults close to 1.0 so only pathological coagulation fires.
    pub clumpiness_threshold: f64,
    /// Consecutive healthy windows required before a channel heals.
    pub heal_windows: u32,
    /// Up to this many extra healthy windows (drawn per escalation from
    /// the controller's seeded stream) are demanded on top, so a clique
    /// of channels does not flap back in lockstep.
    pub heal_jitter: u32,
    /// Salt XORed into the run seed for the controller's RNG stream.
    pub salt: u64,
}

impl AdaptiveConfig {
    /// Defaults tuned for the fault-scenario families: a lac417-style
    /// degrade multiplies link latency 4–10×, so a 2.5× baseline ratio
    /// fires on it without tripping on healthy lognormal jitter; the
    /// failure bar sits well above best-effort's quiescent drop floor.
    pub fn paper_defaults(base: AsyncMode) -> Self {
        Self {
            base,
            latency_ratio: 2.5,
            failure_threshold: 0.25,
            clumpiness_threshold: 0.995,
            heal_windows: 2,
            heal_jitter: 2,
            salt: 0xADA7_71FE,
        }
    }
}

/// Runtime state of the adaptive controller: one escalation flag plus
/// hysteresis bookkeeping per channel. Lives in the engine only when the
/// policy is [`PolicyConfig::Adaptive`]; uniform runs allocate nothing.
#[derive(Clone, Debug)]
pub struct AdaptiveController {
    cfg: AdaptiveConfig,
    /// Channel is currently best-effort (escalated out of the barrier set).
    escalated: Vec<bool>,
    /// First finite windowed delivery latency seen per channel
    /// (NaN = not yet calibrated).
    baseline_latency: Vec<f64>,
    /// Consecutive healthy windows while escalated.
    healthy_streak: Vec<u32>,
    /// Healthy windows demanded before this escalation heals.
    heal_target: Vec<u32>,
    rng: Xoshiro256,
    /// Lifetime escalations (channel flips to best-effort).
    pub flips: u64,
    /// Lifetime heals (channel returns to the barriered base).
    pub heals: u64,
}

impl AdaptiveController {
    pub fn new(cfg: AdaptiveConfig, n_channels: usize, run_seed: u64) -> Self {
        Self {
            cfg,
            escalated: vec![false; n_channels],
            baseline_latency: vec![f64::NAN; n_channels],
            healthy_streak: vec![0; n_channels],
            heal_target: vec![0; n_channels],
            rng: Xoshiro256::new(run_seed ^ cfg.salt),
            flips: 0,
            heals: 0,
        }
    }

    pub fn config(&self) -> &AdaptiveConfig {
        &self.cfg
    }

    pub fn n_channels(&self) -> usize {
        self.escalated.len()
    }

    /// Is this channel currently escalated to best-effort?
    pub fn escalated(&self, cid: usize) -> bool {
        self.escalated[cid]
    }

    pub fn escalated_count(&self) -> usize {
        self.escalated.iter().filter(|e| **e).count()
    }

    /// Feed one closed QoS window for channel `cid`. Returns true when
    /// the channel's discipline changed (caller must recompute the
    /// barrier membership).
    pub fn observe_window(&mut self, cid: usize, m: &QosMetrics) -> bool {
        let lat = m.walltime_latency_ns;
        if self.baseline_latency[cid].is_nan() {
            // Calibration: the first window with real deliveries fixes
            // the channel's reference cost; no decision is taken yet.
            if lat.is_finite() && lat > 0.0 {
                self.baseline_latency[cid] = lat;
            }
            return false;
        }
        let slow = lat.is_finite() && lat > self.cfg.latency_ratio * self.baseline_latency[cid];
        let lossy = m.delivery_failure_rate.is_finite()
            && m.delivery_failure_rate > self.cfg.failure_threshold;
        let clumped = m.delivery_clumpiness.is_finite()
            && m.delivery_clumpiness > self.cfg.clumpiness_threshold;
        let degraded = slow || lossy || clumped;

        if !self.escalated[cid] {
            if degraded {
                self.escalated[cid] = true;
                self.healthy_streak[cid] = 0;
                self.heal_target[cid] = self.cfg.heal_windows
                    + self.rng.below(u64::from(self.cfg.heal_jitter) + 1) as u32;
                self.flips += 1;
                return true;
            }
            return false;
        }
        if degraded {
            self.healthy_streak[cid] = 0;
            return false;
        }
        self.healthy_streak[cid] += 1;
        if self.healthy_streak[cid] >= self.heal_target[cid] {
            self.escalated[cid] = false;
            self.healthy_streak[cid] = 0;
            self.heals += 1;
            return true;
        }
        false
    }
}

// ---- checkpoint encoding ---------------------------------------------

impl Persist for PolicyConfig {
    fn save(&self, w: &mut SnapWriter) {
        match self {
            PolicyConfig::Uniform(m) => {
                w.put_u8(0);
                m.save(w);
            }
            PolicyConfig::Adaptive(a) => {
                w.put_u8(1);
                a.save(w);
            }
        }
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        match r.get_u8()? {
            0 => Ok(PolicyConfig::Uniform(AsyncMode::load(r)?)),
            1 => Ok(PolicyConfig::Adaptive(AdaptiveConfig::load(r)?)),
            _ => Err(SnapError::Corrupt("policy tag")),
        }
    }
}

impl Persist for AdaptiveConfig {
    fn save(&self, w: &mut SnapWriter) {
        self.base.save(w);
        self.latency_ratio.save(w);
        self.failure_threshold.save(w);
        self.clumpiness_threshold.save(w);
        self.heal_windows.save(w);
        self.heal_jitter.save(w);
        self.salt.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        Ok(Self {
            base: AsyncMode::load(r)?,
            latency_ratio: f64::load(r)?,
            failure_threshold: f64::load(r)?,
            clumpiness_threshold: f64::load(r)?,
            heal_windows: u32::load(r)?,
            heal_jitter: u32::load(r)?,
            salt: u64::load(r)?,
        })
    }
}

impl Persist for AdaptiveController {
    fn save(&self, w: &mut SnapWriter) {
        self.cfg.save(w);
        self.escalated.save(w);
        self.baseline_latency.save(w);
        self.healthy_streak.save(w);
        self.heal_target.save(w);
        self.rng.state().save(w);
        self.flips.save(w);
        self.heals.save(w);
    }
    fn load(r: &mut SnapReader) -> Result<Self, SnapError> {
        let cfg = AdaptiveConfig::load(r)?;
        let escalated = Vec::<bool>::load(r)?;
        let baseline_latency = Vec::<f64>::load(r)?;
        let healthy_streak = Vec::<u32>::load(r)?;
        let heal_target = Vec::<u32>::load(r)?;
        let rng = Xoshiro256::from_state(<[u64; 4]>::load(r)?);
        let flips = u64::load(r)?;
        let heals = u64::load(r)?;
        let n = escalated.len();
        if baseline_latency.len() != n || healthy_streak.len() != n || heal_target.len() != n {
            return Err(SnapError::Corrupt("controller vector lengths disagree"));
        }
        Ok(Self {
            cfg,
            escalated,
            baseline_latency,
            healthy_streak,
            heal_target,
            rng,
            flips,
            heals,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(lat: f64, fail: f64, clump: f64) -> QosMetrics {
        QosMetrics {
            simstep_period_ns: 1000.0,
            simstep_latency: 1.0,
            walltime_latency_ns: lat,
            delivery_failure_rate: fail,
            delivery_clumpiness: clump,
        }
    }

    fn controller() -> AdaptiveController {
        AdaptiveController::new(AdaptiveConfig::paper_defaults(AsyncMode::Sync), 4, 0x5EED)
    }

    #[test]
    fn uniform_discipline_matches_mode_semantics() {
        assert_eq!(Discipline::uniform(AsyncMode::Sync), Discipline::Barriered);
        assert_eq!(
            Discipline::uniform(AsyncMode::RollingBarrier),
            Discipline::Barriered
        );
        assert_eq!(
            Discipline::uniform(AsyncMode::FixedBarrier),
            Discipline::Barriered
        );
        assert_eq!(
            Discipline::uniform(AsyncMode::BestEffort),
            Discipline::BestEffort
        );
        assert_eq!(Discipline::uniform(AsyncMode::NoComm), Discipline::Muted);
    }

    #[test]
    fn first_window_calibrates_without_deciding() {
        let mut c = controller();
        // Even an expensive first window only sets the baseline.
        assert!(!c.observe_window(0, &metrics(1e6, 0.0, 0.1)));
        assert!(!c.escalated(0));
        // Second window at 3x baseline escalates (ratio 2.5).
        assert!(c.observe_window(0, &metrics(3e6, 0.0, 0.1)));
        assert!(c.escalated(0));
        assert_eq!(c.flips, 1);
    }

    #[test]
    fn failure_rate_escalates_and_hysteresis_heals() {
        let mut c = controller();
        c.observe_window(1, &metrics(1000.0, 0.0, 0.1));
        assert!(c.observe_window(1, &metrics(1000.0, 0.9, 0.1)));
        assert!(c.escalated(1));
        // Healthy windows accumulate; a relapse resets the streak.
        let target = c.heal_target[1];
        assert!(target >= c.cfg.heal_windows);
        c.observe_window(1, &metrics(1000.0, 0.0, 0.1));
        c.observe_window(1, &metrics(1000.0, 0.9, 0.1)); // relapse
        assert_eq!(c.healthy_streak[1], 0);
        let mut healed = false;
        for _ in 0..target + 1 {
            healed = c.observe_window(1, &metrics(1000.0, 0.0, 0.1)) || healed;
        }
        assert!(healed && !c.escalated(1));
        assert_eq!(c.heals, 1);
    }

    #[test]
    fn nan_windows_are_quiet_not_degraded() {
        let mut c = controller();
        c.observe_window(2, &metrics(1000.0, 0.0, 0.1));
        // A window with no deliveries (NaN latency, zero failures) must
        // neither escalate nor count against a healthy link.
        assert!(!c.observe_window(2, &metrics(f64::NAN, 0.0, f64::NAN)));
        assert!(!c.escalated(2));
    }

    #[test]
    fn controller_persist_round_trips_bitwise() {
        let mut c = controller();
        c.observe_window(0, &metrics(1000.0, 0.0, 0.1));
        c.observe_window(0, &metrics(9000.0, 0.0, 0.1));
        c.observe_window(3, &metrics(500.0, 0.5, 0.2));
        let mut w = SnapWriter::new();
        c.save(&mut w);
        let bytes = w.finish();
        let mut r = SnapReader::new(&bytes).unwrap();
        let back = AdaptiveController::load(&mut r).unwrap();
        assert!(r.is_exhausted());
        let mut w2 = SnapWriter::new();
        back.save(&mut w2);
        assert_eq!(bytes, w2.finish());
        assert_eq!(back.escalated, c.escalated);
        assert_eq!(back.flips, c.flips);
    }

    #[test]
    fn identical_streams_make_identical_decisions() {
        let run = |seed: u64| {
            let mut c = AdaptiveController::new(
                AdaptiveConfig::paper_defaults(AsyncMode::Sync),
                8,
                seed,
            );
            let mut trace = Vec::new();
            for step in 0..64u64 {
                for cid in 0..8 {
                    let lat = 1000.0 + ((step * 7 + cid as u64) % 13) as f64 * 400.0;
                    let fail = if step % 11 == cid as u64 % 11 { 0.6 } else { 0.0 };
                    c.observe_window(cid, &metrics(lat, fail, 0.1));
                    trace.push(c.escalated(cid));
                }
            }
            (trace, c.flips, c.heals)
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42).0, run(43).0, "seed must matter somewhere");
    }
}
