//! Simple linear regression (OLS) with t-based inference.
//!
//! The paper analyses treatment effects on *means* with ordinary least
//! squares regression (§II-E): QoS response against log₄ processor count
//! (weak scaling, Figs. 4, 7, and supplementary) or against a 0/1-coded
//! dichotomous treatment (in which case OLS reduces to an independent
//! t-test). One predictor plus intercept is all the paper uses, so that is
//! all we implement — with exact closed-form estimates and standard
//! errors.

use super::dist::t_two_sided_p;

/// Fitted simple linear regression `y = intercept + slope * x`.
#[derive(Clone, Copy, Debug)]
pub struct OlsFit {
    pub intercept: f64,
    pub slope: f64,
    /// Standard error of the slope.
    pub slope_se: f64,
    /// t statistic for H0: slope = 0.
    pub t_stat: f64,
    /// Two-sided p-value for the slope.
    pub p_value: f64,
    /// 95 % confidence interval for the slope (normal-approx t critical).
    pub slope_ci95: (f64, f64),
    /// Coefficient of determination.
    pub r_squared: f64,
    /// Residual degrees of freedom (n − 2).
    pub df: f64,
    pub n: usize,
}

impl OlsFit {
    /// Significant at the paper's p < 0.05 level?
    pub fn significant(&self) -> bool {
        self.p_value < 0.05
    }
}

/// Fit `y ~ 1 + x` by least squares. Returns `None` when n < 3 or x has no
/// variance (fit undefined).
pub fn ols(x: &[f64], y: &[f64]) -> Option<OlsFit> {
    assert_eq!(x.len(), y.len(), "x/y length mismatch");
    let n = x.len();
    if n < 3 {
        return None;
    }
    let nf = n as f64;
    let mx = x.iter().sum::<f64>() / nf;
    let my = y.iter().sum::<f64>() / nf;
    let sxx: f64 = x.iter().map(|xi| (xi - mx) * (xi - mx)).sum();
    if sxx <= 0.0 {
        return None;
    }
    let sxy: f64 = x
        .iter()
        .zip(y)
        .map(|(xi, yi)| (xi - mx) * (yi - my))
        .sum();
    let slope = sxy / sxx;
    let intercept = my - slope * mx;

    let ss_res: f64 = x
        .iter()
        .zip(y)
        .map(|(xi, yi)| {
            let e = yi - (intercept + slope * xi);
            e * e
        })
        .sum();
    let ss_tot: f64 = y.iter().map(|yi| (yi - my) * (yi - my)).sum();
    let df = nf - 2.0;
    let sigma2 = ss_res / df;
    let slope_se = (sigma2 / sxx).sqrt();
    let t_stat = if slope_se > 0.0 {
        slope / slope_se
    } else if slope == 0.0 {
        0.0
    } else {
        f64::INFINITY * slope.signum()
    };
    let p_value = t_two_sided_p(t_stat, df);
    // 97.5 % t critical value via bisection on the CDF.
    let crit = t_critical_975(df);
    let r_squared = if ss_tot > 0.0 {
        1.0 - ss_res / ss_tot
    } else {
        1.0
    };
    Some(OlsFit {
        intercept,
        slope,
        slope_se,
        t_stat,
        p_value,
        slope_ci95: (slope - crit * slope_se, slope + crit * slope_se),
        r_squared,
        df,
        n,
    })
}

/// 97.5th percentile of the t distribution (for 95 % CIs), by bisection.
pub fn t_critical_975(df: f64) -> f64 {
    let (mut lo, mut hi) = (0.0f64, 1e3f64);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if super::dist::t_cdf(mid, df) < 0.975 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Independent two-sample t-test via 0/1-coded OLS (the paper's approach
/// for dichotomous treatments, §II-E: "this boils down to an independent
/// t-test").
pub fn two_sample_t(group0: &[f64], group1: &[f64]) -> Option<OlsFit> {
    let mut x = Vec::with_capacity(group0.len() + group1.len());
    let mut y = Vec::with_capacity(x.capacity());
    for &v in group0 {
        x.push(0.0);
        y.push(v);
    }
    for &v in group1 {
        x.push(1.0);
        y.push(v);
    }
    ols(&x, &y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Xoshiro256};

    #[test]
    fn exact_line_recovered() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|xi| 3.0 + 2.0 * xi).collect();
        let fit = ols(&x, &y).unwrap();
        assert!((fit.slope - 2.0).abs() < 1e-12);
        assert!((fit.intercept - 3.0).abs() < 1e-12);
        assert!((fit.r_squared - 1.0).abs() < 1e-12);
        assert!(fit.significant());
    }

    #[test]
    fn noisy_slope_inference() {
        let mut rng = Xoshiro256::new(99);
        let x: Vec<f64> = (0..200).map(|i| (i % 20) as f64).collect();
        let y: Vec<f64> = x.iter().map(|xi| 1.0 + 0.5 * xi + rng.normal(0.0, 1.0)).collect();
        let fit = ols(&x, &y).unwrap();
        assert!((fit.slope - 0.5).abs() < 0.05, "slope={}", fit.slope);
        assert!(fit.significant());
        assert!(fit.slope_ci95.0 < 0.5 && 0.5 < fit.slope_ci95.1);
    }

    #[test]
    fn null_slope_usually_insignificant() {
        let mut rng = Xoshiro256::new(7);
        let x: Vec<f64> = (0..100).map(|i| (i % 10) as f64).collect();
        let y: Vec<f64> = x.iter().map(|_| rng.normal(5.0, 1.0)).collect();
        let fit = ols(&x, &y).unwrap();
        assert!(fit.p_value > 0.01, "p={}", fit.p_value);
    }

    #[test]
    fn degenerate_inputs_rejected() {
        assert!(ols(&[1.0, 2.0], &[1.0, 2.0]).is_none());
        assert!(ols(&[3.0, 3.0, 3.0], &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn two_sample_t_detects_shift() {
        let g0: Vec<f64> = (0..30).map(|i| 10.0 + (i % 3) as f64 * 0.1).collect();
        let g1: Vec<f64> = (0..30).map(|i| 12.0 + (i % 3) as f64 * 0.1).collect();
        let fit = two_sample_t(&g0, &g1).unwrap();
        assert!((fit.slope - 2.0).abs() < 1e-6);
        assert!(fit.significant());
    }

    #[test]
    fn t_critical_reference() {
        // df=10 -> 2.228; df=30 -> 2.042; df large -> 1.96
        assert!((t_critical_975(10.0) - 2.228).abs() < 5e-3);
        assert!((t_critical_975(30.0) - 2.042).abs() < 5e-3);
        assert!((t_critical_975(1e6) - 1.96).abs() < 5e-3);
    }
}
