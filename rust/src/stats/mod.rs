//! Statistics used to render the paper's analyses: descriptive summaries,
//! bootstrap CIs (benchmark figures), OLS on means, quantile regression on
//! medians (§II-E).

pub mod descriptive;
pub mod dist;
pub mod ols;
pub mod quantile_reg;

pub use descriptive::{bootstrap_mean_ci95, mean, median, quantile, ConfidenceInterval, Summary};
pub use ols::{ols, two_sample_t, OlsFit};
pub use quantile_reg::{quantile_regression, QuantRegFit};
