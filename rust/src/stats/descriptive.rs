//! Descriptive statistics: means, medians, quantiles, bootstrap CIs.
//!
//! The paper reports bootstrapped 95 % confidence intervals on benchmark
//! bars (Figs. 2–3) and aggregates QoS snapshots per replicate by mean and
//! median (§II-E). All of that lives here.

use crate::util::rng::{Rng, Xoshiro256};

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample variance (n−1 denominator); 0 for fewer than two values.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Median (linear-interpolated between middle values for even n);
/// NaN-safe: NaNs are ignored. 0 for an empty slice.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Quantile `q` in `[0, 1]` via linear interpolation (type-7, the
/// numpy/R default). NaNs ignored; 0 for an empty slice.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let h = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (h - lo as f64) * (v[hi] - v[lo])
    }
}

/// A bootstrapped confidence interval around a point estimate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ConfidenceInterval {
    pub estimate: f64,
    pub lo: f64,
    pub hi: f64,
}

impl ConfidenceInterval {
    /// Do two intervals fail to overlap? (The paper's significance calls
    /// on benchmark results use non-overlapping bootstrapped 95 % CIs.)
    pub fn disjoint_from(&self, other: &ConfidenceInterval) -> bool {
        self.hi < other.lo || other.hi < self.lo
    }
}

/// Percentile-bootstrap CI for an arbitrary statistic.
pub fn bootstrap_ci(
    xs: &[f64],
    statistic: impl Fn(&[f64]) -> f64,
    level: f64,
    resamples: usize,
    seed: u64,
) -> ConfidenceInterval {
    let estimate = statistic(xs);
    if xs.len() < 2 {
        return ConfidenceInterval {
            estimate,
            lo: estimate,
            hi: estimate,
        };
    }
    let mut rng = Xoshiro256::new(seed);
    let mut stats = Vec::with_capacity(resamples);
    let mut resample = vec![0.0; xs.len()];
    for _ in 0..resamples {
        for slot in resample.iter_mut() {
            *slot = xs[rng.index(xs.len())];
        }
        stats.push(statistic(&resample));
    }
    let alpha = 1.0 - level;
    ConfidenceInterval {
        estimate,
        lo: quantile(&stats, alpha / 2.0),
        hi: quantile(&stats, 1.0 - alpha / 2.0),
    }
}

/// 95 % bootstrap CI of the mean with the crate's default resample count.
pub fn bootstrap_mean_ci95(xs: &[f64], seed: u64) -> ConfidenceInterval {
    bootstrap_ci(xs, mean, 0.95, 2_000, seed)
}

/// Full five-number-style summary used in reports.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let finite: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
        Summary {
            n: finite.len(),
            mean: mean(&finite),
            sd: std_dev(&finite),
            min: finite.iter().copied().fold(f64::INFINITY, f64::min),
            p25: quantile(&finite, 0.25),
            median: median(&finite),
            p75: quantile(&finite, 0.75),
            max: finite.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn quantile_interpolation() {
        let xs = [0.0, 10.0];
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 0.25), 2.5);
        assert_eq!(quantile(&xs, 1.0), 10.0);
    }

    #[test]
    fn quantile_ignores_nan() {
        let xs = [1.0, f64::NAN, 3.0];
        assert_eq!(median(&xs), 2.0);
    }

    #[test]
    fn variance_known_value() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        // population var 4.0 => sample var 4.571428...
        assert!((variance(&xs) - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn bootstrap_ci_contains_mean_for_tight_data() {
        let xs: Vec<f64> = (0..100).map(|i| 10.0 + (i % 5) as f64 * 0.01).collect();
        let ci = bootstrap_mean_ci95(&xs, 42);
        assert!(ci.lo <= ci.estimate && ci.estimate <= ci.hi);
        assert!(ci.hi - ci.lo < 0.02, "tight data must give tight CI: {ci:?}");
    }

    #[test]
    fn bootstrap_ci_widens_with_spread() {
        let tight: Vec<f64> = (0..50).map(|i| 5.0 + 0.001 * i as f64).collect();
        let wide: Vec<f64> = (0..50).map(|i| (i as f64) * 2.0).collect();
        let ci_t = bootstrap_mean_ci95(&tight, 1);
        let ci_w = bootstrap_mean_ci95(&wide, 1);
        assert!((ci_w.hi - ci_w.lo) > (ci_t.hi - ci_t.lo) * 10.0);
    }

    #[test]
    fn disjoint_intervals() {
        let a = ConfidenceInterval {
            estimate: 1.0,
            lo: 0.5,
            hi: 1.5,
        };
        let b = ConfidenceInterval {
            estimate: 3.0,
            lo: 2.5,
            hi: 3.5,
        };
        let c = ConfidenceInterval {
            estimate: 1.4,
            lo: 1.2,
            hi: 2.8,
        };
        assert!(a.disjoint_from(&b));
        assert!(!a.disjoint_from(&c));
        assert!(!b.disjoint_from(&c));
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.p25, 2.0);
        assert_eq!(s.p75, 4.0);
    }
}
