//! Quantile (median) regression for one predictor plus intercept.
//!
//! The paper analyses treatment effects on *medians* with quantile
//! regression (§II-E, Koenker & Hallock 2001). For a single predictor the
//! τ = 0.5 problem — minimize Σ |yᵢ − a − b·xᵢ| — can be solved exactly:
//! an optimal line passes through at least two sample points (a basic
//! solution of the underlying LP), so with the small per-replicate sample
//! sizes the paper uses (tens of observations) exhaustively scoring all
//! point pairs is both exact and fast. For larger inputs we fall back to
//! iteratively-reweighted least squares (IRLS) with Huber-style smoothing,
//! which converges to the same minimizer up to smoothing tolerance.
//!
//! Inference: rank-score tests are overkill here; we bootstrap the slope
//! (case resampling), matching how the paper's quantile-regression
//! coefficient CIs are displayed (Figs. 5d, 6d, 8d).

use super::descriptive::quantile;
use crate::util::rng::{Rng, Xoshiro256};

/// Fitted median regression `median(y|x) = intercept + slope * x`.
#[derive(Clone, Copy, Debug)]
pub struct QuantRegFit {
    pub intercept: f64,
    pub slope: f64,
    /// Sum of absolute residuals at the optimum.
    pub objective: f64,
    /// Bootstrap 95 % CI for the slope.
    pub slope_ci95: (f64, f64),
    /// Fraction of bootstrap slopes on the opposite side of zero from the
    /// estimate, doubled — an empirical two-sided p-value.
    pub p_value: f64,
    pub n: usize,
}

impl QuantRegFit {
    pub fn significant(&self) -> bool {
        self.p_value < 0.05
    }
}

fn l1_objective(x: &[f64], y: &[f64], a: f64, b: f64) -> f64 {
    x.iter()
        .zip(y)
        .map(|(xi, yi)| (yi - a - b * xi).abs())
        .sum()
}

/// Exact small-n solver: best line through a pair of points.
fn fit_exact(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    let n = x.len();
    let mut best = (0.0, 0.0, f64::INFINITY);
    for i in 0..n {
        for j in (i + 1)..n {
            if (x[i] - x[j]).abs() < 1e-300 {
                continue;
            }
            let b = (y[i] - y[j]) / (x[i] - x[j]);
            let a = y[i] - b * x[i];
            let obj = l1_objective(x, y, a, b);
            if obj < best.2 {
                best = (a, b, obj);
            }
        }
    }
    // Horizontal-line candidate (slope 0 through the median) for the
    // degenerate case where all pairs are vertical.
    let med = quantile(y, 0.5);
    let obj0 = l1_objective(x, y, med, 0.0);
    if obj0 < best.2 {
        best = (med, 0.0, obj0);
    }
    best
}

/// IRLS fallback for large n.
fn fit_irls(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    let n = x.len() as f64;
    // Initialize from OLS.
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let sxx: f64 = x.iter().map(|xi| (xi - mx) * (xi - mx)).sum();
    let sxy: f64 = x.iter().zip(y).map(|(xi, yi)| (xi - mx) * (yi - my)).sum();
    let mut b = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let mut a = my - b * mx;
    let eps = 1e-9;
    for _ in 0..200 {
        // Weighted least squares with w_i = 1/max(|r_i|, eps).
        let (mut sw, mut swx, mut swy, mut swxx, mut swxy) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for (xi, yi) in x.iter().zip(y) {
            let r = (yi - a - b * xi).abs().max(eps);
            let w = 1.0 / r;
            sw += w;
            swx += w * xi;
            swy += w * yi;
            swxx += w * xi * xi;
            swxy += w * xi * yi;
        }
        let det = sw * swxx - swx * swx;
        if det.abs() < 1e-300 {
            break;
        }
        let new_a = (swy * swxx - swx * swxy) / det;
        let new_b = (sw * swxy - swx * swy) / det;
        if (new_a - a).abs() < 1e-12 && (new_b - b).abs() < 1e-12 {
            a = new_a;
            b = new_b;
            break;
        }
        a = new_a;
        b = new_b;
    }
    (a, b, l1_objective(x, y, a, b))
}

fn fit_point(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    if x.len() <= 64 {
        fit_exact(x, y)
    } else {
        fit_irls(x, y)
    }
}

/// Fit median regression with bootstrap inference. `None` if n < 3 or x is
/// constant.
pub fn quantile_regression(x: &[f64], y: &[f64], seed: u64) -> Option<QuantRegFit> {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n < 3 {
        return None;
    }
    let x_min = x.iter().copied().fold(f64::INFINITY, f64::min);
    let x_max = x.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    if !(x_max > x_min) {
        return None;
    }
    let (a, b, obj) = fit_point(x, y);

    const RESAMPLES: usize = 500;
    let mut rng = Xoshiro256::new(seed);
    let mut slopes = Vec::with_capacity(RESAMPLES);
    let mut bx = vec![0.0; n];
    let mut by = vec![0.0; n];
    for _ in 0..RESAMPLES {
        for k in 0..n {
            let i = rng.index(n);
            bx[k] = x[i];
            by[k] = y[i];
        }
        // Degenerate resample (constant x): slope is 0 by convention.
        let rx_min = bx.iter().copied().fold(f64::INFINITY, f64::min);
        let rx_max = bx.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        if rx_max > rx_min {
            slopes.push(fit_point(&bx, &by).1);
        } else {
            slopes.push(0.0);
        }
    }
    let lo = quantile(&slopes, 0.025);
    let hi = quantile(&slopes, 0.975);
    let opposite = slopes
        .iter()
        .filter(|&&s| if b >= 0.0 { s <= 0.0 } else { s >= 0.0 })
        .count() as f64;
    let p_value = (2.0 * opposite / RESAMPLES as f64).min(1.0);

    Some(QuantRegFit {
        intercept: a,
        slope: b,
        objective: obj,
        slope_ci95: (lo, hi),
        p_value,
        n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let x: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|xi| -1.0 + 0.75 * xi).collect();
        let fit = quantile_regression(&x, &y, 1).unwrap();
        assert!((fit.slope - 0.75).abs() < 1e-9);
        assert!((fit.intercept + 1.0).abs() < 1e-9);
        assert!(fit.objective < 1e-9);
    }

    #[test]
    fn robust_to_outliers_where_ols_is_not() {
        // Median regression must shrug off a massive outlier.
        let x: Vec<f64> = (0..21).map(|i| i as f64).collect();
        let mut y: Vec<f64> = x.iter().map(|xi| 2.0 * xi).collect();
        y[20] = 1e6; // gross outlier
        let qfit = quantile_regression(&x, &y, 2).unwrap();
        assert!((qfit.slope - 2.0).abs() < 0.2, "slope={}", qfit.slope);
        let ofit = super::super::ols::ols(&x, &y).unwrap();
        assert!(
            (ofit.slope - 2.0).abs() > 100.0,
            "OLS should be dragged by the outlier; slope={}",
            ofit.slope
        );
    }

    #[test]
    fn detects_median_shift_between_groups() {
        // 0/1-coded treatment: quantile regression slope = median diff.
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..15 {
            x.push(0.0);
            y.push(10.0 + (i % 5) as f64 * 0.1);
            x.push(1.0);
            y.push(13.0 + (i % 5) as f64 * 0.1);
        }
        let fit = quantile_regression(&x, &y, 3).unwrap();
        assert!((fit.slope - 3.0).abs() < 0.2, "slope={}", fit.slope);
        assert!(fit.significant(), "p={}", fit.p_value);
    }

    #[test]
    fn null_effect_insignificant() {
        let mut x = Vec::new();
        let mut y = Vec::new();
        // identical distributions in both groups
        for i in 0..20 {
            x.push((i % 2) as f64);
            y.push((i % 7) as f64);
        }
        let fit = quantile_regression(&x, &y, 4).unwrap();
        assert!(!fit.significant(), "p={}", fit.p_value);
    }

    #[test]
    fn irls_matches_exact_on_moderate_n() {
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        use crate::util::rng::Rng;
        let x: Vec<f64> = (0..60).map(|i| (i % 12) as f64).collect();
        let y: Vec<f64> = x.iter().map(|xi| 1.0 + 0.4 * xi + rng.normal(0.0, 0.3)).collect();
        let (ae, be, _) = fit_exact(&x, &y);
        let (ai, bi, _) = fit_irls(&x, &y);
        assert!((ae - ai).abs() < 0.15, "a: exact={ae} irls={ai}");
        assert!((be - bi).abs() < 0.05, "b: exact={be} irls={bi}");
    }

    #[test]
    fn degenerate_inputs_rejected() {
        assert!(quantile_regression(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0], 0).is_none());
        assert!(quantile_regression(&[1.0, 2.0], &[1.0, 2.0], 0).is_none());
    }
}
