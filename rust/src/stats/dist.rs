//! Probability distribution functions needed for inference.
//!
//! Implemented from scratch (no `statrs` offline): error function via the
//! Abramowitz–Stegun 7.1.26 rational approximation refined by a couple of
//! Newton steps on `erf`, normal CDF, and Student-t CDF via the regularized
//! incomplete beta function (continued-fraction evaluation, Numerical
//! Recipes §6.4).

/// Error function, |err| < 1.5e-7 (A&S 7.1.26).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        std::f64::consts::PI.ln() - (std::f64::consts::PI * x).sin().ln() - ln_gamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = COEF[0];
        let t = x + 7.5;
        for (i, &c) in COEF.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// Regularized incomplete beta function I_x(a, b) via continued fraction.
pub fn betainc(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_beta = ln_gamma(a) + ln_gamma(b) - ln_gamma(a + b);
    let front = (a * x.ln() + b * (1.0 - x).ln() - ln_beta).exp();
    // Use the symmetry relation for faster convergence.
    if x < (a + 1.0) / (a + b + 2.0) {
        front * beta_cf(a, b, x) / a
    } else {
        1.0 - (a * x.ln() + b * (1.0 - x).ln() - ln_beta).exp() * beta_cf(b, a, 1.0 - x) / b
    }
}

/// Lentz continued fraction for the incomplete beta (NR in C, betacf).
fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 3e-14;
    const FPMIN: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // Even step.
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        // Odd step.
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Student-t CDF with `df` degrees of freedom.
pub fn t_cdf(t: f64, df: f64) -> f64 {
    if df <= 0.0 {
        return f64::NAN;
    }
    let x = df / (df + t * t);
    let p = 0.5 * betainc(0.5 * df, 0.5, x);
    if t >= 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Two-sided p-value for a t statistic.
pub fn t_two_sided_p(t: f64, df: f64) -> f64 {
    if !t.is_finite() {
        return if t == 0.0 { 1.0 } else { 0.0 };
    }
    (2.0 * (1.0 - t_cdf(t.abs(), df))).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_points() {
        // The A&S 7.1.26 coefficients sum to 1 - 1e-9, so erf(0) is not
        // exactly 0; the approximation's stated error bound is 1.5e-7.
        assert!((erf(0.0)).abs() < 1.5e-7);
        assert!((erf(1.0) - 0.842_700_79).abs() < 1e-6);
        assert!((erf(-1.0) + 0.842_700_79).abs() < 1e-6);
        assert!((erf(2.0) - 0.995_322_27).abs() < 1e-6);
    }

    #[test]
    fn normal_cdf_reference_points() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1.5e-7);
        assert!((normal_cdf(1.959_963_985) - 0.975).abs() < 1e-5);
        assert!((normal_cdf(-1.644_853_627) - 0.05).abs() < 1e-5);
    }

    #[test]
    fn ln_gamma_reference_points() {
        // Gamma(5) = 24
        assert!((ln_gamma(5.0) - 24.0f64.ln()).abs() < 1e-10);
        // Gamma(0.5) = sqrt(pi)
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn betainc_symmetry_and_bounds() {
        assert_eq!(betainc(2.0, 3.0, 0.0), 0.0);
        assert_eq!(betainc(2.0, 3.0, 1.0), 1.0);
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        let v = betainc(2.5, 1.5, 0.3);
        let w = 1.0 - betainc(1.5, 2.5, 0.7);
        assert!((v - w).abs() < 1e-10);
    }

    #[test]
    fn t_cdf_reference_points() {
        // t with df=10: P(T < 2.228) ~= 0.975 (critical value table)
        assert!((t_cdf(2.228, 10.0) - 0.975).abs() < 5e-4);
        // df=1 (Cauchy): P(T<1) = 0.75
        assert!((t_cdf(1.0, 1.0) - 0.75).abs() < 1e-6);
        // symmetric
        assert!((t_cdf(1.3, 7.0) + t_cdf(-1.3, 7.0) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn two_sided_p() {
        assert!((t_two_sided_p(2.228, 10.0) - 0.05).abs() < 1e-3);
        assert!(t_two_sided_p(0.0, 10.0) > 0.999);
        assert!(t_two_sided_p(50.0, 10.0) < 1e-6);
    }

    #[test]
    fn t_cdf_approaches_normal_at_high_df() {
        for z in [-2.0, -0.5, 0.0, 1.0, 2.5] {
            assert!(
                (t_cdf(z, 1e6) - normal_cdf(z)).abs() < 1e-4,
                "z={z}"
            );
        }
    }
}
