//! Runtime integration: artifacts load, execute, and match the native
//! computation. Requires `make artifacts`.

use ebcomm::net::{PlacementKind, Topology};
use ebcomm::runtime::{ArtifactManifest, HostTensor, RuntimeClient};
use ebcomm::util::rng::{Rng, Xoshiro256};
use ebcomm::workloads::dishtiny::{native_eval, DeConfig, DishtinyShard, STATE_DIM};
use ebcomm::workloads::graph_coloring::{GcConfig, GraphColoringShard};
use ebcomm::workloads::{HloDishtinyShard, HloGraphColoringShard, ShardWorkload};

fn manifest_or_skip() -> Option<ArtifactManifest> {
    match ArtifactManifest::load(ArtifactManifest::default_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn manifest_lists_every_expected_variant() {
    let Some(m) = manifest_or_skip() else { return };
    for name in [
        "gc_update_1x1",
        "gc_update_8x8",
        "gc_update_32x64",
        "cell_update_16",
        "cell_update_3600",
    ] {
        assert!(m.get(name).is_some(), "missing artifact {name}");
    }
}

#[test]
fn gc_kernel_matches_native_sweep() {
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = RuntimeClient::cpu().unwrap();
    let topo = Topology::new(4, PlacementKind::OnePerNode);
    let cfg = GcConfig {
        simels_per_proc: 64,
        ..GcConfig::default()
    };
    let mut rng = Xoshiro256::new(0xA11CE);
    // Twin shards from identical randomness (same fresh seed stream).
    let mut seed_rng = Xoshiro256::new(0x7717);
    let native = GraphColoringShard::new(cfg, &topo, 1, &mut seed_rng);
    let mut seed_rng = Xoshiro256::new(0x7717);
    let twin = GraphColoringShard::new(cfg, &topo, 1, &mut seed_rng);
    let mut hlo = HloGraphColoringShard::new(twin, &rt, &manifest).unwrap();

    let mut native = native;
    let mut mismatches = 0usize;
    let mut total = 0usize;
    for step in 0..10 {
        let uniforms: Vec<f64> = (0..64).map(|_| rng.next_f64()).collect();
        native.sweep_with_uniforms(&uniforms);
        hlo.sweep_hlo(&uniforms).unwrap();
        total += 64;
        mismatches += native
            .colors()
            .iter()
            .zip(hlo.inner().colors())
            .filter(|(a, b)| a != b)
            .count();
        // Probabilities agree to f32 tolerance.
        for (a, b) in native.probs().iter().zip(hlo.inner().probs()) {
            assert!(
                (a - b).abs() < 1e-4,
                "step {step}: prob mismatch {a} vs {b}"
            );
        }
        // Keep the twins synchronized even if a boundary-u disagreement
        // flipped one color (f32 vs f64 cumsum edge).
        let colors: Vec<u8> = native.colors().to_vec();
        let probs: Vec<f64> = native.probs().to_vec();
        hlo.inner_mut().load_state(&colors, &probs);
    }
    // Sampling-edge disagreements (u within f32 epsilon of a cumsum
    // boundary) are possible but must be vanishingly rare.
    assert!(
        (mismatches as f64) / (total as f64) < 0.005,
        "{mismatches}/{total} color mismatches"
    );
}

#[test]
fn gc_kernel_conflict_count_consistent() {
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = RuntimeClient::cpu().unwrap();
    // 2x2 process mesh: no self-wrap directions, so the ghost view the
    // kernel sees is exactly what local_conflicts() recomputes against.
    let topo = Topology::new(4, PlacementKind::OnePerNode);
    let cfg = GcConfig {
        simels_per_proc: 64,
        ..GcConfig::default()
    };
    let mut rng = Xoshiro256::new(7);
    let inner = GraphColoringShard::new(cfg, &topo, 0, &mut rng);
    let mut hlo = HloGraphColoringShard::new(inner, &rt, &manifest).unwrap();
    for _ in 0..5 {
        let _ = hlo.step(&mut rng);
    }
    // Kernel-reported conflicts use ghost views; for a single shard the
    // ghosts self-wrap, but the kernel's count treats them as fixed
    // neighbors — quality() recomputes on the same view, so they agree.
    let native_count = hlo.inner().local_conflicts() as i32;
    assert_eq!(hlo.last_conflicts, native_count);
}

#[test]
fn de_kernel_matches_native_eval() {
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = RuntimeClient::cpu().unwrap();
    let name = "cell_update_100";
    let spec = manifest.require(name).unwrap();
    let kernel = rt.load_hlo_text(name, &spec.file).unwrap();

    let mut rng = Xoshiro256::new(42);
    let n = 100usize;
    let states: Vec<f32> = (0..n * STATE_DIM).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let coefs: Vec<f32> = (0..n * 2 * STATE_DIM).map(|_| rng.normal(0.0, 0.5) as f32).collect();
    let nbrs: Vec<f32> = (0..n * STATE_DIM).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let resources: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32).collect();
    let inflow = 0.05f32;

    let (exp_states, exp_res) = native_eval(&states, &coefs, &nbrs, &resources, inflow);

    let outputs = kernel
        .run(&[
            HostTensor::f32(states, &[n as i64, STATE_DIM as i64]),
            HostTensor::f32(coefs, &[n as i64, 2 * STATE_DIM as i64]),
            HostTensor::f32(nbrs, &[n as i64, STATE_DIM as i64]),
            HostTensor::f32(resources, &[n as i64]),
            HostTensor::f32(vec![inflow], &[1]),
        ])
        .unwrap();
    let got_states = outputs[0].expect_f32();
    let got_res = outputs[1].expect_f32();
    for (a, b) in exp_states.iter().zip(got_states) {
        assert!((a - b).abs() < 1e-5, "state {a} vs {b}");
    }
    for (a, b) in exp_res.iter().zip(got_res) {
        assert!((a - b).abs() < 1e-5, "resource {a} vs {b}");
    }
}

#[test]
fn hlo_dishtiny_shard_runs_and_evolves() {
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = RuntimeClient::cpu().unwrap();
    let topo = Topology::new(1, PlacementKind::OnePerNode);
    let cfg = DeConfig {
        cells_per_proc: 16,
        ..DeConfig::default()
    };
    let mut rng = Xoshiro256::new(5);
    let inner = DishtinyShard::new(cfg, &topo, 0, &mut rng);
    let mut hlo = HloDishtinyShard::new(inner, &rt, &manifest).unwrap();
    for _ in 0..60 {
        let _ = hlo.step(&mut rng);
    }
    assert!(hlo.inner().mean_resource() > 0.1, "resource must accrue via PJRT path");
}

#[test]
fn executable_cache_returns_same_kernel() {
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = RuntimeClient::cpu().unwrap();
    let spec = manifest.require("gc_update_1x1").unwrap();
    let a = rt.load_hlo_text("gc_update_1x1", &spec.file).unwrap();
    let b = rt.load_hlo_text("gc_update_1x1", &spec.file).unwrap();
    assert_eq!(a.name(), b.name());
}
