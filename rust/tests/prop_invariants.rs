//! Property-based tests on coordinator invariants: routing, batching,
//! delivery accounting, and barrier semantics under randomized
//! configurations (via the in-repo `testing::prop` framework).

use ebcomm::net::{PlacementKind, Topology};
use ebcomm::sim::{healthy_profiles, AsyncMode, Engine, ModeTiming, SimConfig};
use ebcomm::testing::prop::{forall, prop_assert, Config};
use ebcomm::util::rng::Xoshiro256;
use ebcomm::util::MILLI;
use ebcomm::workloads::graph_coloring::{GcConfig, GraphColoringShard};
use ebcomm::workloads::{reciprocal_layer, ShardWorkload};

fn run_gc(
    n_procs: usize,
    simels: usize,
    mode: AsyncMode,
    buffer: usize,
    run_ms: u64,
    seed: u64,
    placement: PlacementKind,
) -> ebcomm::sim::SimResult<GraphColoringShard> {
    let topo = Topology::new(n_procs, placement);
    let mut rng = Xoshiro256::new(seed);
    let shards: Vec<_> = (0..n_procs)
        .map(|r| {
            GraphColoringShard::new(
                GcConfig {
                    simels_per_proc: simels,
                    ..GcConfig::default()
                },
                &topo,
                r,
                &mut rng,
            )
        })
        .collect();
    let mut cfg = SimConfig::from_env(mode, ModeTiming::graph_coloring(n_procs), run_ms * MILLI);
    cfg.seed = seed;
    cfg.send_buffer = buffer;
    let profiles = healthy_profiles(&topo);
    Engine::new(cfg, topo, profiles, shards).run()
}

#[test]
fn prop_delivery_accounting_never_exceeds_attempts() {
    forall(Config::default().cases(24).seed(0xACC7), |g| {
        let n_procs = *g.choose(&[1usize, 2, 4, 9, 16]);
        let simels = *g.choose(&[1usize, 4, 16]);
        let mode = AsyncMode::ALL[g.usize_in(0, 4)];
        let buffer = g.usize_in(1, 64);
        let seed = g.u64_in(0, u64::MAX / 2);
        let r = run_gc(
            n_procs,
            simels,
            mode,
            buffer,
            20,
            seed,
            PlacementKind::OnePerNode,
        );
        prop_assert(
            r.successful_sends <= r.attempted_sends,
            format!(
                "successful {} > attempted {}",
                r.successful_sends, r.attempted_sends
            ),
        )?;
        if mode == AsyncMode::NoComm {
            prop_assert(r.attempted_sends == 0, "mode 4 must be silent")?;
        }
        prop_assert(
            (0.0..=1.0).contains(&r.overall_failure_rate()),
            "failure rate out of range",
        )
    });
}

#[test]
fn prop_sync_mode_is_lockstep_for_any_topology() {
    forall(Config::default().cases(16).seed(0x10C4), |g| {
        let n_procs = g.usize_in(2, 12);
        let seed = g.u64_in(0, u64::MAX / 2);
        let r = run_gc(
            n_procs,
            4,
            AsyncMode::Sync,
            8,
            15,
            seed,
            PlacementKind::OnePerNode,
        );
        let min = r.updates.iter().min().unwrap();
        let max = r.updates.iter().max().unwrap();
        prop_assert(
            max - min <= 1,
            format!("sync lockstep violated: {:?}", r.updates),
        )
    });
}

#[test]
fn prop_update_counts_positive_and_bounded_by_time() {
    forall(Config::default().cases(16).seed(0xB0), |g| {
        let n_procs = g.usize_in(1, 8);
        let run_ms = g.u64_in(5, 40);
        let seed = g.u64_in(0, u64::MAX / 2);
        let r = run_gc(
            n_procs,
            1,
            AsyncMode::BestEffort,
            64,
            run_ms,
            seed,
            PlacementKind::OnePerNode,
        );
        // A 1-simel update costs >= ~3.5us of compute alone, so updates
        // can never exceed run_for / base_cost.
        let hard_cap = (run_ms * MILLI) as f64 / 3_000.0;
        for &u in &r.updates {
            prop_assert(u > 0, "zero updates")?;
            prop_assert(
                (u as f64) < hard_cap,
                format!("updates {u} exceed physical cap {hard_cap}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_channel_routing_is_reciprocal_for_all_workloads() {
    use ebcomm::workloads::dishtiny::{DeConfig, DishtinyShard};
    forall(Config::default().cases(24).seed(0x51AB), |g| {
        let n_procs = *g.choose(&[2usize, 4, 6, 9, 16, 25]);
        let topo = Topology::new(n_procs, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(g.u64_in(0, u64::MAX / 2));
        let gc: Vec<_> = (0..n_procs)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 4,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let de: Vec<_> = (0..n_procs)
            .map(|r| {
                DishtinyShard::new(
                    DeConfig {
                        cells_per_proc: 4,
                        ..DeConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let gc_specs: Vec<_> = gc.iter().map(|s| s.channels()).collect();
        let de_specs: Vec<_> = de.iter().map(|s| s.channels()).collect();
        for specs in [&gc_specs, &de_specs] {
            for (rank, list) in specs.iter().enumerate() {
                for spec in list {
                    let found = specs[spec.peer]
                        .iter()
                        .any(|s| s.peer == rank && s.layer == reciprocal_layer(spec.layer));
                    prop_assert(
                        found,
                        format!("rank {rank} spec {spec:?} lacks reciprocal"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_determinism_across_identical_configs() {
    forall(Config::default().cases(8).seed(0xDE70), |g| {
        let n_procs = g.usize_in(1, 6);
        let mode = AsyncMode::ALL[g.usize_in(0, 4)];
        let seed = g.u64_in(0, u64::MAX / 2);
        let a = run_gc(n_procs, 4, mode, 8, 15, seed, PlacementKind::OnePerNode);
        let b = run_gc(n_procs, 4, mode, 8, 15, seed, PlacementKind::OnePerNode);
        prop_assert(a.updates == b.updates, "update counts diverged")?;
        prop_assert(
            a.attempted_sends == b.attempted_sends
                && a.successful_sends == b.successful_sends,
            "send accounting diverged",
        )?;
        let ca: Vec<u8> = a.shards.iter().flat_map(|s| s.colors().to_vec()).collect();
        let cb: Vec<u8> = b.shards.iter().flat_map(|s| s.colors().to_vec()).collect();
        prop_assert(ca == cb, "final state diverged")
    });
}

#[test]
fn prop_qos_metrics_in_range_for_random_windows() {
    use ebcomm::qos::SnapshotSchedule;
    forall(Config::default().cases(10).seed(0x905), |g| {
        let n_procs = *g.choose(&[2usize, 4]);
        let seed = g.u64_in(0, u64::MAX / 2);
        let topo = Topology::new(n_procs, PlacementKind::OnePerNode);
        let mut rng = Xoshiro256::new(seed);
        let shards: Vec<_> = (0..n_procs)
            .map(|r| {
                GraphColoringShard::new(
                    GcConfig {
                        simels_per_proc: 1,
                        ..GcConfig::default()
                    },
                    &topo,
                    r,
                    &mut rng,
                )
            })
            .collect();
        let mut cfg = SimConfig::from_env(
            AsyncMode::BestEffort,
            ModeTiming::graph_coloring(n_procs),
            120 * MILLI,
        );
        cfg.seed = seed;
        cfg.send_buffer = 64;
        // Asserts on the exact snapshot stream: pin the storage mode so
        // `EBCOMM_QOS=sketch` cannot empty it.
        cfg.qos_storage = ebcomm::qos::QosStorage::Exact;
        cfg.snapshots = Some(SnapshotSchedule::compressed(
            30 * MILLI,
            30 * MILLI,
            10 * MILLI,
            3,
        ));
        let r = Engine::new(cfg, topo.clone(), healthy_profiles(&topo), shards).run();
        prop_assert(!r.qos.snapshots.is_empty(), "no snapshots collected")?;
        for m in &r.qos.snapshots {
            prop_assert(
                (0.0..=1.0).contains(&m.delivery_failure_rate),
                format!("failure rate {}", m.delivery_failure_rate),
            )?;
            prop_assert(
                (0.0..=1.0).contains(&m.delivery_clumpiness),
                format!("clumpiness {}", m.delivery_clumpiness),
            )?;
            prop_assert(m.simstep_period_ns > 0.0, "period <= 0")?;
            prop_assert(
                m.simstep_latency >= 0.0 && m.walltime_latency_ns >= 0.0,
                "negative latency",
            )?;
        }
        Ok(())
    });
}
